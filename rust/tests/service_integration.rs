//! End-to-end service integration: the `cugwas serve` acceptance
//! scenario through the public API — a TOML-configured queue of three
//! jobs (two sharing a dataset) runs to completion, the shared-dataset
//! second pass is served by the block cache, and the streamed results
//! still match the in-core oracle.

use cugwas::config::ServiceConfig;
use cugwas::coordinator::verify_against_oracle;
use cugwas::gwas::problem::Dims;
use cugwas::service::serve;
use cugwas::storage::generate;
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_svc_it_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn toml_configured_service_runs_shared_and_solo_jobs() {
    let s1 = tmpdir("shared");
    let s2 = tmpdir("solo");
    generate(&s1, Dims::new(48, 3, 128).unwrap(), 16, 101).unwrap();
    generate(&s2, Dims::new(40, 2, 96).unwrap(), 16, 102).unwrap();

    let toml = format!(
        r#"[service]
workers = 2
mem_budget_mb = 512
cache_mb = 32

[job.alpha]
dataset = "{s1}"
block = 16
priority = 2

[job.beta]
dataset = "{s2}"
block = 16

[job.gamma]
dataset = "{s1}"
block = 16
# Inert while adapt=false, but it keeps gamma from coalescing onto
# alpha's pass — this test wants gamma to stream (from the cache).
adapt_every = 32
"#,
        s1 = s1.display(),
        s2 = s2.display(),
    );
    let cfg = ServiceConfig::from_toml(&toml).unwrap();
    assert_eq!(cfg.jobs.len(), 3);
    let rep = serve(&cfg).unwrap();

    // All three jobs completed.
    assert_eq!(rep.jobs.len(), 3, "{}", rep.render());
    assert_eq!(rep.failed(), 0, "{}", rep.render());
    assert_eq!(rep.total_snps(), 128 + 96 + 128);

    // gamma (same dataset as alpha, lower priority → runs after it)
    // streamed entirely from the cache: 128/16 = 8 block hits.
    let gamma = rep.jobs.iter().find(|j| j.name == "gamma").unwrap();
    assert_eq!(gamma.cache_hits, 8, "{}", rep.render());
    assert_eq!(gamma.cache_misses, 0, "{}", rep.render());
    assert!(rep.cache.hits >= 8);
    assert!(rep.cache.misses > 0, "first passes still read the disk");

    // The report surfaces per-job phase metrics and the cache lines.
    let rendered = rep.render();
    assert!(rendered.contains("phases for job 'gamma'"), "{rendered}");
    assert!(rendered.contains("cache_hit"), "{rendered}");
    assert!(rendered.contains("block cache:"), "{rendered}");

    // Streamed results are still correct on both datasets.
    verify_against_oracle(&s1, 1e-7).unwrap();
    verify_against_oracle(&s2, 1e-7).unwrap();

    std::fs::remove_dir_all(&s1).unwrap();
    std::fs::remove_dir_all(&s2).unwrap();
}

#[test]
fn repeated_serve_reuses_nothing_across_instances() {
    // Each serve() owns a fresh cache: counters start from zero, so
    // reports are attributable to one service run.
    let d = tmpdir("fresh");
    generate(&d, Dims::new(32, 2, 64).unwrap(), 16, 7).unwrap();
    let toml = format!(
        "[service]\nworkers = 1\ncache_mb = 16\n\n[job.only]\ndataset = \"{}\"\nblock = 16\n",
        d.display()
    );
    let cfg = ServiceConfig::from_toml(&toml).unwrap();
    let first = serve(&cfg).unwrap();
    let second = serve(&cfg).unwrap();
    assert_eq!(first.cache.hits, 0, "single pass cannot hit");
    assert_eq!(second.cache.hits, 0, "new instance starts cold");
    assert_eq!(first.cache.misses, second.cache.misses);
    std::fs::remove_dir_all(&d).unwrap();
}
