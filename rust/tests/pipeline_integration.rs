//! End-to-end integration: stream a dataset from disk through the full
//! multibuffered pipeline and compare against the in-core oracle
//! (Listing 1.1). Native backend — PJRT-artifact runs live in
//! `runtime_integration.rs` (gated on `make artifacts`).

use cugwas::coordinator::{run, verify_against_oracle, OffloadMode, PipelineConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::{generate, Throttle};
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_e2e_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn run_and_verify(tag: &str, dims: Dims, cfg_mut: impl FnOnce(&mut PipelineConfig)) {
    let dir = tmpdir(tag);
    generate(&dir, dims, 8.min(dims.m), 42).unwrap();
    let mut cfg = PipelineConfig::new(&dir, 8);
    cfg_mut(&mut cfg);
    let report = run(&cfg).unwrap();
    assert_eq!(report.snps, dims.m);
    let diff = verify_against_oracle(&dir, 1e-8).unwrap();
    assert!(diff < 1e-8, "diff={diff}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn single_lane_trsm_mode_matches_oracle() {
    run_and_verify("single", Dims::new(32, 3, 40).unwrap(), |_| {});
}

#[test]
fn tail_block_handled() {
    // 37 SNPs with block 8 → 5 blocks, last has 5 columns.
    run_and_verify("tail", Dims::new(24, 2, 37).unwrap(), |_| {});
}

#[test]
fn single_block_study() {
    // m < block: one partial block, exercises warmup==drain.
    run_and_verify("oneblock", Dims::new(24, 2, 5).unwrap(), |_| {});
}

#[test]
fn exactly_two_blocks() {
    run_and_verify("twoblocks", Dims::new(24, 2, 16).unwrap(), |_| {});
}

#[test]
fn multi_lane_matches_oracle() {
    for ngpus in [2, 4] {
        run_and_verify(&format!("multi{ngpus}"), Dims::new(24, 3, 48).unwrap(), |c| {
            c.ngpus = ngpus;
        });
    }
}

#[test]
fn multi_lane_with_ragged_tail() {
    // Tail block smaller than one lane chunk: some lanes idle on the tail.
    run_and_verify("ragged", Dims::new(20, 2, 35).unwrap(), |c| {
        c.ngpus = 4;
    });
}

#[test]
fn fused_block_mode_matches_oracle() {
    run_and_verify("fused", Dims::new(28, 3, 30).unwrap(), |c| {
        c.mode = OffloadMode::Block;
    });
}

#[test]
fn blockfull_mode_matches_oracle() {
    run_and_verify("blockfull", Dims::new(28, 3, 30).unwrap(), |c| {
        c.mode = OffloadMode::BlockFull;
        c.ngpus = 2;
    });
}

#[test]
fn two_host_buffers_still_correct() {
    run_and_verify("hb2", Dims::new(24, 2, 33).unwrap(), |c| {
        c.host_buffers = 2;
    });
}

#[test]
fn many_host_buffers_still_correct() {
    run_and_verify("hb6", Dims::new(24, 2, 33).unwrap(), |c| {
        c.host_buffers = 6;
    });
}

#[test]
fn throttled_storage_still_correct() {
    run_and_verify("throttle", Dims::new(24, 2, 24).unwrap(), |c| {
        c.read_throttle = Some(Throttle { bytes_per_sec: 2e6 });
        c.write_throttle = Some(Throttle { bytes_per_sec: 2e6 });
    });
}

#[test]
fn report_metrics_are_populated() {
    use cugwas::coordinator::Phase;
    let dir = tmpdir("metrics");
    generate(&dir, Dims::new(24, 2, 32).unwrap(), 8, 1).unwrap();
    let cfg = PipelineConfig::new(&dir, 8);
    let report = run(&cfg).unwrap();
    assert_eq!(report.blocks, 4);
    assert!(report.wall_secs > 0.0);
    assert!(report.snps_per_sec > 0.0);
    assert!(report.device_secs > 0.0);
    assert_eq!(report.metrics.count(Phase::DeviceCompute), 4);
    assert!(report.metrics.count(Phase::Sloop) >= 4);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn invalid_configs_rejected() {
    let dir = tmpdir("invalid");
    generate(&dir, Dims::new(16, 2, 8).unwrap(), 4, 1).unwrap();
    let mut cfg = PipelineConfig::new(&dir, 4);
    cfg.ngpus = 0;
    assert!(run(&cfg).is_err());
    let mut cfg = PipelineConfig::new(&dir, 5);
    cfg.ngpus = 2; // 5 % 2 != 0
    assert!(run(&cfg).is_err());
    let mut cfg = PipelineConfig::new(&dir, 4);
    cfg.host_buffers = 1;
    assert!(run(&cfg).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn missing_dataset_is_clean_error() {
    let cfg = PipelineConfig::new("/nonexistent/dataset", 8);
    assert!(run(&cfg).is_err());
}

#[test]
fn rerun_overwrites_results() {
    let dir = tmpdir("rerun");
    let dims = Dims::new(20, 2, 16).unwrap();
    generate(&dir, dims, 8, 9).unwrap();
    let cfg = PipelineConfig::new(&dir, 8);
    run(&cfg).unwrap();
    run(&cfg).unwrap(); // second run must recreate r.xrd cleanly
    verify_against_oracle(&dir, 1e-8).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---- XRD v2: f32 storage (the paper's footnote-3 half-storage mode) ----

#[test]
fn f32_storage_halves_the_file_and_matches_oracle() {
    use cugwas::storage::{generate_with_dtype, Dtype};
    let dims = Dims::new(24, 2, 32).unwrap();
    let d64 = tmpdir("f64mode");
    let d32 = tmpdir("f32mode");
    generate(&d64, dims, 8, 99).unwrap();
    generate_with_dtype(&d32, dims, 8, 99, Dtype::F32).unwrap();

    // Half the X_R bytes (modulo the fixed header).
    let sz = |d: &std::path::Path| std::fs::metadata(d.join("xr.xrd")).unwrap().len() - 64;
    assert_eq!(sz(&d32) * 2, sz(&d64));

    // Identical genotype payload (allele counts are exact in f32)…
    let x64 = cugwas::storage::load_xr_incore(&d64).unwrap();
    let x32 = cugwas::storage::load_xr_incore(&d32).unwrap();
    assert_eq!(x64, x32);

    // …so the streamed solve matches the oracle bit-for-bit tolerance.
    run(&PipelineConfig::new(&d32, 8)).unwrap();
    verify_against_oracle(&d32, 1e-8).unwrap();
    std::fs::remove_dir_all(&d64).unwrap();
    std::fs::remove_dir_all(&d32).unwrap();
}

#[test]
fn f32_results_file_roundtrips_with_precision_loss_bounded() {
    use cugwas::storage::{Dtype, Header, XrdFile};
    let p = std::env::temp_dir().join(format!("cugwas_f32r_{}.xrd", std::process::id()));
    let h = Header::with_dtype(4, 6, 3, 0, Dtype::F32).unwrap();
    let f = XrdFile::create(&p, h).unwrap();
    let vals: Vec<f64> = (0..12).map(|i| 0.1 * i as f64 + 1e-9).collect();
    f.write_cols(0, 3, &vals).unwrap();
    let mut back = vec![0.0; 12];
    f.read_cols_into(0, 3, &mut back).unwrap();
    for (a, b) in vals.iter().zip(&back) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}"); // f32 rounding only
    }
    std::fs::remove_file(&p).unwrap();
}

// ---- checkpoint / resume (long runs must survive interruption) ----------

/// v3 journal layout: 32-byte header (magic + m + block + traits) then
/// 16-byte (col0, ncols) records.
const JHEADER: usize = 32;
const JRECORD: usize = 16;

#[test]
fn resume_skips_journaled_blocks_and_result_is_complete() {
    use cugwas::storage::dataset::DatasetPaths;
    let dims = Dims::new(24, 2, 40).unwrap(); // 5 blocks of 8
    let dir = tmpdir("resume");
    generate(&dir, dims, 8, 31).unwrap();

    // Full run with journaling (resume=true on a fresh dir journals all).
    let mut cfg = PipelineConfig::new(&dir, 8);
    cfg.resume = true;
    let r1 = run(&cfg).unwrap();
    assert_eq!(r1.blocks, 5);
    let paths = DatasetPaths::new(&dir);
    let journal = std::fs::read(paths.progress()).unwrap();
    assert_eq!(journal.len(), JHEADER + 5 * JRECORD);

    // Simulate a crash after 2 blocks: truncate the journal and clobber
    // the "unfinished" blocks' results with garbage.
    std::fs::write(paths.progress(), &journal[..JHEADER + 2 * JRECORD]).unwrap();
    {
        use cugwas::storage::XrdFile;
        let f = XrdFile::open_rw(&paths.results()).unwrap();
        let junk = vec![f64::NAN; 3 * 8];
        for b in [2u64, 3] {
            f.write_cols(b * 8, 8, &junk).unwrap();
        }
    }
    // Resume: only the 3 unjournaled blocks are recomputed…
    let r2 = run(&cfg).unwrap();
    assert_eq!(r2.blocks, 3, "resume must skip journaled blocks");
    // …and the full result matches the oracle again.
    verify_against_oracle(&dir, 1e-8).unwrap();
    // Journal now covers everything.
    let journal = std::fs::read(paths.progress()).unwrap();
    assert_eq!(journal.len(), JHEADER + 5 * JRECORD);

    // A third resume is a no-op.
    let r3 = run(&cfg).unwrap();
    assert_eq!(r3.blocks, 0);
    verify_against_oracle(&dir, 1e-8).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn non_resume_run_clears_stale_journal() {
    use cugwas::storage::dataset::DatasetPaths;
    let dims = Dims::new(20, 2, 16).unwrap();
    let dir = tmpdir("clearjournal");
    generate(&dir, dims, 8, 7).unwrap();
    let mut cfg = PipelineConfig::new(&dir, 8);
    cfg.resume = true;
    run(&cfg).unwrap();
    // A fresh (non-resume) run must recompute everything.
    cfg.resume = false;
    let r = run(&cfg).unwrap();
    assert_eq!(r.blocks, 2);
    verify_against_oracle(&dir, 1e-8).unwrap();
    let journal = std::fs::read(DatasetPaths::new(&dir).progress()).unwrap();
    assert_eq!(journal.len(), JHEADER + 2 * JRECORD);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn resume_with_changed_block_size_is_refused() {
    // The journal header pins the run parameters that define block
    // indices. Resuming with a different block size used to silently
    // restart (or worse, mis-index); now it must fail loudly with
    // Error::Config, and tell the operator how to proceed.
    use cugwas::storage::dataset::DatasetPaths;
    let dims = Dims::new(20, 2, 24).unwrap();
    let dir = tmpdir("regeom");
    generate(&dir, dims, 8, 3).unwrap();
    let mut cfg = PipelineConfig::new(&dir, 8);
    cfg.resume = true;
    run(&cfg).unwrap();
    // Different block size ⇒ parameter mismatch ⇒ refusal.
    let mut cfg2 = PipelineConfig::new(&dir, 12);
    cfg2.resume = true;
    let err = run(&cfg2).unwrap_err();
    assert!(matches!(err, cugwas::error::Error::Config(_)), "{err}");
    assert!(err.to_string().contains("block=8"), "{err}");
    // Deleting the journal (the remedy the error names) starts clean.
    std::fs::remove_file(DatasetPaths::new(&dir).progress()).unwrap();
    let r = run(&cfg2).unwrap();
    assert_eq!(r.blocks, 2); // 24/12 — full recompute
    verify_against_oracle(&dir, 1e-8).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn torn_journal_tail_is_ignored() {
    use cugwas::storage::dataset::DatasetPaths;
    let dims = Dims::new(20, 2, 24).unwrap();
    let dir = tmpdir("torn");
    generate(&dir, dims, 8, 5).unwrap();
    let mut cfg = PipelineConfig::new(&dir, 8);
    cfg.resume = true;
    run(&cfg).unwrap();
    // Append a torn (partial) record — must be ignored, not crash.
    let paths = DatasetPaths::new(&dir);
    let mut j = std::fs::read(paths.progress()).unwrap();
    j.extend_from_slice(&[0xAB, 0xCD, 0xEF]);
    std::fs::write(paths.progress(), &j).unwrap();
    let r = run(&cfg).unwrap();
    assert_eq!(r.blocks, 0);
    verify_against_oracle(&dir, 1e-8).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}
