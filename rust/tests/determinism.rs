//! Thread-count determinism: the parallel compute plane must not change
//! a single bit of any result. The kernels shard work so that every
//! output element is produced by exactly one task running the exact
//! serial operation sequence — so `r.xrd` (and the oracle diff) must be
//! byte-identical for `threads = 1, 2, 8` on the same dataset, in every
//! offload mode and lane count.

use cugwas::coordinator::{run, verify_against_oracle_multi, OffloadMode, PipelineConfig};
use cugwas::gwas::phenotype_batch;
use cugwas::gwas::problem::Dims;
use cugwas::storage::{generate, XrdFile};
use std::path::{Path, PathBuf};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_det_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Run the pipeline at each thread count and return the raw `r.xrd`
/// bytes plus the oracle diff.
fn results_at(
    dir: &std::path::Path,
    block: usize,
    threads: usize,
    mutate: impl FnOnce(&mut PipelineConfig),
) -> (Vec<u8>, f64) {
    let mut cfg = PipelineConfig::new(dir, block);
    cfg.threads = threads;
    mutate(&mut cfg);
    run(&cfg).unwrap();
    let bytes = std::fs::read(dir.join("r.xrd")).unwrap();
    let diff = verify_against_oracle_multi(dir, 1e-7, cfg.traits, cfg.perm_seed).unwrap();
    (bytes, diff)
}

/// Copy a dataset but replace its phenotype with `y` — how the matrix
/// cell below materializes "the single-trait study whose phenotype IS
/// trait column j of the batch".
fn clone_dataset_with_phenotype(src: &Path, dst: &Path, y: &[f64]) {
    std::fs::create_dir_all(dst).unwrap();
    for f in ["meta.txt", "kinship.bin", "covariates.bin", "xr.xrd"] {
        std::fs::copy(src.join(f), dst.join(f)).unwrap();
    }
    let bytes: Vec<u8> = y.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write(dst.join("phenotype.bin"), bytes).unwrap();
}

#[test]
fn pipeline_results_are_bit_identical_across_thread_counts() {
    // n=128, block=4096 puts the per-block trsm (≈67 MFlop) and the
    // 4096-column S-loop over both parallel gates (flops and columns
    // per worker), so threads=8 genuinely exercises the sharded paths
    // rather than falling back to the serial ones.
    let dir = tmpdir("trsm");
    let dims = Dims::new(128, 3, 8192).unwrap();
    generate(&dir, dims, 256, 4242).unwrap();

    let (ref_bytes, ref_diff) = results_at(&dir, 4096, 1, |_| {});
    for threads in [2, 8] {
        let (bytes, diff) = results_at(&dir, 4096, threads, |_| {});
        assert_eq!(bytes, ref_bytes, "r.xrd changed at threads={threads}");
        assert_eq!(
            diff.to_bits(),
            ref_diff.to_bits(),
            "oracle diff changed at threads={threads}"
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// One cell of the CI determinism matrix: CUGWAS_DET_THREADS ×
/// CUGWAS_DET_LANES × CUGWAS_DET_TRAITS select a configuration from the
/// environment, and its `r.xrd` must be byte-identical to the
/// single-thread run of the same lane count and batch width. CI fans
/// this out over threads ∈ {1,2,8} × lanes ∈ {1,2} × traits ∈ {1,16}
/// (plus CUGWAS_NO_MICROKERNEL ∈ {0,1} cells) on every push, so the
/// bit-identical guarantee is enforced there, not just locally. Without
/// the env vars it checks the 2-thread/1-lane/1-trait cell.
///
/// When any of those env vars is explicitly set (i.e. under the CI
/// matrix, where this test runs alone in its process), the cell also
/// re-runs with the microkernel path *flipped* and asserts the bytes
/// still match: the register-tiled kernels and the scalar reference
/// must be indistinguishable at the `r.xrd` level.
///
/// A multi-trait cell additionally proves the batching theorem the
/// whole feature rests on: trait column `j` of the batched result is
/// byte-identical to a plain single-trait run over the same dataset
/// with that column as its phenotype — with the shared block cache on
/// and off.
#[test]
fn matrix_cell_from_env_is_bit_identical() {
    let threads: usize = std::env::var("CUGWAS_DET_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let lanes: usize = std::env::var("CUGWAS_DET_LANES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let traits: usize = std::env::var("CUGWAS_DET_TRAITS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    const PERM_SEED: u64 = 0xDE7;
    let dir = tmpdir(&format!("matrix_t{threads}_l{lanes}_w{traits}"));
    let dims = Dims::new(96, 2, 2048).unwrap();
    generate(&dir, dims, 256, 99).unwrap();
    let mutate = |c: &mut PipelineConfig| {
        c.ngpus = lanes;
        c.traits = traits;
        c.perm_seed = PERM_SEED;
    };
    let (ref_bytes, ref_diff) = results_at(&dir, 1024, 1, mutate);
    let (bytes, diff) = results_at(&dir, 1024, threads, mutate);
    assert_eq!(
        bytes, ref_bytes,
        "r.xrd changed at threads={threads}, lanes={lanes}, traits={traits}"
    );
    assert_eq!(diff.to_bits(), ref_diff.to_bits());

    // Under the CI matrix (env vars set ⇒ this test runs alone via the
    // exact-name filter, so the process-global switch is race-free),
    // flip the kernel path and demand the same bytes. Locally, with no
    // env set, this is skipped — parallel tests in this binary must not
    // see a forced path.
    let env_driven = ["CUGWAS_DET_THREADS", "CUGWAS_DET_LANES", "CUGWAS_DET_TRAITS"]
        .iter()
        .any(|v| std::env::var_os(v).is_some())
        || std::env::var_os("CUGWAS_NO_MICROKERNEL").is_some();
    if env_driven {
        let no_micro = std::env::var("CUGWAS_NO_MICROKERNEL")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
        cugwas::linalg::micro::set_forced(Some(no_micro)); // the *other* path
        let (flip_bytes, flip_diff) = results_at(&dir, 1024, threads, mutate);
        cugwas::linalg::micro::set_forced(None);
        assert_eq!(
            flip_bytes, ref_bytes,
            "microkernel vs reference path changed r.xrd at threads={threads}, \
             lanes={lanes}, traits={traits}"
        );
        assert_eq!(flip_diff.to_bits(), ref_diff.to_bits());
    }

    // Cache on/off must not move a bit either: the cache only changes
    // where blocks are read from, never what is computed.
    let cache = std::sync::Arc::new(cugwas::storage::BlockCache::new(64 << 20));
    let (cached_bytes, _) = results_at(&dir, 1024, threads, |c: &mut PipelineConfig| {
        mutate(c);
        c.cache = Some(std::sync::Arc::clone(&cache));
    });
    assert_eq!(cached_bytes, ref_bytes, "cache on/off changed the batched result");

    if traits > 1 {
        let p = dims.pl + 1;
        let rfile = XrdFile::open(&dir.join("r.xrd")).unwrap();
        let mut batched = vec![0.0f64; p * traits * dims.m];
        rfile.read_cols_into(0, dims.m as u64, &mut batched).unwrap();
        let (_, _, _, y) = cugwas::storage::dataset::load_sidecars(&dir).unwrap();
        let ys = phenotype_batch(&y, traits, PERM_SEED);
        for j in 0..traits {
            let sdir = tmpdir(&format!("matrix_single_w{traits}_{j}"));
            clone_dataset_with_phenotype(&dir, &sdir, ys.col(j));
            let mut cfg = PipelineConfig::new(&sdir, 1024);
            cfg.threads = threads;
            cfg.ngpus = lanes;
            run(&cfg).unwrap();
            let sfile = XrdFile::open(&sdir.join("r.xrd")).unwrap();
            let mut single = vec![0.0f64; p * dims.m];
            sfile.read_cols_into(0, dims.m as u64, &mut single).unwrap();
            for c in 0..dims.m {
                for r in 0..p {
                    assert_eq!(
                        batched[c * p * traits + j * p + r].to_bits(),
                        single[c * p + r].to_bits(),
                        "trait {j}, snp {c}, row {r} diverged from the single-trait run"
                    );
                }
            }
            std::fs::remove_dir_all(&sdir).unwrap();
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fused_modes_and_multi_lane_are_bit_identical_across_thread_counts() {
    for (tag, mode, ngpus) in [
        ("block", OffloadMode::Block, 1),
        ("blockfull", OffloadMode::BlockFull, 1),
        ("multilane", OffloadMode::Trsm, 2),
    ] {
        let dir = tmpdir(tag);
        // 500 SNPs at block 256 leaves a ragged 244-column tail (split
        // unevenly across lanes in the multi-lane case).
        let dims = Dims::new(128, 2, 500).unwrap();
        generate(&dir, dims, 128, 77).unwrap();
        let mutate = |c: &mut PipelineConfig| {
            c.mode = mode;
            c.ngpus = ngpus;
        };
        let (ref_bytes, _) = results_at(&dir, 256, 1, mutate);
        for threads in [2, 8] {
            let (bytes, _) = results_at(&dir, 256, threads, mutate);
            assert_eq!(bytes, ref_bytes, "{tag}: r.xrd changed at threads={threads}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
