//! PJRT-backend integration: the full python→HLO→rust round trip.
//!
//! These tests need `make artifacts` (they are skipped with a notice when
//! `artifacts/manifest.tsv` is absent, so `cargo test` stays green on a
//! fresh clone). They prove the production configuration: the rust
//! coordinator executing the AOT-compiled Pallas/JAX graphs end to end.

use cugwas::coordinator::{run, verify_against_oracle, BackendKind, OffloadMode, PipelineConfig};
use cugwas::gwas::problem::Dims;
use cugwas::runtime::{default_artifacts_dir, ArtifactKey, Engine, HostTensor, Kind, Manifest};
use cugwas::storage::generate;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = default_artifacts_dir();
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT test: no artifacts at {dir:?} (run `make artifacts`)");
        None
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_pjrt_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// The smallest artifact shape shipped in every profile.
const N: usize = 64;
const PL: usize = 3;
const MB: usize = 32;

#[test]
fn pjrt_trsm_artifact_matches_native_linalg() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest
        .get(&ArtifactKey { kind: Kind::Trsm, n: N, pl: PL, mb: MB })
        .unwrap();

    use cugwas::linalg::{potrf, potrf_invert_diag_blocks, trsm_lower_left, Matrix};
    use cugwas::runtime::{dinv_to_rowmajor, matrix_to_rowmajor};
    use cugwas::util::XorShift;
    let mut rng = XorShift::new(17);
    let m = Matrix::rand_spd(N, 4.0, &mut rng);
    let l = potrf(&m).unwrap();
    let dinv = potrf_invert_diag_blocks(&l, entry.nb).unwrap();
    let xb = Matrix::randn(N, MB, &mut rng);

    let mut engine = Engine::cpu().unwrap();
    let exe = engine.load(entry).unwrap();
    let outs = exe
        .run(&[
            HostTensor::new(vec![N as i64, N as i64], matrix_to_rowmajor(&l)).unwrap(),
            HostTensor::new(vec![N as i64, entry.nb as i64], dinv_to_rowmajor(&dinv, entry.nb, N))
                .unwrap(),
            // (mb, n) row-major == our (n, mb) col-major buffer, as-is.
            HostTensor::new(vec![MB as i64, N as i64], xb.as_slice().to_vec()).unwrap(),
        ])
        .unwrap();
    assert_eq!(outs.len(), 1);
    let got = Matrix::from_vec(N, MB, outs[0].data.clone()).unwrap();

    let mut want = xb.clone();
    trsm_lower_left(&l, &mut want).unwrap();
    let diff = got.max_abs_diff(&want);
    assert!(diff < 1e-9, "pjrt vs native trsm diff {diff}");
}

#[test]
fn pjrt_pipeline_trsm_mode_matches_oracle() {
    let Some(art) = artifacts_dir() else { return };
    let dir = tmpdir("trsm");
    generate(&dir, Dims::new(N, PL, 3 * MB + 7).unwrap(), MB, 21).unwrap();
    let mut cfg = PipelineConfig::new(&dir, MB);
    cfg.backend = BackendKind::Pjrt { artifacts: art };
    let report = run(&cfg).unwrap();
    assert!(report.device_secs > 0.0);
    verify_against_oracle(&dir, 1e-7).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pjrt_pipeline_fused_block_mode_matches_oracle() {
    let Some(art) = artifacts_dir() else { return };
    let dir = tmpdir("block");
    generate(&dir, Dims::new(N, PL, 2 * MB).unwrap(), MB, 22).unwrap();
    let mut cfg = PipelineConfig::new(&dir, MB);
    cfg.backend = BackendKind::Pjrt { artifacts: art };
    cfg.mode = OffloadMode::Block;
    run(&cfg).unwrap();
    verify_against_oracle(&dir, 1e-7).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pjrt_pipeline_blockfull_mode_matches_oracle() {
    let Some(art) = artifacts_dir() else { return };
    let dir = tmpdir("blockfull");
    generate(&dir, Dims::new(N, PL, 2 * MB + 3).unwrap(), MB, 23).unwrap();
    let mut cfg = PipelineConfig::new(&dir, MB);
    cfg.backend = BackendKind::Pjrt { artifacts: art };
    cfg.mode = OffloadMode::BlockFull;
    run(&cfg).unwrap();
    verify_against_oracle(&dir, 1e-7).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pjrt_multi_lane_matches_oracle() {
    let Some(art) = artifacts_dir() else { return };
    let dir = tmpdir("multi");
    generate(&dir, Dims::new(N, PL, 4 * MB).unwrap(), MB, 24).unwrap();
    let mut cfg = PipelineConfig::new(&dir, 2 * MB); // 2 lanes × MB each
    cfg.ngpus = 2;
    cfg.backend = BackendKind::Pjrt { artifacts: art };
    run(&cfg).unwrap();
    verify_against_oracle(&dir, 1e-7).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn missing_artifact_shape_is_clean_config_error() {
    let Some(art) = artifacts_dir() else { return };
    let dir = tmpdir("missing");
    // n=48 exists in no profile.
    generate(&dir, Dims::new(48, PL, 64).unwrap(), 32, 25).unwrap();
    let mut cfg = PipelineConfig::new(&dir, 32);
    cfg.backend = BackendKind::Pjrt { artifacts: art };
    let err = run(&cfg).unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pjrt_preprocess_artifact_matches_native_preprocess() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let entry = manifest
        .get(&ArtifactKey { kind: Kind::Preprocess, n: N, pl: PL, mb: 0 })
        .unwrap();

    use cugwas::gwas::preprocess::preprocess;
    use cugwas::gwas::problem::{Dims, Problem};
    use cugwas::runtime::{matrix_to_rowmajor, rowmajor_to_matrix};
    let prob = Problem::synthetic(Dims::new(N, PL, 4).unwrap(), 33).unwrap();
    let native = preprocess(&prob.m, &prob.xl, &prob.y, entry.nb).unwrap();

    let mut engine = Engine::cpu().unwrap();
    let exe = engine.load(entry).unwrap();
    let outs = exe
        .run(&[
            HostTensor::new(vec![N as i64, N as i64], matrix_to_rowmajor(&prob.m)).unwrap(),
            HostTensor::new(vec![N as i64, PL as i64], matrix_to_rowmajor(&prob.xl)).unwrap(),
            HostTensor::new(vec![N as i64], prob.y.clone()).unwrap(),
        ])
        .unwrap();
    // Outputs: l, dinv, xlt, yt, stl, rtop (model.preprocess_entry).
    assert_eq!(outs.len(), 6);
    let l = rowmajor_to_matrix(N, N, &outs[0].data);
    assert!(l.max_abs_diff(&native.l) < 1e-8, "L: {}", l.max_abs_diff(&native.l));
    let xlt = rowmajor_to_matrix(N, PL, &outs[2].data);
    assert!(xlt.max_abs_diff(&native.xl_t) < 1e-8);
    for (a, b) in outs[3].data.iter().zip(&native.y_t) {
        assert!((a - b).abs() < 1e-8);
    }
    let stl = rowmajor_to_matrix(PL, PL, &outs[4].data);
    assert!(stl.max_abs_diff(&native.stl) < 1e-8);
    for (a, b) in outs[5].data.iter().zip(&native.rtop) {
        assert!((a - b).abs() < 1e-8);
    }
    // Dinv: the artifact's (n, nb) row-major stack vs native layout.
    use cugwas::runtime::dinv_to_rowmajor;
    let want = dinv_to_rowmajor(native.dinv.as_ref().unwrap(), entry.nb, N);
    for (a, b) in outs[1].data.iter().zip(&want) {
        assert!((a - b).abs() < 1e-8);
    }
}
