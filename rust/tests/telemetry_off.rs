//! Default-off telemetry: a full pipeline run in a process that never
//! enables the flags must leave the trace ring empty and the registry
//! untouched — the observability plane is a strict no-op unless asked
//! for (the acceptance criterion behind keeping `determinism.rs`
//! bit-identical and the hot path free of telemetry work).
//!
//! Own binary = own process: nothing else here can flip the globals.

use cugwas::coordinator::{run, PipelineConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::generate;
use cugwas::telemetry::{self, registry, StallKind};
use std::path::PathBuf;

#[test]
fn disabled_telemetry_records_nothing() {
    assert!(!telemetry::metrics_enabled());
    assert!(!telemetry::trace_enabled());

    let d: PathBuf =
        std::env::temp_dir().join(format!("cugwas_telemetry_off_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    generate(&d, Dims::new(24, 2, 48).unwrap(), 8, 5).unwrap();
    let report = run(&PipelineConfig::new(&d, 8)).unwrap();
    assert_eq!(report.snps, 48);
    // The in-report accounting still works (it predates the telemetry
    // plane and never depended on the flags) …
    assert!(report.wall_secs > 0.0);
    assert!(!report.stall.render().is_empty());

    // … but the global plane saw none of it. Reading the registry here
    // materializes it — that is the test, not a contradiction: even
    // after a full run, every cell still holds its initial value.
    assert_eq!(telemetry::global_trace().len(), 0, "spans recorded with tracing off");
    let reg = registry::global();
    assert_eq!(reg.jobs_done_total.get(), 0);
    assert_eq!(reg.snps_total.get(), 0);
    assert_eq!(reg.blocks_total.get(), 0);
    assert_eq!(reg.bytes_copied_total.get(), 0);
    assert_eq!(reg.bytes_borrowed_total.get(), 0);
    assert_eq!(reg.cache_misses_total.get(), 0);
    assert_eq!(reg.slab_minted_total.get(), 0);
    for idx in 0..10 {
        assert_eq!(reg.phase_hist(idx).count(), 0, "phase {idx} observed with metrics off");
    }
    for k in StallKind::ALL {
        assert_eq!(reg.stall_count(k), 0);
    }
    assert_eq!(reg.snps_per_sec.get(), 0.0);

    std::fs::remove_dir_all(&d).unwrap();
}
