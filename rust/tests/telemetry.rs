//! Telemetry-plane integration: with the metrics and trace flags ON,
//! real pipeline/service runs populate the Prometheus exposition, the
//! span ring exports Chrome-trace JSON, and the HTTP endpoint answers
//! scrapes.
//!
//! This binary is its own process (unlike the lib unit tests), so it is
//! the one place the global enable flags get flipped on. Tests within
//! it may run concurrently against the shared global registry, so every
//! assertion is monotone (`>=`, `contains`) rather than exact-count.

use cugwas::config::ServiceConfig;
use cugwas::coordinator::{run, PipelineConfig};
use cugwas::gwas::problem::Dims;
use cugwas::service::serve;
use cugwas::storage::generate;
use cugwas::telemetry::{self, registry, StallKind};
use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_telemetry_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn enable() {
    telemetry::set_metrics_enabled(true);
    telemetry::set_trace_enabled(true);
}

/// Extract the value of an unlabeled counter/gauge line from the
/// exposition text.
fn series_value(text: &str, name: &str) -> f64 {
    let line = text
        .lines()
        .find(|l| l.starts_with(name) && l.as_bytes().get(name.len()) == Some(&b' '))
        .unwrap_or_else(|| panic!("series {name} missing from exposition:\n{text}"));
    line[name.len() + 1..].trim().parse().unwrap()
}

#[test]
fn serve_run_populates_the_prometheus_exposition() {
    enable();
    let d = tmpdir("serve");
    generate(&d, Dims::new(32, 2, 64).unwrap(), 16, 9).unwrap();
    // Two jobs on one dataset: the second streams from the shared cache,
    // so hit and miss phases both land in the histograms. Its
    // adapt_every nudge (inert while adapt=false) keeps it from
    // coalescing onto the first job's pass — this test needs the second
    // pass to actually stream.
    let toml = format!(
        "[service]\nworkers = 1\ncache_mb = 16\n\n\
         [job.first]\ndataset = \"{d}\"\nblock = 16\n\n\
         [job.second]\ndataset = \"{d}\"\nblock = 16\nadapt_every = 32\n",
        d = d.display()
    );
    let cfg = ServiceConfig::from_toml(&toml).unwrap();
    let rep = serve(&cfg).unwrap();
    assert_eq!(rep.failed(), 0, "{}", rep.render());

    let text = registry::global().render();
    // Required series from the acceptance criteria: phase histograms,
    // queue/cache/slab gauges, the data-plane byte counters.
    for needle in [
        "# TYPE cugwas_phase_seconds histogram",
        "cugwas_phase_seconds_bucket{phase=\"read_wait\",le=\"+Inf\"}",
        "cugwas_phase_seconds_bucket{phase=\"sloop\",le=\"+Inf\"}",
        "cugwas_phase_seconds_bucket{phase=\"cache_hit\",le=\"+Inf\"}",
        "# TYPE cugwas_job_wall_seconds histogram",
        "# TYPE cugwas_snps_per_sec gauge",
        "cugwas_queue_depth",
        "cugwas_mem_budget_bytes",
        "cugwas_cache_hits_total",
        "cugwas_cache_resident_bytes",
        "cugwas_slab_recycled_total",
        "cugwas_bytes_copied_total",
        "cugwas_bytes_borrowed_total",
        "cugwas_stall_segments_total{verdict=\"read_bound\"}",
        "cugwas_stall_share",
        "# TYPE cugwas_faults_injected_total counter",
        "cugwas_read_retries_total",
        "cugwas_lane_respawns_total",
        "cugwas_job_retries_total",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in exposition:\n{text}");
    }
    assert!(series_value(&text, "cugwas_jobs_done_total") >= 2.0, "{text}");
    assert!(series_value(&text, "cugwas_snps_total") >= 128.0, "{text}");
    assert!(series_value(&text, "cugwas_blocks_total") >= 8.0, "{text}");
    assert!(series_value(&text, "cugwas_cache_hits_total") >= 4.0, "{text}");
    assert!(series_value(&text, "cugwas_snps_per_sec") > 0.0, "{text}");
    // Every segment got a stall verdict.
    let verdicts: u64 = StallKind::ALL.iter().map(|k| registry::global().stall_count(*k)).sum();
    assert!(verdicts >= 1, "no stall verdicts recorded");

    // Exposition validity: every sample line belongs to a # TYPE'd
    // family, and bucket counts are cumulative (monotone, +Inf == count).
    for line in text.lines() {
        if line.starts_with('#') {
            let mut it = line.split_whitespace();
            assert!(matches!(it.next(), Some("#")));
            assert!(matches!(it.next(), Some("HELP") | Some("TYPE")), "{line}");
        } else {
            assert!(line.starts_with("cugwas_"), "unprefixed sample: {line}");
            assert!(line.rsplit(' ').next().unwrap().parse::<f64>().is_ok(), "{line}");
        }
    }
    let read_wait = registry::global().phase_hist(0);
    let cum = read_wait.cumulative();
    assert!(cum.windows(2).all(|w| w[0] <= w[1]), "{cum:?}");
    assert!(read_wait.count() >= *cum.last().unwrap(), "+Inf >= last bound");

    std::fs::remove_dir_all(&d).unwrap();
}

#[test]
fn pipeline_run_records_spans_and_exports_chrome_trace() {
    enable();
    let d = tmpdir("trace");
    generate(&d, Dims::new(24, 2, 48).unwrap(), 8, 11).unwrap();
    let cfg = PipelineConfig::new(&d, 8);
    let report = run(&cfg).unwrap();
    assert_eq!(report.snps, 48);
    // The report carries whole-run stall attribution.
    assert!((0.0..=1.0).contains(&report.stall.share));
    assert!(!report.stall.render().is_empty());

    let sink = telemetry::global_trace();
    assert!(!sink.is_empty(), "a traced run must record spans");
    let spans = sink.snapshot();
    assert!(
        spans.iter().any(|s| s.name == "device_compute"),
        "lane compute spans missing"
    );
    assert!(spans.iter().any(|s| s.cat == "io"), "aio spans missing");

    // Chrome trace-event schema: what Perfetto actually requires — a
    // traceEvents array of complete ("X") events with name/tid/ts/dur.
    let out = d.join("trace.json");
    sink.export_chrome(&out).unwrap();
    let json = std::fs::read_to_string(&out).unwrap();
    assert!(json.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["), "{json}");
    assert!(json.ends_with("]}"), "{json}");
    for needle in ["\"ph\":\"X\"", "\"pid\":1", "\"tid\":", "\"ts\":", "\"dur\":"] {
        assert!(json.contains(needle), "missing {needle:?}");
    }
    let events = json.matches("\"ph\":\"X\"").count();
    assert!(events >= sink.len().min(1), "no events rendered");
    assert_eq!(json.matches('{').count(), json.matches('}').count(), "unbalanced JSON");

    std::fs::remove_dir_all(&d).unwrap();
}

#[test]
fn metrics_endpoint_answers_scrapes() {
    enable();
    let srv = telemetry::MetricsServer::start("127.0.0.1:0").unwrap();
    let req = |method: &str, path: &str| -> String {
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        write!(s, "{method} {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")
            .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    };
    let get = |path: &str| req("GET", path);

    let metrics = get("/metrics");
    assert!(metrics.starts_with("HTTP/1.1 200 OK\r\n"), "{metrics}");
    assert!(metrics.contains("text/plain; version=0.0.4"), "{metrics}");
    assert!(metrics.contains("cugwas_snps_per_sec"), "{metrics}");
    assert!(metrics.contains("cugwas_cache_resident_bytes"), "{metrics}");
    // The lifecycle counters are part of the scrape catalog from boot.
    for needle in [
        "cugwas_wal_replays_total",
        "cugwas_jobs_resumed_total",
        "cugwas_jobs_cancelled_total",
        "cugwas_drains_total",
        "cugwas_disk_low_water_total",
    ] {
        assert!(metrics.contains(needle), "missing {needle} in scrape:\n{metrics}");
    }

    let health = get("/healthz");
    assert!(health.starts_with("HTTP/1.1 200 OK\r\n"), "{health}");
    assert!(health.contains("ok"), "{health}");

    let missing = get("/nope");
    assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

    // The one write endpoint: POST /drain flips the service's drain
    // flag; a GET of the same path stays a 404 (method-aware routing).
    assert!(req("GET", "/drain").starts_with("HTTP/1.1 404"));
    assert!(!cugwas::service::drain_requested());
    let drain = req("POST", "/drain");
    assert!(drain.starts_with("HTTP/1.1 200 OK\r\n"), "{drain}");
    assert!(drain.contains("draining"), "{drain}");
    assert!(cugwas::service::drain_requested(), "POST /drain must request a drain");
}
