//! Microkernel ⇔ reference parity, bit for bit.
//!
//! The register-tiled microkernels (`linalg::micro`) vectorize across
//! *independent output elements*, so every element's accumulation order
//! is identical to the scalar reference nest — which makes the two
//! paths comparable with `to_bits()`, not a tolerance. These tests
//! force each path in turn through the public drivers (`gemm`,
//! `trsm_lower_left`, `syrk_t`, `potrf`) and assert the outputs are
//! byte-identical across adversarial shapes: degenerate (1×1×1, k = 1,
//! single row/column), odd everything, and sub-tile tails straddling
//! the MR/NR register tile, the TRSM/POTRF panel widths and the NC
//! column-panel split.
//!
//! The forced-path switch is process-global, so every test serializes
//! on one mutex and restores the auto path (env-driven) on exit — even
//! on panic, via the drop guard.

use cugwas::linalg::{gemm, micro, potrf, syrk_t, trsm_lower_left, Matrix};
use cugwas::util::{threads, XorShift};
use std::sync::{Mutex, MutexGuard};

static FORCED: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    FORCED.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restores the auto (env-driven) path even if an assertion panics.
struct Restore;
impl Drop for Restore {
    fn drop(&mut self) {
        micro::set_forced(None);
    }
}

fn bits(m: &Matrix) -> Vec<u64> {
    m.as_slice().iter().map(|x| x.to_bits()).collect()
}

/// Run `f` once per forced path and return (micro, reference) outputs.
fn both(mut f: impl FnMut() -> Matrix) -> (Matrix, Matrix) {
    let _restore = Restore;
    micro::set_forced(Some(true));
    let fast = f();
    micro::set_forced(Some(false));
    let slow = f();
    (fast, slow)
}

fn assert_paths_match(fast: &Matrix, slow: &Matrix, what: &str) {
    assert_eq!(bits(fast), bits(slow), "{what}: microkernel differs from reference");
}

#[test]
fn gemm_paths_are_bit_identical_across_adversarial_shapes() {
    let _l = lock();
    let mut rng = XorShift::new(0x05EE_D0A1);
    // (m, k, n): degenerate, odd, and tails around MR=8 / NR=4 / NC=64.
    let shapes = [
        (1usize, 1usize, 1usize),
        (1, 5, 1),
        (5, 1, 9),   // k = 1: a single mul_add per element
        (7, 3, 5),   // everything below one tile
        (8, 4, 4),   // exactly one MR×NR-aligned strip
        (9, 5, 5),   // one row past the tile
        (63, 33, 65), // straddles the NC=64 column panel
        (130, 65, 67),
    ];
    for &(m, k, n) in &shapes {
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let c0 = Matrix::randn(m, n, &mut rng);
        for &(alpha, beta) in &[(1.0f64, 0.0f64), (0.75, 0.5), (-1.0, 1.0)] {
            let (fast, slow) = both(|| {
                let mut c = c0.clone();
                gemm(alpha, &a, &b, beta, &mut c).unwrap();
                c
            });
            assert_paths_match(&fast, &slow, &format!("gemm {m}x{k}x{n} α={alpha} β={beta}"));
        }
    }
}

#[test]
fn gemm_parallel_panels_keep_the_parity() {
    // The scatter hands each NC-wide panel to a worker with its own
    // pack buffers; the per-element order (and hence the bits) must not
    // depend on the path even when several panels run concurrently.
    let _l = lock();
    let mut rng = XorShift::new(0x0BAD_5EED);
    let (m, k, n) = (96usize, 48usize, 200usize); // four NC panels, odd tail
    let a = Matrix::randn(m, k, &mut rng);
    let b = Matrix::randn(k, n, &mut rng);
    let _t = threads::with_budget(3);
    let (fast, slow) = both(|| {
        let mut c = Matrix::zeros(m, n);
        gemm(1.0, &a, &b, 0.0, &mut c).unwrap();
        c
    });
    assert_paths_match(&fast, &slow, "parallel gemm 96x48x200");
}

#[test]
fn trsm_paths_are_bit_identical_across_adversarial_shapes() {
    let _l = lock();
    let mut rng = XorShift::new(0x7125_0001);
    // (n, nrhs) around the TRSM_NB=32 panel and the NC=64 rhs split.
    let shapes = [
        (1usize, 1usize),
        (7, 5),
        (32, 64),  // exactly one diagonal panel, one rhs panel
        (33, 65),  // one past both
        (64, 1),   // single rhs column
        (70, 130),
    ];
    for &(n, nrhs) in &shapes {
        let spd = Matrix::rand_spd(n, 4.0, &mut rng);
        let l = potrf(&spd).unwrap();
        let b0 = Matrix::randn(n, nrhs, &mut rng);
        let (fast, slow) = both(|| {
            let mut b = b0.clone();
            trsm_lower_left(&l, &mut b).unwrap();
            b
        });
        assert_paths_match(&fast, &slow, &format!("trsm {n}x{nrhs}"));
    }
}

#[test]
fn syrk_paths_are_bit_identical_across_adversarial_shapes() {
    let _l = lock();
    let mut rng = XorShift::new(0x5712_C001);
    for &(rows, cols) in &[(1usize, 1usize), (7, 5), (64, 33), (129, 66)] {
        let a = Matrix::randn(rows, cols, &mut rng);
        let (fast, slow) = both(|| syrk_t(&a));
        assert_paths_match(&fast, &slow, &format!("syrk_t {rows}x{cols}"));
    }
}

#[test]
fn potrf_paths_are_bit_identical_across_adversarial_shapes() {
    let _l = lock();
    let mut rng = XorShift::new(0x90_7F_2F_01);
    // n around the POTRF_NB=48 panel: sub-panel, exact, one past, multi.
    for &n in &[1usize, 5, 47, 48, 49, 100] {
        let spd = Matrix::rand_spd(n, 4.0, &mut rng);
        let (fast, slow) = both(|| potrf(&spd).unwrap());
        assert_paths_match(&fast, &slow, &format!("potrf {n}"));
    }
}
