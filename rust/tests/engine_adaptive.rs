//! Engine reuse + deep adaptation coverage: the unified engine must be
//! able to switch host/device buffer counts, the lane-vs-S-loop thread
//! split and the block size *mid-stream* — reusing lanes/pools whenever
//! the switch doesn't resize them — without changing a single bit of
//! `r.xrd`; and the v2 journal must carry a crash-resume across such a
//! switch.

use cugwas::coordinator::{
    verify_against_oracle, Engine, PipelineConfig, SegmentKnobs, SegmentPlan,
};
use cugwas::gwas::problem::Dims;
use cugwas::storage::dataset::DatasetPaths;
use cugwas::storage::{generate, XrdFile};
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_eng_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn knobs(block: usize, hb: usize, db: usize, lt: usize) -> SegmentKnobs {
    SegmentKnobs { block, host_buffers: hb, device_buffers: db, lane_threads: lt }
}

fn plan(k: SegmentKnobs, windows: usize) -> SegmentPlan {
    SegmentPlan { knobs: k, windows }
}

/// The acceptance scenario: a run split across segment boundaries that
/// switch buffers and threads mid-stream is bit-identical to the plain
/// single-configuration run — and the engine's stats prove lanes/pools
/// were reused exactly when the switch left them unchanged.
#[test]
fn deep_knob_switches_mid_stream_are_bit_identical() {
    let dir = tmpdir("deep");
    let dims = Dims::new(96, 2, 3072).unwrap();
    generate(&dir, dims, 256, 4711).unwrap();

    // Reference: one configuration, one thread, start to finish.
    let mut cfg = PipelineConfig::new(&dir, 512);
    cfg.threads = 1;
    cugwas::coordinator::run(&cfg).unwrap();
    let ref_bytes = std::fs::read(dir.join("r.xrd")).unwrap();
    let ref_diff = verify_against_oracle(&dir, 1e-8).unwrap();

    // Same study, now as three segments that switch every knob class:
    //   A: the starting configuration              (2 windows of 512)
    //   B: smaller block, deeper rings, 2 lane threads (4 windows of 256)
    //   C: block back to 512, shallow host ring, B's lanes (the rest)
    let plans = [
        plan(knobs(512, 3, 2, 1), 2),
        plan(knobs(256, 4, 3, 2), 4),
        plan(knobs(512, 2, 3, 2), usize::MAX),
    ];
    let mut engine = Engine::open(&cfg).unwrap();
    let report = engine.execute_plans(&cfg, &plans).unwrap();
    assert_eq!(report.snps, dims.m);
    assert_eq!(report.blocks, 2 + 4 + 2, "512×2 + 256×4 + 512×2 windows");
    assert_eq!(report.replans, 2, "B and C are switches; A is the starting config");

    let bytes = std::fs::read(dir.join("r.xrd")).unwrap();
    assert_eq!(bytes, ref_bytes, "r.xrd changed across mid-stream knob switches");
    let diff = verify_against_oracle(&dir, 1e-8).unwrap();
    assert_eq!(diff.to_bits(), ref_diff.to_bits());

    // Resource reuse accounting: B changed lane_threads + device_buffers
    // (lane respawn); C kept B's lane key (native lanes are block-size-
    // agnostic), so only the pools were re-rung.
    let stats = engine.stats();
    assert_eq!(stats.lane_builds, 2, "A builds, B rebuilds, C reuses");
    assert_eq!(stats.pool_builds, 3, "every segment changed the ring geometry");
    assert_eq!(stats.runs, 1);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Back-to-back runs on one engine (the `serve` path) reuse lanes and
/// pools outright and still produce identical bytes.
#[test]
fn repeated_runs_on_one_engine_reuse_everything() {
    let dir = tmpdir("reuse");
    let dims = Dims::new(64, 2, 1024).unwrap();
    generate(&dir, dims, 128, 99).unwrap();
    let mut cfg = PipelineConfig::new(&dir, 256);
    cfg.threads = 2;

    let mut engine = Engine::open(&cfg).unwrap();
    engine.execute(&cfg).unwrap();
    let first = std::fs::read(dir.join("r.xrd")).unwrap();
    engine.execute(&cfg).unwrap();
    let second = std::fs::read(dir.join("r.xrd")).unwrap();
    assert_eq!(first, second);
    verify_against_oracle(&dir, 1e-8).unwrap();

    let stats = engine.stats();
    assert_eq!(stats.runs, 2);
    assert_eq!(stats.lane_builds, 1, "second run must ride the warm lanes");
    assert_eq!(stats.pool_builds, 1, "second run must ride the warm pools");

    // An incompatible configuration is refused, not silently rebuilt —
    // the caller decides whether to open a fresh engine.
    let mut other = cfg.clone();
    other.threads = 1;
    assert!(!engine.compatible(&other));
    assert!(engine.execute(&other).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Crash-resume across a mid-run knob switch: the v3 journal's
/// column-range records carry mixed window widths, and a resumed run
/// recomputes exactly the uncovered columns.
#[test]
fn crash_resume_across_a_mid_run_knob_switch() {
    let dir = tmpdir("resume");
    let dims = Dims::new(64, 2, 2048).unwrap();
    generate(&dir, dims, 128, 13).unwrap();
    let mut cfg = PipelineConfig::new(&dir, 128);
    cfg.threads = 1;
    cfg.resume = true; // journal every window

    // A run whose second half streams under a switched configuration
    // (wider block, deeper rings, two lane threads).
    let plans = [
        plan(knobs(128, 3, 2, 1), 8),
        plan(knobs(256, 4, 3, 2), usize::MAX),
    ];
    Engine::open(&cfg).unwrap().execute_plans(&cfg, &plans).unwrap();
    verify_against_oracle(&dir, 1e-8).unwrap();

    // Parse the journal (32-byte v3 header + 16-byte column-range
    // records): the record stream must show both window widths, and the
    // header's trait width must pin this single-phenotype run at 1.
    let paths = DatasetPaths::new(&dir);
    let bytes = std::fs::read(paths.progress()).unwrap();
    assert_eq!(&bytes[..8], b"CGWJRNL3");
    assert_eq!(u64::from_le_bytes(bytes[24..32].try_into().unwrap()), 1);
    let ranges: Vec<(u64, u64)> = bytes[32..]
        .chunks_exact(16)
        .map(|r| {
            (
                u64::from_le_bytes(r[..8].try_into().unwrap()),
                u64::from_le_bytes(r[8..].try_into().unwrap()),
            )
        })
        .collect();
    assert_eq!(ranges.iter().map(|&(_, n)| n).sum::<u64>(), dims.m as u64);
    let widths: std::collections::HashSet<u64> = ranges.iter().map(|&(_, n)| n).collect();
    assert!(widths.contains(&128) && widths.contains(&256), "{widths:?}");

    // Crash: keep the journal's first half (which straddles nothing yet
    // of the switched segment or some of it — either way mixed-geometry
    // resume must hold), clobber every column the survivors do NOT
    // cover, and resume with the ORIGINAL starting block.
    let keep = ranges.len() / 2;
    std::fs::write(paths.progress(), &bytes[..32 + keep * 16]).unwrap();
    {
        let covered = &ranges[..keep];
        let f = XrdFile::open_rw(&paths.results()).unwrap();
        let p = dims.pl as u64 + 1;
        for col in 0..dims.m as u64 {
            if !covered.iter().any(|&(c0, n)| col >= c0 && col < c0 + n) {
                f.write_cols(col, 1, &vec![f64::NAN; p as usize]).unwrap();
            }
        }
    }
    let report = Engine::open(&cfg).unwrap().execute(&cfg).unwrap();
    assert!(report.blocks >= 1, "uncovered columns must be recomputed");
    verify_against_oracle(&dir, 1e-8).unwrap();

    // A completed run resumes as a no-op.
    let report = Engine::open(&cfg).unwrap().execute(&cfg).unwrap();
    assert_eq!(report.blocks, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}
