//! All four implementations (cuGWAS pipeline, OOC-HP-GWAS, naive offload,
//! ProbABEL-like) must produce the same numbers for the same dataset —
//! the paper compares their *speed*, never their answers.

use cugwas::baselines::{run_naive, run_ooc_cpu, run_probabel};
use cugwas::coordinator::{run, BackendKind, PipelineConfig};
use cugwas::gwas::problem::Dims;
use cugwas::linalg::Matrix;
use cugwas::storage::{dataset::DatasetPaths, generate, XrdFile};
use std::path::{Path, PathBuf};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_base_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn read_results(dir: &Path, p: usize, m: usize) -> Matrix {
    let rfile = XrdFile::open(&DatasetPaths::new(dir).results()).unwrap();
    let mut buf = vec![0.0; p * m];
    rfile.read_cols_into(0, m as u64, &mut buf).unwrap();
    Matrix::from_vec(p, m, buf).unwrap()
}

#[test]
fn all_solvers_agree() {
    let dims = Dims::new(28, 3, 26).unwrap();
    let (p, m) = (dims.p(), dims.m);
    let dir = tmpdir("agree");
    generate(&dir, dims, 8, 123).unwrap();

    run(&PipelineConfig::new(&dir, 8)).unwrap();
    let r_pipeline = read_results(&dir, p, m);

    run_ooc_cpu(&dir, 8, None).unwrap();
    let r_ooc = read_results(&dir, p, m);

    run_naive(&dir, 8, &BackendKind::Native, None).unwrap();
    let r_naive = read_results(&dir, p, m);

    run_probabel(&dir).unwrap();
    let r_pa = read_results(&dir, p, m);

    assert!(r_pipeline.max_abs_diff(&r_ooc) < 1e-10, "{}", r_pipeline.max_abs_diff(&r_ooc));
    assert!(r_pipeline.max_abs_diff(&r_naive) < 1e-10);
    // ProbABEL uses a different (explicit-inverse) algorithm: looser tol.
    assert!(r_pipeline.max_abs_diff(&r_pa) < 1e-6, "{}", r_pipeline.max_abs_diff(&r_pa));

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn agreement_holds_across_block_sizes() {
    let dims = Dims::new(20, 2, 30).unwrap();
    let (p, m) = (dims.p(), dims.m);
    let dir = tmpdir("blocks");
    generate(&dir, dims, 5, 7).unwrap();

    run(&PipelineConfig::new(&dir, 10)).unwrap();
    let a = read_results(&dir, p, m);
    run(&PipelineConfig::new(&dir, 7)).unwrap(); // non-divisor block size
    let b = read_results(&dir, p, m);
    run_ooc_cpu(&dir, 13, None).unwrap();
    let c = read_results(&dir, p, m);

    assert!(a.max_abs_diff(&b) < 1e-10);
    assert!(a.max_abs_diff(&c) < 1e-10);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn multi_lane_agrees_with_single_lane() {
    let dims = Dims::new(24, 3, 32).unwrap();
    let (p, m) = (dims.p(), dims.m);
    let dir = tmpdir("lanes");
    generate(&dir, dims, 8, 55).unwrap();

    run(&PipelineConfig::new(&dir, 8)).unwrap();
    let one = read_results(&dir, p, m);
    let mut cfg = PipelineConfig::new(&dir, 8);
    cfg.ngpus = 4;
    run(&cfg).unwrap();
    let four = read_results(&dir, p, m);

    assert!(one.max_abs_diff(&four) < 1e-12, "{}", one.max_abs_diff(&four));
    std::fs::remove_dir_all(&dir).unwrap();
}
