//! The zero-copy plane's acceptance suite:
//!
//! (a) the steady-state cache-hit path performs ZERO per-block host
//!     memcpys (`Metrics.bytes_copied == 0`, every block borrowed);
//! (b) results are byte-identical to the copying plane's across
//!     threads × lanes × cache on/off (the refactor may not move a bit);
//! (c) a published block cannot be mutated while the cache or a lane
//!     holds a view — the aliasing guarantee behind (b).

use cugwas::coordinator::metrics::Counter;
use cugwas::coordinator::{run, verify_against_oracle, Phase, PipelineConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::{generate, BlockCache, BlockKey, SlabPool};
use std::path::PathBuf;
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_zc_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// (a) Two passes over one dataset through a shared cache: the second
/// pass is fed entirely from resident blocks, and the counters must
/// show a pure borrow plane — no block bytes copied anywhere on the
/// native path, warm or cold.
#[test]
fn steady_state_cache_hits_copy_zero_bytes() {
    let dir = tmpdir("hits");
    let dims = Dims::new(48, 2, 512).unwrap();
    generate(&dir, dims, 64, 31).unwrap();
    let cache = Arc::new(BlockCache::new(64 << 20));
    let mut cfg = PipelineConfig::new(&dir, 64);
    cfg.cache = Some(Arc::clone(&cache));
    cfg.threads = 1;

    let cold = run(&cfg).unwrap();
    let windows = 512 / 64;
    assert_eq!(cold.metrics.count(Phase::CacheMiss), windows as u64);
    assert_eq!(
        cold.metrics.bytes(Counter::BytesCopied),
        0,
        "the native miss path reads into the slab the lanes view — nothing to copy"
    );
    assert!(cold.metrics.bytes(Counter::BytesBorrowed) > 0);

    let warm = run(&cfg).unwrap();
    assert_eq!(warm.metrics.count(Phase::CacheHit), windows as u64, "fully resident");
    assert_eq!(warm.metrics.count(Phase::CacheMiss), 0);
    assert_eq!(
        warm.metrics.bytes(Counter::BytesCopied),
        0,
        "steady-state serving must be memcpy-free per block"
    );
    // Every window is borrowed at least twice: the cache handout and
    // its lane view(s).
    let block_bytes = (48 * 512 * 8) as u64;
    assert!(
        warm.metrics.bytes(Counter::BytesBorrowed) >= 2 * block_bytes,
        "borrowed {} < {}",
        warm.metrics.bytes(Counter::BytesBorrowed),
        2 * block_bytes
    );
    verify_against_oracle(&dir, 1e-8).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// (b) The refactor is invisible to the numbers: `r.xrd` is
/// byte-identical across thread counts, lane counts, and cache on/off.
#[test]
fn results_identical_across_threads_lanes_and_cache() {
    let dir = tmpdir("det");
    let dims = Dims::new(48, 2, 512).unwrap();
    generate(&dir, dims, 64, 77).unwrap();

    let mut reference: Option<Vec<u8>> = None;
    for threads in [1usize, 8] {
        for ngpus in [1usize, 2] {
            for cached in [false, true] {
                let mut cfg = PipelineConfig::new(&dir, 64);
                cfg.threads = threads;
                cfg.ngpus = ngpus;
                cfg.cache = cached.then(|| Arc::new(BlockCache::new(32 << 20)));
                run(&cfg).unwrap();
                let bytes = std::fs::read(dir.join("r.xrd")).unwrap();
                match &reference {
                    None => {
                        verify_against_oracle(&dir, 1e-8).unwrap();
                        reference = Some(bytes);
                    }
                    Some(want) => {
                        let cell = format!("threads={threads} lanes={ngpus} cache={cached}");
                        assert_eq!(&bytes, want, "r.xrd diverged at {cell}");
                    }
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// (c) Aliasing: once a block is published and shared (cache entry,
/// lane-style view), the only route back to `&mut` — unpublishing —
/// refuses until every other holder is gone. Compile-time, the API
/// offers no `&mut` on `Block` at all; this asserts the runtime face.
#[test]
fn published_block_is_immutable_while_shared() {
    let pool = SlabPool::new(2, 128);
    let mut bm = pool.take(128).unwrap();
    bm.as_mut_slice().fill(1.25);
    let block = bm.publish();

    let cache = BlockCache::new(1 << 20);
    let key = BlockKey { dataset: "ds".into(), col0: 0, ncols: 16 };
    cache.insert(key.clone(), &block);
    let lane_view = block.slice(64, 64);

    // Three holders exist (ours, the cache's, the view's): no mutation.
    let block = block.try_unpublish().expect_err("cache + view still hold the block");
    // Drop our view; the cache still holds it.
    drop(lane_view);
    let block = block.try_unpublish().expect_err("cache still holds the block");
    // Fetch-and-release through the cache keeps the data intact…
    let again = cache.get(&key, 128).expect("resident");
    assert_eq!(again.as_slice()[100], 1.25);
    drop(again);
    // …and only once the cache lets go does exclusivity return.
    drop(cache);
    let mut bm = block.try_unpublish().expect("sole holder at last");
    bm.as_mut_slice()[0] = 9.0;
    assert_eq!(bm.as_slice()[0], 9.0);
}
