//! Property tests over the coordinator's invariants (mini-proptest).
//!
//! The paper's correctness argument is entirely about scheduling: every
//! block is read, solved, and written exactly once, buffers never alias,
//! and the result is independent of topology (lanes, buffer counts,
//! block sizes, throttles). These properties check that over randomized
//! configurations, end-to-end on real files, against the in-core oracle.

use cugwas::coordinator::{run, verify_against_oracle, OffloadMode, PipelineConfig};
use cugwas::devsim::{simulate, Algo, HardwareProfile, SimConfig};
use cugwas::gwas::problem::Dims;
use cugwas::proptest::{forall, prop_assert, Gen};
use cugwas::storage::generate;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static CASE: AtomicU64 = AtomicU64::new(0);

fn tmpdir(tag: &str) -> PathBuf {
    let c = CASE.fetch_add(1, Ordering::Relaxed);
    let d = std::env::temp_dir().join(format!("cugwas_prop_{}_{tag}_{c}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Any valid topology must reproduce the oracle exactly.
#[test]
fn prop_pipeline_matches_oracle_for_any_topology() {
    forall("pipeline_topology", 12, |g: &mut Gen| {
        let n = *g.choose(&[16usize, 24, 32]);
        let pl = g.usize_in(1, 3);
        let m = g.usize_in(1, 60);
        let ngpus = *g.choose(&[1usize, 2, 3]);
        let per_gpu = g.usize_in(1, 8);
        let block = ngpus * per_gpu;
        let host_buffers = g.usize_in(2, 5);
        let mode = *g.choose(&[OffloadMode::Trsm, OffloadMode::Block, OffloadMode::BlockFull]);
        let seed = g.u64();

        let dims = match Dims::new(n, pl, m) {
            Ok(d) => d,
            Err(_) => return Ok(()), // skip invalid dims draws
        };
        let dir = tmpdir("topo");
        generate(&dir, dims, block.min(m), seed).map_err(|e| e.to_string())?;
        let mut cfg = PipelineConfig::new(&dir, block);
        cfg.ngpus = ngpus;
        cfg.host_buffers = host_buffers;
        cfg.mode = mode;
        let report = run(&cfg).map_err(|e| {
            format!("run failed (n={n} pl={pl} m={m} block={block} ngpus={ngpus} hb={host_buffers} mode={mode:?}): {e}")
        })?;
        let blocks_expected = m.div_ceil(block);
        let ok1 = prop_assert(
            report.blocks == blocks_expected,
            format!("blocks {} != {}", report.blocks, blocks_expected),
        );
        let verify = verify_against_oracle(&dir, 1e-7).map_err(|e| {
            format!("mismatch (n={n} pl={pl} m={m} block={block} ngpus={ngpus} hb={host_buffers} mode={mode:?}): {e}")
        });
        let _ = std::fs::remove_dir_all(&dir);
        ok1?;
        verify.map(|_| ())
    });
}

/// Dataset generation is invariant to the file's chunking and the same
/// study re-chunked must solve to the same results.
#[test]
fn prop_results_independent_of_file_chunking() {
    forall("chunk_invariance", 8, |g: &mut Gen| {
        let n = 20;
        let m = g.usize_in(4, 40);
        let chunk_a = g.usize_in(1, m);
        let chunk_b = g.usize_in(1, m);
        let block = g.usize_in(1, 12);
        let seed = g.u64();
        let dims = Dims::new(n, 2, m).map_err(|e| e.to_string())?;

        let da = tmpdir("ca");
        let db = tmpdir("cb");
        generate(&da, dims, chunk_a, seed).map_err(|e| e.to_string())?;
        generate(&db, dims, chunk_b, seed).map_err(|e| e.to_string())?;
        run(&PipelineConfig::new(&da, block)).map_err(|e| e.to_string())?;
        run(&PipelineConfig::new(&db, block)).map_err(|e| e.to_string())?;

        use cugwas::storage::{dataset::DatasetPaths, XrdFile};
        let read = |dir: &PathBuf| -> Result<Vec<f64>, String> {
            let f = XrdFile::open(&DatasetPaths::new(dir).results()).map_err(|e| e.to_string())?;
            let mut buf = vec![0.0; 3 * m];
            f.read_cols_into(0, m as u64, &mut buf).map_err(|e| e.to_string())?;
            Ok(buf)
        };
        let ra = read(&da)?;
        let rb = read(&db)?;
        let _ = std::fs::remove_dir_all(&da);
        let _ = std::fs::remove_dir_all(&db);
        prop_assert(ra == rb, format!("chunk {chunk_a} vs {chunk_b} differ (m={m}, block={block})"))
    });
}

/// DES sanity over random configurations: the pipelined schedule is never
/// slower than the serialized one, and utilizations stay in [0, 1].
#[test]
fn prop_sim_pipelined_never_loses_to_naive() {
    forall("sim_dominance", 40, |g: &mut Gen| {
        let n = g.usize_in(1_000, 20_000);
        let ngpus = *g.choose(&[1usize, 2, 4]);
        let block = ngpus * g.usize_in(200, 8_000);
        let m = block * g.usize_in(2, 20);
        let profile = *g.choose(&[
            HardwareProfile::quadro(),
            HardwareProfile::tesla(),
            HardwareProfile::hdd(),
        ]);
        let cfg = SimConfig {
            dims: Dims::new(n, 3, m).map_err(|e| e.to_string())?,
            block,
            ngpus,
            host_buffers: g.usize_in(2, 4),
            traits: 1,
            profile,
        };
        let cu = simulate(Algo::CuGwas, &cfg).map_err(|e| e.to_string())?;
        let naive = simulate(Algo::NaiveGpu, &cfg).map_err(|e| e.to_string())?;
        prop_assert(
            cu.total_secs <= naive.total_secs * 1.0001,
            format!("cugwas {} > naive {} ({cfg:?})", cu.total_secs, naive.total_secs),
        )?;
        for (name, u) in [
            ("gpu", cu.gpu_util),
            ("cpu", cu.cpu_util),
            ("pcie", cu.pcie_util),
            ("disk", cu.disk_util),
        ] {
            prop_assert((0.0..=1.0001).contains(&u), format!("{name} util {u} out of range"))?;
        }
        Ok(())
    });
}

/// DES conservation: every block appears exactly once per phase.
#[test]
fn prop_sim_timeline_covers_every_block_once() {
    forall("sim_coverage", 30, |g: &mut Gen| {
        let ngpus = *g.choose(&[1usize, 2, 3]);
        let block = ngpus * g.usize_in(100, 2_000);
        let nblocks = g.usize_in(1, 12);
        let m = block * nblocks;
        let cfg = SimConfig {
            dims: Dims::new(5_000, 3, m).map_err(|e| e.to_string())?,
            block,
            ngpus,
            host_buffers: 3,
            traits: 1,
            profile: HardwareProfile::quadro(),
        };
        let rep = simulate(Algo::CuGwas, &cfg).map_err(|e| e.to_string())?;
        let count = |prefix: &str| {
            rep.timeline.intervals.iter().filter(|iv| iv.label.starts_with(prefix)).count()
        };
        prop_assert(count("read[") == nblocks, format!("reads {} != {nblocks}", count("read[")))?;
        prop_assert(
            count("trsm[") == nblocks * ngpus,
            format!("trsms {} != {}", count("trsm["), nblocks * ngpus),
        )?;
        prop_assert(count("sloop[") == nblocks, "sloop count".to_string())?;
        prop_assert(count("write[") == nblocks, "write count".to_string())?;
        // Dependency spot check: the first trsm can never start before the
        // first read (which feeds it) has finished.
        let first_read_end = rep
            .timeline
            .intervals
            .iter()
            .find(|iv| iv.label == "read[0]")
            .map(|iv| iv.finish)
            .unwrap_or(0.0);
        let first_trsm_start = rep
            .timeline
            .intervals
            .iter()
            .find(|iv| iv.label.starts_with("trsm[0."))
            .map(|iv| iv.start)
            .unwrap_or(0.0);
        prop_assert(
            first_trsm_start >= first_read_end,
            format!("trsm[0] at {first_trsm_start} before read[0] done {first_read_end}"),
        )
    });
}

/// XRD header round-trips for arbitrary geometry.
#[test]
fn prop_xrd_header_roundtrip() {
    use cugwas::storage::Header;
    forall("xrd_header", 200, |g: &mut Gen| {
        let rows = g.usize_in(1, 1 << 20) as u64;
        let cols = g.usize_in(1, 1 << 20) as u64;
        let block = g.usize_in(1, cols as usize) as u64;
        let seed = g.u64();
        let h = Header::new(rows, cols, block, seed).map_err(|e| e.to_string())?;
        let back = Header::from_bytes(&h.to_bytes()).map_err(|e| e.to_string())?;
        prop_assert(h == back, format!("{h:?} != {back:?}"))?;
        // Block geometry partitions the columns exactly.
        let total: u64 = (0..h.block_count()).map(|b| h.cols_in_block(b)).sum();
        prop_assert(total == cols, format!("blocks sum to {total}, cols {cols}"))
    });
}

/// TOML parser: parse(print(x)) == x for generated documents.
#[test]
fn prop_toml_roundtrip() {
    use cugwas::config::{Doc, Value};
    forall("toml_roundtrip", 60, |g: &mut Gen| {
        // Generate a small random document.
        let nsec = g.usize_in(1, 3);
        let mut text = String::new();
        let mut expect: Vec<(String, String, Value)> = Vec::new();
        for s in 0..nsec {
            let section = format!("sec{s}");
            text.push_str(&format!("[{section}]\n"));
            let nkeys = g.usize_in(1, 4);
            for k in 0..nkeys {
                let key = format!("k{k}");
                let (vtext, value) = match g.usize_in(0, 3) {
                    0 => {
                        let v = g.usize_in(0, 1 << 30) as i64;
                        (format!("{v}"), Value::Integer(v))
                    }
                    1 => {
                        let v = g.f64_in(-1e3, 1e3);
                        let v = (v * 1e6).round() / 1e6;
                        let formatted = format!("{v:?}");
                        (formatted, Value::Float(v))
                    }
                    2 => {
                        let b = g.bool_p(0.5);
                        (format!("{b}"), Value::Bool(b))
                    }
                    _ => {
                        let v = format!("str-{}", g.usize_in(0, 999));
                        (format!("\"{v}\""), Value::String(v))
                    }
                };
                text.push_str(&format!("{key} = {vtext}\n"));
                expect.push((section.clone(), key, value));
            }
        }
        let doc = Doc::parse(&text).map_err(|e| format!("{e}\n{text}"))?;
        for (section, key, want) in expect {
            let got = doc
                .get(&section, &key)
                .ok_or_else(|| format!("missing {section}.{key}\n{text}"))?;
            // Integers may parse as Integer, which Float values never do
            // (we format floats with a decimal point via {:?}).
            prop_assert(got == &want, format!("{section}.{key}: {got:?} != {want:?}\n{text}"))?;
        }
        Ok(())
    });
}

/// The optimized register-blocked kernels must agree with naive
/// reference implementations at arbitrary shapes (the 4×2 fusion has
/// remainder paths at every edge — this sweeps them all).
#[test]
fn prop_linalg_kernels_match_naive() {
    use cugwas::linalg::{gemm, potrf, trsm_lower_left, Matrix};
    use cugwas::util::XorShift;
    forall("linalg_kernels", 40, |g: &mut Gen| {
        let mut rng = XorShift::new(g.u64());
        let m = g.usize_in(1, 70);
        let k = g.usize_in(1, 70);
        let n = g.usize_in(1, 70);
        // gemm vs naive triple loop.
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let mut c = Matrix::zeros(m, n);
        gemm(1.0, &a, &b, 0.0, &mut c).map_err(|e| e.to_string())?;
        for j in 0..n {
            for i in 0..m {
                let want: f64 = (0..k).map(|s| a.get(i, s) * b.get(s, j)).sum();
                prop_assert(
                    (c.get(i, j) - want).abs() < 1e-9 * (1.0 + want.abs()),
                    format!("gemm {m}x{k}x{n} at ({i},{j}): {} vs {want}", c.get(i, j)),
                )?;
            }
        }
        // trsm: residual L X == B.
        let nn = g.usize_in(1, 60);
        let nrhs = g.usize_in(1, 20);
        let spd = Matrix::rand_spd(nn, 3.0, &mut rng);
        let l = potrf(&spd).map_err(|e| e.to_string())?;
        let b0 = Matrix::randn(nn, nrhs, &mut rng);
        let mut x = b0.clone();
        trsm_lower_left(&l, &mut x).map_err(|e| e.to_string())?;
        for j in 0..nrhs {
            for i in 0..nn {
                let lx: f64 = (0..=i).map(|s| l.get(i, s) * x.get(s, j)).sum();
                prop_assert(
                    (lx - b0.get(i, j)).abs() < 1e-8,
                    format!("trsm n={nn} nrhs={nrhs} at ({i},{j})"),
                )?;
            }
        }
        // potrf: L L^T == M and lower-triangular.
        let mut rec = Matrix::zeros(nn, nn);
        gemm(1.0, &l, &l.transpose(), 0.0, &mut rec).map_err(|e| e.to_string())?;
        prop_assert(
            rec.max_abs_diff(&spd) < 1e-8,
            format!("potrf n={nn}: reconstruction diff {}", rec.max_abs_diff(&spd)),
        )?;
        for j in 1..nn {
            for i in 0..j {
                prop_assert(l.get(i, j) == 0.0, format!("potrf upper non-zero at ({i},{j})"))?;
            }
        }
        Ok(())
    });
}

/// Association statistics invariants over random well-posed studies.
#[test]
fn prop_assoc_stats_well_formed() {
    use cugwas::gwas::problem::{Dims, Problem};
    use cugwas::gwas::solve_incore_with_stats;
    forall("assoc_stats", 10, |g: &mut Gen| {
        let n = g.usize_in(30, 80);
        let pl = g.usize_in(1, 3);
        let m = g.usize_in(1, 12);
        let dims = Dims::new(n, pl, m).map_err(|e| e.to_string())?;
        let prob = Problem::synthetic(dims, g.u64()).map_err(|e| e.to_string())?;
        let (r, stats) = solve_incore_with_stats(&prob).map_err(|e| e.to_string())?;
        for i in 0..m {
            let (beta, se, z) = (stats.get(0, i), stats.get(1, i), stats.get(2, i));
            prop_assert(beta == r.get(pl, i), format!("beta mismatch snp {i}"))?;
            prop_assert(se.is_finite() && se >= 0.0, format!("se {se} snp {i}"))?;
            prop_assert(z.is_finite(), format!("z {z} snp {i}"))?;
            if se > 0.0 {
                prop_assert((z - beta / se).abs() < 1e-10, format!("z≠beta/se snp {i}"))?;
            }
        }
        Ok(())
    });
}
