//! Chaos matrix for the fault-tolerant streaming plane.
//!
//! The injector, policy and integrity switches are process-global, so
//! this suite lives in its own test binary and every scenario runs
//! under one lock: arm → stream → assert → disarm. The acceptance bar
//! (ISSUE 7): with seeded transient read faults, payload corruption and
//! a wedged device lane, a full run must complete with `r.xrd`
//! *byte-identical* to the fault-free baseline and nonzero recovery
//! counters; a permanent fault must fail with an error naming the
//! column range; a torn journal append must truncate cleanly and
//! resume must replay exactly the uncovered columns.

use cugwas::coordinator::PipelineConfig;
use cugwas::gwas::problem::Dims;
use cugwas::storage::fault::{self, FaultPlan, RetryPolicy};
use cugwas::storage::{generate, BlockCache};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// One scenario at a time: the injector state is process-global.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_chaos_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A small study: 8 windows of 64 columns — enough chunks to wedge a
/// lane mid-stream and still finish in well under a second.
fn make_dataset(tag: &str) -> (PathBuf, Dims) {
    let dir = tmpdir(tag);
    let dims = Dims::new(64, 2, 512).unwrap();
    generate(&dir, dims, 64, 2024).unwrap();
    (dir, dims)
}

fn cfg_for(dir: &Path) -> PipelineConfig {
    let mut cfg = PipelineConfig::new(dir, 64);
    cfg.threads = 2;
    cfg
}

/// The chaos policy: quick retries, a fast watchdog (the wedge sleeps
/// well past it), and the default respawn/backoff budget.
fn chaos_policy() -> RetryPolicy {
    RetryPolicy {
        read_retries: 4,
        retry_backoff_ms: 1,
        retry_deadline_ms: 2_000,
        lane_watchdog_ms: 100,
        ..Default::default()
    }
}

/// Reset every process-global switch to its boot state.
fn reset() {
    fault::disarm();
    fault::set_policy(RetryPolicy::default());
    fault::set_integrity_enabled(false);
}

#[test]
fn transient_faults_corruption_and_a_wedged_lane_recover_bit_identically() {
    let _g = lock();
    reset();
    let (dir, dims) = make_dataset("recover");

    // Fault-free baseline.
    let cfg = cfg_for(&dir);
    let rep = cugwas::coordinator::run(&cfg).unwrap();
    assert_eq!(rep.snps, dims.m);
    let baseline = std::fs::read(dir.join("r.xrd")).unwrap();

    // Chaos: every 5th read attempt fails transiently, every 4th
    // delivered payload has a bit flipped after its checksum was taken,
    // and lane 0 wedges on its 2nd chunk for 300 ms (the 100 ms
    // watchdog must catch it). Cache off, then on.
    let plan = FaultPlan {
        seed: 7,
        read_fail_every: 5,
        corrupt_every: 4,
        wedge_lane: 0,
        wedge_at_chunk: 2,
        wedge_ms: 300,
        ..Default::default()
    };
    let shared = Arc::new(BlockCache::new(64 << 20));
    let matrix = [
        ("no cache", None),
        ("cold cache", Some(Arc::clone(&shared))),
        ("warm cache", Some(shared)), // same Arc: every window now hits
    ];
    for (label, cache) in matrix {
        fault::set_policy(chaos_policy());
        fault::set_integrity_enabled(true);
        fault::arm(plan); // rearm: counters and the one-shot wedge reset
        let mut cfg = cfg_for(&dir);
        cfg.cache = cache;
        let rep = cugwas::coordinator::run(&cfg).unwrap();
        assert_eq!(rep.snps, dims.m, "[{label}] chaos run must still cover every SNP");
        let bytes = std::fs::read(dir.join("r.xrd")).unwrap();
        assert_eq!(bytes, baseline, "[{label}] diverged from the fault-free baseline");
        let c = fault::counters();
        assert!(c.injected > 0, "[{label}] injector never fired: {c:?}");
        assert!(c.lane_respawns >= 1, "[{label}] the wedged lane was never respawned: {c:?}");
        // The warm-cache pass streams from RAM — no disk reads, so no
        // read faults to retry; its recovery story is the wedge above.
        if label != "warm cache" {
            assert!(c.read_retries > 0, "[{label}] no read was retried: {c:?}");
        }
    }

    reset();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn a_permanently_bad_column_fails_naming_the_range() {
    let _g = lock();
    reset();
    let (dir, _) = make_dataset("permanent");

    fault::set_policy(RetryPolicy {
        read_retries: 1,
        retry_backoff_ms: 1,
        ..Default::default()
    });
    // Column 130 lives in the window 128..192 (block 64).
    fault::arm(FaultPlan { read_fail_col: 130, ..Default::default() });
    let err = cugwas::coordinator::run(&cfg_for(&dir)).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("read of cols 128..192"), "error must name the range: {msg}");
    assert!(msg.contains("injected permanent read fault at column 130"), "{msg}");
    assert!(msg.contains("attempt"), "error must show the retry count: {msg}");

    reset();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn a_torn_journal_append_is_truncated_and_resume_replays_the_rest() {
    let _g = lock();
    reset();
    let (dir, dims) = make_dataset("torn");

    // Baseline bytes for the final comparison.
    cugwas::coordinator::run(&cfg_for(&dir)).unwrap();
    let baseline = std::fs::read(dir.join("r.xrd")).unwrap();

    // Tear the very first journal append mid-record: the run fails, and
    // the journal is left with a durable partial record — exactly what
    // a power cut mid-append leaves behind.
    fault::arm(FaultPlan { torn_append_at: 1, ..Default::default() });
    let err = cugwas::coordinator::run(&cfg_for(&dir)).unwrap_err();
    assert!(err.to_string().contains("torn"), "{err}");
    let jnl = std::fs::metadata(dir.join("r.progress")).unwrap().len();
    assert_eq!(jnl, 32 + 12, "header plus half a 24-byte record must be on disk");
    fault::disarm();

    // Resume: the torn tail is truncated away and the exact uncovered
    // column range (here: everything — nothing was journaled whole) is
    // recomputed, byte-identical to the baseline.
    let mut cfg = cfg_for(&dir);
    cfg.resume = true;
    let rep = cugwas::coordinator::run(&cfg).unwrap();
    assert_eq!(rep.snps, dims.m);
    let bytes = std::fs::read(dir.join("r.xrd")).unwrap();
    assert_eq!(bytes, baseline, "resume after a torn append diverged");

    reset();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn a_crash_between_intent_and_commit_replays_the_segment_bit_identically() {
    let _g = lock();
    reset();
    let (dir, dims) = make_dataset("twophase");

    // Baseline bytes for the final comparison.
    cugwas::coordinator::run(&cfg_for(&dir)).unwrap();
    let baseline = std::fs::read(dir.join("r.xrd")).unwrap();

    // Crash the first journal commit after its intents landed but
    // before the durable mark — the exact window the two-phase design
    // opens by taking the commit fsync off the critical path.
    fault::arm(FaultPlan { commit_crash_at: 1, ..Default::default() });
    let err = cugwas::coordinator::run(&cfg_for(&dir)).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("commit"), "{msg}");
    assert!(msg.contains("injected"), "{msg}");
    // On disk: the header plus one intent per window (a single segment
    // streams all 8 windows with adaptation off) and no commit record —
    // the buffered intents landed, the durable mark never did.
    let jnl = std::fs::metadata(dir.join("r.progress")).unwrap().len();
    assert_eq!(jnl, 32 + 8 * 24, "all intents, no commit mark: {jnl}");
    assert!(fault::counters().injected > 0);
    fault::disarm();

    // Resume must treat every unsealed intent as not-done and replay the
    // whole segment; the idempotent result writes make the replay land
    // byte-identical.
    let mut cfg = cfg_for(&dir);
    cfg.resume = true;
    let rep = cugwas::coordinator::run(&cfg).unwrap();
    assert_eq!(rep.snps, dims.m, "resume must recompute every unsealed column");
    let bytes = std::fs::read(dir.join("r.xrd")).unwrap();
    assert_eq!(bytes, baseline, "replay after a commit crash diverged");
    // And the replayed run's journal now ends in a durable commit: a
    // second resume finds nothing left to do.
    let mut cfg = cfg_for(&dir);
    cfg.resume = true;
    let rep = cugwas::coordinator::run(&cfg).unwrap();
    assert_eq!(rep.blocks, 0, "a committed journal must leave no windows to replay");

    reset();
    std::fs::remove_dir_all(&dir).unwrap();
}
