//! Multi-trait & permutation batching acceptance: one streamed pass
//! over `X_R` must answer `t` phenotypes exactly as `t` independent
//! single-trait passes would — bit for bit, across thread counts, lane
//! counts and the shared block cache — permutation mode must be
//! reproducible from its seed alone, and the v3 journal must carry the
//! trait dimension across a crash + mid-run knob switch.

use cugwas::coordinator::{
    run, verify_against_oracle_multi, Engine, PipelineConfig, SegmentKnobs, SegmentPlan,
};
use cugwas::gwas::phenotype_batch;
use cugwas::gwas::problem::Dims;
use cugwas::storage::dataset::DatasetPaths;
use cugwas::storage::{generate, BlockCache, XrdFile};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_mt_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Copy a dataset but swap its phenotype for `y` (raw LE f64 file).
fn clone_with_phenotype(src: &Path, dst: &Path, y: &[f64]) {
    std::fs::create_dir_all(dst).unwrap();
    for f in ["meta.txt", "kinship.bin", "covariates.bin", "xr.xrd"] {
        std::fs::copy(src.join(f), dst.join(f)).unwrap();
    }
    let bytes: Vec<u8> = y.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write(dst.join("phenotype.bin"), bytes).unwrap();
}

/// Read the full `r.xrd` payload as f64s (rows × m, column-major).
fn read_results(dir: &Path, rows: usize, m: usize) -> Vec<f64> {
    let f = XrdFile::open(&dir.join("r.xrd")).unwrap();
    let mut out = vec![0.0f64; rows * m];
    f.read_cols_into(0, m as u64, &mut out).unwrap();
    out
}

/// Acceptance (a): the batched `t`-trait pipeline output is column-
/// identical to `t` independent single-trait runs — across thread
/// counts, lane counts, and with the shared block cache on.
#[test]
fn batched_pass_matches_independent_single_trait_runs_bitwise() {
    const TRAITS: usize = 4;
    const SEED: u64 = 2013;
    let dir = tmpdir("batch_vs_singles");
    let dims = Dims::new(80, 2, 1024).unwrap();
    generate(&dir, dims, 128, 31).unwrap();
    let p = dims.p();

    // Single-trait references: one run per batched phenotype column.
    let (_, _, _, y) = cugwas::storage::dataset::load_sidecars(&dir).unwrap();
    let ys = phenotype_batch(&y, TRAITS, SEED);
    let mut singles: Vec<Vec<f64>> = Vec::new();
    for j in 0..TRAITS {
        let sdir = tmpdir(&format!("single_{j}"));
        clone_with_phenotype(&dir, &sdir, ys.col(j));
        let mut cfg = PipelineConfig::new(&sdir, 256);
        cfg.threads = 1;
        run(&cfg).unwrap();
        singles.push(read_results(&sdir, p, dims.m));
        std::fs::remove_dir_all(&sdir).unwrap();
    }

    // The batched pass under several parallel/caching shapes.
    let cache = Arc::new(BlockCache::new(32 << 20));
    for (threads, lanes, cached) in [(1, 1, false), (4, 1, false), (4, 2, false), (4, 2, true)] {
        let mut cfg = PipelineConfig::new(&dir, 256);
        cfg.threads = threads;
        cfg.ngpus = lanes;
        cfg.traits = TRAITS;
        cfg.perm_seed = SEED;
        cfg.cache = cached.then(|| Arc::clone(&cache));
        run(&cfg).unwrap();
        let batched = read_results(&dir, p * TRAITS, dims.m);
        for (j, single) in singles.iter().enumerate() {
            for c in 0..dims.m {
                for r in 0..p {
                    assert_eq!(
                        batched[c * p * TRAITS + j * p + r].to_bits(),
                        single[c * p + r].to_bits(),
                        "trait {j}, snp {c}, row {r} at threads={threads}, lanes={lanes}, \
                         cache={cached}"
                    );
                }
            }
        }
        verify_against_oracle_multi(&dir, 1e-8, TRAITS, SEED).unwrap();
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Acceptance (b): permutation mode is a pure function of `perm_seed` —
/// same seed, same bytes; a different seed moves the permuted columns
/// but never the observed phenotype in column 0.
#[test]
fn permutation_mode_is_reproducible_under_its_seed() {
    const TRAITS: usize = 3; // 1 observed + 2 permutations
    let dir = tmpdir("perm_seed");
    let dims = Dims::new(64, 2, 512).unwrap();
    generate(&dir, dims, 128, 7).unwrap();
    let p = dims.p();

    let run_with = |seed: u64| {
        let mut cfg = PipelineConfig::new(&dir, 128);
        cfg.threads = 2;
        cfg.traits = TRAITS;
        cfg.perm_seed = seed;
        run(&cfg).unwrap();
        read_results(&dir, p * TRAITS, dims.m)
    };

    let a = run_with(41);
    let b = run_with(41);
    assert!(
        a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
        "same perm seed must reproduce every byte"
    );
    let c = run_with(42);
    // Column 0 is the observed phenotype — seed-invariant.
    for snp in 0..dims.m {
        for r in 0..p {
            assert_eq!(
                a[snp * p * TRAITS + r].to_bits(),
                c[snp * p * TRAITS + r].to_bits(),
                "observed-trait results must not depend on the permutation seed"
            );
        }
    }
    // The shuffled columns must actually move with the seed.
    assert!(
        a.iter().zip(&c).any(|(x, y)| x.to_bits() != y.to_bits()),
        "permuted columns should differ between seeds"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Acceptance (c): crash-resume across a mid-run knob switch preserves
/// the trait dimension. The v3 journal pins `t` in its header; a
/// truncated journal resumes exactly the uncovered columns at full
/// `p·t` rows, and a resume attempt with a different width is refused.
#[test]
fn journal_v3_resume_preserves_the_trait_dimension_across_a_replan() {
    const TRAITS: usize = 3;
    const SEED: u64 = 99;
    let dir = tmpdir("resume_traits");
    let dims = Dims::new(64, 2, 1024).unwrap();
    generate(&dir, dims, 64, 17).unwrap();
    let p = dims.p();
    let mut cfg = PipelineConfig::new(&dir, 64);
    cfg.threads = 1;
    cfg.traits = TRAITS;
    cfg.perm_seed = SEED;
    cfg.resume = true; // journal every window

    // A run whose second half streams under switched knobs.
    let knobs = |block, hb, db, lt| SegmentKnobs {
        block,
        host_buffers: hb,
        device_buffers: db,
        lane_threads: lt,
    };
    let plans = [
        SegmentPlan { knobs: knobs(64, 3, 2, 1), windows: 6 },
        SegmentPlan { knobs: knobs(128, 4, 3, 1), windows: usize::MAX },
    ];
    Engine::open(&cfg).unwrap().execute_plans(&cfg, &plans).unwrap();
    verify_against_oracle_multi(&dir, 1e-8, TRAITS, SEED).unwrap();

    // The v3 header pins the batch width.
    let paths = DatasetPaths::new(&dir);
    let bytes = std::fs::read(paths.progress()).unwrap();
    assert_eq!(&bytes[..8], b"CGWJRNL3");
    assert_eq!(
        u64::from_le_bytes(bytes[24..32].try_into().unwrap()),
        TRAITS as u64,
        "journal header must carry the trait width"
    );
    let ranges: Vec<(u64, u64)> = bytes[32..]
        .chunks_exact(16)
        .map(|r| {
            (
                u64::from_le_bytes(r[..8].try_into().unwrap()),
                u64::from_le_bytes(r[8..].try_into().unwrap()),
            )
        })
        .collect();
    assert_eq!(ranges.iter().map(|&(_, n)| n).sum::<u64>(), dims.m as u64);

    // Crash: keep half the journal, clobber every column the survivors
    // do not cover — all p·t rows of it — then resume.
    let keep = ranges.len() / 2;
    std::fs::write(paths.progress(), &bytes[..32 + keep * 16]).unwrap();
    {
        let covered = &ranges[..keep];
        let f = XrdFile::open_rw(&paths.results()).unwrap();
        for col in 0..dims.m as u64 {
            if !covered.iter().any(|&(c0, n)| col >= c0 && col < c0 + n) {
                f.write_cols(col, 1, &vec![f64::NAN; p * TRAITS]).unwrap();
            }
        }
    }
    let report = Engine::open(&cfg).unwrap().execute(&cfg).unwrap();
    assert!(report.blocks >= 1, "uncovered columns must be recomputed");
    verify_against_oracle_multi(&dir, 1e-8, TRAITS, SEED).unwrap();

    // Width mismatch is refused, not silently recomputed: the journal
    // was written for t=3, so a t=2 resume must fail loudly.
    let mut narrow = cfg.clone();
    narrow.traits = 2;
    let err = Engine::open(&narrow).unwrap().execute(&narrow).unwrap_err();
    assert!(
        err.to_string().contains("traits=3"),
        "resume across a width change must name the journal's width: {err}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
