//! End-to-end autotuner coverage: probe a real dataset file, plan from
//! the probed rates, apply the profile to a live run, and exercise the
//! adaptive re-planning path — including crash-resume across an
//! adaptive run's mixed-width journal records.

use cugwas::coordinator::{run, verify_against_oracle, Phase, PipelineConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::dataset::DatasetPaths;
use cugwas::storage::{generate, Throttle};
use cugwas::tune::{plan, probe_dataset, PlanOpts, ProbeOpts, TunedProfile};
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_tune_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn quick_probe() -> ProbeOpts {
    ProbeOpts { threads: 2, max_disk_bytes: 8 << 20, read_throttle: None, quick: true }
}

#[test]
fn tune_plan_apply_roundtrip_matches_oracle() {
    // 128 × 2048 f64 = 2 MiB — big enough for a reliable disk probe.
    let dir = tmpdir("roundtrip");
    let dims = Dims::new(128, 3, 2048).unwrap();
    generate(&dir, dims, 256, 7).unwrap();

    let rates = probe_dataset(&dir, &quick_probe()).unwrap();
    assert!(rates.reliable, "2 MiB dataset must probe reliably");
    assert!(rates.disk_mbps > 0.0 && rates.pcie_gbps > 0.0);

    let opts =
        PlanOpts { total_threads: 2, max_lanes: 1, host_mem_bytes: 0, max_block: 1024, traits: 1 };
    let profile = plan(&rates, dims, &opts);
    assert!(profile.predicted().is_some(), "reliable probe must yield a prediction");
    assert!(profile.block >= 64 && profile.block <= 1024);

    // Persist + reload (what `run --profile` does), then stream with it.
    let ppath = dir.join("tuned.toml");
    profile.save(&ppath).unwrap();
    let loaded = TunedProfile::load(&ppath).unwrap();
    assert_eq!(loaded, profile);

    let mut cfg = PipelineConfig::new(&dir, loaded.block);
    cfg.ngpus = loaded.ngpus;
    cfg.host_buffers = loaded.host_buffers;
    cfg.device_buffers = loaded.device_buffers;
    cfg.threads = loaded.threads;
    cfg.lane_threads = loaded.lane_threads;
    let report = run(&cfg).unwrap();
    assert_eq!(report.snps, dims.m);
    verify_against_oracle(&dir, 1e-8).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn degenerate_probe_on_tiny_dataset_falls_back_to_safe_defaults() {
    // 16 × 8 f64 = 1 KiB — far below the probe's reliability floor. The
    // plan must come back as the paper defaults, and still run fine.
    let dir = tmpdir("tiny");
    let dims = Dims::new(16, 2, 8).unwrap();
    generate(&dir, dims, 4, 5).unwrap();
    let rates = probe_dataset(&dir, &quick_probe()).unwrap();
    assert!(!rates.reliable);
    let profile = plan(&rates, dims, &PlanOpts { total_threads: 2, ..PlanOpts::default() });
    assert_eq!(profile, TunedProfile::safe_defaults(8, 2));
    let mut cfg = PipelineConfig::new(&dir, profile.block);
    cfg.host_buffers = profile.host_buffers;
    cfg.device_buffers = profile.device_buffers;
    run(&cfg).unwrap();
    verify_against_oracle(&dir, 1e-8).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Parse the v3 journal's records (the test double-checks the on-disk
/// format the adaptive path journals its mixed-width windows in).
fn journal_ranges(path: &std::path::Path) -> Vec<(u64, u64)> {
    let bytes = std::fs::read(path).unwrap();
    assert!(bytes.len() >= 32 && &bytes[..8] == b"CGWJRNL3", "v3 journal header");
    bytes[32..]
        .chunks_exact(16)
        .map(|r| {
            (
                u64::from_le_bytes(r[..8].try_into().unwrap()),
                u64::from_le_bytes(r[8..].try_into().unwrap()),
            )
        })
        .collect()
}

#[test]
fn adaptive_run_is_correct_observed_in_metrics_and_resumable_mid_switch() {
    // Throttle reads hard so the pipeline is demonstrably read-starved:
    // the re-planner evaluates at every segment boundary (visible as
    // Phase::Replan in the metrics) and may grow the block mid-run.
    let dir = tmpdir("adapt");
    let dims = Dims::new(64, 2, 4096).unwrap(); // xr = 2 MiB
    generate(&dir, dims, 256, 13).unwrap();
    let mut cfg = PipelineConfig::new(&dir, 128);
    cfg.read_throttle = Some(Throttle { bytes_per_sec: 4e6 });
    cfg.adapt = true;
    cfg.adapt_every = 4;
    cfg.resume = true; // journal every window
    let report = run(&cfg).unwrap();
    assert_eq!(report.snps, dims.m);
    assert!(
        report.metrics.count(Phase::Replan) >= 1,
        "re-plan evaluations must appear in the metrics"
    );
    verify_against_oracle(&dir, 1e-8).unwrap();

    // Crash-resume across whatever geometry the adaptive run journaled:
    // keep the first half of the records, clobber every column they do
    // NOT cover, and resume with the ORIGINAL block size.
    let paths = DatasetPaths::new(&dir);
    let ranges = journal_ranges(&paths.progress());
    assert_eq!(ranges.iter().map(|&(_, n)| n).sum::<u64>(), dims.m as u64);
    let keep = ranges.len() / 2;
    let bytes = std::fs::read(paths.progress()).unwrap();
    std::fs::write(&paths.progress(), &bytes[..32 + keep * 16]).unwrap();
    {
        use cugwas::storage::XrdFile;
        let covered: Vec<(u64, u64)> = ranges[..keep].to_vec();
        let f = XrdFile::open_rw(&paths.results()).unwrap();
        let p = dims.pl as u64 + 1;
        for col in 0..dims.m as u64 {
            if !covered.iter().any(|&(c0, n)| col >= c0 && col < c0 + n) {
                f.write_cols(col, 1, &vec![f64::NAN; p as usize]).unwrap();
            }
        }
    }
    let report2 = run(&cfg).unwrap();
    assert!(report2.blocks >= 1, "uncovered columns must be recomputed");
    verify_against_oracle(&dir, 1e-8).unwrap();

    // A completed adaptive run resumes as a no-op.
    let report3 = run(&cfg).unwrap();
    assert_eq!(report3.blocks, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}
