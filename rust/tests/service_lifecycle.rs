//! Crash-restart / drain / deadline / disk-degradation matrix for the
//! durable service lifecycle (ISSUE 10).
//!
//! The injector and the drain flag are process-global, so this suite
//! lives in its own test binary and every scenario runs under one lock:
//! arm → serve → assert → disarm. The acceptance bar: a `kill -9`
//! simulated at seeded kill points (journal-commit crash, WAL-append
//! crash, torn WAL append) must cost at most the uncommitted segments —
//! the restarted serve resumes from the v4 progress journal and the
//! final `r.xrd` is *byte-identical* to the fault-free baseline, with
//! nonzero recovery counters; a drain must refuse admission, checkpoint
//! in-flight work and exit 0; a deadline must cancel (not fail) within
//! a segment; a disk below the low-water mark must pause admission and
//! fail the right job naming the starved path.

use cugwas::config::ServiceConfig;
use cugwas::gwas::problem::Dims;
use cugwas::service::{serve, JobSpec};
use cugwas::storage::fault::{self, FaultPlan, RetryPolicy};
use cugwas::storage::{generate, Throttle};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Duration;

/// One scenario at a time: injector state and the drain flag are
/// process-global.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cugwas_life_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A small study: 8 windows of 64 columns — enough segment boundaries
/// for checkpoints while finishing in well under a second unthrottled.
fn make_dataset(tag: &str) -> (PathBuf, Dims) {
    let dir = tmpdir(tag);
    let dims = Dims::new(64, 2, 512).unwrap();
    generate(&dir, dims, 64, 2024).unwrap();
    (dir, dims)
}

/// One worker lane, adaptation on so the engine commits (and checks its
/// stop points) every `adapt_every` windows.
fn job(name: &str, dir: &Path) -> JobSpec {
    let mut j = JobSpec::new(name, dir);
    j.block = 64;
    j.adapt = true;
    j.adapt_every = 2;
    j
}

fn svc_cfg(jobs: Vec<JobSpec>, cache_mb: u64, wal: Option<PathBuf>) -> ServiceConfig {
    ServiceConfig {
        workers: 1,
        mem_budget_bytes: 1 << 30,
        cache_bytes: cache_mb << 20,
        threads: 2,
        spool: None,
        watch: false,
        auto_tune: false,
        metrics_addr: None,
        wal,
        drain_timeout_secs: 30,
        disk_low_water_mb: 0,
        jobs,
        fault: Default::default(),
    }
}

/// Reset every process-global switch to its boot state.
fn reset() {
    fault::disarm();
    fault::set_policy(RetryPolicy::default());
    fault::set_integrity_enabled(false);
}

/// Scrub a dataset back to "never streamed": result and journal gone.
fn scrub(dir: &Path) {
    let _ = std::fs::remove_file(dir.join("r.xrd"));
    let _ = std::fs::remove_file(dir.join("r.progress"));
}

/// The tentpole: crash the process at two seeded kill points — the
/// engine's journal commit (power cut mid-segment) AND the WAL append
/// recording the outcome (the window between the journal's state and
/// the WAL's record of it) — then restart. The WAL replay must resume
/// the job from its progress journal, replay only the uncommitted
/// windows, and land `r.xrd` byte-identical. Cache off, then on.
#[test]
fn crash_at_seeded_kill_points_then_restart_resumes_bit_identically() {
    let _g = lock();
    reset();
    cugwas::telemetry::set_metrics_enabled(true);
    for (label, cache_mb) in [("no cache", 0u64), ("cache", 64)] {
        let (dir, dims) = make_dataset(&format!("crash{cache_mb}"));
        let wal = dir.join("svc.wal");

        // Fault-free baseline bytes (no WAL: this pass must not leave a
        // `done` record that would make the chaos serve skip the job).
        let rep = serve(&svc_cfg(vec![job("study", &dir)], cache_mb, None)).unwrap();
        assert_eq!(rep.failed(), 0, "[{label}] {}", rep.render());
        assert_eq!(rep.total_snps(), dims.m, "[{label}]");
        let baseline = std::fs::read(dir.join("r.xrd")).unwrap();
        scrub(&dir);

        // Kill point 1 fires inside the engine: the 2nd segment commit
        // crashes, so the journal holds segment 1 committed and segment
        // 2's intents only. Kill point 2 fires in the scheduler: the 4th
        // WAL append — the `failed` record for that very outcome —
        // crashes too, so the WAL's last word is `streaming`. That is
        // exactly what `kill -9` mid-segment leaves on disk.
        fault::set_policy(RetryPolicy { job_retries: 0, ..Default::default() });
        fault::arm(FaultPlan { commit_crash_at: 2, wal_crash_at: 4, ..Default::default() });
        let err = serve(&svc_cfg(vec![job("study", &dir)], cache_mb, Some(wal.clone())))
            .unwrap_err();
        assert!(err.to_string().contains("injected"), "[{label}] {err}");
        assert!(fault::counters().injected > 0, "[{label}]");
        reset();

        // Restart: replay finds `streaming`, resumes the journal, and
        // recomputes only the windows that never reached a durable
        // commit — strictly fewer than the whole study.
        let reg = cugwas::telemetry::global();
        let replays0 = reg.wal_replays_total.get();
        let resumed0 = reg.jobs_resumed_total.get();
        let rep2 = serve(&svc_cfg(vec![job("study", &dir)], cache_mb, Some(wal.clone())))
            .unwrap();
        assert_eq!(rep2.failed(), 0, "[{label}] {}", rep2.render());
        let replayed = rep2.total_snps();
        assert!(
            replayed > 0 && replayed < dims.m,
            "[{label}] resume must replay only the uncommitted tail, got {replayed}/{}",
            dims.m
        );
        assert!(reg.wal_replays_total.get() > replays0, "[{label}] replay counter");
        assert!(reg.jobs_resumed_total.get() > resumed0, "[{label}] resume counter");
        let bytes = std::fs::read(dir.join("r.xrd")).unwrap();
        assert_eq!(bytes, baseline, "[{label}] restart diverged from the baseline");

        // One more restart: the WAL now ends in `done` + a seal — the
        // job is terminal and must not run a third time.
        let rep3 =
            serve(&svc_cfg(vec![job("study", &dir)], cache_mb, Some(wal))).unwrap();
        assert_eq!(rep3.total_snps(), 0, "[{label}] terminal jobs must not re-run");
        std::fs::remove_dir_all(&dir).unwrap();
    }
    reset();
}

/// A WAL append torn mid-record (power cut mid-write) fails that serve;
/// the next serve truncates the torn tail on open and runs the job to
/// completion from the surviving prefix.
#[test]
fn a_torn_wal_append_is_truncated_on_reopen() {
    let _g = lock();
    reset();
    let (dir, dims) = make_dataset("tornwal");
    let wal = dir.join("svc.wal");

    // The very first append (the job's `submitted` record) tears.
    fault::arm(FaultPlan { wal_torn_append_at: 1, ..Default::default() });
    let err = serve(&svc_cfg(vec![job("study", &dir)], 0, Some(wal.clone()))).unwrap_err();
    assert!(err.to_string().contains("torn"), "{err}");
    let torn_len = std::fs::metadata(&wal).unwrap().len();
    assert!(torn_len > 0, "the torn half-record must be durable");
    fault::disarm();

    let rep = serve(&svc_cfg(vec![job("study", &dir)], 0, Some(wal.clone()))).unwrap();
    assert_eq!(rep.failed(), 0, "{}", rep.render());
    assert_eq!(rep.total_snps(), dims.m);
    // The reopen truncated the torn tail before appending new records:
    // every line in the surviving WAL is intact (checksummed).
    let text = std::fs::read_to_string(&wal).unwrap();
    assert!(text.lines().count() >= 4, "{text}");
    assert!(text.lines().last().unwrap().contains("\tsealed\t"), "{text}");

    reset();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A drain requested mid-stream checkpoints the in-flight job at its
/// next segment boundary, reports it cancelled (exit 0 — not failed),
/// and the next serve resumes it byte-identically.
#[test]
fn drain_mid_stream_checkpoints_and_a_restart_completes_the_job() {
    let _g = lock();
    reset();
    cugwas::telemetry::set_metrics_enabled(true);
    let (dir, dims) = make_dataset("drain");
    let wal = dir.join("svc.wal");

    // Baseline bytes, then scrub.
    serve(&svc_cfg(vec![job("study", &dir)], 0, None)).unwrap();
    let baseline = std::fs::read(dir.join("r.xrd")).unwrap();
    scrub(&dir);

    // Throttle the stream so the drain lands mid-pass (~0.5 s/window,
    // stop points every window), and request it from another thread —
    // the same flag SIGINT and `POST /drain` set.
    let mut slow = job("study", &dir);
    slow.adapt_every = 1;
    slow.read_throttle = Some(Throttle { bytes_per_sec: 64_000.0 });
    let trigger = std::thread::spawn(|| {
        std::thread::sleep(Duration::from_millis(400));
        cugwas::service::request_drain();
    });
    let reg = cugwas::telemetry::global();
    let drains0 = reg.drains_total.get();
    let cancelled0 = reg.jobs_cancelled_total.get();
    let rep = serve(&svc_cfg(vec![slow], 0, Some(wal.clone()))).unwrap();
    trigger.join().unwrap();
    assert_eq!(rep.failed(), 0, "a drain must not fail jobs: {}", rep.render());
    assert_eq!(rep.cancelled(), 1, "{}", rep.render());
    assert!(rep.total_snps() < dims.m, "the drain must interrupt the pass");
    assert!(reg.drains_total.get() > drains0);
    assert!(reg.jobs_cancelled_total.get() > cancelled0);
    let text = std::fs::read_to_string(&wal).unwrap();
    assert!(text.contains("\tcancelled\t"), "{text}");
    assert!(text.lines().last().unwrap().contains("\tsealed\t"), "drain seals the WAL");

    // Restart (unthrottled — throttles are runtime policy, not job
    // identity, though the work-shaping `adapt_every` is): the
    // `cancelled` record resumes the journal and the final bytes match
    // the uninterrupted baseline.
    let mut fresh = job("study", &dir);
    fresh.adapt_every = 1;
    let rep2 = serve(&svc_cfg(vec![fresh], 0, Some(wal))).unwrap();
    assert_eq!(rep2.failed(), 0, "{}", rep2.render());
    let replayed = rep2.total_snps();
    assert!(replayed > 0 && replayed < dims.m, "resumed, not restarted: {replayed}");
    assert_eq!(std::fs::read(dir.join("r.xrd")).unwrap(), baseline);

    reset();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A job past its deadline is cancelled (checkpointed) at the next
/// segment boundary — freeing the lane — and a resubmission *without*
/// the deadline resumes its journal: scheduling policy is not job
/// identity.
#[test]
fn a_deadline_cancels_within_a_segment_and_the_job_stays_resumable() {
    let _g = lock();
    reset();
    let (dir, dims) = make_dataset("deadline");
    let wal = dir.join("svc.wal");

    serve(&svc_cfg(vec![job("study", &dir)], 0, None)).unwrap();
    let baseline = std::fs::read(dir.join("r.xrd")).unwrap();
    scrub(&dir);

    // ~0.5 s/window against a 1 s deadline: the cancel fires one
    // segment boundary after the deadline passes, a few windows in.
    let mut slow = job("study", &dir);
    slow.adapt_every = 1;
    slow.read_throttle = Some(Throttle { bytes_per_sec: 64_000.0 });
    slow.deadline_secs = 1;
    let t0 = std::time::Instant::now();
    let rep = serve(&svc_cfg(vec![slow], 0, Some(wal.clone()))).unwrap();
    assert_eq!(rep.failed(), 0, "a deadline is a cancel, not a failure: {}", rep.render());
    assert_eq!(rep.cancelled(), 1, "{}", rep.render());
    assert!(rep.total_snps() < dims.m, "the deadline must interrupt the pass");
    // Lane freed promptly: well before the ~4 s a full throttled pass
    // would take (deadline 1 s + at most ~one window past it + slack).
    assert!(
        t0.elapsed() < Duration::from_secs(3),
        "deadline took {:?} to free the lane",
        t0.elapsed()
    );

    // Resubmitted with no deadline (and no throttle): scheduling policy
    // is excluded from the spec hash, so this is the *same* job and the
    // WAL's `cancelled` record resumes its journal.
    let mut fresh = job("study", &dir);
    fresh.adapt_every = 1;
    let rep2 = serve(&svc_cfg(vec![fresh], 0, Some(wal))).unwrap();
    assert_eq!(rep2.failed(), 0, "{}", rep2.render());
    let replayed = rep2.total_snps();
    assert!(replayed > 0 && replayed < dims.m, "resumed, not restarted: {replayed}");
    assert_eq!(std::fs::read(dir.join("r.xrd")).unwrap(), baseline);

    reset();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Free space below the low-water mark with nothing in flight: the
/// sentinel pauses admission and fails the queued jobs with an error
/// naming the starved path — never a hang, never a torn journal. With
/// the fault cleared, the same dataset streams to completion.
#[test]
fn disk_below_low_water_fails_queued_jobs_naming_the_path() {
    let _g = lock();
    reset();
    cugwas::telemetry::set_metrics_enabled(true);
    let (dir, dims) = make_dataset("lowwater");

    fault::arm(FaultPlan { fake_disk_free_mb: 1, ..Default::default() });
    let mut cfg = svc_cfg(vec![job("study", &dir)], 0, Some(dir.join("svc.wal")));
    cfg.disk_low_water_mb = 10;
    let reg = cugwas::telemetry::global();
    let low0 = reg.disk_low_water_total.get();
    let rep = serve(&cfg).unwrap();
    assert_eq!(rep.failed(), 1, "{}", rep.render());
    assert_eq!(rep.total_snps(), 0, "nothing may stream under ENOSPC");
    let err = rep.jobs[0].error.as_deref().unwrap();
    assert!(err.contains("low-water"), "{err}");
    assert!(err.contains(dir.file_name().unwrap().to_str().unwrap()), "must name the path: {err}");
    assert!(reg.disk_low_water_total.get() > low0, "sentinel counter");
    fault::disarm();

    // Space recovered: a fresh submission (fresh WAL — the failed job is
    // terminal in the old one) streams normally.
    let rep2 = serve(&svc_cfg(vec![job("study", &dir)], 0, None)).unwrap();
    assert_eq!(rep2.failed(), 0, "{}", rep2.render());
    assert_eq!(rep2.total_snps(), dims.m);

    reset();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A crash injected between the quarantine rename and its directory
/// syncs: the bad spool file still leaves the inbox exactly once, the
/// service reports it and keeps running, and the on-disk state is the
/// recoverable half-move the idempotent retry (unit-tested in the
/// scheduler) completes.
#[test]
fn a_crash_mid_quarantine_rename_leaves_recoverable_state() {
    let _g = lock();
    reset();
    let spool = tmpdir("qcrash");
    std::fs::create_dir_all(&spool).unwrap();
    std::fs::write(spool.join("bad.toml"), "[job]\nblock = 8\n").unwrap(); // no dataset

    fault::arm(FaultPlan { quarantine_crash_at: 1, ..Default::default() });
    let mut cfg = svc_cfg(vec![], 0, None);
    cfg.spool = Some(spool.clone());
    let rep = serve(&cfg).unwrap();
    assert_eq!(rep.failed(), 1, "{}", rep.render());
    assert!(rep.jobs[0].error.as_deref().unwrap().contains("missing dataset"));
    assert!(fault::counters().injected > 0, "the quarantine crash never fired");
    // The rename landed; the crash skipped the syncs and the sidecar —
    // the exact torn state a retry must (and does) tolerate.
    assert!(!spool.join("bad.toml").exists(), "the bad file must leave the inbox");
    assert!(spool.join("quarantine/bad.toml").exists());
    assert!(
        !spool.join("quarantine/bad.toml.reason").exists(),
        "the crash fires before the sidecar"
    );
    // The service's own WAL (implicit at <spool>/service.wal) was still
    // sealed cleanly — a control-plane crash never tears the data plane.
    let text = std::fs::read_to_string(spool.join("service.wal")).unwrap();
    assert!(text.lines().last().unwrap().contains("\tsealed\t"), "{text}");

    reset();
    std::fs::remove_dir_all(&spool).unwrap();
}
