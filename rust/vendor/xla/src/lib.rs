//! API-compatible stub of the `xla` PJRT bindings.
//!
//! The production build links the real bindings (PJRT CPU client + HLO
//! compilation); this environment cannot, so the stub mirrors exactly the
//! surface `cugwas` uses. Host-side literal plumbing ([`Literal::vec1`],
//! [`Literal::reshape`], [`Literal::to_vec`]) is functional; anything that
//! would need a live PJRT runtime ([`PjRtClient::cpu`] and everything
//! behind it) returns an error, which the coordinator surfaces as
//! `Error::Runtime` — the native backend never reaches these paths.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error: carries only a message, like the real crate's `Error`
/// does for the failure modes we surface.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real crate's signatures.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT/XLA runtime not available in this build (offline xla stub — use the native backend)"
    ))
}

/// Element types `Literal::to_vec` can produce. Only `f64` is used by the
/// cuGWAS layout contract.
pub trait NativeType: Sized {
    fn from_f64_slice(v: &[f64]) -> Vec<Self>;
}

impl NativeType for f64 {
    fn from_f64_slice(v: &[f64]) -> Vec<f64> {
        v.to_vec()
    }
}

/// Host-side literal: shape + flat `f64` payload.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f64>,
}

impl Literal {
    /// Rank-1 literal from a slice (copies).
    pub fn vec1(data: &[f64]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: data.to_vec() }
    }

    /// Reinterpret with new dims; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: dims {dims:?} imply {want} elements, literal has {}",
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Unpack a tuple literal — only produced by a live runtime.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Array shape of this literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Copy out the payload as `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(T::from_f64_slice(&self.data))
    }
}

/// Dimensions of an array-shaped literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module — construction requires the real parser.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle. `cpu()` is the only constructor `cugwas` calls and
/// it fails in the stub, so the remaining methods are unreachable but keep
/// the call sites typechecking.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled, device-loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device-resident buffer returned by `execute`.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f64>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[7, 7]).is_err());
    }

    #[test]
    fn runtime_entry_points_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
        assert!(Literal::vec1(&[0.0]).to_tuple().is_err());
    }
}
