//! Service throughput: N jobs sharing one dataset, with and without the
//! shared block cache.
//!
//! The service's claim is that the streamed-block win compounds across
//! studies: the first job pays the disk once, every follow-up on the
//! same dataset is fed from RAM. This bench runs the same 3-job queue
//! (all three on one dataset, serialized by the per-dataset lock)
//! against a throttled "HDD" twice — cache disabled vs. enabled — and
//! prints the wall-clock ratio plus the cache counters.
//!
//! ```bash
//! cargo bench --bench service_throughput
//! ```

use cugwas::bench::Table;
use cugwas::config::ServiceConfig;
use cugwas::gwas::problem::Dims;
use cugwas::service::{serve, JobSpec};
use cugwas::storage::{generate, Throttle};
use cugwas::util::human_duration;
use std::time::Duration;

fn main() {
    let fast = std::env::var("CUGWAS_BENCH_FAST").is_ok();
    let dir = std::env::temp_dir().join("cugwas_bench_service");
    let _ = std::fs::remove_dir_all(&dir);
    let (n, m, block) = if fast { (128, 2048, 256) } else { (256, 8192, 512) };
    generate(&dir, Dims::new(n, 3, m).unwrap(), block, 23).unwrap();

    // Emulate the paper's spinning disk so reads dominate, as they do at
    // Terabyte scale; cache hits bypass the throttle entirely.
    let throttle = Some(Throttle { bytes_per_sec: 120e6 });
    let jobs = || -> Vec<JobSpec> {
        (0..3)
            .map(|i| {
                let mut j = JobSpec::new(format!("job-{i}"), &dir);
                j.block = block;
                j.read_throttle = throttle;
                j
            })
            .collect()
    };

    let mut results = Vec::new();
    for (label, cache_bytes) in [("no cache", 0u64), ("256 MB cache", 256 << 20)] {
        let cfg = ServiceConfig {
            workers: 1, // serialize: per-dataset lock forces this anyway
            mem_budget_bytes: 4 << 30,
            cache_bytes,
            threads: 0,
            spool: None,
            watch: false,
            auto_tune: false, // measure the configured knobs, not a plan
            jobs: jobs(),
        };
        let rep = serve(&cfg).expect("service run");
        assert_eq!(rep.failed(), 0);
        results.push((label, rep));
    }

    let mut t = Table::new(
        format!("3 jobs over one dataset (n={n}, m={m}, 120 MB/s reads)"),
        &["config", "service wall", "agg SNPs/s", "cache hits", "disk reads"],
    );
    for (label, rep) in &results {
        t.row(&[
            label.to_string(),
            human_duration(Duration::from_secs_f64(rep.wall_secs)),
            format!("{:.0}", rep.agg_snps_per_sec()),
            rep.cache.hits.to_string(),
            rep.cache.misses.to_string(),
        ]);
    }
    t.print();

    let cold = results[0].1.wall_secs;
    let warm = results[1].1.wall_secs;
    println!(
        "shared-cache speedup: {:.2}x (jobs 2..3 stream from RAM; {} of {} block\n\
         reads never touched the disk)",
        cold / warm.max(1e-12),
        results[1].1.cache.hits,
        results[1].1.cache.hits + results[1].1.cache.misses,
    );
    let _ = std::fs::remove_dir_all(&dir);
}
