//! Service throughput: N jobs sharing one dataset, with and without the
//! shared block cache.
//!
//! The service's claim is that the streamed-block win compounds across
//! studies: the first job pays the disk once, every follow-up on the
//! same dataset is fed from RAM. This bench runs the same 3-job queue
//! (all three on one dataset, serialized by the per-dataset lock)
//! against a throttled "HDD" twice — cache disabled vs. enabled — and
//! prints the wall-clock ratio plus the cache counters.
//!
//! ```bash
//! cargo bench --bench service_throughput
//! ```

use cugwas::bench::Table;
use cugwas::config::ServiceConfig;
use cugwas::gwas::problem::Dims;
use cugwas::service::{serve, JobSpec};
use cugwas::storage::{generate, Throttle};
use cugwas::util::human_duration;
use std::time::Duration;

fn main() {
    let fast = std::env::var("CUGWAS_BENCH_FAST").is_ok();
    let dir = std::env::temp_dir().join("cugwas_bench_service");
    let _ = std::fs::remove_dir_all(&dir);
    let (n, m, block) = if fast { (128, 2048, 256) } else { (256, 8192, 512) };
    generate(&dir, Dims::new(n, 3, m).unwrap(), block, 23).unwrap();

    // Emulate the paper's spinning disk so reads dominate, as they do at
    // Terabyte scale; cache hits bypass the throttle entirely.
    let throttle = Some(Throttle { bytes_per_sec: 120e6 });
    let jobs = || -> Vec<JobSpec> {
        (0..3)
            .map(|i| {
                let mut j = JobSpec::new(format!("job-{i}"), &dir);
                j.block = block;
                j.read_throttle = throttle;
                // Stagger an inert knob (adapt=false) so the jobs do
                // not coalesce into one pass: this bench measures the
                // follow-up passes streaming from the shared cache.
                j.adapt_every = 16 + i;
                j
            })
            .collect()
    };

    let mut results = Vec::new();
    for (label, cache_bytes) in [("no cache", 0u64), ("256 MB cache", 256 << 20)] {
        let cfg = ServiceConfig {
            workers: 1, // serialize: per-dataset lock forces this anyway
            mem_budget_bytes: 4 << 30,
            cache_bytes,
            threads: 0,
            spool: None,
            watch: false,
            auto_tune: false, // measure the configured knobs, not a plan
            metrics_addr: None,
            jobs: jobs(),
            fault: Default::default(),
        };
        let rep = serve(&cfg).expect("service run");
        assert_eq!(rep.failed(), 0);
        results.push((label, rep));
    }

    let mut t = Table::new(
        format!("3 jobs over one dataset (n={n}, m={m}, 120 MB/s reads)"),
        &["config", "service wall", "agg SNPs/s", "cache hits", "disk reads"],
    );
    for (label, rep) in &results {
        t.row(&[
            label.to_string(),
            human_duration(Duration::from_secs_f64(rep.wall_secs)),
            format!("{:.0}", rep.agg_snps_per_sec()),
            rep.cache.hits.to_string(),
            rep.cache.misses.to_string(),
        ]);
    }
    t.print();

    let cold = results[0].1.wall_secs;
    let warm_rep = &results[1].1;
    let warm = warm_rep.wall_secs;
    println!(
        "shared-cache speedup: {:.2}x (jobs 2..3 stream from RAM; {} of {} block\n\
         reads never touched the disk)",
        cold / warm.max(1e-12),
        warm_rep.cache.hits,
        warm_rep.cache.hits + warm_rep.cache.misses,
    );

    // The cache-hit serving headline: throughput of the jobs fed from
    // resident blocks (zero disk, zero per-block memcpy on the slab
    // plane). This is the second gated metric in tools/bench_trend.py —
    // a regression here means the zero-copy hit path got slower.
    let hit_jobs: Vec<_> = warm_rep.jobs.iter().filter(|j| j.cache_hits > 0).collect();
    let (hit_snps, hit_secs) = hit_jobs
        .iter()
        .fold((0usize, 0.0f64), |(s, w), j| (s + j.snps, w + j.wall_secs));
    let cache_hit_snps_per_sec = hit_snps as f64 / hit_secs.max(1e-12);
    for j in &hit_jobs {
        println!(
            "  {}: {} borrowed / {} copied per-block bytes",
            j.name,
            j.bytes_borrowed,
            j.bytes_copied
        );
    }
    println!(
        "{{\"bench\":\"service_throughput\",\"row\":\"cache_hit_snps_per_sec\",\
         \"value\":{cache_hit_snps_per_sec:.3},\"unit\":\"snps/s\"}}"
    );
    println!(
        "{{\"bench\":\"service_throughput\",\"row\":\"shared_cache_speedup\",\
         \"value\":{:.4},\"unit\":\"x\"}}",
        cold / warm.max(1e-12)
    );

    // Trait-batching headline: (SNP, trait) solves per second for one
    // stream at t=1 vs t=32. The batch reuses the per-SNP factorization
    // and the trsm-sized gemm across all 32 right-hand sides, so the
    // batched rate must far exceed the single-trait rate — this is the
    // third gated metric in tools/bench_trend.py.
    use cugwas::coordinator::{run, PipelineConfig};
    let wide = if fast { 8 } else { 32 };
    let mut rates = Vec::new();
    for traits in [1usize, wide] {
        let mut cfg = PipelineConfig::new(&dir, block);
        cfg.traits = traits;
        cfg.perm_seed = 2013;
        let rep = run(&cfg).expect("batched run");
        let rate = (rep.snps * traits) as f64 / rep.wall_secs.max(1e-12);
        println!(
            "  t={traits:>2}: {} for m={m} → {:.0} SNP·trait/s",
            human_duration(Duration::from_secs_f64(rep.wall_secs)),
            rate
        );
        rates.push(rate);
    }
    println!(
        "{{\"bench\":\"service_throughput\",\"row\":\"batched_snps_x_traits_per_sec\",\
         \"value\":{:.3},\"unit\":\"snp_traits/s\"}}",
        rates[1]
    );
    let _ = std::fs::remove_dir_all(&dir);
}
