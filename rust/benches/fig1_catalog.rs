//! Fig. 1 — GWAS catalog statistics (both panels).
//!
//! Regenerates the data behind the paper's Fig. 1a (SNP-count medians +
//! quartiles per year) and Fig. 1b (sample-size medians + quartiles),
//! and checks the two qualitative claims of §1.2 hold in the output.
//!
//! ```bash
//! cargo bench --bench fig1_catalog
//! ```

use cugwas::bench::Table;
use cugwas::stats::{summarize_by_year, synthesize_catalog};

fn main() {
    let rows = synthesize_catalog(2013);
    let summaries = summarize_by_year(&rows);

    let mut a = Table::new("Fig 1a — SNP count per study", &["year", "studies", "q1", "median", "q3"]);
    let mut b = Table::new("Fig 1b — sample size per study", &["year", "studies", "q1", "median", "q3"]);
    for s in &summaries {
        a.row(&[
            s.year.to_string(),
            s.studies.to_string(),
            format!("{:.0}", s.snp_count.q1),
            format!("{:.0}", s.snp_count.median),
            format!("{:.0}", s.snp_count.q3),
        ]);
        b.row(&[
            s.year.to_string(),
            s.studies.to_string(),
            format!("{:.0}", s.sample_size.q1),
            format!("{:.0}", s.sample_size.median),
            format!("{:.0}", s.sample_size.q3),
        ]);
    }
    a.print();
    b.print();

    // The two claims the paper reads off this figure:
    let med_snp = |y: u32| summaries.iter().find(|s| s.year == y).unwrap().snp_count.median;
    let med_n = |y: u32| summaries.iter().find(|s| s.year == y).unwrap().sample_size.median;
    let snp_growth = med_snp(2012) / med_snp(2008);
    let n_late = med_n(2012) / med_n(2010);
    println!("\nshape checks:");
    println!("  SNP-count median growth 2008→2012: {snp_growth:.1}x (paper: 'tremendous', >3x)  {}", ok(snp_growth > 3.0));
    println!("  sample-size median 2010→2012:      {n_late:.2}x (paper: plateau ~10k, ±40%)     {}", ok((0.6..1.6).contains(&n_late)));
}

fn ok(b: bool) -> &'static str {
    if b { "[OK]" } else { "[MISMATCH]" }
}
