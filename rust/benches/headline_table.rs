//! The paper's headline numbers (§1, §4, §5), regenerated:
//!
//! * 2.6× — cuGWAS (1 GPU) over OOC-HP-GWAS           (§4.1, Fig. 6a)
//! * ~9×  — cuGWAS (4 GPUs) over OOC-HP-GWAS          (§1)
//! * 488× — cuGWAS (4 GPUs) over ProbABEL             (§1)
//! * 2.88 s — the ProbABEL reference problem (p=4, n=1500, m=220 833)
//!            that took ProbABEL ~4 h                 (§5)
//!
//! All at paper scale via the DES with the paper's hardware constants
//! (this testbed has no Fermi GPUs — DESIGN.md §4), plus a live
//! small-scale sanity block with honest measured ratios.
//!
//! ```bash
//! cargo bench --bench headline_table
//! ```

use cugwas::baselines::{run_ooc_cpu, run_probabel};
use cugwas::bench::Table;
use cugwas::coordinator::{run, PipelineConfig};
use cugwas::devsim::{simulate, Algo, HardwareProfile, SimConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::generate;
use cugwas::util::human_duration;
use std::time::Duration;

/// Machine-readable trajectory line (one per row); the CI smoke job
/// collects these into the per-push BENCH_<sha>.json artifact.
fn json_line(row: &str, value: f64, unit: &str) {
    println!(
        "{{\"bench\":\"headline_table\",\"row\":\"{row}\",\
         \"value\":{value:.6},\"unit\":\"{unit}\"}}"
    );
}

fn main() {
    // ---- paper scale (sim) ------------------------------------------------
    let dims = Dims::new(10_000, 3, 100_000).unwrap();
    let quadro = HardwareProfile::quadro();
    let tesla = HardwareProfile::tesla();
    let mk = |block: usize, ngpus: usize, profile: HardwareProfile| SimConfig {
        dims,
        block,
        ngpus,
        host_buffers: 3,
        traits: 1,
        profile,
    };
    let ooc = simulate(Algo::OocCpu, &mk(5_000, 1, quadro)).unwrap();
    let cu1 = simulate(Algo::CuGwas, &mk(5_000, 1, quadro)).unwrap();
    let cu4 = simulate(Algo::CuGwas, &mk(20_000, 4, tesla)).unwrap();

    let mut t = Table::new(
        "headline — paper scale (n=10k, m=100k, paper hardware constants)",
        &["claim", "paper", "reproduced", "status"],
    );
    let r1 = ooc.total_secs / cu1.total_secs;
    let r9 = ooc.total_secs / cu4.total_secs;
    t.row(&["cuGWAS-1GPU vs OOC-HP-GWAS".into(), "2.6x".into(), format!("{r1:.2}x"), ok((2.0..3.2).contains(&r1))]);
    t.row(&["cuGWAS-4GPU vs OOC-HP-GWAS".into(), "~9x".into(), format!("{r9:.2}x"), ok((6.0..12.0).contains(&r9))]);
    json_line("cugwas1_vs_ooc", r1, "x");
    json_line("cugwas4_vs_ooc", r9, "x");

    // The §5 reference problem: p=4, n=1500, m=220 833 → 2.88 s on 4 GPUs.
    let ref_dims = Dims::new(1_500, 3, 220_833).unwrap();
    let cu_ref = simulate(
        Algo::CuGwas,
        &SimConfig {
            dims: ref_dims,
            block: 20_000,
            ngpus: 4,
            host_buffers: 3,
            traits: 1,
            profile: tesla,
        },
    )
    .unwrap();
    let pa_ref = simulate(
        Algo::Probabel,
        &SimConfig {
            dims: ref_dims,
            block: 20_000,
            ngpus: 1,
            host_buffers: 3,
            traits: 1,
            profile: quadro,
        },
    )
    .unwrap();
    t.row(&[
        "ProbABEL ref problem (cuGWAS)".into(),
        "2.88 s".into(),
        human_duration(Duration::from_secs_f64(cu_ref.total_secs)),
        ok((0.5..30.0).contains(&cu_ref.total_secs)),
    ]);
    t.row(&[
        "ProbABEL ref problem (ProbABEL)".into(),
        "~4 h".into(),
        human_duration(Duration::from_secs_f64(pa_ref.total_secs)),
        ok((3_600.0..40_000.0).contains(&pa_ref.total_secs)),
    ]);
    // The 488× claim uses the paper's §5 discounting on the REFERENCE
    // problem: ProbABEL's 2010 timing halved (Moore's law), cuGWAS plus
    // ~6 s of GPU/preprocess init the streaming timings exclude.
    let r488 = (pa_ref.total_secs / 2.0) / (cu_ref.total_secs + 6.0);
    t.row(&[
        "cuGWAS vs ProbABEL (§5 arithmetic)".into(),
        "488x".into(),
        format!("{r488:.0}x"),
        ok((150.0..2_000.0).contains(&r488)),
    ]);
    json_line("probabel_ref_cugwas", cu_ref.total_secs, "s");
    json_line("probabel_ref_probabel", pa_ref.total_secs, "s");
    json_line("cugwas_vs_probabel_488", r488, "x");
    t.print();

    // ---- live sanity block (this machine, measured) -------------------------
    let fast = std::env::var("CUGWAS_BENCH_FAST").is_ok();
    let dir = std::env::temp_dir().join("cugwas_headline_live");
    let _ = std::fs::remove_dir_all(&dir);
    let m = if fast { 2048 } else { 8192 };
    let live_dims = Dims::new(384, 3, m).unwrap();
    generate(&dir, live_dims, 128, 13).unwrap();
    let ooc = run_ooc_cpu(&dir, 128, None).unwrap();
    let cu = run(&PipelineConfig::new(&dir, 128)).unwrap();
    let pa = run_probabel(&dir).unwrap();
    let mut live = Table::new(
        format!("live — measured on this machine (n=384, m={m}, native lanes)"),
        &["solver", "wall", "vs cuGWAS"],
    );
    for (name, key, wall) in [
        ("cuGWAS (pipelined)", "live_cugwas", cu.wall_secs),
        ("OOC-HP-GWAS", "live_ooc", ooc.wall_secs),
        ("ProbABEL-like", "live_probabel", pa.wall_secs),
    ] {
        live.row(&[
            name.into(),
            human_duration(Duration::from_secs_f64(wall)),
            format!("{:.2}x", wall / cu.wall_secs),
        ]);
        json_line(key, wall, "s");
    }
    // The headline streaming throughput: what the perf-trajectory gate
    // (tools/bench_trend.py) compares across pushes.
    json_line("live_cugwas_snps_per_sec", cu.snps_per_sec, "snps/s");
    live.print();
    println!(
        "\nnote: live lanes share this machine's CPU cores, so the live table shows\n\
         schedule overhead/overlap, not accelerator speedups; the paper-hardware\n\
         ratios come from the DES above (DESIGN.md §4)."
    );
    let _ = std::fs::remove_dir_all(&dir);
}

fn ok(b: bool) -> String {
    (if b { "[OK]" } else { "[MISMATCH]" }).to_string()
}
