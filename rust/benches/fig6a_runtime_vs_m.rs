//! Fig. 6a — runtime vs m: cuGWAS (1 GPU) against OOC-HP-GWAS, with the
//! red line marking the largest m for which two blocks of X_R fit in GPU
//! memory (i.e. where a non-streaming implementation would stop).
//!
//! Reproduced twice:
//! 1. **live** — both algorithms on this machine over an m-sweep
//!    (native backend so CPU vs "device" rates are honest);
//! 2. **sim** — at paper scale (n = 10 000) with the Quadro profile,
//!    where the 2.4–2.6× gap and linearity in m should reproduce.
//!
//! ```bash
//! cargo bench --bench fig6a_runtime_vs_m
//! ```

use cugwas::baselines::run_ooc_cpu;
use cugwas::bench::{ratio_cell, Table};
use cugwas::coordinator::{run, PipelineConfig};
use cugwas::devsim::{simulate, Algo, HardwareProfile, SimConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::generate;
use cugwas::util::human_duration;
use std::time::Duration;

fn main() {
    // ---- live sweep -----------------------------------------------------
    let fast = std::env::var("CUGWAS_BENCH_FAST").is_ok();
    let n = 384;
    let block = 128;
    let sweep: &[usize] = if fast { &[1024, 2048] } else { &[1024, 2048, 4096, 8192, 16384] };
    let mut live = Table::new(
        format!("Fig 6a live — runtime vs m (n={n}, block={block})"),
        &["m", "OOC-HP-GWAS", "cuGWAS", "speedup"],
    );
    for &m in sweep {
        let dir = std::env::temp_dir().join(format!("cugwas_fig6a_{m}"));
        let _ = std::fs::remove_dir_all(&dir);
        generate(&dir, Dims::new(n, 3, m).unwrap(), block, 5).unwrap();
        let ooc = run_ooc_cpu(&dir, block, None).unwrap();
        let cu = run(&PipelineConfig::new(&dir, block)).unwrap();
        live.row(&[
            m.to_string(),
            human_duration(Duration::from_secs_f64(ooc.wall_secs)),
            human_duration(Duration::from_secs_f64(cu.wall_secs)),
            ratio_cell(ooc.wall_secs, cu.wall_secs),
        ]);
        let _ = std::fs::remove_dir_all(&dir);
    }
    live.print();

    // ---- sim at paper scale ----------------------------------------------
    // Red line: largest m where TWO blocks of X_R fit in the Quadro 6000's
    // 6 GB next to the 800 MB L (paper: m ≈ 22 500 for n = 10 000).
    let n_paper = 10_000usize;
    let gpu_mem = 6.0e9 - 0.8e9;
    let red_line = (gpu_mem / 2.0 / (n_paper as f64 * 8.0)) as usize;
    let mut sim = Table::new(
        format!("Fig 6a sim — paper scale (n={n_paper}, Quadro profile)"),
        &["m", "OOC-HP-GWAS", "cuGWAS 1GPU", "speedup", "needs streaming?"],
    );
    let mut speedups = Vec::new();
    for m in [25_000usize, 50_000, 100_000, 200_000, 400_000] {
        let cfg = SimConfig {
            dims: Dims::new(n_paper, 3, m).unwrap(),
            block: 5_000,
            ngpus: 1,
            host_buffers: 3,
            traits: 1,
            profile: HardwareProfile::quadro(),
        };
        let ooc = simulate(Algo::OocCpu, &cfg).unwrap();
        let cu = simulate(Algo::CuGwas, &cfg).unwrap();
        speedups.push(ooc.total_secs / cu.total_secs);
        sim.row(&[
            m.to_string(),
            human_duration(Duration::from_secs_f64(ooc.total_secs)),
            human_duration(Duration::from_secs_f64(cu.total_secs)),
            ratio_cell(ooc.total_secs, cu.total_secs),
            if m > red_line { "yes (past red line)".into() } else { "no".into() },
        ]);
    }
    sim.print();
    println!("\nred line (2 blocks in 6 GB GPU memory, n=10 000): m ≈ {red_line}");
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    println!(
        "shape checks: speedup ≈ {avg:.2}x (paper: 2.6x) {}; runtime linear in m {}",
        ok((2.0..3.2).contains(&avg)),
        ok(linearity_ok(&speedups))
    );
}

fn linearity_ok(speedups: &[f64]) -> bool {
    // Linear runtime in m ⇒ constant speedup across the sweep.
    let min = speedups.iter().cloned().fold(f64::MAX, f64::min);
    let max = speedups.iter().cloned().fold(f64::MIN, f64::max);
    max / min < 1.15
}

fn ok(b: bool) -> &'static str {
    if b { "[OK]" } else { "[MISMATCH]" }
}
