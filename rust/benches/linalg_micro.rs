//! Microbenchmarks of the native linalg hot paths (the L3 substrate the
//! CPU baselines and S-loop run on). Reports effective GFlop/s so the
//! §Perf log in EXPERIMENTS.md can track the micro-kernel against the
//! machine's practical roofline.
//!
//! ```bash
//! cargo bench --bench linalg_micro
//! ```

use cugwas::bench::{Bench, Table};
use cugwas::linalg::{gemm, potrf, trsm_lower_left, Matrix};
use cugwas::util::XorShift;

fn main() {
    let bench = Bench::from_env();
    let mut rng = XorShift::new(1);
    let mut t = Table::new("linalg micro", &["kernel", "shape", "median", "GFlop/s"]);

    for &(m, k, n) in &[(256usize, 256usize, 256usize), (512, 512, 512), (1024, 1024, 128)] {
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let mut c = Matrix::zeros(m, n);
        let meas = bench.measure(format!("gemm {m}x{k}x{n}"), || {
            gemm(1.0, &a, &b, 0.0, &mut c).unwrap();
        });
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        t.row(&[
            "gemm".into(),
            format!("{m}x{k}x{n}"),
            cugwas::bench::dur_cell(meas.median()),
            format!("{:.2}", flops / meas.median().as_secs_f64() / 1e9),
        ]);
    }

    for &(nn, nrhs) in &[(512usize, 256usize), (1024, 256)] {
        let spd = Matrix::rand_spd(nn, 4.0, &mut rng);
        let l = potrf(&spd).unwrap();
        let b0 = Matrix::randn(nn, nrhs, &mut rng);
        let mut b = b0.clone();
        let meas = bench.measure(format!("trsm {nn}x{nrhs}"), || {
            b = b0.clone();
            trsm_lower_left(&l, &mut b).unwrap();
        });
        let flops = nn as f64 * nn as f64 * nrhs as f64;
        t.row(&[
            "trsm".into(),
            format!("{nn}x{nrhs}"),
            cugwas::bench::dur_cell(meas.median()),
            format!("{:.2}", flops / meas.median().as_secs_f64() / 1e9),
        ]);
    }

    {
        let nn = 512;
        let spd = Matrix::rand_spd(nn, 4.0, &mut rng);
        let meas = bench.measure("potrf 512", || {
            potrf(&spd).unwrap();
        });
        let flops = nn as f64 * nn as f64 * nn as f64 / 3.0;
        t.row(&[
            "potrf".into(),
            format!("{nn}"),
            cugwas::bench::dur_cell(meas.median()),
            format!("{:.2}", flops / meas.median().as_secs_f64() / 1e9),
        ]);
    }
    t.print();
}
