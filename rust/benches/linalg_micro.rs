//! Microbenchmarks of the native linalg hot paths (the L3 substrate the
//! CPU baselines and S-loop run on). Reports effective GFlop/s so the
//! §Perf log in EXPERIMENTS.md can track the micro-kernel against the
//! machine's practical roofline, plus a thread-count sweep (1/2/4/ncpu)
//! of the parallel gemm/trsm panels.
//!
//! Besides the human-readable tables, every measurement is emitted as a
//! machine-readable JSON line (`{"bench":"linalg_micro",...}`) so future
//! PRs — and the CI smoke job — can track the perf trajectory by
//! grepping the log instead of parsing tables.
//!
//! The sweep also re-checks determinism on the spot: each parallel
//! result is compared bit-for-bit against the single-thread result, so a
//! kernel regression that breaks the reduction order fails this bench
//! loudly rather than shifting numbers quietly. The microkernel section
//! does the same across *paths*: the register-tiled microkernel and the
//! scalar reference nest must agree bit for bit on every element, and
//! the headline `gemm_gflops`/`trsm_gflops` rows (gated by
//! `tools/bench_trend.py`) report the microkernel rate alongside its
//! speedup over the reference and the fraction of the tuner's probed
//! kernel rate it reaches.
//!
//! ```bash
//! cargo bench --bench linalg_micro
//! ```

use cugwas::bench::{Bench, Table};
use cugwas::linalg::{gemm, micro, potrf, trsm_lower_left, Matrix};
use cugwas::util::{threads, XorShift};

fn json_line(kernel: &str, shape: &str, nthreads: usize, median_secs: f64, gflops: f64) {
    println!(
        "{{\"bench\":\"linalg_micro\",\"kernel\":\"{kernel}\",\"shape\":\"{shape}\",\
         \"threads\":{nthreads},\"median_secs\":{median_secs:.6},\"gflops\":{gflops:.3}}}"
    );
}

/// A headline row `tools/bench_trend.py` tracks (and, for the gated
/// rows, enforces) across pushes.
fn headline(row: &str, value: f64) {
    println!("{{\"bench\":\"linalg_micro\",\"row\":\"{row}\",\"value\":{value:.3}}}");
}

/// Bit-exact comparison across kernel paths: value equality is not
/// enough (it conflates `-0.0`/`0.0`), the per-element contract is on
/// the bits.
fn assert_bits_eq(a: &Matrix, b: &Matrix, what: &str) {
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}: element {i} differs: {x:e} vs {y:e}");
    }
}

fn main() {
    let bench = Bench::from_env();
    let mut rng = XorShift::new(1);
    let mut t =
        Table::new("linalg micro (single thread)", &["kernel", "shape", "median", "GFlop/s"]);

    for &(m, k, n) in &[(256usize, 256usize, 256usize), (512, 512, 512), (1024, 1024, 128)] {
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let mut c = Matrix::zeros(m, n);
        let _g = threads::with_budget(1);
        let meas = bench.measure(format!("gemm {m}x{k}x{n}"), || {
            gemm(1.0, &a, &b, 0.0, &mut c).unwrap();
        });
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let gflops = flops / meas.median().as_secs_f64() / 1e9;
        json_line("gemm", &format!("{m}x{k}x{n}"), 1, meas.median().as_secs_f64(), gflops);
        t.row(&[
            "gemm".into(),
            format!("{m}x{k}x{n}"),
            cugwas::bench::dur_cell(meas.median()),
            format!("{gflops:.2}"),
        ]);
    }

    for &(nn, nrhs) in &[(512usize, 256usize), (1024, 256)] {
        let spd = Matrix::rand_spd(nn, 4.0, &mut rng);
        let l = potrf(&spd).unwrap();
        let b0 = Matrix::randn(nn, nrhs, &mut rng);
        let mut b = b0.clone();
        let _g = threads::with_budget(1);
        let meas = bench.measure(format!("trsm {nn}x{nrhs}"), || {
            b = b0.clone();
            trsm_lower_left(&l, &mut b).unwrap();
        });
        let flops = nn as f64 * nn as f64 * nrhs as f64;
        let gflops = flops / meas.median().as_secs_f64() / 1e9;
        json_line("trsm", &format!("{nn}x{nrhs}"), 1, meas.median().as_secs_f64(), gflops);
        t.row(&[
            "trsm".into(),
            format!("{nn}x{nrhs}"),
            cugwas::bench::dur_cell(meas.median()),
            format!("{gflops:.2}"),
        ]);
    }

    {
        let nn = 512;
        let spd = Matrix::rand_spd(nn, 4.0, &mut rng);
        let meas = bench.measure("potrf 512", || {
            potrf(&spd).unwrap();
        });
        let flops = nn as f64 * nn as f64 * nn as f64 / 3.0;
        let gflops = flops / meas.median().as_secs_f64() / 1e9;
        json_line("potrf", "512", 1, meas.median().as_secs_f64(), gflops);
        t.row(&[
            "potrf".into(),
            format!("{nn}"),
            cugwas::bench::dur_cell(meas.median()),
            format!("{gflops:.2}"),
        ]);
    }
    t.print();

    // ---- thread sweep (the tentpole metric: gemm/trsm panel scaling) ----
    let ncpu = threads::available();
    let mut sweep: Vec<usize> = vec![1, 2, 4];
    if !sweep.contains(&ncpu) {
        sweep.push(ncpu);
    }

    let mut ts = Table::new(
        format!("thread sweep ({ncpu} cores) — 512³ gemm, 512×512 trsm"),
        &["kernel", "threads", "median", "GFlop/s", "vs 1T"],
    );

    // gemm 512³
    {
        let (m, k, n) = (512usize, 512usize, 512usize);
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let mut base = 0.0f64;
        let mut reference: Option<Matrix> = None;
        for &nt in &sweep {
            let mut c = Matrix::zeros(m, n);
            let _g = threads::with_budget(nt);
            let meas = bench.measure(format!("gemm 512³ @{nt}T"), || {
                gemm(1.0, &a, &b, 0.0, &mut c).unwrap();
            });
            // Determinism spot-check: parallel == serial, bit for bit.
            match &reference {
                None => reference = Some(c.clone()),
                Some(r) => assert_eq!(&c, r, "gemm result changed at {nt} threads"),
            }
            let secs = meas.median().as_secs_f64();
            let gflops = flops / secs / 1e9;
            if nt == 1 {
                base = secs;
            }
            json_line("gemm", "512x512x512", nt, secs, gflops);
            ts.row(&[
                "gemm".into(),
                nt.to_string(),
                cugwas::bench::dur_cell(meas.median()),
                format!("{gflops:.2}"),
                cugwas::bench::ratio_cell(base, secs),
            ]);
        }
    }

    // trsm 512 × 512
    {
        let (nn, nrhs) = (512usize, 512usize);
        let spd = Matrix::rand_spd(nn, 4.0, &mut rng);
        let l = potrf(&spd).unwrap();
        let b0 = Matrix::randn(nn, nrhs, &mut rng);
        let flops = nn as f64 * nn as f64 * nrhs as f64;
        let mut base = 0.0f64;
        let mut reference: Option<Matrix> = None;
        for &nt in &sweep {
            let mut b = b0.clone();
            let _g = threads::with_budget(nt);
            let meas = bench.measure(format!("trsm 512x512 @{nt}T"), || {
                b = b0.clone();
                trsm_lower_left(&l, &mut b).unwrap();
            });
            match &reference {
                None => reference = Some(b.clone()),
                Some(r) => assert_eq!(&b, r, "trsm result changed at {nt} threads"),
            }
            let secs = meas.median().as_secs_f64();
            let gflops = flops / secs / 1e9;
            if nt == 1 {
                base = secs;
            }
            json_line("trsm", "512x512", nt, secs, gflops);
            ts.row(&[
                "trsm".into(),
                nt.to_string(),
                cugwas::bench::dur_cell(meas.median()),
                format!("{gflops:.2}"),
                cugwas::bench::ratio_cell(base, secs),
            ]);
        }
    }
    ts.print();

    // ---- microkernel vs scalar reference (the tentpole metric) ----------
    // Forced-path runs on the same inputs: parity is asserted bit for
    // bit, the speedup and headline GFlop/s are emitted for the trend
    // gate, and each headline is also reported as a fraction of the
    // tuner's probed kernel rate (the "roofline" the DES prices with).
    // `main` is single-threaded here, so flipping the forced path is
    // race-free; it is restored to auto before exit.
    let probed = cugwas::tune::probe_kernels(1, false).expect("kernel probe");
    let peak = probed[&1];
    let mut tm = Table::new(
        "microkernel vs reference (1 thread)",
        &["kernel", "shape", "micro", "reference", "micro GFlop/s", "speedup"],
    );

    {
        let (m, k, n) = (512usize, 512usize, 512usize);
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        let _g = threads::with_budget(1);
        let mut run = |forced: bool, label: &str| {
            micro::set_forced(Some(forced));
            let mut c = Matrix::zeros(m, n);
            let meas = bench.measure(label, || {
                gemm(1.0, &a, &b, 0.0, &mut c).unwrap();
            });
            (c, meas.median())
        };
        let (c_micro, d_micro) = run(true, "gemm 512³ micro");
        let (c_ref, d_ref) = run(false, "gemm 512³ reference");
        micro::set_forced(None);
        assert_bits_eq(&c_micro, &c_ref, "gemm micro vs reference");
        let gflops = flops / d_micro.as_secs_f64() / 1e9;
        let speedup = d_ref.as_secs_f64() / d_micro.as_secs_f64();
        headline("gemm_gflops", gflops);
        headline("gemm_micro_speedup", speedup);
        headline("gemm_roofline_frac", gflops / peak.gemm_gflops.max(1e-12));
        tm.row(&[
            "gemm".into(),
            format!("{m}x{k}x{n}"),
            cugwas::bench::dur_cell(d_micro),
            cugwas::bench::dur_cell(d_ref),
            format!("{gflops:.2}"),
            format!("{speedup:.2}x"),
        ]);
    }

    {
        let (nn, nrhs) = (512usize, 256usize);
        let spd = Matrix::rand_spd(nn, 4.0, &mut rng);
        let l = potrf(&spd).unwrap();
        let b0 = Matrix::randn(nn, nrhs, &mut rng);
        let flops = (nn * nn * nrhs) as f64;
        let _g = threads::with_budget(1);
        let mut run = |forced: bool, label: &str| {
            micro::set_forced(Some(forced));
            let mut b = b0.clone();
            let meas = bench.measure(label, || {
                b = b0.clone();
                trsm_lower_left(&l, &mut b).unwrap();
            });
            (b, meas.median())
        };
        let (x_micro, d_micro) = run(true, "trsm 512x256 micro");
        let (x_ref, d_ref) = run(false, "trsm 512x256 reference");
        micro::set_forced(None);
        assert_bits_eq(&x_micro, &x_ref, "trsm micro vs reference");
        let gflops = flops / d_micro.as_secs_f64() / 1e9;
        let speedup = d_ref.as_secs_f64() / d_micro.as_secs_f64();
        headline("trsm_gflops", gflops);
        headline("trsm_micro_speedup", speedup);
        headline("trsm_roofline_frac", gflops / peak.trsm_gflops.max(1e-12));
        tm.row(&[
            "trsm".into(),
            format!("{nn}x{nrhs}"),
            cugwas::bench::dur_cell(d_micro),
            cugwas::bench::dur_cell(d_ref),
            format!("{gflops:.2}"),
            format!("{speedup:.2}x"),
        ]);
    }
    tm.print();
}
