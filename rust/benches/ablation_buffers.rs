//! Ablation — the §3.1 design choices:
//!
//! 1. **host buffers**: 2 vs 3 (vs 4) across disk speeds. The paper's
//!    double–triple claim: 2 host buffers stall once block reads are not
//!    ≪ trsm; the third buffer restores full overlap; a fourth buys
//!    nothing.
//! 2. **block size**: the streaming-granularity tradeoff (tiny blocks =
//!    per-iteration overhead; huge blocks = less overlap + more memory).
//! 3. **offload mode**: trsm-only (paper) vs fused reductions vs full
//!    offload, live.
//!
//! ```bash
//! cargo bench --bench ablation_buffers
//! ```

use cugwas::bench::Table;
use cugwas::coordinator::{run, OffloadMode, PipelineConfig};
use cugwas::devsim::{simulate, Algo, HardwareProfile, SimConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::{generate, Throttle};
use cugwas::util::human_duration;
use std::time::Duration;

fn main() {
    // ---- 1) host buffers × disk speed (sim, paper scale) -------------------
    let mut t = Table::new(
        "host-buffer ablation (sim, n=10k, m=100k; block read : trsm ratio varies with disk)",
        &["disk MB/s", "hb=2", "hb=3", "hb=4", "3-buf gain over 2"],
    );
    for disk in [2_000.0, 500.0, 253.0, 120.0] {
        let profile = HardwareProfile { disk_mbps: disk, ..HardwareProfile::quadro() };
        let mut secs = Vec::new();
        for hb in [2usize, 3, 4] {
            let cfg = SimConfig {
                dims: Dims::new(10_000, 3, 100_000).unwrap(),
                block: 5_000,
                ngpus: 1,
                host_buffers: hb,
                traits: 1,
                profile,
            };
            secs.push(simulate(Algo::CuGwas, &cfg).unwrap().total_secs);
        }
        t.row(&[
            format!("{disk:.0}"),
            human_duration(Duration::from_secs_f64(secs[0])),
            human_duration(Duration::from_secs_f64(secs[1])),
            human_duration(Duration::from_secs_f64(secs[2])),
            format!("{:.1}%", (secs[0] / secs[1] - 1.0) * 100.0),
        ]);
    }
    t.print();
    println!(
        "reading: the third buffer pays exactly where the paper says — when the\n\
         block read approaches the trsm time (≈253 MB/s row); on a fast cluster\n\
         FS two suffice, on a saturated HDD the disk is the wall either way."
    );

    // ---- 2) block size (live) ----------------------------------------------
    let fast = std::env::var("CUGWAS_BENCH_FAST").is_ok();
    let dir = std::env::temp_dir().join("cugwas_ablation_block");
    let _ = std::fs::remove_dir_all(&dir);
    let m = if fast { 2048 } else { 8192 };
    generate(&dir, Dims::new(256, 3, m).unwrap(), 256, 17).unwrap();
    let throttle = Some(Throttle { bytes_per_sec: 120e6 });
    let mut bt = Table::new(
        format!("block-size ablation (live, n=256, m={m}, 120 MB/s reads)"),
        &["block", "wall", "SNPs/s"],
    );
    for block in [32usize, 64, 128, 256, 512, 1024] {
        let mut cfg = PipelineConfig::new(&dir, block);
        cfg.read_throttle = throttle;
        let rep = run(&cfg).unwrap();
        bt.row(&[
            block.to_string(),
            human_duration(Duration::from_secs_f64(rep.wall_secs)),
            format!("{:.0}", rep.snps_per_sec),
        ]);
    }
    bt.print();

    // ---- 3) offload mode (live) ---------------------------------------------
    let mut mt = Table::new(
        format!("offload-mode ablation (live, n=256, m={m})"),
        &["mode", "wall", "coordinator sloop share"],
    );
    for mode in [OffloadMode::Trsm, OffloadMode::Block, OffloadMode::BlockFull] {
        let mut cfg = PipelineConfig::new(&dir, 256);
        cfg.mode = mode;
        let rep = run(&cfg).unwrap();
        let sloop = rep.metrics.total(cugwas::coordinator::Phase::Sloop).as_secs_f64();
        mt.row(&[
            mode.as_str().to_string(),
            human_duration(Duration::from_secs_f64(rep.wall_secs)),
            format!("{:.1}%", sloop / rep.wall_secs * 100.0),
        ]);
    }
    mt.print();
    println!(
        "reading: the paper keeps the S-loop on the CPU (mode=trsm) to overlap it\n\
         with the next block's trsm; fused/full offload shift that work to the\n\
         device lane — worthwhile only if the CPU, not the device, is the wall."
    );
    let _ = std::fs::remove_dir_all(&dir);
}
