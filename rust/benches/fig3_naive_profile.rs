//! Fig. 3 — the naive-offload profile ("GPU-offloading as an
//! after-thought"): GPU and CPU both wait on transfers, and on each
//! other. Reproduced twice:
//!
//! 1. **live** — the naive runner vs the pipeline on this machine with a
//!    throttled read stream (so transfer time is visible), phase table
//!    from the real metrics;
//! 2. **sim** — at paper scale with the paper's hardware constants,
//!    reporting per-resource utilization for both schedules.
//!
//! ```bash
//! cargo bench --bench fig3_naive_profile
//! ```

use cugwas::baselines::run_naive;
use cugwas::bench::Table;
use cugwas::coordinator::{run, BackendKind, PipelineConfig};
use cugwas::devsim::{simulate, Algo, HardwareProfile, SimConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::{generate, Throttle};
use cugwas::util::human_duration;
use std::time::Duration;

fn main() {
    // ---- live ----------------------------------------------------------
    let dir = std::env::temp_dir().join("cugwas_bench_fig3");
    let _ = std::fs::remove_dir_all(&dir);
    let dims = Dims::new(256, 3, 4096).unwrap();
    generate(&dir, dims, 256, 3).unwrap();
    let throttle = Some(Throttle { bytes_per_sec: 80e6 }); // visible I/O share

    let naive = run_naive(&dir, 256, &BackendKind::Native, throttle).unwrap();
    let mut cfg = PipelineConfig::new(&dir, 256);
    cfg.read_throttle = throttle;
    let cu = run(&cfg).unwrap();

    println!("== live (n=256, m=4096, read throttled to 80 MB/s) ==");
    println!(
        "naive offload: {}   cuGWAS: {}   speedup {:.2}x",
        human_duration(Duration::from_secs_f64(naive.wall_secs)),
        human_duration(Duration::from_secs_f64(cu.wall_secs)),
        naive.wall_secs / cu.wall_secs
    );
    println!("\nnaive phase profile (everything serialized — Fig. 3's pattern):");
    print!("{}", naive.metrics.table(Duration::from_secs_f64(naive.wall_secs)));
    println!("\ncuGWAS phase profile (waits collapse — the overlap at work):");
    print!("{}", cu.metrics.table(Duration::from_secs_f64(cu.wall_secs)));
    let _ = std::fs::remove_dir_all(&dir);

    // ---- sim at paper scale ---------------------------------------------
    let cfg = SimConfig {
        dims: Dims::new(10_000, 3, 100_000).unwrap(),
        block: 5_000,
        ngpus: 1,
        host_buffers: 3,
        traits: 1,
        profile: HardwareProfile::hdd(), // the title's HDD: transfers dominate
    };
    let naive = simulate(Algo::NaiveGpu, &cfg).unwrap();
    let cu = simulate(Algo::CuGwas, &cfg).unwrap();
    let mut t = Table::new(
        "sim — paper scale (n=10k, m=100k, HDD profile)",
        &["schedule", "total", "gpu util", "cpu util", "disk util"],
    );
    for r in [&naive, &cu] {
        t.row(&[
            r.algo.as_str().to_string(),
            human_duration(Duration::from_secs_f64(r.total_secs)),
            format!("{:.0}%", r.gpu_util * 100.0),
            format!("{:.0}%", r.cpu_util * 100.0),
            format!("{:.0}%", r.disk_util * 100.0),
        ]);
    }
    t.print();
    println!(
        "\nshape check: naive leaves the GPU {}% idle; the multibuffered schedule\n\
         recovers {:.2}x — the gap Fig. 3 visualizes.",
        ((1.0 - naive.gpu_util) * 100.0).round(),
        naive.total_secs / cu.total_secs
    );

    // The figure itself, as ASCII Gantt charts (first 4 blocks).
    let short = SimConfig { dims: Dims::new(10_000, 3, 20_000).unwrap(), ..cfg };
    let naive4 = simulate(Algo::NaiveGpu, &short).unwrap();
    let cu4 = simulate(Algo::CuGwas, &short).unwrap();
    println!("\nFig 3 (naive, 4 blocks — serialized gaps everywhere):");
    print!("{}", naive4.timeline.gantt(100));
    println!("\nmultibuffered (same 4 blocks — every resource dense):");
    print!("{}", cu4.timeline.gantt(100));
}
