//! Autotuner demonstration (fig6-style): on a dataset whose default
//! configuration is demonstrably starved, `tune` + `run --profile` must
//! match or beat the default's wall-clock — and must not regress the
//! balanced case.
//!
//! Two scenarios, both measured live on this machine:
//!
//! * **starved** — reads throttled to an HDD-class rate and a default
//!   config chosen the way a naive user would (tiny blocks, minimal
//!   double buffering): per-window overhead and the missing third host
//!   buffer put stalls on the critical path. The tuner probes *through
//!   the same throttle*, so its plan prices the slow device and picks
//!   bigger blocks / a deeper ring.
//! * **balanced** — unthrottled storage with the paper-default config;
//!   the tuned plan must stay within a few percent (the "never worse"
//!   guard).
//!
//! ```bash
//! cargo bench --bench autotune            # CUGWAS_BENCH_FAST=1 for CI
//! ```

use cugwas::bench::Table;
use cugwas::coordinator::{run, PipelineConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::{generate, Throttle};
use cugwas::tune::{plan, probe_dataset, PlanOpts, ProbeOpts, TunedProfile};
use cugwas::util::human_duration;
use std::time::Duration;

fn json_line(case: &str, config: &str, wall_secs: f64) {
    println!(
        "{{\"bench\":\"autotune\",\"case\":\"{case}\",\"config\":\"{config}\",\
         \"wall_secs\":{wall_secs:.6}}}"
    );
}

fn timed_run(cfg: &PipelineConfig) -> f64 {
    run(cfg).expect("pipeline run").wall_secs
}

fn apply(profile: &TunedProfile, cfg: &mut PipelineConfig) {
    cfg.block = profile.block;
    cfg.ngpus = profile.ngpus;
    cfg.host_buffers = profile.host_buffers;
    cfg.device_buffers = profile.device_buffers;
    cfg.threads = profile.threads;
    cfg.lane_threads = profile.lane_threads;
}

fn main() {
    let fast = std::env::var("CUGWAS_BENCH_FAST").is_ok();
    let m = if fast { 4096 } else { 16384 };
    let dims = Dims::new(256, 3, m).unwrap();
    let dir = std::env::temp_dir().join(format!("cugwas_autotune_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    generate(&dir, dims, 256, 17).unwrap();
    let mut t = Table::new(
        format!("autotune — tuned vs default (n=256, m={m})"),
        &["case", "default", "tuned", "speedup", "tuned block"],
    );

    // ---- starved: HDD-class reads, naive default config -----------------
    let throttle = Some(Throttle { bytes_per_sec: 12e6 });
    let mut naive = PipelineConfig::new(&dir, 64);
    naive.host_buffers = 2;
    naive.read_throttle = throttle;
    let t_naive = timed_run(&naive);
    json_line("starved", "default", t_naive);

    let rates = probe_dataset(
        &dir,
        &ProbeOpts {
            threads: 0,
            max_disk_bytes: 4 << 20,
            read_throttle: throttle,
            quick: fast,
        },
    )
    .expect("probe");
    let opts = PlanOpts {
        total_threads: cugwas::util::threads::available(),
        max_lanes: 1,
        host_mem_bytes: 0,
        max_block: 4096,
        traits: 1,
    };
    let profile = plan(&rates, dims, &opts);
    let mut tuned = PipelineConfig::new(&dir, profile.block);
    apply(&profile, &mut tuned);
    tuned.read_throttle = throttle;
    let t_tuned = timed_run(&tuned);
    json_line("starved", "tuned", t_tuned);
    t.row(&[
        "starved (12 MB/s reads)".into(),
        human_duration(Duration::from_secs_f64(t_naive)),
        human_duration(Duration::from_secs_f64(t_tuned)),
        format!("{:.2}x", t_naive / t_tuned.max(1e-12)),
        profile.block.to_string(),
    ]);

    // ---- balanced: paper defaults on fast storage — must not regress ----
    let base = PipelineConfig::new(&dir, 256);
    let t_base = timed_run(&base);
    json_line("balanced", "default", t_base);
    let rates = probe_dataset(
        &dir,
        &ProbeOpts { threads: 0, max_disk_bytes: 4 << 20, read_throttle: None, quick: fast },
    )
    .expect("probe");
    let profile = plan(&rates, dims, &opts);
    let mut tuned = PipelineConfig::new(&dir, profile.block);
    apply(&profile, &mut tuned);
    let t_tuned = timed_run(&tuned);
    json_line("balanced", "tuned", t_tuned);
    t.row(&[
        "balanced (no throttle)".into(),
        human_duration(Duration::from_secs_f64(t_base)),
        human_duration(Duration::from_secs_f64(t_tuned)),
        format!("{:.2}x", t_base / t_tuned.max(1e-12)),
        profile.block.to_string(),
    ]);

    t.print();
    println!(
        "\nnote: the tuner probed through the same throttle the starved runs use, so its\n\
         plan prices the slow device; `cugwas tune --read-mbps` does the same from the CLI."
    );
    let _ = std::fs::remove_dir_all(&dir);
}
