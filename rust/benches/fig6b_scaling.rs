//! Fig. 6b — multi-GPU scaling: runtime of cuGWAS with 1–4 GPUs on the
//! Tesla S2050 system, p=4, n=10 000, m=100 000. The paper's claim:
//! near-ideal scaling, ×1.9 per GPU doubling.
//!
//! Reproduced via the DES at the paper's exact configuration, plus a live
//! lane-fan-out run on this machine (which demonstrates coordinator
//! correctness under fan-out; CPU lanes share cores so live scaling is
//! not the claim — see DESIGN.md §4).
//!
//! ```bash
//! cargo bench --bench fig6b_scaling
//! ```

use cugwas::bench::{ratio_cell, Table};
use cugwas::coordinator::{run, verify_against_oracle, PipelineConfig};
use cugwas::devsim::{simulate, Algo, HardwareProfile, SimConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::generate;
use cugwas::util::human_duration;
use std::time::Duration;

fn main() {
    // ---- sim at the paper's exact Fig. 6b configuration -------------------
    let mut sim = Table::new(
        "Fig 6b sim — p=4, n=10 000, m=100 000, Tesla S2050 profile",
        &["gpus", "runtime", "speedup vs 1", "gpu util"],
    );
    let mut base = 0.0;
    let mut s2 = 0.0;
    let mut s4 = 0.0;
    for gpus in [1usize, 2, 3, 4] {
        let cfg = SimConfig {
            dims: Dims::new(10_000, 3, 100_000).unwrap(),
            block: 5_000 * gpus,
            ngpus: gpus,
            host_buffers: 3,
            traits: 1,
            profile: HardwareProfile::tesla(),
        };
        let rep = simulate(Algo::CuGwas, &cfg).unwrap();
        if gpus == 1 {
            base = rep.total_secs;
        }
        if gpus == 2 {
            s2 = base / rep.total_secs;
        }
        if gpus == 4 {
            s4 = base / rep.total_secs;
        }
        sim.row(&[
            gpus.to_string(),
            human_duration(Duration::from_secs_f64(rep.total_secs)),
            ratio_cell(base, rep.total_secs),
            format!("{:.0}%", rep.gpu_util * 100.0),
        ]);
    }
    sim.print();
    println!(
        "\nshape checks: 1→2 GPUs {s2:.2}x (paper 1.9x) {}; 1→4 GPUs {s4:.2}x (paper ~3.6x) {}",
        ok((1.7..2.05).contains(&s2)),
        ok((3.0..4.05).contains(&s4))
    );

    // ---- live fan-out (correctness + overlap on this machine) -----------
    let fast = std::env::var("CUGWAS_BENCH_FAST").is_ok();
    let dir = std::env::temp_dir().join("cugwas_fig6b_live");
    let _ = std::fs::remove_dir_all(&dir);
    let m = if fast { 2048 } else { 8192 };
    generate(&dir, Dims::new(256, 3, m).unwrap(), 256, 11).unwrap();
    let mut live = Table::new(
        format!("live lane fan-out (n=256, m={m})"),
        &["lanes", "wall", "SNPs/s", "verified"],
    );
    for lanes in [1usize, 2, 3, 4] {
        let mut cfg = PipelineConfig::new(&dir, 128 * lanes);
        cfg.ngpus = lanes;
        let rep = run(&cfg).unwrap();
        let v = verify_against_oracle(&dir, 1e-6).is_ok();
        live.row(&[
            lanes.to_string(),
            human_duration(Duration::from_secs_f64(rep.wall_secs)),
            format!("{:.0}", rep.snps_per_sec),
            if v { "yes".into() } else { "NO".into() },
        ]);
    }
    live.print();
    let _ = std::fs::remove_dir_all(&dir);
}

fn ok(b: bool) -> &'static str {
    if b { "[OK]" } else { "[MISMATCH]" }
}
