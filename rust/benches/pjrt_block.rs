//! Per-block device-lane cost through PJRT — the L1/L2 §Perf probe.
//!
//! Measures one artifact execution per (kind, shape) the way the lane
//! does it (literal creation + execute + fetch), plus the native-linalg
//! equivalent for reference. This is the number the L1 kernel
//! restructurings in EXPERIMENTS.md §Perf are judged by.
//!
//! ```bash
//! make artifacts && cargo bench --bench pjrt_block
//! ```

use cugwas::bench::{Bench, Table};
use cugwas::gwas::preprocess::preprocess;
use cugwas::gwas::problem::{Dims, Problem};
use cugwas::linalg::{trsm_lower_left, Matrix};
use cugwas::runtime::{
    default_artifacts_dir, dinv_to_rowmajor, matrix_to_rowmajor, ArtifactKey, Engine, HostTensor,
    Kind, Manifest,
};

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("no artifacts at {dir:?} — run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let bench = Bench::from_env();
    let mut t = Table::new(
        "per-block device cost (PJRT CPU) vs native linalg",
        &["kind", "n", "mb", "pjrt median", "native median", "pjrt/native"],
    );

    for &(n, mb) in &[(64usize, 32usize), (256, 128), (512, 256)] {
        let pl = 3;
        let prob = Problem::synthetic(Dims::new(n, pl, mb).unwrap(), 1).unwrap();
        for kind in [Kind::Trsm, Kind::Block, Kind::BlockFull] {
            let Ok(entry) = manifest.get(&ArtifactKey { kind, n, pl, mb }) else { continue };
            let pre = preprocess(&prob.m, &prob.xl, &prob.y, entry.nb).unwrap();
            let mut engine = Engine::cpu().unwrap();
            engine.load(entry).unwrap();
            let l_row = matrix_to_rowmajor(&pre.l);
            let dinv_row = dinv_to_rowmajor(pre.dinv.as_ref().unwrap(), entry.nb, n);
            let xlt_row = matrix_to_rowmajor(&pre.xl_t);
            let stl_row = matrix_to_rowmajor(&pre.stl);
            let xb: Vec<f64> = prob.xr.as_slice().to_vec();
            let nb = entry.nb;
            let meas = bench.measure(format!("{}-{n}", kind.as_str()), || {
                let tsr = |dims: Vec<i64>, data: Vec<f64>| HostTensor::new(dims, data).unwrap();
                let mut inputs = vec![
                    tsr(vec![n as i64, n as i64], l_row.clone()),
                    tsr(vec![n as i64, nb as i64], dinv_row.clone()),
                ];
                if kind != Kind::Trsm {
                    inputs.push(tsr(vec![n as i64, pl as i64], xlt_row.clone()));
                    inputs.push(tsr(vec![n as i64], pre.y_t.clone()));
                }
                if kind == Kind::BlockFull {
                    inputs.push(tsr(vec![pl as i64, pl as i64], stl_row.clone()));
                    inputs.push(tsr(vec![pl as i64], pre.rtop.clone()));
                }
                inputs.push(tsr(vec![mb as i64, n as i64], xb.clone()));
                let exe = engine.load(entry).unwrap();
                exe.run(&inputs).unwrap();
            });
            // Native reference: trsm only (the dominant cost).
            let native = bench.measure("native", || {
                let mut b = Matrix::from_vec(n, mb, xb.clone()).unwrap();
                trsm_lower_left(&pre.l, &mut b).unwrap();
            });
            t.row(&[
                kind.as_str().into(),
                n.to_string(),
                mb.to_string(),
                cugwas::bench::dur_cell(meas.median()),
                cugwas::bench::dur_cell(native.median()),
                format!("{:.2}", meas.median().as_secs_f64() / native.median().as_secs_f64()),
            ]);
        }
    }
    t.print();
}
