//! # cuGWAS-rs
//!
//! A reproduction of *"Streaming Data from HDD to GPUs for Sustained Peak
//! Performance"* (Beyer & Bientinesi, 2013): out-of-core generalized
//! least-squares solves for genome-wide association studies, streamed from
//! disk through a triple-buffered host ring into double-buffered
//! accelerator lanes, with the dependent S-loop pipelined one block behind.
//!
//! The crate is organized in three layers:
//!
//! * **Layer 3 (this crate)** — the streaming coordinator, storage engine,
//!   baselines and benchmark harness, in pure rust (std only + the `xla`
//!   PJRT bindings).
//! * **Layer 2 (build time)** — the JAX compute graphs in
//!   `python/compile/model.py`, AOT-lowered to HLO text under `artifacts/`.
//! * **Layer 1 (build time)** — the Pallas kernels (`trsm`, fused S-loop
//!   reduction) in `python/compile/kernels/`.
//!
//! Python never runs at request time: the rust binary loads the AOT HLO
//! through PJRT (`runtime`) and owns the entire hot path.
//!
//! ## Quick tour
//!
//! * [`linalg`] — from-scratch dense f64 BLAS/LAPACK subset.
//! * [`gwas`] — the GLS problem, native preprocessing and the in-core
//!   oracle (paper Listing 1.1).
//! * [`storage`] — the XRD on-disk block format, the async I/O engine,
//!   and the zero-copy slab plane ([`storage::slab`]): refcounted,
//!   aligned block buffers shared by the reader, the block cache and
//!   the device lanes.
//! * [`runtime`] — PJRT artifact loading and typed execution.
//! * [`devsim`] — discrete-event simulator with the paper's hardware
//!   constants (Quadro 6000 / Tesla S2050 clusters).
//! * [`coordinator`] — the paper's contribution: the multibuffered
//!   streaming pipeline (Listing 1.3), executed by the unified
//!   [`coordinator::engine::Engine`] — a long-lived core owning the aio
//!   engines, device lanes and buffer rings, reused across adaptive
//!   segments and across back-to-back runs.
//! * [`service`] — the multi-study scheduler behind `cugwas serve`: a
//!   priority job queue with memory-budget admission, worker lanes each
//!   holding a warm engine, tune-on-first-contact per dataset, and the
//!   shared [`storage::BlockCache`] that lets concurrent/repeated
//!   studies on one dataset skip the HDD.
//! * [`tune`] — the model-driven autotuner behind `cugwas tune`:
//!   probe the machine (disk bandwidth *and* per-request latency),
//!   search the knob space with the DES as the objective, emit a
//!   profile `run`/`serve` apply — and re-plan the full knob depth live
//!   at segment boundaries, transition costs included.
//! * [`telemetry`] — the observability plane: a Prometheus-style metric
//!   registry + `/metrics` endpoint, a bounded span ring exportable as
//!   Chrome trace JSON (the Fig. 3 timeline from a live run), and
//!   per-segment stall attribution ([`telemetry::StallVerdict`]). Off
//!   by default — disabled telemetry costs one atomic load per record
//!   point.
//! * [`baselines`] — naive offload (Fig. 3), OOC-HP-GWAS (Listing 1.2),
//!   and a ProbABEL-like per-SNP solver.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod devsim;
pub mod error;
pub mod gwas;
pub mod linalg;
pub mod proptest;
pub mod runtime;
pub mod service;
pub mod stats;
pub mod storage;
pub mod telemetry;
pub mod tune;
pub mod util;

pub use error::{Error, Result};
