//! `cugwas` — the Layer-3 coordinator CLI.
//!
//! ```text
//! cugwas gen-data  --dir data/s1 --n 512 --m 4096          # synthesize a study
//! cugwas tune      --dataset data/s1                       # probe + plan → tuned.toml
//! cugwas run       --dataset data/s1 --profile data/s1/tuned.toml --adapt
//! cugwas serve     --config service.toml                   # multi-study scheduler
//! cugwas baseline  --dataset data/s1 --algo ooc            # OOC-HP-GWAS / naive / probabel
//! cugwas sim       --algo cugwas --m 1000000 --ngpus 4     # paper-scale DES
//! cugwas catalog                                           # Fig. 1 data
//! cugwas artifacts                                         # list AOT artifacts
//! cugwas verify    --dataset data/s1                       # r.xrd vs in-core oracle
//! ```

use cugwas::baselines::{run_naive, run_ooc_cpu, run_probabel};
use cugwas::cli::{usage, Args, Flag};
use cugwas::coordinator::{self, BackendKind, OffloadMode, PipelineConfig};
use cugwas::devsim::{simulate, Algo, HardwareProfile, SimConfig};
use cugwas::error::{Error, Result};
use cugwas::gwas::problem::Dims;
use cugwas::runtime::Manifest;
use cugwas::stats::{summarize_by_year, synthesize_catalog};
use cugwas::storage::{self, Throttle};
use cugwas::util::{human_bytes, human_duration};
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::time::Duration;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        print_global_usage();
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "gen-data" => cmd_gen_data(rest),
        "inspect" => cmd_inspect(rest),
        "tune" => cmd_tune(rest),
        "run" => cmd_run(rest),
        "serve" => cmd_serve(rest),
        "baseline" => cmd_baseline(rest),
        "sim" => cmd_sim(rest),
        "assoc" => cmd_assoc(rest),
        "catalog" => cmd_catalog(rest),
        "artifacts" => cmd_artifacts(rest),
        "verify" => cmd_verify(rest),
        "help" | "--help" | "-h" => {
            print_global_usage();
            Ok(())
        }
        other => Err(Error::Config(format!("unknown subcommand '{other}' (try `cugwas help`)"))),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            cugwas::log_error!("cli", "{e}");
            ExitCode::FAILURE
        }
    }
}

/// Shared observability flags: honored by `run` and `serve`.
fn apply_telemetry_flags(a: &Args) {
    if a.switch("log-json") {
        cugwas::util::log::set_json(true);
    }
    if !a.str("trace-out").is_empty() {
        cugwas::telemetry::set_trace_enabled(true);
    }
}

/// After the command ran: write the Chrome trace if `--trace-out` named
/// a path, and a machine-readable report if `--report-json` did.
fn export_trace(a: &Args) -> Result<()> {
    let path = a.str("trace-out");
    if path.is_empty() {
        return Ok(());
    }
    let sink = cugwas::telemetry::global_trace();
    sink.export_chrome(Path::new(path))?;
    cugwas::log_info!(
        "cli",
        "wrote {} trace span(s) to {path} (Perfetto / chrome://tracing)",
        sink.len()
    );
    Ok(())
}

fn write_report_json(a: &Args, json: &str) -> Result<()> {
    let path = a.str("report-json");
    if path.is_empty() {
        return Ok(());
    }
    std::fs::write(path, json).map_err(|e| Error::io(format!("writing report {path}"), e))?;
    cugwas::log_info!("cli", "wrote machine-readable report to {path}");
    Ok(())
}

fn print_global_usage() {
    eprintln!(
        "cugwas — streaming GLS solves from disk through buffered accelerator lanes\n\
         (reproduction of Beyer & Bientinesi 2013)\n\n\
         subcommands:\n\
         \x20 gen-data    synthesize a study dataset on disk\n\
         \x20 inspect     describe a dataset directory\n\
         \x20 tune        probe the machine + plan pipeline knobs (autotuner)\n\
         \x20 run         stream a study through the cuGWAS pipeline\n\
         \x20 serve       multi-study scheduler with a shared block cache\n\
         \x20 baseline    run a comparison solver (ooc | naive | probabel)\n\
         \x20 assoc       association statistics (beta, se, z) per SNP\n\
         \x20 sim         discrete-event simulation at paper scale\n\
         \x20 catalog     Fig. 1 catalog statistics\n\
         \x20 artifacts   list available AOT artifacts\n\
         \x20 verify      compare r.xrd against the in-core oracle\n\n\
         `cugwas <subcommand> --help` shows per-command flags."
    );
}

fn wants_help(argv: &[String]) -> bool {
    argv.iter().any(|a| a == "--help" || a == "-h")
}

// ---------------------------------------------------------------- gen-data

const GEN_FLAGS: &[Flag] = &[
    Flag::req("dir", "output dataset directory"),
    Flag::opt("n", "512", "samples (individuals)"),
    Flag::opt("pl", "3", "fixed covariates (p = pl + 1)"),
    Flag::opt("m", "4096", "SNP count"),
    Flag::opt("block", "256", "file chunk size (columns)"),
    Flag::opt("seed", "42", "RNG seed"),
    Flag::opt("dtype", "f64", "X_R storage type: f64 | f32 (half the disk)"),
];

fn cmd_gen_data(argv: &[String]) -> Result<()> {
    if wants_help(argv) {
        print!("{}", usage("gen-data", "synthesize a study dataset", GEN_FLAGS));
        return Ok(());
    }
    let a = Args::parse(argv, GEN_FLAGS)?;
    let dims = Dims::new(a.usize("n")?, a.usize("pl")?, a.usize("m")?)?;
    let dir = PathBuf::from(a.str("dir"));
    let dtype = match a.str("dtype") {
        "f64" => storage::Dtype::F64,
        "f32" => storage::Dtype::F32,
        other => return Err(Error::Config(format!("unknown dtype '{other}'"))),
    };
    let meta =
        storage::generate_with_dtype(&dir, dims, a.usize("block")?, a.u64("seed")?, dtype)?;
    println!(
        "wrote dataset to {} (n={}, pl={}, m={}, X_R = {} as {})",
        dir.display(),
        meta.dims.n,
        meta.dims.pl,
        meta.dims.m,
        human_bytes(meta.dims.xr_bytes() / (8 / dtype.bytes())),
        dtype.as_str()
    );
    Ok(())
}

// ----------------------------------------------------------------- inspect

const INSPECT_FLAGS: &[Flag] = &[Flag::req("dataset", "dataset directory")];

fn cmd_inspect(argv: &[String]) -> Result<()> {
    if wants_help(argv) {
        print!("{}", usage("inspect", "describe a dataset directory", INSPECT_FLAGS));
        return Ok(());
    }
    let a = Args::parse(argv, INSPECT_FLAGS)?;
    let dir = PathBuf::from(a.str("dataset"));
    let (meta, kin, xl, y) = storage::load_sidecars(&dir)?;
    println!("dataset {}:", dir.display());
    println!("  n={} pl={} m={} (p={})", meta.dims.n, meta.dims.pl, meta.dims.m, meta.dims.p());
    println!("  seed={} file-chunk={}", meta.seed, meta.block);
    println!("  kinship: {}x{}, covariates: {}x{}, phenotype: {}",
        kin.rows(), kin.cols(), xl.rows(), xl.cols(), y.len());
    for (name, path) in [("xr", dir.join("xr.xrd")), ("r", dir.join("r.xrd"))] {
        match storage::XrdFile::open(&path) {
            Ok(f) => {
                let h = f.header();
                println!(
                    "  {name}.xrd: {}x{} {} blocks of {} ({} on disk, dtype {})",
                    h.rows,
                    h.cols,
                    h.block_count(),
                    h.block_cols,
                    human_bytes(h.file_bytes()),
                    h.dtype.as_str()
                );
            }
            Err(e) => println!("  {name}.xrd: unavailable ({e})"),
        }
    }
    Ok(())
}

// -------------------------------------------------------------------- tune

const TUNE_FLAGS: &[Flag] = &[
    Flag::req("dataset", "dataset directory to calibrate against"),
    Flag::opt("out", "", "profile output path (default: <dataset>/tuned.toml)"),
    Flag::opt("threads", "0", "total compute threads to plan for (0 = all cores)"),
    Flag::opt("max-lanes", "1", "largest device-lane count to consider"),
    Flag::opt("max-block", "0", "largest block size to consider (0 = 65536)"),
    Flag::opt("probe-mb", "64", "disk-probe read budget (MB)"),
    Flag::opt("read-mbps", "0", "probe through an emulated storage throttle (0 = off)"),
    Flag::opt("host-mem-mb", "0", "cap the rings' host memory (0 = no cap)"),
    Flag::opt("traits", "1", "phenotype batch width the plan should price in"),
    Flag::switch("quick", "smaller kernel probes (CI smoke)"),
];

fn cmd_tune(argv: &[String]) -> Result<()> {
    use cugwas::tune::{plan, probe_dataset, PlanOpts, ProbeOpts};
    if wants_help(argv) {
        print!("{}", usage("tune", "probe the machine, plan pipeline knobs", TUNE_FLAGS));
        return Ok(());
    }
    let a = Args::parse(argv, TUNE_FLAGS)?;
    let dataset = PathBuf::from(a.str("dataset"));
    let meta = storage::load_meta(&dataset)?;
    let popts = ProbeOpts {
        threads: a.usize("threads")?,
        max_disk_bytes: (a.u64("probe-mb")?.max(1)) << 20,
        read_throttle: parse_throttle(&a, "read-mbps")?,
        quick: a.switch("quick"),
    };
    let rates = probe_dataset(&dataset, &popts)?;
    let total = if popts.threads == 0 {
        cugwas::util::threads::available()
    } else {
        popts.threads
    };
    println!(
        "probe: disk {:.0} MB/s + {:.2} ms/request over {}, memcpy {:.1} GB/s, kernels at {} \
         thread counts{}",
        rates.disk_mbps,
        rates.disk_lat_secs * 1e3,
        human_bytes(rates.disk_bytes),
        rates.pcie_gbps,
        rates.kernels.len(),
        if rates.reliable { "" } else { " (dataset too small — probe unreliable)" }
    );
    for (t, k) in &rates.kernels {
        println!(
            "  {t:>3} threads: trsm {:.2} GF/s, gemm {:.2} GF/s",
            k.trsm_gflops, k.gemm_gflops
        );
    }
    let opts = PlanOpts {
        total_threads: total,
        max_lanes: a.usize("max-lanes")?.max(1),
        host_mem_bytes: a.u64("host-mem-mb")? << 20,
        max_block: a.usize("max-block")?,
        traits: a.usize("traits")?.max(1),
    };
    let profile = plan(&rates, meta.dims, &opts);
    let out = if a.str("out").is_empty() {
        cugwas::tune::TunedProfile::default_path(&dataset)
    } else {
        PathBuf::from(a.str("out"))
    };
    profile.save(&out)?;
    println!(
        "plan: block {} × {} lane(s), {} host / {} device buffers, lane threads {} \
         (of {} total)",
        profile.block,
        profile.ngpus,
        profile.host_buffers,
        profile.device_buffers,
        profile.lane_threads,
        profile.threads
    );
    match profile.predicted() {
        Some(secs) => println!(
            "      predicted {} for m={} — wrote {}",
            human_duration(Duration::from_secs_f64(secs)),
            meta.dims.m,
            out.display()
        ),
        None => println!("      probe was degenerate; wrote safe defaults to {}", out.display()),
    }
    println!("apply: cugwas run --dataset {} --profile {}", dataset.display(), out.display());
    Ok(())
}

// --------------------------------------------------------------------- run

const RUN_FLAGS: &[Flag] = &[
    Flag::req("dataset", "dataset directory"),
    Flag::opt("block", "256", "SNP columns per pipeline iteration"),
    Flag::opt("ngpus", "1", "device lanes"),
    Flag::opt("host-buffers", "3", "host ring size (paper: 3)"),
    Flag::opt("device-buffers", "2", "device buffers per lane (paper: 2)"),
    Flag::opt("threads", "0", "compute threads, split lanes/S-loop (0 = all cores)"),
    Flag::opt("lane-threads", "0", "kernel threads per lane (0 = auto split)"),
    Flag::opt("mode", "trsm", "offload mode: trsm | block | blockfull"),
    Flag::opt("backend", "native", "native | pjrt"),
    Flag::opt("artifacts", "artifacts", "AOT artifacts directory (pjrt)"),
    Flag::opt("read-mbps", "0", "throttle reads to emulate slower storage (0 = off)"),
    Flag::opt("write-mbps", "0", "throttle writes (0 = off)"),
    Flag::opt("profile", "", "tuned profile TOML (explicit flags still win)"),
    Flag::opt("adapt-every", "16", "blocks per adaptive segment"),
    Flag::opt("trace-out", "", "write a Chrome/Perfetto trace JSON here"),
    Flag::opt("report-json", "", "write the job report as JSON here"),
    Flag::opt("read-retries", "3", "extra read attempts on transient I/O errors"),
    Flag::opt("lane-watchdog-ms", "0", "declare a stalled device lane wedged after this (0 = off)"),
    Flag::opt("traits", "1", "phenotype batch width: solve this many traits in one pass"),
    Flag::opt("permutations", "0", "permutation-test mode: batch K shuffled phenotypes with the real one"),
    Flag::opt("perm-seed", "0", "RNG seed for the permutation shuffles (reproducible)"),
    Flag::switch("integrity", "checksum blocks at read time, verify on cache hit and submit"),
    Flag::switch("adapt", "re-plan block size live from the stall profile (native)"),
    Flag::switch("resume", "skip column ranges journaled in r.progress (crash recovery)"),
    Flag::switch("verify", "check r.xrd against the in-core oracle (small studies)"),
    Flag::switch("log-json", "emit log lines as JSON objects (one per line)"),
];

fn parse_mode(s: &str) -> Result<OffloadMode> {
    match s {
        "trsm" => Ok(OffloadMode::Trsm),
        "block" => Ok(OffloadMode::Block),
        "blockfull" => Ok(OffloadMode::BlockFull),
        other => Err(Error::Config(format!("unknown mode '{other}'"))),
    }
}

fn parse_backend(a: &Args) -> Result<BackendKind> {
    match a.str("backend") {
        "native" => Ok(BackendKind::Native),
        "pjrt" => Ok(BackendKind::Pjrt { artifacts: PathBuf::from(a.str("artifacts")) }),
        other => Err(Error::Config(format!("unknown backend '{other}'"))),
    }
}

fn parse_throttle(a: &Args, flag: &str) -> Result<Option<Throttle>> {
    let mbps = a.f64(flag)?;
    Ok(if mbps > 0.0 { Some(Throttle { bytes_per_sec: mbps * 1e6 }) } else { None })
}

fn cmd_run(argv: &[String]) -> Result<()> {
    if wants_help(argv) {
        print!("{}", usage("run", "stream a study through the cuGWAS pipeline", RUN_FLAGS));
        return Ok(());
    }
    let a = Args::parse(argv, RUN_FLAGS)?;
    apply_telemetry_flags(&a);
    // Fault-tolerance policy: retried reads, the lane watchdog, and
    // optional block integrity checking (`serve` reads the same knobs
    // from the `[fault_tolerance]` config section instead).
    cugwas::storage::fault::set_policy(cugwas::storage::fault::RetryPolicy {
        read_retries: a.usize("read-retries")? as u32,
        lane_watchdog_ms: a.usize("lane-watchdog-ms")? as u64,
        ..Default::default()
    });
    cugwas::storage::fault::set_integrity_enabled(a.switch("integrity"));
    // Permutation mode is sugar for a trait batch: the observed phenotype
    // rides in column 0 and K seeded shuffles fill the rest, so one
    // streaming pass prices the whole null distribution. When both flags
    // are given they must agree, so a typo cannot silently change K.
    let mut traits = a.usize("traits")?.max(1);
    let permutations = a.usize("permutations")?;
    let perm_seed = a.u64("perm-seed")?;
    if permutations > 0 {
        if a.given("traits") && traits != permutations + 1 {
            return Err(Error::Config(format!(
                "--traits {traits} conflicts with --permutations {permutations} \
                 (permutation mode implies traits = permutations + 1)"
            )));
        }
        traits = permutations + 1;
    }
    let mut cfg = PipelineConfig {
        dataset: PathBuf::from(a.str("dataset")),
        block: a.usize("block")?,
        ngpus: a.usize("ngpus")?,
        host_buffers: a.usize("host-buffers")?,
        device_buffers: a.usize("device-buffers")?,
        mode: parse_mode(a.str("mode"))?,
        backend: parse_backend(&a)?,
        read_throttle: parse_throttle(&a, "read-mbps")?,
        write_throttle: parse_throttle(&a, "write-mbps")?,
        resume: a.switch("resume"),
        cache: None,
        threads: a.usize("threads")?,
        lane_threads: a.usize("lane-threads")?,
        adapt: a.switch("adapt"),
        adapt_every: a.usize("adapt-every")?,
        traits,
        perm_seed,
        shutdown: None,
        deadline_at: None,
        disk_low_water: 0,
    };
    // A tuned profile supplies defaults; flags the user typed still win.
    // Loading shares one error path with the `[pipeline]`/`[job.*]`
    // `profile` keys and the service's first-contact tuner.
    if !a.str("profile").is_empty() {
        let prof =
            cugwas::tune::profile::load_or_default(Some(Path::new(a.str("profile"))), 0, 0)?;
        if !a.given("block") {
            cfg.block = prof.block;
        }
        if !a.given("ngpus") {
            cfg.ngpus = prof.ngpus;
        }
        if !a.given("host-buffers") {
            cfg.host_buffers = prof.host_buffers;
        }
        if !a.given("device-buffers") {
            cfg.device_buffers = prof.device_buffers;
        }
        if !a.given("threads") {
            cfg.threads = prof.threads;
        }
        if !a.given("lane-threads") {
            cfg.lane_threads = prof.lane_threads;
        }
    }
    let report = coordinator::run(&cfg)?;
    println!(
        "cuGWAS: {} SNPs in {} blocks — {} ({:.0} SNPs/s, device busy {}{})",
        report.snps,
        report.blocks,
        human_duration(Duration::from_secs_f64(report.wall_secs)),
        report.snps_per_sec,
        human_duration(Duration::from_secs_f64(report.device_secs)),
        if report.replans > 0 {
            format!(", {} adaptive switch(es)", report.replans)
        } else {
            String::new()
        },
    );
    print!("{}", report.metrics.table(Duration::from_secs_f64(report.wall_secs)));
    println!("stall: {}", report.stall.render());
    export_trace(&a)?;
    if !a.str("report-json").is_empty() {
        let j = cugwas::service::JobReport::done(
            "run",
            cfg.dataset.clone(),
            0,
            report.wall_secs,
            report.snps,
            report.blocks,
            report.metrics.clone(),
        );
        write_report_json(&a, &j.to_json())?;
    }
    if a.switch("verify") {
        let diff = coordinator::verify_against_oracle_multi(
            Path::new(a.str("dataset")),
            1e-7,
            cfg.traits,
            cfg.perm_seed,
        )?;
        println!("verified against in-core oracle: max |Δ| = {diff:.2e}");
    }
    Ok(())
}

// ------------------------------------------------------------------- serve

const SERVE_FLAGS: &[Flag] = &[
    Flag::req("config", "service TOML ([service] + [job.*] sections)"),
    Flag::opt("spool", "", "spool directory of job TOMLs (overrides config)"),
    Flag::opt("threads", "0", "compute threads across workers (0 = config, then all cores)"),
    Flag::opt("metrics-addr", "", "serve Prometheus /metrics + /healthz here (overrides config)"),
    Flag::opt("wal", "", "service lifecycle WAL path (overrides config; default <spool>/service.wal)"),
    Flag::opt("drain-timeout", "0", "graceful-drain checkpoint budget in seconds (0 = config)"),
    Flag::opt("trace-out", "", "write a Chrome/Perfetto trace JSON here"),
    Flag::opt("report-json", "", "write the service report as JSON here"),
    Flag::switch("watch", "keep polling the spool after the queue drains"),
    Flag::switch("log-json", "emit log lines as JSON objects (one per line)"),
];

fn cmd_serve(argv: &[String]) -> Result<()> {
    if wants_help(argv) {
        let about = "run queued studies through the multi-study scheduler";
        print!("{}", usage("serve", about, SERVE_FLAGS));
        return Ok(());
    }
    let a = Args::parse(argv, SERVE_FLAGS)?;
    apply_telemetry_flags(&a);
    let mut cfg = cugwas::config::ServiceConfig::load(Path::new(a.str("config")))?;
    if !a.str("spool").is_empty() {
        cfg.spool = Some(PathBuf::from(a.str("spool")));
    }
    if a.switch("watch") {
        cfg.watch = true;
    }
    let threads = a.usize("threads")?;
    if threads > 0 {
        cfg.threads = threads;
    }
    if !a.str("metrics-addr").is_empty() {
        cfg.metrics_addr = Some(a.str("metrics-addr").to_string());
    }
    if !a.str("wal").is_empty() {
        cfg.wal = Some(PathBuf::from(a.str("wal")));
    }
    let drain_timeout = a.usize("drain-timeout")?;
    if drain_timeout > 0 {
        cfg.drain_timeout_secs = drain_timeout as u64;
    }
    // Ctrl-C becomes a graceful drain: admission stops, in-flight jobs
    // checkpoint at their next segment boundary, the WAL is sealed, and
    // the report still prints. A second Ctrl-C during the drain is
    // absorbed by the same latch; the drain timeout bounds the wait.
    cugwas::service::install_drain_on_ctrl_c();
    // Install the `[fault_tolerance]` section process-wide: retry
    // policy, integrity checking, and (chaos testing only) the armed
    // fault injector.
    cfg.fault.install();
    // The endpoint outlives serve(): scrapes during AND after the run
    // (final gauge/counter state) both work; Drop stops the listener.
    let _metrics_server = match &cfg.metrics_addr {
        Some(addr) => {
            cugwas::telemetry::set_metrics_enabled(true);
            let srv = cugwas::telemetry::MetricsServer::start(addr)?;
            cugwas::log_info!("cli", "serving /metrics and /healthz on http://{}/", srv.addr());
            Some(srv)
        }
        None => None,
    };
    let report = cugwas::service::serve(&cfg)?;
    print!("{}", report.render());
    export_trace(&a)?;
    write_report_json(&a, &report.to_json())?;
    if report.failed() > 0 {
        return Err(Error::Pipeline(format!("{} job(s) failed", report.failed())));
    }
    Ok(())
}

// ---------------------------------------------------------------- baseline

const BASE_FLAGS: &[Flag] = &[
    Flag::req("dataset", "dataset directory"),
    Flag::opt("algo", "ooc", "ooc | naive | probabel"),
    Flag::opt("block", "256", "block size (ooc / naive)"),
    Flag::opt("backend", "native", "naive backend: native | pjrt"),
    Flag::opt("artifacts", "artifacts", "AOT artifacts directory"),
    Flag::opt("read-mbps", "0", "read throttle (0 = off)"),
    Flag::switch("verify", "check results against the in-core oracle"),
];

fn cmd_baseline(argv: &[String]) -> Result<()> {
    if wants_help(argv) {
        print!("{}", usage("baseline", "run a comparison solver", BASE_FLAGS));
        return Ok(());
    }
    let a = Args::parse(argv, BASE_FLAGS)?;
    let dataset = PathBuf::from(a.str("dataset"));
    let throttle = parse_throttle(&a, "read-mbps")?;
    let (name, wall, snps_per_sec) = match a.str("algo") {
        "ooc" => {
            let r = run_ooc_cpu(&dataset, a.usize("block")?, throttle)?;
            ("OOC-HP-GWAS (CPU)", r.wall_secs, r.snps_per_sec)
        }
        "naive" => {
            let r = run_naive(&dataset, a.usize("block")?, &parse_backend(&a)?, throttle)?;
            ("naive offload", r.wall_secs, r.snps_per_sec)
        }
        "probabel" => {
            let r = run_probabel(&dataset)?;
            ("ProbABEL-like per-SNP", r.wall_secs, r.snps_per_sec)
        }
        other => return Err(Error::Config(format!("unknown algo '{other}'"))),
    };
    println!(
        "{name}: {} ({snps_per_sec:.0} SNPs/s)",
        human_duration(Duration::from_secs_f64(wall))
    );
    if a.switch("verify") {
        let diff = coordinator::verify_against_oracle(&dataset, 1e-6)?;
        println!("verified against in-core oracle: max |Δ| = {diff:.2e}");
    }
    Ok(())
}

// ------------------------------------------------------------------- assoc

const ASSOC_FLAGS: &[Flag] = &[
    Flag::req("dataset", "dataset directory"),
    Flag::opt("block", "256", "SNP columns per streaming step"),
    Flag::opt("top", "10", "print the K most significant SNPs"),
];

/// Stream the study once, computing per-SNP association statistics
/// (beta, se, z) alongside the estimates; writes `stats.xrd` (3×m) and
/// prints the top-K SNPs by |z| — the end product a study reports.
fn cmd_assoc(argv: &[String]) -> Result<()> {
    use cugwas::gwas::assoc::STAT_ROWS;
    use cugwas::gwas::{preprocess, sloop_block_stats, SloopScratch};
    use cugwas::linalg::{trsm_lower_left, Matrix};
    use cugwas::storage::{dataset::DatasetPaths, Header, XrdFile};

    if wants_help(argv) {
        print!("{}", usage("assoc", "per-SNP association statistics", ASSOC_FLAGS));
        return Ok(());
    }
    let a = Args::parse(argv, ASSOC_FLAGS)?;
    let dir = PathBuf::from(a.str("dataset"));
    let block = a.usize("block")?;
    let (meta, kin, xl, y) = storage::load_sidecars(&dir)?;
    let dims = meta.dims;
    let pre = preprocess(&kin, &xl, &y, 0)?;
    let paths = DatasetPaths::new(&dir);
    let xr = XrdFile::open(&paths.xr())?;
    let stats_path = dir.join("stats.xrd");
    let sh = Header::new(STAT_ROWS as u64, dims.m as u64, block.min(dims.m) as u64, meta.seed)?;
    let sfile = XrdFile::create(&stats_path, sh)?;

    let mut scratch = SloopScratch::new(dims.pl);
    let mut top: Vec<(f64, usize, f64, f64)> = Vec::new(); // (|z|, snp, beta, se)
    let k = a.usize("top")?;
    let mut c0 = 0usize;
    while c0 < dims.m {
        let live = block.min(dims.m - c0);
        let mut buf = vec![0.0; dims.n * live];
        xr.read_cols_into(c0 as u64, live as u64, &mut buf)?;
        let mut xb = Matrix::from_vec(dims.n, live, buf)?;
        trsm_lower_left(&pre.l, &mut xb)?;
        let mut r = Matrix::zeros(dims.p(), live);
        let mut st = Matrix::zeros(STAT_ROWS, live);
        sloop_block_stats(&pre, &xb, &mut scratch, &mut r, Some(&mut st))?;
        sfile.write_cols(c0 as u64, live as u64, st.as_slice())?;
        for j in 0..live {
            top.push((st.get(2, j).abs(), c0 + j, st.get(0, j), st.get(1, j)));
        }
        top.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap_or(std::cmp::Ordering::Equal));
        top.truncate(k.max(1));
        c0 += live;
    }
    sfile.sync()?;
    println!("wrote per-SNP statistics to {} (3×{})", stats_path.display(), dims.m);
    println!("{:>8}{:>12}{:>12}{:>10}", "snp", "beta", "se", "|z|");
    for (absz, snp, beta, se) in &top {
        println!("{snp:>8}{beta:>12.4}{se:>12.4}{absz:>10.2}");
    }
    Ok(())
}

// --------------------------------------------------------------------- sim

const SIM_FLAGS: &[Flag] = &[
    Flag::opt("algo", "cugwas", "cugwas | ooc | naive | probabel"),
    Flag::opt("profile", "quadro", "hardware profile: quadro | tesla | hdd"),
    Flag::opt("n", "10000", "samples"),
    Flag::opt("pl", "3", "fixed covariates"),
    Flag::opt("m", "1000000", "SNP count"),
    Flag::opt("block", "5000", "SNP columns per iteration"),
    Flag::opt("ngpus", "1", "GPUs"),
    Flag::opt("host-buffers", "3", "host buffers"),
    Flag::opt("traits", "1", "phenotype batch width (multi-trait / permutation batching)"),
    Flag::opt("timeline", "", "write the task timeline as CSV to this path"),
];

fn parse_profile(s: &str) -> Result<HardwareProfile> {
    match s {
        "quadro" => Ok(HardwareProfile::quadro()),
        "tesla" => Ok(HardwareProfile::tesla()),
        "hdd" => Ok(HardwareProfile::hdd()),
        other => Err(Error::Config(format!("unknown profile '{other}'"))),
    }
}

fn cmd_sim(argv: &[String]) -> Result<()> {
    if wants_help(argv) {
        print!("{}", usage("sim", "paper-scale discrete-event simulation", SIM_FLAGS));
        return Ok(());
    }
    let a = Args::parse(argv, SIM_FLAGS)?;
    let algo = match a.str("algo") {
        "cugwas" => Algo::CuGwas,
        "ooc" => Algo::OocCpu,
        "naive" => Algo::NaiveGpu,
        "probabel" => Algo::Probabel,
        other => return Err(Error::Config(format!("unknown algo '{other}'"))),
    };
    let cfg = SimConfig {
        dims: Dims::new(a.usize("n")?, a.usize("pl")?, a.usize("m")?)?,
        block: a.usize("block")?,
        ngpus: a.usize("ngpus")?,
        host_buffers: a.usize("host-buffers")?,
        profile: parse_profile(a.str("profile"))?,
        traits: a.usize("traits")?.max(1),
    };
    let rep = simulate(algo, &cfg)?;
    println!(
        "{} on '{}': {} for m={} (n={}, block={}, {} GPUs)",
        rep.algo.as_str(),
        cfg.profile.name,
        human_duration(Duration::from_secs_f64(rep.total_secs)),
        cfg.dims.m,
        cfg.dims.n,
        cfg.block,
        cfg.ngpus
    );
    println!(
        "  throughput {:.0} SNPs/s | util: gpu {:.0}% cpu {:.0}% pcie {:.0}% disk {:.0}%",
        rep.snps_per_sec,
        rep.gpu_util * 100.0,
        rep.cpu_util * 100.0,
        rep.pcie_util * 100.0,
        rep.disk_util * 100.0
    );
    for (phase, busy) in &rep.phase_busy {
        println!("  {phase:<8} {}", human_duration(Duration::from_secs_f64(*busy)));
    }
    let timeline_path = a.str("timeline");
    if !timeline_path.is_empty() {
        let mut csv = String::from("label,resource,start,finish\n");
        for iv in &rep.timeline.intervals {
            csv.push_str(&format!("{},{},{:.6},{:.6}\n", iv.label, iv.resource, iv.start, iv.finish));
        }
        std::fs::write(timeline_path, csv)
            .map_err(|e| Error::io(format!("writing {timeline_path}"), e))?;
        println!("wrote timeline CSV to {timeline_path} ({} tasks)", rep.timeline.intervals.len());
    }
    Ok(())
}

// ----------------------------------------------------------------- catalog

const CATALOG_FLAGS: &[Flag] = &[Flag::opt("seed", "2013", "catalog RNG seed")];

fn cmd_catalog(argv: &[String]) -> Result<()> {
    if wants_help(argv) {
        print!("{}", usage("catalog", "Fig. 1 GWAS-catalog statistics", CATALOG_FLAGS));
        return Ok(());
    }
    let a = Args::parse(argv, CATALOG_FLAGS)?;
    let rows = synthesize_catalog(a.u64("seed")?);
    println!(
        "{:<6}{:>9}{:>14}{:>14}{:>14}{:>12}{:>12}{:>12}",
        "year", "studies", "snps_q1", "snps_med", "snps_q3", "n_q1", "n_med", "n_q3"
    );
    for s in summarize_by_year(&rows) {
        println!(
            "{:<6}{:>9}{:>14.0}{:>14.0}{:>14.0}{:>12.0}{:>12.0}{:>12.0}",
            s.year,
            s.studies,
            s.snp_count.q1,
            s.snp_count.median,
            s.snp_count.q3,
            s.sample_size.q1,
            s.sample_size.median,
            s.sample_size.q3
        );
    }
    Ok(())
}

// --------------------------------------------------------------- artifacts

const ART_FLAGS: &[Flag] = &[Flag::opt("artifacts", "artifacts", "artifacts directory")];

fn cmd_artifacts(argv: &[String]) -> Result<()> {
    if wants_help(argv) {
        print!("{}", usage("artifacts", "list available AOT artifacts", ART_FLAGS));
        return Ok(());
    }
    let a = Args::parse(argv, ART_FLAGS)?;
    let dir = PathBuf::from(a.str("artifacts"));
    let manifest = Manifest::load(&dir)?;
    println!("{:<12}{:>8}{:>6}{:>8}{:>6}{:>6}  file", "kind", "n", "pl", "mb", "nb", "bm");
    for kind in [
        cugwas::runtime::Kind::Preprocess,
        cugwas::runtime::Kind::Trsm,
        cugwas::runtime::Kind::Block,
        cugwas::runtime::Kind::BlockFull,
    ] {
        for e in manifest.of_kind(kind) {
            println!(
                "{:<12}{:>8}{:>6}{:>8}{:>6}{:>6}  {}",
                e.key.kind.as_str(),
                e.key.n,
                e.key.pl,
                e.key.mb,
                e.nb,
                e.bm,
                e.path.file_name().and_then(|s| s.to_str()).unwrap_or("?")
            );
        }
    }
    Ok(())
}

// ------------------------------------------------------------------ verify

const VERIFY_FLAGS: &[Flag] = &[
    Flag::req("dataset", "dataset directory (with r.xrd present)"),
    Flag::opt("tol", "1e-7", "max |Δ| tolerance"),
];

fn cmd_verify(argv: &[String]) -> Result<()> {
    if wants_help(argv) {
        print!("{}", usage("verify", "compare r.xrd against the in-core oracle", VERIFY_FLAGS));
        return Ok(());
    }
    let a = Args::parse(argv, VERIFY_FLAGS)?;
    let diff = coordinator::verify_against_oracle(Path::new(a.str("dataset")), a.f64("tol")?)?;
    println!("OK: max |Δ| = {diff:.2e}");
    Ok(())
}
