//! The S-loop (paper Listing 1.2 lines 11–15): per-SNP assembly and solve
//! of the small `(p×p)` system, given the block solution `X̃_b = L^-1 X_b`.
//!
//! This is the CPU half of the paper's pipeline — it runs on block `b-1`
//! while the accelerator solves the trsm of block `b`. Two entry points:
//!
//! * [`sloop_block`] — the pure-native version: computes the block
//!   reductions itself (`G = X̃_L^T X̃_b` via gemm, `d_j = ‖x̃_j‖²`,
//!   `rb = X̃_b^T ỹ`) then assembles + solves per SNP.
//! * [`sloop_from_reductions`] — the offload-ablation version: the
//!   reductions were already produced by the L1 `sloop` kernel on the
//!   device; only the tiny per-SNP `posv`s remain.
//!
//! Both are allocation-free in the per-SNP loop ([`SloopScratch`]).

use crate::error::{Error, Result};
use crate::gwas::assoc::{inv_pp_from_factor, sigma2, stat_column, STAT_ROWS};
use crate::gwas::preprocess::Preprocessed;
use crate::linalg::{chol::posv_small, dot, gemm, sumsq, Matrix};

/// Reusable scratch for the per-SNP loop: the assembled `p×p` system and
/// its right-hand side.
#[derive(Debug, Clone)]
pub struct SloopScratch {
    p: usize,
    s: Vec<f64>,
    rhs: Vec<f64>,
}

impl SloopScratch {
    pub fn new(pl: usize) -> Self {
        let p = pl + 1;
        SloopScratch { p, s: vec![0.0; p * p], rhs: vec![0.0; p] }
    }
}

/// Native S-loop over a solved block `xb_t = X̃_b` (n × mb). Appends one
/// `p`-vector `r_i` per SNP column into `out` (column-major `p × mb`).
pub fn sloop_block(pre: &Preprocessed, xb_t: &Matrix, scratch: &mut SloopScratch, out: &mut Matrix) -> Result<()> {
    sloop_block_stats(pre, xb_t, scratch, out, None)
}

/// [`sloop_block`] plus optional association statistics: when `stats` is
/// given (a `3 × mb` matrix), each column receives `[beta_snp, se, z]`
/// (see [`crate::gwas::assoc`]).
pub fn sloop_block_stats(
    pre: &Preprocessed,
    xb_t: &Matrix,
    scratch: &mut SloopScratch,
    out: &mut Matrix,
    stats: Option<&mut Matrix>,
) -> Result<()> {
    let pl = pre.xl_t.cols();
    let mb = xb_t.cols();
    check_out(out, pl, mb)?;
    if xb_t.rows() != pre.xl_t.rows() {
        return Err(Error::shape(format!(
            "sloop_block: X̃_b has {} rows, X̃_L has {}",
            xb_t.rows(),
            pre.xl_t.rows()
        )));
    }
    // Block reductions (BLAS-3/1): G = X̃_L^T X̃_b  (pl × mb),
    // d_j = ‖x̃_j‖², rb_j = x̃_j · ỹ.
    let mut g = Matrix::zeros(pl, mb);
    gemm(1.0, &pre.xl_t.transpose(), xb_t, 0.0, &mut g)?;
    let mut d = vec![0.0; mb];
    let mut rb = vec![0.0; mb];
    for j in 0..mb {
        let col = xb_t.col(j);
        d[j] = sumsq(col);
        rb[j] = dot(col, &pre.y_t);
    }
    solve_columns(pre, &g, &d, &rb, scratch, out, stats)
}

/// S-loop tail when the reductions `(G, d, rb)` come from the device
/// (the fused L1 kernel): only assembly + the per-SNP `posv` runs here.
pub fn sloop_from_reductions(
    pre: &Preprocessed,
    g: &Matrix,
    d: &[f64],
    rb: &[f64],
    scratch: &mut SloopScratch,
    out: &mut Matrix,
) -> Result<()> {
    let pl = pre.xl_t.cols();
    let mb = d.len();
    check_out(out, pl, mb)?;
    if g.rows() != pl || g.cols() != mb || rb.len() != mb {
        return Err(Error::shape(format!(
            "sloop_from_reductions: G {}x{}, d {}, rb {}",
            g.rows(),
            g.cols(),
            mb,
            rb.len()
        )));
    }
    solve_columns(pre, g, d, rb, scratch, out, None)
}

/// Shared per-SNP assembly + solve:
///
/// ```text
/// S_i = | S_TL      g_i |      rhs_i = | r̃_T  |
///       | g_i^T     d_i |              | rb_i |
/// r_i = S_i^-1 rhs_i
/// ```
fn solve_columns(
    pre: &Preprocessed,
    g: &Matrix,
    d: &[f64],
    rb: &[f64],
    scratch: &mut SloopScratch,
    out: &mut Matrix,
    mut stats: Option<&mut Matrix>,
) -> Result<()> {
    let pl = pre.stl.rows();
    let p = pl + 1;
    let n = pre.y_t.len();
    debug_assert_eq!(scratch.p, p, "scratch built for wrong p");
    if let Some(st) = stats.as_deref() {
        if st.rows() != STAT_ROWS || st.cols() != d.len() {
            return Err(Error::shape(format!(
                "stats must be {STAT_ROWS}x{}, got {}x{}",
                d.len(),
                st.rows(),
                st.cols()
            )));
        }
    }
    let mut rhs_orig = vec![0.0; p];
    for j in 0..d.len() {
        let s = &mut scratch.s;
        // Top-left block: S_TL (symmetric).
        for c in 0..pl {
            for r in 0..pl {
                s[c * p + r] = pre.stl.get(r, c);
            }
        }
        // Border: g_j and d_j.
        for r in 0..pl {
            let v = g.get(r, j);
            s[pl * p + r] = v; // last column
            s[r * p + pl] = v; // last row
        }
        s[pl * p + pl] = d[j];
        // RHS.
        scratch.rhs[..pl].copy_from_slice(&pre.rtop);
        scratch.rhs[pl] = rb[j];
        rhs_orig.copy_from_slice(&scratch.rhs);
        posv_small(s, &mut scratch.rhs, p)
            .map_err(|e| Error::Numerical(format!("S-loop posv failed at column {j}: {e}")))?;
        out.col_mut(j).copy_from_slice(&scratch.rhs);
        if let Some(st) = stats.as_deref_mut() {
            // `s` now holds the Cholesky factor of S_j (posv_small is
            // in-place), so the extra statistics are nearly free.
            let var_pp = inv_pp_from_factor(s, p);
            let s2 = sigma2(pre.yty, &scratch.rhs, &rhs_orig, n, p)?;
            let col = stat_column(scratch.rhs[pl], var_pp, s2);
            st.col_mut(j).copy_from_slice(&col);
        }
    }
    Ok(())
}

fn check_out(out: &Matrix, pl: usize, mb: usize) -> Result<()> {
    if out.rows() != pl + 1 || out.cols() != mb {
        return Err(Error::shape(format!(
            "sloop out must be {}x{mb}, got {}x{}",
            pl + 1,
            out.rows(),
            out.cols()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::preprocess::preprocess;
    use crate::gwas::problem::{Dims, Problem};
    use crate::linalg::trsm_lower_left;

    fn setup(n: usize, pl: usize, m: usize, seed: u64) -> (Problem, Preprocessed, Matrix) {
        let prob = Problem::synthetic(Dims::new(n, pl, m).unwrap(), seed).unwrap();
        let pre = preprocess(&prob.m, &prob.xl, &prob.y, 0).unwrap();
        let mut xb_t = prob.xr.clone();
        trsm_lower_left(&pre.l, &mut xb_t).unwrap();
        (prob, pre, xb_t)
    }

    #[test]
    fn sloop_matches_direct_gls() {
        // Compare each r_i against a direct dense GLS solve built from the
        // definition r_i = (X_i^T M^-1 X_i)^-1 X_i^T M^-1 y.
        let (prob, pre, xb_t) = setup(24, 3, 5, 42);
        let p = 4;
        let mut out = Matrix::zeros(p, 5);
        let mut scratch = SloopScratch::new(3);
        sloop_block(&pre, &xb_t, &mut scratch, &mut out).unwrap();

        for i in 0..5 {
            let r_direct = direct_gls(&prob, i);
            for k in 0..p {
                assert!(
                    (out.get(k, i) - r_direct[k]).abs() < 1e-7,
                    "snp {i} comp {k}: {} vs {}",
                    out.get(k, i),
                    r_direct[k]
                );
            }
        }
    }

    /// Direct dense solve from the definition (independent of our fast path).
    fn direct_gls(prob: &Problem, i: usize) -> Vec<f64> {
        use crate::linalg::{gemv_t, posv, trsv_lower};
        let n = prob.dims.n;
        let pl = prob.dims.pl;
        let p = pl + 1;
        // X_i = [X_L | xr_i], Ã = L^-1 X_i, ỹ = L^-1 y
        let l = crate::linalg::potrf(&prob.m).unwrap();
        let mut a = Matrix::zeros(n, p);
        for j in 0..pl {
            a.col_mut(j).copy_from_slice(prob.xl.col(j));
        }
        a.col_mut(pl).copy_from_slice(prob.xr.col(i));
        trsm_lower_left(&l, &mut a).unwrap();
        let mut yt = prob.y.clone();
        trsv_lower(&l, &mut yt).unwrap();
        let s = crate::linalg::syrk_t(&a);
        let mut rhs = gemv_t(&a, &yt).unwrap();
        posv(&s, &mut rhs).unwrap();
        rhs
    }

    #[test]
    fn reductions_path_matches_native_path() {
        let (_, pre, xb_t) = setup(20, 2, 6, 7);
        let pl = 2;
        let mb = 6;
        let mut out_native = Matrix::zeros(pl + 1, mb);
        let mut scratch = SloopScratch::new(pl);
        sloop_block(&pre, &xb_t, &mut scratch, &mut out_native).unwrap();

        // Build reductions "as the device would".
        let mut g = Matrix::zeros(pl, mb);
        gemm(1.0, &pre.xl_t.transpose(), &xb_t, 0.0, &mut g).unwrap();
        let d: Vec<f64> = (0..mb).map(|j| sumsq(xb_t.col(j))).collect();
        let rb: Vec<f64> = (0..mb).map(|j| dot(xb_t.col(j), &pre.y_t)).collect();
        let mut out_red = Matrix::zeros(pl + 1, mb);
        sloop_from_reductions(&pre, &g, &d, &rb, &mut scratch, &mut out_red).unwrap();
        assert!(out_native.max_abs_diff(&out_red) < 1e-12);
    }

    #[test]
    fn shape_errors() {
        let (_, pre, xb_t) = setup(20, 2, 3, 9);
        let mut scratch = SloopScratch::new(2);
        let mut bad_out = Matrix::zeros(2, 3); // should be 3x3
        assert!(sloop_block(&pre, &xb_t, &mut scratch, &mut bad_out).is_err());
        let mut out = Matrix::zeros(3, 3);
        let bad_g = Matrix::zeros(1, 3);
        assert!(sloop_from_reductions(&pre, &bad_g, &[0.0; 3], &[0.0; 3], &mut scratch, &mut out).is_err());
    }

    #[test]
    fn empty_block_is_ok() {
        let (_, pre, _) = setup(20, 2, 3, 9);
        let xb_t = Matrix::zeros(20, 0);
        let mut out = Matrix::zeros(3, 0);
        let mut scratch = SloopScratch::new(2);
        sloop_block(&pre, &xb_t, &mut scratch, &mut out).unwrap();
    }
}
