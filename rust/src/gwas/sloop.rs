//! The S-loop (paper Listing 1.2 lines 11–15): per-SNP assembly and solve
//! of the small `(p×p)` system, given the block solution `X̃_b = L^-1 X_b`.
//!
//! This is the CPU half of the paper's pipeline — it runs on block `b-1`
//! while the accelerator solves the trsm of block `b`. Two entry points:
//!
//! * [`sloop_block`] — the pure-native version: computes the block
//!   reductions itself (`G = X̃_L^T X̃_b` via gemm, `d_j = ‖x̃_j‖²`,
//!   `rb = X̃_b^T Ỹ`) then assembles + solves per SNP.
//! * [`sloop_from_reductions`] — the offload-ablation version: the
//!   reductions were already produced by the L1 `sloop` kernel on the
//!   device; only the tiny per-SNP `posv`s remain.
//!
//! The `*_into` variants write straight into a caller-provided
//! column-major `(p·t) × mb` slice (the pipeline points them at its block
//! assembly buffer, so the retire path never allocates or copies).
//!
//! Multi-trait batching: the system `S_i` depends only on the SNP, not
//! the trait, so each SNP pays **one** Cholesky factorization
//! ([`posv_small_factor`]) reused across all `t` right-hand sides — the
//! paper's amortization argument applied to the S-loop. Output column
//! `j` holds the `t` solutions stacked: trait `k` occupies rows
//! `[k·p, (k+1)·p)`; statistics stack the same way in `STAT_ROWS`-tall
//! groups. The trait loops are batched through the fused kernels of
//! [`crate::linalg::micro`] — [`micro::dot_many`] for the per-(SNP,
//! trait) reductions, [`micro::chol_solve_multi`] for the `t` solves —
//! both of which replicate the solo kernel's per-element operation
//! order exactly, so trait column `k` of a batched run is byte-identical
//! to an independent single-trait run on that phenotype.
//!
//! Parallelism: the SNP columns are independent, so both the reductions
//! and the per-SNP solves shard their columns across the compute pool
//! ([`crate::util::threads`]) in [`SLOOP_PANEL`]-wide panels, each worker
//! with its own scratch. Per-column arithmetic is untouched by the
//! sharding, so results are bit-identical at every thread count.
//!
//! All paths are allocation-free in the per-SNP loop, and — with the
//! block reductions hoisted into [`SloopScratch`] — allocation-free per
//! block in the steady state too (buffers reallocate only when the block
//! geometry changes, i.e. once at the tail block).

use crate::error::{Error, Result};
use crate::gwas::assoc::{inv_pp_from_factor, sigma2, stat_column, STAT_ROWS};
use crate::gwas::preprocess::Preprocessed;
use crate::linalg::{chol::posv_small_factor, gemm, micro, sumsq, Matrix};
use crate::util::threads;

/// Column-panel width for sharding SNP columns across the pool.
const SLOOP_PANEL: usize = 64;
/// Minimum columns per worker before sharding pays for the spawns.
const SLOOP_COLS_PER_WORKER: usize = 128;
/// Rough per-column cost of the assembly + posv + statistics in
/// flop-equivalents for [`threads::for_flops`]. The per-SNP loop is
/// latency-bound, not flop-bound (the `p×p` systems are tiny), so this
/// is calibrated to wall time: a block only goes parallel when the
/// serial sweep costs on the order of a millisecond — small blocks on
/// the hot retire path must not pay a spawn for microseconds of work.
const SLOOP_COL_COST: f64 = 4000.0;

/// Per-SNP assembly scratch: the `p×p` system plus the stacked `p·t`
/// right-hand sides (all traits solved in one fused
/// [`micro::chol_solve_multi`] call) and the RHS copy the statistics
/// path needs. The RHS buffers grow to `p·t` lazily in `solve_panel`.
#[derive(Debug, Clone)]
struct SnpScratch {
    p: usize,
    s: Vec<f64>,
    rhs: Vec<f64>,
    rhs_orig: Vec<f64>,
}

impl SnpScratch {
    fn new(p: usize) -> Self {
        SnpScratch { p, s: vec![0.0; p * p], rhs: vec![0.0; p], rhs_orig: vec![0.0; p] }
    }
}

/// Per-block reduction scratch (`G`, `d`, `rb`), reused across blocks.
#[derive(Debug, Clone)]
struct BlockScratch {
    g: Matrix,
    d: Vec<f64>,
    rb: Vec<f64>,
}

impl BlockScratch {
    fn new() -> Self {
        BlockScratch { g: Matrix::zeros(0, 0), d: Vec::new(), rb: Vec::new() }
    }

    /// Fill `G = X̃_L^T X̃_b` (pl × mb), `d_j = ‖x̃_j‖²`, and the SNP-major
    /// trait reductions `rb[j·t + k] = x̃_j · ỹ_k`. `G` goes through the
    /// parallel gemm; `d`/`rb` shard their columns directly — the trait
    /// reductions batch through [`micro::dot_many`], which keeps each
    /// output on `blas1::dot`'s exact partial-sum scheme, so trait `k`'s
    /// accumulation order matches a single-trait run bit for bit.
    /// Buffers only reallocate when the block geometry changes.
    fn reduce(&mut self, pre: &Preprocessed, xb_t: &Matrix) -> Result<()> {
        let pl = pre.xl_t.cols();
        let mb = xb_t.cols();
        let t = pre.traits();
        if self.g.rows() != pl || self.g.cols() != mb {
            self.g = Matrix::zeros(pl, mb);
        }
        gemm(1.0, &pre.xl_tt, xb_t, 0.0, &mut self.g)?;
        self.d.clear();
        self.d.resize(mb, 0.0);
        self.rb.clear();
        self.rb.resize(mb * t, 0.0);
        let nt =
            threads::for_flops((2.0 + 2.0 * t as f64) * pre.n() as f64 * mb as f64);
        let yrefs: Vec<&[f64]> = (0..t).map(|k| pre.y_t.col(k)).collect();
        let chunks: Vec<(&mut [f64], &mut [f64])> = self
            .d
            .chunks_mut(SLOOP_PANEL)
            .zip(self.rb.chunks_mut(SLOOP_PANEL * t))
            .collect();
        threads::scatter(nt, chunks, || (), |_, ci, (dc, rc)| {
            let j0 = ci * SLOOP_PANEL;
            for (jj, dv) in dc.iter_mut().enumerate() {
                let col = xb_t.col(j0 + jj);
                *dv = sumsq(col);
                micro::dot_many(col, &yrefs, &mut rc[jj * t..(jj + 1) * t]);
            }
            Ok(())
        })
    }
}

/// Reusable scratch for the S-loop: the per-SNP `p×p` system plus the
/// hoisted per-block reduction buffers. One instance per study/stream —
/// parallel workers build their own per-SNP scratch internally.
#[derive(Debug, Clone)]
pub struct SloopScratch {
    snp: SnpScratch,
    blk: BlockScratch,
}

impl SloopScratch {
    pub fn new(pl: usize) -> Self {
        SloopScratch { snp: SnpScratch::new(pl + 1), blk: BlockScratch::new() }
    }
}

/// Native S-loop over a solved block `xb_t = X̃_b` (n × mb). Appends the
/// `t` stacked `p`-vectors `r_{i,k}` per SNP column into `out`
/// (column-major `(p·t) × mb`).
pub fn sloop_block(
    pre: &Preprocessed,
    xb_t: &Matrix,
    scratch: &mut SloopScratch,
    out: &mut Matrix,
) -> Result<()> {
    sloop_block_stats(pre, xb_t, scratch, out, None)
}

/// [`sloop_block`] plus optional association statistics: when `stats` is
/// given (a `(STAT_ROWS·t) × mb` matrix), each column receives the
/// stacked `[beta_snp, se, z]` per trait (see [`crate::gwas::assoc`]).
pub fn sloop_block_stats(
    pre: &Preprocessed,
    xb_t: &Matrix,
    scratch: &mut SloopScratch,
    out: &mut Matrix,
    stats: Option<&mut Matrix>,
) -> Result<()> {
    let pl = pre.xl_t.cols();
    let mb = xb_t.cols();
    let t = pre.traits();
    check_out(out, pl, mb, t)?;
    let stats_slice = match stats {
        Some(st) => {
            if st.rows() != STAT_ROWS * t || st.cols() != mb {
                return Err(Error::shape(format!(
                    "stats must be {}x{mb}, got {}x{}",
                    STAT_ROWS * t,
                    st.rows(),
                    st.cols()
                )));
            }
            Some(st.as_mut_slice())
        }
        None => None,
    };
    sloop_block_stats_into(pre, xb_t, scratch, out.as_mut_slice(), stats_slice)
}

/// [`sloop_block_stats`] writing into raw column-major slices: `out` is
/// `(p·t) × mb`, `stats` (optional) is `(3·t) × mb`. The pipeline points
/// `out` at its block assembly buffer so retiring a chunk never allocates.
pub fn sloop_block_stats_into(
    pre: &Preprocessed,
    xb_t: &Matrix,
    scratch: &mut SloopScratch,
    out: &mut [f64],
    stats: Option<&mut [f64]>,
) -> Result<()> {
    let pl = pre.xl_t.cols();
    let mb = xb_t.cols();
    let t = pre.traits();
    check_out_len(out.len(), pl, mb, t)?;
    if xb_t.rows() != pre.xl_t.rows() {
        return Err(Error::shape(format!(
            "sloop_block: X̃_b has {} rows, X̃_L has {}",
            xb_t.rows(),
            pre.xl_t.rows()
        )));
    }
    let SloopScratch { snp, blk } = scratch;
    blk.reduce(pre, xb_t)?;
    solve_columns(pre, &blk.g, &blk.d, &blk.rb, snp, out, stats)
}

/// [`sloop_block_stats_into`] without statistics.
pub fn sloop_block_into(
    pre: &Preprocessed,
    xb_t: &Matrix,
    scratch: &mut SloopScratch,
    out: &mut [f64],
) -> Result<()> {
    sloop_block_stats_into(pre, xb_t, scratch, out, None)
}

/// S-loop tail when the reductions `(G, d, rb)` come from the device
/// (the fused L1 kernel): only assembly + the per-SNP `posv` runs here.
/// `rb` is SNP-major (`mb·t`, trait `k` of SNP `j` at `j·t + k`).
pub fn sloop_from_reductions(
    pre: &Preprocessed,
    g: &Matrix,
    d: &[f64],
    rb: &[f64],
    scratch: &mut SloopScratch,
    out: &mut Matrix,
) -> Result<()> {
    let pl = pre.xl_t.cols();
    check_out(out, pl, d.len(), pre.traits())?;
    sloop_from_reductions_into(pre, g, d, rb, scratch, out.as_mut_slice())
}

/// [`sloop_from_reductions`] writing into a raw column-major `(p·t) × mb`
/// slice (the pipeline's assembly buffer).
pub fn sloop_from_reductions_into(
    pre: &Preprocessed,
    g: &Matrix,
    d: &[f64],
    rb: &[f64],
    scratch: &mut SloopScratch,
    out: &mut [f64],
) -> Result<()> {
    let pl = pre.xl_t.cols();
    let mb = d.len();
    let t = pre.traits();
    check_out_len(out.len(), pl, mb, t)?;
    if g.rows() != pl || g.cols() != mb || rb.len() != mb * t {
        return Err(Error::shape(format!(
            "sloop_from_reductions: G {}x{}, d {}, rb {} (want {})",
            g.rows(),
            g.cols(),
            mb,
            rb.len(),
            mb * t
        )));
    }
    solve_columns(pre, g, d, rb, &mut scratch.snp, out, None)
}

/// Shared per-SNP assembly + solve:
///
/// ```text
/// S_i = | S_TL      g_i |      rhs_{i,k} = | r̃_{T,k}  |
///       | g_i^T     d_i |                  | rb_{i,k} |
/// r_{i,k} = S_i^-1 rhs_{i,k}     (one factorization, t solves)
/// ```
///
/// Columns are sharded across the pool in [`SLOOP_PANEL`]-wide panels,
/// each worker with its own [`SnpScratch`]; column `j`'s arithmetic is
/// independent of every other column, so sharding cannot change a single
/// bit of the result. A `posv` failure reports the **lowest** failing
/// column — exactly the column the serial loop would have stopped at.
fn solve_columns(
    pre: &Preprocessed,
    g: &Matrix,
    d: &[f64],
    rb: &[f64],
    snp: &mut SnpScratch,
    out: &mut [f64],
    stats: Option<&mut [f64]>,
) -> Result<()> {
    let pl = pre.stl.rows();
    let p = pl + 1;
    let t = pre.traits();
    let mb = d.len();
    debug_assert_eq!(snp.p, p, "scratch built for wrong p");
    if let Some(st) = stats.as_deref() {
        if st.len() != STAT_ROWS * t * mb {
            return Err(Error::shape(format!(
                "stats must be {}x{mb}, got {} elements",
                STAT_ROWS * t,
                st.len()
            )));
        }
    }
    if mb == 0 {
        return Ok(());
    }
    let nt = threads::for_flops(SLOOP_COL_COST * (mb * t) as f64)
        .min(mb / SLOOP_COLS_PER_WORKER)
        .max(1);
    if nt <= 1 {
        return solve_panel(pre, g, d, rb, snp, 0, out, stats);
    }
    let nchunks = mb.div_ceil(SLOOP_PANEL);
    let stat_chunks: Vec<Option<&mut [f64]>> = match stats {
        Some(st) => st.chunks_mut(SLOOP_PANEL * STAT_ROWS * t).map(Some).collect(),
        None => (0..nchunks).map(|_| None).collect(),
    };
    let items: Vec<(&mut [f64], Option<&mut [f64]>)> =
        out.chunks_mut(SLOOP_PANEL * p * t).zip(stat_chunks).collect();
    threads::scatter(nt, items, || SnpScratch::new(p), |sc, ci, (outp, stp)| {
        solve_panel(pre, g, d, rb, sc, ci * SLOOP_PANEL, outp, stp)
    })
}

/// Serial assembly + solve over one panel: columns `[j0, j0 + ncols)`,
/// with `out`/`stats` holding exactly that panel's column-major storage.
#[allow(clippy::too_many_arguments)]
fn solve_panel(
    pre: &Preprocessed,
    g: &Matrix,
    d: &[f64],
    rb: &[f64],
    snp: &mut SnpScratch,
    j0: usize,
    out: &mut [f64],
    mut stats: Option<&mut [f64]>,
) -> Result<()> {
    let pl = pre.stl.rows();
    let p = pl + 1;
    let t = pre.traits();
    let n = pre.n();
    let ncols = out.len() / (p * t);
    if snp.rhs.len() != p * t {
        snp.rhs.resize(p * t, 0.0);
        snp.rhs_orig.resize(p * t, 0.0);
    }
    for jj in 0..ncols {
        let j = j0 + jj;
        let s = &mut snp.s;
        // Top-left block: S_TL (symmetric).
        for c in 0..pl {
            for r in 0..pl {
                s[c * p + r] = pre.stl.get(r, c);
            }
        }
        // Border: g_j and d_j.
        for r in 0..pl {
            let v = g.get(r, j);
            s[pl * p + r] = v; // last column
            s[r * p + pl] = v; // last row
        }
        s[pl * p + pl] = d[j];
        // One factorization per SNP, reused for every trait's RHS.
        posv_small_factor(s, p)
            .map_err(|e| Error::Numerical(format!("S-loop posv failed at column {j}: {e}")))?;
        // All t right-hand sides stacked, solved in one fused call
        // (each RHS sees `chol_solve_small`'s exact operation order).
        for k in 0..t {
            snp.rhs[k * p..k * p + pl].copy_from_slice(pre.rtop.col(k));
            snp.rhs[k * p + pl] = rb[j * t + k];
        }
        snp.rhs_orig.copy_from_slice(&snp.rhs);
        micro::chol_solve_multi(s, &mut snp.rhs, p, t);
        out[jj * t * p..(jj + 1) * t * p].copy_from_slice(&snp.rhs);
        if let Some(st) = stats.as_deref_mut() {
            // `s` holds the Cholesky factor of S_j, so the extra
            // statistics are nearly free; the (p,p) inverse entry
            // depends only on the factor — one evaluation per SNP.
            let var_pp = inv_pp_from_factor(s, p);
            for k in 0..t {
                let sol = &snp.rhs[k * p..(k + 1) * p];
                let orig = &snp.rhs_orig[k * p..(k + 1) * p];
                let s2 = sigma2(pre.yty[k], sol, orig, n, p)?;
                let col = stat_column(sol[pl], var_pp, s2);
                st[(jj * t + k) * STAT_ROWS..(jj * t + k + 1) * STAT_ROWS]
                    .copy_from_slice(&col);
            }
        }
    }
    Ok(())
}

fn check_out(out: &Matrix, pl: usize, mb: usize, t: usize) -> Result<()> {
    if out.rows() != (pl + 1) * t || out.cols() != mb {
        return Err(Error::shape(format!(
            "sloop out must be {}x{mb}, got {}x{}",
            (pl + 1) * t,
            out.rows(),
            out.cols()
        )));
    }
    Ok(())
}

fn check_out_len(len: usize, pl: usize, mb: usize, t: usize) -> Result<()> {
    if len != (pl + 1) * t * mb {
        return Err(Error::shape(format!(
            "sloop out slice must hold {}x{mb} = {} elements, got {len}",
            (pl + 1) * t,
            (pl + 1) * t * mb
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::preprocess::{phenotype_batch, preprocess, preprocess_multi};
    use crate::gwas::problem::{Dims, Problem};
    use crate::linalg::{dot, trsm_lower_left};

    fn setup(n: usize, pl: usize, m: usize, seed: u64) -> (Problem, Preprocessed, Matrix) {
        let prob = Problem::synthetic(Dims::new(n, pl, m).unwrap(), seed).unwrap();
        let pre = preprocess(&prob.m, &prob.xl, &prob.y, 0).unwrap();
        let mut xb_t = prob.xr.clone();
        trsm_lower_left(&pre.l, &mut xb_t).unwrap();
        (prob, pre, xb_t)
    }

    #[test]
    fn sloop_matches_direct_gls() {
        // Compare each r_i against a direct dense GLS solve built from the
        // definition r_i = (X_i^T M^-1 X_i)^-1 X_i^T M^-1 y.
        let (prob, pre, xb_t) = setup(24, 3, 5, 42);
        let p = 4;
        let mut out = Matrix::zeros(p, 5);
        let mut scratch = SloopScratch::new(3);
        sloop_block(&pre, &xb_t, &mut scratch, &mut out).unwrap();

        for i in 0..5 {
            let r_direct = direct_gls(&prob, i);
            for k in 0..p {
                assert!(
                    (out.get(k, i) - r_direct[k]).abs() < 1e-7,
                    "snp {i} comp {k}: {} vs {}",
                    out.get(k, i),
                    r_direct[k]
                );
            }
        }
    }

    /// Direct dense solve from the definition (independent of our fast path).
    fn direct_gls(prob: &Problem, i: usize) -> Vec<f64> {
        use crate::linalg::{gemv_t, posv, trsv_lower};
        let n = prob.dims.n;
        let pl = prob.dims.pl;
        let p = pl + 1;
        // X_i = [X_L | xr_i], Ã = L^-1 X_i, ỹ = L^-1 y
        let l = crate::linalg::potrf(&prob.m).unwrap();
        let mut a = Matrix::zeros(n, p);
        for j in 0..pl {
            a.col_mut(j).copy_from_slice(prob.xl.col(j));
        }
        a.col_mut(pl).copy_from_slice(prob.xr.col(i));
        trsm_lower_left(&l, &mut a).unwrap();
        let mut yt = prob.y.clone();
        trsv_lower(&l, &mut yt).unwrap();
        let s = crate::linalg::syrk_t(&a);
        let mut rhs = gemv_t(&a, &yt).unwrap();
        posv(&s, &mut rhs).unwrap();
        rhs
    }

    #[test]
    fn reductions_path_matches_native_path() {
        let (_, pre, xb_t) = setup(20, 2, 6, 7);
        let pl = 2;
        let mb = 6;
        let mut out_native = Matrix::zeros(pl + 1, mb);
        let mut scratch = SloopScratch::new(pl);
        sloop_block(&pre, &xb_t, &mut scratch, &mut out_native).unwrap();

        // Build reductions "as the device would".
        let mut g = Matrix::zeros(pl, mb);
        gemm(1.0, &pre.xl_tt, &xb_t, 0.0, &mut g).unwrap();
        let d: Vec<f64> = (0..mb).map(|j| sumsq(xb_t.col(j))).collect();
        let rb: Vec<f64> = (0..mb).map(|j| dot(xb_t.col(j), pre.y_t.col(0))).collect();
        let mut out_red = Matrix::zeros(pl + 1, mb);
        sloop_from_reductions(&pre, &g, &d, &rb, &mut scratch, &mut out_red).unwrap();
        assert!(out_native.max_abs_diff(&out_red) < 1e-12);
    }

    #[test]
    fn into_variants_match_matrix_variants() {
        let (_, pre, xb_t) = setup(20, 2, 6, 7);
        let (pl, mb, p) = (2, 6, 3);
        let mut out = Matrix::zeros(p, mb);
        let mut stats = Matrix::zeros(STAT_ROWS, mb);
        let mut scratch = SloopScratch::new(pl);
        sloop_block_stats(&pre, &xb_t, &mut scratch, &mut out, Some(&mut stats)).unwrap();

        let mut out_flat = vec![f64::NAN; p * mb];
        let mut stats_flat = vec![f64::NAN; STAT_ROWS * mb];
        sloop_block_stats_into(&pre, &xb_t, &mut scratch, &mut out_flat, Some(&mut stats_flat))
            .unwrap();
        assert_eq!(out_flat, out.as_slice());
        assert_eq!(stats_flat, stats.as_slice());

        // Bad slice lengths are rejected, not written past.
        let mut short = vec![0.0; p * mb - 1];
        assert!(sloop_block_into(&pre, &xb_t, &mut scratch, &mut short).is_err());
    }

    #[test]
    fn batched_traits_are_bit_identical_to_single_trait_runs() {
        // The tentpole contract: trait column k of a t-wide batch equals
        // an independent single-trait S-loop on phenotype k, bit for bit
        // — results *and* statistics.
        let (prob, _, _) = setup(24, 2, 40, 17);
        let ys = phenotype_batch(&prob.y, 5, 3);
        let multi = preprocess_multi(&prob.m, &prob.xl, &ys, 0).unwrap();
        let mut xb_t = prob.xr.clone();
        trsm_lower_left(&multi.l, &mut xb_t).unwrap();
        let (pl, p, mb, t) = (2, 3, 40, 5);
        let mut out = Matrix::zeros(p * t, mb);
        let mut stats = Matrix::zeros(STAT_ROWS * t, mb);
        let mut scratch = SloopScratch::new(pl);
        sloop_block_stats(&multi, &xb_t, &mut scratch, &mut out, Some(&mut stats)).unwrap();

        for k in 0..t {
            let single = preprocess(&prob.m, &prob.xl, ys.col(k), 0).unwrap();
            let mut out1 = Matrix::zeros(p, mb);
            let mut stats1 = Matrix::zeros(STAT_ROWS, mb);
            let mut scr1 = SloopScratch::new(pl);
            sloop_block_stats(&single, &xb_t, &mut scr1, &mut out1, Some(&mut stats1))
                .unwrap();
            for j in 0..mb {
                assert_eq!(
                    &out.col(j)[k * p..(k + 1) * p],
                    out1.col(j),
                    "snp {j} trait {k}"
                );
                assert_eq!(
                    &stats.col(j)[k * STAT_ROWS..(k + 1) * STAT_ROWS],
                    stats1.col(j),
                    "stats snp {j} trait {k}"
                );
            }
        }
    }

    #[test]
    fn multi_trait_reductions_path_matches_native_path() {
        let (prob, _, _) = setup(18, 2, 6, 29);
        let ys = phenotype_batch(&prob.y, 3, 11);
        let pre = preprocess_multi(&prob.m, &prob.xl, &ys, 0).unwrap();
        let mut xb_t = prob.xr.clone();
        trsm_lower_left(&pre.l, &mut xb_t).unwrap();
        let (pl, mb, t) = (2, 6, 3);
        let mut out_native = Matrix::zeros((pl + 1) * t, mb);
        let mut scratch = SloopScratch::new(pl);
        sloop_block(&pre, &xb_t, &mut scratch, &mut out_native).unwrap();

        let mut g = Matrix::zeros(pl, mb);
        gemm(1.0, &pre.xl_tt, &xb_t, 0.0, &mut g).unwrap();
        let d: Vec<f64> = (0..mb).map(|j| sumsq(xb_t.col(j))).collect();
        let mut rb = vec![0.0; mb * t];
        for j in 0..mb {
            for k in 0..t {
                rb[j * t + k] = dot(xb_t.col(j), pre.y_t.col(k));
            }
        }
        let mut out_red = Matrix::zeros((pl + 1) * t, mb);
        sloop_from_reductions(&pre, &g, &d, &rb, &mut scratch, &mut out_red).unwrap();
        assert_eq!(out_native, out_red);
    }

    #[test]
    fn sharded_sloop_is_bit_identical_to_serial() {
        // Enough columns that the work gate (SLOOP_COL_COST * mb) and the
        // per-worker column floor both clear, so the parallel path
        // actually engages rather than falling back to serial.
        let (_, pre, xb_t) = setup(16, 2, 8192, 13);
        let (p, mb) = (3, 8192);
        let mut out_serial = Matrix::zeros(p, mb);
        let mut stats_serial = Matrix::zeros(STAT_ROWS, mb);
        {
            let _g = crate::util::threads::with_budget(1);
            let mut scratch = SloopScratch::new(2);
            sloop_block_stats(&pre, &xb_t, &mut scratch, &mut out_serial, Some(&mut stats_serial))
                .unwrap();
        }
        for nt in [2, 3, 8] {
            let _g = crate::util::threads::with_budget(nt);
            let mut scratch = SloopScratch::new(2);
            let mut out = Matrix::zeros(p, mb);
            let mut stats = Matrix::zeros(STAT_ROWS, mb);
            sloop_block_stats(&pre, &xb_t, &mut scratch, &mut out, Some(&mut stats)).unwrap();
            assert_eq!(out, out_serial, "threads={nt}");
            assert_eq!(stats, stats_serial, "threads={nt}");
        }
    }

    #[test]
    fn sharded_multi_trait_sloop_is_bit_identical_to_serial() {
        let prob = Problem::synthetic(Dims::new(16, 2, 4096).unwrap(), 13).unwrap();
        let ys = phenotype_batch(&prob.y, 4, 9);
        let pre = preprocess_multi(&prob.m, &prob.xl, &ys, 0).unwrap();
        let mut xb_t = prob.xr.clone();
        trsm_lower_left(&pre.l, &mut xb_t).unwrap();
        let (p, mb, t) = (3, 4096, 4);
        let mut out_serial = Matrix::zeros(p * t, mb);
        let mut stats_serial = Matrix::zeros(STAT_ROWS * t, mb);
        {
            let _g = crate::util::threads::with_budget(1);
            let mut scratch = SloopScratch::new(2);
            sloop_block_stats(&pre, &xb_t, &mut scratch, &mut out_serial, Some(&mut stats_serial))
                .unwrap();
        }
        for nt in [2, 8] {
            let _g = crate::util::threads::with_budget(nt);
            let mut scratch = SloopScratch::new(2);
            let mut out = Matrix::zeros(p * t, mb);
            let mut stats = Matrix::zeros(STAT_ROWS * t, mb);
            sloop_block_stats(&pre, &xb_t, &mut scratch, &mut out, Some(&mut stats)).unwrap();
            assert_eq!(out, out_serial, "threads={nt}");
            assert_eq!(stats, stats_serial, "threads={nt}");
        }
    }

    #[test]
    fn scratch_reuse_across_block_geometries_is_clean() {
        // Steady-state blocks then a smaller tail block: the hoisted
        // reduction buffers must resize without leaking stale values.
        let (_, pre, xb_t) = setup(18, 2, 48, 3);
        let mut scratch = SloopScratch::new(2);
        let full = xb_t.slice_cols(0, 32);
        let tail = xb_t.slice_cols(32, 48);
        let mut out_full = Matrix::zeros(3, 32);
        let mut out_tail = Matrix::zeros(3, 16);
        sloop_block(&pre, &full, &mut scratch, &mut out_full).unwrap();
        sloop_block(&pre, &tail, &mut scratch, &mut out_tail).unwrap();
        // Fresh scratch gives the same tail answers.
        let mut scratch2 = SloopScratch::new(2);
        let mut out_tail2 = Matrix::zeros(3, 16);
        sloop_block(&pre, &tail, &mut scratch2, &mut out_tail2).unwrap();
        assert_eq!(out_tail, out_tail2);
    }

    #[test]
    fn shape_errors() {
        let (_, pre, xb_t) = setup(20, 2, 3, 9);
        let mut scratch = SloopScratch::new(2);
        let mut bad_out = Matrix::zeros(2, 3); // should be 3x3
        assert!(sloop_block(&pre, &xb_t, &mut scratch, &mut bad_out).is_err());
        let mut out = Matrix::zeros(3, 3);
        let bad_g = Matrix::zeros(1, 3);
        assert!(
            sloop_from_reductions(&pre, &bad_g, &[0.0; 3], &[0.0; 3], &mut scratch, &mut out)
                .is_err()
        );
    }

    #[test]
    fn empty_block_is_ok() {
        let (_, pre, _) = setup(20, 2, 3, 9);
        let xb_t = Matrix::zeros(20, 0);
        let mut out = Matrix::zeros(3, 0);
        let mut scratch = SloopScratch::new(2);
        sloop_block(&pre, &xb_t, &mut scratch, &mut out).unwrap();
    }
}
