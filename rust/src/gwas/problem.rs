//! Problem definition: dimensions and the in-memory representation of one
//! GWAS study (used by generators, oracles, and tests; the streaming path
//! never holds a whole `Problem` — that is the point of the paper).

use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::util::XorShift;

/// Study dimensions, in the paper's notation.
///
/// * `n` — sample count (individuals). Paper median: 10 000.
/// * `pl` — fixed covariates (columns of `X_L`). Paper: `p` between 4 and
///   20 *including* the SNP column, so `pl = p - 1`.
/// * `m` — SNP count (columns of `X_R`). Paper: up to 190 M.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dims {
    pub n: usize,
    pub pl: usize,
    pub m: usize,
}

impl Dims {
    pub fn new(n: usize, pl: usize, m: usize) -> Result<Self> {
        if n == 0 || pl == 0 || m == 0 {
            return Err(Error::Config(format!("dims must be positive: n={n} pl={pl} m={m}")));
        }
        if pl + 1 >= n {
            return Err(Error::Config(format!(
                "need n > p = pl+1 for a well-posed GLS (n={n}, pl={pl})"
            )));
        }
        Ok(Dims { n, pl, m })
    }

    /// Total design-matrix width `p = pl + 1` (covariates + the SNP).
    #[inline]
    pub fn p(&self) -> usize {
        self.pl + 1
    }

    /// Bytes of one f64 SNP column.
    #[inline]
    pub fn col_bytes(&self) -> u64 {
        (self.n * 8) as u64
    }

    /// Total size of `X_R` on disk in bytes (the paper's "14 TB" number
    /// for n=10 000, m=190 M).
    #[inline]
    pub fn xr_bytes(&self) -> u64 {
        self.col_bytes() * self.m as u64
    }
}

/// A fully in-memory study instance. Only sensible for small `m`; the
/// dataset generator writes the streaming-scale equivalent to disk.
#[derive(Debug, Clone)]
pub struct Problem {
    pub dims: Dims,
    /// Kinship / covariance matrix `M ∈ R^{n×n}`, SPD.
    pub m: Matrix,
    /// Fixed covariates `X_L ∈ R^{n×pl}` (first column is the intercept).
    pub xl: Matrix,
    /// Phenotype `y ∈ R^n`.
    pub y: Vec<f64>,
    /// SNP genotypes `X_R ∈ R^{n×m}`.
    pub xr: Matrix,
}

impl Problem {
    /// Deterministic synthetic study. Mirrors what a real GWAS feeds the
    /// solver: `M` = SPD kinship, intercept + standard-normal covariates,
    /// Hardy–Weinberg genotype columns with per-SNP random MAF, and a
    /// phenotype with genetic signal + noise.
    pub fn synthetic(dims: Dims, seed: u64) -> Result<Self> {
        let Dims { n, pl, m } = dims;
        let mut rng = XorShift::new(seed);
        let kin = Matrix::rand_spd(n, 4.0, &mut rng);
        let mut xl = Matrix::randn(n, pl, &mut rng);
        for i in 0..n {
            xl.set(i, 0, 1.0); // intercept column
        }
        let mut xr = Matrix::zeros(n, m);
        for j in 0..m {
            let maf = rng.uniform_in(0.05, 0.5);
            let col = xr.col_mut(j);
            for v in col.iter_mut() {
                *v = rng.genotype(maf);
            }
            // Keep columns polymorphic (constant columns are collinear
            // with the intercept; real pipelines drop such SNPs).
            if col.iter().all(|&v| v == col[0]) {
                col[0] = if col[0] == 1.0 { 2.0 } else { 1.0 };
            }
        }
        // Phenotype: a little real signal on the first SNP + covariates + noise.
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut v = 0.3 * xr.get(i, 0);
            for k in 0..pl {
                v += 0.1 * xl.get(i, k);
            }
            y[i] = v + rng.normal();
        }
        Ok(Problem { dims, m: kin, xl, y, xr })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_validation() {
        assert!(Dims::new(0, 3, 10).is_err());
        assert!(Dims::new(100, 0, 10).is_err());
        assert!(Dims::new(100, 3, 0).is_err());
        assert!(Dims::new(4, 3, 10).is_err()); // n must exceed p
        assert!(Dims::new(100, 3, 10).is_ok());
    }

    #[test]
    fn p_and_sizes() {
        let d = Dims::new(10_000, 3, 190_000_000).unwrap();
        assert_eq!(d.p(), 4);
        assert_eq!(d.col_bytes(), 80_000);
        // The paper's 14 TB claim: 190M SNPs × 10k samples × 8 bytes ≈ 13.8 TiB.
        let tib = d.xr_bytes() as f64 / (1u64 << 40) as f64;
        assert!((13.0..15.0).contains(&tib), "{tib}");
    }

    #[test]
    fn synthetic_is_deterministic() {
        let d = Dims::new(50, 3, 8).unwrap();
        let a = Problem::synthetic(d, 7).unwrap();
        let b = Problem::synthetic(d, 7).unwrap();
        assert_eq!(a.xr, b.xr);
        assert_eq!(a.y, b.y);
        let c = Problem::synthetic(d, 8).unwrap();
        assert!(a.xr.max_abs_diff(&c.xr) > 0.0);
    }

    #[test]
    fn synthetic_shapes_and_intercept() {
        let d = Dims::new(40, 4, 6).unwrap();
        let p = Problem::synthetic(d, 1).unwrap();
        assert_eq!(p.m.rows(), 40);
        assert_eq!(p.xl.cols(), 4);
        assert_eq!(p.xr.cols(), 6);
        assert_eq!(p.y.len(), 40);
        for i in 0..40 {
            assert_eq!(p.xl.get(i, 0), 1.0);
        }
    }

    #[test]
    fn genotypes_are_allele_counts() {
        let d = Dims::new(60, 2, 5).unwrap();
        let p = Problem::synthetic(d, 3).unwrap();
        for v in p.xr.as_slice() {
            assert!(*v == 0.0 || *v == 1.0 || *v == 2.0);
        }
    }
}
