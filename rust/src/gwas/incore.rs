//! In-core reference solver — paper Listing 1.1, the algorithm every
//! streaming/out-of-core variant must agree with. This is the correctness
//! oracle for the whole repo: the pipeline integration tests stream a
//! dataset from disk and compare bit-tolerance against this.

use crate::error::Result;
use crate::gwas::preprocess::{preprocess, preprocess_multi};
use crate::gwas::problem::Problem;
use crate::gwas::sloop::SloopScratch;
use crate::linalg::{trsm_lower_left, Matrix};

/// Solve the full sequence of GLS problems in memory.
/// Returns `r` as a `(pl+1) × m` matrix (one solution vector per SNP).
pub fn solve_incore(prob: &Problem) -> Result<Matrix> {
    Ok(solve_incore_with_stats(prob)?.0)
}

/// [`solve_incore`] plus per-SNP association statistics
/// (`3 × m`: beta, se, z — see [`crate::gwas::assoc`]).
pub fn solve_incore_with_stats(prob: &Problem) -> Result<(Matrix, Matrix)> {
    let pre = preprocess(&prob.m, &prob.xl, &prob.y, 0)?;
    // X̃_R ← trsm L, X_R   (the BLAS-3 bulk — Listing 1.1 line 7 blocked)
    let mut xr_t = prob.xr.clone();
    trsm_lower_left(&pre.l, &mut xr_t)?;
    // S-loop over all columns at once.
    let p = prob.dims.p();
    let mut out = Matrix::zeros(p, prob.dims.m);
    let mut stats = Matrix::zeros(crate::gwas::assoc::STAT_ROWS, prob.dims.m);
    let mut scratch = SloopScratch::new(prob.dims.pl);
    crate::gwas::sloop::sloop_block_stats(&pre, &xr_t, &mut scratch, &mut out, Some(&mut stats))?;
    Ok((out, stats))
}

/// Multi-trait oracle: [`solve_incore`] against a phenotype matrix
/// `Y ∈ R^{n×t}` (e.g. from [`crate::gwas::preprocess::phenotype_batch`]).
/// Returns `r` as `(p·t) × m` and stats as `(3·t) × m`, trait `k` stacked
/// at rows `[k·p, (k+1)·p)` — the layout the streaming engine writes.
pub fn solve_incore_multi(prob: &Problem, ys: &Matrix) -> Result<(Matrix, Matrix)> {
    let pre = preprocess_multi(&prob.m, &prob.xl, ys, 0)?;
    let mut xr_t = prob.xr.clone();
    trsm_lower_left(&pre.l, &mut xr_t)?;
    let p = prob.dims.p();
    let t = pre.traits();
    let mut out = Matrix::zeros(p * t, prob.dims.m);
    let mut stats = Matrix::zeros(crate::gwas::assoc::STAT_ROWS * t, prob.dims.m);
    let mut scratch = SloopScratch::new(prob.dims.pl);
    crate::gwas::sloop::sloop_block_stats(&pre, &xr_t, &mut scratch, &mut out, Some(&mut stats))?;
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::problem::Dims;
    use crate::linalg::{gemv_n, gemv_t, posv, syrk_t};

    /// Fully independent oracle: explicitly invert M via posv column by
    /// column, then form the normal equations from the definition.
    fn definition_gls(prob: &Problem, i: usize) -> Vec<f64> {
        let n = prob.dims.n;
        let pl = prob.dims.pl;
        let p = pl + 1;
        // Build X_i
        let mut x = Matrix::zeros(n, p);
        for j in 0..pl {
            x.col_mut(j).copy_from_slice(prob.xl.col(j));
        }
        x.col_mut(pl).copy_from_slice(prob.xr.col(i));
        // Minv_x = M^-1 X_i (column-wise posv), Minv_y = M^-1 y
        let mut minv_x = Matrix::zeros(n, p);
        for j in 0..p {
            let mut col = x.col(j).to_vec();
            posv(&prob.m, &mut col).unwrap();
            minv_x.col_mut(j).copy_from_slice(&col);
        }
        let mut minv_y = prob.y.clone();
        posv(&prob.m, &mut minv_y).unwrap();
        // S = X^T M^-1 X, rhs = X^T M^-1 y
        let mut s = Matrix::zeros(p, p);
        crate::linalg::gemm(1.0, &x.transpose(), &minv_x, 0.0, &mut s).unwrap();
        let mut rhs = gemv_t(&x, &minv_y).unwrap();
        posv(&s, &mut rhs).unwrap();
        rhs
    }

    #[test]
    fn incore_matches_definition() {
        let prob = Problem::synthetic(Dims::new(28, 3, 7).unwrap(), 99).unwrap();
        let r = solve_incore(&prob).unwrap();
        assert_eq!(r.rows(), 4);
        assert_eq!(r.cols(), 7);
        for i in 0..7 {
            let want = definition_gls(&prob, i);
            for k in 0..4 {
                assert!(
                    (r.get(k, i) - want[k]).abs() < 1e-6,
                    "snp {i} comp {k}: {} vs {}",
                    r.get(k, i),
                    want[k]
                );
            }
        }
    }

    #[test]
    fn incore_recovers_planted_signal() {
        // The synthetic phenotype plants effect 0.3 on SNP 0; with enough
        // samples the estimate should be near it, and SNP effects for null
        // SNPs should be near zero.
        let prob = Problem::synthetic(Dims::new(600, 2, 4).unwrap(), 5).unwrap();
        let r = solve_incore(&prob).unwrap();
        let beta_snp0 = r.get(2, 0); // last row = SNP effect
        assert!((beta_snp0 - 0.3).abs() < 0.15, "beta={beta_snp0}");
        for i in 1..4 {
            assert!(r.get(2, i).abs() < 0.2, "null snp {i} got {}", r.get(2, i));
        }
    }

    #[test]
    fn incore_multi_stacks_single_trait_answers() {
        use crate::gwas::preprocess::phenotype_batch;
        let prob = Problem::synthetic(Dims::new(30, 2, 5).unwrap(), 12).unwrap();
        let ys = phenotype_batch(&prob.y, 3, 77);
        let (r, stats) = solve_incore_multi(&prob, &ys).unwrap();
        assert_eq!(r.rows(), 3 * 3);
        assert_eq!(stats.rows(), 3 * 3);
        // Trait 0 is the unshuffled phenotype: identical to the
        // single-trait solver bit for bit.
        let (r1, stats1) = solve_incore_with_stats(&prob).unwrap();
        for j in 0..5 {
            assert_eq!(&r.col(j)[..3], r1.col(j), "snp {j}");
            assert_eq!(&stats.col(j)[..3], stats1.col(j), "snp {j}");
        }
    }

    #[test]
    fn incore_single_snp() {
        let prob = Problem::synthetic(Dims::new(16, 2, 1).unwrap(), 2).unwrap();
        let r = solve_incore(&prob).unwrap();
        assert_eq!(r.cols(), 1);
        assert!(r.as_slice().iter().all(|v| v.is_finite()));
    }
}

#[cfg(test)]
mod stats_tests {
    use super::*;
    use crate::gwas::assoc::rank_by_z;
    use crate::gwas::problem::Dims;

    #[test]
    fn planted_snp_is_most_significant() {
        // The synthetic phenotype plants effect 0.3 on SNP 0; with enough
        // samples its |z| must dominate the null SNPs.
        let prob = Problem::synthetic(Dims::new(400, 2, 8).unwrap(), 21).unwrap();
        let (_, stats) = solve_incore_with_stats(&prob).unwrap();
        assert_eq!(stats.rows(), 3);
        let ranked = rank_by_z(&stats);
        assert_eq!(ranked[0], 0, "planted SNP should rank first: {ranked:?}");
        assert!(stats.get(2, 0).abs() > 3.0, "z={}", stats.get(2, 0));
    }

    #[test]
    fn stats_are_consistent_with_estimates() {
        let prob = Problem::synthetic(Dims::new(60, 3, 6).unwrap(), 4).unwrap();
        let (r, stats) = solve_incore_with_stats(&prob).unwrap();
        for i in 0..6 {
            // Row 0 is the SNP effect itself.
            assert_eq!(stats.get(0, i), r.get(3, i));
            // se > 0 and z = beta/se.
            let (beta, se, z) = (stats.get(0, i), stats.get(1, i), stats.get(2, i));
            assert!(se > 0.0);
            assert!((z - beta / se).abs() < 1e-12);
        }
    }

    #[test]
    fn null_snps_have_moderate_z() {
        // SNPs 1.. carry no signal: |z| should mostly stay near 0.
        let prob = Problem::synthetic(Dims::new(500, 2, 10).unwrap(), 9).unwrap();
        let (_, stats) = solve_incore_with_stats(&prob).unwrap();
        let high = (1..10).filter(|&i| stats.get(2, i).abs() > 4.0).count();
        assert!(high <= 1, "too many significant null SNPs");
    }
}
