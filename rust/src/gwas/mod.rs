//! The GWAS generalized-least-squares problem itself: dimensions,
//! preprocessing (Listing 1.1 lines 1–5), the per-block S-loop
//! (lines 11–15 / Listing 1.2 lines 11–15), and the in-core reference
//! solver used as the correctness oracle for every streaming variant.

pub mod assoc;
pub mod incore;
pub mod preprocess;
pub mod problem;
pub mod sloop;

pub use incore::{solve_incore, solve_incore_multi, solve_incore_with_stats};
pub use preprocess::{phenotype_batch, preprocess, preprocess_multi, Preprocessed};
pub use problem::{Dims, Problem};
pub use sloop::{
    sloop_block, sloop_block_into, sloop_block_stats, sloop_block_stats_into,
    sloop_from_reductions, sloop_from_reductions_into, SloopScratch,
};
