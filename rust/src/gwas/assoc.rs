//! Association statistics — the deliverable a GWAS actually reports.
//!
//! The paper computes the GLS estimates `r_i`; a study then tests each
//! SNP via its effect's standard error. For GLS with known covariance
//! `M` (the mixed-model score setting of ProbABEL's `--mmscore`):
//!
//! ```text
//! Var(r̂_i)      = σ̂_i² · S_i^-1                     (S_i = X_i^T M^-1 X_i)
//! σ̂_i²          = (ỹ^T ỹ − r̂_i^T rhs_i) / (n − p)   (GLS residual variance)
//! se(β̂_snp)     = sqrt(σ̂_i² · (S_i^-1)_{pp})
//! z_i            = β̂_snp / se(β̂_snp)
//! ```
//!
//! `(S_i^-1)_{pp}` comes for free from the Cholesky factor the S-loop
//! already computes: with `S = L L^T`, `(S^-1)_{pp} = ‖L^-1 e_p‖²` — one
//! extra forward substitution per SNP.

use crate::error::{Error, Result};
use crate::linalg::Matrix;

/// Per-SNP statistics block: rows = [beta_snp, se, z], one column per SNP.
pub const STAT_ROWS: usize = 3;

/// Compute `(S^-1)_{pp}` from the in-place Cholesky factor produced by
/// `posv_small` (lower triangle of `s`, column-major `p×p`).
#[inline]
pub fn inv_pp_from_factor(s_factored: &[f64], p: usize) -> f64 {
    // Solve L w = e_{p-1} by forward substitution; only rows ≥ p-1 matter,
    // and e_{p-1} has a single 1 at the last row, so w = e_p / L[p-1,p-1].
    let lpp = s_factored[(p - 1) * p + (p - 1)];
    let w = 1.0 / lpp;
    w * w
}

/// Residual variance of one GLS fit: `(ỹ·ỹ − r·rhs) / (n − p)`.
#[inline]
pub fn sigma2(yty: f64, r: &[f64], rhs: &[f64], n: usize, p: usize) -> Result<f64> {
    if n <= p {
        return Err(Error::Numerical(format!("sigma2: n={n} ≤ p={p}")));
    }
    let explained: f64 = r.iter().zip(rhs).map(|(a, b)| a * b).sum();
    // Guard tiny negative values from roundoff.
    Ok(((yty - explained) / (n - p) as f64).max(0.0))
}

/// Assemble the `[beta, se, z]` column for one SNP.
#[inline]
pub fn stat_column(beta: f64, var_pp: f64, s2: f64) -> [f64; STAT_ROWS] {
    let se = (var_pp * s2).sqrt();
    let z = if se > 0.0 { beta / se } else { 0.0 };
    [beta, se, z]
}

/// Convenience: significance ranking of a stats matrix (3×m) by |z|.
/// Returns SNP indices sorted most-significant first.
pub fn rank_by_z(stats: &Matrix) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..stats.cols()).collect();
    idx.sort_by(|&a, &b| {
        stats
            .get(2, b)
            .abs()
            .partial_cmp(&stats.get(2, a).abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::chol::posv_small;
    use crate::linalg::posv;
    use crate::util::XorShift;

    #[test]
    fn inv_pp_matches_explicit_inverse() {
        let mut rng = XorShift::new(4);
        for p in [2usize, 4, 6] {
            let s = Matrix::rand_spd(p, 2.0, &mut rng);
            // Explicit (S^-1)_{pp} via posv on e_p.
            let mut e = vec![0.0; p];
            e[p - 1] = 1.0;
            posv(&s, &mut e).unwrap();
            let want = e[p - 1];
            // Via the factored path.
            let mut flat = s.as_slice().to_vec();
            let mut b = vec![0.0; p];
            posv_small(&mut flat, &mut b, p).unwrap();
            let got = inv_pp_from_factor(&flat, p);
            assert!((got - want).abs() < 1e-10 * want.abs().max(1.0), "p={p}: {got} vs {want}");
        }
    }

    #[test]
    fn sigma2_basics() {
        // Perfect fit: residual variance 0 (clamped).
        assert_eq!(sigma2(10.0, &[1.0, 3.0], &[1.0, 3.0], 12, 2).unwrap(), 0.0);
        // Simple case.
        let s2 = sigma2(20.0, &[1.0], &[4.0], 6, 1).unwrap();
        assert!((s2 - 16.0 / 5.0).abs() < 1e-12);
        assert!(sigma2(1.0, &[], &[], 2, 2).is_err());
    }

    #[test]
    fn stat_column_math() {
        let [b, se, z] = stat_column(2.0, 0.25, 4.0);
        assert_eq!(b, 2.0);
        assert_eq!(se, 1.0);
        assert_eq!(z, 2.0);
        let [_, _, z0] = stat_column(1.0, 0.0, 0.0);
        assert_eq!(z0, 0.0); // degenerate → no blow-up
    }

    #[test]
    fn rank_by_z_orders_by_significance() {
        let stats =
            Matrix::from_rows(&[&[0.1, 0.5, 0.2], &[1.0, 1.0, 1.0], &[0.5, -3.0, 1.5]]);
        assert_eq!(rank_by_z(&stats), vec![1, 2, 0]);
    }
}
