//! Preprocessing — Listing 1.1 lines 1–5 (plus the inverted diagonal
//! blocks of `L` the accelerator trsm kernel consumes).
//!
//! Runs once per study, cost `O(n^3)`; the paper measures it "in the order
//! of seconds" and excludes it from the streaming timings. Everything the
//! per-block hot path needs is captured in [`Preprocessed`].
//!
//! Multi-trait batching: the phenotype is a matrix `Y ∈ R^{n×t}` — one
//! column per trait (or per permGWAS-style shuffled phenotype, see
//! [`phenotype_batch`]). All per-trait products (`Ỹ`, `R̃_T`, `ỹ_k·ỹ_k`)
//! are computed column by column with the same kernels the single-trait
//! path used, so column `k` of a batched study is bit-identical to an
//! independent single-trait study on that column.

use crate::error::{Error, Result};
use crate::linalg::{
    dot, gemv_t, potrf, potrf_invert_diag_blocks, syrk_t_pretransposed, trsm_lower_left,
    trsv_lower, Matrix,
};
use crate::util::XorShift;

/// Everything the streaming loop needs, computed once.
#[derive(Debug, Clone)]
pub struct Preprocessed {
    /// Cholesky factor: `L L^T = M` (lower).
    pub l: Matrix,
    /// `X̃_L = L^-1 X_L` (n × pl).
    pub xl_t: Matrix,
    /// `X̃_L^T` (pl × n) — precomputed so the per-block reduction
    /// `G = X̃_L^T X̃_b` never re-transposes (or re-allocates) in the
    /// steady state.
    pub xl_tt: Matrix,
    /// `Ỹ = L^-1 Y` (n × t) — one column per trait.
    pub y_t: Matrix,
    /// `S_TL = X̃_L^T X̃_L` (pl × pl).
    pub stl: Matrix,
    /// `R̃_T = X̃_L^T Ỹ` (pl × t) — one column per trait.
    pub rtop: Matrix,
    /// Inverted `nb×nb` diagonal blocks of `L`, side by side (nb × nb·ceil(n/nb)).
    /// Consumed by the L1 Pallas trsm kernel; `None` when running CPU-only.
    pub dinv: Option<Matrix>,
    /// Diagonal block size used for `dinv`.
    pub dinv_nb: usize,
    /// Per-trait `ỹ_k·ỹ_k` — precomputed for the per-SNP residual
    /// variance (assoc stats).
    pub yty: Vec<f64>,
}

impl Preprocessed {
    /// Number of batched traits `t` (≥ 1).
    pub fn traits(&self) -> usize {
        self.y_t.cols()
    }

    /// Sample count `n`.
    pub fn n(&self) -> usize {
        self.y_t.rows()
    }
}

/// Run the preprocessing over `(M, X_L, y)` for a single phenotype.
///
/// `dinv_nb` — diagonal block size for the accelerator trsm formulation;
/// pass 0 to skip computing `dinv` (CPU-only paths).
pub fn preprocess(m: &Matrix, xl: &Matrix, y: &[f64], dinv_nb: usize) -> Result<Preprocessed> {
    let mut ys = Matrix::zeros(y.len(), 1);
    ys.col_mut(0).copy_from_slice(y);
    preprocess_multi(m, xl, &ys, dinv_nb)
}

/// [`preprocess`] over a phenotype matrix `Y ∈ R^{n×t}`. Every per-trait
/// product runs column-wise through the exact single-trait kernels
/// (`trsv`, `gemv_t`, `dot`), so batching never changes a bit of any
/// individual trait's results.
pub fn preprocess_multi(
    m: &Matrix,
    xl: &Matrix,
    ys: &Matrix,
    dinv_nb: usize,
) -> Result<Preprocessed> {
    let t = ys.cols();
    if t == 0 || ys.rows() != m.rows() {
        return Err(Error::shape(format!(
            "preprocess: Y is {}x{t}, kinship is {}x{}",
            ys.rows(),
            m.rows(),
            m.cols()
        )));
    }
    let l = potrf(m)?; // L ← potrf M
    let mut xl_t = xl.clone();
    trsm_lower_left(&l, &mut xl_t)?; // X̃_L ← trsm L, X_L
    let mut y_t = ys.clone();
    for k in 0..t {
        trsv_lower(&l, y_t.col_mut(k))?; // ỹ_k ← trsv L, y_k
    }
    let mut rtop = Matrix::zeros(xl.cols(), t);
    for k in 0..t {
        let rk = gemv_t(&xl_t, y_t.col(k))?; // r̃_T,k ← gemv X̃_L, ỹ_k
        rtop.col_mut(k).copy_from_slice(&rk);
    }
    let xl_tt = xl_t.transpose(); // cached once: syrk below + per-block G reductions
    let stl = syrk_t_pretransposed(&xl_tt, &xl_t); // S_TL ← syrk X̃_L
    let dinv = if dinv_nb > 0 { Some(potrf_invert_diag_blocks(&l, dinv_nb)?) } else { None };
    let yty = (0..t).map(|k| dot(y_t.col(k), y_t.col(k))).collect();
    Ok(Preprocessed { l, xl_t, xl_tt, y_t, stl, rtop, dinv, dinv_nb, yty })
}

/// Build the batched phenotype matrix `Y ∈ R^{n×t}` for permutation mode:
/// column 0 is the measured phenotype, columns `1..t` are Fisher–Yates
/// shuffles of it, each drawn from its own deterministic stream seeded by
/// `(perm_seed, k)`. Column `k` depends only on `(y, perm_seed, k)` — not
/// on `t` — so widening the batch never changes earlier columns, and the
/// whole batch is reproducible under `--perm-seed`.
pub fn phenotype_batch(y: &[f64], traits: usize, perm_seed: u64) -> Matrix {
    let n = y.len();
    let t = traits.max(1);
    let mut ys = Matrix::zeros(n, t);
    ys.col_mut(0).copy_from_slice(y);
    for k in 1..t {
        let col = ys.col_mut(k);
        col.copy_from_slice(y);
        let mut rng =
            XorShift::new(perm_seed ^ (k as u64).wrapping_mul(0xA24B_AED4_963E_E407));
        for i in (1..n).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            col.swap(i, j);
        }
    }
    ys
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::problem::{Dims, Problem};
    use crate::linalg::{gemm, gemv_n};

    fn small_problem() -> Problem {
        Problem::synthetic(Dims::new(32, 3, 4).unwrap(), 11).unwrap()
    }

    #[test]
    fn preprocess_invariants() {
        let p = small_problem();
        let pre = preprocess(&p.m, &p.xl, &p.y, 8).unwrap();
        assert_eq!(pre.traits(), 1);
        assert_eq!(pre.n(), 32);

        // L L^T == M
        let mut rec = Matrix::zeros(32, 32);
        gemm(1.0, &pre.l, &pre.l.transpose(), 0.0, &mut rec).unwrap();
        assert!(rec.max_abs_diff(&p.m) < 1e-9);

        // L X̃_L == X_L (trsm correctness)
        for j in 0..p.xl.cols() {
            let lx = gemv_n(&pre.l, pre.xl_t.col(j)).unwrap();
            for i in 0..32 {
                assert!((lx[i] - p.xl.get(i, j)).abs() < 1e-9);
            }
        }

        // L ỹ == y
        let ly = gemv_n(&pre.l, pre.y_t.col(0)).unwrap();
        for (a, b) in ly.iter().zip(&p.y) {
            assert!((a - b).abs() < 1e-9);
        }

        // S_TL symmetric pl×pl, r̃_T pl×1
        assert_eq!(pre.stl.rows(), 3);
        assert_eq!(pre.rtop.rows(), 3);
        assert_eq!(pre.rtop.cols(), 1);

        // Cached transpose is exactly X̃_L^T.
        assert_eq!(pre.xl_tt, pre.xl_t.transpose());

        // dinv present with the requested block size
        let dinv = pre.dinv.as_ref().unwrap();
        assert_eq!(dinv.rows(), 8);
        assert_eq!(dinv.cols(), 8 * 4); // ceil(32/8) = 4 blocks
        assert_eq!(pre.dinv_nb, 8);
    }

    #[test]
    fn preprocess_skips_dinv_when_nb_zero() {
        let p = small_problem();
        let pre = preprocess(&p.m, &p.xl, &p.y, 0).unwrap();
        assert!(pre.dinv.is_none());
    }

    #[test]
    fn preprocess_rejects_indefinite_m() {
        let p = small_problem();
        let mut bad = p.m.clone();
        bad.set(0, 0, -5.0);
        assert!(preprocess(&bad, &p.xl, &p.y, 0).is_err());
    }

    #[test]
    fn batched_columns_match_independent_single_trait_preprocess() {
        // The bit-identity contract at the preprocess layer: column k of a
        // batched study equals an independent single-trait study on y_k.
        let p = small_problem();
        let ys = phenotype_batch(&p.y, 4, 7);
        let multi = preprocess_multi(&p.m, &p.xl, &ys, 8).unwrap();
        assert_eq!(multi.traits(), 4);
        for k in 0..4 {
            let single = preprocess(&p.m, &p.xl, ys.col(k), 8).unwrap();
            assert_eq!(multi.y_t.col(k), single.y_t.col(0), "trait {k}");
            assert_eq!(multi.rtop.col(k), single.rtop.col(0), "trait {k}");
            assert_eq!(multi.yty[k], single.yty[0], "trait {k}");
            // Trait-independent products are untouched by batching.
            assert_eq!(multi.stl, single.stl);
            assert_eq!(multi.xl_tt, single.xl_tt);
        }
    }

    #[test]
    fn phenotype_batch_is_seeded_and_prefix_stable() {
        let p = small_problem();
        let a = phenotype_batch(&p.y, 5, 42);
        let b = phenotype_batch(&p.y, 5, 42);
        assert_eq!(a, b, "same seed must reproduce the batch");
        let c = phenotype_batch(&p.y, 5, 43);
        assert_ne!(a.col(1), c.col(1), "different seed must shuffle differently");
        // Column k depends on (y, seed, k) only — not on t.
        let wide = phenotype_batch(&p.y, 8, 42);
        for k in 0..5 {
            assert_eq!(a.col(k), wide.col(k), "column {k} changed when t grew");
        }
        // Column 0 is the phenotype itself; shuffles are permutations.
        assert_eq!(a.col(0), &p.y[..]);
        for k in 1..5 {
            let mut orig = p.y.clone();
            let mut perm = a.col(k).to_vec();
            orig.sort_by(f64::total_cmp);
            perm.sort_by(f64::total_cmp);
            assert_eq!(orig, perm, "column {k} is not a permutation");
            assert_ne!(a.col(k), a.col(0), "column {k} left unshuffled");
        }
    }

    #[test]
    fn preprocess_multi_rejects_bad_shapes() {
        let p = small_problem();
        assert!(preprocess_multi(&p.m, &p.xl, &Matrix::zeros(32, 0), 0).is_err());
        assert!(preprocess_multi(&p.m, &p.xl, &Matrix::zeros(31, 2), 0).is_err());
    }
}
