//! Preprocessing — Listing 1.1 lines 1–5 (plus the inverted diagonal
//! blocks of `L` the accelerator trsm kernel consumes).
//!
//! Runs once per study, cost `O(n^3)`; the paper measures it "in the order
//! of seconds" and excludes it from the streaming timings. Everything the
//! per-block hot path needs is captured in [`Preprocessed`].

use crate::error::Result;
use crate::linalg::{
    gemv_t, potrf, potrf_invert_diag_blocks, syrk_t_pretransposed, trsm_lower_left, trsv_lower,
    Matrix,
};

/// Everything the streaming loop needs, computed once.
#[derive(Debug, Clone)]
pub struct Preprocessed {
    /// Cholesky factor: `L L^T = M` (lower).
    pub l: Matrix,
    /// `X̃_L = L^-1 X_L` (n × pl).
    pub xl_t: Matrix,
    /// `X̃_L^T` (pl × n) — precomputed so the per-block reduction
    /// `G = X̃_L^T X̃_b` never re-transposes (or re-allocates) in the
    /// steady state.
    pub xl_tt: Matrix,
    /// `ỹ = L^-1 y`.
    pub y_t: Vec<f64>,
    /// `S_TL = X̃_L^T X̃_L` (pl × pl).
    pub stl: Matrix,
    /// `r̃_T = X̃_L^T ỹ` (pl).
    pub rtop: Vec<f64>,
    /// Inverted `nb×nb` diagonal blocks of `L`, side by side (nb × nb·ceil(n/nb)).
    /// Consumed by the L1 Pallas trsm kernel; `None` when running CPU-only.
    pub dinv: Option<Matrix>,
    /// Diagonal block size used for `dinv`.
    pub dinv_nb: usize,
    /// `ỹ·ỹ` — precomputed for the per-SNP residual variance (assoc stats).
    pub yty: f64,
}

/// Run the preprocessing over `(M, X_L, y)`.
///
/// `dinv_nb` — diagonal block size for the accelerator trsm formulation;
/// pass 0 to skip computing `dinv` (CPU-only paths).
pub fn preprocess(m: &Matrix, xl: &Matrix, y: &[f64], dinv_nb: usize) -> Result<Preprocessed> {
    let l = potrf(m)?; // L ← potrf M
    let mut xl_t = xl.clone();
    trsm_lower_left(&l, &mut xl_t)?; // X̃_L ← trsm L, X_L
    let mut y_t = y.to_vec();
    trsv_lower(&l, &mut y_t)?; // ỹ ← trsv L, y
    let rtop = gemv_t(&xl_t, &y_t)?; // r̃_T ← gemv X̃_L, ỹ
    let xl_tt = xl_t.transpose(); // cached once: syrk below + per-block G reductions
    let stl = syrk_t_pretransposed(&xl_tt, &xl_t); // S_TL ← syrk X̃_L
    let dinv = if dinv_nb > 0 { Some(potrf_invert_diag_blocks(&l, dinv_nb)?) } else { None };
    let yty = crate::linalg::dot(&y_t, &y_t);
    Ok(Preprocessed { l, xl_t, xl_tt, y_t, stl, rtop, dinv, dinv_nb, yty })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::problem::{Dims, Problem};
    use crate::linalg::{gemm, gemv_n};

    fn small_problem() -> Problem {
        Problem::synthetic(Dims::new(32, 3, 4).unwrap(), 11).unwrap()
    }

    #[test]
    fn preprocess_invariants() {
        let p = small_problem();
        let pre = preprocess(&p.m, &p.xl, &p.y, 8).unwrap();

        // L L^T == M
        let mut rec = Matrix::zeros(32, 32);
        gemm(1.0, &pre.l, &pre.l.transpose(), 0.0, &mut rec).unwrap();
        assert!(rec.max_abs_diff(&p.m) < 1e-9);

        // L X̃_L == X_L (trsm correctness)
        for j in 0..p.xl.cols() {
            let lx = gemv_n(&pre.l, pre.xl_t.col(j)).unwrap();
            for i in 0..32 {
                assert!((lx[i] - p.xl.get(i, j)).abs() < 1e-9);
            }
        }

        // L ỹ == y
        let ly = gemv_n(&pre.l, &pre.y_t).unwrap();
        for (a, b) in ly.iter().zip(&p.y) {
            assert!((a - b).abs() < 1e-9);
        }

        // S_TL symmetric pl×pl, r̃_T length pl
        assert_eq!(pre.stl.rows(), 3);
        assert_eq!(pre.rtop.len(), 3);

        // Cached transpose is exactly X̃_L^T.
        assert_eq!(pre.xl_tt, pre.xl_t.transpose());

        // dinv present with the requested block size
        let dinv = pre.dinv.as_ref().unwrap();
        assert_eq!(dinv.rows(), 8);
        assert_eq!(dinv.cols(), 8 * 4); // ceil(32/8) = 4 blocks
        assert_eq!(pre.dinv_nb, 8);
    }

    #[test]
    fn preprocess_skips_dinv_when_nb_zero() {
        let p = small_problem();
        let pre = preprocess(&p.m, &p.xl, &p.y, 0).unwrap();
        assert!(pre.dinv.is_none());
    }

    #[test]
    fn preprocess_rejects_indefinite_m() {
        let p = small_problem();
        let mut bad = p.m.clone();
        bad.set(0, 0, -5.0);
        assert!(preprocess(&bad, &p.xl, &p.y, 0).is_err());
    }
}
