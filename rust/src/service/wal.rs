//! The service write-ahead log — durable job lifecycle state that
//! outlives the `serve` process.
//!
//! The per-run progress journal (`coordinator::journal`) makes one
//! *run* crash-restartable; this WAL makes the *service* restartable:
//! every job lifecycle transition (submitted → admitted → streaming →
//! done/failed/cancelled, plus coalesced riders) is appended as one
//! checksummed record before the scheduler acts on it. On startup
//! `serve` replays the WAL and reconciles: jobs with a terminal record
//! are not re-run, jobs that were queued re-enter the queue, and jobs
//! that were *streaming* are resubmitted with `resume` set so their v4
//! journal picks up at the last committed segment — a `kill -9`
//! mid-segment costs at most one replayed segment, never a restart
//! from zero.
//!
//! Zero-cost-when-off: the WAL only exists when the service configures
//! a path (`[service] wal`, or implicitly `<spool>/service.wal`); a
//! WAL-less `serve` carries an `Option::None` and no code here runs.
//!
//! Format — line-oriented, tab-separated, one record per line:
//!
//! ```text
//! <seq> \t <event> \t <spec-hash:016x> \t <name> \t <journal> \t <fnv64:016x> \n
//! ```
//!
//! The trailing field is an FNV-1a-64 checksum of everything before
//! it; replay accepts the longest prefix of intact lines and truncates
//! the rest away (a torn tail is exactly what a power cut mid-append
//! leaves). `spec-hash` is a canonical hash of the job's pipeline-
//! shaping spec, which is how a restart matches WAL records against
//! the jobs it re-discovers from config sections and spool files —
//! the service never persists full specs, because config and spool are
//! already the durable spec store.

use crate::coordinator::journal::sync_parent_dir;
use crate::error::{Error, Result};
use crate::service::queue::JobSpec;
use crate::storage::fault::{self, WalFault};
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One job lifecycle transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WalEvent {
    /// The job entered the queue.
    Submitted,
    /// Admission control accepted it (budget charged, lane assigned).
    Admitted,
    /// It was answered by riding a compatible job's streaming pass.
    Coalesced,
    /// Its engine run started — a journal now tracks its progress.
    Streaming,
    Done,
    Failed,
    Cancelled,
    /// Clean shutdown marker appended by [`Wal::seal`]; not a job state.
    Sealed,
}

impl WalEvent {
    pub fn as_str(self) -> &'static str {
        match self {
            WalEvent::Submitted => "submitted",
            WalEvent::Admitted => "admitted",
            WalEvent::Coalesced => "coalesced",
            WalEvent::Streaming => "streaming",
            WalEvent::Done => "done",
            WalEvent::Failed => "failed",
            WalEvent::Cancelled => "cancelled",
            WalEvent::Sealed => "sealed",
        }
    }

    fn parse(s: &str) -> Option<WalEvent> {
        Some(match s {
            "submitted" => WalEvent::Submitted,
            "admitted" => WalEvent::Admitted,
            "coalesced" => WalEvent::Coalesced,
            "streaming" => WalEvent::Streaming,
            "done" => WalEvent::Done,
            "failed" => WalEvent::Failed,
            "cancelled" => WalEvent::Cancelled,
            "sealed" => WalEvent::Sealed,
            _ => return None,
        })
    }

    /// Whether this event ends a job's lifecycle (no replay needed).
    pub fn is_terminal(self) -> bool {
        matches!(self, WalEvent::Done | WalEvent::Failed | WalEvent::Cancelled)
    }
}

/// One replayed record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WalRecord {
    pub seq: u64,
    pub event: WalEvent,
    /// Canonical spec hash — the replay key (see [`spec_hash`]).
    pub spec_hash: u64,
    pub name: String,
    /// Progress-journal path recorded at streaming time (`-` = none).
    pub journal: String,
}

/// FNV-1a-64 over raw bytes (the record checksum — same family as the
/// block checksums in `storage::fault`, byte-granular here because WAL
/// records are text).
fn fnv64(data: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for b in data {
        h ^= u64::from(*b);
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Canonical hash of every spec field that shapes the work a job does.
/// Two submissions hash equal exactly when a WAL record for one is an
/// authoritative statement about the other: same name, same dataset,
/// same pipeline knobs, same trait batch. Runtime bookkeeping
/// (`profile_attached`, pins) is deliberately excluded — a first-
/// contact tune must not orphan the WAL history of the job it tuned.
/// Scheduling *policy* (`deadline_secs`, `priority`) is excluded too:
/// a deadline-cancelled job resubmitted without the deadline is the
/// same work, and must match its `cancelled` record so the next serve
/// resumes the journal instead of streaming from scratch.
pub fn spec_hash(spec: &JobSpec) -> u64 {
    let canon = format!(
        "{}|{}|{}|{}|{}|{}|{:?}|{:?}|{}|{}|{}|{}|{}|{}",
        spec.name,
        spec.dataset.display(),
        spec.block,
        spec.ngpus,
        spec.host_buffers,
        spec.device_buffers,
        spec.mode,
        spec.backend,
        spec.threads,
        spec.lane_threads,
        spec.adapt,
        spec.adapt_every,
        spec.traits,
        spec.perm_seed,
    );
    fnv64(canon.as_bytes())
}

/// Collapse a replayed record stream to each job's *latest* lifecycle
/// event (seal markers skipped). Records arrive in append order, so
/// the last write wins.
pub fn latest_states(records: &[WalRecord]) -> HashMap<u64, WalEvent> {
    let mut out = HashMap::new();
    for r in records {
        if r.event != WalEvent::Sealed {
            out.insert(r.spec_hash, r.event);
        }
    }
    out
}

/// An open WAL, positioned for appending.
pub struct Wal {
    file: Mutex<std::fs::File>,
    /// Next sequence number to append.
    seq: AtomicU64,
    path: PathBuf,
}

impl Wal {
    /// Open (or create) the WAL at `path`, replaying whatever survives:
    /// the longest prefix of checksum-intact lines is returned and the
    /// torn/corrupt tail is truncated away, so future appends start on
    /// a clean line boundary. Appends continue the replayed sequence.
    pub fn open(path: &Path) -> Result<(Wal, Vec<WalRecord>)> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| Error::io(format!("creating WAL directory {}", dir.display()), e))?;
            }
        }
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(Error::io(format!("reading WAL {}", path.display()), e)),
        };
        let (records, valid_bytes) = parse(&bytes);
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .map_err(|e| Error::io(format!("opening WAL {}", path.display()), e))?;
        if valid_bytes as u64 != file.metadata().map_err(|e| Error::io("WAL metadata", e))?.len() {
            file.set_len(valid_bytes as u64)
                .map_err(|e| Error::io("truncating torn WAL tail", e))?;
            file.sync_data().map_err(|e| Error::io("syncing truncated WAL", e))?;
        }
        // A freshly created WAL gets the same durability treatment as
        // the progress journal: the directory entry must survive a
        // power cut or a restart finds bytes with no name.
        sync_parent_dir(path)?;
        let next = records.last().map(|r| r.seq + 1).unwrap_or(0);
        Ok((
            Wal { file: Mutex::new(file), seq: AtomicU64::new(next), path: path.to_path_buf() },
            records,
        ))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one lifecycle record and make it durable (`fdatasync`).
    /// Transitions are per-job, not per-block — a handful of syncs per
    /// job is noise next to the stream it describes.
    pub fn append(
        &self,
        event: WalEvent,
        spec_hash: u64,
        name: &str,
        journal: Option<&Path>,
    ) -> Result<()> {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let jn = journal.map(|p| p.display().to_string()).unwrap_or_else(|| "-".to_string());
        let body = format!(
            "{seq}\t{}\t{spec_hash:016x}\t{}\t{}",
            event.as_str(),
            sanitize(name),
            sanitize(&jn)
        );
        let line = format!("{body}\t{:016x}\n", fnv64(body.as_bytes()));
        let mut file = self.file.lock().unwrap_or_else(|e| e.into_inner());
        use std::io::Seek;
        file.seek(std::io::SeekFrom::End(0)).map_err(|e| Error::io("seeking WAL", e))?;
        // Chaos harness: a torn append leaves a durable partial line
        // (power cut mid-write); a crash fault stops before any byte
        // lands (the window between the journal's state and the WAL's
        // record of it). Both report failure — the scheduler treats a
        // WAL write error as fatal, exactly like the real crash.
        match fault::wal_append_fault(line.len()) {
            Some(WalFault::Torn(k)) => {
                file.write_all(&line.as_bytes()[..k])
                    .map_err(|e| Error::io("appending WAL", e))?;
                file.sync_data().map_err(|e| Error::io("syncing torn WAL append", e))?;
                return Err(Error::io(
                    "WAL append torn mid-record (injected crash)",
                    std::io::Error::new(std::io::ErrorKind::WriteZero, "partial record"),
                ));
            }
            Some(WalFault::Crash) => {
                return Err(Error::io(
                    "crashed before WAL append (injected)",
                    std::io::Error::new(std::io::ErrorKind::Interrupted, "injected crash"),
                ));
            }
            None => {}
        }
        file.write_all(line.as_bytes()).map_err(|e| Error::io("appending WAL", e))?;
        file.sync_data().map_err(|e| Error::io("syncing WAL append", e))
    }

    /// Append the clean-shutdown marker and sync everything, including
    /// the directory entry. A sealed WAL is the drain path's receipt:
    /// every record before the marker was durable when the process
    /// exited 0.
    pub fn seal(&self) -> Result<()> {
        self.append(WalEvent::Sealed, 0, "-", None)?;
        sync_parent_dir(&self.path)
    }
}

/// Replace the record's two structural characters so a hostile job
/// name cannot forge record boundaries.
fn sanitize(s: &str) -> String {
    if s.contains(['\t', '\n']) {
        s.replace(['\t', '\n'], "_")
    } else {
        s.to_string()
    }
}

/// Parse the longest valid prefix: returns the records plus the byte
/// length they occupy (the truncation point for everything after).
fn parse(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
    let mut records = Vec::new();
    let mut consumed = 0usize;
    let text = String::from_utf8_lossy(bytes);
    for line in text.split_inclusive('\n') {
        let Some(stripped) = line.strip_suffix('\n') else { break }; // torn tail
        let Some(rec) = parse_line(stripped) else { break };
        // Sequence numbers must ascend — a stale line block-copied into
        // the middle would otherwise replay out of order.
        if records.last().is_some_and(|p: &WalRecord| rec.seq <= p.seq) {
            break;
        }
        consumed += line.len();
        records.push(rec);
    }
    (records, consumed)
}

fn parse_line(line: &str) -> Option<WalRecord> {
    let (body, crc_hex) = line.rsplit_once('\t')?;
    let crc = u64::from_str_radix(crc_hex, 16).ok()?;
    if fnv64(body.as_bytes()) != crc {
        return None;
    }
    let mut f = body.splitn(5, '\t');
    let seq = f.next()?.parse().ok()?;
    let event = WalEvent::parse(f.next()?)?;
    let spec_hash = u64::from_str_radix(f.next()?, 16).ok()?;
    let name = f.next()?.to_string();
    let journal = f.next()?.to_string();
    Some(WalRecord { seq, event, spec_hash, name, journal })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("cugwas_wal_{}_{tag}.wal", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn append_replay_roundtrip_continues_the_sequence() {
        let p = tmpfile("rt");
        let (wal, replayed) = Wal::open(&p).unwrap();
        assert!(replayed.is_empty());
        wal.append(WalEvent::Submitted, 0xabc, "jobA", None).unwrap();
        wal.append(WalEvent::Streaming, 0xabc, "jobA", Some(Path::new("/d/r.progress"))).unwrap();
        drop(wal);
        let (wal, replayed) = Wal::open(&p).unwrap();
        assert_eq!(replayed.len(), 2);
        assert_eq!(replayed[0].event, WalEvent::Submitted);
        assert_eq!(replayed[1].event, WalEvent::Streaming);
        assert_eq!(replayed[1].spec_hash, 0xabc);
        assert_eq!(replayed[1].journal, "/d/r.progress");
        wal.append(WalEvent::Done, 0xabc, "jobA", None).unwrap();
        drop(wal);
        let (_w, replayed) = Wal::open(&p).unwrap();
        assert_eq!(replayed.len(), 3, "append after reopen stays aligned");
        assert_eq!(replayed[2].seq, 2, "sequence continues across reopen");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn torn_tail_is_truncated_and_appends_recover() {
        let p = tmpfile("torn");
        let (wal, _) = Wal::open(&p).unwrap();
        wal.append(WalEvent::Submitted, 1, "a", None).unwrap();
        drop(wal);
        // A power cut mid-append: half a line, no newline.
        let mut bytes = std::fs::read(&p).unwrap();
        let keep = bytes.len();
        bytes.extend_from_slice(b"9\tdone\tdeadbeef");
        std::fs::write(&p, &bytes).unwrap();
        let (wal, replayed) = Wal::open(&p).unwrap();
        assert_eq!(replayed.len(), 1, "torn tail must not replay");
        assert_eq!(std::fs::metadata(&p).unwrap().len(), keep as u64, "tail truncated");
        wal.append(WalEvent::Done, 1, "a", None).unwrap();
        drop(wal);
        let (_w, replayed) = Wal::open(&p).unwrap();
        assert_eq!(replayed.len(), 2);
        assert_eq!(replayed[1].event, WalEvent::Done);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn corrupt_checksum_stops_replay_at_the_bad_line() {
        let p = tmpfile("crc");
        let (wal, _) = Wal::open(&p).unwrap();
        wal.append(WalEvent::Submitted, 1, "a", None).unwrap();
        wal.append(WalEvent::Done, 1, "a", None).unwrap();
        drop(wal);
        // Flip one byte inside the *first* record's name field: both
        // lines are whole, but line 1's checksum no longer matches, so
        // nothing (including the intact line after it) may be trusted.
        let mut bytes = std::fs::read(&p).unwrap();
        let i = bytes.iter().position(|&b| b == b'a').unwrap();
        bytes[i] = b'z';
        std::fs::write(&p, &bytes).unwrap();
        let (_w, replayed) = Wal::open(&p).unwrap();
        assert!(replayed.is_empty(), "corruption invalidates the line and its tail");
        assert_eq!(std::fs::metadata(&p).unwrap().len(), 0);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn latest_states_keeps_the_last_event_per_job() {
        let p = tmpfile("latest");
        let (wal, _) = Wal::open(&p).unwrap();
        wal.append(WalEvent::Submitted, 1, "a", None).unwrap();
        wal.append(WalEvent::Submitted, 2, "b", None).unwrap();
        wal.append(WalEvent::Streaming, 1, "a", None).unwrap();
        wal.append(WalEvent::Done, 2, "b", None).unwrap();
        wal.seal().unwrap();
        drop(wal);
        let (_w, replayed) = Wal::open(&p).unwrap();
        let states = latest_states(&replayed);
        assert_eq!(states.get(&1), Some(&WalEvent::Streaming));
        assert_eq!(states.get(&2), Some(&WalEvent::Done));
        assert_eq!(states.len(), 2, "the seal marker is not a job");
        assert_eq!(replayed.last().unwrap().event, WalEvent::Sealed);
        assert!(WalEvent::Done.is_terminal() && WalEvent::Cancelled.is_terminal());
        assert!(!WalEvent::Streaming.is_terminal());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn spec_hash_tracks_work_shaping_fields_only() {
        let a = JobSpec::new("j", "/data/s1");
        let mut b = JobSpec::new("j", "/data/s1");
        assert_eq!(spec_hash(&a), spec_hash(&b));
        b.profile_attached = true; // bookkeeping: same identity
        assert_eq!(spec_hash(&a), spec_hash(&b));
        b.block = a.block * 2; // work-shaping: new identity
        assert_ne!(spec_hash(&a), spec_hash(&b));
        let mut c = JobSpec::new("j", "/data/s1");
        c.deadline_secs = 60; // scheduling policy: same identity —
        // dropping a deadline must not orphan the job's WAL history
        assert_eq!(spec_hash(&a), spec_hash(&c));
    }

    #[test]
    fn hostile_names_cannot_forge_record_boundaries() {
        let p = tmpfile("hostile");
        let (wal, _) = Wal::open(&p).unwrap();
        wal.append(WalEvent::Submitted, 7, "evil\tdone\tjob\n9", None).unwrap();
        drop(wal);
        let (_w, replayed) = Wal::open(&p).unwrap();
        assert_eq!(replayed.len(), 1);
        assert_eq!(replayed[0].event, WalEvent::Submitted);
        assert_eq!(replayed[0].name, "evil_done_job_9");
        std::fs::remove_file(&p).unwrap();
    }
}
