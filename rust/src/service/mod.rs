//! The multi-study streaming service — the layer that turns the batch
//! tool into a server.
//!
//! The paper's pipeline sustains one study at the disk's peak; the
//! service multiplexes *many* studies over that machinery and amortizes
//! disk reads across them through the shared
//! [`BlockCache`](crate::storage::BlockCache):
//!
//! * [`queue`] — [`JobQueue`]: priority + FIFO ordering, admission
//!   under an explicit host-memory budget, per-job lifecycle states.
//! * [`scheduler`] — [`serve`]: fixed worker lanes driving
//!   `coordinator::run`, a watched spool directory, the dispatch loop.
//! * [`report`] — [`JobReport`] / [`ServiceReport`]: per-job phase
//!   metrics and aggregate throughput, printed by `cugwas serve`.
//!
//! Configuration comes from the `[service]` and `[job.*]` sections of a
//! TOML file (see [`crate::config::ServiceConfig`]).

pub mod queue;
pub mod report;
pub mod scheduler;

pub use queue::{Job, JobQueue, JobSpec, JobState, KnobPins, Priority};
pub use report::{JobReport, ServiceReport};
pub use scheduler::serve;
