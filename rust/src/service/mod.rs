//! The multi-study streaming service — the layer that turns the batch
//! tool into a server.
//!
//! The paper's pipeline sustains one study at the disk's peak; the
//! service multiplexes *many* studies over that machinery and amortizes
//! disk reads across them through the shared
//! [`BlockCache`](crate::storage::BlockCache):
//!
//! * [`queue`] — [`JobQueue`]: priority + FIFO ordering, admission
//!   under an explicit host-memory budget, per-job lifecycle states.
//! * [`scheduler`] — [`serve`]: fixed worker lanes driving
//!   `coordinator::run`, a watched spool directory, the dispatch loop.
//! * [`report`] — [`JobReport`] / [`ServiceReport`]: per-job phase
//!   metrics and aggregate throughput, printed by `cugwas serve`.
//! * [`wal`] — [`Wal`]: the append-only, checksummed lifecycle log
//!   that makes `serve` crash-restartable (replayed on startup; torn
//!   tails truncated; sealed on clean exit).
//!
//! Configuration comes from the `[service]` and `[job.*]` sections of a
//! TOML file (see [`crate::config::ServiceConfig`]).

pub mod queue;
pub mod report;
pub mod scheduler;
pub mod wal;

pub use queue::{Job, JobQueue, JobSpec, JobState, KnobPins, Priority};
pub use report::{JobReport, ServiceReport};
pub use scheduler::{drain_requested, install_drain_on_ctrl_c, request_drain, serve};
pub use wal::{Wal, WalEvent, WalRecord};
