//! Job queue: priority + FIFO ordering, admission under a host-memory
//! budget, and per-job lifecycle states.
//!
//! A [`Job`] is one study to stream through the coordinator. The queue
//! orders runnable work by **priority (higher first), then submission
//! order (FIFO within a priority)** — the classic batch-scheduler
//! discipline. Admission is *first-fit under constraints*: the best-
//! ranked job whose estimated host footprint fits the remaining memory
//! budget and whose dataset is not already being streamed is admitted;
//! a job that does not fit right now is skipped, not cancelled, and is
//! reconsidered every time capacity frees up.
//!
//! The dataset exclusivity rule exists because the pipeline writes its
//! results to `<dataset>/r.xrd` — two concurrent jobs on one dataset
//! would race on that file. Serializing them is also exactly what makes
//! the shared [`BlockCache`](crate::storage::BlockCache) pay: the first
//! job faults the blocks in, the follow-ups stream from RAM.

use crate::coordinator::{BackendKind, OffloadMode};
use crate::storage::Throttle;
use std::collections::HashSet;
use std::path::PathBuf;

/// Scheduling priority: higher runs first; FIFO within equal priority.
pub type Priority = i32;

/// Which pipeline knobs a job's config/spool file set *explicitly*.
/// The service's tune-on-first-contact fills only unpinned knobs from a
/// dataset's tuned profile — an operator's explicit key always wins,
/// the same precedence `run --profile` gives CLI flags.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KnobPins {
    pub block: bool,
    pub ngpus: bool,
    pub host_buffers: bool,
    pub device_buffers: bool,
    pub threads: bool,
    pub lane_threads: bool,
}

/// Everything one queued study needs from the pipeline.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Display / report name (config section name or spool file stem).
    pub name: String,
    /// Dataset directory (from `storage::generate`).
    pub dataset: PathBuf,
    /// SNP columns per pipeline iteration.
    pub block: usize,
    /// Device lanes.
    pub ngpus: usize,
    /// Host ring size (paper: 3).
    pub host_buffers: usize,
    /// Device buffers per lane (paper: 2).
    pub device_buffers: usize,
    pub mode: OffloadMode,
    pub backend: BackendKind,
    pub priority: Priority,
    pub read_throttle: Option<Throttle>,
    pub write_throttle: Option<Throttle>,
    /// Compute threads for this job's pipeline. 0 = take the service's
    /// per-worker share (total threads / workers) so concurrent jobs
    /// never oversubscribe the host.
    pub threads: usize,
    /// Kernel threads per lane (0 = auto split).
    pub lane_threads: usize,
    /// Adaptive block-size re-planning for this job.
    pub adapt: bool,
    /// Blocks per adaptive segment.
    pub adapt_every: usize,
    /// Tuned-profile prediction of this job's wall seconds, if one was
    /// attached. Within a priority, admission runs predicted-shorter
    /// jobs first (shortest-job-first); unprofiled jobs keep FIFO order
    /// after them.
    pub predicted_secs: Option<f64>,
    /// Phenotype batch width: solve this many traits (or 1 + K
    /// permutations) in one streaming pass. Part of the engine-reuse
    /// and job-coalescing identity — jobs only merge onto one pass when
    /// their widths agree.
    pub traits: usize,
    /// RNG seed for permutation columns (column 0 is always the
    /// observed phenotype; the seed only matters when `traits > 1`).
    pub perm_seed: u64,
    /// Wall-clock deadline in seconds from the moment the job starts
    /// streaming (0 = none). A job past its deadline is checkpointed at
    /// the next segment boundary and reported cancelled — its progress
    /// journal survives, so a resubmission resumes rather than restarts.
    pub deadline_secs: u64,
    /// Knobs the operator set explicitly (see [`KnobPins`]).
    pub pins: KnobPins,
    /// A profile has already been applied to this spec (an explicit
    /// `profile` key, or the service's first-contact tuner). Guards
    /// against the first-contact tuner overriding an operator-chosen
    /// profile whose `predicted_secs` happens to be absent.
    pub profile_attached: bool,
}

impl JobSpec {
    /// Paper-topology defaults: block 256, 1 lane, 3 host buffers,
    /// 2 device buffers, trsm offload, native backend, priority 0.
    pub fn new(name: impl Into<String>, dataset: impl Into<PathBuf>) -> JobSpec {
        JobSpec {
            name: name.into(),
            dataset: dataset.into(),
            block: 256,
            ngpus: 1,
            host_buffers: 3,
            device_buffers: 2,
            mode: OffloadMode::Trsm,
            backend: BackendKind::Native,
            priority: 0,
            read_throttle: None,
            write_throttle: None,
            threads: 0,
            lane_threads: 0,
            adapt: false,
            adapt_every: 16,
            traits: 1,
            perm_seed: 0,
            deadline_secs: 0,
            predicted_secs: None,
            pins: KnobPins::default(),
            profile_attached: false,
        }
    }

    /// Fill the unpinned pipeline knobs from a tuned profile and attach
    /// its DES prediction for shortest-job-first admission. Pinned
    /// knobs (explicit config keys) are left untouched.
    pub fn apply_profile(&mut self, tuned: &crate::tune::TunedProfile) {
        if !self.pins.block {
            self.block = tuned.block;
        }
        if !self.pins.ngpus {
            self.ngpus = tuned.ngpus;
        }
        if !self.pins.host_buffers {
            self.host_buffers = tuned.host_buffers;
        }
        if !self.pins.device_buffers {
            self.device_buffers = tuned.device_buffers;
        }
        if !self.pins.threads {
            self.threads = tuned.threads;
        }
        if !self.pins.lane_threads {
            self.lane_threads = tuned.lane_threads;
        }
        // The merged knobs must keep the block dividing across the
        // lanes. An unpinned block rounds down to the lane multiple; a
        // pinned block wins over a profile-supplied lane count instead
        // (dropping to one lane rather than failing validation later).
        if self.ngpus > 0 && self.block % self.ngpus != 0 {
            if !self.pins.block {
                self.block = ((self.block / self.ngpus) * self.ngpus).max(self.ngpus);
            } else if !self.pins.ngpus {
                self.ngpus = 1;
            }
        }
        self.predicted_secs = tuned.predicted();
        self.profile_attached = true;
    }

    /// Estimated steady-state host bytes for this job given the study's
    /// sample count `n` and result rows `p`: the slab ring the reads
    /// land in (`host_buffers` staged windows plus up to
    /// `device_buffers` windows kept resident by in-flight lane views —
    /// the ledger charges slabs, not the per-lane staging copies the
    /// zero-copy plane eliminated), the result ring, and the dense
    /// sidecars (kinship dominates at n²). Deliberately a slight
    /// over-estimate — admission errs toward not thrashing.
    /// Whether this spec would stream the *identical* pipeline as
    /// `other` over the same dataset — the gate for job coalescing.
    /// Every knob that shapes the pass (geometry, offload mode,
    /// backend, throttles, thread budget, adaptivity, and the phenotype
    /// batch identity) must agree; a job that pins even one knob
    /// differently (say, a different `block`) keeps its own pass.
    /// Priority and name are scheduling/reporting facts, not pipeline
    /// facts, so they do not participate.
    pub fn coalesces_with(&self, other: &JobSpec) -> bool {
        let throttle_eq = |a: &Option<Throttle>, b: &Option<Throttle>| match (a, b) {
            (None, None) => true,
            (Some(x), Some(y)) => x.bytes_per_sec == y.bytes_per_sec,
            _ => false,
        };
        let backend_eq = match (&self.backend, &other.backend) {
            (BackendKind::Native, BackendKind::Native) => true,
            (BackendKind::Pjrt { artifacts: a }, BackendKind::Pjrt { artifacts: b }) => a == b,
            _ => false,
        };
        self.dataset == other.dataset
            && self.block == other.block
            && self.ngpus == other.ngpus
            && self.host_buffers == other.host_buffers
            && self.device_buffers == other.device_buffers
            && self.mode == other.mode
            && backend_eq
            && throttle_eq(&self.read_throttle, &other.read_throttle)
            && throttle_eq(&self.write_throttle, &other.write_throttle)
            && self.threads == other.threads
            && self.lane_threads == other.lane_threads
            && self.adapt == other.adapt
            && self.adapt_every == other.adapt_every
            && self.traits == other.traits
            && self.perm_seed == other.perm_seed
            // Deadlines cancel a *pass*, not a rider: a rider with a
            // tighter deadline than its leader would be cancelled late
            // (or drag its leader down). Only identical deadlines merge.
            && self.deadline_secs == other.deadline_secs
    }

    pub fn host_bytes(&self, n: usize, p: usize) -> u64 {
        // A t-trait batch widens the result rows (p·t per SNP) and the
        // phenotype sidecar (n×t), but not the genotype slab ring.
        let t = self.traits.max(1);
        let slab_ring = (self.host_buffers + self.device_buffers) * n * self.block;
        let result_ring = self.host_buffers * p * t * self.block;
        let sidecars = n * n + n * p + n * t;
        (8 * (slab_ring + result_ring + sidecars)) as u64
    }
}

/// Lifecycle of a job inside the service.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Submitted, waiting for admission.
    Queued,
    /// Admitted under the memory budget, handed to a worker lane.
    Admitted,
    /// A worker lane is streaming it through the coordinator.
    Streaming,
    /// Finished successfully; results are on disk.
    Done,
    /// Failed (admission impossible, dataset missing, or pipeline error).
    Failed,
    /// Stopped cooperatively at a segment boundary (drain, deadline, or
    /// an explicit cancel). Not a failure: the job's progress journal
    /// was checkpointed, so resubmitting it resumes where it stopped.
    Cancelled,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Admitted => "admitted",
            JobState::Streaming => "streaming",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }
}

/// A submitted job with its queue bookkeeping.
#[derive(Debug, Clone)]
pub struct Job {
    /// Monotone submission id — the FIFO tiebreaker.
    pub id: u64,
    pub spec: JobSpec,
    pub state: JobState,
    /// Admission-time host-memory estimate (bytes).
    pub est_bytes: u64,
    /// Canonical dataset identity (for the one-job-per-dataset rule and
    /// the shared cache key).
    pub dataset_key: PathBuf,
    /// Resume from this job's progress journal instead of starting
    /// fresh. Set by WAL replay when a previous `serve` process died
    /// while the job was streaming.
    pub resume: bool,
}

/// The service's job queue (see module docs for the ordering rules).
#[derive(Debug, Default)]
pub struct JobQueue {
    jobs: Vec<Job>,
    next_id: u64,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    /// Submit a job; returns its id.
    pub fn submit(&mut self, spec: JobSpec, est_bytes: u64, dataset_key: PathBuf) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.jobs.push(Job {
            id,
            spec,
            state: JobState::Queued,
            est_bytes,
            dataset_key,
            resume: false,
        });
        id
    }

    /// Mark a queued job as a journal resume (WAL replay found it
    /// streaming when the previous process died).
    pub fn set_resume(&mut self, id: u64) {
        if let Some(j) = self.jobs.iter_mut().find(|j| j.id == id) {
            j.resume = true;
        }
    }

    /// Admit the next runnable job: highest priority first; within a
    /// priority, profiled jobs run shortest-predicted-first (the tuned
    /// profile's DES estimate), unprofiled jobs after them in FIFO
    /// order. Jobs that don't fit `budget_left` or whose dataset is in
    /// `busy_datasets` are skipped, not cancelled. The admitted job
    /// transitions `Queued → Admitted` and a copy is returned.
    pub fn admit_next(
        &mut self,
        budget_left: u64,
        busy_datasets: &HashSet<PathBuf>,
    ) -> Option<Job> {
        let idx = self
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| {
                j.state == JobState::Queued
                    && j.est_bytes <= budget_left
                    && !busy_datasets.contains(&j.dataset_key)
            })
            .max_by(|(_, a), (_, b)| {
                a.spec
                    .priority
                    .cmp(&b.spec.priority)
                    .then_with(|| {
                        // Shorter predicted duration ⇒ better ⇒ larger key.
                        let da = a.spec.predicted_secs.unwrap_or(f64::INFINITY);
                        let db = b.spec.predicted_secs.unwrap_or(f64::INFINITY);
                        db.partial_cmp(&da).unwrap_or(std::cmp::Ordering::Equal)
                    })
                    .then(b.id.cmp(&a.id))
            })
            .map(|(i, _)| i)?;
        self.jobs[idx].state = JobState::Admitted;
        Some(self.jobs[idx].clone())
    }

    /// Whether any queued job could be admitted under `budget_left`.
    /// Non-mutating twin of [`JobQueue::admit_next`]'s filter — the
    /// dispatcher uses it to decide whether evicting idle warm engines
    /// would actually unblock work (memory is the binding constraint)
    /// rather than churning caches on a dataset lock.
    pub fn would_admit(&self, budget_left: u64, busy_datasets: &HashSet<PathBuf>) -> bool {
        self.jobs.iter().any(|j| {
            j.state == JobState::Queued
                && j.est_bytes <= budget_left
                && !busy_datasets.contains(&j.dataset_key)
        })
    }

    /// Mark every queued job whose estimate exceeds the *total* budget as
    /// failed (it could never be admitted, even on an idle service) and
    /// return copies for reporting.
    pub fn fail_oversized(&mut self, total_budget: u64) -> Vec<Job> {
        let mut failed = Vec::new();
        for j in &mut self.jobs {
            if j.state == JobState::Queued && j.est_bytes > total_budget {
                j.state = JobState::Failed;
                failed.push(j.clone());
            }
        }
        failed
    }

    /// Pull every still-queued job that would stream the *identical*
    /// pipeline as `leader` over the same dataset (see
    /// [`JobSpec::coalesces_with`]) and mark it `Streaming`: the
    /// leader's single pass will answer them all, and the dispatcher
    /// mirrors the leader's report back onto each rider on completion.
    pub fn take_coalescable(&mut self, leader: &Job) -> Vec<Job> {
        let mut riders = Vec::new();
        for j in &mut self.jobs {
            if j.id != leader.id
                && j.state == JobState::Queued
                && j.dataset_key == leader.dataset_key
                && j.spec.coalesces_with(&leader.spec)
            {
                j.state = JobState::Streaming;
                riders.push(j.clone());
            }
        }
        riders
    }

    pub fn set_state(&mut self, id: u64, state: JobState) {
        if let Some(j) = self.jobs.iter_mut().find(|j| j.id == id) {
            j.state = state;
        }
    }

    pub fn get(&self, id: u64) -> Option<&Job> {
        self.jobs.iter().find(|j| j.id == id)
    }

    pub fn all(&self) -> &[Job] {
        &self.jobs
    }

    /// Jobs still waiting for admission.
    pub fn queued(&self) -> usize {
        self.jobs.iter().filter(|j| j.state == JobState::Queued).count()
    }

    /// No job is queued, admitted, or streaming — the service may exit.
    pub fn is_drained(&self) -> bool {
        self.jobs
            .iter()
            .all(|j| matches!(j.state, JobState::Done | JobState::Failed | JobState::Cancelled))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, priority: Priority) -> JobSpec {
        let mut s = JobSpec::new(name, format!("/data/{name}"));
        s.priority = priority;
        s
    }

    fn submit(q: &mut JobQueue, name: &str, priority: Priority, est: u64) -> u64 {
        let s = spec(name, priority);
        let key = s.dataset.clone();
        q.submit(s, est, key)
    }

    fn no_busy() -> HashSet<PathBuf> {
        HashSet::new()
    }

    #[test]
    fn priority_then_fifo_ordering() {
        let mut q = JobQueue::new();
        submit(&mut q, "low", 0, 10);
        submit(&mut q, "hi-first", 5, 10);
        submit(&mut q, "hi-second", 5, 10);
        let order: Vec<String> = std::iter::from_fn(|| q.admit_next(u64::MAX, &no_busy()))
            .map(|j| j.spec.name)
            .collect();
        assert_eq!(order, ["hi-first", "hi-second", "low"]);
        assert_eq!(q.queued(), 0);
    }

    #[test]
    fn profiled_jobs_run_shortest_first_within_a_priority() {
        let mut q = JobQueue::new();
        // Same priority: two profiled jobs (out of order), two unprofiled.
        let mut slow = spec("slow", 1);
        slow.predicted_secs = Some(30.0);
        let mut fast = spec("fast", 1);
        fast.predicted_secs = Some(5.0);
        let plain_a = spec("plain-a", 1);
        let plain_b = spec("plain-b", 1);
        // Higher priority always beats a shorter prediction.
        let mut urgent = spec("urgent", 9);
        urgent.predicted_secs = Some(1000.0);
        for s in [slow, plain_a, fast, plain_b, urgent] {
            let key = s.dataset.clone();
            q.submit(s, 10, key);
        }
        let order: Vec<String> = std::iter::from_fn(|| q.admit_next(u64::MAX, &no_busy()))
            .map(|j| j.spec.name)
            .collect();
        assert_eq!(order, ["urgent", "fast", "slow", "plain-a", "plain-b"]);
    }

    #[test]
    fn admission_respects_memory_budget() {
        let mut q = JobQueue::new();
        submit(&mut q, "big", 9, 1000); // best priority but does not fit
        submit(&mut q, "small", 0, 100);
        let j = q.admit_next(500, &no_busy()).expect("small fits");
        assert_eq!(j.spec.name, "small");
        // Nothing else fits in the remaining budget.
        assert!(q.admit_next(400, &no_busy()).is_none());
        assert_eq!(q.queued(), 1, "big is still queued, not cancelled");
        // Capacity frees up → big is admitted.
        assert!(q.would_admit(1000, &no_busy()));
        assert!(!q.would_admit(400, &no_busy()), "peek matches admit");
        let j = q.admit_next(1000, &no_busy()).expect("big fits now");
        assert_eq!(j.spec.name, "big");
        assert!(!q.would_admit(u64::MAX, &no_busy()), "nothing queued anymore");
    }

    #[test]
    fn one_job_per_dataset_at_a_time() {
        let mut q = JobQueue::new();
        let s1 = JobSpec::new("a", "/data/shared");
        let s2 = JobSpec::new("b", "/data/shared");
        q.submit(s1, 10, PathBuf::from("/data/shared"));
        q.submit(s2, 10, PathBuf::from("/data/shared"));
        let first = q.admit_next(u64::MAX, &no_busy()).expect("first admits");
        let mut busy = HashSet::new();
        busy.insert(first.dataset_key.clone());
        assert!(q.admit_next(u64::MAX, &busy).is_none(), "dataset is locked");
        busy.clear();
        let second = q.admit_next(u64::MAX, &busy).expect("unlocked");
        assert_eq!(second.spec.name, "b");
    }

    #[test]
    fn oversized_jobs_fail_fast() {
        let mut q = JobQueue::new();
        submit(&mut q, "fits", 0, 100);
        submit(&mut q, "never", 0, 10_000);
        let failed = q.fail_oversized(1000);
        assert_eq!(failed.len(), 1);
        assert_eq!(failed[0].spec.name, "never");
        assert_eq!(q.get(failed[0].id).unwrap().state, JobState::Failed);
        assert_eq!(q.queued(), 1);
    }

    #[test]
    fn lifecycle_and_drained() {
        let mut q = JobQueue::new();
        let id = submit(&mut q, "a", 0, 10);
        assert!(!q.is_drained());
        let j = q.admit_next(u64::MAX, &no_busy()).unwrap();
        assert_eq!(j.id, id);
        assert_eq!(q.get(id).unwrap().state, JobState::Admitted);
        q.set_state(id, JobState::Streaming);
        assert!(!q.is_drained());
        q.set_state(id, JobState::Done);
        assert!(q.is_drained());
    }

    #[test]
    fn apply_profile_respects_pins_and_divisibility() {
        let mut tuned = crate::tune::TunedProfile::safe_defaults(4096, 4);
        tuned.block = 1000;
        tuned.ngpus = 4;
        tuned.predicted_secs = 2.0;
        // Unpinned: everything applies (the tuned block divides its lanes).
        let mut s = JobSpec::new("a", "/d");
        s.apply_profile(&tuned);
        assert_eq!((s.block, s.ngpus), (1000, 4));
        assert_eq!(s.predicted_secs, Some(2.0));
        assert!(s.profile_attached);
        // A pinned block the tuned lane count does not divide: the pin
        // wins and the lane count falls back to one.
        let mut s = JobSpec::new("b", "/d");
        s.block = 50;
        s.pins.block = true;
        s.apply_profile(&tuned);
        assert_eq!((s.block, s.ngpus), (50, 1));
        // A pinned lane count with a non-dividing tuned block: the
        // block rounds down to the lane multiple.
        let mut s = JobSpec::new("c", "/d");
        s.ngpus = 3;
        s.pins.ngpus = true;
        s.apply_profile(&tuned);
        assert_eq!(s.ngpus, 3);
        assert_eq!(s.block, 999);
    }

    #[test]
    fn host_bytes_scales_with_dims() {
        let s = JobSpec::new("x", "/d");
        let small = s.host_bytes(64, 4);
        let big = s.host_bytes(512, 4);
        assert!(big > small);
        // Kinship (n²) is included: doubling n more than doubles the bill.
        assert!(s.host_bytes(1024, 4) > 2 * s.host_bytes(512, 4));
    }
}
