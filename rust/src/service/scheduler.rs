//! The multi-study scheduler: a fixed set of worker lanes multiplexing
//! GWAS jobs over the streaming coordinator.
//!
//! Topology (one more level of the paper's own pattern — fixed lanes,
//! bounded queues, backpressure by rendezvous):
//!
//! ```text
//!   config [job.*] ─┐
//!                   ├─▶ JobQueue ─admit─▶ worker lanes ──▶ coordinator::Engine
//!   spool *.toml ───┘   (priority,        (N threads,         │
//!                        mem budget,       rendezvous          ▼
//!                        dataset lock)     channels)      shared BlockCache
//! ```
//!
//! The dispatcher thread owns the queue and the memory ledger; each
//! worker owns the job it is streaming plus one *warm engine*: when the
//! next job targets the same dataset with a compatible configuration,
//! it executes on the resident [`Engine`] and inherits its preprocess,
//! aio reader, device lanes and buffer rings — the serve-side payoff of
//! the unified streaming core. A resident warm engine keeps its bytes
//! charged against the memory ledger (its rings and preprocess are
//! still alive) and is evicted — never a job starved — when queued work
//! could only be admitted by reclaiming it. Admission charges a job's
//! estimated host footprint against `mem_budget_bytes` and releases it
//! on completion — and since the zero-copy plane landed, that footprint
//! bills the refcounted *slab* circulation ([`JobSpec::host_bytes`]):
//! a block resident in the shared cache and streaming through a job is
//! one slab, not a cache copy plus a ring copy plus per-lane staging
//! duplicates,
//! so a burst of submissions degrades to queueing — never to swapping,
//! which on the paper's analysis would destroy the disk-bound
//! pipeline's sustained peak. Submission is also where
//! **tune-on-first-contact** happens: a dataset arriving without a
//! tuned profile is probed + planned once (cheap), the profile is
//! persisted next to it, and its DES prediction feeds the queue's
//! shortest-job-first ordering.
//!
//! **Job coalescing**: at dispatch time, every still-queued job that
//! would stream the *identical* pipeline over the leader's dataset
//! (same knobs, same offload mode/backend/throttles, same phenotype
//! batch — see [`JobSpec::coalesces_with`]) rides the leader's single
//! streaming pass instead of waiting for its own. Riders mirror the
//! leader's report under their own names with `coalesced_into` set; a
//! failed leader re-queues its riders untouched (they spent no retry
//! budget). A job pinning even one knob differently keeps its own pass.

use crate::config::ServiceConfig;
use crate::coordinator::{Engine, Metrics, PipelineConfig};
use crate::error::{Error, Result};
use crate::service::queue::{Job, JobQueue, JobSpec, JobState};
use crate::service::report::{JobReport, ServiceReport};
use crate::storage::fault;
use crate::storage::{dataset, BlockCache};
use crate::tune::{self, PlanOpts, ProbeOpts, TunedProfile};
use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, sync_channel, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How often the dispatcher wakes to poll the spool directory while
/// jobs are in flight or the service is watching.
const SPOOL_POLL: Duration = Duration::from_millis(200);

/// Disk-probe budget for tune-on-first-contact — kept small so a new
/// dataset's first submission costs milliseconds, not a second pass
/// over the file.
const FIRST_CONTACT_PROBE_BYTES: u64 = 8 << 20;

/// How the dispatcher attaches profiles at submission time.
#[derive(Clone, Copy)]
struct SubmitOpts {
    /// Probe + plan datasets that have no persisted profile.
    auto_tune: bool,
    /// Thread budget a job will actually run under (the worker share) —
    /// what the probe calibrates and the planner searches.
    plan_threads: usize,
}

/// What the dispatcher sends a worker lane.
enum LaneMsg {
    /// Stream this job.
    Run(Job),
    /// Release the warm engine (the dispatcher reclaims its budget to
    /// admit queued work that would not otherwise fit).
    DropEngine,
}

struct WorkerLane {
    tx: Option<SyncSender<LaneMsg>>,
    handle: JoinHandle<()>,
    busy: bool,
}

/// Run the service to completion (or forever with `watch = true`):
/// enqueue the config's jobs plus any spool files, admit them under the
/// memory budget, stream them across the worker lanes, and return the
/// aggregate report once everything has drained.
pub fn serve(cfg: &ServiceConfig) -> Result<ServiceReport> {
    if cfg.workers == 0 {
        return Err(Error::Config("service.workers must be ≥ 1".into()));
    }
    if cfg.mem_budget_bytes == 0 {
        return Err(Error::Config("service.mem_budget_mb must be > 0".into()));
    }
    let cache = Arc::new(BlockCache::new(cfg.cache_bytes));
    // Partition the compute cores across the worker lanes: each job
    // inherits an equal share unless its spec pins `threads` itself.
    // (A share below a job's `ngpus + 1` clamps to serial kernels but
    // cannot shrink the pipeline's structural lane threads — see
    // `PipelineConfig::threads`.)
    let total_threads =
        if cfg.threads == 0 { crate::util::threads::available() } else { cfg.threads };
    let worker_threads = (total_threads / cfg.workers).max(1);
    let t_wall = Instant::now();

    // Worker lanes: rendezvous submission (depth 0 = the dispatcher only
    // hands a job to a lane that is ready to take it), shared results
    // channel back.
    let (res_tx, res_rx) = channel::<(usize, JobReport)>();
    let mut lanes: Vec<WorkerLane> = Vec::with_capacity(cfg.workers);
    for wi in 0..cfg.workers {
        let (tx, rx) = sync_channel::<LaneMsg>(0);
        let res_tx = res_tx.clone();
        // cache_bytes = 0 disables the cache entirely: jobs stream
        // straight from disk exactly as `cugwas run` does.
        let cache = (cfg.cache_bytes > 0).then(|| Arc::clone(&cache));
        let handle = std::thread::Builder::new()
            .name(format!("cugwas-svc-{wi}"))
            .spawn(move || {
                // The lane's warm engine: back-to-back jobs on one
                // dataset reuse its preprocess, aio reader, device lanes
                // and buffer rings instead of rebuilding the world.
                let mut engine: Option<Engine> = None;
                while let Ok(msg) = rx.recv() {
                    let job = match msg {
                        LaneMsg::Run(job) => job,
                        LaneMsg::DropEngine => {
                            engine = None;
                            continue;
                        }
                    };
                    // A panic inside the pipeline (poisoned pool assert,
                    // debug overflow, …) must become a failed report, not
                    // a silently dead lane: with other lanes still alive
                    // the dispatcher would otherwise wait on this job's
                    // completion forever.
                    let cache = cache.clone();
                    let report = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || run_job(&job, cache, worker_threads, &mut engine),
                    ))
                    .unwrap_or_else(|_| {
                        JobReport::failed(
                            job.spec.name.clone(),
                            job.spec.dataset.clone(),
                            job.spec.priority,
                            "worker panicked while streaming (see stderr)".into(),
                        )
                    });
                    if res_tx.send((wi, report)).is_err() {
                        break; // dispatcher gone — shut down
                    }
                }
            })
            .map_err(|e| Error::io("spawning service worker", e))?;
        lanes.push(WorkerLane { tx: Some(tx), handle, busy: false });
    }
    drop(res_tx); // workers hold the only senders now

    // Seed the queue from the config, then from the spool.
    let submit_opts = SubmitOpts { auto_tune: cfg.auto_tune, plan_threads: worker_threads };
    let mut queue = JobQueue::new();
    let mut reports: Vec<JobReport> = Vec::new();
    for spec in &cfg.jobs {
        submit_spec(&mut queue, spec.clone(), &mut reports, submit_opts);
    }
    let mut spool_state = SpoolState::default();
    scan_spool(cfg.spool.as_deref(), &mut spool_state, &mut queue, &mut reports, submit_opts);
    for job in queue.fail_oversized(cfg.mem_budget_bytes) {
        reports.push(oversized_report(&job, cfg.mem_budget_bytes));
    }

    // ---- dispatch loop --------------------------------------------------
    let mut mem_in_use = 0u64;
    let mut busy_datasets: HashSet<PathBuf> = HashSet::new();
    let mut inflight: HashMap<usize, Job> = HashMap::new();
    // Riders coalesced onto the leader streaming on each lane — they
    // share its pass and its outcome (see the module docs).
    let mut riders: HashMap<usize, Vec<Job>> = HashMap::new();
    // Dispatch instants, for the per-job scheduler-track trace spans.
    let mut dispatched: HashMap<usize, Instant> = HashMap::new();
    // Per-lane residency of the warm engine: the dataset it is warm for
    // and the host bytes it keeps alive. Resident engines stay charged
    // against the admission budget (the rings and preprocess do not
    // vanish when the job's ledger entry is released) until the lane is
    // reused — or evicted, when queued work cannot otherwise fit.
    let mut warm: Vec<Option<(PathBuf, u64)>> = vec![None; cfg.workers];
    // Graceful-degradation state: per-job retry counts, per-dataset
    // backoff deadlines (a re-queued job is not re-admitted until its
    // dataset cools down), and per-dataset consecutive-failure streaks
    // feeding the quarantine gate.
    let mut attempts: HashMap<u64, u32> = HashMap::new();
    let mut cooling: HashMap<PathBuf, Instant> = HashMap::new();
    let mut fail_streak: HashMap<PathBuf, u32> = HashMap::new();
    loop {
        // Hand admissible jobs to idle lanes.
        while lanes.iter().any(|l| !l.busy) {
            // Backoff: a dataset cooling down after a failure counts as
            // busy for admission (and for the eviction probe below).
            let now = Instant::now();
            cooling.retain(|_, until| *until > now);
            let mut blocked = busy_datasets.clone();
            blocked.extend(cooling.keys().cloned());
            let reserved: u64 = warm.iter().flatten().map(|(_, b)| *b).sum();
            let budget_left =
                cfg.mem_budget_bytes.saturating_sub(mem_in_use).saturating_sub(reserved);
            let Some(job) = queue.admit_next(budget_left, &blocked) else {
                // Nothing fits. Evict idle warm engines only when their
                // reserved bytes are what actually blocks admission —
                // queued work beats a warm cache, but an engine must
                // not be churned over a dataset lock.
                let unblocks = reserved > 0
                    && queue.would_admit(budget_left + reserved, &blocked);
                let mut evicted = false;
                if unblocks {
                    for (wi, lane) in lanes.iter().enumerate() {
                        if lane.busy || warm[wi].is_none() {
                            continue;
                        }
                        let tx = lane.tx.as_ref().expect("lane sender alive");
                        if tx.send(LaneMsg::DropEngine).is_ok() {
                            warm[wi] = None;
                            evicted = true;
                        }
                    }
                }
                if evicted {
                    continue;
                }
                break;
            };
            // Quarantine gate: a dataset that has failed this many jobs
            // in a row is presumed broken (bad sectors, truncated file);
            // burning a worker lane and the retry budget on every
            // subsequent job just delays the rest of the queue.
            let streak = fail_streak.get(&job.dataset_key).copied().unwrap_or(0);
            if streak >= fault::policy().quarantine_after {
                queue.set_state(job.id, JobState::Failed);
                note_job_failed();
                reports.push(JobReport::failed(
                    job.spec.name.clone(),
                    job.spec.dataset.clone(),
                    job.spec.priority,
                    format!(
                        "dataset quarantined after {streak} consecutive job failures — \
                         resolve the underlying fault and resubmit"
                    ),
                ));
                continue;
            }
            // Prefer the idle lane already warm on this job's dataset
            // (the reuse the engine refactor pays for), else any idle.
            let matching = (0..lanes.len()).filter(|&wi| !lanes[wi].busy).find(|&wi| {
                warm[wi].as_ref().is_some_and(|(ds, _)| *ds == job.dataset_key)
            });
            let wi = matching
                .or_else(|| (0..lanes.len()).find(|&wi| !lanes[wi].busy))
                .expect("an idle lane exists");
            mem_in_use += job.est_bytes;
            warm[wi] = None; // the resident engine is reused or replaced
            busy_datasets.insert(job.dataset_key.clone());
            queue.set_state(job.id, JobState::Streaming);
            // Coalesce compatible queued work onto this pass: one
            // stream over the dataset answers every identical spec.
            let lane_riders = queue.take_coalescable(&job);
            if !lane_riders.is_empty() {
                crate::log_info!(
                    "service",
                    "coalescing {} queued job(s) onto '{}' over {}",
                    lane_riders.len(),
                    job.spec.name,
                    job.dataset_key.display()
                );
                if crate::telemetry::metrics_enabled() {
                    crate::telemetry::registry::global()
                        .jobs_coalesced_total
                        .add(lane_riders.len() as u64);
                }
                riders.insert(wi, lane_riders);
            }
            inflight.insert(wi, job.clone());
            dispatched.insert(wi, Instant::now());
            let lane = &mut lanes[wi];
            lane.busy = true;
            lane.tx
                .as_ref()
                .expect("lane sender alive")
                .send(LaneMsg::Run(job))
                .map_err(|_| Error::Pipeline("service worker lane died".into()))?;
        }

        // Publish the admission state for this dispatch turn: a scrape
        // renders pure registry state, so the gauges must be pushed
        // wherever they change.
        if crate::telemetry::metrics_enabled() {
            let reg = crate::telemetry::registry::global();
            reg.set_queue(queue.queued(), inflight.len(), mem_in_use, cfg.mem_budget_bytes);
            reg.set_cache(&cache.stats());
        }

        if inflight.is_empty() && queue.is_drained() {
            // Idle. One more spool scan; exit unless watching, new work
            // arrived, or a spool file is still settling (mid-write).
            let before = queue.all().len();
            scan_spool(
                cfg.spool.as_deref(),
                &mut spool_state,
                &mut queue,
                &mut reports,
                submit_opts,
            );
            for job in queue.fail_oversized(cfg.mem_budget_bytes) {
                reports.push(oversized_report(&job, cfg.mem_budget_bytes));
            }
            if queue.all().len() > before {
                continue;
            }
            if cfg.watch || !spool_state.pending_bad.is_empty() {
                std::thread::sleep(SPOOL_POLL);
                continue;
            }
            break;
        }

        // Wait for a completion, polling the spool in between.
        match res_rx.recv_timeout(SPOOL_POLL) {
            Ok((wi, report)) => {
                let job = inflight.remove(&wi).expect("completion from a dispatched lane");
                if let Some(t0) = dispatched.remove(&wi) {
                    crate::telemetry::span(
                        "job",
                        "sched",
                        crate::telemetry::trace::TID_SCHED,
                        t0,
                        t0.elapsed(),
                        &[("id", job.id as u64), ("ok", u64::from(report.ok()))],
                    );
                }
                mem_in_use -= job.est_bytes;
                // A successful run leaves the engine warm on this lane;
                // its footprint stays charged until reuse or eviction.
                // A failed run dropped the engine.
                warm[wi] = report.ok().then(|| (job.dataset_key.clone(), job.est_bytes));
                busy_datasets.remove(&job.dataset_key);
                lanes[wi].busy = false;
                let lane_riders = riders.remove(&wi).unwrap_or_default();
                if report.ok() {
                    attempts.remove(&job.id);
                    cooling.remove(&job.dataset_key);
                    fail_streak.remove(&job.dataset_key);
                    queue.set_state(job.id, JobState::Done);
                    // Riders share the leader's outcome: the one pass
                    // answered them all, so each mirrors the leader's
                    // numbers under its own name, stamped with whose
                    // stream carried it.
                    for r in &lane_riders {
                        queue.set_state(r.id, JobState::Done);
                        reports.push(
                            JobReport::done(
                                r.spec.name.clone(),
                                r.spec.dataset.clone(),
                                r.spec.priority,
                                report.wall_secs,
                                report.snps,
                                report.blocks,
                                report.metrics.clone().unwrap_or_else(Metrics::new),
                            )
                            .with_coalesced_into(report.name.clone()),
                        );
                    }
                    reports.push(report);
                } else {
                    // A failed pass answered nobody: riders go straight
                    // back to the queue with their retry budgets intact
                    // (only the leader's attempt counter advances).
                    for r in &lane_riders {
                        queue.set_state(r.id, JobState::Queued);
                    }
                    // Graceful degradation: a failed run re-enters the
                    // queue (bounded, with per-dataset backoff) before
                    // its failure is final — a transient fault costs a
                    // retry, not the job.
                    let tried = attempts.entry(job.id).or_insert(0);
                    *tried += 1;
                    let pol = fault::policy();
                    if *tried <= pol.job_retries {
                        let delay = Duration::from_millis(
                            pol.job_backoff_ms.saturating_mul(1u64 << (*tried - 1).min(10)),
                        );
                        crate::log_warn!(
                            "service",
                            "job '{}' failed ({}); re-queueing attempt {}/{} after {:.0?}",
                            job.spec.name,
                            report.error.as_deref().unwrap_or("unknown error"),
                            *tried,
                            pol.job_retries,
                            delay
                        );
                        cooling.insert(job.dataset_key.clone(), Instant::now() + delay);
                        fault::note_job_retry();
                        queue.set_state(job.id, JobState::Queued);
                        // The report is not recorded: one report per
                        // job, and this one's story isn't over.
                    } else {
                        attempts.remove(&job.id);
                        *fail_streak.entry(job.dataset_key.clone()).or_insert(0) += 1;
                        note_job_failed();
                        queue.set_state(job.id, JobState::Failed);
                        reports.push(report);
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                return Err(Error::Pipeline("all service worker lanes exited".into()));
            }
        }
        scan_spool(cfg.spool.as_deref(), &mut spool_state, &mut queue, &mut reports, submit_opts);
        for job in queue.fail_oversized(cfg.mem_budget_bytes) {
            reports.push(oversized_report(&job, cfg.mem_budget_bytes));
        }
    }

    // Drop the submission side so lanes exit, then join them.
    for lane in &mut lanes {
        lane.tx.take();
    }
    for lane in lanes {
        let _ = lane.handle.join();
    }

    Ok(ServiceReport {
        jobs: reports,
        wall_secs: t_wall.elapsed().as_secs_f64(),
        workers: cfg.workers,
        mem_budget_bytes: cfg.mem_budget_bytes,
        cache: cache.stats(),
    })
}

/// Estimate a spec's host footprint from the dataset's metadata (cheap:
/// reads `meta.txt` only). The spec's knobs are the *planned* ones when
/// a tuned profile was attached (first-contact or `profile` key), so
/// admission bills the geometry the job will actually stream with
/// instead of a static worst-case — a tuned small-block plan no longer
/// gets rejected for a default ring it will never allocate.
fn estimate_bytes(spec: &JobSpec) -> Result<u64> {
    let meta = dataset::load_meta(&spec.dataset)?;
    Ok(spec.host_bytes(meta.dims.n, meta.dims.p()))
}

/// Tune-on-first-contact: make sure the spec carries a profile before
/// its footprint is estimated and its admission rank decided. An
/// existing `<dataset>/tuned.toml` is loaded; with `auto_tune` on, a
/// missing one is created by a cheap probe + plan and persisted next to
/// the dataset for every later job (and every other tool) to reuse.
/// Explicitly pinned knobs are never overridden; failures here only
/// lose the optimization, never the job.
fn attach_first_contact_profile(spec: &mut JobSpec, opts: SubmitOpts) {
    if spec.profile_attached || !opts.auto_tune {
        // An explicit `profile` key always wins over first contact, and
        // `auto_tune = false` means "stream exactly the configured
        // knobs" — neither probing nor applying a found profile.
        return;
    }
    let path = TunedProfile::default_path(&spec.dataset);
    let tuned = if path.exists() {
        match tune::profile::load_or_default(Some(&path), 0, 0) {
            Ok(t) => t,
            Err(e) => {
                crate::log_warn!("service", "ignoring unreadable profile {}: {e}", path.display());
                return;
            }
        }
    } else {
        match tune_first_contact(spec, opts.plan_threads, &path) {
            Some(t) => t,
            None => return,
        }
    };
    spec.apply_profile(&tuned);
}

/// Probe + plan a dataset the service has never seen, persisting the
/// profile beside it. `None` when the dataset is unreadable (the
/// estimate will fail the job with a better error) — probing never
/// sinks a submission.
///
/// This runs synchronously on the dispatcher thread, so it briefly
/// delays admission: ~10 MB of reads plus the quick kernel/memcpy
/// probes (tens of milliseconds). It is paid once per dataset *ever* —
/// the persisted profile short-circuits every later submission — and a
/// spool burst of K new datasets costs K probes before the first
/// dispatch, a bounded, amortized trade the module docs call out.
fn tune_first_contact(spec: &JobSpec, plan_threads: usize, out: &Path) -> Option<TunedProfile> {
    let meta = dataset::load_meta(&spec.dataset).ok()?;
    let popts = ProbeOpts {
        threads: plan_threads,
        max_disk_bytes: FIRST_CONTACT_PROBE_BYTES,
        read_throttle: spec.read_throttle,
        quick: true,
    };
    let rates = tune::probe_dataset(&spec.dataset, &popts).ok()?;
    let opts = PlanOpts {
        total_threads: plan_threads.max(1),
        max_lanes: spec.ngpus.max(1),
        host_mem_bytes: 0,
        max_block: 0,
        traits: spec.traits.max(1),
    };
    let profile = tune::plan(&rates, meta.dims, &opts);
    match profile.save(out) {
        Ok(()) => crate::log_info!(
            "service",
            "first contact with {}: tuned block {} × {} lane(s), {} host / {} device buffers \
             → {}",
            spec.dataset.display(),
            profile.block,
            profile.ngpus,
            profile.host_buffers,
            profile.device_buffers,
            out.display()
        ),
        Err(e) => {
            crate::log_warn!("service", "could not persist {}: {e}", out.display());
        }
    }
    Some(profile)
}

/// Queue a spec, or record an immediate failure (bad dataset, bad dims).
fn submit_spec(
    queue: &mut JobQueue,
    mut spec: JobSpec,
    reports: &mut Vec<JobReport>,
    opts: SubmitOpts,
) {
    attach_first_contact_profile(&mut spec, opts);
    match estimate_bytes(&spec) {
        Ok(est) => {
            // Same canonicalization the pipeline keys the cache by.
            let key = dataset::canonical_key(&spec.dataset);
            queue.submit(spec, est, key);
        }
        Err(e) => {
            note_job_failed();
            reports.push(JobReport::failed(
                spec.name.clone(),
                spec.dataset.clone(),
                spec.priority,
                format!("cannot estimate job footprint: {e}"),
            ));
        }
    }
}

/// Count one failed job in the telemetry registry. Successes are
/// counted by the engine when the run completes; failures never reach
/// that point, so every site that mints a failure report notes it here.
fn note_job_failed() {
    if crate::telemetry::metrics_enabled() {
        crate::telemetry::registry::global().jobs_failed_total.add(1);
    }
}

fn oversized_report(job: &Job, budget: u64) -> JobReport {
    note_job_failed();
    let spec = &job.spec;
    JobReport::failed(
        spec.name.clone(),
        spec.dataset.clone(),
        spec.priority,
        format!(
            "estimated host footprint {} ({} geometry: block {} × {} lane(s), {} host / {} \
             device buffers) exceeds the service memory budget {}",
            crate::util::human_bytes(job.est_bytes),
            if spec.predicted_secs.is_some() { "tuned" } else { "requested" },
            spec.block,
            spec.ngpus,
            spec.host_buffers,
            spec.device_buffers,
            crate::util::human_bytes(budget)
        ),
    )
}

/// Spool ingestion state: paths already ingested or reported, plus
/// parse failures awaiting confirmation (a file copied into the spool
/// non-atomically can be caught mid-write — it is only reported as bad
/// once a later scan sees it unchanged *and* still unparsable).
#[derive(Default)]
struct SpoolState {
    seen: HashSet<PathBuf>,
    pending_bad: HashMap<PathBuf, std::time::SystemTime>,
}

/// Ingest new `*.toml` job files from the spool directory. Malformed
/// files become failed-job reports rather than crashing the service.
/// Files are never deleted — the spool is an inbox the operator owns.
fn scan_spool(
    spool: Option<&Path>,
    state: &mut SpoolState,
    queue: &mut JobQueue,
    reports: &mut Vec<JobReport>,
    opts: SubmitOpts,
) {
    let Some(dir) = spool else { return };
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|s| s.to_str()) == Some("toml"))
        .filter(|p| !state.seen.contains(p))
        .collect();
    paths.sort(); // deterministic FIFO for same-priority spool jobs
    for path in paths {
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("spool-job")
            .to_string();
        match ServiceConfig::job_from_file(&path, &name) {
            Ok(spec) => {
                state.seen.insert(path.clone());
                state.pending_bad.remove(&path);
                submit_spec(queue, spec, reports, opts);
            }
            Err(e) => {
                let mtime = std::fs::metadata(&path).and_then(|m| m.modified()).ok();
                match (state.pending_bad.get(&path), mtime) {
                    // Unchanged since the last failing scan → genuinely
                    // bad: report it AND move it out of the inbox so it
                    // is never re-scanned (or silently retried forever).
                    (Some(prev), Some(now)) if *prev == now => {
                        state.seen.insert(path.clone());
                        state.pending_bad.remove(&path);
                        quarantine_spool_file(dir, &path, &e.to_string());
                        note_job_failed();
                        reports.push(JobReport::failed(
                            name,
                            path.clone(),
                            0,
                            format!("bad spool job file: {e}"),
                        ));
                    }
                    // First failure or still changing → retry next scan.
                    (_, Some(now)) => {
                        state.pending_bad.insert(path.clone(), now);
                    }
                    // File vanished / unstattable → report it as it is.
                    (_, None) => {
                        state.seen.insert(path.clone());
                        state.pending_bad.remove(&path);
                        note_job_failed();
                        reports.push(JobReport::failed(
                            name,
                            path.clone(),
                            0,
                            format!("bad spool job file: {e}"),
                        ));
                    }
                }
            }
        }
    }
}

/// Move a confirmed-bad spool file to `<spool>/quarantine/` with a
/// `<name>.reason` sidecar explaining why, so the operator's inbox
/// holds only live work and the diagnosis travels with the file. A
/// failed move only loses the tidying (the file stays in `seen`, so it
/// is not retried either way).
fn quarantine_spool_file(spool: &Path, path: &Path, reason: &str) {
    let qdir = spool.join("quarantine");
    if let Err(e) = std::fs::create_dir_all(&qdir) {
        crate::log_warn!("service", "cannot create {}: {e}", qdir.display());
        return;
    }
    let Some(file_name) = path.file_name() else { return };
    let dest = qdir.join(file_name);
    if let Err(e) = std::fs::rename(path, &dest) {
        crate::log_warn!(
            "service",
            "cannot quarantine {}: {e} (leaving it in place)",
            path.display()
        );
        return;
    }
    let mut sidecar = dest.clone().into_os_string();
    sidecar.push(".reason");
    if let Err(e) = std::fs::write(&sidecar, format!("{reason}\n")) {
        crate::log_warn!("service", "cannot write quarantine reason: {e}");
    }
    crate::log_warn!("service", "quarantined bad spool job file: {}", dest.display());
}

/// Stream one job through the unified engine on this worker lane.
/// `worker_threads` is this lane's share of the host cores; a job spec
/// with an explicit `threads` overrides it. `slot` is the lane's warm
/// engine: when the incoming job is compatible (same dataset identity,
/// mode, backend, thread budget, cache), the job executes on it and the
/// preprocess/reader/lanes/pools all carry over; otherwise a fresh
/// engine is opened and becomes the new resident. A failed run drops
/// the engine — the next job starts clean.
fn run_job(
    job: &Job,
    cache: Option<Arc<BlockCache>>,
    worker_threads: usize,
    slot: &mut Option<Engine>,
) -> JobReport {
    let spec = &job.spec;
    let cfg = PipelineConfig {
        dataset: spec.dataset.clone(),
        block: spec.block,
        ngpus: spec.ngpus,
        host_buffers: spec.host_buffers,
        device_buffers: spec.device_buffers,
        mode: spec.mode,
        backend: spec.backend.clone(),
        read_throttle: spec.read_throttle,
        write_throttle: spec.write_throttle,
        resume: false,
        cache,
        threads: if spec.threads > 0 { spec.threads } else { worker_threads },
        lane_threads: spec.lane_threads,
        adapt: spec.adapt,
        adapt_every: spec.adapt_every,
        traits: spec.traits.max(1),
        perm_seed: spec.perm_seed,
    };
    let failed = |e: &Error| {
        JobReport::failed(spec.name.clone(), spec.dataset.clone(), spec.priority, e.to_string())
    };
    let (mut engine, reused) = match slot.take() {
        Some(engine) if engine.compatible(&cfg) => (engine, true),
        _ => match Engine::open(&cfg) {
            Ok(engine) => (engine, false),
            Err(e) => return failed(&e),
        },
    };
    match engine.execute(&cfg) {
        Ok(rep) => {
            *slot = Some(engine);
            JobReport::done(
                spec.name.clone(),
                spec.dataset.clone(),
                spec.priority,
                rep.wall_secs,
                rep.snps,
                rep.blocks,
                rep.metrics,
            )
            .with_reused_engine(reused)
        }
        Err(e) => failed(&e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::problem::Dims;
    use crate::storage::generate;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cugwas_svc_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn small_cfg(jobs: Vec<JobSpec>, workers: usize, cache_mb: u64) -> ServiceConfig {
        ServiceConfig {
            workers,
            mem_budget_bytes: 1 << 30,
            cache_bytes: cache_mb << 20,
            threads: 0,
            spool: None,
            watch: false,
            // Off by default in tests: explicit blocks stay explicit and
            // no probe noise; the first-contact test opts back in.
            auto_tune: false,
            metrics_addr: None,
            jobs,
            fault: Default::default(),
        }
    }

    /// The acceptance scenario: three jobs, two sharing a dataset — all
    /// complete, and the shared dataset's second pass hits the cache.
    #[test]
    fn three_jobs_two_sharing_a_dataset() {
        let d1 = tmpdir("shared");
        let d2 = tmpdir("solo");
        generate(&d1, Dims::new(32, 2, 96).unwrap(), 16, 11).unwrap();
        generate(&d2, Dims::new(32, 2, 64).unwrap(), 16, 12).unwrap();
        let mut j1 = JobSpec::new("shared-a", &d1);
        j1.block = 16;
        j1.priority = 2; // runs first → faults the cache in
        let mut j2 = JobSpec::new("shared-b", &d1);
        j2.block = 16;
        // This test is about the shared cache, so shared-b must stream
        // its own pass: nudge an (inert while adapt=false) knob so it
        // does not coalesce onto shared-a's pass instead.
        j2.adapt_every = 32;
        let mut j3 = JobSpec::new("solo", &d2);
        j3.block = 16;
        let rep = serve(&small_cfg(vec![j1, j2, j3], 2, 64)).unwrap();
        assert_eq!(rep.jobs.len(), 3);
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        assert!(rep.cache.hits > 0, "second pass over the shared dataset must hit");
        let shared_b = rep.jobs.iter().find(|j| j.name == "shared-b").unwrap();
        assert_eq!(shared_b.cache_hits, 6, "all 6 blocks of shared-b served from RAM");
        assert_eq!(rep.total_snps(), 96 + 96 + 64);
        std::fs::remove_dir_all(&d1).unwrap();
        std::fs::remove_dir_all(&d2).unwrap();
    }

    #[test]
    fn missing_dataset_fails_without_sinking_the_service() {
        let d = tmpdir("good");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        let mut ok = JobSpec::new("ok", &d);
        ok.block = 8;
        let bad = JobSpec::new("bad", "/nonexistent/dataset");
        let rep = serve(&small_cfg(vec![ok, bad], 1, 16)).unwrap();
        assert_eq!(rep.jobs.len(), 2);
        assert_eq!(rep.failed(), 1);
        assert!(rep.jobs.iter().any(|j| j.name == "ok" && j.ok()));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn oversized_job_fails_fast_under_tiny_budget() {
        let d = tmpdir("tiny");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        let mut j = JobSpec::new("too-big", &d);
        j.block = 8;
        let mut cfg = small_cfg(vec![j], 1, 16);
        cfg.mem_budget_bytes = 1; // nothing fits
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.failed(), 1);
        assert!(rep.jobs[0].error.as_deref().unwrap().contains("memory budget"));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn spool_jobs_are_ingested() {
        let d = tmpdir("spoolds");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        let spool = tmpdir("spooldir");
        std::fs::create_dir_all(&spool).unwrap();
        std::fs::write(
            spool.join("late.toml"),
            format!("[job]\ndataset = \"{}\"\nblock = 8\n", d.display()),
        )
        .unwrap();
        std::fs::write(spool.join("broken.toml"), "[job]\nblock = 8\n").unwrap(); // no dataset
        std::fs::write(spool.join("notes.txt"), "ignored").unwrap();
        let mut cfg = small_cfg(vec![], 1, 16);
        cfg.spool = Some(spool.clone());
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.jobs.len(), 2, "{}", rep.render());
        assert!(rep.jobs.iter().any(|j| j.name == "late" && j.ok()));
        assert!(rep.jobs.iter().any(|j| j.name == "broken" && !j.ok()));
        // The confirmed-bad file moved out of the inbox, with its
        // diagnosis in a sidecar.
        assert!(!spool.join("broken.toml").exists(), "bad file must leave the inbox");
        assert!(spool.join("quarantine/broken.toml").exists());
        let reason =
            std::fs::read_to_string(spool.join("quarantine/broken.toml.reason")).unwrap();
        assert!(reason.contains("missing dataset"), "{reason}");
        // Good files and strangers stay where the operator put them.
        assert!(spool.join("late.toml").exists());
        assert!(spool.join("notes.txt").exists());
        std::fs::remove_dir_all(&d).unwrap();
        std::fs::remove_dir_all(&spool).unwrap();
    }

    #[test]
    fn failing_jobs_retry_then_quarantine_the_dataset() {
        // Default policy: one retry per job, quarantine after three
        // consecutive final failures on a dataset. Four jobs on a
        // dataset whose data file vanished: the first three each run
        // twice (retry) and fail for real; the fourth never reaches a
        // worker lane — the quarantine gate fails it at admission.
        let d = tmpdir("quarantine");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        // Break the dataset *after* generation: the metadata stays
        // readable (admission estimates still work), streaming fails.
        std::fs::remove_file(dataset::DatasetPaths::new(&d).xr()).unwrap();
        let jobs = (0..4)
            .map(|i| {
                let mut j = JobSpec::new(format!("j{i}"), &d);
                j.block = 8;
                j
            })
            .collect();
        let rep = serve(&small_cfg(jobs, 1, 0)).unwrap();
        assert_eq!(rep.jobs.len(), 4, "{}", rep.render());
        assert_eq!(rep.failed(), 4, "{}", rep.render());
        let quarantined: Vec<_> = rep
            .jobs
            .iter()
            .filter(|j| j.error.as_deref().is_some_and(|e| e.contains("quarantined")))
            .collect();
        assert_eq!(quarantined.len(), 1, "{}", rep.render());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(serve(&small_cfg(vec![], 0, 0)).is_err());
    }

    #[test]
    fn first_contact_tunes_persists_and_back_to_back_jobs_reuse_the_engine() {
        use crate::coordinator::verify_against_oracle;
        use crate::tune::TunedProfile;
        let d = tmpdir("firstcontact");
        generate(&d, Dims::new(48, 2, 512).unwrap(), 64, 21).unwrap();
        assert!(!d.join("tuned.toml").exists());
        // Two jobs on one dataset, one worker lane: the first
        // submission tunes the dataset, the second rides both the
        // persisted profile and the first job's warm engine. The warm-
        // engine path needs job two to actually *run*, so it differs in
        // a knob that blocks coalescing (adapt_every is inert while
        // adapt=false) yet keeps the engine identity intact.
        let two = {
            let mut j = JobSpec::new("two", &d);
            j.adapt_every = 32;
            j
        };
        let cfg = {
            let mut c = small_cfg(vec![JobSpec::new("one", &d), two], 1, 16);
            c.auto_tune = true;
            c
        };
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        // The profile was persisted next to the dataset (a tiny dataset
        // probes degenerate — safe defaults still count as a profile)…
        let prof = TunedProfile::load(&d.join("tuned.toml")).unwrap();
        assert!(prof.block >= 1 && prof.threads >= 1);
        // …the jobs streamed with its knobs…
        let one = rep.jobs.iter().find(|j| j.name == "one").unwrap();
        let two = rep.jobs.iter().find(|j| j.name == "two").unwrap();
        assert_eq!(one.blocks, 512usize.div_ceil(prof.block));
        // …and the second run rode the first's warm engine.
        assert!(!one.reused_engine);
        assert!(two.reused_engine, "{}", rep.render());
        assert!(rep.render().contains("1 warm-engine reuse(s)"), "{}", rep.render());
        verify_against_oracle(&d, 1e-8).unwrap();
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn warm_engine_is_evicted_when_its_budget_blocks_the_next_job() {
        // One worker, a budget that fits one job OR one warm engine but
        // not both: after job a completes, its resident engine's bytes
        // must be reclaimed (LaneMsg::DropEngine) so job b — a
        // different dataset — can be admitted instead of queueing
        // forever against memory the idle engine is holding.
        let a = tmpdir("evict_a");
        let b = tmpdir("evict_b");
        generate(&a, Dims::new(24, 2, 32).unwrap(), 8, 1).unwrap();
        generate(&b, Dims::new(24, 2, 32).unwrap(), 8, 2).unwrap();
        let mut ja = JobSpec::new("a", &a);
        ja.block = 8;
        ja.priority = 1; // runs first, leaves its engine warm
        let mut jb = JobSpec::new("b", &b);
        jb.block = 8;
        let est = ja.host_bytes(24, 3);
        let mut cfg = small_cfg(vec![ja, jb], 1, 0);
        cfg.mem_budget_bytes = est + est / 2;
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.jobs.len(), 2, "{}", rep.render());
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        std::fs::remove_dir_all(&a).unwrap();
        std::fs::remove_dir_all(&b).unwrap();
    }

    /// Compatible queued jobs sharing a dataset merge into one pass:
    /// the rider's answer IS the leader's streamed result, so it never
    /// occupies a worker lane. A job whose pinned knobs shape a
    /// different pipeline must NOT merge — it pays its own pass.
    #[test]
    fn compatible_jobs_coalesce_into_one_streaming_pass() {
        use crate::coordinator::verify_against_oracle;
        let d = tmpdir("coalesce");
        generate(&d, Dims::new(32, 2, 96).unwrap(), 16, 11).unwrap();
        let mut ja = JobSpec::new("lead", &d);
        ja.block = 16;
        ja.priority = 2; // dispatches first → becomes the pass leader
        let mut jb = JobSpec::new("rider", &d);
        jb.block = 16; // identical pipeline shape → rides lead's pass
        let mut jc = JobSpec::new("own-pass", &d);
        jc.block = 32; // pinned to a different block → incompatible
        jc.pins.block = true;
        assert!(ja.coalesces_with(&jb));
        assert!(!ja.coalesces_with(&jc));
        let rep = serve(&small_cfg(vec![ja, jb, jc], 1, 0)).unwrap();
        assert_eq!(rep.jobs.len(), 3, "{}", rep.render());
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        let lead = rep.jobs.iter().find(|j| j.name == "lead").unwrap();
        let rider = rep.jobs.iter().find(|j| j.name == "rider").unwrap();
        let own = rep.jobs.iter().find(|j| j.name == "own-pass").unwrap();
        // The rider's report mirrors the leader's single pass.
        assert_eq!(rider.coalesced_into.as_deref(), Some("lead"), "{}", rep.render());
        assert_eq!(rider.snps, lead.snps);
        assert_eq!(rider.blocks, lead.blocks);
        assert_eq!(lead.coalesced_into, None);
        assert_eq!(lead.blocks, 6, "96 SNPs at block 16 → 6 windows");
        // The incompatible job streamed its own (differently-shaped) pass.
        assert_eq!(own.coalesced_into, None, "pinned block must not merge");
        assert_eq!(own.blocks, 3, "96 SNPs at block 32 → 3 windows");
        verify_against_oracle(&d, 1e-8).unwrap();
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn pinned_knobs_survive_first_contact_tuning() {
        let d = tmpdir("pinned");
        generate(&d, Dims::new(32, 2, 256).unwrap(), 32, 9).unwrap();
        // Persist a profile whose block differs from the pinned one.
        let mut tuned = crate::tune::TunedProfile::safe_defaults(256, 2);
        tuned.block = 128;
        tuned.predicted_secs = 3.0;
        tuned.save(&d.join("tuned.toml")).unwrap();
        let mut j = JobSpec::new("pinned", &d);
        j.block = 32;
        j.pins.block = true;
        let mut cfg = small_cfg(vec![j], 1, 0);
        cfg.auto_tune = true;
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        // 256 SNPs at the pinned block 32 → 8 windows, not 2.
        assert_eq!(rep.jobs[0].blocks, 8);
        std::fs::remove_dir_all(&d).unwrap();
    }
}
