//! The multi-study scheduler: a fixed set of worker lanes multiplexing
//! GWAS jobs over the streaming coordinator.
//!
//! Topology (one more level of the paper's own pattern — fixed lanes,
//! bounded queues, backpressure by rendezvous):
//!
//! ```text
//!   config [job.*] ─┐
//!                   ├─▶ JobQueue ─admit─▶ worker lanes ──▶ coordinator::run
//!   spool *.toml ───┘   (priority,        (N threads,         │
//!                        mem budget,       rendezvous          ▼
//!                        dataset lock)     channels)      shared BlockCache
//! ```
//!
//! The dispatcher thread owns the queue and the memory ledger; workers
//! own nothing but the job they are streaming. Admission charges a job's
//! estimated host footprint against `mem_budget_bytes` and releases it
//! on completion, so a burst of submissions degrades to queueing — never
//! to swapping, which on the paper's analysis would destroy the
//! disk-bound pipeline's sustained peak.

use crate::config::ServiceConfig;
use crate::coordinator::{self, PipelineConfig};
use crate::error::{Error, Result};
use crate::service::queue::{Job, JobQueue, JobSpec, JobState};
use crate::service::report::{JobReport, ServiceReport};
use crate::storage::{dataset, BlockCache};
use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, sync_channel, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How often the dispatcher wakes to poll the spool directory while
/// jobs are in flight or the service is watching.
const SPOOL_POLL: Duration = Duration::from_millis(200);

struct WorkerLane {
    tx: Option<SyncSender<Job>>,
    handle: JoinHandle<()>,
    busy: bool,
}

/// Run the service to completion (or forever with `watch = true`):
/// enqueue the config's jobs plus any spool files, admit them under the
/// memory budget, stream them across the worker lanes, and return the
/// aggregate report once everything has drained.
pub fn serve(cfg: &ServiceConfig) -> Result<ServiceReport> {
    if cfg.workers == 0 {
        return Err(Error::Config("service.workers must be ≥ 1".into()));
    }
    if cfg.mem_budget_bytes == 0 {
        return Err(Error::Config("service.mem_budget_mb must be > 0".into()));
    }
    let cache = Arc::new(BlockCache::new(cfg.cache_bytes));
    // Partition the compute cores across the worker lanes: each job
    // inherits an equal share unless its spec pins `threads` itself.
    // (A share below a job's `ngpus + 1` clamps to serial kernels but
    // cannot shrink the pipeline's structural lane threads — see
    // `PipelineConfig::threads`.)
    let total_threads =
        if cfg.threads == 0 { crate::util::threads::available() } else { cfg.threads };
    let worker_threads = (total_threads / cfg.workers).max(1);
    let t_wall = Instant::now();

    // Worker lanes: rendezvous submission (depth 0 = the dispatcher only
    // hands a job to a lane that is ready to take it), shared results
    // channel back.
    let (res_tx, res_rx) = channel::<(usize, JobReport)>();
    let mut lanes: Vec<WorkerLane> = Vec::with_capacity(cfg.workers);
    for wi in 0..cfg.workers {
        let (tx, rx) = sync_channel::<Job>(0);
        let res_tx = res_tx.clone();
        // cache_bytes = 0 disables the cache entirely: jobs stream
        // straight from disk exactly as `cugwas run` does.
        let cache = (cfg.cache_bytes > 0).then(|| Arc::clone(&cache));
        let handle = std::thread::Builder::new()
            .name(format!("cugwas-svc-{wi}"))
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    // A panic inside the pipeline (poisoned pool assert,
                    // debug overflow, …) must become a failed report, not
                    // a silently dead lane: with other lanes still alive
                    // the dispatcher would otherwise wait on this job's
                    // completion forever.
                    let cache = cache.clone();
                    let report = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || run_job(&job, cache, worker_threads),
                    ))
                    .unwrap_or_else(|_| {
                        JobReport::failed(
                            job.spec.name.clone(),
                            job.spec.dataset.clone(),
                            job.spec.priority,
                            "worker panicked while streaming (see stderr)".into(),
                        )
                    });
                    if res_tx.send((wi, report)).is_err() {
                        break; // dispatcher gone — shut down
                    }
                }
            })
            .map_err(|e| Error::io("spawning service worker", e))?;
        lanes.push(WorkerLane { tx: Some(tx), handle, busy: false });
    }
    drop(res_tx); // workers hold the only senders now

    // Seed the queue from the config, then from the spool.
    let mut queue = JobQueue::new();
    let mut reports: Vec<JobReport> = Vec::new();
    for spec in &cfg.jobs {
        submit_spec(&mut queue, spec.clone(), &mut reports);
    }
    let mut spool_state = SpoolState::default();
    scan_spool(cfg.spool.as_deref(), &mut spool_state, &mut queue, &mut reports);
    for job in queue.fail_oversized(cfg.mem_budget_bytes) {
        reports.push(oversized_report(&job, cfg.mem_budget_bytes));
    }

    // ---- dispatch loop --------------------------------------------------
    let mut mem_in_use = 0u64;
    let mut busy_datasets: HashSet<PathBuf> = HashSet::new();
    let mut inflight: HashMap<usize, Job> = HashMap::new();
    loop {
        // Hand admissible jobs to idle lanes.
        while let Some(wi) = lanes.iter().position(|l| !l.busy) {
            let budget_left = cfg.mem_budget_bytes - mem_in_use;
            let Some(job) = queue.admit_next(budget_left, &busy_datasets) else { break };
            mem_in_use += job.est_bytes;
            busy_datasets.insert(job.dataset_key.clone());
            queue.set_state(job.id, JobState::Streaming);
            inflight.insert(wi, job.clone());
            let lane = &mut lanes[wi];
            lane.busy = true;
            lane.tx
                .as_ref()
                .expect("lane sender alive")
                .send(job)
                .map_err(|_| Error::Pipeline("service worker lane died".into()))?;
        }

        if inflight.is_empty() && queue.is_drained() {
            // Idle. One more spool scan; exit unless watching, new work
            // arrived, or a spool file is still settling (mid-write).
            let before = queue.all().len();
            scan_spool(cfg.spool.as_deref(), &mut spool_state, &mut queue, &mut reports);
            for job in queue.fail_oversized(cfg.mem_budget_bytes) {
                reports.push(oversized_report(&job, cfg.mem_budget_bytes));
            }
            if queue.all().len() > before {
                continue;
            }
            if cfg.watch || !spool_state.pending_bad.is_empty() {
                std::thread::sleep(SPOOL_POLL);
                continue;
            }
            break;
        }

        // Wait for a completion, polling the spool in between.
        match res_rx.recv_timeout(SPOOL_POLL) {
            Ok((wi, report)) => {
                let job = inflight.remove(&wi).expect("completion from a dispatched lane");
                mem_in_use -= job.est_bytes;
                busy_datasets.remove(&job.dataset_key);
                lanes[wi].busy = false;
                queue.set_state(
                    job.id,
                    if report.ok() { JobState::Done } else { JobState::Failed },
                );
                reports.push(report);
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                return Err(Error::Pipeline("all service worker lanes exited".into()));
            }
        }
        scan_spool(cfg.spool.as_deref(), &mut spool_state, &mut queue, &mut reports);
        for job in queue.fail_oversized(cfg.mem_budget_bytes) {
            reports.push(oversized_report(&job, cfg.mem_budget_bytes));
        }
    }

    // Drop the submission side so lanes exit, then join them.
    for lane in &mut lanes {
        lane.tx.take();
    }
    for lane in lanes {
        let _ = lane.handle.join();
    }

    Ok(ServiceReport {
        jobs: reports,
        wall_secs: t_wall.elapsed().as_secs_f64(),
        workers: cfg.workers,
        mem_budget_bytes: cfg.mem_budget_bytes,
        cache: cache.stats(),
    })
}

/// Estimate a spec's host footprint from the dataset's metadata (cheap:
/// reads `meta.txt` only).
fn estimate_bytes(spec: &JobSpec) -> Result<u64> {
    let meta = dataset::load_meta(&spec.dataset)?;
    Ok(spec.host_bytes(meta.dims.n, meta.dims.p()))
}

/// Queue a spec, or record an immediate failure (bad dataset, bad dims).
fn submit_spec(queue: &mut JobQueue, spec: JobSpec, reports: &mut Vec<JobReport>) {
    match estimate_bytes(&spec) {
        Ok(est) => {
            // Same canonicalization the pipeline keys the cache by.
            let key = dataset::canonical_key(&spec.dataset);
            queue.submit(spec, est, key);
        }
        Err(e) => reports.push(JobReport::failed(
            spec.name.clone(),
            spec.dataset.clone(),
            spec.priority,
            format!("cannot estimate job footprint: {e}"),
        )),
    }
}

fn oversized_report(job: &Job, budget: u64) -> JobReport {
    JobReport::failed(
        job.spec.name.clone(),
        job.spec.dataset.clone(),
        job.spec.priority,
        format!(
            "estimated host footprint {} exceeds the service memory budget {}",
            crate::util::human_bytes(job.est_bytes),
            crate::util::human_bytes(budget)
        ),
    )
}

/// Spool ingestion state: paths already ingested or reported, plus
/// parse failures awaiting confirmation (a file copied into the spool
/// non-atomically can be caught mid-write — it is only reported as bad
/// once a later scan sees it unchanged *and* still unparsable).
#[derive(Default)]
struct SpoolState {
    seen: HashSet<PathBuf>,
    pending_bad: HashMap<PathBuf, std::time::SystemTime>,
}

/// Ingest new `*.toml` job files from the spool directory. Malformed
/// files become failed-job reports rather than crashing the service.
/// Files are never deleted — the spool is an inbox the operator owns.
fn scan_spool(
    spool: Option<&Path>,
    state: &mut SpoolState,
    queue: &mut JobQueue,
    reports: &mut Vec<JobReport>,
) {
    let Some(dir) = spool else { return };
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|s| s.to_str()) == Some("toml"))
        .filter(|p| !state.seen.contains(p))
        .collect();
    paths.sort(); // deterministic FIFO for same-priority spool jobs
    for path in paths {
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("spool-job")
            .to_string();
        match ServiceConfig::job_from_file(&path, &name) {
            Ok(spec) => {
                state.seen.insert(path.clone());
                state.pending_bad.remove(&path);
                submit_spec(queue, spec, reports);
            }
            Err(e) => {
                let mtime = std::fs::metadata(&path).and_then(|m| m.modified()).ok();
                match (state.pending_bad.get(&path), mtime) {
                    // Unchanged since the last failing scan → genuinely bad.
                    (Some(prev), Some(now)) if *prev == now => {
                        state.seen.insert(path.clone());
                        state.pending_bad.remove(&path);
                        reports.push(JobReport::failed(
                            name,
                            path.clone(),
                            0,
                            format!("bad spool job file: {e}"),
                        ));
                    }
                    // First failure or still changing → retry next scan.
                    (_, Some(now)) => {
                        state.pending_bad.insert(path.clone(), now);
                    }
                    // File vanished / unstattable → report it as it is.
                    (_, None) => {
                        state.seen.insert(path.clone());
                        state.pending_bad.remove(&path);
                        reports.push(JobReport::failed(
                            name,
                            path.clone(),
                            0,
                            format!("bad spool job file: {e}"),
                        ));
                    }
                }
            }
        }
    }
}

/// Stream one job through the coordinator on this worker lane.
/// `worker_threads` is this lane's share of the host cores; a job spec
/// with an explicit `threads` overrides it.
fn run_job(job: &Job, cache: Option<Arc<BlockCache>>, worker_threads: usize) -> JobReport {
    let spec = &job.spec;
    let cfg = PipelineConfig {
        dataset: spec.dataset.clone(),
        block: spec.block,
        ngpus: spec.ngpus,
        host_buffers: spec.host_buffers,
        device_buffers: spec.device_buffers,
        mode: spec.mode,
        backend: spec.backend.clone(),
        read_throttle: spec.read_throttle,
        write_throttle: spec.write_throttle,
        resume: false,
        cache,
        threads: if spec.threads > 0 { spec.threads } else { worker_threads },
        lane_threads: spec.lane_threads,
        adapt: spec.adapt,
        adapt_every: spec.adapt_every,
    };
    match coordinator::run(&cfg) {
        Ok(rep) => JobReport::done(
            spec.name.clone(),
            spec.dataset.clone(),
            spec.priority,
            rep.wall_secs,
            rep.snps,
            rep.blocks,
            rep.metrics,
        ),
        Err(e) => JobReport::failed(
            spec.name.clone(),
            spec.dataset.clone(),
            spec.priority,
            e.to_string(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::problem::Dims;
    use crate::storage::generate;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cugwas_svc_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn small_cfg(jobs: Vec<JobSpec>, workers: usize, cache_mb: u64) -> ServiceConfig {
        ServiceConfig {
            workers,
            mem_budget_bytes: 1 << 30,
            cache_bytes: cache_mb << 20,
            threads: 0,
            spool: None,
            watch: false,
            jobs,
        }
    }

    /// The acceptance scenario: three jobs, two sharing a dataset — all
    /// complete, and the shared dataset's second pass hits the cache.
    #[test]
    fn three_jobs_two_sharing_a_dataset() {
        let d1 = tmpdir("shared");
        let d2 = tmpdir("solo");
        generate(&d1, Dims::new(32, 2, 96).unwrap(), 16, 11).unwrap();
        generate(&d2, Dims::new(32, 2, 64).unwrap(), 16, 12).unwrap();
        let mut j1 = JobSpec::new("shared-a", &d1);
        j1.block = 16;
        j1.priority = 2; // runs first → faults the cache in
        let mut j2 = JobSpec::new("shared-b", &d1);
        j2.block = 16;
        let mut j3 = JobSpec::new("solo", &d2);
        j3.block = 16;
        let rep = serve(&small_cfg(vec![j1, j2, j3], 2, 64)).unwrap();
        assert_eq!(rep.jobs.len(), 3);
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        assert!(rep.cache.hits > 0, "second pass over the shared dataset must hit");
        let shared_b = rep.jobs.iter().find(|j| j.name == "shared-b").unwrap();
        assert_eq!(shared_b.cache_hits, 6, "all 6 blocks of shared-b served from RAM");
        assert_eq!(rep.total_snps(), 96 + 96 + 64);
        std::fs::remove_dir_all(&d1).unwrap();
        std::fs::remove_dir_all(&d2).unwrap();
    }

    #[test]
    fn missing_dataset_fails_without_sinking_the_service() {
        let d = tmpdir("good");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        let mut ok = JobSpec::new("ok", &d);
        ok.block = 8;
        let bad = JobSpec::new("bad", "/nonexistent/dataset");
        let rep = serve(&small_cfg(vec![ok, bad], 1, 16)).unwrap();
        assert_eq!(rep.jobs.len(), 2);
        assert_eq!(rep.failed(), 1);
        assert!(rep.jobs.iter().any(|j| j.name == "ok" && j.ok()));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn oversized_job_fails_fast_under_tiny_budget() {
        let d = tmpdir("tiny");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        let mut j = JobSpec::new("too-big", &d);
        j.block = 8;
        let mut cfg = small_cfg(vec![j], 1, 16);
        cfg.mem_budget_bytes = 1; // nothing fits
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.failed(), 1);
        assert!(rep.jobs[0].error.as_deref().unwrap().contains("memory budget"));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn spool_jobs_are_ingested() {
        let d = tmpdir("spoolds");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        let spool = tmpdir("spooldir");
        std::fs::create_dir_all(&spool).unwrap();
        std::fs::write(
            spool.join("late.toml"),
            format!("[job]\ndataset = \"{}\"\nblock = 8\n", d.display()),
        )
        .unwrap();
        std::fs::write(spool.join("broken.toml"), "[job]\nblock = 8\n").unwrap(); // no dataset
        std::fs::write(spool.join("notes.txt"), "ignored").unwrap();
        let mut cfg = small_cfg(vec![], 1, 16);
        cfg.spool = Some(spool.clone());
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.jobs.len(), 2, "{}", rep.render());
        assert!(rep.jobs.iter().any(|j| j.name == "late" && j.ok()));
        assert!(rep.jobs.iter().any(|j| j.name == "broken" && !j.ok()));
        std::fs::remove_dir_all(&d).unwrap();
        std::fs::remove_dir_all(&spool).unwrap();
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(serve(&small_cfg(vec![], 0, 0)).is_err());
    }
}
