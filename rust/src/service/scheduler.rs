//! The multi-study scheduler: a fixed set of worker lanes multiplexing
//! GWAS jobs over the streaming coordinator.
//!
//! Topology (one more level of the paper's own pattern — fixed lanes,
//! bounded queues, backpressure by rendezvous):
//!
//! ```text
//!   config [job.*] ─┐
//!                   ├─▶ JobQueue ─admit─▶ worker lanes ──▶ coordinator::Engine
//!   spool *.toml ───┘   (priority,        (N threads,         │
//!                        mem budget,       rendezvous          ▼
//!                        dataset lock)     channels)      shared BlockCache
//! ```
//!
//! The dispatcher thread owns the queue and the memory ledger; each
//! worker owns the job it is streaming plus one *warm engine*: when the
//! next job targets the same dataset with a compatible configuration,
//! it executes on the resident [`Engine`] and inherits its preprocess,
//! aio reader, device lanes and buffer rings — the serve-side payoff of
//! the unified streaming core. A resident warm engine keeps its bytes
//! charged against the memory ledger (its rings and preprocess are
//! still alive) and is evicted — never a job starved — when queued work
//! could only be admitted by reclaiming it. Admission charges a job's
//! estimated host footprint against `mem_budget_bytes` and releases it
//! on completion — and since the zero-copy plane landed, that footprint
//! bills the refcounted *slab* circulation ([`JobSpec::host_bytes`]):
//! a block resident in the shared cache and streaming through a job is
//! one slab, not a cache copy plus a ring copy plus per-lane staging
//! duplicates,
//! so a burst of submissions degrades to queueing — never to swapping,
//! which on the paper's analysis would destroy the disk-bound
//! pipeline's sustained peak. Submission is also where
//! **tune-on-first-contact** happens: a dataset arriving without a
//! tuned profile is probed + planned once (cheap), the profile is
//! persisted next to it, and its DES prediction feeds the queue's
//! shortest-job-first ordering.
//!
//! **Job coalescing**: at dispatch time, every still-queued job that
//! would stream the *identical* pipeline over the leader's dataset
//! (same knobs, same offload mode/backend/throttles, same phenotype
//! batch — see [`JobSpec::coalesces_with`]) rides the leader's single
//! streaming pass instead of waiting for its own. Riders mirror the
//! leader's report under their own names with `coalesced_into` set; a
//! failed leader re-queues its riders untouched (they spent no retry
//! budget). A job pinning even one knob differently keeps its own pass.

use crate::config::ServiceConfig;
use crate::coordinator::{Engine, Metrics, PipelineConfig, ShutdownToken};
use crate::error::{Error, Result};
use crate::service::queue::{Job, JobQueue, JobSpec, JobState};
use crate::service::report::{JobReport, ServiceReport};
use crate::service::wal::{self, Wal, WalEvent};
use crate::storage::fault;
use crate::storage::{dataset, BlockCache};
use crate::tune::{self, PlanOpts, ProbeOpts, TunedProfile};
use crate::util::human_bytes;
use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How often the dispatcher wakes to poll the spool directory while
/// jobs are in flight or the service is watching.
const SPOOL_POLL: Duration = Duration::from_millis(200);

/// Disk-probe budget for tune-on-first-contact — kept small so a new
/// dataset's first submission costs milliseconds, not a second pass
/// over the file.
const FIRST_CONTACT_PROBE_BYTES: u64 = 8 << 20;

/// Process-global drain request — the one mailbox every drain source
/// writes to: the SIGINT handler (async-signal-safe: a store is all it
/// may do), the telemetry server's `POST /drain`, and the spool's
/// `control/drain` file. The dispatcher polls it once per turn.
static DRAIN_REQUESTED: AtomicBool = AtomicBool::new(false);

/// Ask the running service to drain: admission stops, in-flight jobs
/// checkpoint at their next segment boundary, the WAL is sealed, and
/// `serve` returns its report with exit status success.
pub fn request_drain() {
    DRAIN_REQUESTED.store(true, Ordering::Release);
}

/// Whether a drain has been requested (and not yet consumed by a new
/// `serve` run starting).
pub fn drain_requested() -> bool {
    DRAIN_REQUESTED.load(Ordering::Acquire)
}

extern "C" fn sigint_drain(_signum: i32) {
    // Async-signal-safe by construction: a single atomic store.
    DRAIN_REQUESTED.store(true, Ordering::Release);
}

/// Route Ctrl-C into a graceful drain instead of the default
/// kill-the-process. std has no signal API, so this declares libc's
/// `signal` directly (always linked on the unix targets this crate
/// supports); on other platforms Ctrl-C keeps its default meaning and
/// the control file / HTTP endpoint remain the drain levers.
pub fn install_drain_on_ctrl_c() {
    #[cfg(unix)]
    {
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        const SIGINT: i32 = 2;
        unsafe {
            signal(SIGINT, sigint_drain);
        }
    }
}

/// How the dispatcher attaches profiles at submission time.
#[derive(Clone, Copy)]
struct SubmitOpts {
    /// Probe + plan datasets that have no persisted profile.
    auto_tune: bool,
    /// Thread budget a job will actually run under (the worker share) —
    /// what the probe calibrates and the planner searches.
    plan_threads: usize,
}

/// What the dispatcher sends a worker lane.
enum LaneMsg {
    /// Stream this job; the token is the dispatcher's cancel/drain lever
    /// (checked by the engine at segment boundaries).
    Run(Job, ShutdownToken),
    /// Release the warm engine (the dispatcher reclaims its budget to
    /// admit queued work that would not otherwise fit).
    DropEngine,
}

struct WorkerLane {
    tx: Option<SyncSender<LaneMsg>>,
    handle: JoinHandle<()>,
    busy: bool,
}

/// Run the service to completion (or forever with `watch = true`):
/// enqueue the config's jobs plus any spool files, admit them under the
/// memory budget, stream them across the worker lanes, and return the
/// aggregate report once everything has drained.
pub fn serve(cfg: &ServiceConfig) -> Result<ServiceReport> {
    if cfg.workers == 0 {
        return Err(Error::Config("service.workers must be ≥ 1".into()));
    }
    if cfg.mem_budget_bytes == 0 {
        return Err(Error::Config("service.mem_budget_mb must be > 0".into()));
    }
    // A fresh serve consumes any stale drain request: the global is a
    // mailbox shared with signal handlers and the HTTP control endpoint,
    // and a previous run's drain must not abort this one at birth.
    DRAIN_REQUESTED.store(false, Ordering::Release);
    let low_water = cfg.disk_low_water_mb << 20;
    let cache = Arc::new(BlockCache::new(cfg.cache_bytes));
    // Partition the compute cores across the worker lanes: each job
    // inherits an equal share unless its spec pins `threads` itself.
    // (A share below a job's `ngpus + 1` clamps to serial kernels but
    // cannot shrink the pipeline's structural lane threads — see
    // `PipelineConfig::threads`.)
    let total_threads =
        if cfg.threads == 0 { crate::util::threads::available() } else { cfg.threads };
    let worker_threads = (total_threads / cfg.workers).max(1);
    let t_wall = Instant::now();

    // Worker lanes: rendezvous submission (depth 0 = the dispatcher only
    // hands a job to a lane that is ready to take it), shared results
    // channel back.
    let (res_tx, res_rx) = channel::<(usize, JobReport)>();
    let mut lanes: Vec<WorkerLane> = Vec::with_capacity(cfg.workers);
    for wi in 0..cfg.workers {
        let (tx, rx) = sync_channel::<LaneMsg>(0);
        let res_tx = res_tx.clone();
        // cache_bytes = 0 disables the cache entirely: jobs stream
        // straight from disk exactly as `cugwas run` does.
        let cache = (cfg.cache_bytes > 0).then(|| Arc::clone(&cache));
        let handle = std::thread::Builder::new()
            .name(format!("cugwas-svc-{wi}"))
            .spawn(move || {
                // The lane's warm engine: back-to-back jobs on one
                // dataset reuse its preprocess, aio reader, device lanes
                // and buffer rings instead of rebuilding the world.
                let mut engine: Option<Engine> = None;
                while let Ok(msg) = rx.recv() {
                    let (job, stop) = match msg {
                        LaneMsg::Run(job, stop) => (job, stop),
                        LaneMsg::DropEngine => {
                            engine = None;
                            continue;
                        }
                    };
                    // A panic inside the pipeline (poisoned pool assert,
                    // debug overflow, …) must become a failed report, not
                    // a silently dead lane: with other lanes still alive
                    // the dispatcher would otherwise wait on this job's
                    // completion forever.
                    let cache = cache.clone();
                    let report = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || run_job(&job, cache, worker_threads, &mut engine, &stop, low_water),
                    ))
                    .unwrap_or_else(|_| {
                        JobReport::failed(
                            job.spec.name.clone(),
                            job.spec.dataset.clone(),
                            job.spec.priority,
                            "worker panicked while streaming (see stderr)".into(),
                        )
                    });
                    if res_tx.send((wi, report)).is_err() {
                        break; // dispatcher gone — shut down
                    }
                }
            })
            .map_err(|e| Error::io("spawning service worker", e))?;
        lanes.push(WorkerLane { tx: Some(tx), handle, busy: false });
    }
    drop(res_tx); // workers hold the only senders now

    // The service WAL: explicit path, or `<spool>/service.wal` when a
    // spool exists, else off. Opening replays whatever the previous
    // process managed to record before it died.
    let wal_path =
        cfg.wal.clone().or_else(|| cfg.spool.as_ref().map(|s| s.join("service.wal")));
    let mut wal_records: Vec<wal::WalRecord> = Vec::new();
    let wal = match &wal_path {
        Some(p) => {
            let (w, records) = Wal::open(p)?;
            wal_records = records;
            Some(w)
        }
        None => None,
    };

    // Seed the queue from the config, then from the spool.
    let submit_opts = SubmitOpts { auto_tune: cfg.auto_tune, plan_threads: worker_threads };
    let mut queue = JobQueue::new();
    let mut reports: Vec<JobReport> = Vec::new();
    for spec in &cfg.jobs {
        submit_spec(&mut queue, spec.clone(), &mut reports, submit_opts);
    }
    let mut spool_state = SpoolState::default();
    scan_spool(cfg.spool.as_deref(), &mut spool_state, &mut queue, &mut reports, submit_opts);
    for job in queue.fail_oversized(cfg.mem_budget_bytes) {
        reports.push(oversized_report(&job, cfg.mem_budget_bytes));
    }

    // WAL replay: reconcile the re-discovered jobs (config + spool are
    // the durable spec store; the WAL never persists full specs) against
    // the previous process's lifecycle records, keyed by canonical spec
    // hash. Terminal outcomes are not re-run; jobs the old process died
    // holding resume from their v4 progress journals — a `kill -9`
    // mid-segment costs at most one replayed segment.
    let mut walled: HashSet<u64> = HashSet::new();
    if let Some(w) = &wal {
        if !wal_records.is_empty() {
            let states = wal::latest_states(&wal_records);
            let mut resumed = 0u64;
            let mut skipped = 0u64;
            for job in queue.all().to_vec() {
                match states.get(&wal::spec_hash(&job.spec)) {
                    Some(WalEvent::Done) => {
                        queue.set_state(job.id, JobState::Done);
                        walled.insert(job.id);
                        skipped += 1;
                    }
                    Some(WalEvent::Failed) => {
                        queue.set_state(job.id, JobState::Failed);
                        walled.insert(job.id);
                        skipped += 1;
                    }
                    Some(WalEvent::Streaming | WalEvent::Cancelled) => {
                        // Streaming: the process died mid-pass. Cancelled:
                        // a drain/deadline checkpointed it deliberately.
                        // Either way the journal holds its committed
                        // segments; resume instead of restarting.
                        queue.set_resume(job.id);
                        walled.insert(job.id);
                        resumed += 1;
                    }
                    Some(_) => {
                        // Submitted / admitted / coalesced: queued again
                        // from scratch — no progress reached the journal.
                        walled.insert(job.id);
                    }
                    None => {}
                }
            }
            crate::log_info!(
                "service",
                "WAL replay: {} record(s) from {} — {} job(s) resuming, {} already terminal",
                wal_records.len(),
                w.path().display(),
                resumed,
                skipped
            );
            if crate::telemetry::metrics_enabled() {
                let reg = crate::telemetry::registry::global();
                reg.wal_replays_total.add(1);
                reg.jobs_resumed_total.add(resumed);
            }
        }
        wal_note_new(w, &queue, &mut walled)?;
    }

    // ---- dispatch loop --------------------------------------------------
    let mut mem_in_use = 0u64;
    let mut busy_datasets: HashSet<PathBuf> = HashSet::new();
    let mut inflight: HashMap<usize, Job> = HashMap::new();
    // Riders coalesced onto the leader streaming on each lane — they
    // share its pass and its outcome (see the module docs).
    let mut riders: HashMap<usize, Vec<Job>> = HashMap::new();
    // Dispatch instants, for the per-job scheduler-track trace spans.
    let mut dispatched: HashMap<usize, Instant> = HashMap::new();
    // Per-lane residency of the warm engine: the dataset it is warm for
    // and the host bytes it keeps alive. Resident engines stay charged
    // against the admission budget (the rings and preprocess do not
    // vanish when the job's ledger entry is released) until the lane is
    // reused — or evicted, when queued work cannot otherwise fit.
    let mut warm: Vec<Option<(PathBuf, u64)>> = vec![None; cfg.workers];
    // Graceful-degradation state: per-job retry counts, per-dataset
    // backoff deadlines (a re-queued job is not re-admitted until its
    // dataset cools down), and per-dataset consecutive-failure streaks
    // feeding the quarantine gate.
    let mut attempts: HashMap<u64, u32> = HashMap::new();
    let mut cooling: HashMap<PathBuf, Instant> = HashMap::new();
    let mut fail_streak: HashMap<PathBuf, u32> = HashMap::new();
    // Lifecycle state: the per-lane shutdown tokens (cancel/drain reach
    // a streaming job through these), the drain latch and its timeout,
    // and the disk-space sentinel's pause flag.
    let mut tokens: HashMap<usize, ShutdownToken> = HashMap::new();
    let mut draining = false;
    let mut drain_deadline: Option<Instant> = None;
    let mut drain_timed_out = false;
    let mut disk_paused = false;
    loop {
        // Control plane: the spool's `control/drain` and `control/cancel`
        // files are consumed here; SIGINT and `POST /drain` land in the
        // same global the drain file feeds.
        for name in poll_controls(cfg.spool.as_deref()) {
            cancel_job(&name, &mut queue, &inflight, &tokens, &wal, &mut reports)?;
        }
        if drain_requested() && !draining {
            draining = true;
            let timeout = cfg.drain_timeout_secs.max(1);
            drain_deadline = Some(Instant::now() + Duration::from_secs(timeout));
            crate::log_info!(
                "service",
                "drain requested: admission stopped, {} in-flight job(s) checkpointing \
                 (timeout {timeout}s)",
                inflight.len()
            );
            if crate::telemetry::metrics_enabled() {
                crate::telemetry::registry::global().drains_total.add(1);
            }
            for tok in tokens.values() {
                tok.trigger();
            }
        }
        // Disk-space sentinel (admission side): below the low-water mark
        // the service stops admitting, sheds the shared cache, and — when
        // nothing is in flight to free space organically and nobody is
        // watching — fails the queued jobs with an error naming the
        // starved path rather than deadlocking.
        if low_water > 0 && !draining {
            if let Some(p) = disk_probe_path(cfg, &queue, &inflight) {
                match crate::util::disk_free_bytes(&p) {
                    Some(free) if free < low_water => {
                        if !disk_paused {
                            disk_paused = true;
                            let shed = cache.shed(0);
                            crate::log_warn!(
                                "service",
                                "free space on {} is below the low-water mark ({} < {}): \
                                 admission paused, {} of shared cache shed",
                                p.display(),
                                human_bytes(free),
                                human_bytes(low_water),
                                human_bytes(shed)
                            );
                            if crate::telemetry::metrics_enabled() {
                                crate::telemetry::registry::global().disk_low_water_total.add(1);
                            }
                        }
                        if inflight.is_empty() && !cfg.watch {
                            for job in queue.all().to_vec() {
                                if job.state != JobState::Queued {
                                    continue;
                                }
                                queue.set_state(job.id, JobState::Failed);
                                wal_append(&wal, WalEvent::Failed, &job.spec, None)?;
                                note_job_failed();
                                reports.push(JobReport::failed(
                                    job.spec.name.clone(),
                                    job.spec.dataset.clone(),
                                    job.spec.priority,
                                    format!(
                                        "free space on {} is below the service low-water \
                                         mark ({} < {}) — free disk space and resubmit",
                                        p.display(),
                                        human_bytes(free),
                                        human_bytes(low_water)
                                    ),
                                ));
                            }
                        }
                    }
                    Some(_) if disk_paused => {
                        disk_paused = false;
                        crate::log_info!(
                            "service",
                            "free space recovered on {} — admission resumed",
                            p.display()
                        );
                    }
                    _ => {}
                }
            }
        }
        // Hand admissible jobs to idle lanes (never while draining or
        // starved for disk — both gates pause admission, not the queue).
        while !draining && !disk_paused && lanes.iter().any(|l| !l.busy) {
            // Backoff: a dataset cooling down after a failure counts as
            // busy for admission (and for the eviction probe below).
            let now = Instant::now();
            cooling.retain(|_, until| *until > now);
            let mut blocked = busy_datasets.clone();
            blocked.extend(cooling.keys().cloned());
            let reserved: u64 = warm.iter().flatten().map(|(_, b)| *b).sum();
            let budget_left =
                cfg.mem_budget_bytes.saturating_sub(mem_in_use).saturating_sub(reserved);
            let Some(job) = queue.admit_next(budget_left, &blocked) else {
                // Nothing fits. Evict idle warm engines only when their
                // reserved bytes are what actually blocks admission —
                // queued work beats a warm cache, but an engine must
                // not be churned over a dataset lock.
                let unblocks = reserved > 0
                    && queue.would_admit(budget_left + reserved, &blocked);
                let mut evicted = false;
                if unblocks {
                    for (wi, lane) in lanes.iter().enumerate() {
                        if lane.busy || warm[wi].is_none() {
                            continue;
                        }
                        let tx = lane.tx.as_ref().expect("lane sender alive");
                        if tx.send(LaneMsg::DropEngine).is_ok() {
                            warm[wi] = None;
                            evicted = true;
                        }
                    }
                }
                if evicted {
                    continue;
                }
                break;
            };
            // Quarantine gate: a dataset that has failed this many jobs
            // in a row is presumed broken (bad sectors, truncated file);
            // burning a worker lane and the retry budget on every
            // subsequent job just delays the rest of the queue.
            let streak = fail_streak.get(&job.dataset_key).copied().unwrap_or(0);
            if streak >= fault::policy().quarantine_after {
                queue.set_state(job.id, JobState::Failed);
                note_job_failed();
                reports.push(JobReport::failed(
                    job.spec.name.clone(),
                    job.spec.dataset.clone(),
                    job.spec.priority,
                    format!(
                        "dataset quarantined after {streak} consecutive job failures — \
                         resolve the underlying fault and resubmit"
                    ),
                ));
                continue;
            }
            // Prefer the idle lane already warm on this job's dataset
            // (the reuse the engine refactor pays for), else any idle.
            let matching = (0..lanes.len()).filter(|&wi| !lanes[wi].busy).find(|&wi| {
                warm[wi].as_ref().is_some_and(|(ds, _)| *ds == job.dataset_key)
            });
            let Some(wi) = matching.or_else(|| (0..lanes.len()).find(|&wi| !lanes[wi].busy))
            else {
                // Defensive: the while-condition saw an idle lane, but if
                // the bookkeeping ever disagrees mid-turn this must fail
                // the dispatch turn — roll the admission back and retry
                // next tick — not panic the whole service.
                crate::log_warn!(
                    "service",
                    "no idle lane for admitted job '{}' — re-queueing for the next \
                     dispatch turn",
                    job.spec.name
                );
                queue.set_state(job.id, JobState::Queued);
                break;
            };
            mem_in_use += job.est_bytes;
            warm[wi] = None; // the resident engine is reused or replaced
            busy_datasets.insert(job.dataset_key.clone());
            queue.set_state(job.id, JobState::Streaming);
            wal_append(&wal, WalEvent::Admitted, &job.spec, None)?;
            // Coalesce compatible queued work onto this pass: one
            // stream over the dataset answers every identical spec.
            let lane_riders = queue.take_coalescable(&job);
            if !lane_riders.is_empty() {
                crate::log_info!(
                    "service",
                    "coalescing {} queued job(s) onto '{}' over {}",
                    lane_riders.len(),
                    job.spec.name,
                    job.dataset_key.display()
                );
                if crate::telemetry::metrics_enabled() {
                    crate::telemetry::registry::global()
                        .jobs_coalesced_total
                        .add(lane_riders.len() as u64);
                }
                for r in &lane_riders {
                    wal_append(&wal, WalEvent::Coalesced, &r.spec, None)?;
                }
                riders.insert(wi, lane_riders);
            }
            // The streaming record carries the progress-journal path the
            // engine will write — the breadcrumb a post-crash operator
            // (or debugger) follows from the WAL to the journal.
            let journal_path = dataset::DatasetPaths::new(&job.spec.dataset).progress();
            wal_append(&wal, WalEvent::Streaming, &job.spec, Some(&journal_path))?;
            let stop = ShutdownToken::new();
            tokens.insert(wi, stop.clone());
            inflight.insert(wi, job.clone());
            dispatched.insert(wi, Instant::now());
            let lane = &mut lanes[wi];
            lane.busy = true;
            lane.tx
                .as_ref()
                .expect("lane sender alive")
                .send(LaneMsg::Run(job, stop))
                .map_err(|_| Error::Pipeline("service worker lane died".into()))?;
        }

        // Publish the admission state for this dispatch turn: a scrape
        // renders pure registry state, so the gauges must be pushed
        // wherever they change.
        if crate::telemetry::metrics_enabled() {
            let reg = crate::telemetry::registry::global();
            reg.set_queue(queue.queued(), inflight.len(), mem_in_use, cfg.mem_budget_bytes);
            reg.set_cache(&cache.stats());
        }

        if draining {
            // Draining: no admission, no ingestion — the loop only waits
            // for the in-flight jobs to checkpoint, bounded by the
            // timeout (their journals are committed through their last
            // finished segment either way).
            if inflight.is_empty() {
                break;
            }
            if drain_deadline.is_some_and(|d| Instant::now() >= d) {
                crate::log_warn!(
                    "service",
                    "drain timeout: abandoning {} in-flight job(s) still streaming \
                     (their journals are committed through the last segment boundary)",
                    inflight.len()
                );
                drain_timed_out = true;
                break;
            }
        } else if inflight.is_empty() && queue.is_drained() {
            // Idle. One more spool scan; exit unless watching, new work
            // arrived, or a spool file is still settling (mid-write).
            let before = queue.all().len();
            scan_spool(
                cfg.spool.as_deref(),
                &mut spool_state,
                &mut queue,
                &mut reports,
                submit_opts,
            );
            for job in queue.fail_oversized(cfg.mem_budget_bytes) {
                reports.push(oversized_report(&job, cfg.mem_budget_bytes));
            }
            if let Some(w) = &wal {
                wal_note_new(w, &queue, &mut walled)?;
            }
            if queue.all().len() > before {
                continue;
            }
            if cfg.watch || !spool_state.pending_bad.is_empty() {
                std::thread::sleep(SPOOL_POLL);
                continue;
            }
            break;
        }

        // Wait for a completion, polling the spool in between.
        match res_rx.recv_timeout(SPOOL_POLL) {
            Ok((wi, report)) => {
                let job = inflight.remove(&wi).expect("completion from a dispatched lane");
                tokens.remove(&wi);
                if let Some(t0) = dispatched.remove(&wi) {
                    crate::telemetry::span(
                        "job",
                        "sched",
                        crate::telemetry::trace::TID_SCHED,
                        t0,
                        t0.elapsed(),
                        &[("id", job.id as u64), ("ok", u64::from(report.ok()))],
                    );
                }
                mem_in_use -= job.est_bytes;
                // A successful run leaves the engine warm on this lane;
                // its footprint stays charged until reuse or eviction.
                // A failed OR cancelled run dropped the engine.
                warm[wi] = (report.ok() && !report.cancelled)
                    .then(|| (job.dataset_key.clone(), job.est_bytes));
                busy_datasets.remove(&job.dataset_key);
                lanes[wi].busy = false;
                let lane_riders = riders.remove(&wi).unwrap_or_default();
                if report.cancelled {
                    // Cooperative stop (drain, deadline, cancel): the
                    // pass checkpointed at a segment boundary. Not a
                    // failure — no retry budget spent, no streak, and
                    // the WAL's `cancelled` record makes the next serve
                    // resume the journal instead of restarting. Riders
                    // rode a pass that stopped early: back to the queue
                    // untouched (a drain reports them cancelled at exit).
                    attempts.remove(&job.id);
                    cooling.remove(&job.dataset_key);
                    fail_streak.remove(&job.dataset_key);
                    for r in &lane_riders {
                        queue.set_state(r.id, JobState::Queued);
                    }
                    queue.set_state(job.id, JobState::Cancelled);
                    wal_append(&wal, WalEvent::Cancelled, &job.spec, None)?;
                    if crate::telemetry::metrics_enabled() {
                        crate::telemetry::registry::global().jobs_cancelled_total.add(1);
                    }
                    reports.push(report);
                } else if report.ok() {
                    attempts.remove(&job.id);
                    cooling.remove(&job.dataset_key);
                    fail_streak.remove(&job.dataset_key);
                    queue.set_state(job.id, JobState::Done);
                    wal_append(&wal, WalEvent::Done, &job.spec, None)?;
                    // Riders share the leader's outcome: the one pass
                    // answered them all, so each mirrors the leader's
                    // numbers under its own name, stamped with whose
                    // stream carried it.
                    for r in &lane_riders {
                        queue.set_state(r.id, JobState::Done);
                        wal_append(&wal, WalEvent::Done, &r.spec, None)?;
                        reports.push(
                            JobReport::done(
                                r.spec.name.clone(),
                                r.spec.dataset.clone(),
                                r.spec.priority,
                                report.wall_secs,
                                report.snps,
                                report.blocks,
                                report.metrics.clone().unwrap_or_else(Metrics::new),
                            )
                            .with_coalesced_into(report.name.clone()),
                        );
                    }
                    reports.push(report);
                } else {
                    // A failed pass answered nobody: riders go straight
                    // back to the queue with their retry budgets intact
                    // (only the leader's attempt counter advances).
                    for r in &lane_riders {
                        queue.set_state(r.id, JobState::Queued);
                    }
                    // Graceful degradation: a failed run re-enters the
                    // queue (bounded, with per-dataset backoff) before
                    // its failure is final — a transient fault costs a
                    // retry, not the job.
                    let tried = attempts.entry(job.id).or_insert(0);
                    *tried += 1;
                    let pol = fault::policy();
                    if *tried <= pol.job_retries {
                        let delay = Duration::from_millis(
                            pol.job_backoff_ms.saturating_mul(1u64 << (*tried - 1).min(10)),
                        );
                        crate::log_warn!(
                            "service",
                            "job '{}' failed ({}); re-queueing attempt {}/{} after {:.0?}",
                            job.spec.name,
                            report.error.as_deref().unwrap_or("unknown error"),
                            *tried,
                            pol.job_retries,
                            delay
                        );
                        cooling.insert(job.dataset_key.clone(), Instant::now() + delay);
                        fault::note_job_retry();
                        queue.set_state(job.id, JobState::Queued);
                        // The report is not recorded: one report per
                        // job, and this one's story isn't over.
                    } else {
                        attempts.remove(&job.id);
                        *fail_streak.entry(job.dataset_key.clone()).or_insert(0) += 1;
                        note_job_failed();
                        queue.set_state(job.id, JobState::Failed);
                        wal_append(&wal, WalEvent::Failed, &job.spec, None)?;
                        reports.push(report);
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                return Err(Error::Pipeline("all service worker lanes exited".into()));
            }
        }
        if !draining {
            scan_spool(
                cfg.spool.as_deref(),
                &mut spool_state,
                &mut queue,
                &mut reports,
                submit_opts,
            );
            for job in queue.fail_oversized(cfg.mem_budget_bytes) {
                reports.push(oversized_report(&job, cfg.mem_budget_bytes));
            }
            if let Some(w) = &wal {
                wal_note_new(w, &queue, &mut walled)?;
            }
        }
    }

    // A drain reports the work it deliberately did not finish: queued
    // jobs it refused to start and (on timeout) in-flight jobs it
    // abandoned. They stay non-terminal in the WAL, so the next serve
    // re-queues or resumes them — cancelled, never failed.
    if draining {
        for job in queue.all().to_vec() {
            if matches!(job.state, JobState::Queued | JobState::Admitted) {
                queue.set_state(job.id, JobState::Cancelled);
                reports.push(JobReport::cancelled(
                    job.spec.name.clone(),
                    job.spec.dataset.clone(),
                    job.spec.priority,
                    0.0,
                ));
            }
        }
        for job in inflight.values() {
            reports.push(JobReport::cancelled(
                job.spec.name.clone(),
                job.spec.dataset.clone(),
                job.spec.priority,
                0.0,
            ));
        }
    }

    // Drop the submission side so lanes exit, then join them — unless
    // the drain timed out with a lane still streaming: joining would
    // block on the very work the timeout gave up waiting for, so those
    // threads are detached instead (the results channel closes when
    // this function returns, and the lane exits at its next send).
    for lane in &mut lanes {
        lane.tx.take();
    }
    if !drain_timed_out {
        for lane in lanes {
            let _ = lane.handle.join();
        }
    }

    // Seal the WAL: the durable receipt that every record above was on
    // disk when the service exited cleanly.
    if let Some(w) = &wal {
        w.seal()?;
    }

    Ok(ServiceReport {
        jobs: reports,
        wall_secs: t_wall.elapsed().as_secs_f64(),
        workers: cfg.workers,
        mem_budget_bytes: cfg.mem_budget_bytes,
        cache: cache.stats(),
    })
}

/// Estimate a spec's host footprint from the dataset's metadata (cheap:
/// reads `meta.txt` only). The spec's knobs are the *planned* ones when
/// a tuned profile was attached (first-contact or `profile` key), so
/// admission bills the geometry the job will actually stream with
/// instead of a static worst-case — a tuned small-block plan no longer
/// gets rejected for a default ring it will never allocate.
fn estimate_bytes(spec: &JobSpec) -> Result<u64> {
    let meta = dataset::load_meta(&spec.dataset)?;
    Ok(spec.host_bytes(meta.dims.n, meta.dims.p()))
}

/// Tune-on-first-contact: make sure the spec carries a profile before
/// its footprint is estimated and its admission rank decided. An
/// existing `<dataset>/tuned.toml` is loaded; with `auto_tune` on, a
/// missing one is created by a cheap probe + plan and persisted next to
/// the dataset for every later job (and every other tool) to reuse.
/// Explicitly pinned knobs are never overridden; failures here only
/// lose the optimization, never the job.
fn attach_first_contact_profile(spec: &mut JobSpec, opts: SubmitOpts) {
    if spec.profile_attached || !opts.auto_tune {
        // An explicit `profile` key always wins over first contact, and
        // `auto_tune = false` means "stream exactly the configured
        // knobs" — neither probing nor applying a found profile.
        return;
    }
    let path = TunedProfile::default_path(&spec.dataset);
    let tuned = if path.exists() {
        match tune::profile::load_or_default(Some(&path), 0, 0) {
            Ok(t) => t,
            Err(e) => {
                crate::log_warn!("service", "ignoring unreadable profile {}: {e}", path.display());
                return;
            }
        }
    } else {
        match tune_first_contact(spec, opts.plan_threads, &path) {
            Some(t) => t,
            None => return,
        }
    };
    spec.apply_profile(&tuned);
}

/// Probe + plan a dataset the service has never seen, persisting the
/// profile beside it. `None` when the dataset is unreadable (the
/// estimate will fail the job with a better error) — probing never
/// sinks a submission.
///
/// This runs synchronously on the dispatcher thread, so it briefly
/// delays admission: ~10 MB of reads plus the quick kernel/memcpy
/// probes (tens of milliseconds). It is paid once per dataset *ever* —
/// the persisted profile short-circuits every later submission — and a
/// spool burst of K new datasets costs K probes before the first
/// dispatch, a bounded, amortized trade the module docs call out.
fn tune_first_contact(spec: &JobSpec, plan_threads: usize, out: &Path) -> Option<TunedProfile> {
    let meta = dataset::load_meta(&spec.dataset).ok()?;
    let popts = ProbeOpts {
        threads: plan_threads,
        max_disk_bytes: FIRST_CONTACT_PROBE_BYTES,
        read_throttle: spec.read_throttle,
        quick: true,
    };
    let rates = tune::probe_dataset(&spec.dataset, &popts).ok()?;
    let opts = PlanOpts {
        total_threads: plan_threads.max(1),
        max_lanes: spec.ngpus.max(1),
        host_mem_bytes: 0,
        max_block: 0,
        traits: spec.traits.max(1),
    };
    let profile = tune::plan(&rates, meta.dims, &opts);
    match profile.save(out) {
        Ok(()) => crate::log_info!(
            "service",
            "first contact with {}: tuned block {} × {} lane(s), {} host / {} device buffers \
             → {}",
            spec.dataset.display(),
            profile.block,
            profile.ngpus,
            profile.host_buffers,
            profile.device_buffers,
            out.display()
        ),
        Err(e) => {
            crate::log_warn!("service", "could not persist {}: {e}", out.display());
        }
    }
    Some(profile)
}

/// Queue a spec, or record an immediate failure (bad dataset, bad dims).
fn submit_spec(
    queue: &mut JobQueue,
    mut spec: JobSpec,
    reports: &mut Vec<JobReport>,
    opts: SubmitOpts,
) {
    attach_first_contact_profile(&mut spec, opts);
    match estimate_bytes(&spec) {
        Ok(est) => {
            // Same canonicalization the pipeline keys the cache by.
            let key = dataset::canonical_key(&spec.dataset);
            queue.submit(spec, est, key);
        }
        Err(e) => {
            note_job_failed();
            reports.push(JobReport::failed(
                spec.name.clone(),
                spec.dataset.clone(),
                spec.priority,
                format!("cannot estimate job footprint: {e}"),
            ));
        }
    }
}

/// Append one lifecycle record when a WAL is configured (a WAL-less
/// service pays nothing here). WAL failures are fatal to `serve`: a
/// service that cannot record its promises must stop making them — and
/// the chaos tests exploit exactly this to simulate a crash between a
/// state change and its record.
fn wal_append(
    wal: &Option<Wal>,
    ev: WalEvent,
    spec: &JobSpec,
    journal: Option<&Path>,
) -> Result<()> {
    match wal {
        Some(w) => w.append(ev, wal::spec_hash(spec), &spec.name, journal),
        None => Ok(()),
    }
}

/// Append a `submitted` record for every queued job the WAL has not
/// seen yet (new config sections, fresh spool arrivals). Jobs whose
/// replayed state already covers them are pre-seeded into `walled` so a
/// resumed job's `streaming` record is never regressed to `submitted`.
fn wal_note_new(wal: &Wal, queue: &JobQueue, walled: &mut HashSet<u64>) -> Result<()> {
    for job in queue.all() {
        if job.state == JobState::Queued && !walled.contains(&job.id) {
            wal.append(WalEvent::Submitted, wal::spec_hash(&job.spec), &job.spec.name, None)?;
            walled.insert(job.id);
        }
    }
    Ok(())
}

/// Consume the spool's control files: `control/drain` (its existence is
/// the request) feeds the same global as SIGINT and `POST /drain`;
/// `control/cancel` holds job names, one per line (`#` comments
/// allowed), returned for [`cancel_job`]. Both are noticed once, then
/// deleted — the control directory is a mailbox, not state.
fn poll_controls(spool: Option<&Path>) -> Vec<String> {
    let Some(dir) = spool else { return Vec::new() };
    let ctl = dir.join("control");
    let drain = ctl.join("drain");
    if drain.exists() {
        let _ = std::fs::remove_file(&drain);
        crate::log_info!("service", "drain control file noticed at {}", drain.display());
        request_drain();
    }
    let cancel = ctl.join("cancel");
    let Ok(text) = std::fs::read_to_string(&cancel) else { return Vec::new() };
    let _ = std::fs::remove_file(&cancel);
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(String::from)
        .collect()
}

/// Cancel a job by name: a queued job is cancelled outright (terminal
/// this run, re-queued by the next serve since config/spool still list
/// it); a streaming job has its shutdown token triggered and
/// checkpoints at its next segment boundary, flowing back through the
/// normal completion path as cancelled.
fn cancel_job(
    name: &str,
    queue: &mut JobQueue,
    inflight: &HashMap<usize, Job>,
    tokens: &HashMap<usize, ShutdownToken>,
    wal: &Option<Wal>,
    reports: &mut Vec<JobReport>,
) -> Result<()> {
    let mut hit = false;
    for job in queue.all().to_vec() {
        if job.spec.name == name && matches!(job.state, JobState::Queued | JobState::Admitted) {
            hit = true;
            queue.set_state(job.id, JobState::Cancelled);
            wal_append(wal, WalEvent::Cancelled, &job.spec, None)?;
            if crate::telemetry::metrics_enabled() {
                crate::telemetry::registry::global().jobs_cancelled_total.add(1);
            }
            reports.push(JobReport::cancelled(
                job.spec.name.clone(),
                job.spec.dataset.clone(),
                job.spec.priority,
                0.0,
            ));
            crate::log_info!("service", "cancelled queued job '{name}'");
        }
    }
    for (wi, job) in inflight {
        if job.spec.name == name {
            hit = true;
            if let Some(tok) = tokens.get(wi) {
                tok.trigger();
                crate::log_info!(
                    "service",
                    "cancel requested for streaming job '{name}' — checkpointing at the \
                     next segment boundary"
                );
            }
        }
    }
    if !hit {
        crate::log_warn!("service", "cancel control named unknown job '{name}'");
    }
    Ok(())
}

/// Where the disk-space sentinel looks: the spool's filesystem when one
/// exists (it holds the WAL and the control plane), else the filesystem
/// of whichever dataset the service is about to touch.
fn disk_probe_path(
    cfg: &ServiceConfig,
    queue: &JobQueue,
    inflight: &HashMap<usize, Job>,
) -> Option<PathBuf> {
    if let Some(s) = &cfg.spool {
        return Some(s.clone());
    }
    queue
        .all()
        .iter()
        .find(|j| j.state == JobState::Queued)
        .map(|j| j.dataset_key.clone())
        .or_else(|| inflight.values().next().map(|j| j.dataset_key.clone()))
}

/// Count one failed job in the telemetry registry. Successes are
/// counted by the engine when the run completes; failures never reach
/// that point, so every site that mints a failure report notes it here.
fn note_job_failed() {
    if crate::telemetry::metrics_enabled() {
        crate::telemetry::registry::global().jobs_failed_total.add(1);
    }
}

fn oversized_report(job: &Job, budget: u64) -> JobReport {
    note_job_failed();
    let spec = &job.spec;
    JobReport::failed(
        spec.name.clone(),
        spec.dataset.clone(),
        spec.priority,
        format!(
            "estimated host footprint {} ({} geometry: block {} × {} lane(s), {} host / {} \
             device buffers) exceeds the service memory budget {}",
            crate::util::human_bytes(job.est_bytes),
            if spec.predicted_secs.is_some() { "tuned" } else { "requested" },
            spec.block,
            spec.ngpus,
            spec.host_buffers,
            spec.device_buffers,
            crate::util::human_bytes(budget)
        ),
    )
}

/// Spool ingestion state: paths already ingested or reported, plus
/// parse failures awaiting confirmation (a file copied into the spool
/// non-atomically can be caught mid-write — it is only reported as bad
/// once a later scan sees it unchanged *and* still unparsable).
#[derive(Default)]
struct SpoolState {
    seen: HashSet<PathBuf>,
    pending_bad: HashMap<PathBuf, std::time::SystemTime>,
}

/// Ingest new `*.toml` job files from the spool directory. Malformed
/// files become failed-job reports rather than crashing the service.
/// Files are never deleted — the spool is an inbox the operator owns.
fn scan_spool(
    spool: Option<&Path>,
    state: &mut SpoolState,
    queue: &mut JobQueue,
    reports: &mut Vec<JobReport>,
    opts: SubmitOpts,
) {
    let Some(dir) = spool else { return };
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|s| s.to_str()) == Some("toml"))
        .filter(|p| !state.seen.contains(p))
        .collect();
    paths.sort(); // deterministic FIFO for same-priority spool jobs
    for path in paths {
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("spool-job")
            .to_string();
        match ServiceConfig::job_from_file(&path, &name) {
            Ok(spec) => {
                state.seen.insert(path.clone());
                state.pending_bad.remove(&path);
                submit_spec(queue, spec, reports, opts);
            }
            Err(e) => {
                let mtime = std::fs::metadata(&path).and_then(|m| m.modified()).ok();
                match (state.pending_bad.get(&path), mtime) {
                    // Unchanged since the last failing scan → genuinely
                    // bad: report it AND move it out of the inbox so it
                    // is never re-scanned (or silently retried forever).
                    (Some(prev), Some(now)) if *prev == now => {
                        state.seen.insert(path.clone());
                        state.pending_bad.remove(&path);
                        quarantine_spool_file(dir, &path, &e.to_string());
                        note_job_failed();
                        reports.push(JobReport::failed(
                            name,
                            path.clone(),
                            0,
                            format!("bad spool job file: {e}"),
                        ));
                    }
                    // First failure or still changing → retry next scan.
                    (_, Some(now)) => {
                        state.pending_bad.insert(path.clone(), now);
                    }
                    // File vanished / unstattable → report it as it is.
                    (_, None) => {
                        state.seen.insert(path.clone());
                        state.pending_bad.remove(&path);
                        note_job_failed();
                        reports.push(JobReport::failed(
                            name,
                            path.clone(),
                            0,
                            format!("bad spool job file: {e}"),
                        ));
                    }
                }
            }
        }
    }
}

/// Move a confirmed-bad spool file to `<spool>/quarantine/` with a
/// `<name>.reason` sidecar explaining why, so the operator's inbox
/// holds only live work and the diagnosis travels with the file. A
/// failed move only loses the tidying (the file stays in `seen`, so it
/// is not retried either way).
///
/// Durability: a rename is only atomic *in memory* until both directory
/// entries are synced — a crash in between can resurrect the file in
/// the inbox, or leave it moved with nothing recorded. Both directories
/// are fsynced after the rename, and the function is idempotent: a
/// retry that finds the file already moved (source gone, destination
/// present — exactly what a crash between rename and sync leaves)
/// completes the durable half instead of erroring. `pub(crate)` so the
/// lifecycle tests can drive the recovery path directly.
pub(crate) fn quarantine_spool_file(spool: &Path, path: &Path, reason: &str) {
    let qdir = spool.join("quarantine");
    if let Err(e) = std::fs::create_dir_all(&qdir) {
        crate::log_warn!("service", "cannot create {}: {e}", qdir.display());
        return;
    }
    let Some(file_name) = path.file_name() else { return };
    let dest = qdir.join(file_name);
    match std::fs::rename(path, &dest) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::NotFound && dest.exists() => {
            // Torn-rename recovery: a previous attempt crashed after the
            // rename — finish the syncs and the sidecar below.
        }
        Err(e) => {
            crate::log_warn!(
                "service",
                "cannot quarantine {}: {e} (leaving it in place)",
                path.display()
            );
            return;
        }
    }
    if fault::quarantine_crash() {
        crate::log_warn!(
            "service",
            "injected crash after quarantine rename of {} (directory syncs skipped)",
            dest.display()
        );
        return;
    }
    // Make the move durable on both ends: the destination directory
    // first (the entry must exist somewhere), then the source (the
    // inbox's forgetting of it).
    if let Err(e) = crate::coordinator::journal::sync_parent_dir(&dest)
        .and_then(|()| crate::coordinator::journal::sync_parent_dir(path))
    {
        crate::log_warn!("service", "cannot sync quarantine directories: {e}");
    }
    let mut sidecar = dest.clone().into_os_string();
    sidecar.push(".reason");
    if let Err(e) = std::fs::write(&sidecar, format!("{reason}\n")) {
        crate::log_warn!("service", "cannot write quarantine reason: {e}");
    }
    crate::log_warn!("service", "quarantined bad spool job file: {}", dest.display());
}

/// Stream one job through the unified engine on this worker lane.
/// `worker_threads` is this lane's share of the host cores; a job spec
/// with an explicit `threads` overrides it. `slot` is the lane's warm
/// engine: when the incoming job is compatible (same dataset identity,
/// mode, backend, thread budget, cache), the job executes on it and the
/// preprocess/reader/lanes/pools all carry over; otherwise a fresh
/// engine is opened and becomes the new resident. A failed run drops
/// the engine — the next job starts clean.
fn run_job(
    job: &Job,
    cache: Option<Arc<BlockCache>>,
    worker_threads: usize,
    slot: &mut Option<Engine>,
    stop: &ShutdownToken,
    disk_low_water: u64,
) -> JobReport {
    let spec = &job.spec;
    let cfg = PipelineConfig {
        dataset: spec.dataset.clone(),
        block: spec.block,
        ngpus: spec.ngpus,
        host_buffers: spec.host_buffers,
        device_buffers: spec.device_buffers,
        mode: spec.mode,
        backend: spec.backend.clone(),
        read_throttle: spec.read_throttle,
        write_throttle: spec.write_throttle,
        resume: job.resume,
        cache,
        threads: if spec.threads > 0 { spec.threads } else { worker_threads },
        lane_threads: spec.lane_threads,
        adapt: spec.adapt,
        adapt_every: spec.adapt_every,
        traits: spec.traits.max(1),
        perm_seed: spec.perm_seed,
        shutdown: Some(stop.clone()),
        deadline_at: (spec.deadline_secs > 0)
            .then(|| Instant::now() + Duration::from_secs(spec.deadline_secs)),
        disk_low_water,
    };
    let failed = |e: &Error| {
        JobReport::failed(spec.name.clone(), spec.dataset.clone(), spec.priority, e.to_string())
    };
    let (mut engine, reused) = match slot.take() {
        Some(engine) if engine.compatible(&cfg) => (engine, true),
        _ => match Engine::open(&cfg) {
            Ok(engine) => (engine, false),
            Err(e) => return failed(&e),
        },
    };
    let t0 = Instant::now();
    match engine.execute(&cfg) {
        Ok(rep) => {
            *slot = Some(engine);
            JobReport::done(
                spec.name.clone(),
                spec.dataset.clone(),
                spec.priority,
                rep.wall_secs,
                rep.snps,
                rep.blocks,
                rep.metrics,
            )
            .with_reused_engine(reused)
        }
        Err(Error::Cancelled(why)) => {
            // Cooperative stop at a segment boundary: the journal holds
            // every committed window, so this is a checkpoint, not a
            // failure. The engine is dropped (the slot stays empty) —
            // the lane starts clean if the job is ever resumed here.
            crate::log_info!("service", "job '{}' checkpointed: {why}", spec.name);
            JobReport::cancelled(
                spec.name.clone(),
                spec.dataset.clone(),
                spec.priority,
                t0.elapsed().as_secs_f64(),
            )
        }
        Err(e) => failed(&e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::problem::Dims;
    use crate::storage::generate;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cugwas_svc_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn small_cfg(jobs: Vec<JobSpec>, workers: usize, cache_mb: u64) -> ServiceConfig {
        ServiceConfig {
            workers,
            mem_budget_bytes: 1 << 30,
            cache_bytes: cache_mb << 20,
            threads: 0,
            spool: None,
            watch: false,
            // Off by default in tests: explicit blocks stay explicit and
            // no probe noise; the first-contact test opts back in.
            auto_tune: false,
            metrics_addr: None,
            wal: None,
            drain_timeout_secs: 30,
            disk_low_water_mb: 0,
            jobs,
            fault: Default::default(),
        }
    }

    /// The acceptance scenario: three jobs, two sharing a dataset — all
    /// complete, and the shared dataset's second pass hits the cache.
    #[test]
    fn three_jobs_two_sharing_a_dataset() {
        let d1 = tmpdir("shared");
        let d2 = tmpdir("solo");
        generate(&d1, Dims::new(32, 2, 96).unwrap(), 16, 11).unwrap();
        generate(&d2, Dims::new(32, 2, 64).unwrap(), 16, 12).unwrap();
        let mut j1 = JobSpec::new("shared-a", &d1);
        j1.block = 16;
        j1.priority = 2; // runs first → faults the cache in
        let mut j2 = JobSpec::new("shared-b", &d1);
        j2.block = 16;
        // This test is about the shared cache, so shared-b must stream
        // its own pass: nudge an (inert while adapt=false) knob so it
        // does not coalesce onto shared-a's pass instead.
        j2.adapt_every = 32;
        let mut j3 = JobSpec::new("solo", &d2);
        j3.block = 16;
        let rep = serve(&small_cfg(vec![j1, j2, j3], 2, 64)).unwrap();
        assert_eq!(rep.jobs.len(), 3);
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        assert!(rep.cache.hits > 0, "second pass over the shared dataset must hit");
        let shared_b = rep.jobs.iter().find(|j| j.name == "shared-b").unwrap();
        assert_eq!(shared_b.cache_hits, 6, "all 6 blocks of shared-b served from RAM");
        assert_eq!(rep.total_snps(), 96 + 96 + 64);
        std::fs::remove_dir_all(&d1).unwrap();
        std::fs::remove_dir_all(&d2).unwrap();
    }

    #[test]
    fn missing_dataset_fails_without_sinking_the_service() {
        let d = tmpdir("good");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        let mut ok = JobSpec::new("ok", &d);
        ok.block = 8;
        let bad = JobSpec::new("bad", "/nonexistent/dataset");
        let rep = serve(&small_cfg(vec![ok, bad], 1, 16)).unwrap();
        assert_eq!(rep.jobs.len(), 2);
        assert_eq!(rep.failed(), 1);
        assert!(rep.jobs.iter().any(|j| j.name == "ok" && j.ok()));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn oversized_job_fails_fast_under_tiny_budget() {
        let d = tmpdir("tiny");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        let mut j = JobSpec::new("too-big", &d);
        j.block = 8;
        let mut cfg = small_cfg(vec![j], 1, 16);
        cfg.mem_budget_bytes = 1; // nothing fits
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.failed(), 1);
        assert!(rep.jobs[0].error.as_deref().unwrap().contains("memory budget"));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn spool_jobs_are_ingested() {
        let d = tmpdir("spoolds");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        let spool = tmpdir("spooldir");
        std::fs::create_dir_all(&spool).unwrap();
        std::fs::write(
            spool.join("late.toml"),
            format!("[job]\ndataset = \"{}\"\nblock = 8\n", d.display()),
        )
        .unwrap();
        std::fs::write(spool.join("broken.toml"), "[job]\nblock = 8\n").unwrap(); // no dataset
        std::fs::write(spool.join("notes.txt"), "ignored").unwrap();
        let mut cfg = small_cfg(vec![], 1, 16);
        cfg.spool = Some(spool.clone());
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.jobs.len(), 2, "{}", rep.render());
        assert!(rep.jobs.iter().any(|j| j.name == "late" && j.ok()));
        assert!(rep.jobs.iter().any(|j| j.name == "broken" && !j.ok()));
        // The confirmed-bad file moved out of the inbox, with its
        // diagnosis in a sidecar.
        assert!(!spool.join("broken.toml").exists(), "bad file must leave the inbox");
        assert!(spool.join("quarantine/broken.toml").exists());
        let reason =
            std::fs::read_to_string(spool.join("quarantine/broken.toml.reason")).unwrap();
        assert!(reason.contains("missing dataset"), "{reason}");
        // Good files and strangers stay where the operator put them.
        assert!(spool.join("late.toml").exists());
        assert!(spool.join("notes.txt").exists());
        std::fs::remove_dir_all(&d).unwrap();
        std::fs::remove_dir_all(&spool).unwrap();
    }

    #[test]
    fn failing_jobs_retry_then_quarantine_the_dataset() {
        // Default policy: one retry per job, quarantine after three
        // consecutive final failures on a dataset. Four jobs on a
        // dataset whose data file vanished: the first three each run
        // twice (retry) and fail for real; the fourth never reaches a
        // worker lane — the quarantine gate fails it at admission.
        let d = tmpdir("quarantine");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        // Break the dataset *after* generation: the metadata stays
        // readable (admission estimates still work), streaming fails.
        std::fs::remove_file(dataset::DatasetPaths::new(&d).xr()).unwrap();
        let jobs = (0..4)
            .map(|i| {
                let mut j = JobSpec::new(format!("j{i}"), &d);
                j.block = 8;
                j
            })
            .collect();
        let rep = serve(&small_cfg(jobs, 1, 0)).unwrap();
        assert_eq!(rep.jobs.len(), 4, "{}", rep.render());
        assert_eq!(rep.failed(), 4, "{}", rep.render());
        let quarantined: Vec<_> = rep
            .jobs
            .iter()
            .filter(|j| j.error.as_deref().is_some_and(|e| e.contains("quarantined")))
            .collect();
        assert_eq!(quarantined.len(), 1, "{}", rep.render());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(serve(&small_cfg(vec![], 0, 0)).is_err());
    }

    /// A pre-requested drain stops admission before anything streams:
    /// queued jobs are reported cancelled (not failed), serve returns
    /// Ok, and the WAL is sealed — then a second serve picks the same
    /// jobs up from config and runs them to completion.
    #[test]
    fn drain_refuses_admission_and_the_next_serve_finishes_the_work() {
        let d = tmpdir("drainq");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        let spool = tmpdir("drainspool");
        std::fs::create_dir_all(spool.join("control")).unwrap();
        std::fs::write(spool.join("control/drain"), "").unwrap();
        let mut j = JobSpec::new("held", &d);
        j.block = 8;
        let mut cfg = small_cfg(vec![j], 1, 0);
        cfg.spool = Some(spool.clone());
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.failed(), 0, "drain must not fail jobs: {}", rep.render());
        assert_eq!(rep.cancelled(), 1, "{}", rep.render());
        assert_eq!(rep.total_snps(), 0, "nothing streamed under a pre-drain");
        assert!(!spool.join("control/drain").exists(), "control file consumed");
        // The implicit spool WAL was created and sealed.
        let wal_text = std::fs::read_to_string(spool.join("service.wal")).unwrap();
        assert!(wal_text.contains("\tsubmitted\t"), "{wal_text}");
        assert!(wal_text.lines().last().unwrap().contains("\tsealed\t"), "{wal_text}");
        // Restart: the job (still listed in config, non-terminal in the
        // WAL) runs to completion this time.
        let rep2 = serve(&cfg).unwrap();
        assert_eq!(rep2.failed(), 0, "{}", rep2.render());
        assert_eq!(rep2.total_snps(), 32, "{}", rep2.render());
        // Third serve: the WAL now records `done`, so nothing re-runs.
        let rep3 = serve(&cfg).unwrap();
        assert_eq!(rep3.total_snps(), 0, "terminal jobs must not re-run");
        std::fs::remove_dir_all(&d).unwrap();
        std::fs::remove_dir_all(&spool).unwrap();
    }

    /// The cancel control file kills a queued job by name without
    /// touching its siblings.
    #[test]
    fn cancel_control_file_cancels_a_queued_job_by_name() {
        let d = tmpdir("cancelq");
        generate(&d, Dims::new(24, 2, 32).unwrap(), 8, 5).unwrap();
        let spool = tmpdir("cancelspool");
        std::fs::create_dir_all(spool.join("control")).unwrap();
        // The victim is named before serve starts; the survivor runs.
        std::fs::write(spool.join("control/cancel"), "# operator note\nvictim\n").unwrap();
        let mut victim = JobSpec::new("victim", &d);
        victim.block = 8;
        let mut survivor = JobSpec::new("survivor", &d);
        survivor.block = 8;
        survivor.adapt_every = 32; // don't coalesce with the victim
        survivor.priority = 1;
        let mut cfg = small_cfg(vec![victim, survivor], 1, 0);
        cfg.spool = Some(spool.clone());
        cfg.wal = Some(spool.join("svc.wal"));
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        assert_eq!(rep.cancelled(), 1, "{}", rep.render());
        let v = rep.jobs.iter().find(|j| j.name == "victim").unwrap();
        assert!(v.cancelled && v.ok());
        let s = rep.jobs.iter().find(|j| j.name == "survivor").unwrap();
        assert!(!s.cancelled && s.ok() && s.snps == 32);
        let wal_text = std::fs::read_to_string(spool.join("svc.wal")).unwrap();
        assert!(wal_text.contains("\tcancelled\t"), "{wal_text}");
        std::fs::remove_dir_all(&d).unwrap();
        std::fs::remove_dir_all(&spool).unwrap();
    }

    /// The torn-rename recovery: a retry that finds the spool file
    /// already moved (source gone, destination present) completes the
    /// sidecar instead of erroring — the idempotent half of the
    /// quarantine durability story (the injected-crash half lives in
    /// `tests/service_lifecycle.rs`, which owns the fault injector).
    #[test]
    fn quarantine_retry_after_a_completed_rename_is_idempotent() {
        let spool = tmpdir("qidem");
        std::fs::create_dir_all(&spool).unwrap();
        let bad = spool.join("bad.toml");
        std::fs::write(&bad, "not toml at all").unwrap();
        quarantine_spool_file(&spool, &bad, "unparsable");
        assert!(!bad.exists());
        assert!(spool.join("quarantine/bad.toml").exists());
        // Simulate the crash-recovery retry: source is gone, destination
        // exists, and the sidecar from the first pass was lost.
        std::fs::remove_file(spool.join("quarantine/bad.toml.reason")).unwrap();
        quarantine_spool_file(&spool, &bad, "unparsable");
        let reason =
            std::fs::read_to_string(spool.join("quarantine/bad.toml.reason")).unwrap();
        assert!(reason.contains("unparsable"), "{reason}");
        assert!(spool.join("quarantine/bad.toml").exists(), "no double-move");
        std::fs::remove_dir_all(&spool).unwrap();
    }

    #[test]
    fn first_contact_tunes_persists_and_back_to_back_jobs_reuse_the_engine() {
        use crate::coordinator::verify_against_oracle;
        use crate::tune::TunedProfile;
        let d = tmpdir("firstcontact");
        generate(&d, Dims::new(48, 2, 512).unwrap(), 64, 21).unwrap();
        assert!(!d.join("tuned.toml").exists());
        // Two jobs on one dataset, one worker lane: the first
        // submission tunes the dataset, the second rides both the
        // persisted profile and the first job's warm engine. The warm-
        // engine path needs job two to actually *run*, so it differs in
        // a knob that blocks coalescing (adapt_every is inert while
        // adapt=false) yet keeps the engine identity intact.
        let two = {
            let mut j = JobSpec::new("two", &d);
            j.adapt_every = 32;
            j
        };
        let cfg = {
            let mut c = small_cfg(vec![JobSpec::new("one", &d), two], 1, 16);
            c.auto_tune = true;
            c
        };
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        // The profile was persisted next to the dataset (a tiny dataset
        // probes degenerate — safe defaults still count as a profile)…
        let prof = TunedProfile::load(&d.join("tuned.toml")).unwrap();
        assert!(prof.block >= 1 && prof.threads >= 1);
        // …the jobs streamed with its knobs…
        let one = rep.jobs.iter().find(|j| j.name == "one").unwrap();
        let two = rep.jobs.iter().find(|j| j.name == "two").unwrap();
        assert_eq!(one.blocks, 512usize.div_ceil(prof.block));
        // …and the second run rode the first's warm engine.
        assert!(!one.reused_engine);
        assert!(two.reused_engine, "{}", rep.render());
        assert!(rep.render().contains("1 warm-engine reuse(s)"), "{}", rep.render());
        verify_against_oracle(&d, 1e-8).unwrap();
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn warm_engine_is_evicted_when_its_budget_blocks_the_next_job() {
        // One worker, a budget that fits one job OR one warm engine but
        // not both: after job a completes, its resident engine's bytes
        // must be reclaimed (LaneMsg::DropEngine) so job b — a
        // different dataset — can be admitted instead of queueing
        // forever against memory the idle engine is holding.
        let a = tmpdir("evict_a");
        let b = tmpdir("evict_b");
        generate(&a, Dims::new(24, 2, 32).unwrap(), 8, 1).unwrap();
        generate(&b, Dims::new(24, 2, 32).unwrap(), 8, 2).unwrap();
        let mut ja = JobSpec::new("a", &a);
        ja.block = 8;
        ja.priority = 1; // runs first, leaves its engine warm
        let mut jb = JobSpec::new("b", &b);
        jb.block = 8;
        let est = ja.host_bytes(24, 3);
        let mut cfg = small_cfg(vec![ja, jb], 1, 0);
        cfg.mem_budget_bytes = est + est / 2;
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.jobs.len(), 2, "{}", rep.render());
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        std::fs::remove_dir_all(&a).unwrap();
        std::fs::remove_dir_all(&b).unwrap();
    }

    /// Compatible queued jobs sharing a dataset merge into one pass:
    /// the rider's answer IS the leader's streamed result, so it never
    /// occupies a worker lane. A job whose pinned knobs shape a
    /// different pipeline must NOT merge — it pays its own pass.
    #[test]
    fn compatible_jobs_coalesce_into_one_streaming_pass() {
        use crate::coordinator::verify_against_oracle;
        let d = tmpdir("coalesce");
        generate(&d, Dims::new(32, 2, 96).unwrap(), 16, 11).unwrap();
        let mut ja = JobSpec::new("lead", &d);
        ja.block = 16;
        ja.priority = 2; // dispatches first → becomes the pass leader
        let mut jb = JobSpec::new("rider", &d);
        jb.block = 16; // identical pipeline shape → rides lead's pass
        let mut jc = JobSpec::new("own-pass", &d);
        jc.block = 32; // pinned to a different block → incompatible
        jc.pins.block = true;
        assert!(ja.coalesces_with(&jb));
        assert!(!ja.coalesces_with(&jc));
        let rep = serve(&small_cfg(vec![ja, jb, jc], 1, 0)).unwrap();
        assert_eq!(rep.jobs.len(), 3, "{}", rep.render());
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        let lead = rep.jobs.iter().find(|j| j.name == "lead").unwrap();
        let rider = rep.jobs.iter().find(|j| j.name == "rider").unwrap();
        let own = rep.jobs.iter().find(|j| j.name == "own-pass").unwrap();
        // The rider's report mirrors the leader's single pass.
        assert_eq!(rider.coalesced_into.as_deref(), Some("lead"), "{}", rep.render());
        assert_eq!(rider.snps, lead.snps);
        assert_eq!(rider.blocks, lead.blocks);
        assert_eq!(lead.coalesced_into, None);
        assert_eq!(lead.blocks, 6, "96 SNPs at block 16 → 6 windows");
        // The incompatible job streamed its own (differently-shaped) pass.
        assert_eq!(own.coalesced_into, None, "pinned block must not merge");
        assert_eq!(own.blocks, 3, "96 SNPs at block 32 → 3 windows");
        verify_against_oracle(&d, 1e-8).unwrap();
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn pinned_knobs_survive_first_contact_tuning() {
        let d = tmpdir("pinned");
        generate(&d, Dims::new(32, 2, 256).unwrap(), 32, 9).unwrap();
        // Persist a profile whose block differs from the pinned one.
        let mut tuned = crate::tune::TunedProfile::safe_defaults(256, 2);
        tuned.block = 128;
        tuned.predicted_secs = 3.0;
        tuned.save(&d.join("tuned.toml")).unwrap();
        let mut j = JobSpec::new("pinned", &d);
        j.block = 32;
        j.pins.block = true;
        let mut cfg = small_cfg(vec![j], 1, 0);
        cfg.auto_tune = true;
        let rep = serve(&cfg).unwrap();
        assert_eq!(rep.failed(), 0, "{}", rep.render());
        // 256 SNPs at the pinned block 32 → 8 windows, not 2.
        assert_eq!(rep.jobs[0].blocks, 8);
        std::fs::remove_dir_all(&d).unwrap();
    }
}
