//! Per-job and aggregate reporting for the multi-study service — the
//! service-level counterpart of the pipeline's `Metrics` table.

use crate::coordinator::metrics::Counter;
use crate::coordinator::{Metrics, Phase};
use crate::storage::CacheStats;
use crate::telemetry::StallVerdict;
use crate::util::{human_bytes, human_duration, json};
use std::fmt::Write;
use std::path::PathBuf;
use std::time::Duration;

/// Outcome of one job, in completion order.
#[derive(Debug)]
pub struct JobReport {
    pub name: String,
    pub dataset: PathBuf,
    pub priority: i32,
    /// Wall seconds spent streaming (0 for jobs failed before running).
    pub wall_secs: f64,
    pub snps: usize,
    pub blocks: usize,
    pub snps_per_sec: f64,
    /// Blocks served from the shared cache / read from disk.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Data-plane bytes memcpy'd / handed over by reference while this
    /// job streamed (see [`Counter`]) — the zero-copy plane's receipts.
    pub bytes_copied: u64,
    pub bytes_borrowed: u64,
    /// Full phase accounting (absent for jobs that never ran).
    pub metrics: Option<Metrics>,
    /// Whole-run stall attribution (absent for jobs that never ran):
    /// which resource bounded the stream and by what share of wall time.
    pub stall: Option<StallVerdict>,
    /// `Some` means the job failed with this error.
    pub error: Option<String>,
    /// The job was checkpointed and stopped at a segment boundary
    /// (drain, deadline, or explicit cancel) rather than run to
    /// completion. Deliberately **not** a failure: `error` stays `None`
    /// so a drained service exits 0, and the job's progress journal
    /// survives for a later resume.
    pub cancelled: bool,
    /// The job rode a warm engine left by the previous job on the same
    /// dataset (preprocess, reader, lanes and pools all reused).
    pub reused_engine: bool,
    /// `Some(leader)` when the scheduler coalesced this job onto the
    /// named job's streaming pass instead of streaming it separately —
    /// the two specs resolved to identical pipelines over the same
    /// dataset, so one pass answers both.
    pub coalesced_into: Option<String>,
}

impl JobReport {
    /// A job that failed before (or instead of) streaming.
    pub fn failed(name: impl Into<String>, dataset: PathBuf, priority: i32, error: String) -> Self {
        JobReport {
            name: name.into(),
            dataset,
            priority,
            wall_secs: 0.0,
            snps: 0,
            blocks: 0,
            snps_per_sec: 0.0,
            cache_hits: 0,
            cache_misses: 0,
            bytes_copied: 0,
            bytes_borrowed: 0,
            metrics: None,
            stall: None,
            error: Some(error),
            cancelled: false,
            reused_engine: false,
            coalesced_into: None,
        }
    }

    /// A job stopped cooperatively at a segment boundary with its
    /// progress checkpointed (drain, deadline, or explicit cancel).
    pub fn cancelled(
        name: impl Into<String>,
        dataset: PathBuf,
        priority: i32,
        wall_secs: f64,
    ) -> Self {
        JobReport {
            name: name.into(),
            dataset,
            priority,
            wall_secs,
            snps: 0,
            blocks: 0,
            snps_per_sec: 0.0,
            cache_hits: 0,
            cache_misses: 0,
            bytes_copied: 0,
            bytes_borrowed: 0,
            metrics: None,
            stall: None,
            error: None,
            cancelled: true,
            reused_engine: false,
            coalesced_into: None,
        }
    }

    /// A job that streamed to completion.
    pub fn done(
        name: impl Into<String>,
        dataset: PathBuf,
        priority: i32,
        wall_secs: f64,
        snps: usize,
        blocks: usize,
        metrics: Metrics,
    ) -> Self {
        let stall = StallVerdict::from_metrics(&metrics, wall_secs);
        JobReport {
            name: name.into(),
            dataset,
            priority,
            wall_secs,
            snps,
            blocks,
            snps_per_sec: snps as f64 / wall_secs.max(1e-12),
            cache_hits: metrics.count(Phase::CacheHit),
            cache_misses: metrics.count(Phase::CacheMiss),
            bytes_copied: metrics.bytes(Counter::BytesCopied),
            bytes_borrowed: metrics.bytes(Counter::BytesBorrowed),
            metrics: Some(metrics),
            stall: Some(stall),
            error: None,
            cancelled: false,
            reused_engine: false,
            coalesced_into: None,
        }
    }

    /// Mark whether this job ran on a reused engine.
    pub fn with_reused_engine(mut self, reused: bool) -> Self {
        self.reused_engine = reused;
        self
    }

    /// Mark this report as a coalesced rider on `leader`'s pass.
    pub fn with_coalesced_into(mut self, leader: impl Into<String>) -> Self {
        self.coalesced_into = Some(leader.into());
        self
    }

    pub fn ok(&self) -> bool {
        self.error.is_none()
    }

    /// One JSON object describing this job — the machine-readable face
    /// of the report (`--report-json`). Hand-rolled against
    /// [`crate::util::json`]; phase totals render only for phases that
    /// fired.
    pub fn to_json(&self) -> String {
        let mut o = String::with_capacity(512);
        let _ = write!(
            o,
            "{{\"name\":\"{}\",\"dataset\":\"{}\",\"priority\":{},\"ok\":{},",
            json::escape(&self.name),
            json::escape(&self.dataset.to_string_lossy()),
            self.priority,
            self.ok(),
        );
        let _ = write!(o, "\"cancelled\":{},", self.cancelled);
        match &self.error {
            Some(e) => {
                let _ = write!(o, "\"error\":\"{}\",", json::escape(e));
            }
            None => o.push_str("\"error\":null,"),
        }
        let _ = write!(
            o,
            "\"wall_secs\":{},\"snps\":{},\"blocks\":{},\"snps_per_sec\":{},\
             \"cache_hits\":{},\"cache_misses\":{},\"bytes_copied\":{},\"bytes_borrowed\":{},\
             \"reused_engine\":{},",
            json::num(self.wall_secs),
            self.snps,
            self.blocks,
            json::num(self.snps_per_sec),
            self.cache_hits,
            self.cache_misses,
            self.bytes_copied,
            self.bytes_borrowed,
            self.reused_engine,
        );
        match &self.coalesced_into {
            Some(leader) => {
                let _ = write!(o, "\"coalesced_into\":\"{}\",", json::escape(leader));
            }
            None => o.push_str("\"coalesced_into\":null,"),
        }
        match &self.stall {
            Some(v) => {
                let _ = write!(
                    o,
                    "\"stall\":{{\"kind\":\"{}\",\"share\":{}}},",
                    v.kind.as_str(),
                    json::num(v.share)
                );
            }
            None => o.push_str("\"stall\":null,"),
        }
        o.push_str("\"phases\":{");
        if let Some(m) = &self.metrics {
            let mut first = true;
            for ph in Phase::ALL {
                let c = m.count(ph);
                if c == 0 {
                    continue;
                }
                if !first {
                    o.push(',');
                }
                first = false;
                let _ = write!(
                    o,
                    "\"{}\":{{\"secs\":{},\"count\":{}}}",
                    ph.as_str(),
                    json::num(m.total(ph).as_secs_f64()),
                    c
                );
            }
        }
        o.push_str("}}");
        o
    }
}

/// Aggregate run summary printed by `cugwas serve`.
#[derive(Debug)]
pub struct ServiceReport {
    /// Jobs in completion order (failures included).
    pub jobs: Vec<JobReport>,
    /// Service wall clock, submission of the first job to the last drain.
    pub wall_secs: f64,
    pub workers: usize,
    pub mem_budget_bytes: u64,
    /// Final counters of the shared block cache.
    pub cache: CacheStats,
}

impl ServiceReport {
    pub fn total_snps(&self) -> usize {
        self.jobs.iter().map(|j| j.snps).sum()
    }

    pub fn failed(&self) -> usize {
        self.jobs.iter().filter(|j| !j.ok()).count()
    }

    /// Jobs checkpointed and stopped rather than completed (resumable).
    pub fn cancelled(&self) -> usize {
        self.jobs.iter().filter(|j| j.cancelled).count()
    }

    /// Aggregate throughput: all streamed SNPs over the service wall time.
    pub fn agg_snps_per_sec(&self) -> f64 {
        self.total_snps() as f64 / self.wall_secs.max(1e-12)
    }

    /// Render the full report: one row per job, per-job phase tables,
    /// then the aggregate and cache lines.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<16}{:>9}{:>6}{:>8}{:>10}{:>12}{:>12}{:>8}{:>8}\n",
            "job", "state", "prio", "blocks", "snps", "wall", "SNPs/s", "hits", "miss"
        ));
        for j in &self.jobs {
            let state = if j.cancelled {
                "cancelled"
            } else if j.ok() {
                "done"
            } else {
                "failed"
            };
            out.push_str(&format!(
                "{:<16}{:>9}{:>6}{:>8}{:>10}{:>12}{:>12.0}{:>8}{:>8}\n",
                truncate(&j.name, 15),
                state,
                j.priority,
                j.blocks,
                j.snps,
                human_duration(Duration::from_secs_f64(j.wall_secs)),
                j.snps_per_sec,
                j.cache_hits,
                j.cache_misses,
            ));
            if let Some(err) = &j.error {
                out.push_str(&format!("  ^ error: {err}\n"));
            }
        }
        for j in &self.jobs {
            if let Some(m) = &j.metrics {
                out.push_str(&format!("\nphases for job '{}':\n", j.name));
                out.push_str(&m.table(Duration::from_secs_f64(j.wall_secs)));
                if let Some(v) = &j.stall {
                    out.push_str(&format!("stall: {}\n", v.render()));
                }
            }
        }
        let reused = self.jobs.iter().filter(|j| j.reused_engine).count();
        out.push_str(&format!(
            "\nservice: {} job(s) ({} failed, {} cancelled) on {} worker lane(s), \
             mem budget {}, {} warm-engine reuse(s)\n",
            self.jobs.len(),
            self.failed(),
            self.cancelled(),
            self.workers,
            human_bytes(self.mem_budget_bytes),
            reused,
        ));
        out.push_str(&format!(
            "aggregate: {} SNPs in {} — {:.0} SNPs/s across the fleet\n",
            self.total_snps(),
            human_duration(Duration::from_secs_f64(self.wall_secs)),
            self.agg_snps_per_sec(),
        ));
        out.push_str(&format!(
            "block cache: {} hits / {} misses, {} resident in {} entries (budget {}), \
             {} eviction(s)\n",
            self.cache.hits,
            self.cache.misses,
            human_bytes(self.cache.bytes),
            self.cache.entries,
            human_bytes(self.cache.capacity_bytes),
            self.cache.evictions,
        ));
        out
    }

    /// The whole service run as one JSON object (`--report-json`):
    /// aggregates, final cache counters, and one object per job in
    /// completion order.
    pub fn to_json(&self) -> String {
        let mut o = String::with_capacity(1024 + 512 * self.jobs.len());
        let _ = write!(
            o,
            "{{\"wall_secs\":{},\"workers\":{},\"mem_budget_bytes\":{},\"total_snps\":{},\
             \"failed\":{},\"agg_snps_per_sec\":{},",
            json::num(self.wall_secs),
            self.workers,
            self.mem_budget_bytes,
            self.total_snps(),
            self.failed(),
            json::num(self.agg_snps_per_sec()),
        );
        let _ = write!(
            o,
            "\"cache\":{{\"hits\":{},\"misses\":{},\"insertions\":{},\"evictions\":{},\
             \"bytes\":{},\"entries\":{},\"capacity_bytes\":{}}},",
            self.cache.hits,
            self.cache.misses,
            self.cache.insertions,
            self.cache.evictions,
            self.cache.bytes,
            self.cache.entries,
            self.cache.capacity_bytes,
        );
        o.push_str("\"jobs\":[");
        for (i, j) in self.jobs.iter().enumerate() {
            if i > 0 {
                o.push(',');
            }
            o.push_str(&j.to_json());
        }
        o.push_str("]}");
        o
    }
}

fn truncate(s: &str, max: usize) -> &str {
    match s.char_indices().nth(max) {
        Some((i, _)) => &s[..i],
        None => s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_includes_jobs_aggregate_and_cache() {
        let mut m = Metrics::new();
        m.add(Phase::CacheHit, Duration::from_millis(1));
        m.add(Phase::CacheHit, Duration::from_millis(1));
        m.add(Phase::CacheMiss, Duration::ZERO);
        let rep = ServiceReport {
            jobs: vec![
                JobReport::done("alpha", PathBuf::from("/d1"), 1, 2.0, 4096, 16, m),
                JobReport::failed("beta", PathBuf::from("/d2"), 0, "dataset missing".into()),
            ],
            wall_secs: 2.5,
            workers: 2,
            mem_budget_bytes: 1 << 30,
            cache: CacheStats { hits: 2, misses: 1, ..CacheStats::default() },
        };
        assert_eq!(rep.total_snps(), 4096);
        assert_eq!(rep.failed(), 1);
        let s = rep.render();
        assert!(s.contains("alpha"), "{s}");
        assert!(s.contains("beta"), "{s}");
        assert!(s.contains("dataset missing"), "{s}");
        assert!(s.contains("block cache: 2 hits / 1 misses"), "{s}");
        assert!(s.contains("phases for job 'alpha'"), "{s}");
        assert!(s.contains("cache_hit"), "{s}");
    }

    #[test]
    fn done_report_pulls_cache_counts_from_metrics() {
        let mut m = Metrics::new();
        for _ in 0..3 {
            m.add(Phase::CacheHit, Duration::ZERO);
        }
        m.add(Phase::CacheMiss, Duration::ZERO);
        m.add_bytes(Counter::BytesBorrowed, 4096);
        let j = JobReport::done("x", PathBuf::from("/d"), 0, 1.0, 100, 4, m);
        assert_eq!(j.cache_hits, 3);
        assert_eq!(j.cache_misses, 1);
        assert_eq!(j.bytes_borrowed, 4096);
        assert_eq!(j.bytes_copied, 0);
        assert!(j.ok());
        assert!(j.stall.is_some());
    }

    #[test]
    fn done_report_attributes_stall_from_metrics() {
        let mut m = Metrics::new();
        m.add(Phase::ReadWait, Duration::from_millis(700));
        let j = JobReport::done("x", PathBuf::from("/d"), 0, 1.0, 100, 4, m);
        let v = j.stall.unwrap();
        assert_eq!(v.kind, crate::telemetry::StallKind::ReadBound);
        assert!((v.share - 0.7).abs() < 1e-9);
    }

    #[test]
    fn cancelled_jobs_are_not_failures() {
        let rep = ServiceReport {
            jobs: vec![
                JobReport::cancelled("halted", PathBuf::from("/d1"), 0, 1.25),
                JobReport::failed("broken", PathBuf::from("/d2"), 0, "boom".into()),
            ],
            wall_secs: 2.0,
            workers: 1,
            mem_budget_bytes: 1 << 20,
            cache: CacheStats::default(),
        };
        assert_eq!(rep.failed(), 1, "cancellation must not count as failure");
        assert_eq!(rep.cancelled(), 1);
        assert!(rep.jobs[0].ok(), "cancelled job carries no error");
        let s = rep.render();
        assert!(s.contains("cancelled"), "{s}");
        assert!(s.contains("1 failed, 1 cancelled"), "{s}");
        let j = rep.jobs[0].to_json();
        assert!(j.contains("\"cancelled\":true"), "{j}");
        assert!(rep.jobs[1].to_json().contains("\"cancelled\":false"));
    }

    #[test]
    fn json_report_is_well_formed_and_escaped() {
        let mut m = Metrics::new();
        m.add(Phase::Sloop, Duration::from_millis(250));
        m.add(Phase::ReadWait, Duration::from_millis(500));
        let rep = ServiceReport {
            jobs: vec![
                JobReport::done("alpha", PathBuf::from("/d1"), 1, 1.0, 100, 4, m),
                JobReport::failed("bad\"name", PathBuf::from("/d2"), 0, "line1\nline2".into()),
            ],
            wall_secs: 1.5,
            workers: 2,
            mem_budget_bytes: 1 << 20,
            cache: CacheStats { hits: 7, ..CacheStats::default() },
        };
        let s = rep.to_json();
        // Structural spot checks (no JSON parser in a std-only crate):
        // balanced braces/brackets and the fields the consumers grep for.
        assert_eq!(s.matches('{').count(), s.matches('}').count(), "{s}");
        assert_eq!(s.matches('[').count(), s.matches(']').count(), "{s}");
        assert!(s.contains("\"total_snps\":100"), "{s}");
        assert!(s.contains("\"failed\":1"), "{s}");
        assert!(s.contains("\"hits\":7"), "{s}");
        assert!(s.contains("\"stall\":{\"kind\":\"read_bound\""), "{s}");
        assert!(s.contains("\"read_wait\":{\"secs\":0.5,\"count\":1}"), "{s}");
        assert!(s.contains("bad\\\"name"), "quotes escaped: {s}");
        assert!(s.contains("line1\\nline2"), "newlines escaped: {s}");
        assert!(s.contains("\"stall\":null"), "failed job carries no verdict: {s}");
    }
}
