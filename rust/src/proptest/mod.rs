//! A miniature property-testing framework (the `proptest` crate is
//! unavailable offline — see DESIGN.md §4).
//!
//! Provides what the coordinator invariants need: seeded generators,
//! `forall`-style runners with iteration counts, and greedy input
//! shrinking on failure. Deterministic: failures print the seed and the
//! shrunk case so they replay exactly.

use crate::util::XorShift;

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Convenience assertion for property bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Seeded input generator handed to property bodies.
pub struct Gen {
    rng: XorShift,
    /// Log of generated scalars, used for shrinking replay.
    log: Vec<u64>,
    /// When replaying a shrink candidate: predetermined values.
    replay: Option<Vec<u64>>,
    cursor: usize,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: XorShift::new(seed), log: Vec::new(), replay: None, cursor: 0 }
    }

    fn replaying(values: Vec<u64>, seed: u64) -> Self {
        Gen { rng: XorShift::new(seed), log: Vec::new(), replay: Some(values), cursor: 0 }
    }

    fn next_raw(&mut self, fresh: impl FnOnce(&mut XorShift) -> u64) -> u64 {
        let v = match &self.replay {
            Some(vals) if self.cursor < vals.len() => vals[self.cursor],
            _ => fresh(&mut self.rng),
        };
        self.cursor += 1;
        self.log.push(v);
        v
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        let raw = self.next_raw(|r| r.below(span));
        lo + (raw % span) as usize
    }

    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        self.next_raw(|r| r.next_u64())
    }

    /// Boolean with probability `p` of true.
    pub fn bool_p(&mut self, p: f64) -> bool {
        let raw = self.next_raw(|r| r.below(1 << 32));
        (raw as f64 / (1u64 << 32) as f64) < p
    }

    /// f64 in `[lo, hi)` with 2^32 grain (replayable/shrinkable).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let raw = self.next_raw(|r| r.below(1 << 32));
        lo + (hi - lo) * (raw as f64 / (1u64 << 32) as f64)
    }

    /// Pick one of the provided options.
    pub fn choose<'a, T>(&mut self, options: &'a [T]) -> &'a T {
        assert!(!options.is_empty());
        let i = self.usize_in(0, options.len() - 1);
        &options[i]
    }
}

/// Run `prop` for `iterations` random cases. Panics with seed + shrunk
/// input log on the first failure.
pub fn forall(name: &str, iterations: u32, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let base_seed = match std::env::var("CUGWAS_PROPTEST_SEED") {
        Ok(s) => s.parse().unwrap_or(0xC0FFEE),
        Err(_) => 0xC0FFEE,
    };
    for i in 0..iterations {
        let seed = base_seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            let log = g.log.clone();
            let (shrunk_log, shrunk_msg) = shrink(&log, seed, &mut prop).unwrap_or((log, msg));
            panic!(
                "property '{name}' failed (seed {seed:#x}, iteration {i}):\n  {shrunk_msg}\n  inputs: {shrunk_log:?}\n  replay: CUGWAS_PROPTEST_SEED={base_seed}"
            );
        }
    }
}

/// Greedy shrink: repeatedly try halving each logged scalar (toward 0)
/// and keep any candidate that still fails.
fn shrink(
    log: &[u64],
    seed: u64,
    prop: &mut impl FnMut(&mut Gen) -> PropResult,
) -> Option<(Vec<u64>, String)> {
    let mut current = log.to_vec();
    let mut last_msg: Option<String> = None;
    let mut improved = true;
    let mut budget = 200;
    while improved && budget > 0 {
        improved = false;
        for idx in 0..current.len() {
            if current[idx] == 0 {
                continue;
            }
            let mut candidate = current.clone();
            candidate[idx] /= 2;
            let mut g = Gen::replaying(candidate.clone(), seed);
            if let Err(msg) = prop(&mut g) {
                current = candidate;
                last_msg = Some(msg);
                improved = true;
            }
            budget -= 1;
            if budget == 0 {
                break;
            }
        }
    }
    last_msg.map(|m| (current, m))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall("tautology", 50, |g| {
            let x = g.usize_in(0, 100);
            prop_assert(x <= 100, "bound")
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails' failed")]
    fn failing_property_panics_with_seed() {
        forall("fails", 50, |g| {
            let x = g.usize_in(0, 100);
            prop_assert(x < 95, format!("x={x}"))
        });
    }

    #[test]
    fn shrinking_reduces_magnitude() {
        let result = std::panic::catch_unwind(|| {
            forall("shrinks", 100, |g| {
                let x = g.usize_in(0, 1_000_000);
                prop_assert(x < 10, format!("x={x}"))
            });
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("panic payload"),
            Ok(()) => panic!("property should have failed"),
        };
        let inputs: Vec<u64> = msg
            .split("inputs: [")
            .nth(1)
            .and_then(|s| s.split(']').next())
            .unwrap()
            .split(", ")
            .map(|v| v.parse().unwrap())
            .collect();
        assert!(inputs[0] <= 20, "shrunk to {inputs:?}\n{msg}");
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut a = Gen::new(1);
        let mut b = Gen::new(1);
        for _ in 0..20 {
            assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
        }
    }

    #[test]
    fn choose_covers_options() {
        let mut g = Gen::new(3);
        let opts = [1, 2, 3];
        let mut seen = [false; 3];
        for _ in 0..100 {
            seen[*g.choose(&opts) - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_bounds() {
        let mut g = Gen::new(5);
        for _ in 0..100 {
            let v = g.f64_in(2.0, 3.0);
            assert!((2.0..3.0).contains(&v));
        }
    }

    #[test]
    fn bool_p_extremes() {
        let mut g = Gen::new(7);
        assert!(!(0..50).any(|_| g.bool_p(0.0)));
        assert!((0..50).all(|_| g.bool_p(1.0)));
    }
}
