//! Free-space probing for the disk-space degradation sentinel.
//!
//! std has no `statvfs` binding, so the real probe shells out to
//! `df -Pk` (POSIX-mandated output format) and parses the "Available"
//! column. It is strictly best-effort: any failure — no `df`, weird
//! output, the path vanishing — returns `None` and the sentinel simply
//! has no opinion this tick, which callers treat as "not low". Tests
//! never touch `df`: the fault plan's `fake_disk_free_mb` override is
//! consulted first, making every degradation path deterministic.

use std::path::Path;

/// Free bytes on the filesystem holding `path`, or `None` when the
/// probe cannot tell. Checked (at most) once per scheduler dispatch
/// turn and once per engine segment boundary — seconds apart, so the
/// subprocess cost is noise against the stream it protects.
pub fn disk_free_bytes(path: &Path) -> Option<u64> {
    if let Some(bytes) = crate::storage::fault::fake_disk_free() {
        return Some(bytes);
    }
    let out = std::process::Command::new("df").arg("-Pk").arg(path).output().ok()?;
    if !out.status.success() {
        return None;
    }
    let text = String::from_utf8_lossy(&out.stdout);
    // -P guarantees one header line, then one line per filesystem with
    // the 1024-byte "Available" count in column 4.
    let line = text.lines().nth(1)?;
    let avail_kb: u64 = line.split_whitespace().nth(3)?.parse().ok()?;
    Some(avail_kb.saturating_mul(1024))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_override_wins_and_real_probe_is_best_effort() {
        // Without a fault plan the probe either reports a real number
        // for the current directory or (no `df` in the environment)
        // declines — both are valid "best effort" outcomes; what must
        // never happen is a panic.
        let _ = disk_free_bytes(Path::new("."));
        // A nonexistent path must decline, not error out.
        // (`df` exits nonzero; the error path maps to None.)
        let probed = disk_free_bytes(Path::new("/nonexistent/cugwas/probe/path"));
        if crate::storage::fault::fake_disk_free().is_none() {
            assert_eq!(probed, None);
        }
    }
}
