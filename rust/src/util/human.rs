//! Human-readable formatting of byte counts, durations and rates, used by
//! the CLI, logs, and benchmark tables.

use std::time::Duration;

/// `1536 → "1.50 KiB"`. Binary units, two decimals above bytes.
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 7] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB", "EiB"];
    if bytes < 1024 {
        return format!("{bytes} B");
    }
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    format!("{v:.2} {}", UNITS[unit])
}

/// Pretty duration: ns/µs/ms/s/min scales, ~3 significant figures.
pub fn human_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns < 60 * 1_000_000_000u128 {
        format!("{:.2} s", ns as f64 / 1e9)
    } else {
        let s = d.as_secs_f64();
        format!("{}m {:04.1}s", (s / 60.0) as u64, s % 60.0)
    }
}

/// Throughput: `bytes` moved over `d` → "X/s" string.
pub fn human_rate(bytes: u64, d: Duration) -> String {
    let secs = d.as_secs_f64();
    if secs <= 0.0 {
        return "∞/s".to_string();
    }
    format!("{}/s", human_bytes((bytes as f64 / secs) as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_scales() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(1023), "1023 B");
        assert_eq!(human_bytes(1024), "1.00 KiB");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert_eq!(human_bytes(1024 * 1024), "1.00 MiB");
        assert_eq!(human_bytes(14 * 1024u64.pow(4)), "14.00 TiB"); // the paper's X_R
    }

    #[test]
    fn duration_scales() {
        assert_eq!(human_duration(Duration::from_nanos(12)), "12 ns");
        assert_eq!(human_duration(Duration::from_micros(12)), "12.00 µs");
        assert_eq!(human_duration(Duration::from_millis(12)), "12.00 ms");
        assert_eq!(human_duration(Duration::from_secs(2)), "2.00 s");
        assert!(human_duration(Duration::from_secs(150)).starts_with("2m"));
    }

    #[test]
    fn rate_basic() {
        let r = human_rate(100 * 1024 * 1024, Duration::from_secs(1));
        assert_eq!(r, "100.00 MiB/s");
    }
}
