//! The crate-wide compute thread pool (std-only).
//!
//! Every BLAS-3 kernel and the S-loop route their data-parallel work
//! through [`scatter`]: column-panel tasks go into a shared FIFO queue and
//! scoped workers (the calling thread plus up to `budget − 1` spawned
//! ones) claim them dynamically — the load-balancing effect of work
//! stealing without per-worker deques. Workers are scoped
//! (`std::thread::scope`), so tasks may borrow the caller's matrices and
//! panic propagation is automatic.
//!
//! Sizing is a two-level *budget*:
//!
//! * a process-wide pool size ([`set_pool_size`], 0 = all cores), and
//! * an optional per-thread override ([`with_budget`]) — how the pipeline
//!   partitions cores between device lanes and the coordinator-side
//!   S-loop so `serve` with N workers doesn't oversubscribe the host.
//!
//! Kernels then clamp the budget by available work ([`for_flops`]): a
//! parallel region is only opened when each worker gets enough flops to
//! amortize the spawn, so the tiny shapes the tests use stay on the
//! serial path with zero overhead.
//!
//! Determinism: callers split work so that no two tasks touch the same
//! output element and each task performs the exact serial operation
//! sequence on its slice; results are therefore bit-identical at every
//! thread count (enforced by `tests/determinism.rs`).

use crate::error::Result;
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

/// Process-wide pool size; 0 = resolve to [`available`] at use.
static POOL_SIZE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread budget override; 0 = inherit the process-wide size.
    static BUDGET: Cell<usize> = const { Cell::new(0) };
}

/// Hardware parallelism of this host (≥ 1).
pub fn available() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Set the process-wide pool size. 0 restores the default (all cores).
pub fn set_pool_size(n: usize) {
    POOL_SIZE.store(n, Ordering::Relaxed);
}

/// Effective compute-thread budget for the calling thread: the innermost
/// [`with_budget`] override, else the process-wide pool size, else all
/// cores. Always ≥ 1.
pub fn budget() -> usize {
    let local = BUDGET.with(|b| b.get());
    if local > 0 {
        return local;
    }
    let global = POOL_SIZE.load(Ordering::Relaxed);
    if global > 0 {
        global
    } else {
        available()
    }
}

/// RAII guard restoring the previous per-thread budget on drop.
pub struct BudgetGuard {
    prev: usize,
}

impl Drop for BudgetGuard {
    fn drop(&mut self) {
        BUDGET.with(|b| b.set(self.prev));
    }
}

/// Override the calling thread's budget (e.g. a device lane pinning
/// itself to its core share). `n = 0` clears back to the pool default.
pub fn with_budget(n: usize) -> BudgetGuard {
    let prev = BUDGET.with(|b| b.replace(n));
    BudgetGuard { prev }
}

/// Minimum useful work per worker: ≈ 1 ms of micro-kernel time. Below
/// this, spawn + queue overhead beats the speedup.
const FLOPS_PER_WORKER: f64 = 8e6;

/// Workers worth opening for `flops` of arithmetic: the thread budget
/// clamped so each worker gets at least [`FLOPS_PER_WORKER`].
pub fn for_flops(flops: f64) -> usize {
    let b = budget();
    if b <= 1 {
        return 1;
    }
    let by_work = (flops / FLOPS_PER_WORKER) as usize;
    b.min(by_work.max(1))
}

/// Run `items` across up to `threads` scoped workers (the caller counts
/// as one). Items are claimed from a shared FIFO queue, so a slow panel
/// doesn't stall the rest. `init` builds one private state per worker
/// (scratch buffers); `f(state, index, item)` receives the item's
/// position in the original vector.
///
/// Errors: every item runs (no cancellation — tasks are short); the
/// error with the **lowest item index** is returned, which for
/// independent tasks is exactly the error the serial loop would have hit
/// first, keeping failure behavior deterministic and thread-count
/// independent.
pub fn scatter<S, T, G, F>(threads: usize, items: Vec<T>, init: G, f: F) -> Result<()>
where
    T: Send,
    G: Fn() -> S + Sync,
    F: Fn(&mut S, usize, T) -> Result<()> + Sync,
{
    let n = items.len();
    if n == 0 {
        return Ok(());
    }
    let nw = threads.max(1).min(n);
    if nw == 1 {
        let mut state = init();
        for (i, item) in items.into_iter().enumerate() {
            f(&mut state, i, item)?;
        }
        return Ok(());
    }

    // Pre-fill the queue, then drop the sender: try_recv drains Ok(..)
    // until empty and then yields Disconnected — no blocking recv while
    // holding the lock.
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    for pair in items.into_iter().enumerate() {
        let _ = tx.send(pair);
    }
    drop(tx);
    let queue = Mutex::new(rx);
    let first_err: Mutex<Option<(usize, crate::error::Error)>> = Mutex::new(None);

    let worker = || {
        let mut state = init();
        loop {
            let next = match queue.lock() {
                Ok(rx) => rx.try_recv(),
                Err(_) => break, // another worker panicked; stop cleanly
            };
            let Ok((i, item)) = next else { break };
            if let Err(e) = f(&mut state, i, item) {
                let mut slot = match first_err.lock() {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
                if slot.as_ref().map_or(true, |(j, _)| i < *j) {
                    *slot = Some((i, e));
                }
            }
        }
    };
    std::thread::scope(|s| {
        for _ in 1..nw {
            s.spawn(&worker);
        }
        worker();
    });

    match first_err.into_inner() {
        Ok(Some((_, e))) => Err(e),
        Ok(None) => Ok(()),
        Err(poisoned) => match poisoned.into_inner() {
            Some((_, e)) => Err(e),
            None => Ok(()),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    #[test]
    fn budget_resolves_layers() {
        assert!(available() >= 1);
        set_pool_size(3);
        assert_eq!(budget(), 3);
        {
            let _g = with_budget(7);
            assert_eq!(budget(), 7);
            {
                let _g2 = with_budget(2);
                assert_eq!(budget(), 2);
            }
            assert_eq!(budget(), 7);
        }
        assert_eq!(budget(), 3);
        set_pool_size(0);
        assert_eq!(budget(), available());
    }

    #[test]
    fn for_flops_clamps_by_work() {
        let _g = with_budget(8);
        assert_eq!(for_flops(1.0), 1);
        assert_eq!(for_flops(FLOPS_PER_WORKER * 3.0), 3);
        assert_eq!(for_flops(FLOPS_PER_WORKER * 100.0), 8);
    }

    #[test]
    fn scatter_runs_every_item_once() {
        use std::sync::atomic::AtomicU64;
        for threads in [1, 2, 4, 9] {
            let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
            let items: Vec<usize> = (0..100).collect();
            scatter(threads, items, || (), |_, i, item| {
                assert_eq!(i, item);
                hits[item].fetch_add(1, Ordering::Relaxed);
                Ok(())
            })
            .unwrap();
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn scatter_reports_lowest_index_error() {
        for threads in [1, 2, 4] {
            let items: Vec<usize> = (0..64).collect();
            let err = scatter(threads, items, || (), |_, i, _| {
                if i == 7 || i == 50 {
                    Err(Error::Numerical(format!("boom {i}")))
                } else {
                    Ok(())
                }
            })
            .unwrap_err();
            assert!(err.to_string().contains("boom 7"), "{err}");
        }
    }

    #[test]
    fn scatter_worker_state_is_private() {
        // Each worker's state starts fresh; mutating it never races.
        let items: Vec<usize> = (0..32).collect();
        scatter(4, items, || 0usize, |count, _, _| {
            *count += 1;
            assert!(*count <= 32);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn scatter_empty_and_single() {
        scatter(4, Vec::<usize>::new(), || (), |_, _, _| Ok(())).unwrap();
        scatter(4, vec![1usize], || (), |_, i, v| {
            assert_eq!((i, v), (0, 1));
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn scatter_tasks_can_borrow_and_mutate_disjoint_slices() {
        let mut buf = vec![0.0f64; 64];
        let chunks: Vec<&mut [f64]> = buf.chunks_mut(16).collect();
        scatter(3, chunks, || (), |_, i, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = (i * 16 + k) as f64;
            }
            Ok(())
        })
        .unwrap();
        for (k, v) in buf.iter().enumerate() {
            assert_eq!(*v, k as f64);
        }
    }
}
