//! Small shared utilities: deterministic RNG, wall-clock timers, humanized
//! quantities, JSON emission helpers, a leveled logger, and the compute
//! thread pool. All std-only.

pub mod disk;
pub mod human;
pub mod json;
pub mod log;
pub mod rng;
pub mod threads;
pub mod timer;

pub use disk::disk_free_bytes;
pub use human::{human_bytes, human_duration, human_rate};
pub use rng::XorShift;
pub use timer::Timer;
