//! Tiny hand-rolled JSON emission helpers (std-only — the crate vendors
//! no serde). Shared by the structured logger (`--log-json`), the Chrome
//! trace exporter (`--trace-out`) and the machine-readable reports
//! (`--report-json`); all three only ever *write* JSON, so an escaper
//! and a float formatter are the whole surface.

use std::fmt::Write;

/// Escape `s` for embedding inside a JSON string literal (no quotes
/// added).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    escape_into(&mut out, s);
    out
}

/// Escape `s` into `out` (allocation-free when `out` has capacity).
pub fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

/// Format a float as a JSON value: finite numbers print plainly,
/// NaN/±Inf (not representable in JSON) become `null`.
pub fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_specials() {
        assert_eq!(escape(r#"a"b\c"#), r#"a\"b\\c"#);
        assert_eq!(escape("x\ny\tz"), "x\\ny\\tz");
        assert_eq!(escape("\u{1}"), "\\u0001");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    fn nonfinite_floats_become_null() {
        assert_eq!(num(1.5), "1.5");
        assert_eq!(num(f64::NAN), "null");
        assert_eq!(num(f64::INFINITY), "null");
    }
}
