//! Wall-clock timing helpers used by the coordinator metrics and the
//! benchmark framework.

use std::time::{Duration, Instant};

/// A simple start/lap timer.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
    last: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        let now = Instant::now();
        Timer { start: now, last: now }
    }

    /// Total elapsed time since `start()`.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since the previous `lap()` (or since start for the first lap).
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        d
    }

    /// Elapsed seconds as f64 (convenience for throughput math).
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning `(result, duration)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_monotonic() {
        let t = Timer::start();
        let a = t.elapsed();
        let b = t.elapsed();
        assert!(b >= a);
    }

    #[test]
    fn lap_resets() {
        let mut t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        let l1 = t.lap();
        let l2 = t.lap();
        assert!(l1 >= Duration::from_millis(1));
        assert!(l2 <= l1);
    }

    #[test]
    fn timed_returns_value() {
        let (v, d) = timed(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
