//! Minimal leveled logger (stderr). The vendored crate set has no `log`
//! facade consumers here, so we keep a tiny global with the same spirit:
//! levels, timestamps relative to process start, and zero allocation when
//! a level is disabled.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log verbosity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static JSON: AtomicBool = AtomicBool::new(false);
static START: OnceLock<Instant> = OnceLock::new();

/// Set the global verbosity.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Emit one JSON object per line instead of the human format
/// (`--log-json`): `{"secs":…,"level":"info","target":"engine","msg":"…"}`.
pub fn set_json(on: bool) {
    JSON.store(on, Ordering::Relaxed);
}

/// Whether structured (JSON-lines) output is on.
pub fn json_mode() -> bool {
    JSON.load(Ordering::Relaxed)
}

/// Current verbosity.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Whether `l` would be printed.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Print a log line (used through the macros below).
pub fn emit(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t0 = *START.get_or_init(Instant::now);
    let secs = t0.elapsed().as_secs_f64();
    if json_mode() {
        let name = match l {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        };
        eprintln!(
            "{{\"secs\":{secs:.3},\"level\":\"{name}\",\"target\":\"{}\",\"msg\":\"{}\"}}",
            crate::util::json::escape(target),
            crate::util::json::escape(&msg.to_string()),
        );
        return;
    }
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{secs:9.3}s {tag} {target}] {msg}");
}

/// `info!(target, "fmt {}", arg)`-style macros.
#[macro_export]
macro_rules! log_error { ($t:expr, $($a:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($t:expr, $($a:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($t:expr, $($a:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($t:expr, $($a:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, $t, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_trace { ($t:expr, $($a:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Trace, $t, format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_and_check() {
        let prev = level();
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        assert!(!enabled(Level::Trace));
        set_level(prev);
    }

    #[test]
    fn json_mode_toggles() {
        // Only the stderr *format* changes with the flag, so briefly
        // flipping it cannot break concurrent tests' assertions.
        set_json(true);
        assert!(json_mode());
        log_error!("test", "a \"quoted\" {}", "msg");
        set_json(false);
        assert!(!json_mode());
    }

    #[test]
    fn macros_compile() {
        let prev = level();
        set_level(Level::Error);
        log_info!("test", "suppressed {}", 1);
        log_error!("test", "printed {}", 2);
        set_level(prev);
    }
}
