//! A minimal TOML-subset parser (std-only substrate — the crates.io
//! `toml` stack is unavailable offline; see DESIGN.md §4).
//!
//! Supported: `[section]` headers, `key = value` with string, integer,
//! float, and boolean values, `#` comments, and blank lines. That covers
//! every config this repo ships. Unsupported syntax is a hard error — no
//! silent misparses.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    String(String),
    Integer(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Integer(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `section.key → value`. Top-level keys use section "".
/// Section headers are tracked even when the section body is empty, so
/// schema validators see (and can reject or require keys in) a section
/// the author declared but left blank.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Doc {
    values: BTreeMap<(String, String), Value>,
    headers: std::collections::BTreeSet<String>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let errline = |msg: String| Error::Config(format!("line {}: {msg}", lineno + 1));
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| errline("unterminated section header".into()))?
                    .trim();
                if name.is_empty() {
                    return Err(errline("empty section name".into()));
                }
                section = name.to_string();
                doc.headers.insert(section.clone());
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| errline(format!("expected key = value, got '{line}'")))?;
            let key = k.trim();
            if key.is_empty() {
                return Err(errline("empty key".into()));
            }
            let value = parse_value(v.trim()).map_err(errline)?;
            if doc
                .values
                .insert((section.clone(), key.to_string()), value)
                .is_some()
            {
                return Err(errline(format!("duplicate key '{key}'")));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    /// Typed getters with defaulting.
    pub fn int_or(&self, section: &str, key: &str, default: i64) -> Result<i64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_int()
                .ok_or_else(|| Error::Config(format!("{section}.{key}: expected integer"))),
        }
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_float()
                .ok_or_else(|| Error::Config(format!("{section}.{key}: expected number"))),
        }
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> Result<&'a str> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_str()
                .ok_or_else(|| Error::Config(format!("{section}.{key}: expected string"))),
        }
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_bool()
                .ok_or_else(|| Error::Config(format!("{section}.{key}: expected bool"))),
        }
    }

    /// Keys present in a section (for unknown-key validation).
    pub fn keys_in(&self, section: &str) -> Vec<&str> {
        self.values
            .keys()
            .filter(|(s, _)| s == section)
            .map(|(_, k)| k.as_str())
            .collect()
    }

    /// All declared sections — including ones with no keys (a header
    /// whose body was forgotten must not silently vanish).
    pub fn sections(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .values
            .keys()
            .map(|(s, _)| s.as_str())
            .chain(self.headers.iter().map(|s| s.as_str()))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string must survive.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(Value::String(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Integer(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_document() {
        let doc = Doc::parse(
            r#"
# a comment
title = "cugwas"
[pipeline]
block = 5_000   # SNPs per iteration
ngpus = 4
saturate = true
[hardware]
disk_mbps = 120.5
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "title").unwrap().as_str(), Some("cugwas"));
        assert_eq!(doc.get("pipeline", "block").unwrap().as_int(), Some(5000));
        assert_eq!(doc.get("pipeline", "saturate").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("hardware", "disk_mbps").unwrap().as_float(), Some(120.5));
    }

    #[test]
    fn typed_getters_and_defaults() {
        let doc = Doc::parse("[a]\nx = 3\n").unwrap();
        assert_eq!(doc.int_or("a", "x", 9).unwrap(), 3);
        assert_eq!(doc.int_or("a", "missing", 9).unwrap(), 9);
        assert!(doc.str_or("a", "x", "d").is_err()); // wrong type
        assert_eq!(doc.float_or("a", "x", 0.0).unwrap(), 3.0); // int coerces
    }

    #[test]
    fn rejects_malformed() {
        assert!(Doc::parse("[unterminated\n").is_err());
        assert!(Doc::parse("keyonly\n").is_err());
        assert!(Doc::parse("k = \n").is_err());
        assert!(Doc::parse("k = \"open\n").is_err());
        assert!(Doc::parse("k = maybe\n").is_err());
        assert!(Doc::parse("x = 1\nx = 2\n").is_err());
        assert!(Doc::parse("[]\n").is_err());
    }

    #[test]
    fn comment_inside_string_survives() {
        let doc = Doc::parse("k = \"a # b\"\n").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn sections_and_keys_enumerate() {
        let doc = Doc::parse("[b]\nx=1\n[a]\ny=2\nz=3\n").unwrap();
        assert_eq!(doc.sections(), vec!["a", "b"]);
        let mut keys = doc.keys_in("a");
        keys.sort_unstable();
        assert_eq!(keys, vec!["y", "z"]);
    }

    #[test]
    fn empty_sections_are_still_declared() {
        // A header whose body was forgotten must be visible to schema
        // validators, not silently dropped.
        let doc = Doc::parse("[a]\nx = 1\n[empty]\n").unwrap();
        assert_eq!(doc.sections(), vec!["a", "empty"]);
        assert!(doc.keys_in("empty").is_empty());
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = Doc::parse("a = -5\nb = 1e3\nc = -2.5e-2\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_int(), Some(-5));
        assert_eq!(doc.get("", "b").unwrap().as_float(), Some(1000.0));
        assert_eq!(doc.get("", "c").unwrap().as_float(), Some(-0.025));
    }
}
