//! Configuration: TOML-subset parsing + the typed run configuration the
//! CLI and examples consume.

pub mod schema;
pub mod toml;

pub use schema::{FaultToleranceConfig, RunConfig, ServiceConfig, SimSection};
pub use toml::{Doc, Value};
