//! Typed run configuration loaded from a TOML file (or defaults).
//!
//! ```toml
//! [dataset]
//! dir = "data/study1"
//! n = 512
//! pl = 3
//! m = 4096
//! seed = 42
//!
//! [pipeline]
//! block = 256        # SNP columns per iteration (whole pipeline)
//! ngpus = 1
//! host_buffers = 3
//! device_buffers = 2 # device buffers per lane (paper: 2)
//! threads = 0        # compute threads (0 = all cores), split lanes/S-loop
//! lane_threads = 0   # kernel threads per lane (0 = auto split)
//! mode = "trsm"      # trsm | block | blockfull
//! backend = "pjrt"   # pjrt | native
//! artifacts = "artifacts"
//! read_mbps = 0      # 0 = unthrottled; >0 emulates that storage speed
//! write_mbps = 0
//! profile = ""       # tuned profile TOML (its knobs become the defaults)
//! adapt = false      # re-plan block size live at segment boundaries
//! adapt_every = 16   # blocks per adaptive segment
//! traits = 1         # phenotype batch width (multi-trait in one pass)
//! permutations = 0   # K seeded shuffles batched with the real phenotype
//! perm_seed = 0      # RNG seed for the permutation columns
//!
//! [sim]
//! profile = "quadro" # quadro | tesla | hdd
//! ```
//!
//! A *service* configuration (for `cugwas serve`) instead uses a
//! `[service]` section plus one `[job.<name>]` section per study:
//!
//! ```toml
//! [service]
//! workers = 2          # concurrent worker lanes
//! mem_budget_mb = 4096 # admission budget for jobs' host footprints
//! cache_mb = 256       # shared block cache (0 disables)
//! threads = 0          # compute threads across all workers (0 = all cores)
//! spool = "spool"      # optional: watched directory of job TOMLs
//! watch = false        # keep serving after the queue drains
//! auto_tune = true     # probe + plan each dataset on first contact
//! metrics_addr = "127.0.0.1:9184" # optional: serve /metrics + /healthz
//! wal = "service.wal"  # lifecycle WAL (default: <spool>/service.wal)
//! drain_timeout_secs = 30  # graceful-drain checkpoint budget
//! disk_low_water_mb = 0    # pause admission below this free space (0 = off)
//!
//! [job.alpha]
//! dataset = "data/s1"
//! block = 256
//! priority = 2         # higher runs first; FIFO within a priority
//! deadline_secs = 0    # cancel (checkpointed) past this wall time (0 = none)
//!
//! [job.beta]
//! dataset = "data/s1"  # same dataset → second pass hits the cache
//! ```

use crate::config::toml::Doc;
use crate::coordinator::{BackendKind, OffloadMode, PipelineConfig};
use crate::devsim::HardwareProfile;
use crate::error::{Error, Result};
use crate::gwas::problem::Dims;
use crate::service::JobSpec;
use crate::storage::fault::{FaultPlan, RetryPolicy, NO_COL, NO_DISK, NO_LANE};
use crate::storage::Throttle;
use std::path::{Path, PathBuf};

/// Simulation section.
#[derive(Debug, Clone)]
pub struct SimSection {
    pub profile: HardwareProfile,
}

/// Parsed `[fault_tolerance]` section (shared by run and service
/// configs): the retry/supervision policy, whether published blocks
/// carry a verified checksum, and the chaos-injection plan (all-off
/// unless `inject_*` keys are set — production configs never set them).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultToleranceConfig {
    pub policy: RetryPolicy,
    pub integrity: bool,
    pub plan: FaultPlan,
}

impl FaultToleranceConfig {
    /// Install this configuration process-wide: policy, integrity flag
    /// and (when any `inject_*` knob is live) the armed injector.
    /// Called once at `run`/`serve` startup.
    pub fn install(&self) {
        crate::storage::fault::set_policy(self.policy);
        crate::storage::fault::set_integrity_enabled(self.integrity);
        crate::storage::fault::arm(self.plan);
    }
}

/// Keys a `[fault_tolerance]` section may carry.
const FAULT_KEYS: &[&str] = &[
    "read_retries",
    "retry_backoff_ms",
    "retry_deadline_ms",
    "integrity",
    "lane_watchdog_ms",
    "max_lane_respawns",
    "job_retries",
    "job_backoff_ms",
    "quarantine_after",
    "inject_seed",
    "inject_read_fail_every",
    "inject_read_fail_col",
    "inject_read_delay_every",
    "inject_read_delay_ms",
    "inject_corrupt_every",
    "inject_torn_append_at",
    "inject_commit_crash_at",
    "inject_wedge_lane",
    "inject_wedge_at_chunk",
    "inject_wedge_ms",
    "inject_wal_torn_append_at",
    "inject_wal_crash_at",
    "inject_quarantine_crash_at",
    "inject_fake_disk_free_mb",
];

/// Parse the `[fault_tolerance]` section (absent section → defaults:
/// a few read retries, integrity off, injector off).
fn fault_from_doc(doc: &Doc) -> Result<FaultToleranceConfig> {
    let s = "fault_tolerance";
    let key = |k, default, min, max| int_in(doc, s, k, default, min, max);
    let d = RetryPolicy::default();
    let policy = RetryPolicy {
        read_retries: key("read_retries", d.read_retries as i64, 0, 1_000)? as u32,
        retry_backoff_ms: key("retry_backoff_ms", d.retry_backoff_ms as i64, 0, 60_000)? as u64,
        retry_deadline_ms: key("retry_deadline_ms", d.retry_deadline_ms as i64, 1, 3_600_000)?
            as u64,
        lane_watchdog_ms: key("lane_watchdog_ms", d.lane_watchdog_ms as i64, 0, 3_600_000)? as u64,
        max_lane_respawns: key("max_lane_respawns", d.max_lane_respawns as i64, 0, 1_000)? as u32,
        job_retries: key("job_retries", d.job_retries as i64, 0, 1_000)? as u32,
        job_backoff_ms: key("job_backoff_ms", d.job_backoff_ms as i64, 0, 3_600_000)? as u64,
        quarantine_after: key("quarantine_after", d.quarantine_after as i64, 1, 1_000)? as u32,
    };
    let integrity = doc.bool_or(s, "integrity", false)?;
    let dp = FaultPlan::default();
    let plan = FaultPlan {
        seed: key("inject_seed", 0, 0, i64::MAX)? as u64,
        read_fail_every: key("inject_read_fail_every", 0, 0, i64::MAX)? as u64,
        // -1 = "no column targeted" (the sentinel is not expressible in
        // TOML-friendly unsigned space).
        read_fail_col: match key("inject_read_fail_col", -1, -1, i64::MAX)? {
            -1 => NO_COL,
            v => v as u64,
        },
        read_delay_every: key("inject_read_delay_every", 0, 0, i64::MAX)? as u64,
        read_delay_ms: key("inject_read_delay_ms", 0, 0, 60_000)? as u64,
        corrupt_every: key("inject_corrupt_every", 0, 0, i64::MAX)? as u64,
        torn_append_at: key("inject_torn_append_at", 0, 0, i64::MAX)? as u64,
        commit_crash_at: key("inject_commit_crash_at", 0, 0, i64::MAX)? as u64,
        wedge_lane: match key("inject_wedge_lane", -1, -1, 4_096)? {
            -1 => NO_LANE,
            v => v as usize,
        },
        wedge_at_chunk: key("inject_wedge_at_chunk", dp.wedge_at_chunk as i64, 1, i64::MAX)?
            as u64,
        wedge_ms: key("inject_wedge_ms", dp.wedge_ms as i64, 0, 600_000)? as u64,
        wal_torn_append_at: key("inject_wal_torn_append_at", 0, 0, i64::MAX)? as u64,
        wal_crash_at: key("inject_wal_crash_at", 0, 0, i64::MAX)? as u64,
        quarantine_crash_at: key("inject_quarantine_crash_at", 0, 0, i64::MAX)? as u64,
        // -1 = "no override" (the NO_DISK sentinel, like NO_COL above).
        fake_disk_free_mb: match key("inject_fake_disk_free_mb", -1, -1, i64::MAX)? {
            -1 => NO_DISK,
            v => v as u64,
        },
    };
    Ok(FaultToleranceConfig { policy, integrity, plan })
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub dataset_dir: PathBuf,
    pub dims: Dims,
    pub gen_block: usize,
    pub seed: u64,
    pub pipeline: PipelineConfig,
    pub sim: SimSection,
    pub fault: FaultToleranceConfig,
}

impl RunConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let doc = Doc::parse(text)?;
        Self::from_doc(&doc)
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(format!("reading config {}", path.display()), e))?;
        Self::from_toml(&text)
    }

    /// Built from a parsed document; unknown keys are errors (typo guard).
    pub fn from_doc(doc: &Doc) -> Result<RunConfig> {
        for section in doc.sections() {
            let allowed: &[&str] = match section {
                "dataset" => &["dir", "n", "pl", "m", "seed", "block"],
                "pipeline" => &[
                    "block",
                    "ngpus",
                    "host_buffers",
                    "device_buffers",
                    "threads",
                    "lane_threads",
                    "mode",
                    "backend",
                    "artifacts",
                    "read_mbps",
                    "write_mbps",
                    "profile",
                    "adapt",
                    "adapt_every",
                    "traits",
                    "permutations",
                    "perm_seed",
                ],
                "sim" => &["profile"],
                "fault_tolerance" => FAULT_KEYS,
                "" => &[],
                other => {
                    return Err(Error::Config(format!("unknown section [{other}]")));
                }
            };
            for key in doc.keys_in(section) {
                if !allowed.contains(&key) {
                    return Err(Error::Config(format!("unknown key {section}.{key}")));
                }
            }
        }
        let dataset_dir = PathBuf::from(doc.str_or("dataset", "dir", "data/study")?);
        let n = doc.int_or("dataset", "n", 512)? as usize;
        let pl = doc.int_or("dataset", "pl", 3)? as usize;
        let m = doc.int_or("dataset", "m", 4096)? as usize;
        let dims = Dims::new(n, pl, m)?;
        let gen_block = doc.int_or("dataset", "block", 256)? as usize;
        let seed = doc.int_or("dataset", "seed", 42)? as u64;

        // A tuned profile's knobs become the *defaults*; explicit keys in
        // this config still win (same precedence as `run --profile`).
        let base =
            crate::tune::profile::load_or_default(profile_path(doc, "pipeline")?.as_deref(), m, 0)?;
        let block = doc.int_or("pipeline", "block", base.block as i64)? as usize;
        let ngpus = int_in(doc, "pipeline", "ngpus", base.ngpus as i64, 1, 4096)? as usize;
        let host_buffers =
            int_in(doc, "pipeline", "host_buffers", base.host_buffers as i64, 2, 1024)? as usize;
        let device_buffers =
            int_in(doc, "pipeline", "device_buffers", base.device_buffers as i64, 2, 64)? as usize;
        let threads = int_in(doc, "pipeline", "threads", base.threads as i64, 0, 4096)? as usize;
        let lane_threads =
            int_in(doc, "pipeline", "lane_threads", base.lane_threads as i64, 0, 4096)? as usize;
        let adapt = doc.bool_or("pipeline", "adapt", false)?;
        let adapt_every = int_in(doc, "pipeline", "adapt_every", 16, 1, 1 << 30)? as usize;
        let mode = parse_mode(doc.str_or("pipeline", "mode", "trsm")?)?;
        let backend = parse_backend(doc, "pipeline")?;
        let read_throttle = throttle_of(doc.float_or("pipeline", "read_mbps", 0.0)?);
        let write_throttle = throttle_of(doc.float_or("pipeline", "write_mbps", 0.0)?);
        let (traits, perm_seed) = resolve_traits(doc, "pipeline")?;

        let profile = match doc.str_or("sim", "profile", "quadro")? {
            "quadro" => HardwareProfile::quadro(),
            "tesla" => HardwareProfile::tesla(),
            "hdd" => HardwareProfile::hdd(),
            other => return Err(Error::Config(format!("unknown sim profile '{other}'"))),
        };

        Ok(RunConfig {
            dataset_dir: dataset_dir.clone(),
            dims,
            gen_block,
            seed,
            pipeline: PipelineConfig {
                dataset: dataset_dir,
                block,
                ngpus,
                host_buffers,
                device_buffers,
                mode,
                backend,
                read_throttle,
                write_throttle,
                resume: false,
                cache: None,
                threads,
                lane_threads,
                adapt,
                adapt_every,
                traits,
                perm_seed,
                shutdown: None,
                deadline_at: None,
                disk_low_water: 0,
            },
            sim: SimSection { profile },
            fault: fault_from_doc(doc)?,
        })
    }

    /// All defaults (native backend, synthetic mid-size study).
    pub fn defaults() -> RunConfig {
        Self::from_toml("").expect("defaults parse")
    }
}

fn parse_mode(s: &str) -> Result<OffloadMode> {
    match s {
        "trsm" => Ok(OffloadMode::Trsm),
        "block" => Ok(OffloadMode::Block),
        "blockfull" => Ok(OffloadMode::BlockFull),
        other => Err(Error::Config(format!("unknown mode '{other}'"))),
    }
}

fn parse_backend(doc: &Doc, section: &str) -> Result<BackendKind> {
    match doc.str_or(section, "backend", "native")? {
        "native" => Ok(BackendKind::Native),
        "pjrt" => Ok(BackendKind::Pjrt {
            artifacts: PathBuf::from(doc.str_or(section, "artifacts", "artifacts")?),
        }),
        other => Err(Error::Config(format!("unknown backend '{other}'"))),
    }
}

fn throttle_of(mbps: f64) -> Option<Throttle> {
    if mbps > 0.0 {
        Some(Throttle { bytes_per_sec: mbps * 1e6 })
    } else {
        None
    }
}

/// Resolve the effective phenotype batch width from a section's
/// `traits`/`permutations`/`perm_seed` keys (shared by `[pipeline]` and
/// `[job.*]`). Permutation mode is sugar for a trait batch — the real
/// phenotype in column 0 plus K seeded shuffles — so `permutations = K`
/// implies `traits = K + 1`; spelling out both with different numbers
/// is a config error, not a silent override.
fn resolve_traits(doc: &Doc, section: &str) -> Result<(usize, u64)> {
    let traits = int_in(doc, section, "traits", 1, 1, 1 << 20)? as usize;
    let permutations = int_in(doc, section, "permutations", 0, 0, 1 << 20)? as usize;
    let perm_seed = doc.int_or(section, "perm_seed", 0)? as u64;
    let effective = if permutations > 0 {
        if doc.get(section, "traits").is_some() && traits != permutations + 1 {
            return Err(Error::Config(format!(
                "{section}.traits = {traits} conflicts with {section}.permutations = \
                 {permutations} (permutation mode implies traits = permutations + 1)"
            )));
        }
        permutations + 1
    } else {
        traits
    };
    Ok((effective, perm_seed))
}

/// Resolve a section's `profile` key to a path (`None` when absent or
/// empty). Loading goes through [`crate::tune::profile::load_or_default`]
/// — the same single error path `run --profile` and the service's
/// first-contact tuner use.
fn profile_path(doc: &Doc, section: &str) -> Result<Option<PathBuf>> {
    match doc.get(section, "profile") {
        None => Ok(None),
        Some(v) => {
            let path = v
                .as_str()
                .ok_or_else(|| Error::Config(format!("{section}.profile: expected string")))?;
            if path.is_empty() {
                Ok(None)
            } else {
                Ok(Some(PathBuf::from(path)))
            }
        }
    }
}

/// Integer in `[min, max]` — out-of-range config (negative worker
/// counts, zero block sizes, absurd budgets) becomes `Error::Config`
/// instead of a wrapped cast or a downstream panic.
fn int_in(doc: &Doc, section: &str, key: &str, default: i64, min: i64, max: i64) -> Result<i64> {
    let v = doc.int_or(section, key, default)?;
    if v < min || v > max {
        return Err(Error::Config(format!(
            "{section}.{key} = {v}: must be in {min}..={max}"
        )));
    }
    Ok(v)
}

/// Keys a `[job.*]` (or spool `[job]`) section may carry.
const JOB_KEYS: &[&str] = &[
    "dataset",
    "block",
    "ngpus",
    "host_buffers",
    "device_buffers",
    "threads",
    "lane_threads",
    "mode",
    "backend",
    "artifacts",
    "priority",
    "read_mbps",
    "write_mbps",
    "profile",
    "adapt",
    "adapt_every",
    "traits",
    "permutations",
    "perm_seed",
    "deadline_secs",
];

/// Parse one job section into a [`JobSpec`]. `dataset` is required; a
/// `profile` key makes that tuned profile's knobs the defaults (and its
/// predicted duration the scheduler's admission-ordering hint);
/// explicit keys still win; everything else falls back to the pipeline
/// defaults.
fn job_from_doc(doc: &Doc, section: &str, name: &str) -> Result<JobSpec> {
    for key in doc.keys_in(section) {
        if !JOB_KEYS.contains(&key) {
            return Err(Error::Config(format!("unknown key {section}.{key}")));
        }
    }
    let dataset = doc
        .get(section, "dataset")
        .ok_or_else(|| Error::Config(format!("job '{name}': missing dataset")))?
        .as_str()
        .ok_or_else(|| Error::Config(format!("job '{name}': dataset must be a string")))?;
    let mut spec = JobSpec::new(name, dataset);
    if let Some(path) = profile_path(doc, section)? {
        let tuned = crate::tune::profile::load_or_default(Some(&path), usize::MAX, 0)?;
        spec.apply_profile(&tuned);
    }
    spec.block = int_in(doc, section, "block", spec.block as i64, 1, 1 << 30)? as usize;
    spec.ngpus = int_in(doc, section, "ngpus", spec.ngpus as i64, 1, 4096)? as usize;
    spec.host_buffers =
        int_in(doc, section, "host_buffers", spec.host_buffers as i64, 2, 1024)? as usize;
    spec.device_buffers =
        int_in(doc, section, "device_buffers", spec.device_buffers as i64, 2, 64)? as usize;
    spec.threads = int_in(doc, section, "threads", spec.threads as i64, 0, 4096)? as usize;
    spec.lane_threads =
        int_in(doc, section, "lane_threads", spec.lane_threads as i64, 0, 4096)? as usize;
    // Record which knobs the operator pinned — the service's first-
    // contact tuner must not override an explicit key.
    spec.pins = crate::service::KnobPins {
        block: doc.get(section, "block").is_some(),
        ngpus: doc.get(section, "ngpus").is_some(),
        host_buffers: doc.get(section, "host_buffers").is_some(),
        device_buffers: doc.get(section, "device_buffers").is_some(),
        threads: doc.get(section, "threads").is_some(),
        lane_threads: doc.get(section, "lane_threads").is_some(),
    };
    spec.adapt = doc.bool_or(section, "adapt", false)?;
    spec.adapt_every =
        int_in(doc, section, "adapt_every", spec.adapt_every as i64, 1, 1 << 30)? as usize;
    spec.mode = parse_mode(doc.str_or(section, "mode", "trsm")?)?;
    spec.backend = parse_backend(doc, section)?;
    spec.priority =
        int_in(doc, section, "priority", 0, i32::MIN as i64, i32::MAX as i64)? as i32;
    spec.read_throttle = throttle_of(doc.float_or(section, "read_mbps", 0.0)?);
    spec.write_throttle = throttle_of(doc.float_or(section, "write_mbps", 0.0)?);
    let (traits, perm_seed) = resolve_traits(doc, section)?;
    spec.traits = traits;
    spec.perm_seed = perm_seed;
    // A year bounds out absurd values while leaving any real deadline
    // expressible; 0 (the default) means none.
    spec.deadline_secs =
        int_in(doc, section, "deadline_secs", 0, 0, 365 * 86_400)? as u64;
    Ok(spec)
}

/// `cugwas serve` configuration: the `[service]` section plus one
/// `[job.<name>]` per queued study (see module docs for the grammar).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Concurrent worker lanes (each one full pipeline).
    pub workers: usize,
    /// Admission budget for the jobs' estimated host footprints.
    pub mem_budget_bytes: u64,
    /// Shared block-cache budget; 0 disables caching.
    pub cache_bytes: u64,
    /// Total compute threads partitioned across the worker lanes
    /// (0 = all cores). A job's own `threads` key overrides its share.
    pub threads: usize,
    /// Optional spool directory of single-job TOML files.
    pub spool: Option<PathBuf>,
    /// Keep polling the spool after the queue drains (a true daemon).
    pub watch: bool,
    /// Tune each dataset on first contact: load `<dataset>/tuned.toml`
    /// if present, else run a cheap probe + plan and persist it, filling
    /// the job's unpinned knobs and feeding the prediction to
    /// shortest-job-first admission. Explicit job keys always win.
    /// `false` streams *exactly* the configured knobs — no probing and
    /// no profile application (an explicit `profile` key still works).
    pub auto_tune: bool,
    /// Optional `host:port` to serve the Prometheus `/metrics` (and
    /// `/healthz`) endpoint on; also turns the metrics plane on. The
    /// `--metrics-addr` flag overrides this key.
    pub metrics_addr: Option<String>,
    /// Path of the service lifecycle WAL. Defaults to
    /// `<spool>/service.wal` when a spool is configured, else no WAL
    /// (a WAL-less serve is not crash-restartable). The `--wal` flag
    /// overrides this key.
    pub wal: Option<PathBuf>,
    /// How long a graceful drain waits for in-flight jobs to checkpoint
    /// at a segment boundary before abandoning them (their journals are
    /// still committed through the last finished segment).
    pub drain_timeout_secs: u64,
    /// Free-space low-water mark: below this many MB free on the spool
    /// (or active dataset) filesystem, admission pauses and the shared
    /// cache is shed; 0 disables the sentinel.
    pub disk_low_water_mb: u64,
    /// Jobs from `[job.*]` sections, in section (alphabetical) order —
    /// `priority` is the scheduling knob, not file order.
    pub jobs: Vec<JobSpec>,
    /// Retry/supervision policy, integrity checking and (for the chaos
    /// harness) fault injection — the `[fault_tolerance]` section.
    pub fault: FaultToleranceConfig,
}

impl ServiceConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<ServiceConfig> {
        Self::from_doc(&Doc::parse(text)?)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<ServiceConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(format!("reading config {}", path.display()), e))?;
        Self::from_toml(&text)
    }

    /// Built from a parsed document; unknown sections/keys are errors.
    pub fn from_doc(doc: &Doc) -> Result<ServiceConfig> {
        for section in doc.sections() {
            match section {
                "service" => {}
                "fault_tolerance" => {
                    for key in doc.keys_in(section) {
                        if !FAULT_KEYS.contains(&key) {
                            return Err(Error::Config(format!("unknown key {section}.{key}")));
                        }
                    }
                }
                "" => {
                    if let Some(key) = doc.keys_in("").first() {
                        return Err(Error::Config(format!("unknown top-level key {key}")));
                    }
                }
                s if s.strip_prefix("job.").is_some_and(|n| !n.is_empty()) => {}
                other => return Err(Error::Config(format!("unknown section [{other}]"))),
            }
        }
        for key in doc.keys_in("service") {
            if ![
                "workers",
                "mem_budget_mb",
                "cache_mb",
                "threads",
                "spool",
                "watch",
                "auto_tune",
                "metrics_addr",
                "wal",
                "drain_timeout_secs",
                "disk_low_water_mb",
            ]
            .contains(&key)
            {
                return Err(Error::Config(format!("unknown key service.{key}")));
            }
        }
        let workers = int_in(doc, "service", "workers", 2, 1, 4096)? as usize;
        // ≤ 2^40 MB keeps the <<20 shift far from u64 overflow.
        let mem_budget_mb = int_in(doc, "service", "mem_budget_mb", 4096, 1, 1 << 40)?;
        let cache_mb = int_in(doc, "service", "cache_mb", 256, 0, 1 << 40)?;
        let threads = int_in(doc, "service", "threads", 0, 0, 4096)? as usize;
        let spool = match doc.get("service", "spool") {
            None => None,
            Some(v) => Some(PathBuf::from(v.as_str().ok_or_else(|| {
                Error::Config("service.spool: expected string".into())
            })?)),
        };
        let watch = doc.bool_or("service", "watch", false)?;
        let auto_tune = doc.bool_or("service", "auto_tune", true)?;
        let metrics_addr = match doc.get("service", "metrics_addr") {
            None => None,
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    Error::Config("service.metrics_addr: expected string".into())
                })?;
                if s.is_empty() {
                    None
                } else {
                    Some(s.to_string())
                }
            }
        };
        let wal = match doc.get("service", "wal") {
            None => None,
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| Error::Config("service.wal: expected string".into()))?;
                if s.is_empty() {
                    None
                } else {
                    Some(PathBuf::from(s))
                }
            }
        };
        let drain_timeout_secs =
            int_in(doc, "service", "drain_timeout_secs", 30, 1, 86_400)? as u64;
        let disk_low_water_mb =
            int_in(doc, "service", "disk_low_water_mb", 0, 0, 1 << 40)? as u64;
        let mut jobs = Vec::new();
        for section in doc.sections() {
            if let Some(name) = section.strip_prefix("job.") {
                jobs.push(job_from_doc(doc, section, name)?);
            }
        }
        Ok(ServiceConfig {
            workers,
            mem_budget_bytes: (mem_budget_mb as u64) << 20,
            cache_bytes: (cache_mb as u64) << 20,
            threads,
            spool,
            watch,
            auto_tune,
            metrics_addr,
            wal,
            drain_timeout_secs,
            disk_low_water_mb,
            jobs,
            fault: fault_from_doc(doc)?,
        })
    }

    /// Parse a spool job file: a single `[job]` section; the job's name
    /// is the file stem (passed in by the scheduler).
    pub fn job_from_file(path: &Path, name: &str) -> Result<JobSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(format!("reading job file {}", path.display()), e))?;
        let doc = Doc::parse(&text)?;
        for section in doc.sections() {
            if section != "job" {
                return Err(Error::Config(format!(
                    "spool job file: unexpected section [{section}] (expected [job])"
                )));
            }
        }
        job_from_doc(&doc, "job", name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::TunedProfile;

    #[test]
    fn defaults_are_sane() {
        let c = RunConfig::defaults();
        assert_eq!(c.dims.n, 512);
        assert_eq!(c.pipeline.block, 256);
        assert_eq!(c.pipeline.host_buffers, 3);
        assert_eq!(c.pipeline.threads, 0);
        assert!(matches!(c.pipeline.backend, BackendKind::Native));
    }

    #[test]
    fn full_config_parses() {
        let c = RunConfig::from_toml(
            r#"
[dataset]
dir = "/tmp/ds"
n = 64
pl = 3
m = 128
seed = 7

[pipeline]
block = 32
ngpus = 2
threads = 6
mode = "block"
backend = "pjrt"
artifacts = "arts"
read_mbps = 120.0

[sim]
profile = "tesla"
"#,
        )
        .unwrap();
        assert_eq!(c.dims.m, 128);
        assert_eq!(c.pipeline.ngpus, 2);
        assert_eq!(c.pipeline.threads, 6);
        assert!(matches!(c.pipeline.mode, OffloadMode::Block));
        match &c.pipeline.backend {
            BackendKind::Pjrt { artifacts } => assert_eq!(artifacts.to_str(), Some("arts")),
            _ => panic!(),
        }
        assert!(c.pipeline.read_throttle.is_some());
        assert_eq!(c.sim.profile.name, "tesla");
    }

    #[test]
    fn unknown_keys_and_values_rejected() {
        assert!(RunConfig::from_toml("[pipeline]\nblok = 2\n").is_err());
        assert!(RunConfig::from_toml("[pipelin]\nblock = 2\n").is_err());
        assert!(RunConfig::from_toml("[pipeline]\nmode = \"warp\"\n").is_err());
        assert!(RunConfig::from_toml("[sim]\nprofile = \"cray\"\n").is_err());
        assert!(RunConfig::from_toml("[dataset]\nn = 0\n").is_err());
    }

    #[test]
    fn service_config_parses() {
        let c = ServiceConfig::from_toml(
            r#"
[service]
workers = 3
mem_budget_mb = 1024
cache_mb = 64
threads = 12
spool = "spool"
watch = true

[job.alpha]
dataset = "data/s1"
block = 128
threads = 4
priority = 2
read_mbps = 120.0

[job.beta]
dataset = "data/s1"
mode = "block"
backend = "pjrt"
artifacts = "arts"
"#,
        )
        .unwrap();
        assert_eq!(c.workers, 3);
        assert_eq!(c.mem_budget_bytes, 1024 << 20);
        assert_eq!(c.cache_bytes, 64 << 20);
        assert_eq!(c.threads, 12);
        assert_eq!(c.spool.as_deref(), Some(std::path::Path::new("spool")));
        assert!(c.watch);
        assert_eq!(c.jobs.len(), 2);
        // Sections come back in alphabetical order.
        assert_eq!(c.jobs[0].name, "alpha");
        assert_eq!(c.jobs[0].block, 128);
        assert_eq!(c.jobs[0].threads, 4);
        assert_eq!(c.jobs[0].priority, 2);
        assert_eq!(c.jobs[1].threads, 0, "threads defaults to auto");
        assert!(c.jobs[0].read_throttle.is_some());
        assert_eq!(c.jobs[1].name, "beta");
        assert!(matches!(c.jobs[1].mode, OffloadMode::Block));
        match &c.jobs[1].backend {
            BackendKind::Pjrt { artifacts } => assert_eq!(artifacts.to_str(), Some("arts")),
            _ => panic!("expected pjrt backend"),
        }
    }

    #[test]
    fn metrics_addr_parses_and_defaults_off() {
        let c = ServiceConfig::from_toml("[service]\nmetrics_addr = \"127.0.0.1:9184\"\n").unwrap();
        assert_eq!(c.metrics_addr.as_deref(), Some("127.0.0.1:9184"));
        // Absent or empty → off.
        assert!(ServiceConfig::from_toml("").unwrap().metrics_addr.is_none());
        let c = ServiceConfig::from_toml("[service]\nmetrics_addr = \"\"\n").unwrap();
        assert!(c.metrics_addr.is_none());
        // Non-string values rejected.
        assert!(ServiceConfig::from_toml("[service]\nmetrics_addr = 9184\n").is_err());
    }

    #[test]
    fn service_defaults_are_sane() {
        let c = ServiceConfig::from_toml("").unwrap();
        assert_eq!(c.workers, 2);
        assert_eq!(c.mem_budget_bytes, 4096 << 20);
        assert_eq!(c.cache_bytes, 256 << 20);
        assert_eq!(c.threads, 0, "compute threads default to all cores");
        assert!(c.spool.is_none());
        assert!(!c.watch);
        assert!(c.auto_tune, "first-contact tuning is on by default");
        assert!(c.wal.is_none(), "no WAL unless a spool or explicit path supplies one");
        assert_eq!(c.drain_timeout_secs, 30);
        assert_eq!(c.disk_low_water_mb, 0, "disk sentinel defaults off");
        assert!(c.jobs.is_empty());
    }

    #[test]
    fn lifecycle_keys_parse_and_reject_garbage() {
        let c = ServiceConfig::from_toml(
            "[service]\nwal = \"svc.wal\"\ndrain_timeout_secs = 5\ndisk_low_water_mb = 512\n\n\
             [job.a]\ndataset = \"d\"\ndeadline_secs = 90\n",
        )
        .unwrap();
        assert_eq!(c.wal.as_deref(), Some(std::path::Path::new("svc.wal")));
        assert_eq!(c.drain_timeout_secs, 5);
        assert_eq!(c.disk_low_water_mb, 512);
        assert_eq!(c.jobs[0].deadline_secs, 90);
        // Empty wal string → default resolution (spool-based), like
        // metrics_addr.
        assert!(ServiceConfig::from_toml("[service]\nwal = \"\"\n").unwrap().wal.is_none());
        assert!(ServiceConfig::from_toml("[service]\nwal = 3\n").is_err());
        assert!(ServiceConfig::from_toml("[service]\ndrain_timeout_secs = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[service]\ndisk_low_water_mb = -1\n").is_err());
        assert!(
            ServiceConfig::from_toml("[job.a]\ndataset = \"d\"\ndeadline_secs = -5\n").is_err()
        );
    }

    #[test]
    fn auto_tune_can_be_disabled_and_pins_track_explicit_keys() {
        let c = ServiceConfig::from_toml(
            "[service]\nauto_tune = false\n\n[job.a]\ndataset = \"d\"\nblock = 64\nthreads = 2\n",
        )
        .unwrap();
        assert!(!c.auto_tune);
        let pins = c.jobs[0].pins;
        assert!(pins.block && pins.threads);
        assert!(!pins.ngpus && !pins.host_buffers && !pins.device_buffers && !pins.lane_threads);
        // A job with no explicit knobs pins nothing.
        let c = ServiceConfig::from_toml("[job.b]\ndataset = \"d\"\n").unwrap();
        assert_eq!(c.jobs[0].pins, crate::service::KnobPins::default());
    }

    #[test]
    fn service_config_rejects_garbage() {
        // Unknown section / key, missing dataset, empty job name, bad budget.
        assert!(ServiceConfig::from_toml("[servce]\nworkers = 1\n").is_err());
        assert!(ServiceConfig::from_toml("[service]\nworker = 1\n").is_err());
        assert!(ServiceConfig::from_toml("[job.a]\nblock = 8\n").is_err());
        assert!(ServiceConfig::from_toml("[job.a]\ndataset = \"d\"\nblokc = 8\n").is_err());
        assert!(ServiceConfig::from_toml("[job.]\ndataset = \"d\"\n").is_err());
        assert!(ServiceConfig::from_toml("[service]\nmem_budget_mb = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[job.a]\ndataset = \"d\"\nmode = \"warp\"\n").is_err());
    }

    #[test]
    fn service_config_rejects_out_of_range_integers() {
        // Negative/zero values must become Error::Config, not wrapped
        // casts that panic (or allocate absurdly) downstream.
        assert!(ServiceConfig::from_toml("[service]\nworkers = -1\n").is_err());
        assert!(ServiceConfig::from_toml("[service]\nworkers = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[service]\ncache_mb = -5\n").is_err());
        assert!(ServiceConfig::from_toml("[job.a]\ndataset = \"d\"\nblock = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[job.a]\ndataset = \"d\"\nblock = -1\n").is_err());
        assert!(ServiceConfig::from_toml("[job.a]\ndataset = \"d\"\nngpus = 0\n").is_err());
        assert!(ServiceConfig::from_toml("[job.a]\ndataset = \"d\"\nhost_buffers = 1\n").is_err());
        assert!(ServiceConfig::from_toml("[service]\nthreads = -2\n").is_err());
        assert!(ServiceConfig::from_toml("[job.a]\ndataset = \"d\"\nthreads = -1\n").is_err());
    }

    #[test]
    fn empty_job_section_is_an_error_not_a_silent_drop() {
        // `[job.gamma]` with its body deleted must fail loudly (missing
        // dataset), not parse to a config with one fewer job.
        let err = ServiceConfig::from_toml("[job.gamma]\n").unwrap_err();
        assert!(err.to_string().contains("missing dataset"), "{err}");
        // Same for a typo'd empty section.
        assert!(ServiceConfig::from_toml("[servce]\n").is_err());
    }

    #[test]
    fn tuned_profile_supplies_defaults_but_explicit_keys_win() {
        let dir = std::env::temp_dir()
            .join(format!("cugwas_schema_{}_prof", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let prof = dir.join("tuned.toml");
        let tuned = TunedProfile {
            block: 1024,
            host_buffers: 4,
            device_buffers: 3,
            ngpus: 2,
            threads: 8,
            lane_threads: 3,
            predicted_secs: 7.5,
            disk_mbps: 100.0,
            disk_lat_secs: 0.0,
            pcie_gbps: 8.0,
            trsm_gflops: 4.0,
            cpu_gflops: 4.0,
        };
        tuned.save(&prof).unwrap();

        // [pipeline] profile: knobs default from the profile…
        let c = RunConfig::from_toml(&format!(
            "[pipeline]\nprofile = \"{}\"\nblock = 512\n",
            prof.display()
        ))
        .unwrap();
        assert_eq!(c.pipeline.block, 512, "explicit key wins");
        assert_eq!(c.pipeline.host_buffers, 4);
        assert_eq!(c.pipeline.device_buffers, 3);
        assert_eq!(c.pipeline.ngpus, 2);
        assert_eq!(c.pipeline.threads, 8);
        assert_eq!(c.pipeline.lane_threads, 3);

        // [job.*] profile: same semantics, plus the predicted duration.
        let s = ServiceConfig::from_toml(&format!(
            "[job.a]\ndataset = \"d\"\nprofile = \"{}\"\nngpus = 1\n",
            prof.display()
        ))
        .unwrap();
        assert_eq!(s.jobs[0].block, 1024);
        assert_eq!(s.jobs[0].ngpus, 1, "explicit key wins");
        assert_eq!(s.jobs[0].device_buffers, 3);
        assert_eq!(s.jobs[0].predicted_secs, Some(7.5));

        // A missing profile file is a config error, not a silent default.
        assert!(RunConfig::from_toml("[pipeline]\nprofile = \"/nonexistent.toml\"\n").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fault_tolerance_section_parses_and_defaults_off() {
        let c = RunConfig::from_toml(
            "[fault_tolerance]\nread_retries = 5\nintegrity = true\nlane_watchdog_ms = 50\n\
             inject_read_fail_every = 7\ninject_wedge_lane = 0\n",
        )
        .unwrap();
        assert_eq!(c.fault.policy.read_retries, 5);
        assert!(c.fault.integrity);
        assert_eq!(c.fault.policy.lane_watchdog_ms, 50);
        assert_eq!(c.fault.plan.read_fail_every, 7);
        assert_eq!(c.fault.plan.wedge_lane, 0);

        // Absent section → defaults: injector off, integrity off.
        let c = RunConfig::defaults();
        assert_eq!(c.fault, FaultToleranceConfig::default());
        assert!(!c.fault.integrity);
        assert_eq!(c.fault.plan.read_fail_col, NO_COL);
        assert_eq!(c.fault.plan.wedge_lane, NO_LANE);

        // Service configs carry the same section, including the
        // lifecycle-chaos knobs (off by default).
        let s = ServiceConfig::from_toml(
            "[fault_tolerance]\njob_retries = 2\nquarantine_after = 4\n\
             inject_wal_torn_append_at = 3\ninject_fake_disk_free_mb = 1\n",
        )
        .unwrap();
        assert_eq!(s.fault.policy.job_retries, 2);
        assert_eq!(s.fault.policy.quarantine_after, 4);
        assert_eq!(s.fault.plan.wal_torn_append_at, 3);
        assert_eq!(s.fault.plan.fake_disk_free_mb, 1);
        assert_eq!(s.fault.plan.wal_crash_at, 0);
        assert_eq!(s.fault.plan.quarantine_crash_at, 0);
        assert_eq!(RunConfig::defaults().fault.plan.fake_disk_free_mb, NO_DISK);

        // Typos and out-of-range values are config errors.
        assert!(RunConfig::from_toml("[fault_tolerance]\nread_retrys = 1\n").is_err());
        assert!(ServiceConfig::from_toml("[fault_tolerance]\nquarantine_after = 0\n").is_err());
        assert!(RunConfig::from_toml("[fault_tolerance]\ninject_wedge_lane = -2\n").is_err());
    }

    #[test]
    fn spool_job_file_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("cugwas_schema_{}_spool", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("myjob.toml");
        std::fs::write(&p, "[job]\ndataset = \"data/x\"\npriority = 7\n").unwrap();
        let spec = ServiceConfig::job_from_file(&p, "myjob").unwrap();
        assert_eq!(spec.name, "myjob");
        assert_eq!(spec.priority, 7);
        assert_eq!(spec.dataset.to_str(), Some("data/x"));
        // A stray section is rejected.
        std::fs::write(&p, "[job]\ndataset = \"d\"\n[extra]\nx = 1\n").unwrap();
        assert!(ServiceConfig::job_from_file(&p, "myjob").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
