//! Typed run configuration loaded from a TOML file (or defaults).
//!
//! ```toml
//! [dataset]
//! dir = "data/study1"
//! n = 512
//! pl = 3
//! m = 4096
//! seed = 42
//!
//! [pipeline]
//! block = 256        # SNP columns per iteration (whole pipeline)
//! ngpus = 1
//! host_buffers = 3
//! mode = "trsm"      # trsm | block | blockfull
//! backend = "pjrt"   # pjrt | native
//! artifacts = "artifacts"
//! read_mbps = 0      # 0 = unthrottled; >0 emulates that storage speed
//! write_mbps = 0
//!
//! [sim]
//! profile = "quadro" # quadro | tesla | hdd
//! ```

use crate::config::toml::Doc;
use crate::coordinator::{BackendKind, OffloadMode, PipelineConfig};
use crate::devsim::HardwareProfile;
use crate::error::{Error, Result};
use crate::gwas::problem::Dims;
use crate::storage::Throttle;
use std::path::PathBuf;

/// Simulation section.
#[derive(Debug, Clone)]
pub struct SimSection {
    pub profile: HardwareProfile,
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub dataset_dir: PathBuf,
    pub dims: Dims,
    pub gen_block: usize,
    pub seed: u64,
    pub pipeline: PipelineConfig,
    pub sim: SimSection,
}

impl RunConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let doc = Doc::parse(text)?;
        Self::from_doc(&doc)
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(format!("reading config {}", path.display()), e))?;
        Self::from_toml(&text)
    }

    /// Built from a parsed document; unknown keys are errors (typo guard).
    pub fn from_doc(doc: &Doc) -> Result<RunConfig> {
        for section in doc.sections() {
            let allowed: &[&str] = match section {
                "dataset" => &["dir", "n", "pl", "m", "seed", "block"],
                "pipeline" => &[
                    "block",
                    "ngpus",
                    "host_buffers",
                    "mode",
                    "backend",
                    "artifacts",
                    "read_mbps",
                    "write_mbps",
                ],
                "sim" => &["profile"],
                "" => &[],
                other => {
                    return Err(Error::Config(format!("unknown section [{other}]")));
                }
            };
            for key in doc.keys_in(section) {
                if !allowed.contains(&key) {
                    return Err(Error::Config(format!("unknown key {section}.{key}")));
                }
            }
        }
        let dataset_dir = PathBuf::from(doc.str_or("dataset", "dir", "data/study")?);
        let n = doc.int_or("dataset", "n", 512)? as usize;
        let pl = doc.int_or("dataset", "pl", 3)? as usize;
        let m = doc.int_or("dataset", "m", 4096)? as usize;
        let dims = Dims::new(n, pl, m)?;
        let gen_block = doc.int_or("dataset", "block", 256)? as usize;
        let seed = doc.int_or("dataset", "seed", 42)? as u64;

        let block = doc.int_or("pipeline", "block", 256)? as usize;
        let ngpus = doc.int_or("pipeline", "ngpus", 1)? as usize;
        let host_buffers = doc.int_or("pipeline", "host_buffers", 3)? as usize;
        let mode = match doc.str_or("pipeline", "mode", "trsm")? {
            "trsm" => OffloadMode::Trsm,
            "block" => OffloadMode::Block,
            "blockfull" => OffloadMode::BlockFull,
            other => return Err(Error::Config(format!("unknown mode '{other}'"))),
        };
        let backend = match doc.str_or("pipeline", "backend", "native")? {
            "native" => BackendKind::Native,
            "pjrt" => BackendKind::Pjrt {
                artifacts: PathBuf::from(doc.str_or("pipeline", "artifacts", "artifacts")?),
            },
            other => return Err(Error::Config(format!("unknown backend '{other}'"))),
        };
        let throttle = |mbps: f64| {
            if mbps > 0.0 {
                Some(Throttle { bytes_per_sec: mbps * 1e6 })
            } else {
                None
            }
        };
        let read_throttle = throttle(doc.float_or("pipeline", "read_mbps", 0.0)?);
        let write_throttle = throttle(doc.float_or("pipeline", "write_mbps", 0.0)?);

        let profile = match doc.str_or("sim", "profile", "quadro")? {
            "quadro" => HardwareProfile::quadro(),
            "tesla" => HardwareProfile::tesla(),
            "hdd" => HardwareProfile::hdd(),
            other => return Err(Error::Config(format!("unknown sim profile '{other}'"))),
        };

        Ok(RunConfig {
            dataset_dir: dataset_dir.clone(),
            dims,
            gen_block,
            seed,
            pipeline: PipelineConfig {
                dataset: dataset_dir,
                block,
                ngpus,
                host_buffers,
                mode,
                backend,
                read_throttle,
                write_throttle,
                resume: false,
            },
            sim: SimSection { profile },
        })
    }

    /// All defaults (native backend, synthetic mid-size study).
    pub fn defaults() -> RunConfig {
        Self::from_toml("").expect("defaults parse")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = RunConfig::defaults();
        assert_eq!(c.dims.n, 512);
        assert_eq!(c.pipeline.block, 256);
        assert_eq!(c.pipeline.host_buffers, 3);
        assert!(matches!(c.pipeline.backend, BackendKind::Native));
    }

    #[test]
    fn full_config_parses() {
        let c = RunConfig::from_toml(
            r#"
[dataset]
dir = "/tmp/ds"
n = 64
pl = 3
m = 128
seed = 7

[pipeline]
block = 32
ngpus = 2
mode = "block"
backend = "pjrt"
artifacts = "arts"
read_mbps = 120.0

[sim]
profile = "tesla"
"#,
        )
        .unwrap();
        assert_eq!(c.dims.m, 128);
        assert_eq!(c.pipeline.ngpus, 2);
        assert!(matches!(c.pipeline.mode, OffloadMode::Block));
        match &c.pipeline.backend {
            BackendKind::Pjrt { artifacts } => assert_eq!(artifacts.to_str(), Some("arts")),
            _ => panic!(),
        }
        assert!(c.pipeline.read_throttle.is_some());
        assert_eq!(c.sim.profile.name, "tesla");
    }

    #[test]
    fn unknown_keys_and_values_rejected() {
        assert!(RunConfig::from_toml("[pipeline]\nblok = 2\n").is_err());
        assert!(RunConfig::from_toml("[pipelin]\nblock = 2\n").is_err());
        assert!(RunConfig::from_toml("[pipeline]\nmode = \"warp\"\n").is_err());
        assert!(RunConfig::from_toml("[sim]\nprofile = \"cray\"\n").is_err());
        assert!(RunConfig::from_toml("[dataset]\nn = 0\n").is_err());
    }
}
