//! Plan: search the pipeline's knob space with the DES model as the
//! objective. Each candidate configuration is priced by simulating the
//! cuGWAS task graph ([`crate::devsim::pipeline_model`]) under a
//! [`HardwareProfile`] built from *probed* rates, so the whole search
//! costs milliseconds — no trial runs.
//!
//! [`plan`] is literally `argmin(predict)` over [`candidates`]: the unit
//! tests prove the planner inverts the model by recomputing the
//! predictions independently and checking the argmin matches.
//!
//! [`replan_block`] is the in-flight variant the coordinator calls at
//! segment boundaries: the observed stall profile picks a direction
//! (read-starved → larger blocks, compute-starved → smaller — the
//! real-machine effects of sequential locality and per-request overhead
//! that a linear disk model cannot see), and the DES veto-guards the
//! move against pipeline-structure regressions (fill/drain, buffer
//! dependencies) before the switch is taken.

use crate::devsim::{
    simulate_cugwas_with, transition_secs, HardwareProfile, SegmentKnobs, SimConfig,
};
use crate::error::Result;
use crate::gwas::problem::Dims;
use crate::tune::probe::ProbedRates;
use crate::tune::profile::TunedProfile;

/// Planner search bounds.
#[derive(Debug, Clone, Copy)]
pub struct PlanOpts {
    /// Total compute threads available (resolved, ≥ 1).
    pub total_threads: usize,
    /// Largest lane count to consider.
    pub max_lanes: usize,
    /// Host-memory cap on the rings + staging chunks (0 = uncapped).
    pub host_mem_bytes: u64,
    /// Largest block size to consider (0 = 65536).
    pub max_block: usize,
    /// Trait-batch width the run will stream with (≥ 1). Widens the
    /// S-loop and the result ring in every priced candidate, so the
    /// planner trades block size against batch width instead of sizing
    /// the pipeline for a single phenotype it won't run.
    pub traits: usize,
}

impl Default for PlanOpts {
    fn default() -> Self {
        PlanOpts { total_threads: 1, max_lanes: 1, host_mem_bytes: 0, max_block: 0, traits: 1 }
    }
}

/// One point of the search space, with the rate profile priced for it.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub block: usize,
    pub host_buffers: usize,
    pub device_buffers: usize,
    pub ngpus: usize,
    pub lane_threads: usize,
    pub coord_threads: usize,
    /// Trait-batch width this candidate is priced for (from
    /// [`PlanOpts::traits`]).
    pub traits: usize,
    pub profile: HardwareProfile,
}

/// Steady-state host bytes of a candidate for the memory cap: the slab
/// ring (`host_buffers` staged reads) and the result ring, plus up to
/// `device_buffers` windows' worth of slabs kept alive by lane views in
/// flight. (The pre-slab plane spent the same `device_buffers` term on
/// per-lane staging copies; zero-copy moves those bytes into shared
/// slab residency, so the bill is unchanged — just no longer doubled
/// when the cache also holds a block.)
fn candidate_bytes(c: &Candidate, n: usize, p: usize) -> u64 {
    let slabs = (c.host_buffers + c.device_buffers) * c.block * n;
    // Result buffers hold t stacked p-vectors per column.
    let results = c.host_buffers * c.block * p * c.traits.max(1);
    (8 * (slabs + results)) as u64
}

/// Enumerate the search space for `dims` under `opts`, pricing each point
/// with the probed rates. Deterministic order (the argmin tie-break).
pub fn candidates(rates: &ProbedRates, dims: Dims, opts: &PlanOpts) -> Vec<Candidate> {
    let total = opts.total_threads.max(1);
    let max_block = if opts.max_block == 0 { 65_536 } else { opts.max_block };
    let mut blocks = Vec::new();
    let mut b = 64usize;
    while b < max_block.min(dims.m) {
        blocks.push(b);
        b *= 2;
    }
    blocks.push(max_block.min(dims.m));
    blocks.dedup();

    let mut out = Vec::new();
    for ngpus in 1..=opts.max_lanes.max(1) {
        // Feasible per-lane thread budgets: probed counts that leave the
        // coordinator at least one thread. Oversubscribed fallback: 1.
        let mut lane_counts: Vec<usize> = rates
            .kernels
            .keys()
            .copied()
            .filter(|&lt| lt * ngpus < total)
            .collect();
        if lane_counts.is_empty() {
            lane_counts.push(1);
        }
        for &raw in &blocks {
            let block = (raw / ngpus) * ngpus;
            if block == 0 || block > dims.m {
                continue;
            }
            for host_buffers in [2usize, 3, 4] {
                for device_buffers in [2usize, 3] {
                    for &lane_threads in &lane_counts {
                        let coord_threads = total.saturating_sub(lane_threads * ngpus).max(1);
                        let c = Candidate {
                            block,
                            host_buffers,
                            device_buffers,
                            ngpus,
                            lane_threads,
                            coord_threads,
                            traits: opts.traits.max(1),
                            profile: HardwareProfile {
                                name: "probed",
                                gpu_trsm_gflops: rates.trsm_at(lane_threads),
                                // The coordinator's CPU work is the
                                // S-loop — priced with the skinny-gemm
                                // rate, not the square-panel one.
                                cpu_gflops: rates.sloop_at(coord_threads),
                                pcie_gbps: rates.pcie_gbps,
                                disk_mbps: rates.disk_mbps,
                                disk_lat_secs: rates.disk_lat_secs.max(0.0),
                                probabel_gflops: 0.1,
                            },
                        };
                        if opts.host_mem_bytes > 0
                            && candidate_bytes(&c, dims.n, dims.p()) > opts.host_mem_bytes
                        {
                            continue;
                        }
                        out.push(c);
                    }
                }
            }
        }
    }
    out
}

/// DES-predicted wall seconds for one candidate over `dims`.
pub fn predict(c: &Candidate, dims: Dims) -> Result<f64> {
    let cfg = SimConfig {
        dims,
        block: c.block,
        ngpus: c.ngpus,
        host_buffers: c.host_buffers.clamp(2, 8),
        traits: c.traits.max(1),
        profile: c.profile,
    };
    Ok(simulate_cugwas_with(&cfg, c.device_buffers.clamp(2, 8))?.total_secs)
}

/// Pick the candidate the model simulates fastest. A degenerate probe
/// (or an empty/unpriceable search space) falls back to
/// [`TunedProfile::safe_defaults`] — tuning must never panic or emit a
/// plan built on garbage rates.
pub fn plan(rates: &ProbedRates, dims: Dims, opts: &PlanOpts) -> TunedProfile {
    let total = opts.total_threads.max(1);
    if rates.degenerate() {
        return TunedProfile::safe_defaults(dims.m, total);
    }
    let mut best: Option<(f64, Candidate)> = None;
    for c in candidates(rates, dims, opts) {
        let Ok(secs) = predict(&c, dims) else { continue };
        if !secs.is_finite() {
            continue;
        }
        let better = match &best {
            None => true,
            Some((bs, _)) => secs < *bs,
        };
        if better {
            best = Some((secs, c));
        }
    }
    match best {
        Some((secs, c)) => TunedProfile {
            block: c.block,
            host_buffers: c.host_buffers,
            device_buffers: c.device_buffers,
            ngpus: c.ngpus,
            threads: total,
            lane_threads: c.lane_threads,
            predicted_secs: secs,
            disk_mbps: rates.disk_mbps,
            disk_lat_secs: rates.disk_lat_secs.max(0.0),
            pcie_gbps: rates.pcie_gbps,
            trsm_gflops: c.profile.gpu_trsm_gflops,
            cpu_gflops: c.profile.cpu_gflops,
        },
        None => TunedProfile::safe_defaults(dims.m, total),
    }
}

// ---- adaptive re-planning (step 4: the coordinator's in-flight loop) ---

/// Live rates + stall profile observed over one pipeline segment.
#[derive(Debug, Clone, Copy)]
pub struct LiveObs {
    /// Segment wall seconds.
    pub wall_secs: f64,
    /// Coordinator seconds stalled on `aio_read` (Phase::ReadWait).
    pub read_wait_secs: f64,
    /// Coordinator seconds stalled on device results (Phase::RecvWait).
    pub recv_wait_secs: f64,
    /// Effective disk bandwidth from the reader engine's own accounting
    /// (asymptotic when a per-request latency has been separated out).
    pub disk_mbps: f64,
    /// Per-request read latency (seconds; 0 = unknown). The coordinator
    /// fits this live from per-request timings once two segments have
    /// streamed at different block sizes — the in-flight analogue of the
    /// probe's two-window measurement.
    pub disk_lat_secs: f64,
    /// Observed lane trsm rate (device seconds vs trsm flops).
    pub trsm_gflops: f64,
    /// Observed coordinator S-loop rate (sloop seconds vs its flops).
    pub cpu_gflops: f64,
    /// Effective staging bandwidth. On the zero-copy plane the chunk
    /// handoff is a borrowed view — the link is structurally never the
    /// constraint — so the observer reports a large finite constant
    /// (`ZERO_COPY_LINK_GBPS`) rather than a noise-seeded timing of an
    /// O(1) handoff. The PJRT literal-boundary copy happens lane-side
    /// and lands in device-compute time / `DevOut::staged_copy_bytes`,
    /// not here — moot for this field's consumers, which only run with
    /// the native backend (`--adapt` refuses PJRT).
    pub pcie_gbps: f64,
}

/// Stall fraction below which the live profile counts as matching the
/// model's prediction of a balanced pipeline — no re-plan.
pub const STALL_THRESHOLD: f64 = 0.10;
/// The DES veto: a directional switch is taken only if the model does
/// not predict the candidate to be worse than staying put by more than
/// this factor (the model cannot see the sequential-locality gains that
/// motivate growing, so it guards rather than drives).
const VETO_FACTOR: f64 = 1.02;
const MIN_BLOCK: usize = 64;
const MAX_BLOCK: usize = 1 << 20;

/// Decide a new block size for the remaining work, or `None` to keep the
/// current one. `dims.m` must be the *remaining* SNP columns.
pub fn replan_block(
    obs: &LiveObs,
    dims: Dims,
    cur_block: usize,
    ngpus: usize,
    host_buffers: usize,
    device_buffers: usize,
) -> Option<usize> {
    if obs.wall_secs <= 0.0 {
        return None;
    }
    let read_frac = obs.read_wait_secs / obs.wall_secs;
    let recv_frac = obs.recv_wait_secs / obs.wall_secs;
    // Model prediction for a healthy multibuffered pipeline: neither
    // stall dominates. Within threshold → observed matches → keep.
    if read_frac < STALL_THRESHOLD && recv_frac < STALL_THRESHOLD {
        return None;
    }
    let rates = [obs.disk_mbps, obs.trsm_gflops, obs.cpu_gflops, obs.pcie_gbps];
    if rates.iter().any(|r| !r.is_finite() || *r <= 0.0) {
        return None;
    }
    let grow = read_frac >= recv_frac; // read-starved → larger blocks
    let raw = if grow { cur_block.saturating_mul(2) } else { cur_block / 2 };
    let clamp = |b: usize| -> usize {
        let b = b.clamp(MIN_BLOCK.min(dims.m), MAX_BLOCK.min(dims.m));
        ((b / ngpus) * ngpus).max(ngpus)
    };
    let cand = clamp(raw);
    let cur = clamp(cur_block);
    if cand == cur {
        return None;
    }
    let profile = HardwareProfile {
        name: "live",
        gpu_trsm_gflops: obs.trsm_gflops,
        cpu_gflops: obs.cpu_gflops,
        pcie_gbps: obs.pcie_gbps,
        disk_mbps: obs.disk_mbps,
        disk_lat_secs: obs.disk_lat_secs.max(0.0),
        probabel_gflops: 0.1,
    };
    let predict_at = |block: usize| -> Option<f64> {
        // The directional fallback predates trait batching and only runs
        // single-phenotype streams; the deep planner carries `traits`.
        let cfg = SimConfig {
            dims,
            block,
            ngpus,
            host_buffers: host_buffers.clamp(2, 8),
            traits: 1,
            profile,
        };
        simulate_cugwas_with(&cfg, device_buffers.clamp(2, 8))
            .ok()
            .map(|r| r.total_secs)
            .filter(|s| s.is_finite())
    };
    let p_cur = predict_at(cur)?;
    let p_cand = predict_at(cand)?;
    if p_cand <= p_cur * VETO_FACTOR {
        Some(cand)
    } else {
        None
    }
}

/// Minimum predicted improvement (including the transition cost) before
/// a knob switch is taken — the hysteresis that keeps the pipeline from
/// flapping between near-equivalent configurations.
const SWITCH_GAIN: f64 = 0.98;

/// Full-depth in-flight re-plan: search the one-step neighborhood of the
/// current knobs (block ×2/÷2, host/device buffers ±1, lane threads
/// ×2/÷2) with the DES as the objective, each candidate priced over the
/// *remaining* columns **plus** its own [`transition_secs`]. With the
/// per-request latency term in the live profile the model itself now
/// favors larger blocks when read-starved — the DES *drives* the move
/// instead of only veto-guarding a heuristic.
///
/// `dims.m` must be the remaining SNP columns; `total_threads` the run's
/// resolved compute budget (the lane/coordinator split is re-derived per
/// candidate); `traits` the run's batch width (the S-loop and result
/// geometry every candidate is priced with). Returns `None` when the
/// pipeline is balanced, the observations are degenerate, or no neighbor
/// beats staying put by at least the hysteresis margin.
pub fn replan_knobs(
    obs: &LiveObs,
    dims: Dims,
    cur: SegmentKnobs,
    ngpus: usize,
    total_threads: usize,
    traits: usize,
) -> Option<SegmentKnobs> {
    if obs.wall_secs <= 0.0 {
        return None;
    }
    let read_frac = obs.read_wait_secs / obs.wall_secs;
    let recv_frac = obs.recv_wait_secs / obs.wall_secs;
    if read_frac < STALL_THRESHOLD && recv_frac < STALL_THRESHOLD {
        return None;
    }
    let rates = [obs.disk_mbps, obs.trsm_gflops, obs.cpu_gflops, obs.pcie_gbps];
    if rates.iter().any(|r| !r.is_finite() || *r <= 0.0) {
        return None;
    }
    let p_cur = predict_switch(obs, dims, &cur, &cur, ngpus, total_threads, traits)?;
    let mut best: Option<(f64, SegmentKnobs)> = None;
    for cand in knob_neighborhood(&cur, dims, ngpus, total_threads) {
        let Some(secs) = predict_switch(obs, dims, &cand, &cur, ngpus, total_threads, traits)
        else {
            continue;
        };
        if best.as_ref().map_or(true, |(b, _)| secs < *b) {
            best = Some((secs, cand));
        }
    }
    match best {
        Some((secs, cand)) if secs < p_cur * SWITCH_GAIN => Some(cand),
        _ => None,
    }
}

/// One-step neighbors of `cur`, deduplicated, every one respecting the
/// pipeline invariants (block divides across lanes, buffers in the DES
/// range, the coordinator keeps ≥ 1 thread).
fn knob_neighborhood(
    cur: &SegmentKnobs,
    dims: Dims,
    ngpus: usize,
    total_threads: usize,
) -> Vec<SegmentKnobs> {
    let g = ngpus.max(1);
    let clamp_block = |b: usize| -> usize {
        let b = b.clamp(MIN_BLOCK.min(dims.m), MAX_BLOCK.min(dims.m));
        ((b / g) * g).max(g)
    };
    let mut out = Vec::new();
    for b in [cur.block.saturating_mul(2), cur.block / 2] {
        let b = clamp_block(b);
        if b != cur.block {
            out.push(SegmentKnobs { block: b, ..*cur });
        }
    }
    for hb in [cur.host_buffers + 1, cur.host_buffers.saturating_sub(1)] {
        if (2..=8).contains(&hb) && hb != cur.host_buffers {
            out.push(SegmentKnobs { host_buffers: hb, ..*cur });
        }
    }
    for db in [cur.device_buffers + 1, cur.device_buffers.saturating_sub(1)] {
        if (2..=8).contains(&db) && db != cur.device_buffers {
            out.push(SegmentKnobs { device_buffers: db, ..*cur });
        }
    }
    for lt in [cur.lane_threads.saturating_mul(2), cur.lane_threads / 2] {
        // The coordinator must keep at least one thread for the S-loop.
        if lt >= 1 && lt * g < total_threads.max(2) && lt != cur.lane_threads {
            out.push(SegmentKnobs { lane_threads: lt, ..*cur });
        }
    }
    out.dedup();
    out
}

/// DES seconds for the remaining `dims` under `cand`, plus what it costs
/// to get there from `cur`. Kernel rates were observed at the *current*
/// thread split; a candidate that moves threads is priced with the
/// observed rate scaled by its thread ratio (linear-scaling assumption —
/// optimistic, which is why the hysteresis margin and the next segment's
/// real observation both stand behind it).
#[allow(clippy::too_many_arguments)]
fn predict_switch(
    obs: &LiveObs,
    dims: Dims,
    cand: &SegmentKnobs,
    cur: &SegmentKnobs,
    ngpus: usize,
    total_threads: usize,
    traits: usize,
) -> Option<f64> {
    let g = ngpus.max(1);
    let coord_of = |lt: usize| total_threads.saturating_sub(lt * g).max(1);
    let lane_scale = cand.lane_threads as f64 / cur.lane_threads.max(1) as f64;
    let coord_scale = coord_of(cand.lane_threads) as f64 / coord_of(cur.lane_threads) as f64;
    let profile = HardwareProfile {
        name: "live",
        gpu_trsm_gflops: obs.trsm_gflops * lane_scale,
        cpu_gflops: obs.cpu_gflops * coord_scale,
        pcie_gbps: obs.pcie_gbps,
        disk_mbps: obs.disk_mbps,
        disk_lat_secs: obs.disk_lat_secs.max(0.0),
        probabel_gflops: 0.1,
    };
    // Tail clamp: the remainder may be smaller than the block; keep the
    // simulated block within it and divisible across lanes.
    let block = ((cand.block.min(dims.m) / g) * g).max(g);
    let t = traits.max(1);
    let cfg = SimConfig {
        dims,
        block,
        ngpus: g,
        host_buffers: cand.host_buffers.clamp(2, 8),
        traits: t,
        profile,
    };
    let steady = simulate_cugwas_with(&cfg, cand.device_buffers.clamp(2, 8))
        .ok()
        .map(|r| r.total_secs)
        .filter(|s| s.is_finite())?;
    // Transition pricing sees the widened result rows (`p·t`): a bigger
    // batch makes ring re-allocation proportionally more expensive.
    Some(steady + transition_secs(cur, cand, dims.n, dims.p() * t, g, &profile))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::probe::KernelRates;
    use std::collections::BTreeMap;

    fn rates() -> ProbedRates {
        let mut kernels = BTreeMap::new();
        // sloop rates mirror the old gemm fixture values so the argmin
        // checks below exercise the same decision landscape.
        kernels
            .insert(1, KernelRates { trsm_gflops: 2.0, gemm_gflops: 2.5, sloop_gflops: 2.5 });
        kernels
            .insert(2, KernelRates { trsm_gflops: 3.6, gemm_gflops: 4.5, sloop_gflops: 4.5 });
        kernels
            .insert(4, KernelRates { trsm_gflops: 6.0, gemm_gflops: 8.0, sloop_gflops: 8.0 });
        ProbedRates {
            disk_mbps: 120.0,
            disk_lat_secs: 0.0,
            disk_bytes: 8 << 20,
            pcie_gbps: 8.0,
            kernels,
            reliable: true,
        }
    }

    #[test]
    fn planner_inverts_the_model() {
        // The profile the planner picks must be the one the DES simulates
        // fastest — recompute every prediction independently and check
        // the argmin matches.
        let dims = Dims::new(256, 3, 4096).unwrap();
        let opts = PlanOpts {
            total_threads: 4,
            max_lanes: 2,
            host_mem_bytes: 0,
            max_block: 2048,
            traits: 1,
        };
        let r = rates();
        let chosen = plan(&r, dims, &opts);
        let mut best = f64::INFINITY;
        let mut best_c = None;
        for c in candidates(&r, dims, &opts) {
            let secs = predict(&c, dims).unwrap();
            if secs < best {
                best = secs;
                best_c = Some(c);
            }
        }
        let best_c = best_c.expect("non-empty grid");
        assert_eq!(chosen.block, best_c.block);
        assert_eq!(chosen.host_buffers, best_c.host_buffers);
        assert_eq!(chosen.device_buffers, best_c.device_buffers);
        assert_eq!(chosen.ngpus, best_c.ngpus);
        assert_eq!(chosen.lane_threads, best_c.lane_threads);
        assert!((chosen.predicted_secs - best).abs() < 1e-12);
    }

    #[test]
    fn degenerate_probe_falls_back_to_safe_defaults() {
        let dims = Dims::new(64, 2, 100).unwrap();
        let opts = PlanOpts { total_threads: 2, ..PlanOpts::default() };
        for bad in [
            ProbedRates { disk_mbps: 0.0, ..rates() },
            ProbedRates { reliable: false, ..rates() },
            ProbedRates { kernels: BTreeMap::new(), ..rates() },
            ProbedRates { pcie_gbps: f64::NAN, ..rates() },
        ] {
            let p = plan(&bad, dims, &opts);
            assert_eq!(p, TunedProfile::safe_defaults(100, 2), "probe: {bad:?}");
        }
    }

    #[test]
    fn candidates_respect_memory_cap_and_block_bounds() {
        let dims = Dims::new(256, 3, 4096).unwrap();
        let mut opts = PlanOpts {
            total_threads: 4,
            max_lanes: 2,
            host_mem_bytes: 0,
            max_block: 2048,
            traits: 1,
        };
        let all = candidates(&rates(), dims, &opts);
        assert!(!all.is_empty());
        for c in &all {
            assert!(c.block <= 2048 && c.block % c.ngpus == 0);
            assert!(c.coord_threads >= 1);
        }
        // A tight cap prunes the big-block candidates but never empties
        // the space entirely at the small end.
        opts.host_mem_bytes = 8 * 1024 * (256 + 4) * 3; // ≈ 3 host buffers of 1024 cols
        let capped = candidates(&rates(), dims, &opts);
        assert!(!capped.is_empty());
        assert!(capped.iter().all(|c| c.block < 2048));
        assert!(capped.len() < all.len());
    }

    #[test]
    fn trait_batch_widens_predicted_cost_and_memory() {
        // The same geometry priced at t=32 must simulate slower than at
        // t=1 (more S-loop work, bigger write-back) but nowhere near 32×
        // (the stream and the factorizations are shared).
        let dims = Dims::new(256, 3, 4096).unwrap();
        let base =
            PlanOpts { total_threads: 4, max_lanes: 1, host_mem_bytes: 0, max_block: 1024, traits: 1 };
        let wide = PlanOpts { traits: 32, ..base };
        let one = &candidates(&rates(), dims, &base)[0];
        let batched = &candidates(&rates(), dims, &wide)[0];
        assert_eq!(one.block, batched.block);
        let p1 = predict(one, dims).unwrap();
        let p32 = predict(batched, dims).unwrap();
        assert!(p32 > p1, "t=32 must cost more: {p32} vs {p1}");
        assert!(p32 < 32.0 * p1, "t=32 must amortize the stream: {p32} vs {p1}");
        // And the memory cap sees the widened result ring.
        assert!(
            candidate_bytes(batched, dims.n, dims.p()) > candidate_bytes(one, dims.n, dims.p())
        );
    }

    fn obs() -> LiveObs {
        LiveObs {
            wall_secs: 10.0,
            read_wait_secs: 0.2,
            recv_wait_secs: 0.2,
            disk_mbps: 80.0,
            disk_lat_secs: 0.0,
            trsm_gflops: 4.0,
            cpu_gflops: 4.0,
            pcie_gbps: 8.0,
        }
    }

    #[test]
    fn balanced_pipeline_is_left_alone() {
        let dims = Dims::new(256, 3, 100_000).unwrap();
        assert_eq!(replan_block(&obs(), dims, 1024, 1, 3, 2), None);
    }

    #[test]
    fn read_starved_grows_the_block() {
        let dims = Dims::new(256, 3, 100_000).unwrap();
        let o = LiveObs { read_wait_secs: 6.0, ..obs() };
        assert_eq!(replan_block(&o, dims, 1024, 1, 3, 2), Some(2048));
        // Multi-lane: the new block still divides across lanes.
        let switched = replan_block(&o, dims, 1024, 2, 3, 2).unwrap();
        assert_eq!(switched % 2, 0);
    }

    #[test]
    fn compute_starved_shrinks_the_block() {
        let dims = Dims::new(256, 3, 100_000).unwrap();
        let o = LiveObs { recv_wait_secs: 6.0, ..obs() };
        assert_eq!(replan_block(&o, dims, 1024, 1, 3, 2), Some(512));
    }

    #[test]
    fn degenerate_observations_never_switch() {
        let dims = Dims::new(256, 3, 100_000).unwrap();
        let o = LiveObs { read_wait_secs: 6.0, disk_mbps: 0.0, ..obs() };
        assert_eq!(replan_block(&o, dims, 1024, 1, 3, 2), None);
        let o = LiveObs { wall_secs: 0.0, ..obs() };
        assert_eq!(replan_block(&o, dims, 1024, 1, 3, 2), None);
        // Already at the floor/ceiling → no switch.
        let o = LiveObs { recv_wait_secs: 6.0, ..obs() };
        assert_eq!(replan_block(&o, dims, MIN_BLOCK, 1, 3, 2), None);
    }

    // ---- full-depth re-planning --------------------------------------

    fn knobs(block: usize, hb: usize, db: usize, lt: usize) -> SegmentKnobs {
        SegmentKnobs { block, host_buffers: hb, device_buffers: db, lane_threads: lt }
    }

    #[test]
    fn balanced_pipeline_keeps_all_knobs() {
        let dims = Dims::new(256, 3, 100_000).unwrap();
        assert_eq!(replan_knobs(&obs(), dims, knobs(1024, 3, 2, 1), 1, 4, 1), None);
    }

    #[test]
    fn latency_heavy_read_starved_pipeline_grows_the_block_model_driven() {
        // 5 ms per request at 80 MB/s: a 1024-column read (2 MiB at
        // n=256) pays ~17% latency overhead, a 2048-column one half
        // that. The DES itself — not a heuristic — must prefer the
        // bigger block once read waits dominate.
        let dims = Dims::new(256, 3, 100_000).unwrap();
        let o = LiveObs { read_wait_secs: 6.0, disk_lat_secs: 5e-3, ..obs() };
        let cur = knobs(1024, 3, 2, 1);
        let picked = replan_knobs(&o, dims, cur, 1, 4, 1).expect("must switch");
        assert!(picked.block > cur.block, "picked {picked:?}");
        // The same stall profile with a latency-free disk still has the
        // directional rule available via `replan_block`; the deep
        // planner only moves when the model predicts a real win.
        let flat = LiveObs { read_wait_secs: 6.0, ..obs() };
        if let Some(k) = replan_knobs(&flat, dims, cur, 1, 4, 1) {
            assert!(k != cur);
        }
        // A wide trait batch re-prices the neighborhood but must still
        // produce a valid decision (any switch keeps the invariants).
        if let Some(k) = replan_knobs(&o, dims, cur, 1, 4, 16) {
            assert!(k.block % 1 == 0 && k.host_buffers >= 2);
        }
    }

    #[test]
    fn neighborhood_respects_invariants() {
        let dims = Dims::new(256, 3, 100_000).unwrap();
        for cand in knob_neighborhood(&knobs(1024, 3, 2, 2), dims, 2, 8) {
            assert!(cand.block % 2 == 0 && cand.block <= dims.m);
            assert!((2..=8).contains(&cand.host_buffers));
            assert!((2..=8).contains(&cand.device_buffers));
            assert!(cand.lane_threads >= 1 && cand.lane_threads * 2 < 8);
        }
        // A 2-thread budget on one lane cannot move lane_threads at all
        // (the coordinator must keep a thread).
        for cand in knob_neighborhood(&knobs(1024, 3, 2, 1), dims, 1, 2) {
            assert_eq!(cand.lane_threads, 1);
        }
    }

    #[test]
    fn transition_cost_vetoes_a_switch_near_the_end_of_the_stream() {
        // Same starved observation, but only one tail window of work
        // left: every neighbor's steady-state prediction collapses to
        // the same tail-clamped schedule, so no candidate can pay for
        // its own migration and the planner stays put.
        let o = LiveObs { read_wait_secs: 6.0, disk_lat_secs: 5e-3, ..obs() };
        let cur = knobs(1024, 3, 2, 1);
        let plenty = Dims::new(256, 3, 100_000).unwrap();
        let sliver = Dims::new(256, 3, 256).unwrap();
        assert!(replan_knobs(&o, plenty, cur, 1, 2, 1).is_some());
        assert_eq!(replan_knobs(&o, sliver, cur, 1, 2, 1), None);
    }
}
