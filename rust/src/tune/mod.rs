//! Model-driven autotuner for the streaming plane.
//!
//! The paper's "sophisticated transfer strategy" is not a fixed
//! configuration but a *derived* one: §3.1's multibuffering analysis
//! picks the block size and buffer counts that balance disk bandwidth
//! against compute rate. This module closes that loop for the live
//! pipeline in three steps, with a fourth running inside the coordinator:
//!
//! 1. **Probe** ([`probe`]) — short calibration runs measure effective
//!    disk read bandwidth (through [`crate::storage::probe_read_bandwidth`],
//!    i.e. the same aio engine + read-ahead pattern the pipeline uses),
//!    kernel GFlop/s at each feasible thread count (the `linalg` kernels
//!    as a library, not a bench), and host memcpy bandwidth (the
//!    emulated PCIe link).
//! 2. **Plan** ([`plan`]) — feed the probed rates into
//!    [`crate::devsim::pipeline_model`] and search the (block size, host
//!    buffers, device buffers, lane count, lane-vs-S-loop thread split)
//!    space with the DES as the objective, so the search costs
//!    milliseconds instead of runs. The winner is a [`TunedProfile`].
//! 3. **Apply** — `cugwas tune` writes the profile as TOML; `run` and
//!    `serve` accept it via `--profile` / a `[job.*] profile` key, and
//!    the service scheduler orders admission by the profile's predicted
//!    duration (shortest-job-first within a priority).
//! 4. **Adapt** ([`plan::replan_knobs`]) — at segment boundaries the
//!    engine compares its live `Metrics` stall profile against the
//!    model and re-plans the *full* knob depth (block size, host/device
//!    buffer counts, lane-vs-S-loop thread split), pricing each
//!    candidate switch with the DES over the remaining work plus its
//!    transition cost ([`crate::devsim::transition_secs`]), journaling
//!    every persisted window so resume stays correct across a switch.
//!    ([`plan::replan_block`] remains as the block-only directional
//!    variant.)

pub mod plan;
pub mod probe;
pub mod profile;

pub use crate::devsim::SegmentKnobs;
pub use plan::{candidates, plan, predict, replan_block, replan_knobs, Candidate, LiveObs, PlanOpts};
pub use probe::{
    fit_disk_latency, probe_dataset, probe_kernels, KernelRates, ProbeOpts, ProbedRates,
};
pub use profile::{load_or_default, TunedProfile};
