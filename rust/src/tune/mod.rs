//! Model-driven autotuner for the streaming plane.
//!
//! The paper's "sophisticated transfer strategy" is not a fixed
//! configuration but a *derived* one: §3.1's multibuffering analysis
//! picks the block size and buffer counts that balance disk bandwidth
//! against compute rate. This module closes that loop for the live
//! pipeline in three steps, with a fourth running inside the coordinator:
//!
//! 1. **Probe** ([`probe`]) — short calibration runs measure effective
//!    disk read bandwidth (through [`crate::storage::probe_read_bandwidth`],
//!    i.e. the same aio engine + read-ahead pattern the pipeline uses),
//!    kernel GFlop/s at each feasible thread count (the `linalg` kernels
//!    as a library, not a bench), and host memcpy bandwidth (the
//!    emulated PCIe link).
//! 2. **Plan** ([`plan`]) — feed the probed rates into
//!    [`crate::devsim::pipeline_model`] and search the (block size, host
//!    buffers, device buffers, lane count, lane-vs-S-loop thread split)
//!    space with the DES as the objective, so the search costs
//!    milliseconds instead of runs. The winner is a [`TunedProfile`].
//! 3. **Apply** — `cugwas tune` writes the profile as TOML; `run` and
//!    `serve` accept it via `--profile` / a `[job.*] profile` key, and
//!    the service scheduler orders admission by the profile's predicted
//!    duration (shortest-job-first within a priority).
//! 4. **Adapt** ([`plan::replan_block`]) — at segment boundaries the
//!    coordinator compares its live `Metrics` stall profile against the
//!    model's prediction and re-plans the block size (read-starved →
//!    larger blocks, compute-starved → smaller), journaling every
//!    persisted window so resume stays correct across a switch.

pub mod plan;
pub mod probe;
pub mod profile;

pub use plan::{candidates, plan, predict, replan_block, Candidate, LiveObs, PlanOpts};
pub use probe::{probe_dataset, probe_kernels, KernelRates, ProbeOpts, ProbedRates};
pub use profile::TunedProfile;
