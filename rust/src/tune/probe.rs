//! Probe: short calibration runs that measure what this machine (and
//! this dataset's storage) can actually do. The numbers feed the planner
//! — and, re-measured live per segment, the adaptive re-planner.
//!
//! Three measurements:
//!
//! * **disk** — [`crate::storage::probe_read_bandwidth`] streams a
//!   bounded prefix of the dataset's `xr.xrd` through the same aio
//!   engine + read-ahead pattern the pipeline uses, honoring any
//!   emulated-storage throttle;
//! * **kernels** — the `linalg` trsm/gemm kernels (as a library, not a
//!   bench) timed at every feasible thread count, so the planner can
//!   price each lane-vs-S-loop thread split with a measured rate
//!   instead of an interpolation;
//! * **memcpy** — host copy bandwidth, the stand-in for the PCIe link
//!   the native lanes cross.

use crate::error::Result;
use crate::linalg::{gemm, potrf, trsm_lower_left, Matrix};
use crate::storage::{
    dataset::DatasetPaths, probe_read_bandwidth_windowed, ReadProbe, Throttle, XrdFile,
};
use crate::util::{threads, XorShift};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

/// Below this many probed bytes the disk estimate is noise, not signal;
/// the planner falls back to safe defaults instead of planning on it.
pub const MIN_DISK_PROBE_BYTES: u64 = 1 << 20;

/// Measured kernel rates at one thread count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelRates {
    pub trsm_gflops: f64,
    pub gemm_gflops: f64,
    /// gemm on the S-loop's skinny shape (a handful of rows against the
    /// full column panel) — the microkernel's rate here differs from the
    /// square-ish `gemm_gflops` by an integer factor, so the DES prices
    /// CPU compute with the rate of the kernel it actually runs.
    pub sloop_gflops: f64,
}

/// Everything the probe learned about this machine + dataset.
#[derive(Debug, Clone)]
pub struct ProbedRates {
    /// Asymptotic sequential disk read bandwidth (MB/s) — with the
    /// per-request latency already separated out when the two-window
    /// fit succeeded, else the effective rate of the large-window probe.
    pub disk_mbps: f64,
    /// Per-request disk latency (seconds; 0 when the fit was not
    /// possible). Fitted from probes at two window sizes:
    /// `t_req = lat + bytes_req / bw` is two unknowns, two equations.
    pub disk_lat_secs: f64,
    /// Bytes the disk probe actually streamed.
    pub disk_bytes: u64,
    /// Host memcpy bandwidth (GB/s) — the emulated PCIe link.
    pub pcie_gbps: f64,
    /// Kernel rates keyed by thread count (every feasible split).
    pub kernels: BTreeMap<usize, KernelRates>,
    /// False when the dataset was too small (or the clock too coarse)
    /// for the disk number to mean anything.
    pub reliable: bool,
}

impl ProbedRates {
    /// trsm rate at the largest probed thread count ≤ `threads`.
    pub fn trsm_at(&self, threads: usize) -> f64 {
        self.at(threads).map(|k| k.trsm_gflops).unwrap_or(0.0)
    }

    /// gemm rate at the largest probed thread count ≤ `threads`.
    pub fn gemm_at(&self, threads: usize) -> f64 {
        self.at(threads).map(|k| k.gemm_gflops).unwrap_or(0.0)
    }

    /// Skinny (S-loop-shaped) gemm rate at the largest probed thread
    /// count ≤ `threads`.
    pub fn sloop_at(&self, threads: usize) -> f64 {
        self.at(threads).map(|k| k.sloop_gflops).unwrap_or(0.0)
    }

    fn at(&self, threads: usize) -> Option<&KernelRates> {
        self.kernels
            .range(..=threads.max(1))
            .next_back()
            .or_else(|| self.kernels.iter().next())
            .map(|(_, k)| k)
    }

    /// A probe the planner must not trust: unreliable disk numbers or
    /// any non-positive (or non-finite) rate. Plans fall back to safe
    /// defaults.
    pub fn degenerate(&self) -> bool {
        fn bad(x: f64) -> bool {
            !x.is_finite() || x <= 0.0
        }
        !self.reliable
            || bad(self.disk_mbps)
            || bad(self.pcie_gbps)
            || self.kernels.is_empty()
            || self
                .kernels
                .values()
                .any(|k| bad(k.trsm_gflops) || bad(k.gemm_gflops) || bad(k.sloop_gflops))
    }
}

/// Probe configuration.
#[derive(Debug, Clone, Copy)]
pub struct ProbeOpts {
    /// Total compute threads to calibrate for (0 = all cores).
    pub threads: usize,
    /// Disk-probe read budget in bytes.
    pub max_disk_bytes: u64,
    /// Probe through an emulated storage throttle (plan for that device).
    pub read_throttle: Option<Throttle>,
    /// Smaller kernel/memcpy shapes — for tests and CI smoke.
    pub quick: bool,
}

impl Default for ProbeOpts {
    fn default() -> Self {
        ProbeOpts { threads: 0, max_disk_bytes: 64 << 20, read_throttle: None, quick: false }
    }
}

/// Run the full probe against a dataset directory.
pub fn probe_dataset(dir: &Path, opts: &ProbeOpts) -> Result<ProbedRates> {
    let paths = DatasetPaths::new(dir);
    let open = || -> Result<XrdFile> {
        Ok(XrdFile::open(&paths.xr())?.with_throttle(opts.read_throttle))
    };
    // Two window sizes over the same file: the large windows measure the
    // asymptotic stream rate, the small ones expose the per-request
    // latency the linear model hides.
    let budget = opts.max_disk_bytes.max(1);
    let big = probe_read_bandwidth_windowed(open()?, budget, 2, 4 << 20)?;
    let small = probe_read_bandwidth_windowed(open()?, (budget / 4).max(1), 2, 256 << 10)?;
    let total = if opts.threads == 0 { threads::available() } else { opts.threads };
    let kernels = probe_kernels(total, opts.quick)?;
    let pcie_gbps = probe_memcpy_gbps(if opts.quick { 4 << 20 } else { 32 << 20 });
    let (disk_lat_secs, mbps) = match fit_disk_latency(&small, &big) {
        Some((lat, bw_bps)) => (lat, bw_bps / 1e6),
        None => (0.0, big.mbps()),
    };
    // `secs` floor is about clock resolution, not measurement quality —
    // a page-cached read of the minimum probe size can finish in tens
    // of microseconds and still yield a usable (if flattering) rate.
    let reliable =
        big.bytes >= MIN_DISK_PROBE_BYTES && big.secs > 1e-5 && mbps.is_finite() && mbps > 0.0;
    Ok(ProbedRates {
        disk_mbps: mbps,
        disk_lat_secs,
        disk_bytes: big.bytes,
        pcie_gbps,
        kernels,
        reliable,
    })
}

/// Solve `t_req = lat + bytes_req / bw` from two probes at different
/// request sizes. `None` when the windows were not distinct enough (a
/// tiny file collapses both to one request) or the timings inverted
/// (page-cache noise) — callers then fall back to a latency-free model,
/// which is exactly the pre-fit behavior.
pub fn fit_disk_latency(small: &ReadProbe, big: &ReadProbe) -> Option<(f64, f64)> {
    if small.ops == 0 || big.ops == 0 {
        return None;
    }
    let bs = small.bytes as f64 / small.ops as f64;
    let ts = small.secs / small.ops as f64;
    let bb = big.bytes as f64 / big.ops as f64;
    let tb = big.secs / big.ops as f64;
    if bb < bs * 1.5 || tb <= ts {
        return None;
    }
    let bw = (bb - bs) / (tb - ts); // bytes/sec, latency-free
    if !bw.is_finite() || bw <= 0.0 {
        return None;
    }
    let lat = (ts - bs / bw).max(0.0);
    lat.is_finite().then_some((lat, bw))
}

/// Time the trsm/gemm kernels at 1, 2, 4, … and `total_threads` threads.
/// Each rate is the kernel's effective GFlop/s under that per-thread
/// budget — the exact quantity the DES profile wants.
pub fn probe_kernels(total_threads: usize, quick: bool) -> Result<BTreeMap<usize, KernelRates>> {
    let total = total_threads.max(1);
    let mut ladder = vec![1usize];
    while ladder.last().copied().unwrap_or(1) * 2 <= total {
        let next = ladder.last().copied().unwrap_or(1) * 2;
        ladder.push(next);
    }
    if !ladder.contains(&total) {
        ladder.push(total);
    }
    let (nn, rhs) = if quick { (192, 96) } else { (512, 256) };
    let mut rng = XorShift::new(0xCA11B8);
    let spd = Matrix::rand_spd(nn, 4.0, &mut rng);
    let l = potrf(&spd)?;
    let a = Matrix::randn(nn, nn, &mut rng);
    let b = Matrix::randn(nn, rhs, &mut rng);
    let b0 = Matrix::randn(nn, rhs, &mut rng);
    // The S-loop's gemm shape: a short strip of output rows against the
    // same k-depth — few enough rows that only partial microkernel tiles
    // run, which is why its rate is probed separately.
    let a_s = Matrix::randn(16, nn, &mut rng);
    let reps = if quick { 1 } else { 2 };
    let mut out = BTreeMap::new();
    for &t in &ladder {
        let _g = threads::with_budget(t);
        let gemm_flops = 2.0 * (nn * nn * rhs) as f64;
        let mut c = Matrix::zeros(nn, rhs);
        gemm(1.0, &a, &b, 0.0, &mut c)?; // warm
        let t0 = Instant::now();
        for _ in 0..reps {
            gemm(1.0, &a, &b, 0.0, &mut c)?;
        }
        let gemm_gflops = gflops(gemm_flops, reps, t0.elapsed().as_secs_f64());

        let trsm_flops = (nn * nn * rhs) as f64;
        let mut x = b0.clone();
        trsm_lower_left(&l, &mut x)?; // warm
        let t0 = Instant::now();
        for _ in 0..reps {
            x = b0.clone();
            trsm_lower_left(&l, &mut x)?;
        }
        let trsm_gflops = gflops(trsm_flops, reps, t0.elapsed().as_secs_f64());

        let sloop_flops = 2.0 * (16 * nn * rhs) as f64;
        let mut c_s = Matrix::zeros(16, rhs);
        gemm(1.0, &a_s, &b, 0.0, &mut c_s)?; // warm
        let t0 = Instant::now();
        for _ in 0..reps {
            gemm(1.0, &a_s, &b, 0.0, &mut c_s)?;
        }
        let sloop_gflops = gflops(sloop_flops, reps, t0.elapsed().as_secs_f64());
        out.insert(t, KernelRates { trsm_gflops, gemm_gflops, sloop_gflops });
    }
    Ok(out)
}

/// Host copy bandwidth in GB/s over a `bytes`-sized buffer.
pub fn probe_memcpy_gbps(bytes: usize) -> f64 {
    let elems = (bytes / 8).max(1);
    let src = vec![1.0f64; elems];
    let mut dst = vec![0.0f64; elems];
    dst.copy_from_slice(&src); // warm / fault pages
    let reps = 3u32;
    let t0 = Instant::now();
    for _ in 0..reps {
        dst.copy_from_slice(&src);
    }
    std::hint::black_box(&dst);
    let secs = t0.elapsed().as_secs_f64();
    if secs > 0.0 {
        (elems * 8) as f64 * reps as f64 / secs / 1e9
    } else {
        0.0
    }
}

fn gflops(flops: f64, reps: u32, total_secs: f64) -> f64 {
    let per = total_secs / reps as f64;
    if per > 0.0 {
        flops / per / 1e9
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_probe_yields_positive_rates_per_thread_count() {
        let rates = probe_kernels(2, true).unwrap();
        assert!(rates.contains_key(&1));
        assert!(rates.contains_key(&2));
        for k in rates.values() {
            assert!(
                k.trsm_gflops > 0.0 && k.gemm_gflops > 0.0 && k.sloop_gflops > 0.0,
                "{k:?}"
            );
        }
    }

    #[test]
    fn rate_lookup_floors_to_probed_counts() {
        let mut kernels = BTreeMap::new();
        kernels.insert(1, KernelRates { trsm_gflops: 1.0, gemm_gflops: 1.5, sloop_gflops: 1.2 });
        kernels.insert(4, KernelRates { trsm_gflops: 3.0, gemm_gflops: 4.0, sloop_gflops: 3.5 });
        let r = ProbedRates {
            disk_mbps: 100.0,
            disk_lat_secs: 0.0,
            disk_bytes: 2 << 20,
            pcie_gbps: 8.0,
            kernels,
            reliable: true,
        };
        assert_eq!(r.trsm_at(1), 1.0);
        assert_eq!(r.trsm_at(3), 1.0, "floors to the largest probed count ≤ 3");
        assert_eq!(r.trsm_at(4), 3.0);
        assert_eq!(r.gemm_at(100), 4.0);
        assert_eq!(r.sloop_at(2), 1.2);
        assert_eq!(r.sloop_at(4), 3.5);
        assert_eq!(r.trsm_at(0), 1.0, "clamps up to the smallest probed count");
        assert!(!r.degenerate());
    }

    #[test]
    fn degenerate_probes_are_flagged() {
        let mut kernels = BTreeMap::new();
        kernels.insert(1, KernelRates { trsm_gflops: 1.0, gemm_gflops: 1.0, sloop_gflops: 1.0 });
        let good = ProbedRates {
            disk_mbps: 50.0,
            disk_lat_secs: 0.0,
            disk_bytes: 2 << 20,
            pcie_gbps: 8.0,
            kernels: kernels.clone(),
            reliable: true,
        };
        assert!(!good.degenerate());
        assert!(ProbedRates { disk_mbps: 0.0, ..good.clone() }.degenerate());
        assert!(ProbedRates { reliable: false, ..good.clone() }.degenerate());
        assert!(ProbedRates { kernels: BTreeMap::new(), ..good.clone() }.degenerate());
        assert!(ProbedRates { disk_mbps: f64::NAN, ..good }.degenerate());
    }

    #[test]
    fn memcpy_probe_is_positive() {
        assert!(probe_memcpy_gbps(1 << 20) > 0.0);
    }

    #[test]
    fn latency_fit_recovers_synthetic_device_parameters() {
        // A device with 5 ms latency + 100 MB/s: windows of 256 KiB and
        // 4 MiB must reproduce both constants exactly.
        let (lat, bw) = (5e-3, 100e6);
        let mk = |window: f64, ops: u64| ReadProbe {
            bytes: (window * ops as f64) as u64,
            secs: ops as f64 * (lat + window / bw),
            ops,
        };
        let small = mk(256.0 * 1024.0, 16);
        let big = mk(4.0 * 1024.0 * 1024.0, 4);
        let (flat, fbw) = fit_disk_latency(&small, &big).unwrap();
        assert!((flat - lat).abs() < 1e-9, "lat={flat}");
        assert!((fbw - bw).abs() / bw < 1e-9, "bw={fbw}");
        // Degenerate inputs refuse to fit instead of producing garbage.
        assert!(fit_disk_latency(&small, &small).is_none(), "same window");
        assert!(fit_disk_latency(&big, &small).is_none(), "inverted timings");
        assert!(fit_disk_latency(&ReadProbe { bytes: 0, secs: 0.0, ops: 0 }, &big).is_none());
    }
}
