//! Flag parsing for the `cugwas` binary.
//!
//! Grammar: `cugwas <subcommand> [--key value | --key=value | --switch]…`.
//! Flags are declared per subcommand in `main.rs`; unknown flags are
//! errors (no silent typos on a tool that runs for hours).

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Declared flag (for usage text + validation).
#[derive(Debug, Clone)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    /// `true` if the flag takes no value.
    pub switch: bool,
    pub default: Option<&'static str>,
}

impl Flag {
    pub const fn opt(name: &'static str, default: &'static str, help: &'static str) -> Flag {
        Flag { name, help, switch: false, default: Some(default) }
    }
    pub const fn req(name: &'static str, help: &'static str) -> Flag {
        Flag { name, help, switch: false, default: None }
    }
    pub const fn switch(name: &'static str, help: &'static str) -> Flag {
        Flag { name, help, switch: true, default: None }
    }
}

/// Parsed arguments for one subcommand.
#[derive(Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Flags the user typed (as opposed to filled-in defaults) — lets a
    /// tuned profile supply defaults while explicit flags still win.
    explicit: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parse `argv` (everything after the subcommand) against `flags`.
    pub fn parse(argv: &[String], flags: &[Flag]) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut switches = Vec::new();
        let mut explicit = std::collections::BTreeSet::new();
        let find = |name: &str| flags.iter().find(|f| f.name == name);
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            let stripped = arg
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("unexpected argument '{arg}'")))?;
            let (name, inline_value) = match stripped.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (stripped, None),
            };
            let flag = find(name)
                .ok_or_else(|| Error::Config(format!("unknown flag --{name}")))?;
            explicit.insert(name.to_string());
            if flag.switch {
                if inline_value.is_some() {
                    return Err(Error::Config(format!("--{name} takes no value")));
                }
                switches.push(name.to_string());
            } else {
                let value = match inline_value {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| Error::Config(format!("--{name} needs a value")))?
                    }
                };
                if values.insert(name.to_string(), value).is_some() {
                    return Err(Error::Config(format!("--{name} given twice")));
                }
            }
            i += 1;
        }
        // Fill defaults.
        for f in flags {
            if !f.switch && !values.contains_key(f.name) {
                match f.default {
                    Some(d) => {
                        values.insert(f.name.to_string(), d.to_string());
                    }
                    None => return Err(Error::Config(format!("missing required flag --{}", f.name))),
                }
            }
        }
        Ok(Args { values, switches, explicit })
    }

    pub fn str(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    /// Whether the user typed `--name` themselves (a filled-in default
    /// returns false).
    pub fn given(&self, name: &str) -> bool {
        self.explicit.contains(name)
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        self.str(name)
            .replace('_', "")
            .parse()
            .map_err(|_| Error::Config(format!("--{name}: expected integer, got '{}'", self.str(name))))
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        self.str(name)
            .replace('_', "")
            .parse()
            .map_err(|_| Error::Config(format!("--{name}: expected integer, got '{}'", self.str(name))))
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        self.str(name)
            .parse()
            .map_err(|_| Error::Config(format!("--{name}: expected number, got '{}'", self.str(name))))
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, flags: &[Flag]) -> String {
    let mut out = format!("cugwas {cmd} — {about}\n\nflags:\n");
    for f in flags {
        let default = match (f.switch, f.default) {
            (true, _) => String::new(),
            (false, Some(d)) => format!(" [default: {d}]"),
            (false, None) => " (required)".to_string(),
        };
        out.push_str(&format!("  --{:<16} {}{}\n", f.name, f.help, default));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const FLAGS: &[Flag] = &[
        Flag::opt("block", "256", "block size"),
        Flag::req("dataset", "dataset dir"),
        Flag::switch("verbose", "chatty"),
    ];

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_defaults_switches() {
        let a = Args::parse(&argv(&["--dataset", "/d", "--verbose"]), FLAGS).unwrap();
        assert_eq!(a.str("dataset"), "/d");
        assert_eq!(a.usize("block").unwrap(), 256);
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
        // Explicit flags are distinguishable from filled-in defaults.
        assert!(a.given("dataset") && a.given("verbose"));
        assert!(!a.given("block"));
    }

    #[test]
    fn equals_form_and_underscores() {
        let a = Args::parse(&argv(&["--dataset=/d", "--block=5_000"]), FLAGS).unwrap();
        assert_eq!(a.usize("block").unwrap(), 5000);
    }

    #[test]
    fn missing_required_is_error() {
        assert!(Args::parse(&argv(&["--block", "5"]), FLAGS).is_err());
    }

    #[test]
    fn unknown_and_malformed_flags_rejected() {
        assert!(Args::parse(&argv(&["--dataset", "/d", "--bogus", "1"]), FLAGS).is_err());
        assert!(Args::parse(&argv(&["positional"]), FLAGS).is_err());
        assert!(Args::parse(&argv(&["--dataset"]), FLAGS).is_err());
        assert!(Args::parse(&argv(&["--dataset", "/a", "--dataset", "/b"]), FLAGS).is_err());
        assert!(Args::parse(&argv(&["--dataset=/d", "--verbose=1"]), FLAGS).is_err());
    }

    #[test]
    fn bad_numbers_rejected() {
        let a = Args::parse(&argv(&["--dataset", "/d", "--block", "abc"]), FLAGS).unwrap();
        assert!(a.usize("block").is_err());
    }

    #[test]
    fn usage_lists_flags() {
        let u = usage("run", "stream a study", FLAGS);
        assert!(u.contains("--block"));
        assert!(u.contains("[default: 256]"));
        assert!(u.contains("(required)"));
    }
}
