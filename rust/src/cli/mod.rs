//! Hand-rolled CLI argument parsing (clap is unavailable offline — see
//! DESIGN.md §4). Subcommands + `--flag value` / `--flag=value` options,
//! with typed accessors and generated usage text.

pub mod args;

pub use args::{usage, Args, Flag};
