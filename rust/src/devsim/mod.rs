//! Discrete-event simulation of the streaming pipeline at paper scale.
//!
//! The paper's results were measured on hardware this repo does not have
//! (Fermi GPUs, a cluster filesystem feeding them). Per the substitution
//! rule in DESIGN.md §4, this module reproduces the *shape* of Fig. 3,
//! Fig. 6a and Fig. 6b by simulating the exact task graphs of the three
//! algorithms (naive offload, OOC-HP-GWAS, cuGWAS) over a hardware profile
//! with the paper's published constants. The real code path (PJRT + disk
//! + threads) is validated separately at laptop scale; the simulator's
//! task graphs follow the same scheduling rules the live coordinator
//! uses, so the two cannot drift apart silently.

pub mod des;
pub mod pipeline_model;
pub mod profile;
pub mod transition;

pub use des::{Des, TaskId, Timeline};
pub use pipeline_model::{simulate, simulate_cugwas_with, Algo, SimConfig, SimReport};
pub use profile::{sloop_flops, trsm_flops, HardwareProfile};
pub use transition::{transition_secs, SegmentKnobs};
