//! Pricing of a mid-stream knob switch — the cost side of the in-flight
//! re-planner's ledger.
//!
//! The steady-state DES ([`super::pipeline_model`]) prices what the
//! remaining work costs *under* a configuration; it cannot see what it
//! costs to *get there* from the configuration currently streaming. A
//! switch is not free: buffer rings are reallocated and faulted, and a
//! change to the per-lane threading or queue depth tears down and
//! respawns the device lanes (their thread budget and channel depth are
//! fixed at spawn). The re-planner adds [`transition_secs`] to every
//! candidate's DES prediction, so a switch is only taken when the
//! remaining work amortizes its own migration.

use super::profile::HardwareProfile;

/// The knobs a pipeline segment streams under — the full depth the
/// offline planner searches, now also switchable in flight. The lane
/// count (`ngpus`) is deliberately absent: lanes are the pipeline's
/// structural concurrency and stay fixed for a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentKnobs {
    /// SNP columns per pipeline iteration (across all lanes).
    pub block: usize,
    /// Host ring size (read + result rings).
    pub host_buffers: usize,
    /// Device buffers per lane (the lane channel depth).
    pub device_buffers: usize,
    /// Kernel threads per device lane (the lane-vs-S-loop split).
    pub lane_threads: usize,
}

/// Thread + statics setup cost of respawning one device lane.
const LANE_SPAWN_SECS: f64 = 1e-3;

/// Seconds a live pipeline pays to move from `cur` to `cand` at a
/// segment boundary, beyond what both configurations pay anyway (the
/// boundary's write flush + journal sync). `n`/`p` are the study's
/// sample count and result rows, `ngpus` the lane count.
pub fn transition_secs(
    cur: &SegmentKnobs,
    cand: &SegmentKnobs,
    n: usize,
    p: usize,
    ngpus: usize,
    profile: &HardwareProfile,
) -> f64 {
    if cur == cand {
        return 0.0;
    }
    let g = ngpus.max(1);
    let memcpy_bps = (profile.pcie_gbps * 1e9).max(1.0);
    let mut secs = 0.0;
    // Ring geometry changed → the slab ring and the result ring are
    // reallocated, zeroed, and page-faulted. (The per-lane staging
    // chunks the pre-slab plane also rebuilt here no longer exist —
    // lanes borrow views into the slabs — so a device-buffer-only
    // switch is pool-free and priced purely as a lane respawn below.)
    if (cand.block, cand.host_buffers) != (cur.block, cur.host_buffers) {
        let ring = cand.host_buffers * cand.block * (n + p);
        secs += (8 * ring) as f64 / memcpy_bps;
    }
    // Lane thread budget or channel depth changed → every lane is torn
    // down and respawned. Since the zero-copy refactor the statics are
    // one shared `Arc<Preprocessed>` — a respawn clones a pointer, not
    // ≈ 3 n² f64 of preprocess products — so the cost is the thread
    // spawn itself. (PJRT lanes would additionally rebuild their
    // row-major literals, but the in-flight replanner that prices this
    // is native-only: `--adapt` is refused with the PJRT backend.)
    if cand.lane_threads != cur.lane_threads || cand.device_buffers != cur.device_buffers {
        secs += g as f64 * LANE_SPAWN_SECS;
    }
    secs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knobs(block: usize, hb: usize, db: usize, lt: usize) -> SegmentKnobs {
        SegmentKnobs { block, host_buffers: hb, device_buffers: db, lane_threads: lt }
    }

    #[test]
    fn staying_put_is_free_and_any_switch_is_not() {
        let p = HardwareProfile::quadro();
        let a = knobs(1024, 3, 2, 2);
        assert_eq!(transition_secs(&a, &a, 512, 4, 1, &p), 0.0);
        let moves = [
            knobs(2048, 3, 2, 2),
            knobs(1024, 4, 2, 2),
            knobs(1024, 3, 3, 2),
            knobs(1024, 3, 2, 4),
        ];
        for b in moves {
            assert!(transition_secs(&a, &b, 512, 4, 1, &p) > 0.0, "{b:?}");
        }
    }

    #[test]
    fn lane_respawn_costs_more_than_a_pool_resize() {
        // Same ring geometry, threading changed vs a small block change:
        // the lane teardown (one fixed spawn cost per lane) must
        // dominate at modest n.
        let p = HardwareProfile::quadro();
        let a = knobs(256, 3, 2, 2);
        let threads = transition_secs(&a, &knobs(256, 3, 2, 4), 512, 4, 2, &p);
        let pools = transition_secs(&a, &knobs(512, 3, 2, 2), 512, 4, 2, &p);
        assert!(threads > pools, "{threads} vs {pools}");
    }

    #[test]
    fn bigger_candidates_cost_more_to_build() {
        let p = HardwareProfile::quadro();
        let a = knobs(1024, 3, 2, 2);
        let small = transition_secs(&a, &knobs(2048, 3, 2, 2), 512, 4, 1, &p);
        let big = transition_secs(&a, &knobs(8192, 6, 2, 2), 512, 4, 1, &p);
        assert!(big > small);
    }
}
