//! Hardware profiles: the paper's published machine constants, plus
//! op-cost functions mapping GWAS operations to seconds.

/// Machine model for the simulator. Rates are *effective* (already
/// derated to achievable efficiency, as the paper reports them).
#[derive(Debug, Clone, Copy)]
pub struct HardwareProfile {
    pub name: &'static str,
    /// Effective GPU trsm rate, per GPU (GFlop/s). Paper: cuBLAS dtrsm
    /// reaches ~60 % of Fermi's 515 GF/s peak ⇒ 309.
    pub gpu_trsm_gflops: f64,
    /// Effective CPU BLAS-3 rate (GFlop/s), whole socket set. Paper:
    /// OOC-HP-GWAS attains >90 % of peak.
    pub cpu_gflops: f64,
    /// Host↔device link bandwidth (GB/s). PCIe 2.0 x16 ≈ 6 effective.
    pub pcie_gbps: f64,
    /// Storage streaming bandwidth (MB/s). The Quadro cluster reads from
    /// a parallel filesystem the paper reports as "an order of magnitude
    /// faster than the trsm"; the `hdd()` profile models a literal
    /// spinning disk instead.
    pub disk_mbps: f64,
    /// Per-request storage latency (seconds): seek + dispatch overhead
    /// paid once per read/write regardless of its size. This is what
    /// makes small-block reads slower than the linear `bytes / bw` model
    /// predicts — and therefore what lets the DES *drive* the
    /// grow-on-read-starved rule instead of only veto-guarding it.
    pub disk_lat_secs: f64,
    /// Effective rate of a naive per-SNP BLAS-2 code (GFlop/s), used for
    /// the ProbABEL-like baseline. Order 0.1 = unblocked C++ loops.
    pub probabel_gflops: f64,
}

impl HardwareProfile {
    /// RWTH *Quadro* cluster (§4.1): 2× Quadro 6000 (515 GF each, 6 GB),
    /// 2× Xeon X5650 (128 GF combined), 24 GB RAM.
    pub fn quadro() -> Self {
        HardwareProfile {
            name: "quadro",
            gpu_trsm_gflops: 309.0,
            cpu_gflops: 128.0 * 0.9,
            pcie_gbps: 6.0,
            disk_mbps: 2000.0,
            disk_lat_secs: 1e-4,
            probabel_gflops: 0.12,
        }
    }

    /// UJI *Tesla* cluster (§4.2): Tesla S2050, 4 Fermi chips (2.06 TF
    /// total), Xeon E5440 ≈ 90 GF host.
    pub fn tesla() -> Self {
        HardwareProfile {
            name: "tesla",
            gpu_trsm_gflops: 309.0,
            cpu_gflops: 90.0 * 0.9,
            pcie_gbps: 6.0,
            disk_mbps: 2000.0,
            disk_lat_secs: 1e-4,
            probabel_gflops: 0.12,
        }
    }

    /// A literal single spinning disk (the title's HDD), for the ablation
    /// that shows where the I/O-bound crossover sits.
    pub fn hdd() -> Self {
        // ~8 ms average seek/rotational latency per request: the number
        // that makes tiny blocks on a spinning disk pay for themselves.
        HardwareProfile { name: "hdd", disk_mbps: 120.0, disk_lat_secs: 8e-3, ..Self::quadro() }
    }

    // ---- op costs (seconds) -------------------------------------------

    /// trsm of `L (n×n)` against `mb` RHS columns: `n² · mb` flops.
    pub fn t_trsm_gpu(&self, n: usize, mb: usize) -> f64 {
        trsm_flops(n, mb) / (self.gpu_trsm_gflops * 1e9)
    }

    /// Same trsm on the CPU (the OOC-HP-GWAS baseline).
    pub fn t_trsm_cpu(&self, n: usize, mb: usize) -> f64 {
        trsm_flops(n, mb) / (self.cpu_gflops * 1e9)
    }

    /// S-loop over a block: gemm `(pl×n)(n×mb)` + per-column syrk/gemv +
    /// m tiny posv solves, batched over `traits` right-hand sides.
    pub fn t_sloop_cpu(&self, n: usize, pl: usize, mb: usize, traits: usize) -> f64 {
        sloop_flops(n, pl, mb, traits) / (self.cpu_gflops * 1e9)
    }

    /// Host↔device transfer of a block (n×mb f64).
    pub fn t_pcie(&self, n: usize, mb: usize) -> f64 {
        (n as f64) * (mb as f64) * 8.0 / (self.pcie_gbps * 1e9)
    }

    /// Disk read/write of `bytes` as ONE request: per-request latency
    /// plus the linear transfer term. Fewer, larger requests amortize
    /// the latency — the model-side reason to grow the block size when
    /// the pipeline observes itself read-starved.
    pub fn t_disk(&self, bytes: u64) -> f64 {
        self.disk_lat_secs + bytes as f64 / (self.disk_mbps * 1e6)
    }

    /// ProbABEL-like per-SNP work: two `n²` gemv-class ops per SNP plus
    /// per-SNP `p³` solves, at unblocked BLAS-2 rate.
    pub fn t_probabel(&self, n: usize, pl: usize, m: usize) -> f64 {
        let p = (pl + 1) as f64;
        let per_snp = 3.0 * (n as f64) * (n as f64) + 2.0 * p * p * (n as f64);
        (m as f64) * per_snp / (self.probabel_gflops * 1e9)
    }
}

// ---- flop counts (shared by the model and the live rate observer) ------

/// Flops of a trsm of `L (n×n)` against `mb` RHS columns. The autotuner's
/// live observer divides measured device seconds by this same count, so
/// model and measurement can never disagree on the flop convention.
pub fn trsm_flops(n: usize, mb: usize) -> f64 {
    (n as f64) * (n as f64) * (mb as f64)
}

/// Flops of the CPU S-loop over an `mb`-column block (gemm + per-column
/// syrk/gemv + `mb` tiny posv solves), batched over `traits` right-hand
/// sides. At `traits = 1` this is exactly the single-phenotype count;
/// each extra trait reuses the per-SNP factorization and adds only one
/// `dot` (`2n`) and one pair of triangular solves (`~2p²`) per column —
/// the model-side statement of the amortization the batch buys.
pub fn sloop_flops(n: usize, pl: usize, mb: usize, traits: usize) -> f64 {
    let p = (pl + 1) as f64;
    let gemm = 2.0 * (pl as f64) * (n as f64) * (mb as f64);
    let vec_ops = 4.0 * (n as f64) * (mb as f64); // syrk col + gemv
    let posv = (mb as f64) * p * p * p / 3.0;
    let extra_traits =
        traits.saturating_sub(1) as f64 * (2.0 * (n as f64) + 2.0 * p * p) * (mb as f64);
    gemm + vec_ops + posv + extra_traits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_sanity() {
        // n = 10 000, block of 5 000 SNPs on the Quadro profile.
        let p = HardwareProfile::quadro();
        let t_gpu = p.t_trsm_gpu(10_000, 5_000);
        let t_cpu = p.t_trsm_cpu(10_000, 5_000);
        // GPU ≈ 2.6–2.7× the CPU rate (309 vs 115 GF) — the paper's core ratio.
        let ratio = t_cpu / t_gpu;
        assert!((2.2..3.2).contains(&ratio), "ratio={ratio}");
        // Disk read of the block is ~an order of magnitude faster than trsm
        // on the cluster FS profile (the paper's multi-GPU scaling premise).
        let t_read = p.t_disk(10_000 * 5_000 * 8);
        assert!(t_read * 5.0 < t_gpu, "read={t_read}, trsm={t_gpu}");
        // ...but NOT on a literal HDD.
        let hdd = HardwareProfile::hdd();
        assert!(hdd.t_disk(10_000 * 5_000 * 8) > t_gpu);
    }

    #[test]
    fn sloop_is_cheaper_than_trsm_at_scale() {
        // The pipeline premise: the delayed S-loop hides under the trsm.
        let p = HardwareProfile::quadro();
        assert!(p.t_sloop_cpu(10_000, 3, 5_000, 1) < p.t_trsm_gpu(10_000, 5_000));
    }

    #[test]
    fn trait_batch_cost_is_sublinear() {
        // 32 traits on one stream must cost far less than 32 streams:
        // the trsm-sized gemm and the factorization are paid once.
        let p = HardwareProfile::quadro();
        let one = p.t_sloop_cpu(10_000, 3, 5_000, 1);
        let batched = p.t_sloop_cpu(10_000, 3, 5_000, 32);
        assert!(batched > one, "extra traits cost something");
        assert!(batched < 32.0 * one * 0.5, "batched={batched}, one={one}");
    }

    #[test]
    fn probabel_reference_runtime_magnitude() {
        // Paper §1.4: ProbABEL took ~4 h for p=4, n=1500, m=220 833 (2010
        // hardware). Our model should land within the same decade.
        let p = HardwareProfile::quadro();
        let t = p.t_probabel(1_500, 3, 220_833);
        assert!((3_600.0..40_000.0).contains(&t), "t={t}");
    }

    #[test]
    fn disk_latency_penalizes_small_requests() {
        // Same bytes in 100 requests vs 1: the per-request term makes
        // the split strictly slower, and dominates on the HDD profile.
        let hdd = HardwareProfile::hdd();
        let total = 100 * (1 << 20);
        let one = hdd.t_disk(total);
        let hundred = 100.0 * hdd.t_disk(total / 100);
        assert!(hundred > one + 99.0 * hdd.disk_lat_secs * 0.999);
        // The cluster-FS profiles keep latency nearly negligible.
        assert!(HardwareProfile::quadro().disk_lat_secs < 1e-3);
    }

    #[test]
    fn costs_scale_linearly_in_mb() {
        let p = HardwareProfile::quadro();
        let a = p.t_trsm_gpu(1000, 100);
        let b = p.t_trsm_gpu(1000, 200);
        assert!((b / a - 2.0).abs() < 1e-12);
    }
}
