//! Task-graph generators for the three algorithms the paper times, plus
//! the simulation driver that turns them into runtime reports.
//!
//! The graphs encode exactly the scheduling rules of the live coordinator
//! (`coordinator/pipeline.rs`); the integration test
//! `tests/devsim_vs_coordinator.rs` keeps the two in lockstep.

use super::des::{Des, TaskId, Timeline};
use super::profile::HardwareProfile;
use crate::error::{Error, Result};
use crate::gwas::problem::Dims;

/// Which algorithm to simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// Serialized offload (Fig. 3): no overlap anywhere.
    NaiveGpu,
    /// CPU-only OOC-HP-GWAS (Listing 1.2): disk double-buffered.
    OocCpu,
    /// cuGWAS (Listing 1.3): full double–triple multibuffering.
    CuGwas,
    /// ProbABEL-like per-SNP BLAS-2 baseline.
    Probabel,
}

impl Algo {
    pub fn as_str(&self) -> &'static str {
        match self {
            Algo::NaiveGpu => "naive-gpu",
            Algo::OocCpu => "ooc-cpu",
            Algo::CuGwas => "cugwas",
            Algo::Probabel => "probabel",
        }
    }
}

/// Simulation parameters.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub dims: Dims,
    /// Total SNP columns per pipeline iteration (split across GPUs).
    pub block: usize,
    pub ngpus: usize,
    /// Host-side buffers (paper: 3; set 2 for the ablation that stalls).
    pub host_buffers: usize,
    /// Trait-batch width `t`: the S-loop solves `t` right-hand sides per
    /// SNP and writes `p·t` result rows per column. 1 = the paper's run.
    pub traits: usize,
    pub profile: HardwareProfile,
}

/// Simulation output summary.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub algo: Algo,
    pub total_secs: f64,
    pub snps_per_sec: f64,
    /// Utilizations over the makespan.
    pub gpu_util: f64,
    pub cpu_util: f64,
    pub pcie_util: f64,
    pub disk_util: f64,
    /// Busy seconds by phase label prefix (read/send/trsm/recv/sloop/write).
    pub phase_busy: Vec<(String, f64)>,
    pub timeline: Timeline,
}

/// Simulate `algo` under `cfg` (cuGWAS with the paper's 2 device buffers).
pub fn simulate(algo: Algo, cfg: &SimConfig) -> Result<SimReport> {
    validate(cfg)?;
    let des = match algo {
        Algo::NaiveGpu => build_naive(cfg),
        Algo::OocCpu => build_ooc_cpu(cfg),
        Algo::CuGwas => build_cugwas(cfg, 2),
        Algo::Probabel => build_probabel(cfg),
    };
    let tl = des.run()?;
    Ok(summarize(algo, cfg, tl))
}

/// Simulate cuGWAS with an explicit device-buffer count per lane (the
/// autotuner's search knob; `simulate` fixes it at the paper's 2).
pub fn simulate_cugwas_with(cfg: &SimConfig, dev_buffers: usize) -> Result<SimReport> {
    validate(cfg)?;
    if !(2..=8).contains(&dev_buffers) {
        return Err(Error::Config("dev_buffers must be in 2..=8".into()));
    }
    let tl = build_cugwas(cfg, dev_buffers).run()?;
    Ok(summarize(Algo::CuGwas, cfg, tl))
}

fn validate(cfg: &SimConfig) -> Result<()> {
    if cfg.block == 0 || cfg.block > cfg.dims.m {
        return Err(Error::Config(format!("block {} out of range", cfg.block)));
    }
    if cfg.ngpus == 0 {
        return Err(Error::Config("ngpus must be ≥ 1".into()));
    }
    if cfg.block % cfg.ngpus != 0 {
        return Err(Error::Config(format!(
            "block {} must divide evenly across {} GPUs",
            cfg.block, cfg.ngpus
        )));
    }
    if !(2..=8).contains(&cfg.host_buffers) {
        return Err(Error::Config("host_buffers must be in 2..=8".into()));
    }
    if cfg.traits == 0 {
        return Err(Error::Config("traits must be ≥ 1".into()));
    }
    Ok(())
}

fn nblocks(cfg: &SimConfig) -> usize {
    cfg.dims.m.div_ceil(cfg.block)
}

fn block_cols(cfg: &SimConfig, b: usize) -> usize {
    if (b + 1) * cfg.block <= cfg.dims.m {
        cfg.block
    } else {
        cfg.dims.m - b * cfg.block
    }
}

/// Result block bytes: (p·t)×mb f64 (what the S-loop writes back).
fn r_bytes(cfg: &SimConfig, mb: usize) -> u64 {
    (cfg.dims.p() * cfg.traits * mb * 8) as u64
}

fn xr_bytes(cfg: &SimConfig, mb: usize) -> u64 {
    (cfg.dims.n * mb * 8) as u64
}

/// cuGWAS (Listing 1.3). Buffer-reuse dependencies:
/// * host ring of `hb` buffers ⇒ `read[b]` waits on `write[b-hb]`;
/// * `db` device buffers per GPU ⇒ `send[b]` waits on `recv[b-db]`
///   (paper: db = 2, one computing while the next is staged).
///
/// Submission order mirrors the listing's iteration order because the
/// PCIe link is FIFO: at iteration b the link first drains the *results*
/// of block b-db (`recv[b-db]`) and then stages block b (`send[b]`) —
/// both while `trsm[b-1]` runs. Emitting recv[b-1] before send[b] instead
/// would inject a full trsm into the link's critical path and the GPU
/// could never saturate (the exact mistake the naive schedule makes).
fn build_cugwas(cfg: &SimConfig, db: usize) -> Des {
    let p = &cfg.profile;
    let n = cfg.dims.n;
    let g = cfg.ngpus;
    let hb = cfg.host_buffers;
    let mut des = Des::new();
    let nb = nblocks(cfg);
    let mut read: Vec<TaskId> = Vec::with_capacity(nb);
    let mut trsm: Vec<Vec<TaskId>> = Vec::with_capacity(nb);
    let mut recv: Vec<Vec<TaskId>> = Vec::with_capacity(nb);
    let mut write: Vec<TaskId> = Vec::with_capacity(nb);
    // Retire block b: recv results per GPU, S-loop, write-back.
    let retire = |des: &mut Des,
                  b: usize,
                  trsm: &[Vec<TaskId>],
                  recv: &mut Vec<Vec<TaskId>>,
                  write: &mut Vec<TaskId>| {
        let mb = block_cols(cfg, b);
        let mb_gpu = mb.div_ceil(g);
        let mut recvs = Vec::with_capacity(g);
        for gi in 0..g {
            recvs.push(des.task(
                format!("recv[{b}.{gi}]"),
                "pcie",
                p.t_pcie(n, mb_gpu),
                &[trsm[b][gi]],
            ));
        }
        let sl = des.task(
            format!("sloop[{b}]"),
            "cpu",
            p.t_sloop_cpu(n, cfg.dims.pl, mb, cfg.traits),
            &recvs,
        );
        recv.push(recvs);
        write.push(des.task(format!("write[{b}]"), "disk_w", p.t_disk(r_bytes(cfg, mb)), &[sl]));
    };
    for b in 0..nb {
        let mb = block_cols(cfg, b);
        let mb_gpu = mb.div_ceil(g);
        // Retire block b-db first (its recv precedes send[b] on the link,
        // frees the device buffer send[b] targets, and — when hb == db —
        // frees the very host buffer read[b] needs).
        if b >= db {
            retire(&mut des, b - db, &trsm, &mut recv, &mut write);
        }
        // read[b] — host buffer freed once block b-hb's results are on disk.
        let mut deps = Vec::new();
        if b >= hb {
            deps.push(write[b - hb]);
        }
        let rd = des.task(format!("read[{b}]"), "disk_r", p.t_disk(xr_bytes(cfg, mb)), &deps);
        read.push(rd);
        // Stage block b and dispatch its trsm on every GPU.
        let mut sends = Vec::with_capacity(g);
        for gi in 0..g {
            let mut sdeps = vec![rd];
            if b >= db {
                sdeps.push(recv[b - db][gi]); // device buffer ring
            }
            sends.push(des.task(format!("send[{b}.{gi}]"), "pcie", p.t_pcie(n, mb_gpu), &sdeps));
        }
        let mut trsms = Vec::with_capacity(g);
        for gi in 0..g {
            trsms.push(des.task(
                format!("trsm[{b}.{gi}]"),
                format!("gpu{gi}"),
                p.t_trsm_gpu(n, mb_gpu),
                &[sends[gi]],
            ));
        }
        trsm.push(trsms);
    }
    // Drain the last db blocks.
    for b in nb.saturating_sub(db)..nb {
        retire(&mut des, b, &trsm, &mut recv, &mut write);
    }
    des
}

/// Naive offload (Fig. 3): one global chain, zero overlap.
fn build_naive(cfg: &SimConfig) -> Des {
    let p = &cfg.profile;
    let n = cfg.dims.n;
    let g = cfg.ngpus;
    let mut des = Des::new();
    let mut prev: Option<TaskId> = None;
    for b in 0..nblocks(cfg) {
        let mb = block_cols(cfg, b);
        let mb_gpu = mb.div_ceil(g);
        let chain = |des: &mut Des, label: String, res: String, dur: f64, prev: Option<TaskId>| {
            let deps: Vec<TaskId> = prev.into_iter().collect();
            des.task(label, res, dur, &deps)
        };
        let mut t = chain(&mut des, format!("read[{b}]"), "disk_r".into(), p.t_disk(xr_bytes(cfg, mb)), prev);
        for gi in 0..g {
            t = chain(&mut des, format!("send[{b}.{gi}]"), "pcie".into(), p.t_pcie(n, mb_gpu), Some(t));
            t = chain(&mut des, format!("trsm[{b}.{gi}]"), format!("gpu{gi}"), p.t_trsm_gpu(n, mb_gpu), Some(t));
            t = chain(&mut des, format!("recv[{b}.{gi}]"), "pcie".into(), p.t_pcie(n, mb_gpu), Some(t));
        }
        t = chain(&mut des, format!("sloop[{b}]"), "cpu".into(), p.t_sloop_cpu(n, cfg.dims.pl, mb, cfg.traits), Some(t));
        t = chain(&mut des, format!("write[{b}]"), "disk_w".into(), p.t_disk(r_bytes(cfg, mb)), Some(t));
        prev = Some(t);
    }
    des
}

/// OOC-HP-GWAS (Listing 1.2): CPU compute, disk reads double-buffered.
fn build_ooc_cpu(cfg: &SimConfig) -> Des {
    let p = &cfg.profile;
    let n = cfg.dims.n;
    let mut des = Des::new();
    let nb = nblocks(cfg);
    let mut compute: Vec<TaskId> = Vec::with_capacity(nb);
    for b in 0..nb {
        let mb = block_cols(cfg, b);
        // Two host buffers: read[b] reuses the buffer of block b-2.
        let mut deps = Vec::new();
        if b >= 2 {
            deps.push(compute[b - 2]);
        }
        let rd = des.task(format!("read[{b}]"), "disk_r", p.t_disk(xr_bytes(cfg, mb)), &deps);
        let comp = des.task(
            format!("compute[{b}]"),
            "cpu",
            p.t_trsm_cpu(n, mb) + p.t_sloop_cpu(n, cfg.dims.pl, mb, cfg.traits),
            &[rd],
        );
        compute.push(comp);
        des.task(format!("write[{b}]"), "disk_w", p.t_disk(r_bytes(cfg, mb)), &[comp]);
    }
    des
}

/// ProbABEL-like per-SNP baseline: one long CPU task + streaming reads.
fn build_probabel(cfg: &SimConfig) -> Des {
    let p = &cfg.profile;
    let mut des = Des::new();
    let rd = des.task("read[all]", "disk_r", p.t_disk(cfg.dims.xr_bytes()), &[]);
    des.task(
        "persnp[all]",
        "cpu",
        p.t_probabel(cfg.dims.n, cfg.dims.pl, cfg.dims.m),
        &[rd],
    );
    des
}

fn summarize(algo: Algo, cfg: &SimConfig, tl: Timeline) -> SimReport {
    let phases = ["read", "send", "trsm", "recv", "sloop", "write", "compute", "persnp"];
    let mut phase_busy: Vec<(String, f64)> = Vec::new();
    for ph in phases {
        let total: f64 = tl
            .intervals
            .iter()
            .filter(|iv| iv.label.starts_with(ph))
            .map(|iv| iv.finish - iv.start)
            .sum();
        if total > 0.0 {
            phase_busy.push((ph.to_string(), total));
        }
    }
    let gpu_busy = tl.busy_with_prefix("gpu");
    let gpu_util = if tl.makespan > 0.0 {
        gpu_busy / (tl.makespan * cfg.ngpus as f64)
    } else {
        0.0
    };
    SimReport {
        algo,
        total_secs: tl.makespan,
        snps_per_sec: cfg.dims.m as f64 / tl.makespan.max(1e-12),
        gpu_util,
        cpu_util: tl.utilization("cpu"),
        pcie_util: tl.utilization("pcie"),
        disk_util: tl.utilization("disk_r"),
        phase_busy,
        timeline: tl,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(m: usize, block: usize, ngpus: usize) -> SimConfig {
        SimConfig {
            dims: Dims::new(10_000, 3, m).unwrap(),
            block,
            ngpus,
            host_buffers: 3,
            traits: 1,
            profile: HardwareProfile::quadro(),
        }
    }

    #[test]
    fn cugwas_beats_naive_and_ooc() {
        let c = cfg(100_000, 5_000, 1);
        let cu = simulate(Algo::CuGwas, &c).unwrap();
        let naive = simulate(Algo::NaiveGpu, &c).unwrap();
        let ooc = simulate(Algo::OocCpu, &c).unwrap();
        assert!(cu.total_secs < naive.total_secs);
        assert!(cu.total_secs < ooc.total_secs);
        // Paper headline: ~2.4–2.6× over the CPU-only implementation.
        let speedup = ooc.total_secs / cu.total_secs;
        assert!((2.0..3.2).contains(&speedup), "speedup={speedup}");
    }

    #[test]
    fn cugwas_gpu_stays_nearly_saturated() {
        // "Sustained peak performance": in steady state the GPU never waits.
        let c = cfg(200_000, 5_000, 1);
        let cu = simulate(Algo::CuGwas, &c).unwrap();
        assert!(cu.gpu_util > 0.9, "gpu_util={}", cu.gpu_util);
        // The naive offload leaves the GPU idle during transfers/CPU work —
        // mildly at cluster-FS speeds, dramatically on the title's HDD.
        let naive = simulate(Algo::NaiveGpu, &c).unwrap();
        assert!(naive.gpu_util < 0.9, "naive gpu_util={}", naive.gpu_util);
        let mut hc = c;
        hc.profile = HardwareProfile::hdd();
        let naive_hdd = simulate(Algo::NaiveGpu, &hc).unwrap();
        assert!(naive_hdd.gpu_util < 0.5, "naive hdd gpu_util={}", naive_hdd.gpu_util);
        let cu_hdd = simulate(Algo::CuGwas, &hc).unwrap();
        assert!(cu_hdd.total_secs < naive_hdd.total_secs * 0.7);
    }

    #[test]
    fn multi_gpu_scales_nearly_ideally() {
        // Paper Fig. 6b: doubling GPUs → ×1.9.
        let base = simulate(Algo::CuGwas, &cfg(100_000, 5_000, 1)).unwrap();
        let two = simulate(Algo::CuGwas, &cfg(100_000, 10_000, 2)).unwrap();
        let four = simulate(Algo::CuGwas, &cfg(100_000, 20_000, 4)).unwrap();
        let s2 = base.total_secs / two.total_secs;
        let s4 = base.total_secs / four.total_secs;
        assert!((1.7..=2.0).contains(&s2), "s2={s2}");
        assert!((3.0..=4.0).contains(&s4), "s4={s4}");
    }

    #[test]
    fn probabel_is_orders_of_magnitude_slower() {
        let c = cfg(100_000, 5_000, 4);
        let cu = simulate(Algo::CuGwas, &c).unwrap();
        let pa = simulate(Algo::Probabel, &c).unwrap();
        let speedup = pa.total_secs / cu.total_secs;
        assert!(speedup > 100.0, "speedup={speedup}");
    }

    #[test]
    fn two_host_buffers_stall_the_gpu() {
        // The §3.1 insight: a block's host buffer is occupied from its
        // disk read until its results are written back (~3 pipeline
        // periods). With only 2 host buffers the read of b can only start
        // once b-2 is fully retired, which pushes the read + send latency
        // onto the critical path whenever the read is not ≪ trsm. Profile:
        // disk tuned so a block read ≈ 0.99× the trsm time (a realistic
        // local-RAID rate for the 2012 testbed) — the regime the third
        // buffer exists for.
        let mut c = cfg(100_000, 5_000, 1);
        c.profile = HardwareProfile { disk_mbps: 253.0, ..HardwareProfile::quadro() };
        let three = simulate(Algo::CuGwas, &c).unwrap();
        let mut c2 = c;
        c2.host_buffers = 2;
        let two = simulate(Algo::CuGwas, &c2).unwrap();
        assert!(
            two.total_secs > three.total_secs * 1.05,
            "{} vs {}",
            two.total_secs,
            three.total_secs
        );
        // ...while on the fast cluster FS both configurations coincide —
        // quantifying exactly when the third buffer pays off.
        let fast3 = simulate(Algo::CuGwas, &cfg(100_000, 5_000, 1)).unwrap();
        let mut cf = cfg(100_000, 5_000, 1);
        cf.host_buffers = 2;
        let fast2 = simulate(Algo::CuGwas, &cf).unwrap();
        assert!(fast2.total_secs < fast3.total_secs * 1.05);
    }

    #[test]
    fn tail_block_is_handled() {
        let c = cfg(12_500, 5_000, 1); // 3 blocks: 5000, 5000, 2500
        let cu = simulate(Algo::CuGwas, &c).unwrap();
        assert!(cu.total_secs > 0.0);
        let reads: Vec<_> = cu
            .timeline
            .intervals
            .iter()
            .filter(|iv| iv.label.starts_with("read"))
            .collect();
        assert_eq!(reads.len(), 3);
        assert!(reads[2].finish - reads[2].start < reads[0].finish - reads[0].start);
    }

    #[test]
    fn config_validation() {
        let mut c = cfg(1000, 0, 1);
        assert!(simulate(Algo::CuGwas, &c).is_err());
        c.block = 100;
        c.ngpus = 0;
        assert!(simulate(Algo::CuGwas, &c).is_err());
        c.ngpus = 3;
        assert!(simulate(Algo::CuGwas, &c).is_err()); // 100 % 3 != 0
        c.ngpus = 2;
        c.host_buffers = 1;
        assert!(simulate(Algo::CuGwas, &c).is_err());
    }

    #[test]
    fn explicit_two_device_buffers_match_the_default_schedule() {
        let c = cfg(100_000, 5_000, 1);
        let a = simulate(Algo::CuGwas, &c).unwrap();
        let b = simulate_cugwas_with(&c, 2).unwrap();
        assert_eq!(a.total_secs, b.total_secs);
    }

    #[test]
    fn extra_device_buffers_never_hurt_and_bounds_enforced() {
        // On a profile where the link is the constraint, a third device
        // buffer can only relax dependencies — never add any.
        let mut c = cfg(100_000, 5_000, 1);
        c.profile = HardwareProfile { pcie_gbps: 1.0, ..HardwareProfile::quadro() };
        let two = simulate_cugwas_with(&c, 2).unwrap();
        let three = simulate_cugwas_with(&c, 3).unwrap();
        assert!(three.total_secs <= two.total_secs * (1.0 + 1e-9));
        assert!(simulate_cugwas_with(&c, 1).is_err());
        assert!(simulate_cugwas_with(&c, 9).is_err());
    }

    #[test]
    fn more_device_buffers_than_blocks_still_drains() {
        let c = cfg(9_000, 5_000, 1); // 2 blocks, db = 4
        let r = simulate_cugwas_with(&c, 4).unwrap();
        assert!(r.total_secs > 0.0);
        let writes =
            r.timeline.intervals.iter().filter(|iv| iv.label.starts_with("write")).count();
        assert_eq!(writes, 2);
    }

    #[test]
    fn linear_in_m() {
        // Fig. 6a: runtime is linear in m.
        let a = simulate(Algo::CuGwas, &cfg(50_000, 5_000, 1)).unwrap();
        let b = simulate(Algo::CuGwas, &cfg(100_000, 5_000, 1)).unwrap();
        let ratio = b.total_secs / a.total_secs;
        assert!((1.8..2.2).contains(&ratio), "ratio={ratio}");
    }
}
