//! A small deterministic discrete-event scheduler.
//!
//! Tasks have a duration, a set of dependencies, and occupy exactly one
//! exclusive resource. A task starts at
//! `max(max(dep.finish), resource.available)` and the resource serializes
//! tasks in submission order (FIFO per device — how a single HDD, a PCIe
//! link, a GPU stream, and a CPU thread all behave for this workload).
//! The result is a [`Timeline`]: per-task intervals plus per-resource busy
//! time, from which the pipeline reports derive total runtime, overlap
//! efficiency and idle fractions.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Handle to a scheduled task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub usize);

#[derive(Debug, Clone)]
struct Task {
    label: String,
    resource: String,
    duration: f64,
    deps: Vec<TaskId>,
}

/// One executed task interval.
#[derive(Debug, Clone)]
pub struct Interval {
    pub label: String,
    pub resource: String,
    pub start: f64,
    pub finish: f64,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub intervals: Vec<Interval>,
    /// Wall-clock end of the last task.
    pub makespan: f64,
    /// Busy seconds per resource.
    pub busy: HashMap<String, f64>,
}

impl Timeline {
    /// Fraction of the makespan a resource spent busy.
    pub fn utilization(&self, resource: &str) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.busy.get(resource).copied().unwrap_or(0.0) / self.makespan
    }

    /// Total busy time across resources matching a prefix (e.g. "gpu").
    pub fn busy_with_prefix(&self, prefix: &str) -> f64 {
        self.busy
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, v)| v)
            .sum()
    }

    /// Render an ASCII Gantt chart — one row per resource, `█` where the
    /// resource is busy. This is the terminal rendition of the paper's
    /// Fig. 3/4 profile bars; `width` is the chart width in characters.
    pub fn gantt(&self, width: usize) -> String {
        if self.makespan <= 0.0 || width == 0 {
            return String::new();
        }
        let mut resources: Vec<&str> =
            self.intervals.iter().map(|iv| iv.resource.as_str()).collect();
        resources.sort_unstable();
        resources.dedup();
        let name_w = resources.iter().map(|r| r.len()).max().unwrap_or(4).max(4);
        let scale = width as f64 / self.makespan;
        let mut out = String::new();
        out.push_str(&format!(
            "{:>name_w$} 0{}{:.2}s\n",
            "",
            " ".repeat(width.saturating_sub(8)),
            self.makespan
        ));
        for res in resources {
            let mut row = vec![' '; width];
            for iv in self.intervals.iter().filter(|iv| iv.resource == res) {
                let a = (iv.start * scale) as usize;
                let b = ((iv.finish * scale) as usize).min(width.saturating_sub(1));
                for c in row.iter_mut().take(b + 1).skip(a.min(width - 1)) {
                    *c = '█';
                }
            }
            out.push_str(&format!("{res:>name_w$} {}\n", row.into_iter().collect::<String>()));
        }
        out
    }
}

/// Discrete-event scheduler (build the task graph, then [`Des::run`]).
#[derive(Debug, Default)]
pub struct Des {
    tasks: Vec<Task>,
}

impl Des {
    pub fn new() -> Self {
        Des { tasks: Vec::new() }
    }

    /// Add a task; `deps` must already exist (ids are handed out in
    /// submission order, which makes cycles unrepresentable).
    pub fn task(&mut self, label: impl Into<String>, resource: impl Into<String>, duration: f64, deps: &[TaskId]) -> TaskId {
        let id = TaskId(self.tasks.len());
        for d in deps {
            assert!(d.0 < id.0, "dependency on a future task");
        }
        self.tasks.push(Task {
            label: label.into(),
            resource: resource.into(),
            duration: duration.max(0.0),
            deps: deps.to_vec(),
        });
        id
    }

    /// Execute the schedule.
    pub fn run(&self) -> Result<Timeline> {
        if self.tasks.is_empty() {
            return Err(Error::Pipeline("DES: empty task graph".into()));
        }
        let mut finish = vec![0.0f64; self.tasks.len()];
        let mut resource_free: HashMap<&str, f64> = HashMap::new();
        let mut busy: HashMap<String, f64> = HashMap::new();
        let mut intervals = Vec::with_capacity(self.tasks.len());
        let mut makespan = 0.0f64;
        // Submission order == topological order (enforced in `task`).
        for (i, t) in self.tasks.iter().enumerate() {
            let dep_ready = t.deps.iter().map(|d| finish[d.0]).fold(0.0, f64::max);
            let res_ready = *resource_free.get(t.resource.as_str()).unwrap_or(&0.0);
            let start = dep_ready.max(res_ready);
            let end = start + t.duration;
            finish[i] = end;
            resource_free.insert(t.resource.as_str(), end);
            *busy.entry(t.resource.clone()).or_insert(0.0) += t.duration;
            makespan = makespan.max(end);
            intervals.push(Interval {
                label: t.label.clone(),
                resource: t.resource.clone(),
                start,
                finish: end,
            });
        }
        Ok(Timeline { intervals, makespan, busy })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_chain_adds_up() {
        let mut des = Des::new();
        let a = des.task("a", "r", 1.0, &[]);
        let b = des.task("b", "r", 2.0, &[a]);
        let _c = des.task("c", "r", 3.0, &[b]);
        let tl = des.run().unwrap();
        assert_eq!(tl.makespan, 6.0);
        assert_eq!(tl.utilization("r"), 1.0);
    }

    #[test]
    fn independent_tasks_on_different_resources_overlap() {
        let mut des = Des::new();
        des.task("a", "r1", 5.0, &[]);
        des.task("b", "r2", 3.0, &[]);
        let tl = des.run().unwrap();
        assert_eq!(tl.makespan, 5.0);
        assert!((tl.utilization("r2") - 0.6).abs() < 1e-12);
    }

    #[test]
    fn same_resource_serializes_in_submission_order() {
        let mut des = Des::new();
        des.task("a", "disk", 2.0, &[]);
        des.task("b", "disk", 2.0, &[]);
        let tl = des.run().unwrap();
        assert_eq!(tl.intervals[1].start, 2.0);
        assert_eq!(tl.makespan, 4.0);
    }

    #[test]
    fn dependency_across_resources_delays_start() {
        let mut des = Des::new();
        let a = des.task("produce", "gpu", 4.0, &[]);
        let b = des.task("consume", "cpu", 1.0, &[a]);
        des.task("late", "cpu", 1.0, &[b]);
        let tl = des.run().unwrap();
        assert_eq!(tl.intervals[1].start, 4.0);
        assert_eq!(tl.makespan, 6.0);
    }

    #[test]
    fn pipeline_steady_state_is_bottleneck_bound() {
        // 10-stage pipeline, stage A (3 s) feeds stage B (1 s) on another
        // resource: makespan → 10·3 + 1 (fill).
        let mut des = Des::new();
        let mut prev_a: Option<TaskId> = None;
        for _ in 0..10 {
            let deps: Vec<TaskId> = prev_a.into_iter().collect();
            let a = des.task("a", "A", 3.0, &deps);
            des.task("b", "B", 1.0, &[a]);
            prev_a = Some(a);
        }
        let tl = des.run().unwrap();
        assert_eq!(tl.makespan, 31.0);
    }

    #[test]
    fn busy_with_prefix_sums_gpus() {
        let mut des = Des::new();
        des.task("a", "gpu0", 2.0, &[]);
        des.task("b", "gpu1", 3.0, &[]);
        des.task("c", "cpu", 1.0, &[]);
        let tl = des.run().unwrap();
        assert_eq!(tl.busy_with_prefix("gpu"), 5.0);
    }

    #[test]
    fn empty_graph_is_error() {
        assert!(Des::new().run().is_err());
    }

    #[test]
    fn gantt_renders_busy_and_idle() {
        let mut des = Des::new();
        let a = des.task("a", "gpu", 2.0, &[]);
        des.task("b", "cpu", 2.0, &[a]); // cpu idle first half, busy second
        let tl = des.run().unwrap();
        let g = tl.gantt(20);
        let cpu_row = g.lines().find(|l| l.trim_start().starts_with("cpu")).unwrap();
        let gpu_row = g.lines().find(|l| l.trim_start().starts_with("gpu")).unwrap();
        assert!(gpu_row.contains('█'));
        assert!(cpu_row.contains('█'));
        // cpu idle at the start: its bars begin with blanks (names are
        // right-aligned, so strip the "cpu " prefix after trimming).
        let bars = cpu_row.trim_start().strip_prefix("cpu ").unwrap();
        assert!(bars.starts_with(' '), "cpu bars: {bars:?}");
        // gpu busy from t=0: bars begin immediately.
        let gbars = gpu_row.trim_start().strip_prefix("gpu ").unwrap();
        assert!(gbars.starts_with('█'), "gpu bars: {gbars:?}");
    }

    #[test]
    fn gantt_degenerate_inputs() {
        let mut des = Des::new();
        des.task("a", "r", 1.0, &[]);
        let tl = des.run().unwrap();
        assert_eq!(tl.gantt(0), "");
    }

    #[test]
    #[should_panic(expected = "future task")]
    fn forward_dependency_panics() {
        let mut des = Des::new();
        des.task("a", "r", 1.0, &[TaskId(5)]);
    }
}
