//! Descriptive statistics + the synthetic GWAS catalog behind Fig. 1.

pub mod catalog;
pub mod quartiles;

pub use catalog::{summarize_by_year, synthesize_catalog, CatalogRow, YearSummary};
pub use quartiles::{median, quartiles, Quartiles};
