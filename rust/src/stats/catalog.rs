//! Synthetic GWAS catalog — the data pipeline behind Fig. 1.
//!
//! The paper derives Fig. 1 from the NHGRI "Catalog of Published GWAS"
//! (genome.gov/gwastudies): per published study, its year, SNP count and
//! sample size; the figure plots per-year medians with quartile bars.
//! That catalog snapshot is not redistributable here, so per DESIGN.md §4
//! we synthesize a catalog with the paper's reported growth shape —
//! study counts rising to ~2300/yr by 2011, SNP counts exploding after
//! 2009, sample sizes plateauing around 10 000 — and regenerate the
//! figure's data through the same medians/quartiles pipeline.

use crate::stats::quartiles::{quartiles, Quartiles};
use crate::util::XorShift;

/// One published study in the catalog.
#[derive(Debug, Clone, Copy)]
pub struct CatalogRow {
    pub year: u32,
    pub snp_count: f64,
    pub sample_size: f64,
}

/// Per-year aggregate — one point of each Fig. 1 panel.
#[derive(Debug, Clone, Copy)]
pub struct YearSummary {
    pub year: u32,
    pub studies: usize,
    pub snp_count: Quartiles,
    pub sample_size: Quartiles,
}

/// Log-normal sampler (catalog quantities span decades).
fn lognormal(rng: &mut XorShift, median: f64, sigma: f64) -> f64 {
    (median.ln() + sigma * rng.normal()).exp()
}

/// Synthesize the 2005–2012 catalog.
pub fn synthesize_catalog(seed: u64) -> Vec<CatalogRow> {
    let mut rng = XorShift::new(seed);
    // (year, #studies, median SNPs, median sample size) following the
    // trends reported in §1.2 and visible in Fig. 1.
    let shape: [(u32, usize, f64, f64); 8] = [
        (2005, 4, 80_000.0, 900.0),
        (2006, 12, 100_000.0, 1_200.0),
        (2007, 90, 300_000.0, 2_500.0),
        (2008, 160, 500_000.0, 5_000.0),
        (2009, 380, 550_000.0, 8_000.0),
        (2010, 680, 900_000.0, 10_000.0),
        (2011, 2_300, 1_200_000.0, 10_000.0),
        (2012, 1_800, 2_200_000.0, 11_000.0),
    ];
    let mut rows = Vec::new();
    for (year, count, snp_med, n_med) in shape {
        for _ in 0..count {
            rows.push(CatalogRow {
                year,
                snp_count: lognormal(&mut rng, snp_med, 0.8),
                sample_size: lognormal(&mut rng, n_med, 0.6),
            });
        }
    }
    rows
}

/// Aggregate a catalog into the per-year summaries Fig. 1 plots.
pub fn summarize_by_year(rows: &[CatalogRow]) -> Vec<YearSummary> {
    let mut years: Vec<u32> = rows.iter().map(|r| r.year).collect();
    years.sort_unstable();
    years.dedup();
    years
        .into_iter()
        .filter_map(|year| {
            let snps: Vec<f64> =
                rows.iter().filter(|r| r.year == year).map(|r| r.snp_count).collect();
            let sizes: Vec<f64> =
                rows.iter().filter(|r| r.year == year).map(|r| r.sample_size).collect();
            Some(YearSummary {
                year,
                studies: snps.len(),
                snp_count: quartiles(&snps)?,
                sample_size: quartiles(&sizes)?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_deterministic() {
        let a = synthesize_catalog(1);
        let b = synthesize_catalog(1);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[0].snp_count, b[0].snp_count);
    }

    #[test]
    fn fig1a_snp_growth_shape() {
        // The paper's observation: SNP counts grow tremendously after 2009.
        let rows = synthesize_catalog(7);
        let sum = summarize_by_year(&rows);
        let med = |y: u32| sum.iter().find(|s| s.year == y).unwrap().snp_count.median;
        assert!(med(2011) > 2.0 * med(2008), "{} vs {}", med(2011), med(2008));
        assert!(med(2012) > 3.0 * med(2008));
        assert!(med(2012) > med(2009));
    }

    #[test]
    fn fig1b_sample_size_plateaus() {
        // ...while sample sizes settle around 10 000 (§1.2).
        let rows = synthesize_catalog(7);
        let sum = summarize_by_year(&rows);
        let med = |y: u32| sum.iter().find(|s| s.year == y).unwrap().sample_size.median;
        let late_growth = med(2012) / med(2010);
        assert!((0.7..1.6).contains(&late_growth), "late growth {late_growth}");
        assert!(med(2010) > 3.0 * med(2005));
    }

    #[test]
    fn study_counts_rise_to_2011_peak() {
        let rows = synthesize_catalog(3);
        let sum = summarize_by_year(&rows);
        let n = |y: u32| sum.iter().find(|s| s.year == y).unwrap().studies;
        assert!(n(2011) > 2000);
        assert!(n(2005) < 10);
    }

    #[test]
    fn quartile_bars_are_ordered() {
        let rows = synthesize_catalog(9);
        for s in summarize_by_year(&rows) {
            assert!(s.snp_count.q1 <= s.snp_count.median);
            assert!(s.snp_count.median <= s.snp_count.q3);
            assert!(s.sample_size.q1 <= s.sample_size.q3);
        }
    }
}
