//! Medians and quartiles — the statistics Fig. 1 plots per publication
//! year (median with first/second-quartile error bars).

/// First quartile, median, third quartile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quartiles {
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
}

/// Linear-interpolation quantile (R-7, the spreadsheet default).
fn quantile(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let h = q * (sorted.len() - 1) as f64;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    let frac = h - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Median of a sample (not required sorted). `None` on empty input.
pub fn median(values: &[f64]) -> Option<f64> {
    let mut v: Vec<f64> = values.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return None;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(quantile(&v, 0.5))
}

/// Q1/median/Q3 of a sample. `None` on empty input.
pub fn quartiles(values: &[f64]) -> Option<Quartiles> {
    let mut v: Vec<f64> = values.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return None;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(Quartiles {
        q1: quantile(&v, 0.25),
        median: quantile(&v, 0.5),
        q3: quantile(&v, 0.75),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), Some(2.0));
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), Some(2.5));
        assert_eq!(median(&[5.0]), Some(5.0));
        assert_eq!(median(&[]), None);
    }

    #[test]
    fn quartiles_known_sample() {
        let q = quartiles(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(q.q1, 2.0);
        assert_eq!(q.median, 3.0);
        assert_eq!(q.q3, 4.0);
    }

    #[test]
    fn quartiles_interpolate() {
        let q = quartiles(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(q.q1, 1.75);
        assert_eq!(q.median, 2.5);
        assert_eq!(q.q3, 3.25);
    }

    #[test]
    fn non_finite_values_ignored() {
        assert_eq!(median(&[f64::NAN, 1.0, 3.0]), Some(2.0));
        assert_eq!(median(&[f64::NAN]), None);
    }

    #[test]
    fn order_invariant() {
        let a = quartiles(&[9.0, 1.0, 5.0, 3.0, 7.0]).unwrap();
        let b = quartiles(&[1.0, 3.0, 5.0, 7.0, 9.0]).unwrap();
        assert_eq!(a, b);
    }
}
