//! Column-major dense `f64` matrix.
//!
//! One SNP block on disk is exactly the byte image of one of these (n rows
//! = samples, columns = SNPs), so the storage layer reads straight into a
//! `Matrix` buffer with no transposition.

use crate::error::{Error, Result};
use crate::util::XorShift;
use std::fmt;

/// Dense column-major matrix. Row index varies fastest in memory.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zeros `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity `n × n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Wrap an existing column-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "from_vec: buffer has {} elements, expected {rows}x{cols}={}",
                data.len(),
                rows * cols
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from a row-major slice-of-rows literal (tests/readability).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = Matrix::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            for (j, &v) in row.iter().enumerate() {
                m.set(i, j, v);
            }
        }
        m
    }

    /// Random i.i.d. standard-normal entries (deterministic under `rng`).
    pub fn randn(rows: usize, cols: usize, rng: &mut XorShift) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Matrix { rows, cols, data }
    }

    /// A random symmetric positive-definite matrix: `A A^T / cols + diag`.
    /// Used for synthetic kinship matrices `M`.
    pub fn rand_spd(n: usize, diag_boost: f64, rng: &mut XorShift) -> Self {
        let a = Matrix::randn(n, n, rng);
        let mut m = Matrix::zeros(n, n);
        // m = a a^T / n  (small n only; fine for generation)
        for j in 0..n {
            for i in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a.get(i, k) * a.get(j, k);
                }
                m.set(i, j, s / n as f64);
            }
        }
        for i in 0..n {
            *m.get_mut(i, i) += diag_boost;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element access (column-major).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn get_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        *self.get_mut(i, j) = v;
    }

    /// Borrow column `j` as a contiguous slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Full backing buffer (column-major).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Copy columns `[j0, j1)` into a new matrix.
    pub fn slice_cols(&self, j0: usize, j1: usize) -> Matrix {
        assert!(j0 <= j1 && j1 <= self.cols);
        Matrix {
            rows: self.rows,
            cols: j1 - j0,
            data: self.data[j0 * self.rows..j1 * self.rows].to_vec(),
        }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for j in 0..self.cols {
            for i in 0..self.rows {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// Max-abs elementwise difference; `inf` on shape mismatch.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        if self.rows != other.rows || self.cols != other.cols {
            return f64::INFINITY;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Lower-triangular copy (zeroes strictly-upper part). Used to
    /// normalize `potrf` output for comparisons.
    pub fn tril(&self) -> Matrix {
        let mut m = self.clone();
        for j in 0..m.cols {
            for i in 0..j.min(m.rows) {
                m.set(i, j, 0.0);
            }
        }
        m
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let rmax = self.rows.min(8);
        let cmax = self.cols.min(8);
        for i in 0..rmax {
            write!(f, "  ")?;
            for j in 0..cmax {
                write!(f, "{:>10.4} ", self.get(i, j))?;
            }
            writeln!(f, "{}", if cmax < self.cols { "…" } else { "" })?;
        }
        if rmax < self.rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_eye() {
        let z = Matrix::zeros(3, 2);
        assert_eq!(z.rows(), 3);
        assert_eq!(z.cols(), 2);
        assert!(z.as_slice().iter().all(|&v| v == 0.0));
        let i = Matrix::eye(3);
        assert_eq!(i.get(1, 1), 1.0);
        assert_eq!(i.get(0, 1), 0.0);
    }

    #[test]
    fn column_major_layout() {
        // [[1,3],[2,4]] stored as [1,2,3,4]
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 0), 2.0);
        assert_eq!(m.get(0, 1), 3.0);
        assert_eq!(m.get(1, 1), 4.0);
        assert_eq!(m.col(1), &[3.0, 4.0]);
    }

    #[test]
    fn from_vec_shape_check() {
        assert!(Matrix::from_vec(2, 2, vec![0.0; 3]).is_err());
    }

    #[test]
    fn from_rows_matches_get() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.get(0, 1), 2.0);
        assert_eq!(m.get(1, 0), 3.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = XorShift::new(3);
        let m = Matrix::randn(5, 3, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(2, 4), m.get(4, 2));
    }

    #[test]
    fn slice_cols_takes_contiguous_block() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let s = m.slice_cols(1, 3);
        assert_eq!(s.cols(), 2);
        assert_eq!(s.get(0, 0), 2.0);
        assert_eq!(s.get(1, 1), 6.0);
    }

    #[test]
    fn spd_matrix_is_symmetric_with_big_diag() {
        let mut rng = XorShift::new(7);
        let m = Matrix::rand_spd(16, 4.0, &mut rng);
        for i in 0..16 {
            assert!(m.get(i, i) >= 4.0 - 1e-9);
            for j in 0..16 {
                assert!((m.get(i, j) - m.get(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn max_abs_diff_shape_mismatch_is_inf() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(2, 3);
        assert_eq!(a.max_abs_diff(&b), f64::INFINITY);
    }

    #[test]
    fn tril_zeroes_upper() {
        let m = Matrix::from_rows(&[&[1.0, 9.0], &[2.0, 3.0]]);
        let t = m.tril();
        assert_eq!(t.get(0, 1), 0.0);
        assert_eq!(t.get(1, 0), 2.0);
    }
}
