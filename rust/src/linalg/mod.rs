//! From-scratch dense linear algebra over `f64`, column-major.
//!
//! This is the substrate OOC-HP-GWAS (the paper's baseline, Listing 1.2)
//! and the native S-loop run on. It is a deliberately small BLAS/LAPACK
//! subset — exactly the calls the paper's listings name:
//!
//! | paper call | here |
//! |------------|------|
//! | `potrf`    | [`chol::potrf`] |
//! | `trsm`     | [`blas3::trsm_lower_left`] |
//! | `trsv`     | [`blas2::trsv_lower`] |
//! | `gemv`     | [`blas2::gemv_t`] / [`blas2::gemv_n`] |
//! | `gemm`     | [`blas3::gemm`] |
//! | `syrk`     | [`blas3::syrk_t`] |
//! | `dot`      | [`blas1::dot`] |
//! | `posv`     | [`chol::posv`] |
//!
//! Layout is column-major (BLAS convention, and the layout of blocks of
//! `X_R` on disk: one SNP = one contiguous column). The BLAS-3 kernels
//! all bottom out in the register-tiled `mul_add` microkernels of
//! [`micro`] (with a scalar reference path behind
//! `CUGWAS_NO_MICROKERNEL` that is bit-identical per element); see
//! `micro.rs` for the tile/packing notes and `EXPERIMENTS.md` §Perf
//! for measured rates.

pub mod blas1;
pub mod blas2;
pub mod blas3;
pub mod chol;
pub mod matrix;
pub mod micro;

pub use blas1::{axpy, dot, nrm2, sumsq};
pub use blas2::{gemv_n, gemv_t, trsv_lower};
pub use blas3::{gemm, syrk_t, syrk_t_pretransposed, trsm_lower_left};
pub use chol::{chol_solve_small, posv, posv_small_factor, potrf, potrf_invert_diag_blocks};
pub use matrix::Matrix;
pub use micro::PackBuf;
