//! BLAS-1: vector-vector kernels. The S-loop's `dot`s land here.

/// `x · y`. Unrolled 4-way with fused multiply-adds: the independent
/// partial sums re-associate the reduction explicitly (so LLVM can
/// vectorize without `-ffast-math`) and each partial advances through
/// one `mul_add` per element. [`crate::linalg::micro::dot_many`]
/// replicates this exact scheme per output, which is what makes the
/// batched and the one-at-a-time reductions bitwise interchangeable.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = k * 4;
        s0 = x[i].mul_add(y[i], s0);
        s1 = x[i + 1].mul_add(y[i + 1], s1);
        s2 = x[i + 2].mul_add(y[i + 2], s2);
        s3 = x[i + 3].mul_add(y[i + 3], s3);
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        s = x[i].mul_add(y[i], s);
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Sum of squares (`syrk` of a single column, the S-loop's `Sbr`).
#[inline]
pub fn sumsq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    sumsq(x).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn naive_dot(x: &[f64], y: &[f64]) -> f64 {
        x.iter().zip(y).map(|(a, b)| a * b).sum()
    }

    #[test]
    fn dot_matches_naive_all_lengths() {
        let mut rng = XorShift::new(1);
        for n in 0..40 {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let a = dot(&x, &y);
            let b = naive_dot(&x, &y);
            assert!((a - b).abs() < 1e-10 * (1.0 + b.abs()), "n={n}: {a} vs {b}");
        }
    }

    #[test]
    fn dot_empty_is_zero() {
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn nrm2_pythagorean() {
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn sumsq_matches_dot_self() {
        let v = [1.5, -2.0, 0.25];
        assert_eq!(sumsq(&v), dot(&v, &v));
    }
}
