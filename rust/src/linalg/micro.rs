//! Register-tiled microkernels behind the dense BLAS-3 drivers.
//!
//! Every hot kernel in this crate reduces to one primitive: a
//! rank-k update `C[i,j] += Σ_p A[i,p]·W[p,j]` over some window of a
//! column-major buffer (gemm panels, trsm trailing updates, the
//! Cholesky trailing square). This module implements that primitive
//! twice over one shared packed layout:
//!
//! * [`micro_sweep`] — the fast path: `MR×NR` register tiles walked
//!   down a full-`k` chain of `f64::mul_add` FMAs, operands packed
//!   into contiguous zero-padded strips so the inner loop is pure
//!   unit-stride loads + fused multiply-adds.
//! * [`reference_sweep`] — the scalar nest, selectable with
//!   `CUGWAS_NO_MICROKERNEL=1` (or [`set_forced`]) for parity testing.
//!
//! **Why the two paths are bit-identical.** The microkernel vectorizes
//! across *independent output elements* only: tile position `(r, cc)`
//! accumulates element `C[i0+r, j0+cc]` and nothing else, with `p`
//! ascending through the full `k` range in one register chain. Per
//! element, both paths therefore execute the exact same operation
//! sequence — load `C[i,j]`, then `acc = A[i,p].mul_add(W[p,j], acc)`
//! for `p = 0..k`, then store — so every output bit matches by
//! construction, at any shape, tail or thread count. Scale factors
//! (gemm's `alpha`, the `-1` of the trsm/Cholesky updates) are folded
//! into `W` **once at pack time**, so both paths see the identical
//! pre-scaled operand. Tails smaller than a tile are handled by the
//! pack's zero padding (dead lanes compute on zeros and are never
//! stored), which is the "exactly one code path per kernel" the
//! bit-identity contract wants.
//!
//! The same vectorize-across-outputs rule shapes the two batched
//! helpers the S-loop uses: [`dot_many`] fuses many dot products
//! against one shared vector while replicating `blas1::dot`'s exact
//! 4-way partial-sum scheme per output, and [`chol_solve_multi`]
//! marches a group of right-hand sides through forward/backward
//! substitution in lockstep, each RHS seeing the per-element operation
//! order of a solo [`super::chol::chol_solve_small`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Register-tile rows (unit-stride direction of column-major C).
pub const MR: usize = 8;
/// Register-tile columns.
pub const NR: usize = 4;

// 0 = auto (environment), 1 = force micro, 2 = force reference.
static FORCED: AtomicU8 = AtomicU8::new(0);
static ENV_DISABLED: OnceLock<bool> = OnceLock::new();

/// Override the path selection (tests and benches). `None` restores
/// the `CUGWAS_NO_MICROKERNEL` environment decision. Process-global:
/// callers that flip it must not race concurrent kernel users.
pub fn set_forced(v: Option<bool>) {
    let code = match v {
        None => 0,
        Some(true) => 1,
        Some(false) => 2,
    };
    FORCED.store(code, Ordering::SeqCst);
}

/// Whether the register-tiled path is live. `CUGWAS_NO_MICROKERNEL=1`
/// (or `true`) selects the scalar reference nest; anything else — the
/// default — selects the microkernel. One relaxed load on the hot
/// path once the environment has been read.
pub fn enabled() -> bool {
    match FORCED.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => !*ENV_DISABLED.get_or_init(|| {
            std::env::var("CUGWAS_NO_MICROKERNEL")
                .map(|v| v.trim() == "1" || v.trim().eq_ignore_ascii_case("true"))
                .unwrap_or(false)
        }),
    }
}

/// Reusable packing scratch: operands land in tile-strip layouts
/// (`A` in `MR`-row strips, `W` in `NR`-column strips), zero-padded to
/// whole tiles so the kernel never branches on a tail. One `PackBuf`
/// per worker, allocation amortized across panels.
#[derive(Debug)]
pub struct PackBuf {
    ap: Vec<f64>,
    wp: Vec<f64>,
}

impl Default for PackBuf {
    fn default() -> Self {
        PackBuf::new()
    }
}

impl PackBuf {
    pub const fn new() -> PackBuf {
        PackBuf { ap: Vec::new(), wp: Vec::new() }
    }

    /// Pack the `m×k` left operand: `a(i, p)` lands at
    /// `ap[(i/MR)·k·MR + p·MR + i%MR]`; rows past `m` are zero.
    pub fn pack_a(&mut self, m: usize, k: usize, a: impl Fn(usize, usize) -> f64) {
        let strips = m.div_ceil(MR);
        self.ap.clear();
        self.ap.resize(strips * k * MR, 0.0);
        for s in 0..strips {
            let base = s * k * MR;
            let rows = (m - s * MR).min(MR);
            for p in 0..k {
                for r in 0..rows {
                    self.ap[base + p * MR + r] = a(s * MR + r, p);
                }
            }
        }
    }

    /// Pack the `k×np` right operand with any scale already folded in:
    /// `w(p, j)` lands at `wp[(j/NR)·k·NR + p·NR + j%NR]`; columns past
    /// `np` are zero.
    pub fn pack_w(&mut self, k: usize, np: usize, w: impl Fn(usize, usize) -> f64) {
        let strips = np.div_ceil(NR);
        self.wp.clear();
        self.wp.resize(strips * k * NR, 0.0);
        for s in 0..strips {
            let base = s * k * NR;
            let cols = (np - s * NR).min(NR);
            for p in 0..k {
                for c in 0..cols {
                    self.wp[base + p * NR + c] = w(p, s * NR + c);
                }
            }
        }
    }
}

/// Apply `C[i,j] += Σ_p A[i,p]·W[p,j]` for the packed `m×k` / `k×np`
/// operands to the column-major window of `c` (leading dimension
/// `ldc`) whose top-left element is `(row0, col0)`. Dispatches to the
/// register-tiled or the scalar reference path — bit-identical per
/// element either way (module docs).
#[allow(clippy::too_many_arguments)]
pub fn sweep(
    pack: &PackBuf,
    m: usize,
    np: usize,
    k: usize,
    c: &mut [f64],
    ldc: usize,
    row0: usize,
    col0: usize,
) {
    if m == 0 || np == 0 || k == 0 {
        return;
    }
    if enabled() {
        micro_sweep(pack, m, np, k, c, ldc, row0, col0);
    } else {
        reference_sweep(pack, m, np, k, c, ldc, row0, col0);
    }
}

/// The register-tiled path: `MR×NR` accumulator tiles, full-`k`
/// `mul_add` chains, live lanes loaded from / stored to `C`, dead
/// lanes riding the pack's zero padding.
#[allow(clippy::too_many_arguments)]
pub fn micro_sweep(
    pack: &PackBuf,
    m: usize,
    np: usize,
    k: usize,
    c: &mut [f64],
    ldc: usize,
    row0: usize,
    col0: usize,
) {
    let mut i0 = 0;
    while i0 < m {
        let mr = (m - i0).min(MR);
        let ap = &pack.ap[(i0 / MR) * k * MR..][..k * MR];
        let mut j0 = 0;
        while j0 < np {
            let nr = (np - j0).min(NR);
            let wp = &pack.wp[(j0 / NR) * k * NR..][..k * NR];
            let mut acc = [[0.0f64; MR]; NR];
            for (cc, col) in acc.iter_mut().enumerate().take(nr) {
                let base = (col0 + j0 + cc) * ldc + row0 + i0;
                col[..mr].copy_from_slice(&c[base..base + mr]);
            }
            for (a, w) in ap.chunks_exact(MR).zip(wp.chunks_exact(NR)) {
                let a: &[f64; MR] = a.try_into().unwrap();
                let w: &[f64; NR] = w.try_into().unwrap();
                for (col, &wv) in acc.iter_mut().zip(w.iter()) {
                    for (av, cv) in a.iter().zip(col.iter_mut()) {
                        *cv = av.mul_add(wv, *cv);
                    }
                }
            }
            for (cc, col) in acc.iter().enumerate().take(nr) {
                let base = (col0 + j0 + cc) * ldc + row0 + i0;
                c[base..base + mr].copy_from_slice(&col[..mr]);
            }
            j0 += NR;
        }
        i0 += MR;
    }
}

/// The scalar reference path over the same packed operands: one
/// element at a time, the identical ascending-`p` `mul_add` chain.
#[allow(clippy::too_many_arguments)]
pub fn reference_sweep(
    pack: &PackBuf,
    m: usize,
    np: usize,
    k: usize,
    c: &mut [f64],
    ldc: usize,
    row0: usize,
    col0: usize,
) {
    for j in 0..np {
        let wp = &pack.wp[(j / NR) * k * NR..][..k * NR];
        let jc = j % NR;
        for i in 0..m {
            let ap = &pack.ap[(i / MR) * k * MR..][..k * MR];
            let ir = i % MR;
            let idx = (col0 + j) * ldc + row0 + i;
            let mut acc = c[idx];
            for p in 0..k {
                acc = ap[p * MR + ir].mul_add(wp[p * NR + jc], acc);
            }
            c[idx] = acc;
        }
    }
}

/// How many dot products [`dot_many`] fuses per pass over `x`.
const DOT_GROUP: usize = 8;

/// Batched dot products against one shared left vector:
/// `out[q] = x · ys[q]`. The fused path loads each `x` chunk once per
/// group of [`DOT_GROUP`] outputs while keeping, per output, the exact
/// 4-way partial-sum scheme of [`super::blas1::dot`] — so
/// `dot_many(x, ys, out)` is bitwise `out[q] = dot(x, ys[q])` for
/// every `q`, on either path.
pub fn dot_many(x: &[f64], ys: &[&[f64]], out: &mut [f64]) {
    assert_eq!(ys.len(), out.len(), "dot_many: one output per right-hand vector");
    if !enabled() {
        for (o, y) in out.iter_mut().zip(ys) {
            *o = super::blas1::dot(x, y);
        }
        return;
    }
    let n = x.len();
    for y in ys {
        assert_eq!(y.len(), n, "dot_many: every vector must match x's length");
    }
    let chunks = n / 4;
    for (ys_g, out_g) in ys.chunks(DOT_GROUP).zip(out.chunks_mut(DOT_GROUP)) {
        let mut part = [[0.0f64; 4]; DOT_GROUP];
        for i in 0..chunks {
            let b = 4 * i;
            let xb: &[f64; 4] = x[b..b + 4].try_into().unwrap();
            for (p, y) in part.iter_mut().zip(ys_g.iter()) {
                let yb: &[f64; 4] = y[b..b + 4].try_into().unwrap();
                p[0] = xb[0].mul_add(yb[0], p[0]);
                p[1] = xb[1].mul_add(yb[1], p[1]);
                p[2] = xb[2].mul_add(yb[2], p[2]);
                p[3] = xb[3].mul_add(yb[3], p[3]);
            }
        }
        for ((o, y), p) in out_g.iter_mut().zip(ys_g.iter()).zip(part.iter()) {
            let mut s = (p[0] + p[1]) + (p[2] + p[3]);
            for i in 4 * chunks..n {
                s = x[i].mul_add(y[i], s);
            }
            *o = s;
        }
    }
}

/// How many right-hand sides [`chol_solve_multi`] marches in lockstep.
const SOLVE_GROUP: usize = 8;

/// Solve `L·Lᵀ x = b` for `t` stacked right-hand sides (`rhs[q·n..
/// (q+1)·n]` is RHS `q`) against one factored `n×n` system `s` (lower
/// triangle of the column-major factor). The fused path interleaves a
/// group of RHS per pass so the factor's columns are loaded once per
/// group; per RHS, the operation sequence is exactly
/// [`super::chol::chol_solve_small`]'s — bitwise equal on either path.
pub fn chol_solve_multi(s: &[f64], rhs: &mut [f64], n: usize, t: usize) {
    if n == 0 || t == 0 {
        return;
    }
    if !enabled() {
        for chunk in rhs.chunks_exact_mut(n).take(t) {
            super::chol::chol_solve_small(s, chunk, n);
        }
        return;
    }
    for chunk in rhs[..n * t].chunks_mut(n * SOLVE_GROUP) {
        let g = chunk.len() / n;
        // Forward substitution: L y = b, `g` systems in lockstep.
        for j in 0..n {
            let sjj = s[j * n + j];
            for q in 0..g {
                chunk[q * n + j] /= sjj;
            }
            for i in (j + 1)..n {
                let sij = s[j * n + i];
                for q in 0..g {
                    let bj = chunk[q * n + j];
                    chunk[q * n + i] = (-bj).mul_add(sij, chunk[q * n + i]);
                }
            }
        }
        // Backward substitution: Lᵀ x = y, accumulators in registers.
        for j in (0..n).rev() {
            let sjj = s[j * n + j];
            let mut v = [0.0f64; SOLVE_GROUP];
            for (q, vq) in v.iter_mut().enumerate().take(g) {
                *vq = chunk[q * n + j];
            }
            for i in (j + 1)..n {
                let sij = s[j * n + i];
                for (q, vq) in v.iter_mut().enumerate().take(g) {
                    *vq = (-sij).mul_add(chunk[q * n + i], *vq);
                }
            }
            for (q, vq) in v.iter().enumerate().take(g) {
                chunk[q * n + j] = vq / sjj;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    // NOTE: `set_forced` is process-global and lib unit tests share one
    // process, so these tests never touch it — they call the two sweep
    // paths directly. Whole-driver parity under forced selection lives
    // in `tests/kernel_parity.rs`, which serializes on its own lock.

    fn randn(rng: &mut XorShift, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
    }

    fn pack_for(a: &[f64], w: &[f64], m: usize, k: usize, np: usize) -> PackBuf {
        let mut pack = PackBuf::new();
        pack.pack_a(m, k, |i, p| a[p * m + i]);
        pack.pack_w(k, np, |p, j| w[j * k + p]);
        pack
    }

    #[test]
    fn micro_and_reference_sweeps_are_bitwise_identical() {
        let mut rng = XorShift::new(0x5EED_01CE);
        for &(m, np, k) in &[
            (1usize, 1usize, 1usize),
            (8, 4, 16),
            (7, 3, 5),
            (9, 5, 1),
            (17, 2, 33),
            (130, 70, 65),
            (64, 64, 64),
            (3, 129, 7),
        ] {
            let a = randn(&mut rng, m * k);
            let w = randn(&mut rng, k * np);
            let c0 = randn(&mut rng, m * np);
            let pack = pack_for(&a, &w, m, k, np);
            let mut c_micro = c0.clone();
            micro_sweep(&pack, m, np, k, &mut c_micro, m, 0, 0);
            let mut c_ref = c0.clone();
            reference_sweep(&pack, m, np, k, &mut c_ref, m, 0, 0);
            for (i, (x, y)) in c_micro.iter().zip(c_ref.iter()).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "element {i} diverged at shape ({m},{np},{k})"
                );
            }
        }
    }

    #[test]
    fn sweep_matches_naive_product_within_tolerance() {
        let mut rng = XorShift::new(77);
        let (m, np, k) = (23usize, 11usize, 19usize);
        let a = randn(&mut rng, m * k);
        let w = randn(&mut rng, k * np);
        let mut c = vec![0.0f64; m * np];
        let pack = pack_for(&a, &w, m, k, np);
        micro_sweep(&pack, m, np, k, &mut c, m, 0, 0);
        for j in 0..np {
            for i in 0..m {
                let naive: f64 = (0..k).map(|p| a[p * m + i] * w[j * k + p]).sum();
                assert!((c[j * m + i] - naive).abs() < 1e-12 * (k as f64), "({i},{j})");
            }
        }
    }

    #[test]
    fn sweep_respects_the_window_and_leaves_the_rest_untouched() {
        let mut rng = XorShift::new(31);
        let (ldc, rows, cols) = (10usize, 10usize, 8usize);
        let (m, np, k) = (4usize, 3usize, 6usize);
        let (row0, col0) = (5usize, 2usize);
        let a = randn(&mut rng, m * k);
        let w = randn(&mut rng, k * np);
        let c0 = randn(&mut rng, ldc * cols);
        let pack = pack_for(&a, &w, m, k, np);
        let mut c = c0.clone();
        micro_sweep(&pack, m, np, k, &mut c, ldc, row0, col0);
        for j in 0..cols {
            for i in 0..rows {
                let inside = (row0..row0 + m).contains(&i) && (col0..col0 + np).contains(&j);
                if !inside {
                    assert_eq!(
                        c[j * ldc + i].to_bits(),
                        c0[j * ldc + i].to_bits(),
                        "({i},{j}) outside the window moved"
                    );
                }
            }
        }
    }

    #[test]
    fn dot_many_is_bitwise_equal_to_repeated_dot() {
        let mut rng = XorShift::new(2024);
        for &(n, t) in &[(1usize, 1usize), (4, 3), (7, 8), (129, 17), (256, 9)] {
            let x = randn(&mut rng, n);
            let cols: Vec<Vec<f64>> = (0..t).map(|_| randn(&mut rng, n)).collect();
            let ys: Vec<&[f64]> = cols.iter().map(|c| c.as_slice()).collect();
            let mut out = vec![0.0f64; t];
            dot_many(&x, &ys, &mut out);
            for (q, y) in ys.iter().enumerate() {
                assert_eq!(
                    out[q].to_bits(),
                    crate::linalg::blas1::dot(&x, y).to_bits(),
                    "output {q} diverged at n={n}, t={t}"
                );
            }
        }
    }

    #[test]
    fn chol_solve_multi_is_bitwise_equal_to_per_rhs_solves() {
        let mut rng = XorShift::new(99);
        for &(n, t) in &[(1usize, 1usize), (3, 2), (4, 8), (5, 17), (8, 9)] {
            // A well-conditioned synthetic lower factor: unit-ish
            // diagonal plus small off-diagonal noise.
            let mut s = vec![0.0f64; n * n];
            for j in 0..n {
                s[j * n + j] = 2.0 + rng.uniform();
                for i in (j + 1)..n {
                    s[j * n + i] = 0.25 * (rng.uniform() - 0.5);
                }
            }
            let rhs0 = randn(&mut rng, n * t);
            let mut fused = rhs0.clone();
            chol_solve_multi(&s, &mut fused, n, t);
            let mut solo = rhs0.clone();
            for chunk in solo.chunks_exact_mut(n) {
                crate::linalg::chol::chol_solve_small(&s, chunk, n);
            }
            for (i, (a, b)) in fused.iter().zip(solo.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "rhs element {i} at n={n}, t={t}");
            }
        }
    }
}
