//! Cholesky factorization and SPD solves — the paper's `potrf` and `posv`.
//!
//! `potrf` runs once per study over the kinship matrix `M` (preprocessing,
//! Listing 1.1 line 1). `posv` runs per SNP over the small `(p+1)×(p+1)`
//! assembled `S_i` — millions of times — so it is written allocation-free
//! over caller buffers.

use super::matrix::Matrix;
use super::micro::{self, PackBuf};
use crate::error::{Error, Result};

/// Panel width for the blocked factorization.
const POTRF_NB: usize = 48;

/// In-place lower Cholesky: `M = L L^T`, returns `L` (strictly-upper part
/// zeroed). Blocked right-looking: unblocked panel factorizations plus a
/// BLAS-3 trailing update through the register-tiled microkernel sweep
/// of [`super::micro`] (§Perf: 1.4 → ~8 GFlop/s at n=512 with the old
/// 4×2 kernel; the sweep does better). `M` must be SPD.
pub fn potrf(m: &Matrix) -> Result<Matrix> {
    let n = m.rows();
    if m.cols() != n {
        return Err(Error::shape(format!("potrf: matrix is {}x{}", m.rows(), m.cols())));
    }
    let mut l = m.clone();
    let mut pack = PackBuf::new();
    let mut k0 = 0;
    while k0 < n {
        let kb = POTRF_NB.min(n - k0);
        // Unblocked panel over columns [k0, k0+kb): prior blocks' trailing
        // updates already applied, so sums run over panel columns only.
        for j in k0..k0 + kb {
            let mut d = l.get(j, j);
            for s in k0..j {
                let v = l.get(j, s);
                d = (-v).mul_add(v, d);
            }
            if d <= 0.0 {
                return Err(Error::Numerical(format!(
                    "potrf: matrix not positive definite (pivot {d:.3e} at column {j})"
                )));
            }
            let djj = d.sqrt();
            l.set(j, j, djj);
            for i in j + 1..n {
                let mut v = l.get(i, j);
                for s in k0..j {
                    v = (-l.get(i, s)).mul_add(l.get(j, s), v);
                }
                l.set(i, j, v / djj);
            }
        }
        // BLAS-3 trailing update: A[t.., t..] -= P P^T with P the panel
        // rows below it. Writes the full rectangle (upper-trailing entries
        // are never read by later panels and get zeroed at the end).
        let t = k0 + kb;
        if t < n {
            potrf_trailing(&mut pack, &mut l, k0, kb, t, n);
        }
        k0 += kb;
    }
    // Zero the strictly-upper part.
    for j in 1..n {
        for i in 0..j {
            l.set(i, j, 0.0);
        }
    }
    Ok(l)
}

/// Trailing update `A[t.., t..] -= A[t.., k0..k0+kb] * A[t.., k0..k0+kb]^T`
/// (full rectangle) via one microkernel sweep: the panel rows pack as
/// `A`, their transpose (negated at pack time) as `W`, and the sweep
/// writes the trailing square in place — tail widths < NR ride the
/// pack's zero padding instead of a separate scalar nest.
#[inline]
fn potrf_trailing(pack: &mut PackBuf, l: &mut Matrix, k0: usize, kb: usize, t: usize, n: usize) {
    let data = l.as_mut_slice();
    let rest = n - t;
    pack.pack_a(rest, kb, |i, p| data[(k0 + p) * n + t + i]);
    pack.pack_w(kb, rest, |p, j| -data[(k0 + p) * n + t + j]);
    micro::sweep(pack, rest, rest, kb, data, n, t, t);
}

/// Solve `S x = b` for SPD `S` via Cholesky (the paper's `posv`), writing
/// the solution over `b`. `scratch` must be `n*n` elements; it receives the
/// factor so repeated solves can reuse the allocation.
pub fn posv(s: &Matrix, b: &mut [f64]) -> Result<()> {
    let n = s.rows();
    if s.cols() != n || b.len() != n {
        return Err(Error::shape(format!("posv: S {}x{}, b {}", s.rows(), s.cols(), b.len())));
    }
    let l = potrf(s)?;
    // Forward then backward substitution.
    super::blas2::trsv_lower(&l, b)?;
    trsv_lower_transposed(&l, b)
}

/// Allocation-free `posv` for the tiny per-SNP systems: factors `S`
/// (given as a flat column-major `n×n` slice) in place and solves into `b`.
/// This is the S-loop hot call — no `Matrix`, no `Vec`.
pub fn posv_small(s: &mut [f64], b: &mut [f64], n: usize) -> Result<()> {
    posv_small_factor(s, n)?;
    chol_solve_small(s, b, n);
    Ok(())
}

/// Factor half of [`posv_small`]: in-place lower Cholesky of a flat
/// column-major `n×n` SPD slice. Exposed separately so the multi-trait
/// S-loop can factor each SNP's system once and reuse it for every
/// trait's right-hand side via [`chol_solve_small`].
pub fn posv_small_factor(s: &mut [f64], n: usize) -> Result<()> {
    debug_assert_eq!(s.len(), n * n);
    // Cholesky in place (lower).
    for j in 0..n {
        let mut d = s[j * n + j];
        for k in 0..j {
            let v = s[k * n + j];
            d = (-v).mul_add(v, d);
        }
        if d <= 0.0 {
            return Err(Error::Numerical(format!("posv_small: pivot {d:.3e} at {j}")));
        }
        let djj = d.sqrt();
        s[j * n + j] = djj;
        for i in j + 1..n {
            let mut v = s[j * n + i];
            for k in 0..j {
                v = (-s[k * n + i]).mul_add(s[k * n + j], v);
            }
            s[j * n + i] = v / djj;
        }
    }
    Ok(())
}

/// Solve half of [`posv_small`]: forward + backward substitution against
/// a factor produced by [`posv_small_factor`], overwriting `b` with the
/// solution. Arithmetic is identical to the fused path bit for bit, and
/// the per-element `mul_add` sequence here is exactly what
/// [`super::micro::chol_solve_multi`] runs per RHS — keep the two in
/// lockstep or batched solves drift from solo ones.
pub fn chol_solve_small(s: &[f64], b: &mut [f64], n: usize) {
    debug_assert_eq!(s.len(), n * n);
    debug_assert_eq!(b.len(), n);
    // L z = b (forward).
    for j in 0..n {
        b[j] /= s[j * n + j];
        let bj = b[j];
        for i in j + 1..n {
            b[i] = (-bj).mul_add(s[j * n + i], b[i]);
        }
    }
    // L^T x = z (backward).
    for j in (0..n).rev() {
        let mut v = b[j];
        for i in j + 1..n {
            v = (-s[j * n + i]).mul_add(b[i], v);
        }
        b[j] = v / s[j * n + j];
    }
}

/// Solve `L^T x = b` in place for lower-triangular `L`.
fn trsv_lower_transposed(l: &Matrix, b: &mut [f64]) -> Result<()> {
    let n = l.rows();
    for j in (0..n).rev() {
        let mut v = b[j];
        let col = l.col(j);
        for i in j + 1..n {
            v -= col[i] * b[i];
        }
        let ljj = col[j];
        if ljj == 0.0 {
            return Err(Error::Numerical(format!("trsv^T: zero diagonal at {j}")));
        }
        b[j] = v / ljj;
    }
    Ok(())
}

/// Invert the `nb × nb` diagonal blocks of a lower-triangular `L`.
/// Returns a `(nb, nb*nblocks)` matrix holding `inv(L[kk])` side by side.
///
/// This is the accelerator-friendly trsm formulation (see DESIGN.md
/// §Hardware-Adaptation): with inverted diagonal blocks the entire forward
/// substitution becomes matmuls — which is what the Pallas L1 kernel and
/// the cuBLAS implementation the paper relied on both exploit. The last
/// block is zero-padded (identity outside the live part) when `n % nb != 0`.
pub fn potrf_invert_diag_blocks(l: &Matrix, nb: usize) -> Result<Matrix> {
    let n = l.rows();
    if l.cols() != n {
        return Err(Error::shape("invert_diag_blocks: L not square".to_string()));
    }
    if nb == 0 {
        return Err(Error::Config("invert_diag_blocks: nb must be > 0".to_string()));
    }
    let nblocks = n.div_ceil(nb);
    let mut out = Matrix::zeros(nb, nb * nblocks);
    for kb in 0..nblocks {
        let base = kb * nb;
        let live = nb.min(n - base);
        // Invert the live lower-triangular block by forward substitution on
        // identity columns; pad the rest with the identity.
        for c in 0..nb {
            let mut e = vec![0.0; nb];
            e[c] = 1.0;
            if c < live {
                for r in 0..live {
                    let mut v = e[r];
                    for s in 0..r {
                        v -= l.get(base + r, base + s) * e[s];
                    }
                    let d = l.get(base + r, base + r);
                    if d == 0.0 {
                        return Err(Error::Numerical(format!("zero diag at {}", base + r)));
                    }
                    e[r] = v / d;
                }
            }
            for r in 0..nb {
                out.set(r, kb * nb + c, e[r]);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas3::gemm;
    use crate::util::XorShift;

    #[test]
    fn potrf_reconstructs() {
        let mut rng = XorShift::new(31);
        for &n in &[1, 2, 5, 16, 33] {
            let m = Matrix::rand_spd(n, 2.0, &mut rng);
            let l = potrf(&m).unwrap();
            // L L^T == M
            let mut rec = Matrix::zeros(n, n);
            gemm(1.0, &l, &l.transpose(), 0.0, &mut rec).unwrap();
            assert!(rec.max_abs_diff(&m) < 1e-9, "n={n}");
            // Strictly-upper part of L is zero.
            for j in 0..n {
                for i in 0..j {
                    assert_eq!(l.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn potrf_rejects_indefinite() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(potrf(&m).is_err());
    }

    #[test]
    fn potrf_rejects_nonsquare() {
        assert!(potrf(&Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn posv_solves_spd() {
        let mut rng = XorShift::new(32);
        let n = 12;
        let s = Matrix::rand_spd(n, 3.0, &mut rng);
        let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut b = crate::linalg::blas2::gemv_n(&s, &x_true).unwrap();
        posv(&s, &mut b).unwrap();
        for (a, t) in b.iter().zip(&x_true) {
            assert!((a - t).abs() < 1e-8, "{a} vs {t}");
        }
    }

    #[test]
    fn posv_small_matches_posv() {
        let mut rng = XorShift::new(33);
        for &n in &[1, 2, 5, 9] {
            let s = Matrix::rand_spd(n, 2.0, &mut rng);
            let b0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut b_ref = b0.clone();
            posv(&s, &mut b_ref).unwrap();
            let mut s_flat = s.as_slice().to_vec();
            let mut b = b0.clone();
            posv_small(&mut s_flat, &mut b, n).unwrap();
            for (a, r) in b.iter().zip(&b_ref) {
                assert!((a - r).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn factor_then_solve_is_bit_identical_to_fused_posv_small() {
        // The multi-trait S-loop factors once and solves t RHS; every
        // solve must match what the fused call would have produced bit
        // for bit, or batched runs drift from single-trait runs.
        let mut rng = XorShift::new(35);
        for &n in &[1, 3, 6, 9] {
            let s = Matrix::rand_spd(n, 2.0, &mut rng);
            let rhs: Vec<Vec<f64>> =
                (0..4).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
            let mut factored = s.as_slice().to_vec();
            posv_small_factor(&mut factored, n).unwrap();
            for b0 in &rhs {
                let mut fused_s = s.as_slice().to_vec();
                let mut fused_b = b0.clone();
                posv_small(&mut fused_s, &mut fused_b, n).unwrap();
                assert_eq!(fused_s, factored, "factor differs at n={n}");
                let mut b = b0.clone();
                chol_solve_small(&factored, &mut b, n);
                assert_eq!(b, fused_b, "solve differs at n={n}");
            }
        }
    }

    #[test]
    fn posv_small_rejects_indefinite() {
        let mut s = vec![1.0, 2.0, 2.0, 1.0];
        let mut b = vec![1.0, 1.0];
        assert!(posv_small(&mut s, &mut b, 2).is_err());
    }

    #[test]
    fn inverted_diag_blocks_invert() {
        let mut rng = XorShift::new(34);
        let n = 40;
        let nb = 16; // 40 = 2*16 + 8 → exercises the padded tail block
        let m = Matrix::rand_spd(n, 2.0, &mut rng);
        let l = potrf(&m).unwrap();
        let inv = potrf_invert_diag_blocks(&l, nb).unwrap();
        assert_eq!(inv.rows(), nb);
        assert_eq!(inv.cols(), nb * 3);
        for kb in 0..3 {
            let base = kb * nb;
            let live = nb.min(n - base);
            // inv_block * L_block == I on the live part.
            for c in 0..live {
                for r in 0..live {
                    let mut s = 0.0;
                    for k in 0..live {
                        s += inv.get(r, kb * nb + k) * l.get(base + k, base + c);
                    }
                    let want = if r == c { 1.0 } else { 0.0 };
                    assert!((s - want).abs() < 1e-9, "kb={kb} r={r} c={c}: {s}");
                }
            }
            // Padded part is identity.
            for c in live..nb {
                assert_eq!(inv.get(c, kb * nb + c), 1.0);
            }
        }
    }

    #[test]
    fn inverted_diag_blocks_bad_args() {
        let l = Matrix::eye(4);
        assert!(potrf_invert_diag_blocks(&l, 0).is_err());
        assert!(potrf_invert_diag_blocks(&Matrix::zeros(2, 3), 2).is_err());
    }
}
