//! BLAS-3: the performance-critical kernels. The paper's whole point is
//! that blocked BLAS-3 (`trsm` on the accelerator, `gemm`/`syrk` in the
//! S-loop) beats per-SNP BLAS-2 by an order of magnitude; these native
//! implementations back the CPU baselines and the S-loop lane.
//!
//! Every kernel is a thin driver over the register-tiled microkernel in
//! [`super::micro`]: operands are packed into zero-padded tile strips
//! (any scale — gemm's `alpha`, the `-1` of the trsm update — folded
//! into `W` at pack time), then one [`micro::sweep`] applies the rank-k
//! update `C[i,j] += Σ_p A[i,p]·W[p,j]` with `MR×NR` accumulator tiles
//! and explicit `f64::mul_add` chains. The sweep vectorizes across
//! *independent output elements* only, so each element's accumulation
//! order never changes — the scalar reference path behind
//! `CUGWAS_NO_MICROKERNEL` produces bit-identical output (see
//! `micro.rs` and `tests/kernel_parity.rs`). Not MKL, but within a
//! small factor of peak for the sizes the pipeline feeds it — see
//! EXPERIMENTS.md §Perf for measured GFlop/s.
//!
//! §Perf (threading): `gemm`, `trsm` and `syrk_t` fan their NC-wide
//! column panels of B/C out over the compute pool
//! ([`crate::util::threads`]). Panels are independent — every output
//! element is produced by exactly one panel task running the exact
//! serial loop nest — so parallel results are **bit-identical** to the
//! serial path at every thread count, and the paper's multi-threaded
//! BLAS baseline is finally matched on multi-core hosts (the
//! `linalg_micro` bench sweeps 1/2/4/ncpu threads and reports GFlop/s;
//! ≥ 2× at 4 threads on 512³ is the acceptance bar). Small shapes stay
//! on the serial path — [`crate::util::threads::for_flops`] only opens a
//! parallel region when each worker gets ≥ ~1 ms of arithmetic.

use super::matrix::Matrix;
use super::micro::{self, PackBuf};
use crate::error::{Error, Result};
use crate::util::threads;

/// Column-panel width: the unit of parallel work distribution (a
/// multiple of the microkernel's NR columns, so panel boundaries never
/// split a register tile).
const NC: usize = 64;

/// `C += A^T_or_A * B` driver — here the plain `C = alpha*A*B + beta*C`
/// with `A: m×k`, `B: k×n`, all column-major.
pub fn gemm(alpha: f64, a: &Matrix, b: &Matrix, beta: f64, c: &mut Matrix) -> Result<()> {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    if b.rows() != k || c.rows() != m || c.cols() != n {
        return Err(Error::shape(format!(
            "gemm: A {}x{}, B {}x{}, C {}x{}",
            m, k, b.rows(), n, c.rows(), c.cols()
        )));
    }
    if beta != 1.0 {
        for v in c.as_mut_slice() {
            *v *= beta;
        }
    }
    if alpha == 0.0 || m == 0 || n == 0 || k == 0 {
        return Ok(());
    }
    // NC-wide column panels of B/C are independent: distribute them over
    // the pool (1 worker ⇒ plain serial sweep, identical either way).
    let nt = threads::for_flops(2.0 * m as f64 * k as f64 * n as f64);
    let a_data = a.as_slice();
    let b_data = b.as_slice();
    let b_rows = b.rows();
    let c_rows = m;
    let panels: Vec<&mut [f64]> = c.as_mut_slice().chunks_mut(NC * c_rows).collect();
    threads::scatter(nt, panels, PackBuf::new, |pack, pi, panel| {
        let nb = panel.len() / c_rows;
        gemm_panel(pack, alpha, a_data, m, k, b_data, b_rows, pi * NC, panel, c_rows, nb);
        Ok(())
    })
}

/// One NC-wide panel: columns `[jc, jc+nb)` of C (`panel` is their
/// contiguous column-major storage). Packs `A` into MR-row strips and
/// `alpha·B[:, jc..jc+nb]` into NR-column strips, then runs one
/// full-`k` microkernel sweep — tails ride the pack's zero padding, so
/// odd shapes take the same code path as whole tiles.
#[allow(clippy::too_many_arguments)]
fn gemm_panel(
    pack: &mut PackBuf,
    alpha: f64,
    a_data: &[f64],
    m: usize,
    k: usize,
    b_data: &[f64],
    b_rows: usize,
    jc: usize,
    panel: &mut [f64],
    c_rows: usize,
    nb: usize,
) {
    pack.pack_a(m, k, |i, p| a_data[p * m + i]);
    pack.pack_w(k, nb, |p, j| alpha * b_data[(jc + j) * b_rows + p]);
    micro::sweep(pack, m, nb, k, panel, c_rows, 0, 0);
}

/// `C = A^T A` (the paper's `syrk`, transposed variant: `S_TL = X̃_L^T X̃_L`,
/// `S_BR = X̃_R^T X̃_R`). Returns the full symmetric matrix (both halves
/// filled) because downstream assembly reads both.
///
/// Built on the tiled [`gemm`] kernel (one transpose of the narrow
/// operand, then the full register-blocked sweep — parallel over column
/// panels like every other BLAS-3 call) instead of the old per-entry
/// `dot` double loop. The lower triangle is mirrored onto the upper
/// afterwards so both halves stay bit-identical, which the per-entry
/// version guaranteed by construction.
pub fn syrk_t(a: &Matrix) -> Matrix {
    syrk_t_pretransposed(&a.transpose(), a)
}

/// [`syrk_t`] when the caller already holds `A^T` (e.g. the cached
/// `Preprocessed::xl_tt`) — skips the re-transpose. Panics (via the gemm
/// shape check) if `at` is not the transpose shape of `a`.
pub fn syrk_t_pretransposed(at: &Matrix, a: &Matrix) -> Matrix {
    let k = a.cols();
    let mut c = Matrix::zeros(k, k);
    if k == 0 {
        return c;
    }
    gemm(1.0, at, a, 0.0, &mut c).expect("syrk_t: `at` must be the transpose shape of `a`");
    for j in 0..k {
        for i in (j + 1)..k {
            let v = c.get(i, j);
            c.set(j, i, v);
        }
    }
    c
}

/// Block size for the trsm right-hand-side sweep.
const TRSM_NB: usize = 32;

/// Solve `L X = B` in place over `B` (the paper's `trsm`: left, lower,
/// non-transposed, unit-stride RHS columns). Blocked forward substitution:
/// diagonal-block `trsv`s plus rank-`kb` `gemm` updates, so the bulk of the
/// flops run through the BLAS-3 micro-kernel. RHS columns are solved
/// independently, NC at a time, across the compute pool (each panel runs
/// the exact serial schedule, so results are bit-identical at every
/// thread count). The diagonal is checked up front: a singular `L` errors
/// before any column of `B` is touched.
pub fn trsm_lower_left(l: &Matrix, b: &mut Matrix) -> Result<()> {
    let n = l.rows();
    if l.cols() != n || b.rows() != n {
        return Err(Error::shape(format!(
            "trsm: L {}x{}, B {}x{}",
            l.rows(),
            l.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let nrhs = b.cols();
    if n == 0 || nrhs == 0 {
        return Ok(());
    }
    for row in 0..n {
        if l.get(row, row) == 0.0 {
            return Err(Error::Numerical(format!("trsm: zero diagonal at {row}")));
        }
    }
    let nt = threads::for_flops(n as f64 * n as f64 * nrhs as f64);
    let l_data = l.as_slice();
    let panels: Vec<&mut [f64]> = b.as_mut_slice().chunks_mut(NC * n).collect();
    threads::scatter(nt, panels, PackBuf::new, |pack, _, panel| {
        trsm_panel(pack, l_data, n, panel);
        Ok(())
    })
}

/// Blocked forward substitution over one panel of RHS columns.
fn trsm_panel(pack: &mut PackBuf, l_data: &[f64], n: usize, panel: &mut [f64]) {
    let ncols = panel.len() / n;
    let mut k0 = 0;
    while k0 < n {
        let kb = TRSM_NB.min(n - k0);
        // 1) Solve the diagonal block for this panel's RHS columns:
        //    B[k0..k0+kb, :] ← L[diag]^-1 * same.
        for j in 0..ncols {
            let col = &mut panel[j * n..(j + 1) * n];
            for r in 0..kb {
                let row = k0 + r;
                let mut v = col[row];
                for s in 0..r {
                    v = (-l_data[(k0 + s) * n + row]).mul_add(col[k0 + s], v);
                }
                col[row] = v / l_data[row * n + row];
            }
        }
        // 2) Update the trailing rows with a microkernel sweep:
        //    B[k0+kb.., :] -= L[k0+kb.., k0..k0+kb] * B[diag rows, :].
        //    The -1 is folded into W at pack time; the sweep writes the
        //    strided trailing window in place (no sub-matrix copies).
        let rest = n - k0 - kb;
        if rest > 0 {
            let row0 = k0 + kb;
            pack.pack_a(rest, kb, |i, p| l_data[(k0 + p) * n + row0 + i]);
            pack.pack_w(kb, ncols, |p, j| -panel[j * n + k0 + p]);
            micro::sweep(pack, rest, ncols, kb, panel, n, row0, 0);
        }
        k0 += kb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas2::gemv_n;
    use crate::util::XorShift;

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for j in 0..b.cols() {
            for i in 0..a.rows() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_over_shapes() {
        let mut rng = XorShift::new(21);
        for &(m, k, n) in
            &[(1, 1, 1), (3, 5, 2), (17, 9, 13), (64, 64, 64), (130, 70, 65), (257, 300, 3)]
        {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let mut c = Matrix::zeros(m, n);
            gemm(1.0, &a, &b, 0.0, &mut c).unwrap();
            let r = naive_gemm(&a, &b);
            assert!(c.max_abs_diff(&r) < 1e-9, "m={m} k={k} n={n}: {}", c.max_abs_diff(&r));
        }
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = XorShift::new(22);
        let a = Matrix::randn(8, 8, &mut rng);
        let b = Matrix::randn(8, 8, &mut rng);
        let c0 = Matrix::randn(8, 8, &mut rng);
        let mut c = c0.clone();
        gemm(2.0, &a, &b, 0.5, &mut c).unwrap();
        let ab = naive_gemm(&a, &b);
        for j in 0..8 {
            for i in 0..8 {
                let want = 2.0 * ab.get(i, j) + 0.5 * c0.get(i, j);
                assert!((c.get(i, j) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn gemm_shape_error() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 2); // should be 3 rows
        let mut c = Matrix::zeros(2, 2);
        assert!(gemm(1.0, &a, &b, 0.0, &mut c).is_err());
    }

    #[test]
    fn gemm_degenerate_dims() {
        let a = Matrix::zeros(0, 0);
        let b = Matrix::zeros(0, 0);
        let mut c = Matrix::zeros(0, 0);
        gemm(1.0, &a, &b, 0.0, &mut c).unwrap();
    }

    #[test]
    fn gemm_parallel_is_bit_identical_to_serial() {
        // Big enough to clear the for_flops threshold (320³ ≈ 65 MFlop).
        let mut rng = XorShift::new(31);
        let a = Matrix::randn(320, 320, &mut rng);
        let b = Matrix::randn(320, 320, &mut rng);
        let mut c_serial = Matrix::zeros(320, 320);
        {
            let _g = crate::util::threads::with_budget(1);
            gemm(1.5, &a, &b, 0.0, &mut c_serial).unwrap();
        }
        for nt in [2, 4, 8] {
            let mut c_par = Matrix::zeros(320, 320);
            let _g = crate::util::threads::with_budget(nt);
            gemm(1.5, &a, &b, 0.0, &mut c_par).unwrap();
            assert_eq!(c_par, c_serial, "threads={nt}");
        }
    }

    #[test]
    fn syrk_matches_gemm_transpose() {
        let mut rng = XorShift::new(23);
        let a = Matrix::randn(20, 6, &mut rng);
        let s = syrk_t(&a);
        let r = naive_gemm(&a.transpose(), &a);
        assert!(s.max_abs_diff(&r) < 1e-10);
        // Symmetry.
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(s.get(i, j), s.get(j, i));
            }
        }
    }

    #[test]
    fn syrk_parallel_is_bit_identical_and_symmetric() {
        // Tall-skinny (the S-loop shape) and wide enough to go parallel.
        let mut rng = XorShift::new(33);
        let a = Matrix::randn(2048, 96, &mut rng);
        let s_serial = {
            let _g = crate::util::threads::with_budget(1);
            syrk_t(&a)
        };
        let s_par = {
            let _g = crate::util::threads::with_budget(4);
            syrk_t(&a)
        };
        assert_eq!(s_par, s_serial);
        for i in 0..96 {
            for j in 0..96 {
                assert_eq!(s_par.get(i, j), s_par.get(j, i));
            }
        }
    }

    #[test]
    fn syrk_degenerate_dims() {
        assert_eq!(syrk_t(&Matrix::zeros(0, 0)).rows(), 0);
        let s = syrk_t(&Matrix::zeros(0, 3));
        assert_eq!((s.rows(), s.cols()), (3, 3));
        assert!(s.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn trsm_matches_trsv_per_column() {
        let mut rng = XorShift::new(24);
        for &(n, nrhs) in &[(1, 1), (5, 3), (33, 7), (64, 64), (100, 17)] {
            let mut l = Matrix::randn(n, n, &mut rng).tril();
            for i in 0..n {
                l.set(i, i, 2.0 + l.get(i, i).abs());
            }
            let b0 = Matrix::randn(n, nrhs, &mut rng);
            let mut b = b0.clone();
            trsm_lower_left(&l, &mut b).unwrap();
            // Residual check: L * X == B0, column by column.
            for j in 0..nrhs {
                let lx = gemv_n(&l, b.col(j)).unwrap();
                for i in 0..n {
                    assert!(
                        (lx[i] - b0.get(i, j)).abs() < 1e-9,
                        "n={n} nrhs={nrhs} col={j} row={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn trsm_parallel_is_bit_identical_to_serial() {
        // 256² × 384 ≈ 25 MFlop — clears the threshold at 2+ workers.
        let mut rng = XorShift::new(34);
        let mut l = Matrix::randn(256, 256, &mut rng).tril();
        for i in 0..256 {
            l.set(i, i, 2.0 + l.get(i, i).abs());
        }
        let b0 = Matrix::randn(256, 384, &mut rng);
        let mut b_serial = b0.clone();
        {
            let _g = crate::util::threads::with_budget(1);
            trsm_lower_left(&l, &mut b_serial).unwrap();
        }
        for nt in [2, 4, 8] {
            let mut b_par = b0.clone();
            let _g = crate::util::threads::with_budget(nt);
            trsm_lower_left(&l, &mut b_par).unwrap();
            assert_eq!(b_par, b_serial, "threads={nt}");
        }
    }

    #[test]
    fn trsm_identity_is_noop() {
        let mut rng = XorShift::new(25);
        let l = Matrix::eye(10);
        let b0 = Matrix::randn(10, 4, &mut rng);
        let mut b = b0.clone();
        trsm_lower_left(&l, &mut b).unwrap();
        assert!(b.max_abs_diff(&b0) < 1e-15);
    }

    #[test]
    fn trsm_zero_diag_error_leaves_b_untouched() {
        let mut rng = XorShift::new(26);
        let mut l = Matrix::eye(4);
        l.set(2, 2, 0.0);
        let b0 = Matrix::randn(4, 2, &mut rng);
        let mut b = b0.clone();
        assert!(trsm_lower_left(&l, &mut b).is_err());
        // The singular diagonal is rejected before any column is modified.
        assert_eq!(b, b0);
    }

    #[test]
    fn trsm_shape_error() {
        let l = Matrix::zeros(4, 3);
        let mut b = Matrix::zeros(4, 1);
        assert!(trsm_lower_left(&l, &mut b).is_err());
    }
}
