//! BLAS-3: the performance-critical kernels. The paper's whole point is
//! that blocked BLAS-3 (`trsm` on the accelerator, `gemm`/`syrk` in the
//! S-loop) beats per-SNP BLAS-2 by an order of magnitude; these native
//! implementations back the CPU baselines and the S-loop lane.
//!
//! `gemm` uses a two-level scheme: an outer cache tiling (MC×KC×NC) and an
//! inner 4×4 register micro-kernel over unit-stride columns. Not MKL, but
//! within a small factor of peak for the sizes the pipeline feeds it — see
//! EXPERIMENTS.md §Perf for measured GFlop/s.
//!
//! §Perf (threading): `gemm`, `trsm` and `syrk_t` fan their NC-wide
//! column panels of B/C out over the compute pool
//! ([`crate::util::threads`]). Panels are independent — every output
//! element is produced by exactly one panel task running the exact
//! serial loop nest — so parallel results are **bit-identical** to the
//! serial path at every thread count, and the paper's multi-threaded
//! BLAS baseline is finally matched on multi-core hosts (the
//! `linalg_micro` bench sweeps 1/2/4/ncpu threads and reports GFlop/s;
//! ≥ 2× at 4 threads on 512³ is the acceptance bar). Small shapes stay
//! on the serial path — [`crate::util::threads::for_flops`] only opens a
//! parallel region when each worker gets ≥ ~1 ms of arithmetic.

use super::matrix::Matrix;
use crate::error::{Error, Result};
use crate::util::threads;

/// Cache-tile sizes for the gemm loop nest (f64 elements).
const MC: usize = 128;
const KC: usize = 256;
/// Column-panel width: the cache tile of the serial loop nest and the
/// unit of parallel work distribution (a multiple of the 4-column
/// micro-kernel, so panel boundaries never split a register block).
const NC: usize = 64;

/// `C += A^T_or_A * B` driver — here the plain `C = alpha*A*B + beta*C`
/// with `A: m×k`, `B: k×n`, all column-major.
pub fn gemm(alpha: f64, a: &Matrix, b: &Matrix, beta: f64, c: &mut Matrix) -> Result<()> {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    if b.rows() != k || c.rows() != m || c.cols() != n {
        return Err(Error::shape(format!(
            "gemm: A {}x{}, B {}x{}, C {}x{}",
            m, k, b.rows(), n, c.rows(), c.cols()
        )));
    }
    if beta != 1.0 {
        for v in c.as_mut_slice() {
            *v *= beta;
        }
    }
    if alpha == 0.0 || m == 0 || n == 0 || k == 0 {
        return Ok(());
    }
    // NC-wide column panels of B/C are independent: distribute them over
    // the pool (1 worker ⇒ plain serial sweep, identical either way).
    let nt = threads::for_flops(2.0 * m as f64 * k as f64 * n as f64);
    let a_data = a.as_slice();
    let b_data = b.as_slice();
    let b_rows = b.rows();
    let c_rows = m;
    let panels: Vec<&mut [f64]> = c.as_mut_slice().chunks_mut(NC * c_rows).collect();
    threads::scatter(nt, panels, || (), |_, pi, panel| {
        let nb = panel.len() / c_rows;
        gemm_panel(alpha, a_data, m, k, b_data, b_rows, pi * NC, panel, c_rows, nb);
        Ok(())
    })
}

/// Serial loop nest over one NC-wide panel: columns `[jc, jc+nb)` of C
/// (`panel` is their contiguous column-major storage).
fn gemm_panel(
    alpha: f64,
    a_data: &[f64],
    m: usize,
    k: usize,
    b_data: &[f64],
    b_rows: usize,
    jc: usize,
    panel: &mut [f64],
    c_rows: usize,
    nb: usize,
) {
    for pc in (0..k).step_by(KC) {
        let kb = KC.min(k - pc);
        for ic in (0..m).step_by(MC) {
            let mb = MC.min(m - ic);
            gemm_block(alpha, a_data, m, b_data, b_rows, jc, panel, c_rows, ic, pc, mb, nb, kb);
        }
    }
}

/// Inner block: panel[ic..ic+mb, 0..nb] += alpha * A[ic.., pc..] * B[pc.., jc..].
/// 4-column × 2-rank register kernel; columns of A, B, C are contiguous
/// so all accesses below are unit-stride. Each loaded A column feeds four
/// output columns and two k-ranks are fused per sweep, which cuts C
/// traffic 2× and A traffic 4× vs the naive axpy form (§Perf: 8.6 →
/// ~11 GFlop/s at 512³ on this machine).
#[inline]
#[allow(clippy::too_many_arguments)]
fn gemm_block(
    alpha: f64,
    a_data: &[f64],
    m: usize,
    b_data: &[f64],
    b_rows: usize,
    jc: usize,
    panel: &mut [f64],
    c_rows: usize,
    ic: usize,
    pc: usize,
    mb: usize,
    nb: usize,
    kb: usize,
) {
    let w_at = |p: usize, j: usize| alpha * b_data[(jc + j) * b_rows + pc + p];
    // 4-column panels of C.
    let mut j = 0;
    while j + 4 <= nb {
        let mut p = 0;
        // Two ranks fused per sweep: C[:,j..j+4] += a_p w_p^T + a_q w_q^T.
        while p + 2 <= kb {
            let a0 = &a_data[(pc + p) * m + ic..(pc + p) * m + ic + mb];
            let a1 = &a_data[(pc + p + 1) * m + ic..(pc + p + 1) * m + ic + mb];
            let (w00, w01, w02, w03) = (w_at(p, j), w_at(p, j + 1), w_at(p, j + 2), w_at(p, j + 3));
            let (w10, w11, w12, w13) =
                (w_at(p + 1, j), w_at(p + 1, j + 1), w_at(p + 1, j + 2), w_at(p + 1, j + 3));
            let o0 = j * c_rows + ic;
            let o1 = (j + 1) * c_rows + ic;
            let o2 = (j + 2) * c_rows + ic;
            let o3 = (j + 3) * c_rows + ic;
            for i in 0..mb {
                let (x, y) = (a0[i], a1[i]);
                panel[o0 + i] += w00 * x + w10 * y;
                panel[o1 + i] += w01 * x + w11 * y;
                panel[o2 + i] += w02 * x + w12 * y;
                panel[o3 + i] += w03 * x + w13 * y;
            }
            p += 2;
        }
        if p < kb {
            let a0 = &a_data[(pc + p) * m + ic..(pc + p) * m + ic + mb];
            let (w0, w1, w2, w3) = (w_at(p, j), w_at(p, j + 1), w_at(p, j + 2), w_at(p, j + 3));
            let o0 = j * c_rows + ic;
            let o1 = (j + 1) * c_rows + ic;
            let o2 = (j + 2) * c_rows + ic;
            let o3 = (j + 3) * c_rows + ic;
            for i in 0..mb {
                let x = a0[i];
                panel[o0 + i] += w0 * x;
                panel[o1 + i] += w1 * x;
                panel[o2 + i] += w2 * x;
                panel[o3 + i] += w3 * x;
            }
        }
        j += 4;
    }
    // Remainder columns: simple axpy sweeps.
    while j < nb {
        for p in 0..kb {
            let acol = &a_data[(pc + p) * m + ic..(pc + p) * m + ic + mb];
            let w = w_at(p, j);
            if w == 0.0 {
                continue;
            }
            let c_off = j * c_rows + ic;
            for i in 0..mb {
                panel[c_off + i] += w * acol[i];
            }
        }
        j += 1;
    }
}

/// `C = A^T A` (the paper's `syrk`, transposed variant: `S_TL = X̃_L^T X̃_L`,
/// `S_BR = X̃_R^T X̃_R`). Returns the full symmetric matrix (both halves
/// filled) because downstream assembly reads both.
///
/// Built on the tiled [`gemm`] kernel (one transpose of the narrow
/// operand, then the full register-blocked sweep — parallel over column
/// panels like every other BLAS-3 call) instead of the old per-entry
/// `dot` double loop. The lower triangle is mirrored onto the upper
/// afterwards so both halves stay bit-identical, which the per-entry
/// version guaranteed by construction.
pub fn syrk_t(a: &Matrix) -> Matrix {
    syrk_t_pretransposed(&a.transpose(), a)
}

/// [`syrk_t`] when the caller already holds `A^T` (e.g. the cached
/// `Preprocessed::xl_tt`) — skips the re-transpose. Panics (via the gemm
/// shape check) if `at` is not the transpose shape of `a`.
pub fn syrk_t_pretransposed(at: &Matrix, a: &Matrix) -> Matrix {
    let k = a.cols();
    let mut c = Matrix::zeros(k, k);
    if k == 0 {
        return c;
    }
    gemm(1.0, at, a, 0.0, &mut c).expect("syrk_t: `at` must be the transpose shape of `a`");
    for j in 0..k {
        for i in (j + 1)..k {
            let v = c.get(i, j);
            c.set(j, i, v);
        }
    }
    c
}

/// Block size for the trsm right-hand-side sweep.
const TRSM_NB: usize = 32;

/// Solve `L X = B` in place over `B` (the paper's `trsm`: left, lower,
/// non-transposed, unit-stride RHS columns). Blocked forward substitution:
/// diagonal-block `trsv`s plus rank-`kb` `gemm` updates, so the bulk of the
/// flops run through the BLAS-3 micro-kernel. RHS columns are solved
/// independently, NC at a time, across the compute pool (each panel runs
/// the exact serial schedule, so results are bit-identical at every
/// thread count). The diagonal is checked up front: a singular `L` errors
/// before any column of `B` is touched.
pub fn trsm_lower_left(l: &Matrix, b: &mut Matrix) -> Result<()> {
    let n = l.rows();
    if l.cols() != n || b.rows() != n {
        return Err(Error::shape(format!(
            "trsm: L {}x{}, B {}x{}",
            l.rows(),
            l.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let nrhs = b.cols();
    if n == 0 || nrhs == 0 {
        return Ok(());
    }
    for row in 0..n {
        if l.get(row, row) == 0.0 {
            return Err(Error::Numerical(format!("trsm: zero diagonal at {row}")));
        }
    }
    let nt = threads::for_flops(n as f64 * n as f64 * nrhs as f64);
    let l_data = l.as_slice();
    let panels: Vec<&mut [f64]> = b.as_mut_slice().chunks_mut(NC * n).collect();
    threads::scatter(nt, panels, || (), |_, _, panel| {
        trsm_panel(l_data, n, panel);
        Ok(())
    })
}

/// Blocked forward substitution over one panel of RHS columns.
fn trsm_panel(l_data: &[f64], n: usize, panel: &mut [f64]) {
    let ncols = panel.len() / n;
    let mut k0 = 0;
    while k0 < n {
        let kb = TRSM_NB.min(n - k0);
        // 1) Solve the diagonal block for this panel's RHS columns:
        //    B[k0..k0+kb, :] ← L[diag]^-1 * same.
        for j in 0..ncols {
            let col = &mut panel[j * n..(j + 1) * n];
            for r in 0..kb {
                let row = k0 + r;
                let mut v = col[row];
                for s in 0..r {
                    v -= l_data[(k0 + s) * n + row] * col[k0 + s];
                }
                col[row] = v / l_data[row * n + row];
            }
        }
        // 2) Update the trailing rows with a gemm:
        //    B[k0+kb.., :] -= L[k0+kb.., k0..k0+kb] * B[diag rows, :]
        let rest = n - k0 - kb;
        if rest > 0 {
            update_trailing(l_data, n, panel, ncols, k0, kb, rest);
        }
        k0 += kb;
    }
}

/// Trailing update of the blocked trsm, written directly over the strided
/// sub-block (avoids materializing sub-matrices). Same 4-column × 2-rank
/// register kernel as `gemm_block` — each loaded L column feeds four RHS
/// columns (§Perf).
#[inline]
fn update_trailing(
    l_data: &[f64],
    n: usize,
    bdata: &mut [f64],
    ncols: usize,
    k0: usize,
    kb: usize,
    rest: usize,
) {
    let row0 = k0 + kb;
    let mut j = 0;
    while j + 4 <= ncols {
        let (o0, o1, o2, o3) = (j * n, (j + 1) * n, (j + 2) * n, (j + 3) * n);
        let mut p = 0;
        while p + 2 <= kb {
            let lc0 = &l_data[(k0 + p) * n + row0..(k0 + p) * n + row0 + rest];
            let lc1 = &l_data[(k0 + p + 1) * n + row0..(k0 + p + 1) * n + row0 + rest];
            let (w00, w01, w02, w03) = (
                bdata[o0 + k0 + p],
                bdata[o1 + k0 + p],
                bdata[o2 + k0 + p],
                bdata[o3 + k0 + p],
            );
            let (w10, w11, w12, w13) = (
                bdata[o0 + k0 + p + 1],
                bdata[o1 + k0 + p + 1],
                bdata[o2 + k0 + p + 1],
                bdata[o3 + k0 + p + 1],
            );
            for i in 0..rest {
                let (x, y) = (lc0[i], lc1[i]);
                bdata[o0 + row0 + i] -= w00 * x + w10 * y;
                bdata[o1 + row0 + i] -= w01 * x + w11 * y;
                bdata[o2 + row0 + i] -= w02 * x + w12 * y;
                bdata[o3 + row0 + i] -= w03 * x + w13 * y;
            }
            p += 2;
        }
        if p < kb {
            let lc = &l_data[(k0 + p) * n + row0..(k0 + p) * n + row0 + rest];
            let (w0, w1, w2, w3) =
                (bdata[o0 + k0 + p], bdata[o1 + k0 + p], bdata[o2 + k0 + p], bdata[o3 + k0 + p]);
            for i in 0..rest {
                let x = lc[i];
                bdata[o0 + row0 + i] -= w0 * x;
                bdata[o1 + row0 + i] -= w1 * x;
                bdata[o2 + row0 + i] -= w2 * x;
                bdata[o3 + row0 + i] -= w3 * x;
            }
        }
        j += 4;
    }
    while j < ncols {
        let off = j * n;
        for p in 0..kb {
            let w = bdata[off + k0 + p];
            if w == 0.0 {
                continue;
            }
            let lcol = &l_data[(k0 + p) * n + row0..(k0 + p) * n + row0 + rest];
            for i in 0..rest {
                bdata[off + row0 + i] -= w * lcol[i];
            }
        }
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas2::gemv_n;
    use crate::util::XorShift;

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for j in 0..b.cols() {
            for i in 0..a.rows() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_over_shapes() {
        let mut rng = XorShift::new(21);
        for &(m, k, n) in
            &[(1, 1, 1), (3, 5, 2), (17, 9, 13), (64, 64, 64), (130, 70, 65), (257, 300, 3)]
        {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let mut c = Matrix::zeros(m, n);
            gemm(1.0, &a, &b, 0.0, &mut c).unwrap();
            let r = naive_gemm(&a, &b);
            assert!(c.max_abs_diff(&r) < 1e-9, "m={m} k={k} n={n}: {}", c.max_abs_diff(&r));
        }
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = XorShift::new(22);
        let a = Matrix::randn(8, 8, &mut rng);
        let b = Matrix::randn(8, 8, &mut rng);
        let c0 = Matrix::randn(8, 8, &mut rng);
        let mut c = c0.clone();
        gemm(2.0, &a, &b, 0.5, &mut c).unwrap();
        let ab = naive_gemm(&a, &b);
        for j in 0..8 {
            for i in 0..8 {
                let want = 2.0 * ab.get(i, j) + 0.5 * c0.get(i, j);
                assert!((c.get(i, j) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn gemm_shape_error() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 2); // should be 3 rows
        let mut c = Matrix::zeros(2, 2);
        assert!(gemm(1.0, &a, &b, 0.0, &mut c).is_err());
    }

    #[test]
    fn gemm_degenerate_dims() {
        let a = Matrix::zeros(0, 0);
        let b = Matrix::zeros(0, 0);
        let mut c = Matrix::zeros(0, 0);
        gemm(1.0, &a, &b, 0.0, &mut c).unwrap();
    }

    #[test]
    fn gemm_parallel_is_bit_identical_to_serial() {
        // Big enough to clear the for_flops threshold (320³ ≈ 65 MFlop).
        let mut rng = XorShift::new(31);
        let a = Matrix::randn(320, 320, &mut rng);
        let b = Matrix::randn(320, 320, &mut rng);
        let mut c_serial = Matrix::zeros(320, 320);
        {
            let _g = crate::util::threads::with_budget(1);
            gemm(1.5, &a, &b, 0.0, &mut c_serial).unwrap();
        }
        for nt in [2, 4, 8] {
            let mut c_par = Matrix::zeros(320, 320);
            let _g = crate::util::threads::with_budget(nt);
            gemm(1.5, &a, &b, 0.0, &mut c_par).unwrap();
            assert_eq!(c_par, c_serial, "threads={nt}");
        }
    }

    #[test]
    fn syrk_matches_gemm_transpose() {
        let mut rng = XorShift::new(23);
        let a = Matrix::randn(20, 6, &mut rng);
        let s = syrk_t(&a);
        let r = naive_gemm(&a.transpose(), &a);
        assert!(s.max_abs_diff(&r) < 1e-10);
        // Symmetry.
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(s.get(i, j), s.get(j, i));
            }
        }
    }

    #[test]
    fn syrk_parallel_is_bit_identical_and_symmetric() {
        // Tall-skinny (the S-loop shape) and wide enough to go parallel.
        let mut rng = XorShift::new(33);
        let a = Matrix::randn(2048, 96, &mut rng);
        let s_serial = {
            let _g = crate::util::threads::with_budget(1);
            syrk_t(&a)
        };
        let s_par = {
            let _g = crate::util::threads::with_budget(4);
            syrk_t(&a)
        };
        assert_eq!(s_par, s_serial);
        for i in 0..96 {
            for j in 0..96 {
                assert_eq!(s_par.get(i, j), s_par.get(j, i));
            }
        }
    }

    #[test]
    fn syrk_degenerate_dims() {
        assert_eq!(syrk_t(&Matrix::zeros(0, 0)).rows(), 0);
        let s = syrk_t(&Matrix::zeros(0, 3));
        assert_eq!((s.rows(), s.cols()), (3, 3));
        assert!(s.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn trsm_matches_trsv_per_column() {
        let mut rng = XorShift::new(24);
        for &(n, nrhs) in &[(1, 1), (5, 3), (33, 7), (64, 64), (100, 17)] {
            let mut l = Matrix::randn(n, n, &mut rng).tril();
            for i in 0..n {
                l.set(i, i, 2.0 + l.get(i, i).abs());
            }
            let b0 = Matrix::randn(n, nrhs, &mut rng);
            let mut b = b0.clone();
            trsm_lower_left(&l, &mut b).unwrap();
            // Residual check: L * X == B0, column by column.
            for j in 0..nrhs {
                let lx = gemv_n(&l, b.col(j)).unwrap();
                for i in 0..n {
                    assert!(
                        (lx[i] - b0.get(i, j)).abs() < 1e-9,
                        "n={n} nrhs={nrhs} col={j} row={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn trsm_parallel_is_bit_identical_to_serial() {
        // 256² × 384 ≈ 25 MFlop — clears the threshold at 2+ workers.
        let mut rng = XorShift::new(34);
        let mut l = Matrix::randn(256, 256, &mut rng).tril();
        for i in 0..256 {
            l.set(i, i, 2.0 + l.get(i, i).abs());
        }
        let b0 = Matrix::randn(256, 384, &mut rng);
        let mut b_serial = b0.clone();
        {
            let _g = crate::util::threads::with_budget(1);
            trsm_lower_left(&l, &mut b_serial).unwrap();
        }
        for nt in [2, 4, 8] {
            let mut b_par = b0.clone();
            let _g = crate::util::threads::with_budget(nt);
            trsm_lower_left(&l, &mut b_par).unwrap();
            assert_eq!(b_par, b_serial, "threads={nt}");
        }
    }

    #[test]
    fn trsm_identity_is_noop() {
        let mut rng = XorShift::new(25);
        let l = Matrix::eye(10);
        let b0 = Matrix::randn(10, 4, &mut rng);
        let mut b = b0.clone();
        trsm_lower_left(&l, &mut b).unwrap();
        assert!(b.max_abs_diff(&b0) < 1e-15);
    }

    #[test]
    fn trsm_zero_diag_error_leaves_b_untouched() {
        let mut rng = XorShift::new(26);
        let mut l = Matrix::eye(4);
        l.set(2, 2, 0.0);
        let b0 = Matrix::randn(4, 2, &mut rng);
        let mut b = b0.clone();
        assert!(trsm_lower_left(&l, &mut b).is_err());
        // The singular diagonal is rejected before any column is modified.
        assert_eq!(b, b0);
    }

    #[test]
    fn trsm_shape_error() {
        let l = Matrix::zeros(4, 3);
        let mut b = Matrix::zeros(4, 1);
        assert!(trsm_lower_left(&l, &mut b).is_err());
    }
}
