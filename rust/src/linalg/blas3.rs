//! BLAS-3: the performance-critical kernels. The paper's whole point is
//! that blocked BLAS-3 (`trsm` on the accelerator, `gemm`/`syrk` in the
//! S-loop) beats per-SNP BLAS-2 by an order of magnitude; these native
//! implementations back the CPU baselines and the S-loop lane.
//!
//! `gemm` uses a two-level scheme: an outer cache tiling (MC×KC×NC) and an
//! inner 4×4 register micro-kernel over unit-stride columns. Not MKL, but
//! within a small factor of peak for the sizes the pipeline feeds it — see
//! EXPERIMENTS.md §Perf for measured GFlop/s.

use super::matrix::Matrix;
use crate::error::{Error, Result};

/// Cache-tile sizes for the gemm loop nest (f64 elements).
const MC: usize = 128;
const KC: usize = 256;
const NC: usize = 64;

/// `C += A^T_or_A * B` driver — here the plain `C = alpha*A*B + beta*C`
/// with `A: m×k`, `B: k×n`, all column-major.
pub fn gemm(alpha: f64, a: &Matrix, b: &Matrix, beta: f64, c: &mut Matrix) -> Result<()> {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    if b.rows() != k || c.rows() != m || c.cols() != n {
        return Err(Error::shape(format!(
            "gemm: A {}x{}, B {}x{}, C {}x{}",
            m, k, b.rows(), n, c.rows(), c.cols()
        )));
    }
    if beta != 1.0 {
        for v in c.as_mut_slice() {
            *v *= beta;
        }
    }
    if alpha == 0.0 || m == 0 || n == 0 || k == 0 {
        return Ok(());
    }
    // Cache-tiled loop nest; micro-kernel works on raw slices.
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mb = MC.min(m - ic);
                gemm_block(alpha, a, b, c, ic, jc, pc, mb, nb, kb);
            }
        }
    }
    Ok(())
}

/// Inner block: C[ic..ic+mb, jc..jc+nb] += alpha * A[ic.., pc..] * B[pc.., jc..].
/// 4-column × 2-rank register kernel; columns of A, B, C are contiguous
/// so all accesses below are unit-stride. Each loaded A column feeds four
/// output columns and two k-ranks are fused per sweep, which cuts C
/// traffic 2× and A traffic 4× vs the naive axpy form (§Perf: 8.6 →
/// ~11 GFlop/s at 512³ on this machine).
#[inline]
fn gemm_block(
    alpha: f64,
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
    ic: usize,
    jc: usize,
    pc: usize,
    mb: usize,
    nb: usize,
    kb: usize,
) {
    let m = a.rows();
    let a_data = a.as_slice();
    let b_data = b.as_slice();
    let b_rows = b.rows();
    let c_rows = c.rows();
    let w_at = |p: usize, j: usize| alpha * b_data[(jc + j) * b_rows + pc + p];
    // 4-column panels of C.
    let mut j = 0;
    while j + 4 <= nb {
        let mut p = 0;
        // Two ranks fused per sweep: C[:,j..j+4] += a_p w_p^T + a_q w_q^T.
        while p + 2 <= kb {
            let a0 = &a_data[(pc + p) * m + ic..(pc + p) * m + ic + mb];
            let a1 = &a_data[(pc + p + 1) * m + ic..(pc + p + 1) * m + ic + mb];
            let (w00, w01, w02, w03) = (w_at(p, j), w_at(p, j + 1), w_at(p, j + 2), w_at(p, j + 3));
            let (w10, w11, w12, w13) =
                (w_at(p + 1, j), w_at(p + 1, j + 1), w_at(p + 1, j + 2), w_at(p + 1, j + 3));
            let cdata = c.as_mut_slice();
            let o0 = (jc + j) * c_rows + ic;
            let o1 = (jc + j + 1) * c_rows + ic;
            let o2 = (jc + j + 2) * c_rows + ic;
            let o3 = (jc + j + 3) * c_rows + ic;
            for i in 0..mb {
                let (x, y) = (a0[i], a1[i]);
                cdata[o0 + i] += w00 * x + w10 * y;
                cdata[o1 + i] += w01 * x + w11 * y;
                cdata[o2 + i] += w02 * x + w12 * y;
                cdata[o3 + i] += w03 * x + w13 * y;
            }
            p += 2;
        }
        if p < kb {
            let a0 = &a_data[(pc + p) * m + ic..(pc + p) * m + ic + mb];
            let (w0, w1, w2, w3) = (w_at(p, j), w_at(p, j + 1), w_at(p, j + 2), w_at(p, j + 3));
            let cdata = c.as_mut_slice();
            let o0 = (jc + j) * c_rows + ic;
            let o1 = (jc + j + 1) * c_rows + ic;
            let o2 = (jc + j + 2) * c_rows + ic;
            let o3 = (jc + j + 3) * c_rows + ic;
            for i in 0..mb {
                let x = a0[i];
                cdata[o0 + i] += w0 * x;
                cdata[o1 + i] += w1 * x;
                cdata[o2 + i] += w2 * x;
                cdata[o3 + i] += w3 * x;
            }
        }
        j += 4;
    }
    // Remainder columns: simple axpy sweeps.
    while j < nb {
        for p in 0..kb {
            let acol = &a_data[(pc + p) * m + ic..(pc + p) * m + ic + mb];
            let w = w_at(p, j);
            if w == 0.0 {
                continue;
            }
            let cdata = c.as_mut_slice();
            let c_off = (jc + j) * c_rows + ic;
            for i in 0..mb {
                cdata[c_off + i] += w * acol[i];
            }
        }
        j += 1;
    }
}

/// `C = A^T A` (the paper's `syrk`, transposed variant: `S_TL = X̃_L^T X̃_L`,
/// `S_BR = X̃_R^T X̃_R`). Returns the full symmetric matrix (both halves
/// filled) because downstream assembly reads both.
pub fn syrk_t(a: &Matrix) -> Matrix {
    let k = a.cols();
    let mut c = Matrix::zeros(k, k);
    for j in 0..k {
        let cj = a.col(j);
        for i in j..k {
            let v = super::blas1::dot(a.col(i), cj);
            c.set(i, j, v);
            c.set(j, i, v);
        }
    }
    c
}

/// Block size for the trsm right-hand-side sweep.
const TRSM_NB: usize = 32;

/// Solve `L X = B` in place over `B` (the paper's `trsm`: left, lower,
/// non-transposed, unit-stride RHS columns). Blocked forward substitution:
/// diagonal-block `trsv`s plus rank-`kb` `gemm` updates, so the bulk of the
/// flops run through the BLAS-3 micro-kernel.
pub fn trsm_lower_left(l: &Matrix, b: &mut Matrix) -> Result<()> {
    let n = l.rows();
    if l.cols() != n || b.rows() != n {
        return Err(Error::shape(format!(
            "trsm: L {}x{}, B {}x{}",
            l.rows(), l.cols(), b.rows(), b.cols()
        )));
    }
    let nrhs = b.cols();
    if nrhs == 0 {
        return Ok(());
    }
    let nb = TRSM_NB;
    let mut kb_start = 0;
    while kb_start < n {
        let kb = nb.min(n - kb_start);
        // 1) Solve the diagonal block for all RHS columns:
        //    B[kb_start..kb_start+kb, :] ← L[diag]^-1 * same.
        for j in 0..nrhs {
            let col = b.col_mut(j);
            for r in 0..kb {
                let row = kb_start + r;
                let lrr = l.get(row, row);
                if lrr == 0.0 {
                    return Err(Error::Numerical(format!("trsm: zero diagonal at {row}")));
                }
                let mut v = col[row];
                for s in 0..r {
                    v -= l.get(row, kb_start + s) * col[kb_start + s];
                }
                col[row] = v / lrr;
            }
        }
        // 2) Update the trailing rows with a gemm:
        //    B[kb_start+kb.., :] -= L[kb_start+kb.., kb_start..kb_start+kb] * B[diag rows, :]
        let rest = n - kb_start - kb;
        if rest > 0 {
            update_trailing(l, b, kb_start, kb, rest);
        }
        kb_start += kb;
    }
    Ok(())
}

/// Trailing update of the blocked trsm, written directly over the strided
/// sub-block (avoids materializing sub-matrices). Same 4-column × 2-rank
/// register kernel as `gemm_block` — each loaded L column feeds four RHS
/// columns (§Perf).
#[inline]
fn update_trailing(l: &Matrix, b: &mut Matrix, k0: usize, kb: usize, rest: usize) {
    let n = l.rows();
    let l_data = l.as_slice();
    let row0 = k0 + kb;
    let b_rows = b.rows();
    let ncols = b.cols();
    let bdata = b.as_mut_slice();
    let mut j = 0;
    while j + 4 <= ncols {
        let (o0, o1, o2, o3) =
            (j * b_rows, (j + 1) * b_rows, (j + 2) * b_rows, (j + 3) * b_rows);
        let mut p = 0;
        while p + 2 <= kb {
            let lc0 = &l_data[(k0 + p) * n + row0..(k0 + p) * n + row0 + rest];
            let lc1 = &l_data[(k0 + p + 1) * n + row0..(k0 + p + 1) * n + row0 + rest];
            let (w00, w01, w02, w03) = (
                bdata[o0 + k0 + p],
                bdata[o1 + k0 + p],
                bdata[o2 + k0 + p],
                bdata[o3 + k0 + p],
            );
            let (w10, w11, w12, w13) = (
                bdata[o0 + k0 + p + 1],
                bdata[o1 + k0 + p + 1],
                bdata[o2 + k0 + p + 1],
                bdata[o3 + k0 + p + 1],
            );
            for i in 0..rest {
                let (x, y) = (lc0[i], lc1[i]);
                bdata[o0 + row0 + i] -= w00 * x + w10 * y;
                bdata[o1 + row0 + i] -= w01 * x + w11 * y;
                bdata[o2 + row0 + i] -= w02 * x + w12 * y;
                bdata[o3 + row0 + i] -= w03 * x + w13 * y;
            }
            p += 2;
        }
        if p < kb {
            let lc = &l_data[(k0 + p) * n + row0..(k0 + p) * n + row0 + rest];
            let (w0, w1, w2, w3) =
                (bdata[o0 + k0 + p], bdata[o1 + k0 + p], bdata[o2 + k0 + p], bdata[o3 + k0 + p]);
            for i in 0..rest {
                let x = lc[i];
                bdata[o0 + row0 + i] -= w0 * x;
                bdata[o1 + row0 + i] -= w1 * x;
                bdata[o2 + row0 + i] -= w2 * x;
                bdata[o3 + row0 + i] -= w3 * x;
            }
        }
        j += 4;
    }
    while j < ncols {
        let off = j * b_rows;
        for p in 0..kb {
            let w = bdata[off + k0 + p];
            if w == 0.0 {
                continue;
            }
            let lcol = &l_data[(k0 + p) * n + row0..(k0 + p) * n + row0 + rest];
            for i in 0..rest {
                bdata[off + row0 + i] -= w * lcol[i];
            }
        }
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas2::gemv_n;
    use crate::util::XorShift;

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for j in 0..b.cols() {
            for i in 0..a.rows() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive_over_shapes() {
        let mut rng = XorShift::new(21);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 9, 13), (64, 64, 64), (130, 70, 65), (257, 300, 3)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let mut c = Matrix::zeros(m, n);
            gemm(1.0, &a, &b, 0.0, &mut c).unwrap();
            let r = naive_gemm(&a, &b);
            assert!(c.max_abs_diff(&r) < 1e-9, "m={m} k={k} n={n}: {}", c.max_abs_diff(&r));
        }
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = XorShift::new(22);
        let a = Matrix::randn(8, 8, &mut rng);
        let b = Matrix::randn(8, 8, &mut rng);
        let c0 = Matrix::randn(8, 8, &mut rng);
        let mut c = c0.clone();
        gemm(2.0, &a, &b, 0.5, &mut c).unwrap();
        let ab = naive_gemm(&a, &b);
        for j in 0..8 {
            for i in 0..8 {
                let want = 2.0 * ab.get(i, j) + 0.5 * c0.get(i, j);
                assert!((c.get(i, j) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn gemm_shape_error() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 2); // should be 3 rows
        let mut c = Matrix::zeros(2, 2);
        assert!(gemm(1.0, &a, &b, 0.0, &mut c).is_err());
    }

    #[test]
    fn gemm_degenerate_dims() {
        let a = Matrix::zeros(0, 0);
        let b = Matrix::zeros(0, 0);
        let mut c = Matrix::zeros(0, 0);
        gemm(1.0, &a, &b, 0.0, &mut c).unwrap();
    }

    #[test]
    fn syrk_matches_gemm_transpose() {
        let mut rng = XorShift::new(23);
        let a = Matrix::randn(20, 6, &mut rng);
        let s = syrk_t(&a);
        let r = naive_gemm(&a.transpose(), &a);
        assert!(s.max_abs_diff(&r) < 1e-10);
        // Symmetry.
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(s.get(i, j), s.get(j, i));
            }
        }
    }

    #[test]
    fn trsm_matches_trsv_per_column() {
        let mut rng = XorShift::new(24);
        for &(n, nrhs) in &[(1, 1), (5, 3), (33, 7), (64, 64), (100, 17)] {
            let mut l = Matrix::randn(n, n, &mut rng).tril();
            for i in 0..n {
                l.set(i, i, 2.0 + l.get(i, i).abs());
            }
            let b0 = Matrix::randn(n, nrhs, &mut rng);
            let mut b = b0.clone();
            trsm_lower_left(&l, &mut b).unwrap();
            // Residual check: L * X == B0, column by column.
            for j in 0..nrhs {
                let lx = gemv_n(&l, b.col(j)).unwrap();
                for i in 0..n {
                    assert!(
                        (lx[i] - b0.get(i, j)).abs() < 1e-9,
                        "n={n} nrhs={nrhs} col={j} row={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn trsm_identity_is_noop() {
        let mut rng = XorShift::new(25);
        let l = Matrix::eye(10);
        let b0 = Matrix::randn(10, 4, &mut rng);
        let mut b = b0.clone();
        trsm_lower_left(&l, &mut b).unwrap();
        assert!(b.max_abs_diff(&b0) < 1e-15);
    }

    #[test]
    fn trsm_zero_diag_error() {
        let mut l = Matrix::eye(4);
        l.set(2, 2, 0.0);
        let mut b = Matrix::zeros(4, 1);
        assert!(trsm_lower_left(&l, &mut b).is_err());
    }

    #[test]
    fn trsm_shape_error() {
        let l = Matrix::zeros(4, 3);
        let mut b = Matrix::zeros(4, 1);
        assert!(trsm_lower_left(&l, &mut b).is_err());
    }
}
