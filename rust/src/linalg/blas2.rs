//! BLAS-2: matrix-vector kernels. `trsv` (preprocessing of `y`) and the
//! `gemv`s of the S-loop live here.

use super::blas1::{axpy, dot};
use super::matrix::Matrix;
use crate::error::{Error, Result};

/// `y = A x` (no transpose). Column-sweep formulation: each column of `A`
/// is contiguous, so the inner loop is an `axpy` over a unit-stride slice.
pub fn gemv_n(a: &Matrix, x: &[f64]) -> Result<Vec<f64>> {
    if a.cols() != x.len() {
        return Err(Error::shape(format!("gemv_n: A is {}x{}, x has {}", a.rows(), a.cols(), x.len())));
    }
    let mut y = vec![0.0; a.rows()];
    for (j, &xj) in x.iter().enumerate() {
        if xj != 0.0 {
            axpy(xj, a.col(j), &mut y);
        }
    }
    Ok(y)
}

/// `y = A^T x`. Row of `A^T` = column of `A` ⇒ each output element is a
/// unit-stride `dot`.
pub fn gemv_t(a: &Matrix, x: &[f64]) -> Result<Vec<f64>> {
    if a.rows() != x.len() {
        return Err(Error::shape(format!("gemv_t: A is {}x{}, x has {}", a.rows(), a.cols(), x.len())));
    }
    Ok((0..a.cols()).map(|j| dot(a.col(j), x)).collect())
}

/// Solve `L z = b` in place for lower-triangular `L` (the paper's `trsv`).
/// Forward substitution, column-oriented so updates stream through
/// contiguous memory.
pub fn trsv_lower(l: &Matrix, b: &mut [f64]) -> Result<()> {
    let n = l.rows();
    if l.cols() != n || b.len() != n {
        return Err(Error::shape(format!("trsv_lower: L is {}x{}, b has {}", l.rows(), l.cols(), b.len())));
    }
    for j in 0..n {
        let ljj = l.get(j, j);
        if ljj == 0.0 {
            return Err(Error::Numerical(format!("trsv: zero diagonal at {j}")));
        }
        b[j] /= ljj;
        let bj = b[j];
        let col = l.col(j);
        // b[j+1..] -= bj * L[j+1.., j]
        for i in j + 1..n {
            b[i] -= bj * col[i];
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn gemv_n_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let y = gemv_n(&a, &[1.0, -1.0]).unwrap();
        assert_eq!(y, vec![-1.0, -1.0, -1.0]);
    }

    #[test]
    fn gemv_t_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let y = gemv_t(&a, &[1.0, 1.0]).unwrap();
        assert_eq!(y, vec![4.0, 6.0]);
    }

    #[test]
    fn gemv_shape_errors() {
        let a = Matrix::zeros(3, 2);
        assert!(gemv_n(&a, &[0.0; 3]).is_err());
        assert!(gemv_t(&a, &[0.0; 2]).is_err());
    }

    #[test]
    fn gemv_t_is_transpose_of_gemv_n() {
        let mut rng = XorShift::new(5);
        let a = Matrix::randn(7, 4, &mut rng);
        let x: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        let direct = gemv_t(&a, &x).unwrap();
        let via_t = gemv_n(&a.transpose(), &x).unwrap();
        for (d, v) in direct.iter().zip(&via_t) {
            assert!((d - v).abs() < 1e-12);
        }
    }

    #[test]
    fn trsv_solves_lower_system() {
        // L = [[2,0],[1,3]], b = [4, 7] → z = [2, 5/3]
        let l = Matrix::from_rows(&[&[2.0, 0.0], &[1.0, 3.0]]);
        let mut b = vec![4.0, 7.0];
        trsv_lower(&l, &mut b).unwrap();
        assert!((b[0] - 2.0).abs() < 1e-15);
        assert!((b[1] - 5.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn trsv_residual_random() {
        let mut rng = XorShift::new(9);
        let n = 32;
        // Well-conditioned lower-triangular matrix.
        let mut l = Matrix::randn(n, n, &mut rng).tril();
        for i in 0..n {
            l.set(i, i, 2.0 + l.get(i, i).abs());
        }
        let b0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut z = b0.clone();
        trsv_lower(&l, &mut z).unwrap();
        // Check L z == b0.
        let lz = gemv_n(&l, &z).unwrap();
        for (a, b) in lz.iter().zip(&b0) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
    }

    #[test]
    fn trsv_zero_diag_is_error() {
        let l = Matrix::from_rows(&[&[0.0, 0.0], &[1.0, 1.0]]);
        let mut b = vec![1.0, 1.0];
        assert!(trsv_lower(&l, &mut b).is_err());
    }
}
