//! Structured tracing: a bounded ring of completed spans, exportable as
//! Chrome trace-event JSON.
//!
//! Spans are recorded at the pipeline's *existing* `Instant::now()`
//! timing points (segment loop, device lanes, aio workers, scheduler) —
//! tracing observes durations the code already measures, it never adds
//! its own synchronization to the compute path. The ring is fixed-size:
//! when full, the oldest spans are overwritten, so a long `serve`
//! session keeps the most recent window of activity and memory stays
//! bounded.
//!
//! The export is the Chrome trace-event format (`ph: "X"` complete
//! events, microsecond timestamps), which Perfetto and `chrome://tracing`
//! load directly — the paper's Fig. 3 lane timeline, rendered from a
//! live run. Track layout (`tid`): 0 = the coordinator thread,
//! `1 + lane` = device lanes ([`TID_LANE0`]), [`TID_AIO`] = the aio
//! workers, [`TID_SCHED`] = the service scheduler.

use crate::error::{Error, Result};
use crate::util::json::escape_into;
use std::fmt::Write as _;
use std::io::Write as _;
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// `tid` of the coordinator (segment-loop) spans.
pub const TID_COORD: u32 = 0;
/// `tid` of device lane `i` is `TID_LANE0 + i`.
pub const TID_LANE0: u32 = 1;
/// `tid` of aio worker spans (reads and writes).
pub const TID_AIO: u32 = 64;
/// `tid` of service scheduler spans (job lifecycles).
pub const TID_SCHED: u32 = 65;

/// Ring capacity in spans (~3 MB resident when full).
pub const CAPACITY: usize = 1 << 16;

/// One completed span. `args` carries up to two id pairs (block /
/// lane / column ids); keys are `""` past `nargs`.
#[derive(Debug, Clone, Copy)]
pub struct SpanRec {
    pub name: &'static str,
    pub cat: &'static str,
    pub tid: u32,
    /// Start, µs since the sink's epoch.
    pub ts_us: u64,
    pub dur_us: u64,
    pub args: [(&'static str, u64); 2],
    pub nargs: u8,
}

struct Ring {
    spans: Vec<SpanRec>,
    /// Next write slot once the ring has wrapped.
    next: usize,
    wrapped: bool,
}

/// A bounded span sink. The global one behind `--trace-out` lives in
/// [`global_trace`]; tests construct their own.
pub struct TraceSink {
    epoch: Instant,
    cap: usize,
    ring: Mutex<Ring>,
}

impl Default for TraceSink {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceSink {
    pub fn new() -> TraceSink {
        TraceSink::with_capacity(CAPACITY)
    }

    pub fn with_capacity(cap: usize) -> TraceSink {
        TraceSink {
            epoch: Instant::now(),
            cap: cap.max(1),
            ring: Mutex::new(Ring { spans: Vec::new(), next: 0, wrapped: false }),
        }
    }

    /// Record one completed span that ran `[start, start + dur)`.
    pub fn record(
        &self,
        name: &'static str,
        cat: &'static str,
        tid: u32,
        start: Instant,
        dur: Duration,
        args: &[(&'static str, u64)],
    ) {
        let ts_us = start.checked_duration_since(self.epoch).unwrap_or_default().as_micros() as u64;
        let mut a = [("", 0u64); 2];
        let nargs = args.len().min(2);
        a[..nargs].copy_from_slice(&args[..nargs]);
        let rec = SpanRec {
            name,
            cat,
            tid,
            ts_us,
            dur_us: dur.as_micros() as u64,
            args: a,
            nargs: nargs as u8,
        };
        let mut g = self.ring.lock().unwrap();
        if g.spans.len() < self.cap {
            g.spans.push(rec);
        } else {
            let slot = g.next;
            g.spans[slot] = rec;
            g.next = (slot + 1) % self.cap;
            g.wrapped = true;
        }
    }

    /// Spans recorded and retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Retained spans, oldest first.
    pub fn snapshot(&self) -> Vec<SpanRec> {
        let g = self.ring.lock().unwrap();
        if g.wrapped {
            let mut out = Vec::with_capacity(g.spans.len());
            out.extend_from_slice(&g.spans[g.next..]);
            out.extend_from_slice(&g.spans[..g.next]);
            out
        } else {
            g.spans.clone()
        }
    }

    /// Render the retained spans as Chrome trace-event JSON.
    pub fn chrome_json(&self) -> String {
        let spans = self.snapshot();
        let mut o = String::with_capacity(spans.len() * 96 + 64);
        o.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        for (i, s) in spans.iter().enumerate() {
            if i > 0 {
                o.push(',');
            }
            o.push_str("{\"name\":\"");
            escape_into(&mut o, s.name);
            o.push_str("\",\"cat\":\"");
            escape_into(&mut o, s.cat);
            let _ = write!(
                o,
                "\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{},\"dur\":{}",
                s.tid, s.ts_us, s.dur_us
            );
            if s.nargs > 0 {
                o.push_str(",\"args\":{");
                for (j, (k, v)) in s.args[..s.nargs as usize].iter().enumerate() {
                    if j > 0 {
                        o.push(',');
                    }
                    o.push('"');
                    escape_into(&mut o, k);
                    let _ = write!(o, "\":{v}");
                }
                o.push('}');
            }
            o.push('}');
        }
        o.push_str("]}");
        o
    }

    /// Write the Chrome trace JSON to `path`.
    pub fn export_chrome(&self, path: &std::path::Path) -> Result<()> {
        let json = self.chrome_json();
        let mut f = std::fs::File::create(path)
            .map_err(|e| Error::io(format!("creating trace file {}", path.display()), e))?;
        f.write_all(json.as_bytes())
            .map_err(|e| Error::io(format!("writing trace file {}", path.display()), e))?;
        Ok(())
    }
}

static GLOBAL: OnceLock<TraceSink> = OnceLock::new();

/// The process-wide sink behind `--trace-out`. First touch pins the
/// trace epoch; the disabled fast path never touches it.
pub fn global_trace() -> &'static TraceSink {
    GLOBAL.get_or_init(TraceSink::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_renders_chrome_events() {
        let t = TraceSink::new();
        let t0 = t.epoch;
        t.record("read", "io", TID_AIO, t0, Duration::from_micros(120), &[("col0", 64)]);
        t.record(
            "compute",
            "lane",
            TID_LANE0,
            t0 + Duration::from_micros(5),
            Duration::from_micros(40),
            &[("block", 0), ("lane", 0)],
        );
        assert_eq!(t.len(), 2);
        let json = t.chrome_json();
        assert!(json.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["), "{json}");
        assert!(json.contains("\"name\":\"read\""), "{json}");
        assert!(json.contains("\"ph\":\"X\""), "{json}");
        assert!(json.contains("\"dur\":120"), "{json}");
        assert!(json.contains("\"args\":{\"col0\":64}"), "{json}");
        assert!(json.contains("\"args\":{\"block\":0,\"lane\":0}"), "{json}");
        assert!(json.ends_with("]}"), "{json}");
    }

    #[test]
    fn ring_overwrites_oldest_when_full() {
        let t = TraceSink::with_capacity(4);
        let t0 = t.epoch;
        for i in 0..6u64 {
            t.record("s", "test", 0, t0 + Duration::from_micros(i), Duration::ZERO, &[("i", i)]);
        }
        assert_eq!(t.len(), 4);
        let snap = t.snapshot();
        let ids: Vec<u64> = snap.iter().map(|s| s.args[0].1).collect();
        assert_eq!(ids, vec![2, 3, 4, 5], "oldest spans evicted, order kept");
    }

    #[test]
    fn spans_before_the_epoch_clamp_to_zero() {
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        let t = TraceSink::new();
        t.record("early", "test", 0, t0, Duration::from_micros(1), &[]);
        assert_eq!(t.snapshot()[0].ts_us, 0);
    }
}
