//! The metrics registry: a fixed catalog of atomic counters, gauges and
//! log-bucketed latency histograms with Prometheus text exposition.
//!
//! The catalog is a plain struct, not a dynamic map: every series the
//! pipeline exports is known at compile time, so recording is a couple
//! of relaxed atomic ops (no locks, no allocation, no hashing) and the
//! exposition renders pure registry state — a scrape never calls back
//! into the live pipeline. Values are *pushed* by the code that already
//! owns the accounting: [`Metrics`](crate::coordinator::Metrics) feeds
//! the phase histograms, the scheduler pushes queue/budget/cache state
//! at every dispatch turn, and the engine pushes slab circulation and
//! stall verdicts at segment boundaries.

use crate::coordinator::metrics::Phase;
use crate::storage::{CacheStats, SlabStats};
use crate::telemetry::stall::{StallKind, StallVerdict};
use std::fmt::Write;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Duration;

/// Histogram bucket upper bounds in seconds: powers of 4 from 1 µs to
/// ~67 s. Log-spaced so one layout covers a 4 µs cache hit and a
/// minute-long job wall time; anything beyond the last bound lands in
/// `+Inf` only.
pub const BUCKET_BOUNDS: [f64; 14] = [
    0.000001, 0.000004, 0.000016, 0.000064, 0.000256, 0.001024, 0.004096, 0.016384, 0.065536,
    0.262144, 1.048576, 4.194304, 16.777216, 67.108864,
];

/// Most device lanes the per-lane gauges track (the knob space tops out
/// far below this; extra lanes are simply not exported).
pub const MAX_LANES: usize = 16;

/// A monotone counter (integer).
#[derive(Default)]
pub struct CounterCell(AtomicU64);

impl CounterCell {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite with an absolute value — used to mirror accounting that
    /// is already cumulative at its source (e.g. [`CacheStats::hits`]).
    pub fn set(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge (f64, stored as bits in an `AtomicU64`).
#[derive(Default)]
pub struct GaugeCell(AtomicU64);

impl GaugeCell {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Log-bucketed latency histogram over [`BUCKET_BOUNDS`].
pub struct Histogram {
    buckets: [AtomicU64; BUCKET_BOUNDS.len()],
    sum_nanos: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_nanos: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, d: Duration) {
        let secs = d.as_secs_f64();
        for (i, b) in BUCKET_BOUNDS.iter().enumerate() {
            if secs <= *b {
                self.buckets[i].fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
        self.sum_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_secs(&self) -> f64 {
        self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Cumulative bucket counts in bound order (the `+Inf` bucket is
    /// [`Histogram::count`]). Monotone by construction.
    pub fn cumulative(&self) -> [u64; BUCKET_BOUNDS.len()] {
        let mut acc = 0u64;
        std::array::from_fn(|i| {
            acc += self.buckets[i].load(Ordering::Relaxed);
            acc
        })
    }

    /// Render the Prometheus `_bucket`/`_sum`/`_count` lines. `labels`
    /// is an extra label set like `phase="read_wait"` (or empty).
    fn render_into(&self, out: &mut String, name: &str, labels: &str) {
        let sep = if labels.is_empty() { "" } else { "," };
        for (i, cum) in self.cumulative().iter().enumerate() {
            let _ =
                writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{}\"}} {cum}", BUCKET_BOUNDS[i]);
        }
        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}", self.count());
        let braces = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
        let _ = writeln!(out, "{name}_sum{braces} {}", self.sum_secs());
        let _ = writeln!(out, "{name}_count{braces} {}", self.count());
    }
}

/// The full metric catalog (see module docs for who pushes what).
pub struct Registry {
    phase: Vec<Histogram>,
    pub job_wall_seconds: Histogram,
    pub bytes_copied_total: CounterCell,
    pub bytes_borrowed_total: CounterCell,
    pub snps_total: CounterCell,
    pub blocks_total: CounterCell,
    pub replans_total: CounterCell,
    pub jobs_done_total: CounterCell,
    pub jobs_failed_total: CounterCell,
    pub jobs_coalesced_total: CounterCell,
    pub snps_per_sec: GaugeCell,
    pub traits_width: GaugeCell,
    pub queue_depth: GaugeCell,
    pub jobs_inflight: GaugeCell,
    pub mem_in_use_bytes: GaugeCell,
    pub mem_budget_bytes: GaugeCell,
    pub cache_hits_total: CounterCell,
    pub cache_misses_total: CounterCell,
    pub cache_insertions_total: CounterCell,
    pub cache_evictions_total: CounterCell,
    pub cache_resident_bytes: GaugeCell,
    pub cache_entries: GaugeCell,
    pub cache_capacity_bytes: GaugeCell,
    pub slab_minted_total: CounterCell,
    pub slab_recycled_total: CounterCell,
    pub slab_dropped_total: CounterCell,
    pub slab_free: GaugeCell,
    pub slab_target: GaugeCell,
    pub faults_injected_total: CounterCell,
    pub read_retries_total: CounterCell,
    pub lane_respawns_total: CounterCell,
    pub job_retries_total: CounterCell,
    pub wal_replays_total: CounterCell,
    pub jobs_resumed_total: CounterCell,
    pub jobs_cancelled_total: CounterCell,
    pub drains_total: CounterCell,
    pub disk_low_water_total: CounterCell,
    stall_total: [CounterCell; StallKind::ALL.len()],
    pub stall_share: GaugeCell,
    lane_outstanding: [GaugeCell; MAX_LANES],
    lanes_seen: AtomicUsize,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            phase: Phase::ALL.iter().map(|_| Histogram::default()).collect(),
            job_wall_seconds: Histogram::default(),
            bytes_copied_total: CounterCell::default(),
            bytes_borrowed_total: CounterCell::default(),
            snps_total: CounterCell::default(),
            blocks_total: CounterCell::default(),
            replans_total: CounterCell::default(),
            jobs_done_total: CounterCell::default(),
            jobs_failed_total: CounterCell::default(),
            jobs_coalesced_total: CounterCell::default(),
            snps_per_sec: GaugeCell::default(),
            traits_width: GaugeCell::default(),
            queue_depth: GaugeCell::default(),
            jobs_inflight: GaugeCell::default(),
            mem_in_use_bytes: GaugeCell::default(),
            mem_budget_bytes: GaugeCell::default(),
            cache_hits_total: CounterCell::default(),
            cache_misses_total: CounterCell::default(),
            cache_insertions_total: CounterCell::default(),
            cache_evictions_total: CounterCell::default(),
            cache_resident_bytes: GaugeCell::default(),
            cache_entries: GaugeCell::default(),
            cache_capacity_bytes: GaugeCell::default(),
            slab_minted_total: CounterCell::default(),
            slab_recycled_total: CounterCell::default(),
            slab_dropped_total: CounterCell::default(),
            slab_free: GaugeCell::default(),
            slab_target: GaugeCell::default(),
            faults_injected_total: CounterCell::default(),
            read_retries_total: CounterCell::default(),
            lane_respawns_total: CounterCell::default(),
            job_retries_total: CounterCell::default(),
            wal_replays_total: CounterCell::default(),
            jobs_resumed_total: CounterCell::default(),
            jobs_cancelled_total: CounterCell::default(),
            drains_total: CounterCell::default(),
            disk_low_water_total: CounterCell::default(),
            stall_total: std::array::from_fn(|_| CounterCell::default()),
            stall_share: GaugeCell::default(),
            lane_outstanding: std::array::from_fn(|_| GaugeCell::default()),
            lanes_seen: AtomicUsize::new(0),
        }
    }

    /// Feed one duration into the histogram of the phase at `idx` (the
    /// position in [`Phase::ALL`] — see [`Phase::index`]).
    pub fn observe_phase(&self, idx: usize, d: Duration) {
        if let Some(h) = self.phase.get(idx) {
            h.observe(d);
        }
    }

    /// The histogram backing phase `idx` (test/inspection access).
    pub fn phase_hist(&self, idx: usize) -> &Histogram {
        &self.phase[idx]
    }

    pub fn set_lane_outstanding(&self, lane: usize, depth: usize) {
        if lane < MAX_LANES {
            self.lane_outstanding[lane].set(depth as f64);
            self.lanes_seen.fetch_max(lane + 1, Ordering::Relaxed);
        }
    }

    /// Count one per-segment stall verdict and remember its share.
    pub fn record_stall(&self, v: StallVerdict) {
        self.stall_total[v.kind.index()].add(1);
        self.stall_share.set(v.share);
    }

    pub fn stall_count(&self, kind: StallKind) -> u64 {
        self.stall_total[kind.index()].get()
    }

    /// Mirror the shared block cache's cumulative accounting.
    pub fn set_cache(&self, s: &CacheStats) {
        self.cache_hits_total.set(s.hits);
        self.cache_misses_total.set(s.misses);
        self.cache_insertions_total.set(s.insertions);
        self.cache_evictions_total.set(s.evictions);
        self.cache_resident_bytes.set(s.bytes as f64);
        self.cache_entries.set(s.entries as f64);
        self.cache_capacity_bytes.set(s.capacity_bytes as f64);
    }

    /// Mirror a slab pool's circulation counters.
    pub fn set_slabs(&self, s: &SlabStats, target: usize) {
        self.slab_minted_total.set(s.minted);
        self.slab_recycled_total.set(s.recycled);
        self.slab_dropped_total.set(s.dropped);
        self.slab_free.set(s.free as f64);
        self.slab_target.set(target as f64);
    }

    /// Push the scheduler's admission state for this dispatch turn.
    pub fn set_queue(&self, depth: usize, inflight: usize, mem_in_use: u64, budget: u64) {
        self.queue_depth.set(depth as f64);
        self.jobs_inflight.set(inflight as f64);
        self.mem_in_use_bytes.set(mem_in_use as f64);
        self.mem_budget_bytes.set(budget as f64);
    }

    /// Record one completed job.
    pub fn job_done(&self, wall_secs: f64, snps: u64, blocks: u64, snps_per_sec: f64) {
        self.job_wall_seconds.observe(Duration::from_secs_f64(wall_secs.max(0.0)));
        self.snps_total.add(snps);
        self.blocks_total.add(blocks);
        self.jobs_done_total.add(1);
        self.snps_per_sec.set(snps_per_sec);
    }

    /// Render the whole catalog as Prometheus text exposition (v0.0.4).
    pub fn render(&self) -> String {
        let mut o = String::with_capacity(16 * 1024);
        let head = |o: &mut String, name: &str, help: &str, ty: &str| {
            let _ = writeln!(o, "# HELP {name} {help}");
            let _ = writeln!(o, "# TYPE {name} {ty}");
        };
        let counter = |o: &mut String, name: &str, help: &str, v: u64| {
            head(o, name, help, "counter");
            let _ = writeln!(o, "{name} {v}");
        };
        let gauge = |o: &mut String, name: &str, help: &str, v: f64| {
            head(o, name, help, "gauge");
            let _ = writeln!(o, "{name} {v}");
        };

        head(
            &mut o,
            "cugwas_phase_seconds",
            "Per-event time in each pipeline phase (the live Fig. 3 profile).",
            "histogram",
        );
        for (i, ph) in Phase::ALL.iter().enumerate() {
            let labels = format!("phase=\"{}\"", ph.as_str());
            self.phase[i].render_into(&mut o, "cugwas_phase_seconds", &labels);
        }
        head(
            &mut o,
            "cugwas_job_wall_seconds",
            "End-to-end wall time of completed jobs.",
            "histogram",
        );
        self.job_wall_seconds.render_into(&mut o, "cugwas_job_wall_seconds", "");

        counter(
            &mut o,
            "cugwas_bytes_copied_total",
            "Block bytes memcpy'd on the host data plane.",
            self.bytes_copied_total.get(),
        );
        counter(
            &mut o,
            "cugwas_bytes_borrowed_total",
            "Block bytes handed across a stage boundary by reference.",
            self.bytes_borrowed_total.get(),
        );
        counter(&mut o, "cugwas_snps_total", "SNP columns solved.", self.snps_total.get());
        counter(&mut o, "cugwas_blocks_total", "Column windows streamed.", self.blocks_total.get());
        counter(
            &mut o,
            "cugwas_replans_total",
            "Adaptive knob switches taken at segment boundaries.",
            self.replans_total.get(),
        );
        counter(&mut o, "cugwas_jobs_done_total", "Jobs completed.", self.jobs_done_total.get());
        counter(&mut o, "cugwas_jobs_failed_total", "Jobs failed.", self.jobs_failed_total.get());
        counter(
            &mut o,
            "cugwas_jobs_coalesced_total",
            "Queued jobs answered by riding a compatible job's streaming pass.",
            self.jobs_coalesced_total.get(),
        );
        gauge(
            &mut o,
            "cugwas_snps_per_sec",
            "Streaming throughput of the most recently completed job.",
            self.snps_per_sec.get(),
        );
        gauge(
            &mut o,
            "cugwas_traits",
            "Phenotype batch width of the engine's current streaming pass.",
            self.traits_width.get(),
        );

        gauge(&mut o, "cugwas_queue_depth", "Jobs waiting for admission.", self.queue_depth.get());
        gauge(
            &mut o,
            "cugwas_jobs_inflight",
            "Jobs currently streaming.",
            self.jobs_inflight.get(),
        );
        gauge(
            &mut o,
            "cugwas_mem_in_use_bytes",
            "Host bytes admitted jobs hold against the budget.",
            self.mem_in_use_bytes.get(),
        );
        gauge(
            &mut o,
            "cugwas_mem_budget_bytes",
            "Host memory budget of the admission controller.",
            self.mem_budget_bytes.get(),
        );

        counter(
            &mut o,
            "cugwas_cache_hits_total",
            "Shared block cache hits.",
            self.cache_hits_total.get(),
        );
        counter(
            &mut o,
            "cugwas_cache_misses_total",
            "Shared block cache misses.",
            self.cache_misses_total.get(),
        );
        counter(
            &mut o,
            "cugwas_cache_insertions_total",
            "Blocks inserted into the shared cache.",
            self.cache_insertions_total.get(),
        );
        counter(
            &mut o,
            "cugwas_cache_evictions_total",
            "Blocks evicted from the shared cache.",
            self.cache_evictions_total.get(),
        );
        gauge(
            &mut o,
            "cugwas_cache_resident_bytes",
            "Bytes resident in the shared block cache.",
            self.cache_resident_bytes.get(),
        );
        gauge(
            &mut o,
            "cugwas_cache_entries",
            "Blocks resident in the shared cache.",
            self.cache_entries.get(),
        );
        gauge(
            &mut o,
            "cugwas_cache_capacity_bytes",
            "Byte capacity of the shared cache.",
            self.cache_capacity_bytes.get(),
        );

        counter(
            &mut o,
            "cugwas_slab_minted_total",
            "Aligned slabs allocated fresh by the pool.",
            self.slab_minted_total.get(),
        );
        counter(
            &mut o,
            "cugwas_slab_recycled_total",
            "Slab takes served from the free list.",
            self.slab_recycled_total.get(),
        );
        counter(
            &mut o,
            "cugwas_slab_dropped_total",
            "Slabs released past the pool's retain target.",
            self.slab_dropped_total.get(),
        );
        gauge(&mut o, "cugwas_slab_free", "Slabs idle in the pool.", self.slab_free.get());
        gauge(
            &mut o,
            "cugwas_slab_target",
            "The pool's retain target (host_buffers).",
            self.slab_target.get(),
        );

        counter(
            &mut o,
            "cugwas_faults_injected_total",
            "Faults the chaos injector fired (read faults, corruption, torn appends, wedges).",
            self.faults_injected_total.get(),
        );
        counter(
            &mut o,
            "cugwas_read_retries_total",
            "Block reads retried after a transient failure or integrity mismatch.",
            self.read_retries_total.get(),
        );
        counter(
            &mut o,
            "cugwas_lane_respawns_total",
            "Device-lane sets respawned after a lane died or wedged mid-stream.",
            self.lane_respawns_total.get(),
        );
        counter(
            &mut o,
            "cugwas_job_retries_total",
            "Failed jobs re-queued by the scheduler's degradation policy.",
            self.job_retries_total.get(),
        );

        counter(
            &mut o,
            "cugwas_wal_replays_total",
            "Service starts that replayed lifecycle records from the WAL.",
            self.wal_replays_total.get(),
        );
        counter(
            &mut o,
            "cugwas_jobs_resumed_total",
            "Jobs resumed from their progress journals after a crash or drain.",
            self.jobs_resumed_total.get(),
        );
        counter(
            &mut o,
            "cugwas_jobs_cancelled_total",
            "Jobs checkpointed by a drain, deadline, or cancel request.",
            self.jobs_cancelled_total.get(),
        );
        counter(
            &mut o,
            "cugwas_drains_total",
            "Graceful drains the service has begun.",
            self.drains_total.get(),
        );
        counter(
            &mut o,
            "cugwas_disk_low_water_total",
            "Times free disk space fell below the low-water mark and paused admission.",
            self.disk_low_water_total.get(),
        );

        head(
            &mut o,
            "cugwas_stall_segments_total",
            "Segments by stall verdict (per-segment stall attribution).",
            "counter",
        );
        for k in StallKind::ALL {
            let _ = writeln!(
                o,
                "cugwas_stall_segments_total{{verdict=\"{}\"}} {}",
                k.as_str(),
                self.stall_total[k.index()].get()
            );
        }
        gauge(
            &mut o,
            "cugwas_stall_share",
            "Dominating phase's share of wall time in the latest verdict.",
            self.stall_share.get(),
        );

        let lanes = self.lanes_seen.load(Ordering::Relaxed);
        if lanes > 0 {
            head(
                &mut o,
                "cugwas_lane_outstanding",
                "Chunks submitted to each device lane and not yet retired.",
                "gauge",
            );
            for lane in 0..lanes {
                let _ = writeln!(
                    o,
                    "cugwas_lane_outstanding{{lane=\"{lane}\"}} {}",
                    self.lane_outstanding[lane].get()
                );
            }
        }
        o
    }
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry (materialized on first touch — which the
/// disabled-telemetry fast path never performs).
pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_cumulative_and_monotone() {
        let h = Histogram::default();
        h.observe(Duration::from_micros(2)); // bucket 1 (4 µs)
        h.observe(Duration::from_millis(2)); // 0.004096
        h.observe(Duration::from_secs(100)); // beyond last bound: +Inf only
        let cum = h.cumulative();
        assert!(cum.windows(2).all(|w| w[0] <= w[1]), "{cum:?}");
        assert_eq!(cum[BUCKET_BOUNDS.len() - 1], 2, "overflow lands only in +Inf");
        assert_eq!(h.count(), 3);
        assert!((h.sum_secs() - 100.002002).abs() < 1e-6);
    }

    #[test]
    fn gauge_roundtrips_f64() {
        let g = GaugeCell::default();
        assert_eq!(g.get(), 0.0);
        g.set(1234.5);
        assert_eq!(g.get(), 1234.5);
    }

    #[test]
    fn render_covers_the_catalog() {
        let r = Registry::new();
        r.observe_phase(0, Duration::from_millis(1));
        r.job_done(0.5, 1000, 4, 2000.0);
        r.set_lane_outstanding(1, 2);
        r.record_stall(StallVerdict { kind: StallKind::ReadBound, share: 0.7 });
        let text = r.render();
        for needle in [
            "# TYPE cugwas_phase_seconds histogram",
            "cugwas_phase_seconds_bucket{phase=\"read_wait\",le=\"+Inf\"} 1",
            "cugwas_job_wall_seconds_count 1",
            "# TYPE cugwas_snps_per_sec gauge",
            "cugwas_snps_per_sec 2000",
            "cugwas_cache_resident_bytes",
            "cugwas_slab_recycled_total",
            "cugwas_stall_segments_total{verdict=\"read_bound\"} 1",
            "cugwas_lane_outstanding{lane=\"1\"} 2",
            "cugwas_bytes_copied_total 0",
            "# TYPE cugwas_faults_injected_total counter",
            "cugwas_read_retries_total 0",
            "cugwas_lane_respawns_total 0",
            "cugwas_job_retries_total 0",
            "cugwas_jobs_coalesced_total 0",
            "cugwas_wal_replays_total 0",
            "cugwas_jobs_resumed_total 0",
            "cugwas_jobs_cancelled_total 0",
            "cugwas_drains_total 0",
            "cugwas_disk_low_water_total 0",
            "# TYPE cugwas_traits gauge",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Lane 2 was never seen; lanes 0..=1 render.
        assert!(!text.contains("lane=\"2\""));
    }
}
