//! The telemetry plane: structured tracing, Prometheus-style metrics and
//! per-segment stall attribution for the streaming engine. Std-only.
//!
//! The paper makes its argument *observationally* — Figs. 3/4 are
//! per-phase lane profiles proving the pipeline sustains peak, and the
//! companion tuning work reads exactly those stall profiles. This module
//! exports that seam from a *live* process instead of end-of-run text:
//!
//! * [`registry`] — a fixed catalog of lock-cheap atomic counters,
//!   gauges and log-bucketed latency histograms, fed by the existing
//!   accounting ([`Metrics`](crate::coordinator::Metrics) phase adds,
//!   [`CacheStats`](crate::storage::CacheStats),
//!   [`SlabStats`](crate::storage::SlabStats), the job queue and the
//!   engine) rather than duplicating it, rendered as Prometheus text
//!   exposition (v0.0.4).
//! * [`http`] — a minimal `TcpListener` responder serving `/metrics`
//!   and `/healthz` (`cugwas serve --metrics-addr`).
//! * [`trace`] — a bounded ring of spans recorded at the pipeline's
//!   existing `Instant::now()` timing points, exportable as Chrome
//!   trace-event JSON (`--trace-out`): the Fig. 3 lane timeline,
//!   rendered from a real run in Perfetto / `chrome://tracing`.
//! * [`stall`] — [`StallVerdict`]: the adapt path's observed stall
//!   profile promoted to a first-class per-segment verdict (read-bound /
//!   compute-bound / sloop-bound / balanced), surfaced in replan events,
//!   job reports and the exposition.
//!
//! **Disabled telemetry is a no-op.** Both planes sit behind a global
//! `AtomicBool`; every record function begins with one relaxed load and
//! returns before touching the registry, taking a lock or formatting
//! anything. `run`/`serve` without the flags never even materialize the
//! global registry. Tracing observes existing timing points only — it
//! never changes what is computed, so determinism is unaffected with it
//! on.

pub mod http;
pub mod registry;
pub mod stall;
pub mod trace;

pub use http::MetricsServer;
pub use registry::{global, Registry};
pub use stall::{StallKind, StallVerdict};
pub use trace::{global_trace, TraceSink};

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

static METRICS_ON: AtomicBool = AtomicBool::new(false);
static TRACE_ON: AtomicBool = AtomicBool::new(false);

/// Turn the metrics plane on (done once at startup by `serve` when
/// `--metrics-addr`/`[service] metrics_addr` is given; tests flip it in
/// their own process).
pub fn set_metrics_enabled(on: bool) {
    if on {
        registry::global(); // materialize outside the hot path
    }
    METRICS_ON.store(on, Ordering::Release);
}

/// Whether the metrics plane records (one relaxed load — the entire
/// cost of disabled telemetry on the hot path).
#[inline(always)]
pub fn metrics_enabled() -> bool {
    METRICS_ON.load(Ordering::Relaxed)
}

/// Turn span tracing on (done once at startup by `--trace-out`). The
/// trace epoch is pinned at the first enable, so span timestamps are
/// relative to it.
pub fn set_trace_enabled(on: bool) {
    if on {
        trace::global_trace(); // pin the epoch outside the hot path
    }
    TRACE_ON.store(on, Ordering::Release);
}

/// Whether span tracing records (one relaxed load when off).
#[inline(always)]
pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Feed one phase duration into the global phase histogram. Called by
/// [`Metrics::add`](crate::coordinator::Metrics::add) — the single
/// accounting point every pipeline phase already flows through.
#[inline]
pub fn phase_observe(phase_idx: usize, d: Duration) {
    if !metrics_enabled() {
        return;
    }
    registry::global().observe_phase(phase_idx, d);
}

/// Feed data-plane byte counters (mirrors
/// [`Metrics::add_bytes`](crate::coordinator::Metrics::add_bytes)).
#[inline]
pub fn bytes_observe(copied: bool, n: u64) {
    if !metrics_enabled() {
        return;
    }
    let r = registry::global();
    if copied {
        r.bytes_copied_total.add(n);
    } else {
        r.bytes_borrowed_total.add(n);
    }
}

/// Publish a lane's outstanding-chunk depth (the coordinator pushes
/// this where `SegmentState::outstanding` changes).
#[inline]
pub fn lane_outstanding(lane: usize, depth: usize) {
    if !metrics_enabled() {
        return;
    }
    registry::global().set_lane_outstanding(lane, depth);
}

/// Record one completed span at an existing timing point. `tid` groups
/// spans into Perfetto tracks (see [`trace`] for the track layout);
/// up to two `(key, value)` args ride along (block/lane/column ids).
#[inline]
pub fn span(
    name: &'static str,
    cat: &'static str,
    tid: u32,
    start: Instant,
    dur: Duration,
    args: &[(&'static str, u64)],
) {
    if !trace_enabled() {
        return;
    }
    trace::global_trace().record(name, cat, tid, start, dur, args);
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the enable flags are process-global, and `cargo test` runs
    // every lib unit test in one process — so these tests never flip
    // them. The flag-driven paths are covered by the dedicated
    // integration-test binaries (`tests/telemetry.rs` enables, and
    // `tests/telemetry_off.rs` asserts the default-off no-op), each in
    // its own process.
    #[test]
    fn disabled_record_paths_are_inert() {
        assert!(!metrics_enabled());
        assert!(!trace_enabled());
        phase_observe(0, Duration::from_millis(1));
        bytes_observe(true, 128);
        lane_outstanding(0, 2);
        span("x", "test", 0, Instant::now(), Duration::ZERO, &[("a", 1)]);
    }
}
