//! A minimal HTTP responder for `/metrics` and `/healthz` — just enough
//! protocol for a Prometheus scraper or a load-balancer probe, std-only.
//!
//! One background thread accepts connections on a non-blocking listener
//! and answers each request from pure registry state (a scrape never
//! calls into the live pipeline). `GET /metrics` returns the text
//! exposition, `GET /healthz` returns `ok`, and `POST /drain` asks the
//! running service to drain gracefully (the one write endpoint — it
//! flips the same process-global flag as SIGINT and the spool's
//! `control/drain` file, so the response is immediate while the drain
//! itself proceeds at the next dispatch turn); everything else is 404.
//! Dropping the server stops the thread (bounded by the accept-poll
//! interval), so `serve` shuts it down cleanly on exit.

use crate::error::{Error, Result};
use crate::telemetry::registry;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How often the accept loop polls the stop flag.
const POLL: Duration = Duration::from_millis(20);

/// A running metrics endpoint. Construct with [`MetricsServer::start`];
/// drop to stop.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`; port `0` picks a free one)
    /// and start answering in a background thread.
    pub fn start(addr: &str) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::io(format!("binding metrics endpoint {addr}"), e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::io("metrics listener set_nonblocking", e))?;
        let local = listener.local_addr().map_err(|e| Error::io("metrics local_addr", e))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("cugwas-metrics-http".into())
            .spawn(move || accept_loop(listener, stop2))
            .map_err(|e| Error::io("spawning metrics thread", e))?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port `0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((conn, _)) => {
                // Serve inline: scrapes are rare (seconds apart) and the
                // response is a few KB — a worker pool would be ceremony.
                let _ = handle_conn(conn);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(POLL),
            Err(_) => std::thread::sleep(POLL),
        }
    }
}

fn handle_conn(mut conn: TcpStream) -> std::io::Result<()> {
    // The accepted socket does not inherit the listener's non-blocking
    // mode on every platform — pin both, with a timeout so a stuck
    // client can't wedge the accept loop.
    conn.set_nonblocking(false)?;
    conn.set_read_timeout(Some(Duration::from_millis(500)))?;
    let mut buf = [0u8; 2048];
    let mut used = 0;
    loop {
        match conn.read(&mut buf[used..]) {
            Ok(0) => break,
            Ok(n) => {
                used += n;
                if buf[..used].windows(4).any(|w| w == b"\r\n\r\n") || used == buf.len() {
                    break;
                }
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => break,
            Err(e) => return Err(e),
        }
    }
    let head = String::from_utf8_lossy(&buf[..used]);
    let mut req = head.split_whitespace();
    let method = req.next().unwrap_or("GET");
    let path = req.next().unwrap_or("/");
    let (status, ctype, body) = match (method, path) {
        ("GET", p) if p == "/metrics" || p.starts_with("/metrics?") => (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            registry::global().render(),
        ),
        ("GET", "/healthz") => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
        ("POST", "/drain") => {
            crate::service::request_drain();
            ("200 OK", "text/plain; charset=utf-8", "draining\n".to_string())
        }
        _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(resp.as_bytes())
}
