//! Per-segment stall attribution: the adapt path's observed stall
//! profile promoted to a first-class verdict.
//!
//! The paper's Fig. 4 (and the companion tuning work) reason about the
//! pipeline in exactly these terms — which resource the coordinator is
//! *waiting on*: the disk (`ReadWait` dominates), the device
//! (`RecvWait`: results aren't back when the coordinator needs them),
//! or its own CPU tail (`Sloop`). A verdict is derived from the same
//! phase shares the re-planner reads, so every autotuner decision is
//! auditable: the replan log line, the job report and the Prometheus
//! exposition all carry the same attribution.

use crate::coordinator::metrics::{Metrics, Phase};

/// Which resource bounded a segment (or a whole run).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StallKind {
    /// Disk-bound: the coordinator mostly waited on `aio_read`.
    ReadBound,
    /// Device-bound: mostly waited on lane results (`RecvWait`).
    ComputeBound,
    /// CPU-tail-bound: the S-loop dominated the coordinator's time.
    SloopBound,
    /// No single phase dominated — the pipeline is overlapping well.
    Balanced,
}

impl StallKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            StallKind::ReadBound => "read_bound",
            StallKind::ComputeBound => "compute_bound",
            StallKind::SloopBound => "sloop_bound",
            StallKind::Balanced => "balanced",
        }
    }

    pub const ALL: [StallKind; 4] =
        [StallKind::ReadBound, StallKind::ComputeBound, StallKind::SloopBound, StallKind::Balanced];

    /// Position in [`StallKind::ALL`] (registry counter index).
    pub fn index(self) -> usize {
        match self {
            StallKind::ReadBound => 0,
            StallKind::ComputeBound => 1,
            StallKind::SloopBound => 2,
            StallKind::Balanced => 3,
        }
    }
}

/// A verdict plus the dominating phase's share of wall time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StallVerdict {
    pub kind: StallKind,
    /// The dominating phase's fraction of wall time, in `[0, 1]` (for
    /// `Balanced`: the largest share that still fell below the
    /// domination threshold).
    pub share: f64,
}

/// A phase must claim at least this fraction of wall time to bound the
/// segment; below it the verdict is `Balanced`.
const DOMINANT_SHARE: f64 = 0.15;

impl StallVerdict {
    /// Attribute from the three stall shares (fractions of wall time
    /// spent in `ReadWait`, `RecvWait` and `Sloop` respectively — the
    /// same numbers [`crate::tune::replan_knobs`] reads).
    pub fn from_shares(read: f64, recv: f64, sloop: f64) -> StallVerdict {
        let mut kind = StallKind::ReadBound;
        let mut share = read;
        if recv > share {
            kind = StallKind::ComputeBound;
            share = recv;
        }
        if sloop > share {
            kind = StallKind::SloopBound;
            share = sloop;
        }
        if share < DOMINANT_SHARE {
            kind = StallKind::Balanced;
        }
        StallVerdict { kind, share: share.clamp(0.0, 1.0) }
    }

    /// Whole-run attribution from the accumulated phase totals.
    pub fn from_metrics(m: &Metrics, wall_secs: f64) -> StallVerdict {
        let w = wall_secs.max(1e-12);
        StallVerdict::from_shares(
            m.total(Phase::ReadWait).as_secs_f64() / w,
            m.total(Phase::RecvWait).as_secs_f64() / w,
            m.total(Phase::Sloop).as_secs_f64() / w,
        )
    }

    /// Human rendering, e.g. `read_bound (62% of wall)`.
    pub fn render(&self) -> String {
        format!("{} ({:.0}% of wall)", self.kind.as_str(), 100.0 * self.share)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn dominating_phase_wins() {
        let v = StallVerdict::from_shares(0.62, 0.10, 0.05);
        assert_eq!(v.kind, StallKind::ReadBound);
        assert!((v.share - 0.62).abs() < 1e-12);
        assert_eq!(StallVerdict::from_shares(0.1, 0.5, 0.2).kind, StallKind::ComputeBound);
        assert_eq!(StallVerdict::from_shares(0.1, 0.2, 0.5).kind, StallKind::SloopBound);
    }

    #[test]
    fn small_shares_are_balanced() {
        let v = StallVerdict::from_shares(0.05, 0.08, 0.02);
        assert_eq!(v.kind, StallKind::Balanced);
        assert!((v.share - 0.08).abs() < 1e-12);
        assert!(v.render().contains("balanced"), "{}", v.render());
    }

    #[test]
    fn from_metrics_uses_phase_totals() {
        let mut m = Metrics::new();
        m.add(Phase::ReadWait, Duration::from_millis(700));
        m.add(Phase::Sloop, Duration::from_millis(100));
        let v = StallVerdict::from_metrics(&m, 1.0);
        assert_eq!(v.kind, StallKind::ReadBound);
        assert!((v.share - 0.7).abs() < 1e-9);
    }
}
