//! Hand-rolled benchmark framework (criterion is unavailable offline —
//! see DESIGN.md §4). `cargo bench` targets use `harness = false` and
//! drive this: warmup, repeated timed runs, robust statistics, and
//! aligned table output that EXPERIMENTS.md captures verbatim.

use crate::util::human_duration;
use std::time::{Duration, Instant};

/// Samples + summary statistics for one measured case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub label: String,
    pub samples: Vec<Duration>,
}

impl Measurement {
    pub fn median(&self) -> Duration {
        let mut v = self.samples.clone();
        v.sort_unstable();
        v[v.len() / 2]
    }

    pub fn min(&self) -> Duration {
        *self.samples.iter().min().expect("non-empty")
    }

    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    /// Relative spread: (max-min)/median, a cheap stability indicator.
    pub fn spread(&self) -> f64 {
        let max = self.samples.iter().max().unwrap().as_secs_f64();
        let min = self.min().as_secs_f64();
        let med = self.median().as_secs_f64();
        if med > 0.0 {
            (max - min) / med
        } else {
            0.0
        }
    }
}

/// Benchmark runner with fixed warmup/sample counts.
pub struct Bench {
    pub warmup: u32,
    pub samples: u32,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 1, samples: 5 }
    }
}

impl Bench {
    pub fn new(warmup: u32, samples: u32) -> Self {
        assert!(samples > 0);
        Bench { warmup, samples }
    }

    /// Quick-mode override: `CUGWAS_BENCH_FAST=1` drops to 1 sample (CI).
    pub fn from_env() -> Self {
        if std::env::var("CUGWAS_BENCH_FAST").is_ok() {
            Bench::new(0, 1)
        } else {
            Bench::default()
        }
    }

    /// Measure `f` (called once per sample).
    pub fn measure(&self, label: impl Into<String>, mut f: impl FnMut()) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples as usize);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        Measurement { label: label.into(), samples }
    }
}

/// Aligned table output for bench results.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i] + 2))
                .collect::<Vec<_>>()
                .join("")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().map(|w| w + 2).sum::<usize>()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        let _ = ncols;
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a duration cell.
pub fn dur_cell(d: Duration) -> String {
    human_duration(d)
}

/// Format a ratio cell like "2.61x".
pub fn ratio_cell(num: f64, den: f64) -> String {
    if den > 0.0 {
        format!("{:.2}x", num / den)
    } else {
        "n/a".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_collects_samples() {
        let b = Bench::new(0, 3);
        let mut calls = 0;
        let m = b.measure("noop", || calls += 1);
        assert_eq!(calls, 3);
        assert_eq!(m.samples.len(), 3);
        assert!(m.median() <= m.samples.iter().max().copied().unwrap());
        assert!(m.min() <= m.mean());
    }

    #[test]
    fn warmup_not_counted() {
        let b = Bench::new(2, 1);
        let mut calls = 0;
        let m = b.measure("noop", || calls += 1);
        assert_eq!(calls, 3);
        assert_eq!(m.samples.len(), 1);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["algo", "time"]);
        t.row(&["cugwas".into(), "1.00 s".into()]);
        t.row(&["ooc".into(), "2.61 s".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("cugwas"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn ratio_cell_formats() {
        assert_eq!(ratio_cell(5.2, 2.0), "2.60x");
        assert_eq!(ratio_cell(1.0, 0.0), "n/a");
    }
}
