//! Phase-level timing of the live pipeline — the measured counterpart of
//! the simulator's timeline, and the data behind the Fig. 3 reproduction
//! (how much time each lane spends working vs waiting).

use std::collections::BTreeMap;
use std::time::Duration;

/// Pipeline phases, matching the paper's profile categories (Fig. 3/4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Waiting on `aio_read` of the next block (disk).
    ReadWait,
    /// Staging a block into a device lane (the "send" copy).
    Send,
    /// Device compute (trsm or fused block), measured inside the lane.
    DeviceCompute,
    /// Waiting on device results (the "recv").
    RecvWait,
    /// CPU S-loop.
    Sloop,
    /// Waiting on `aio_write` of results.
    WriteWait,
    /// Block served from the shared block cache (no disk read issued);
    /// the duration is the RAM memcpy.
    CacheHit,
    /// Block absent from the cache — a real disk read was issued (count
    /// tracks misses; the read time itself lands in `ReadWait`).
    CacheMiss,
    /// Adaptive re-planning at a segment boundary (count = number of
    /// re-plan decisions taken; duration = time spent in the DES search).
    Replan,
    /// Everything else on the coordinator thread (rotation, bookkeeping).
    Other,
}

impl Phase {
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::ReadWait => "read_wait",
            Phase::Send => "send",
            Phase::DeviceCompute => "device_compute",
            Phase::RecvWait => "recv_wait",
            Phase::Sloop => "sloop",
            Phase::WriteWait => "write_wait",
            Phase::CacheHit => "cache_hit",
            Phase::CacheMiss => "cache_miss",
            Phase::Replan => "replan",
            Phase::Other => "other",
        }
    }

    pub const ALL: [Phase; 10] = [
        Phase::ReadWait,
        Phase::Send,
        Phase::DeviceCompute,
        Phase::RecvWait,
        Phase::Sloop,
        Phase::WriteWait,
        Phase::CacheHit,
        Phase::CacheMiss,
        Phase::Replan,
        Phase::Other,
    ];

    /// Position in [`Phase::ALL`] — the telemetry registry's histogram
    /// index for this phase.
    pub fn index(&self) -> usize {
        match self {
            Phase::ReadWait => 0,
            Phase::Send => 1,
            Phase::DeviceCompute => 2,
            Phase::RecvWait => 3,
            Phase::Sloop => 4,
            Phase::WriteWait => 5,
            Phase::CacheHit => 6,
            Phase::CacheMiss => 7,
            Phase::Replan => 8,
            Phase::Other => 9,
        }
    }
}

/// Data-plane byte counters — the observable proof of the zero-copy
/// refactor. Every block that crosses a stage boundary is tallied once:
/// under `BytesCopied` when a host `memcpy` moved it (the pre-slab
/// plane did this up to three times per block), under `BytesBorrowed`
/// when only a reference crossed (a published slab shared with the
/// cache, or a [`BlockSlice`](crate::storage::BlockSlice) view handed
/// to a lane). `tests/zero_copy.rs` pins the steady-state cache-hit
/// path at `BytesCopied == 0`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Counter {
    /// Block bytes memcpy'd on the host data plane (staging copies; the
    /// PJRT literal-boundary copy is the one legitimate remainder).
    BytesCopied,
    /// Block bytes handed across a stage boundary by reference.
    BytesBorrowed,
}

impl Counter {
    pub fn as_str(&self) -> &'static str {
        match self {
            Counter::BytesCopied => "bytes_copied",
            Counter::BytesBorrowed => "bytes_borrowed",
        }
    }

    pub const ALL: [Counter; 2] = [Counter::BytesCopied, Counter::BytesBorrowed];
}

/// Accumulated phase durations + counts, plus the data-plane byte
/// counters.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    totals: BTreeMap<&'static str, (Duration, u64)>,
    byte_totals: BTreeMap<&'static str, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: Phase, d: Duration) {
        crate::telemetry::phase_observe(phase.index(), d);
        self.add_local(phase, d);
    }

    /// Like [`Metrics::add`], but without feeding the telemetry plane's
    /// phase histograms. The device lanes use this for their
    /// thread-local `DeviceCompute` accounting: the coordinator
    /// re-records every chunk's compute time from
    /// [`DevOut::compute_secs`](crate::coordinator::lane::DevOut) when
    /// it retires the result, so exporting both sides would
    /// double-count the global histogram.
    pub fn add_local(&mut self, phase: Phase, d: Duration) {
        let e = self.totals.entry(phase.as_str()).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Tally data-plane bytes (see [`Counter`]).
    pub fn add_bytes(&mut self, counter: Counter, bytes: u64) {
        crate::telemetry::bytes_observe(matches!(counter, Counter::BytesCopied), bytes);
        *self.byte_totals.entry(counter.as_str()).or_insert(0) += bytes;
    }

    pub fn bytes(&self, counter: Counter) -> u64 {
        self.byte_totals.get(counter.as_str()).copied().unwrap_or(0)
    }

    /// Merge another metrics object (e.g. a lane's) into this one.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, (d, c)) in &other.totals {
            let e = self.totals.entry(k).or_insert((Duration::ZERO, 0));
            e.0 += *d;
            e.1 += *c;
        }
        for (k, b) in &other.byte_totals {
            *self.byte_totals.entry(k).or_insert(0) += *b;
        }
    }

    pub fn total(&self, phase: Phase) -> Duration {
        self.totals.get(phase.as_str()).map(|e| e.0).unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, phase: Phase) -> u64 {
        self.totals.get(phase.as_str()).map(|e| e.1).unwrap_or(0)
    }

    /// Render a compact per-phase table (for logs / bench output).
    ///
    /// The duration column is labeled `busy Σ` because it is a *sum of
    /// busy seconds*, not an interval: `device_compute` merges the
    /// per-lane compute times, so with `g` lanes overlapping it can
    /// legitimately sum past the job wall clock (and `%wall` past
    /// 100%). A footnote flags the table whenever that happens so the
    /// Fig. 3 reproduction isn't misread as >100% utilization of one
    /// thread.
    pub fn table(&self, wall: Duration) -> String {
        let mut out = String::new();
        out.push_str(&format!("{:<16}{:>12}{:>8}{:>8}\n", "phase", "busy Σ", "count", "%wall"));
        let mut lane_merged_past_wall = false;
        for ph in Phase::ALL {
            let t = self.total(ph);
            let c = self.count(ph);
            if c == 0 {
                continue;
            }
            let pct = if wall.as_secs_f64() > 0.0 {
                100.0 * t.as_secs_f64() / wall.as_secs_f64()
            } else {
                0.0
            };
            if t > wall {
                lane_merged_past_wall = true;
            }
            out.push_str(&format!(
                "{:<16}{:>12}{:>8}{:>7.1}%\n",
                ph.as_str(),
                crate::util::human_duration(t),
                c,
                pct
            ));
        }
        if lane_merged_past_wall {
            out.push_str(
                "(busy Σ sums per-lane busy seconds; with overlapping lanes %wall exceeds 100%)\n",
            );
        }
        for counter in Counter::ALL {
            let b = self.bytes(counter);
            if b > 0 {
                let human = crate::util::human_bytes(b);
                out.push_str(&format!("{:<16}{:>12}\n", counter.as_str(), human));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_totals() {
        let mut m = Metrics::new();
        m.add(Phase::Sloop, Duration::from_millis(10));
        m.add(Phase::Sloop, Duration::from_millis(5));
        m.add(Phase::ReadWait, Duration::from_millis(1));
        assert_eq!(m.total(Phase::Sloop), Duration::from_millis(15));
        assert_eq!(m.count(Phase::Sloop), 2);
        assert_eq!(m.count(Phase::DeviceCompute), 0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics::new();
        a.add(Phase::Send, Duration::from_millis(2));
        let mut b = Metrics::new();
        b.add(Phase::Send, Duration::from_millis(3));
        b.add(Phase::RecvWait, Duration::from_millis(1));
        a.merge(&b);
        assert_eq!(a.total(Phase::Send), Duration::from_millis(5));
        assert_eq!(a.count(Phase::RecvWait), 1);
    }

    #[test]
    fn table_renders_nonempty_phases_only() {
        let mut m = Metrics::new();
        m.add(Phase::Sloop, Duration::from_millis(10));
        let t = m.table(Duration::from_millis(20));
        assert!(t.contains("busy Σ"), "duration column labeled as a busy-seconds sum: {t}");
        assert!(t.contains("sloop"));
        assert!(!t.contains("recv_wait"));
        assert!(t.contains("50.0%"));
        assert!(!t.contains("bytes_copied"), "zero byte counters stay hidden");
        assert!(!t.contains("overlapping lanes"), "no footnote when nothing exceeds wall");
    }

    #[test]
    fn table_flags_lane_merged_time_past_wall() {
        // Two lanes overlapping: device_compute sums to 2× the wall
        // clock. The table must say so instead of implying >100%
        // utilization of one thread.
        let mut m = Metrics::new();
        m.add(Phase::DeviceCompute, Duration::from_millis(10));
        m.add(Phase::DeviceCompute, Duration::from_millis(10));
        let t = m.table(Duration::from_millis(10));
        assert!(t.contains("200.0%"), "{t}");
        assert!(t.contains("overlapping lanes"), "footnote explains the >100% row: {t}");
    }

    #[test]
    fn byte_counters_accumulate_merge_and_render() {
        let mut m = Metrics::new();
        assert_eq!(m.bytes(Counter::BytesCopied), 0);
        m.add_bytes(Counter::BytesBorrowed, 1000);
        m.add_bytes(Counter::BytesBorrowed, 24);
        m.add_bytes(Counter::BytesCopied, 8);
        let mut other = Metrics::new();
        other.add_bytes(Counter::BytesCopied, 2);
        m.merge(&other);
        assert_eq!(m.bytes(Counter::BytesBorrowed), 1024);
        assert_eq!(m.bytes(Counter::BytesCopied), 10);
        let t = m.table(Duration::from_millis(1));
        assert!(t.contains("bytes_borrowed"), "{t}");
        assert!(t.contains("bytes_copied"), "{t}");
    }
}
