//! Phase-level timing of the live pipeline — the measured counterpart of
//! the simulator's timeline, and the data behind the Fig. 3 reproduction
//! (how much time each lane spends working vs waiting).

use std::collections::BTreeMap;
use std::time::Duration;

/// Pipeline phases, matching the paper's profile categories (Fig. 3/4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Waiting on `aio_read` of the next block (disk).
    ReadWait,
    /// Staging a block into a device lane (the "send" copy).
    Send,
    /// Device compute (trsm or fused block), measured inside the lane.
    DeviceCompute,
    /// Waiting on device results (the "recv").
    RecvWait,
    /// CPU S-loop.
    Sloop,
    /// Waiting on `aio_write` of results.
    WriteWait,
    /// Block served from the shared block cache (no disk read issued);
    /// the duration is the RAM memcpy.
    CacheHit,
    /// Block absent from the cache — a real disk read was issued (count
    /// tracks misses; the read time itself lands in `ReadWait`).
    CacheMiss,
    /// Adaptive re-planning at a segment boundary (count = number of
    /// re-plan decisions taken; duration = time spent in the DES search).
    Replan,
    /// Everything else on the coordinator thread (rotation, bookkeeping).
    Other,
}

impl Phase {
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::ReadWait => "read_wait",
            Phase::Send => "send",
            Phase::DeviceCompute => "device_compute",
            Phase::RecvWait => "recv_wait",
            Phase::Sloop => "sloop",
            Phase::WriteWait => "write_wait",
            Phase::CacheHit => "cache_hit",
            Phase::CacheMiss => "cache_miss",
            Phase::Replan => "replan",
            Phase::Other => "other",
        }
    }

    pub const ALL: [Phase; 10] = [
        Phase::ReadWait,
        Phase::Send,
        Phase::DeviceCompute,
        Phase::RecvWait,
        Phase::Sloop,
        Phase::WriteWait,
        Phase::CacheHit,
        Phase::CacheMiss,
        Phase::Replan,
        Phase::Other,
    ];
}

/// Accumulated phase durations + counts.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    totals: BTreeMap<&'static str, (Duration, u64)>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: Phase, d: Duration) {
        let e = self.totals.entry(phase.as_str()).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Merge another metrics object (e.g. a lane's) into this one.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, (d, c)) in &other.totals {
            let e = self.totals.entry(k).or_insert((Duration::ZERO, 0));
            e.0 += *d;
            e.1 += *c;
        }
    }

    pub fn total(&self, phase: Phase) -> Duration {
        self.totals.get(phase.as_str()).map(|e| e.0).unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, phase: Phase) -> u64 {
        self.totals.get(phase.as_str()).map(|e| e.1).unwrap_or(0)
    }

    /// Render a compact per-phase table (for logs / bench output).
    pub fn table(&self, wall: Duration) -> String {
        let mut out = String::new();
        out.push_str(&format!("{:<16}{:>12}{:>8}{:>8}\n", "phase", "total", "count", "%wall"));
        for ph in Phase::ALL {
            let t = self.total(ph);
            let c = self.count(ph);
            if c == 0 {
                continue;
            }
            let pct = if wall.as_secs_f64() > 0.0 {
                100.0 * t.as_secs_f64() / wall.as_secs_f64()
            } else {
                0.0
            };
            out.push_str(&format!(
                "{:<16}{:>12}{:>8}{:>7.1}%\n",
                ph.as_str(),
                crate::util::human_duration(t),
                c,
                pct
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_totals() {
        let mut m = Metrics::new();
        m.add(Phase::Sloop, Duration::from_millis(10));
        m.add(Phase::Sloop, Duration::from_millis(5));
        m.add(Phase::ReadWait, Duration::from_millis(1));
        assert_eq!(m.total(Phase::Sloop), Duration::from_millis(15));
        assert_eq!(m.count(Phase::Sloop), 2);
        assert_eq!(m.count(Phase::DeviceCompute), 0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics::new();
        a.add(Phase::Send, Duration::from_millis(2));
        let mut b = Metrics::new();
        b.add(Phase::Send, Duration::from_millis(3));
        b.add(Phase::RecvWait, Duration::from_millis(1));
        a.merge(&b);
        assert_eq!(a.total(Phase::Send), Duration::from_millis(5));
        assert_eq!(a.count(Phase::RecvWait), 1);
    }

    #[test]
    fn table_renders_nonempty_phases_only() {
        let mut m = Metrics::new();
        m.add(Phase::Sloop, Duration::from_millis(10));
        let t = m.table(Duration::from_millis(20));
        assert!(t.contains("sloop"));
        assert!(!t.contains("recv_wait"));
        assert!(t.contains("50.0%"));
    }
}
