//! Device lanes: one worker thread per (emulated) GPU.
//!
//! Each lane owns its PJRT client and compiled executable — the analogue
//! of one CUDA context per device — and pulls work from a bounded channel
//! whose depth-1 queue plus the in-flight item realize the paper's **two
//! device buffers**: one block computing (`α`) while the next is staged
//! (`β`). A third submission blocks the coordinator, which is precisely
//! the paper's `cu_send_wait`.
//!
//! Backends:
//! * [`Backend::Pjrt`] — execute the AOT HLO artifact (the shipped path).
//! * [`Backend::Native`] — same math with the in-crate linalg; lets the
//!   coordinator logic be tested without artifacts and serves as the
//!   apples-to-apples CPU reference for lane overhead.

use crate::coordinator::metrics::{Metrics, Phase};
use crate::error::{Error, Result};
use crate::gwas::preprocess::Preprocessed;
use crate::linalg::{trsm_lower_left, Matrix};
use crate::runtime::{dinv_to_rowmajor, matrix_to_rowmajor, ArtifactEntry, Engine, HostTensor};
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;
use std::time::Instant;

/// How much of the per-block math the device executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadMode {
    /// Paper mode: device does only the trsm; CPU runs the full S-loop.
    Trsm,
    /// Fused: device also produces the S-loop reductions (G, rb, d).
    Block,
    /// Full offload: device returns final solutions r (ablation).
    BlockFull,
}

impl OffloadMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            OffloadMode::Trsm => "trsm",
            OffloadMode::Block => "block",
            OffloadMode::BlockFull => "blockfull",
        }
    }
}

/// Compute backend for a lane.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Execute the AOT artifact found in this manifest entry.
    Pjrt { entry: ArtifactEntry },
    /// In-crate linalg (no PJRT). `nb` mirrors the artifact block size.
    Native,
}

/// Work item: one per-GPU chunk of a host block.
pub struct DevIn {
    /// Global block index.
    pub block: u64,
    /// Chunk buffer, `(mb, n)` row-major == `(n, mb)` col-major, zero-padded
    /// to the artifact width.
    pub buf: Vec<f64>,
    /// Live (non-padding) columns in this chunk.
    pub live: usize,
}

/// Lane result for one chunk.
pub struct DevOut {
    pub block: u64,
    pub lane: usize,
    /// The input buffer, returned for recycling (paper: buffer rotation).
    pub inbuf: Vec<f64>,
    /// Mode-dependent outputs (see `process`).
    pub outs: LaneOutputs,
    /// Device-side compute seconds for this chunk.
    pub compute_secs: f64,
}

/// Outputs by offload mode, always truncated to the live columns.
pub enum LaneOutputs {
    /// `Trsm`: solved chunk `X̃_b`, col-major `(n, live)`.
    Xbt(Matrix),
    /// `Block`: `(X̃_b, G (pl×live), rb, d)`.
    Reductions { xbt: Matrix, g: Matrix, rb: Vec<f64>, d: Vec<f64> },
    /// `BlockFull`: solutions, col-major `(p, live)`.
    Solutions(Matrix),
}

/// Static data each lane needs (built once from [`Preprocessed`]).
struct LaneStatics {
    n: usize,
    pl: usize,
    mb: usize,
    l_row: Vec<f64>,
    dinv_row: Vec<f64>,
    xlt_row: Vec<f64>,
    yt: Vec<f64>,
    stl_row: Vec<f64>,
    rtop: Vec<f64>,
    // Native-backend copies.
    l: Matrix,
    pre: Preprocessed,
}

/// A running device lane.
pub struct DeviceLane {
    pub lane: usize,
    tx: Option<SyncSender<DevIn>>,
    pub rx_out: Receiver<DevOut>,
    worker: Option<JoinHandle<Result<Metrics>>>,
}

impl DeviceLane {
    /// Spawn lane `lane` with chunk width `mb` columns. `threads` is the
    /// lane's compute-thread budget — its share of the host cores (see
    /// `PipelineConfig::threads`); the native trsm/gemm kernels fan out
    /// up to that many workers. 0 = inherit the process-wide pool size.
    /// `depth` is the device-buffer count (paper: 2): the submission
    /// channel holds `depth - 1` staged chunks plus the one in flight, so
    /// submission `depth + 1` blocks — the paper's `cu_send_wait`.
    pub fn spawn(
        lane: usize,
        mode: OffloadMode,
        backend: Backend,
        pre: &Preprocessed,
        mb: usize,
        threads: usize,
        depth: usize,
    ) -> Result<DeviceLane> {
        let n = pre.l.rows();
        let pl = pre.xl_t.cols();
        let statics = LaneStatics {
            n,
            pl,
            mb,
            l_row: matrix_to_rowmajor(&pre.l),
            dinv_row: pre
                .dinv
                .as_ref()
                .map(|d| dinv_to_rowmajor(d, pre.dinv_nb, n))
                .unwrap_or_default(),
            xlt_row: matrix_to_rowmajor(&pre.xl_t),
            yt: pre.y_t.clone(),
            stl_row: matrix_to_rowmajor(&pre.stl),
            rtop: pre.rtop.clone(),
            l: pre.l.clone(),
            pre: pre.clone(),
        };
        if matches!(backend, Backend::Pjrt { .. }) && statics.dinv_row.is_empty() {
            return Err(Error::Config(
                "PJRT backend needs preprocess(dinv_nb > 0) matching the artifact".into(),
            ));
        }
        if depth < 2 {
            return Err(Error::Config("device buffer depth must be ≥ 2".into()));
        }
        // Bounded queue of depth-1 + the item being processed = `depth`
        // device buffers (paper default: 2).
        let (tx, rx) = sync_channel::<DevIn>(depth - 1);
        let (tx_out, rx_out) = channel::<DevOut>();
        let worker = std::thread::Builder::new()
            .name(format!("cugwas-lane{lane}"))
            .spawn(move || {
                let _budget = crate::util::threads::with_budget(threads);
                lane_main(lane, mode, backend, statics, rx, tx_out)
            })
            .map_err(|e| Error::Pipeline(format!("spawning lane {lane}: {e}")))?;
        Ok(DeviceLane { lane, tx: Some(tx), rx_out, worker: Some(worker) })
    }

    /// Submit a chunk (blocks when both device buffers are occupied —
    /// the paper's `cu_send_wait`).
    pub fn submit(&self, item: DevIn) -> Result<()> {
        self.tx
            .as_ref()
            .expect("lane already closed")
            .send(item)
            .map_err(|_| Error::Pipeline(format!("lane {} died", self.lane)))
    }

    /// Close the input side; the lane drains and exits.
    pub fn close(&mut self) {
        self.tx.take();
    }

    /// Join the lane, returning its device-side metrics.
    pub fn join(mut self) -> Result<Metrics> {
        self.close();
        match self.worker.take() {
            Some(w) => w
                .join()
                .map_err(|_| Error::Pipeline(format!("lane {} panicked", self.lane)))?,
            None => Ok(Metrics::new()),
        }
    }
}

fn lane_main(
    lane: usize,
    mode: OffloadMode,
    backend: Backend,
    st: LaneStatics,
    rx: Receiver<DevIn>,
    tx_out: std::sync::mpsc::Sender<DevOut>,
) -> Result<Metrics> {
    let mut metrics = Metrics::new();
    // PJRT client + executable live on this thread (not Send). The
    // constant inputs (L, Dinv, X̃_L, ỹ, S_TL, r̃_T) are converted to XLA
    // literals ONCE here — the paper's "send L once, keep it on the GPU"
    // (§3); only the block tensor crosses per call. §Perf: this removed
    // the dominant per-block copy at small n.
    let mut engine = None;
    if let Backend::Pjrt { entry } = &backend {
        let mut e = Engine::cpu()?;
        e.load(entry)?; // compile up front, not on the first block
        let statics = build_static_literals(mode, &st, entry)?;
        engine = Some((e, statics));
    }
    while let Ok(DevIn { block, buf, live }) = rx.recv() {
        let t0 = Instant::now();
        let (outs, inbuf) = match &backend {
            Backend::Pjrt { entry } => {
                let (eng, statics) = engine.as_mut().expect("engine initialized");
                process_pjrt(mode, &st, eng, statics, entry, buf, live)?
            }
            Backend::Native => process_native(mode, &st, buf, live)?,
        };
        let compute_secs = t0.elapsed().as_secs_f64();
        metrics.add(Phase::DeviceCompute, t0.elapsed());
        if tx_out.send(DevOut { block, lane, inbuf, outs, compute_secs }).is_err() {
            break; // coordinator went away
        }
    }
    Ok(metrics)
}

/// Convert the constant artifact inputs to literals, once per lane.
fn build_static_literals(
    mode: OffloadMode,
    st: &LaneStatics,
    entry: &ArtifactEntry,
) -> Result<Vec<xla::Literal>> {
    let (n, pl) = (st.n, st.pl);
    let nb = entry.nb;
    let lit = |dims: Vec<i64>, data: &[f64]| {
        crate::runtime::exec::to_literal(&HostTensor::new(dims, data.to_vec())?)
    };
    let mut out = vec![
        lit(vec![n as i64, n as i64], &st.l_row)?,
        lit(vec![n as i64, nb as i64], &st.dinv_row)?,
    ];
    if matches!(mode, OffloadMode::Block | OffloadMode::BlockFull) {
        out.push(lit(vec![n as i64, pl as i64], &st.xlt_row)?);
        out.push(lit(vec![n as i64], &st.yt)?);
    }
    if matches!(mode, OffloadMode::BlockFull) {
        out.push(lit(vec![pl as i64, pl as i64], &st.stl_row)?);
        out.push(lit(vec![pl as i64], &st.rtop)?);
    }
    Ok(out)
}

/// Execute the AOT artifact for one chunk and unpack per mode.
fn process_pjrt(
    mode: OffloadMode,
    st: &LaneStatics,
    engine: &mut Engine,
    statics: &[xla::Literal],
    entry: &ArtifactEntry,
    buf: Vec<f64>,
    live: usize,
) -> Result<(LaneOutputs, Vec<f64>)> {
    let (n, pl, mb) = (st.n, st.pl, st.mb);
    // Only the block crosses per call ("cu_send"); constants are cached.
    // `to_literal` copies, so the chunk buffer survives for recycling.
    let xb = HostTensor::new(vec![mb as i64, n as i64], buf)?;
    let xb_lit = crate::runtime::exec::to_literal(&xb)?;
    let inbuf = xb.data;
    let mut lits: Vec<&xla::Literal> = statics.iter().collect();
    lits.push(&xb_lit);
    let exe = engine.load(entry)?;
    let mut outs = exe.run_literals(&lits)?;
    let unpack = |t: HostTensor| t.data;
    let result = match mode {
        OffloadMode::Trsm => {
            let xbt = unpack(take(&mut outs, 0)?);
            // (mb, n) row-major == (n, mb) col-major; keep live columns.
            LaneOutputs::Xbt(Matrix::from_vec(n, live, xbt[..n * live].to_vec())?)
        }
        OffloadMode::Block => {
            let xbt = unpack(take(&mut outs, 0)?);
            let g_rows = unpack(take(&mut outs, 0)?); // (mb, pl) row-major
            let rb = unpack(take(&mut outs, 0)?);
            let d = unpack(take(&mut outs, 0)?);
            let mut g = Matrix::zeros(pl, live);
            for j in 0..live {
                for k in 0..pl {
                    g.set(k, j, g_rows[j * pl + k]);
                }
            }
            LaneOutputs::Reductions {
                xbt: Matrix::from_vec(n, live, xbt[..n * live].to_vec())?,
                g,
                rb: rb[..live].to_vec(),
                d: d[..live].to_vec(),
            }
        }
        OffloadMode::BlockFull => {
            let r_rows = unpack(take(&mut outs, 0)?); // (mb, p) row-major
            let p = pl + 1;
            LaneOutputs::Solutions(Matrix::from_vec(p, live, r_rows[..p * live].to_vec())?)
        }
    };
    Ok((result, inbuf))
}

fn take(v: &mut Vec<HostTensor>, i: usize) -> Result<HostTensor> {
    if v.is_empty() {
        return Err(Error::Runtime("artifact returned fewer outputs than expected".into()));
    }
    Ok(v.remove(i))
}

/// Native (in-crate) equivalent of the artifact, for artifact-free runs.
fn process_native(
    mode: OffloadMode,
    st: &LaneStatics,
    buf: Vec<f64>,
    live: usize,
) -> Result<(LaneOutputs, Vec<f64>)> {
    let n = st.n;
    // The chunk buffer is col-major (n, mb); solve only the live columns.
    let mut xbt = Matrix::from_vec(n, live, buf[..n * live].to_vec())?;
    trsm_lower_left(&st.l, &mut xbt)?;
    let outs = match mode {
        OffloadMode::Trsm => LaneOutputs::Xbt(xbt),
        OffloadMode::Block => {
            let mut g = Matrix::zeros(st.pl, live);
            crate::linalg::gemm(1.0, &st.pre.xl_tt, &xbt, 0.0, &mut g)?;
            let rb: Vec<f64> = (0..live).map(|j| crate::linalg::dot(xbt.col(j), &st.yt)).collect();
            let d: Vec<f64> = (0..live).map(|j| crate::linalg::sumsq(xbt.col(j))).collect();
            LaneOutputs::Reductions { xbt, g, rb, d }
        }
        OffloadMode::BlockFull => {
            let mut out = Matrix::zeros(st.pl + 1, live);
            let mut scratch = crate::gwas::sloop::SloopScratch::new(st.pl);
            crate::gwas::sloop::sloop_block(&st.pre, &xbt, &mut scratch, &mut out)?;
            LaneOutputs::Solutions(out)
        }
    };
    Ok((outs, buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::preprocess::preprocess;
    use crate::gwas::problem::{Dims, Problem};

    fn setup(n: usize, pl: usize, m: usize) -> (Problem, Preprocessed) {
        let prob = Problem::synthetic(Dims::new(n, pl, m).unwrap(), 3).unwrap();
        let pre = preprocess(&prob.m, &prob.xl, &prob.y, 8).unwrap();
        (prob, pre)
    }

    /// Pack columns [c0, c0+live) of xr into a padded chunk buffer.
    fn chunk(prob: &Problem, c0: usize, live: usize, mb: usize) -> Vec<f64> {
        let n = prob.dims.n;
        let mut buf = vec![0.0; n * mb];
        for j in 0..live {
            buf[j * n..(j + 1) * n].copy_from_slice(prob.xr.col(c0 + j));
        }
        buf
    }

    #[test]
    fn native_lane_trsm_roundtrip() {
        let (prob, pre) = setup(24, 3, 8);
        let lane = DeviceLane::spawn(0, OffloadMode::Trsm, Backend::Native, &pre, 4, 1, 2).unwrap();
        lane.submit(DevIn { block: 0, buf: chunk(&prob, 0, 4, 4), live: 4 }).unwrap();
        let out = lane.rx_out.recv().unwrap();
        assert_eq!(out.block, 0);
        assert_eq!(out.inbuf.len(), 24 * 4);
        match out.outs {
            LaneOutputs::Xbt(xbt) => {
                // L @ xbt == original columns
                for j in 0..4 {
                    let lx = crate::linalg::gemv_n(&pre.l, xbt.col(j)).unwrap();
                    for i in 0..24 {
                        assert!((lx[i] - prob.xr.get(i, j)).abs() < 1e-9);
                    }
                }
            }
            _ => panic!("wrong output kind"),
        }
        let metrics = lane.join().unwrap();
        assert_eq!(metrics.count(crate::coordinator::metrics::Phase::DeviceCompute), 1);
    }

    #[test]
    fn native_lane_blockfull_matches_incore() {
        let (prob, pre) = setup(20, 2, 6);
        let lane =
            DeviceLane::spawn(0, OffloadMode::BlockFull, Backend::Native, &pre, 6, 1, 2).unwrap();
        lane.submit(DevIn { block: 0, buf: chunk(&prob, 0, 6, 6), live: 6 }).unwrap();
        let out = lane.rx_out.recv().unwrap();
        let want = crate::gwas::solve_incore(&prob).unwrap();
        match out.outs {
            LaneOutputs::Solutions(r) => assert!(r.max_abs_diff(&want) < 1e-9),
            _ => panic!("wrong output kind"),
        }
        lane.join().unwrap();
    }

    #[test]
    fn padded_tail_columns_are_dropped() {
        let (prob, pre) = setup(16, 2, 3);
        let lane = DeviceLane::spawn(0, OffloadMode::Trsm, Backend::Native, &pre, 8, 1, 2).unwrap();
        lane.submit(DevIn { block: 0, buf: chunk(&prob, 0, 3, 8), live: 3 }).unwrap();
        let out = lane.rx_out.recv().unwrap();
        match out.outs {
            LaneOutputs::Xbt(xbt) => assert_eq!(xbt.cols(), 3),
            _ => panic!(),
        }
        lane.join().unwrap();
    }

    #[test]
    fn lane_processes_stream_in_order() {
        let (prob, pre) = setup(16, 2, 8);
        let lane = DeviceLane::spawn(0, OffloadMode::Trsm, Backend::Native, &pre, 2, 1, 2).unwrap();
        // More submissions than device buffers: exercises backpressure.
        let feeder = std::thread::spawn({
            let chunks: Vec<Vec<f64>> = (0..4).map(|b| chunk(&prob, b * 2, 2, 2)).collect();
            let tx = lane.tx.as_ref().unwrap().clone();
            move || {
                for (b, c) in chunks.into_iter().enumerate() {
                    tx.send(DevIn { block: b as u64, buf: c, live: 2 }).unwrap();
                }
            }
        });
        for want in 0..4u64 {
            let out = lane.rx_out.recv().unwrap();
            assert_eq!(out.block, want);
        }
        feeder.join().unwrap();
        lane.join().unwrap();
    }
}
