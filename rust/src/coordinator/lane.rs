//! Device lanes: one worker thread per (emulated) GPU.
//!
//! Each lane owns its PJRT client and compiled executable — the analogue
//! of one CUDA context per device — and pulls work from a bounded channel
//! whose depth-1 queue plus the in-flight item realize the paper's **two
//! device buffers**: one block computing (`α`) while the next is staged
//! (`β`). A third submission blocks the coordinator, which is precisely
//! the paper's `cu_send_wait`.
//!
//! Backends:
//! * [`Backend::Pjrt`] — execute the AOT HLO artifact (the shipped path).
//! * [`Backend::Native`] — same math with the in-crate linalg; lets the
//!   coordinator logic be tested without artifacts and serves as the
//!   apples-to-apples CPU reference for lane overhead.

use crate::coordinator::metrics::{Metrics, Phase};
use crate::error::{Error, Result};
use crate::gwas::preprocess::Preprocessed;
use crate::linalg::{trsm_lower_left, Matrix};
use crate::runtime::{dinv_to_rowmajor, matrix_to_rowmajor, ArtifactEntry, Engine, HostTensor};
use crate::storage::BlockSlice;
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// How much of the per-block math the device executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadMode {
    /// Paper mode: device does only the trsm; CPU runs the full S-loop.
    Trsm,
    /// Fused: device also produces the S-loop reductions (G, rb, d).
    Block,
    /// Full offload: device returns final solutions r (ablation).
    BlockFull,
}

impl OffloadMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            OffloadMode::Trsm => "trsm",
            OffloadMode::Block => "block",
            OffloadMode::BlockFull => "blockfull",
        }
    }
}

/// Compute backend for a lane.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Execute the AOT artifact found in this manifest entry.
    Pjrt { entry: ArtifactEntry },
    /// In-crate linalg (no PJRT). `nb` mirrors the artifact block size.
    Native,
}

/// Work item: one per-GPU chunk of a host block — a borrowed view into
/// the shared slab the disk read landed in (`(n, live)` col-major, no
/// padding: the PJRT path pads at the literal boundary). Holding it
/// keeps the slab alive; the lane drops it as soon as the chunk is
/// computed, releasing the slab back toward its pool.
pub struct DevIn {
    /// Global block index.
    pub block: u64,
    /// Zero-copy chunk view, `n * live` elements.
    pub view: BlockSlice,
    /// Live columns in this chunk.
    pub live: usize,
}

/// Lane result for one chunk. (No input buffer rides back: the view is
/// dropped lane-side — releasing a reference *is* the buffer rotation.)
pub struct DevOut {
    pub block: u64,
    pub lane: usize,
    /// Mode-dependent outputs (see `process`).
    pub outs: LaneOutputs,
    /// Device-side compute seconds for this chunk.
    pub compute_secs: f64,
    /// Host bytes the lane memcpy'd to stage the view into its
    /// backend's input format: 0 for native (the kernels read the view
    /// in place), `n·mb·8` for PJRT (the literal-boundary pad+copy).
    /// The coordinator tallies this under `Counter::BytesCopied`.
    pub staged_copy_bytes: u64,
}

/// Outputs by offload mode, always truncated to the live columns.
pub enum LaneOutputs {
    /// `Trsm`: solved chunk `X̃_b`, col-major `(n, live)`.
    Xbt(Matrix),
    /// `Block`: `(X̃_b, G (pl×live), rb, d)`. `rb` is SNP-major
    /// `live·t` (trait `k` of SNP `j` at `j·t + k`) — the layout
    /// [`sloop_from_reductions_into`](crate::gwas::sloop_from_reductions_into)
    /// consumes.
    Reductions { xbt: Matrix, g: Matrix, rb: Vec<f64>, d: Vec<f64> },
    /// `BlockFull`: solutions, col-major `(p·t, live)` — trait `k`'s
    /// `p`-vector stacked at rows `[k·p, (k+1)·p)`.
    Solutions(Matrix),
}

/// Row-major conversions of the constant artifact inputs — built only
/// for PJRT lanes (XLA literals are row-major; the in-crate matrices
/// are col-major).
struct PjrtRows {
    l_row: Vec<f64>,
    dinv_row: Vec<f64>,
    xlt_row: Vec<f64>,
    stl_row: Vec<f64>,
}

/// Static data each lane needs. All lanes share one refcounted
/// [`Preprocessed`] (`L`, `X̃_L`, `ỹ`, `S_TL`, `r̃_T`, …) instead of
/// deep-cloning it per lane — at paper scale the Cholesky factor alone
/// is `n²` f64, and it is read-only for the stream's whole life.
struct LaneStatics {
    n: usize,
    pl: usize,
    mb: usize,
    pre: Arc<Preprocessed>,
    /// `Some` only for PJRT backends.
    rows: Option<PjrtRows>,
}

/// A running device lane.
pub struct DeviceLane {
    pub lane: usize,
    tx: Option<SyncSender<DevIn>>,
    pub rx_out: Receiver<DevOut>,
    worker: Option<JoinHandle<Result<Metrics>>>,
}

impl DeviceLane {
    /// Spawn lane `lane` with chunk width `mb` columns. `threads` is the
    /// lane's compute-thread budget — its share of the host cores (see
    /// `PipelineConfig::threads`); the native trsm/gemm kernels fan out
    /// up to that many workers. 0 = inherit the process-wide pool size.
    /// `depth` is the device-buffer count (paper: 2): the submission
    /// channel holds `depth - 1` staged chunks plus the one in flight, so
    /// submission `depth + 1` blocks — the paper's `cu_send_wait`.
    pub fn spawn(
        lane: usize,
        mode: OffloadMode,
        backend: Backend,
        pre: &Arc<Preprocessed>,
        mb: usize,
        threads: usize,
        depth: usize,
    ) -> Result<DeviceLane> {
        let n = pre.l.rows();
        let pl = pre.xl_t.cols();
        // The row-major literal inputs are the only per-lane copies left
        // — and only PJRT lanes pay them; native lanes borrow `pre`.
        let rows = if matches!(backend, Backend::Pjrt { .. }) {
            let dinv_row = pre
                .dinv
                .as_ref()
                .map(|d| dinv_to_rowmajor(d, pre.dinv_nb, n))
                .unwrap_or_default();
            if dinv_row.is_empty() {
                return Err(Error::Config(
                    "PJRT backend needs preprocess(dinv_nb > 0) matching the artifact".into(),
                ));
            }
            Some(PjrtRows {
                l_row: matrix_to_rowmajor(&pre.l),
                dinv_row,
                xlt_row: matrix_to_rowmajor(&pre.xl_t),
                stl_row: matrix_to_rowmajor(&pre.stl),
            })
        } else {
            None
        };
        let statics = LaneStatics { n, pl, mb, pre: Arc::clone(pre), rows };
        if depth < 2 {
            return Err(Error::Config("device buffer depth must be ≥ 2".into()));
        }
        // Bounded queue of depth-1 + the item being processed = `depth`
        // device buffers (paper default: 2).
        let (tx, rx) = sync_channel::<DevIn>(depth - 1);
        let (tx_out, rx_out) = channel::<DevOut>();
        let worker = std::thread::Builder::new()
            .name(format!("cugwas-lane{lane}"))
            .spawn(move || {
                let _budget = crate::util::threads::with_budget(threads);
                lane_main(lane, mode, backend, statics, rx, tx_out)
            })
            .map_err(|e| Error::Pipeline(format!("spawning lane {lane}: {e}")))?;
        Ok(DeviceLane { lane, tx: Some(tx), rx_out, worker: Some(worker) })
    }

    /// Submit a chunk (blocks when both device buffers are occupied —
    /// the paper's `cu_send_wait`).
    pub fn submit(&self, item: DevIn) -> Result<()> {
        self.tx
            .as_ref()
            .expect("lane already closed")
            .send(item)
            .map_err(|_| Error::Pipeline(format!("lane {} died", self.lane)))
    }

    /// Non-blocking submit: `Full` hands the chunk back so the
    /// coordinator can drain results (the S-loop of block `b-1`
    /// overlapping the trsm of `b`) instead of idling in `cu_send_wait`.
    pub fn try_submit(&self, item: DevIn) -> std::result::Result<(), TrySendError<DevIn>> {
        self.tx.as_ref().expect("lane already closed").try_send(item)
    }

    /// Close the input side; the lane drains and exits.
    pub fn close(&mut self) {
        self.tx.take();
    }

    /// Join the lane, returning its device-side metrics.
    pub fn join(mut self) -> Result<Metrics> {
        self.close();
        match self.worker.take() {
            Some(w) => w
                .join()
                .map_err(|_| Error::Pipeline(format!("lane {} panicked", self.lane)))?,
            None => Ok(Metrics::new()),
        }
    }
}

fn lane_main(
    lane: usize,
    mode: OffloadMode,
    backend: Backend,
    st: LaneStatics,
    rx: Receiver<DevIn>,
    tx_out: std::sync::mpsc::Sender<DevOut>,
) -> Result<Metrics> {
    let mut metrics = Metrics::new();
    // PJRT client + executable live on this thread (not Send). The
    // constant inputs (L, Dinv, X̃_L, ỹ, S_TL, r̃_T) are converted to XLA
    // literals ONCE here — the paper's "send L once, keep it on the GPU"
    // (§3); only the block tensor crosses per call. §Perf: this removed
    // the dominant per-block copy at small n.
    let mut engine = None;
    if let Backend::Pjrt { entry } = &backend {
        let mut e = Engine::cpu()?;
        e.load(entry)?; // compile up front, not on the first block
        let statics = build_static_literals(mode, &st, entry)?;
        engine = Some((e, statics));
    }
    // One reusable staging buffer for the PJRT literal boundary —
    // allocated on the first chunk, recycled for the lane's whole life
    // (the zero-copy plane's fixed-pool discipline, lane-side).
    let mut staging: Vec<f64> = Vec::new();
    while let Ok(DevIn { block, view, live }) = rx.recv() {
        // Chaos harness: a wedged lane releases its view, sleeps through
        // the coordinator's watchdog window, and never reports the chunk
        // — the stuck-device failure the supervision path must recover
        // from (one relaxed load when faults are off).
        if let Some(nap) = crate::storage::fault::lane_wedge(lane) {
            drop(view);
            std::thread::sleep(nap);
            continue;
        }
        let t0 = Instant::now();
        let (outs, staged_copy_bytes) = match &backend {
            Backend::Pjrt { entry } => {
                let (eng, statics) = engine.as_mut().expect("engine initialized");
                process_pjrt(mode, &st, eng, statics, entry, &view, &mut staging)?
            }
            Backend::Native => (process_native(mode, &st, view.as_slice(), live)?, 0),
        };
        // Release the slab reference before reporting the result: once
        // the chunk is computed, nothing here still needs the block.
        drop(view);
        let elapsed = t0.elapsed();
        let compute_secs = elapsed.as_secs_f64();
        // Local only: the coordinator re-records this chunk's compute
        // time from `compute_secs` when it retires the result, and that
        // is the copy the telemetry plane exports.
        metrics.add_local(Phase::DeviceCompute, elapsed);
        crate::telemetry::span(
            "device_compute",
            "lane",
            crate::telemetry::trace::TID_LANE0 + lane as u32,
            t0,
            elapsed,
            &[("block", block), ("lane", lane as u64)],
        );
        if tx_out.send(DevOut { block, lane, outs, compute_secs, staged_copy_bytes }).is_err() {
            break; // coordinator went away
        }
    }
    Ok(metrics)
}

/// Convert the constant artifact inputs to literals, once per lane.
fn build_static_literals(
    mode: OffloadMode,
    st: &LaneStatics,
    entry: &ArtifactEntry,
) -> Result<Vec<xla::Literal>> {
    let (n, pl) = (st.n, st.pl);
    let nb = entry.nb;
    let rows = st.rows.as_ref().expect("pjrt lanes carry row-major statics");
    let lit = |dims: Vec<i64>, data: &[f64]| {
        crate::runtime::exec::to_literal(&HostTensor::new(dims, data.to_vec())?)
    };
    let mut out = vec![
        lit(vec![n as i64, n as i64], &rows.l_row)?,
        lit(vec![n as i64, nb as i64], &rows.dinv_row)?,
    ];
    // PJRT artifacts are compiled for a single phenotype (validate()
    // rejects traits > 1 on this backend), so trait column 0 is the run.
    if matches!(mode, OffloadMode::Block | OffloadMode::BlockFull) {
        out.push(lit(vec![n as i64, pl as i64], &rows.xlt_row)?);
        out.push(lit(vec![n as i64], st.pre.y_t.col(0))?);
    }
    if matches!(mode, OffloadMode::BlockFull) {
        out.push(lit(vec![pl as i64, pl as i64], &rows.stl_row)?);
        out.push(lit(vec![pl as i64], st.pre.rtop.col(0))?);
    }
    Ok(out)
}

/// Execute the AOT artifact for one chunk and unpack per mode. Returns
/// the outputs plus the staged bytes: PJRT is the one backend that must
/// copy — the live view is padded to the artifact's chunk width at the
/// literal boundary (the device cannot borrow host slabs). `staging` is
/// the lane's reusable pad buffer: taken here, handed back after the
/// literal is built, so the hot path never allocates.
fn process_pjrt(
    mode: OffloadMode,
    st: &LaneStatics,
    engine: &mut Engine,
    statics: &[xla::Literal],
    entry: &ArtifactEntry,
    view: &BlockSlice,
    staging: &mut Vec<f64>,
) -> Result<(LaneOutputs, u64)> {
    let (n, pl, mb) = (st.n, st.pl, st.mb);
    let live = view.len() / n;
    // Only the block crosses per call ("cu_send"); constants are cached.
    // The pad+copy into the literal's layout is the single remaining
    // host copy of the plane — reported for `Counter::BytesCopied`.
    // The tail fill only does work on a short final chunk.
    let mut padded = std::mem::take(staging);
    padded.resize(n * mb, 0.0);
    padded[..n * live].copy_from_slice(view.as_slice());
    padded[n * live..].fill(0.0);
    let staged_bytes = (n * mb * std::mem::size_of::<f64>()) as u64;
    let xb = HostTensor::new(vec![mb as i64, n as i64], padded)?;
    let xb_lit = crate::runtime::exec::to_literal(&xb)?;
    *staging = xb.data;
    let mut lits: Vec<&xla::Literal> = statics.iter().collect();
    lits.push(&xb_lit);
    let exe = engine.load(entry)?;
    let mut outs = exe.run_literals(&lits)?;
    let unpack = |t: HostTensor| t.data;
    let result = match mode {
        OffloadMode::Trsm => {
            let xbt = unpack(take(&mut outs, 0)?);
            // (mb, n) row-major == (n, mb) col-major; keep live columns.
            LaneOutputs::Xbt(Matrix::from_vec(n, live, xbt[..n * live].to_vec())?)
        }
        OffloadMode::Block => {
            let xbt = unpack(take(&mut outs, 0)?);
            let g_rows = unpack(take(&mut outs, 0)?); // (mb, pl) row-major
            let rb = unpack(take(&mut outs, 0)?);
            let d = unpack(take(&mut outs, 0)?);
            let mut g = Matrix::zeros(pl, live);
            for j in 0..live {
                for k in 0..pl {
                    g.set(k, j, g_rows[j * pl + k]);
                }
            }
            LaneOutputs::Reductions {
                xbt: Matrix::from_vec(n, live, xbt[..n * live].to_vec())?,
                g,
                rb: rb[..live].to_vec(),
                d: d[..live].to_vec(),
            }
        }
        OffloadMode::BlockFull => {
            let r_rows = unpack(take(&mut outs, 0)?); // (mb, p) row-major
            let p = pl + 1;
            LaneOutputs::Solutions(Matrix::from_vec(p, live, r_rows[..p * live].to_vec())?)
        }
    };
    Ok((result, staged_bytes))
}

fn take(v: &mut Vec<HostTensor>, i: usize) -> Result<HostTensor> {
    if v.is_empty() {
        return Err(Error::Runtime("artifact returned fewer outputs than expected".into()));
    }
    Ok(v.remove(i))
}

/// Native (in-crate) equivalent of the artifact, for artifact-free runs.
/// Computes straight from the shared view: the trsm's input-to-output
/// step (solving into its own `X̃_b` matrix) is the first compute op, not
/// a staging copy — the immutable slab is never written.
fn process_native(
    mode: OffloadMode,
    st: &LaneStatics,
    view: &[f64],
    live: usize,
) -> Result<LaneOutputs> {
    let n = st.n;
    let pre = &*st.pre;
    // The view is col-major (n, live): solve it into the output matrix.
    let mut xbt = Matrix::from_vec(n, live, view.to_vec())?;
    trsm_lower_left(&pre.l, &mut xbt)?;
    let outs = match mode {
        OffloadMode::Trsm => LaneOutputs::Xbt(xbt),
        OffloadMode::Block => {
            let mut g = Matrix::zeros(st.pl, live);
            crate::linalg::gemm(1.0, &pre.xl_tt, &xbt, 0.0, &mut g)?;
            let t = pre.traits();
            // SNP-major per-trait reductions, one `dot` per (SNP, trait)
            // — the same accumulation order the CPU S-loop uses, so the
            // fused path stays bit-identical to the Trsm path per trait.
            let mut rb = Vec::with_capacity(live * t);
            for j in 0..live {
                for k in 0..t {
                    rb.push(crate::linalg::dot(xbt.col(j), pre.y_t.col(k)));
                }
            }
            let d: Vec<f64> = (0..live).map(|j| crate::linalg::sumsq(xbt.col(j))).collect();
            LaneOutputs::Reductions { xbt, g, rb, d }
        }
        OffloadMode::BlockFull => {
            let mut out = Matrix::zeros((st.pl + 1) * pre.traits(), live);
            let mut scratch = crate::gwas::sloop::SloopScratch::new(st.pl);
            crate::gwas::sloop::sloop_block(pre, &xbt, &mut scratch, &mut out)?;
            LaneOutputs::Solutions(out)
        }
    };
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gwas::preprocess::preprocess;
    use crate::gwas::problem::{Dims, Problem};
    use crate::storage::SlabPool;

    fn setup(n: usize, pl: usize, m: usize) -> (Problem, Arc<Preprocessed>) {
        let prob = Problem::synthetic(Dims::new(n, pl, m).unwrap(), 3).unwrap();
        let pre = preprocess(&prob.m, &prob.xl, &prob.y, 8).unwrap();
        (prob, Arc::new(pre))
    }

    /// Publish columns [c0, c0+live) of xr as a shared block and hand
    /// back the whole-block view (what the coordinator does per chunk).
    fn chunk(pool: &SlabPool, prob: &Problem, c0: usize, live: usize) -> BlockSlice {
        let n = prob.dims.n;
        let mut bm = pool.take(n * live).unwrap();
        for j in 0..live {
            bm.as_mut_slice()[j * n..(j + 1) * n].copy_from_slice(prob.xr.col(c0 + j));
        }
        bm.publish().slice(0, n * live)
    }

    #[test]
    fn native_lane_trsm_roundtrip() {
        let (prob, pre) = setup(24, 3, 8);
        let pool = SlabPool::new(2, 24 * 4);
        let lane = DeviceLane::spawn(0, OffloadMode::Trsm, Backend::Native, &pre, 4, 1, 2).unwrap();
        lane.submit(DevIn { block: 0, view: chunk(&pool, &prob, 0, 4), live: 4 }).unwrap();
        let out = lane.rx_out.recv().unwrap();
        assert_eq!(out.block, 0);
        assert_eq!(out.staged_copy_bytes, 0, "native lanes compute from the view");
        match out.outs {
            LaneOutputs::Xbt(xbt) => {
                // L @ xbt == original columns
                for j in 0..4 {
                    let lx = crate::linalg::gemv_n(&pre.l, xbt.col(j)).unwrap();
                    for i in 0..24 {
                        assert!((lx[i] - prob.xr.get(i, j)).abs() < 1e-9);
                    }
                }
            }
            _ => panic!("wrong output kind"),
        }
        // The lane dropped its view before reporting: the slab is home.
        assert_eq!(pool.stats().free, 2);
        let metrics = lane.join().unwrap();
        assert_eq!(metrics.count(crate::coordinator::metrics::Phase::DeviceCompute), 1);
    }

    #[test]
    fn native_lane_blockfull_matches_incore() {
        let (prob, pre) = setup(20, 2, 6);
        let pool = SlabPool::new(2, 20 * 6);
        let lane =
            DeviceLane::spawn(0, OffloadMode::BlockFull, Backend::Native, &pre, 6, 1, 2).unwrap();
        lane.submit(DevIn { block: 0, view: chunk(&pool, &prob, 0, 6), live: 6 }).unwrap();
        let out = lane.rx_out.recv().unwrap();
        let want = crate::gwas::solve_incore(&prob).unwrap();
        match out.outs {
            LaneOutputs::Solutions(r) => assert!(r.max_abs_diff(&want) < 1e-9),
            _ => panic!("wrong output kind"),
        }
        lane.join().unwrap();
    }

    #[test]
    fn tail_chunk_narrower_than_the_lane_width_is_handled() {
        // mb = 8 but only 3 live columns: the view carries exactly the
        // live data (no padding on the zero-copy plane) and the output
        // is truncated to match.
        let (prob, pre) = setup(16, 2, 3);
        let pool = SlabPool::new(2, 16 * 8);
        let lane = DeviceLane::spawn(0, OffloadMode::Trsm, Backend::Native, &pre, 8, 1, 2).unwrap();
        lane.submit(DevIn { block: 0, view: chunk(&pool, &prob, 0, 3), live: 3 }).unwrap();
        let out = lane.rx_out.recv().unwrap();
        match out.outs {
            LaneOutputs::Xbt(xbt) => assert_eq!(xbt.cols(), 3),
            _ => panic!(),
        }
        lane.join().unwrap();
    }

    #[test]
    fn lane_processes_stream_in_order() {
        let (prob, pre) = setup(16, 2, 8);
        let pool = SlabPool::new(4, 16 * 2);
        let lane = DeviceLane::spawn(0, OffloadMode::Trsm, Backend::Native, &pre, 2, 1, 2).unwrap();
        // More submissions than device buffers: exercises backpressure.
        let feeder = std::thread::spawn({
            let chunks: Vec<BlockSlice> = (0..4).map(|b| chunk(&pool, &prob, b * 2, 2)).collect();
            let tx = lane.tx.as_ref().unwrap().clone();
            move || {
                for (b, c) in chunks.into_iter().enumerate() {
                    tx.send(DevIn { block: b as u64, view: c, live: 2 }).unwrap();
                }
            }
        });
        for want in 0..4u64 {
            let out = lane.rx_out.recv().unwrap();
            assert_eq!(out.block, want);
        }
        feeder.join().unwrap();
        lane.join().unwrap();
        assert_eq!(pool.stats().free, 4, "every view released");
    }

    #[test]
    fn try_submit_drain_loop_delivers_every_chunk() {
        // The coordinator's submit pattern: try_send, and on Full drain
        // one result before retrying (never idle in cu_send_wait). Six
        // chunks through a depth-2 lane must all come back, whatever
        // interleaving of Full bounces the timing produces.
        let (prob, pre) = setup(16, 2, 8);
        let pool = SlabPool::new(4, 16 * 2);
        let lane = DeviceLane::spawn(0, OffloadMode::Trsm, Backend::Native, &pre, 2, 1, 2).unwrap();
        let mut received = 0u64;
        for b in 0..6u64 {
            let mut item = DevIn { block: b, view: chunk(&pool, &prob, 0, 2), live: 2 };
            loop {
                match lane.try_submit(item) {
                    Ok(()) => break,
                    Err(TrySendError::Full(bounced)) => {
                        item = bounced;
                        let _ = lane.rx_out.recv().unwrap();
                        received += 1;
                    }
                    Err(TrySendError::Disconnected(_)) => panic!("lane died"),
                }
            }
        }
        while received < 6 {
            let _ = lane.rx_out.recv().unwrap();
            received += 1;
        }
        lane.join().unwrap();
        assert_eq!(pool.stats().free, 4, "every view released");
    }
}
