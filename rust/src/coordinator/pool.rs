//! Fixed-capacity buffer pools — the in-code form of the paper's
//! buffer rotation, used for the *result* ring (the write side).
//!
//! The paper rotates a fixed set of buffers by pointer swaps; in rust the
//! same discipline is ownership moving through the pipeline stages and
//! back into the pool. The pool *is* the backpressure mechanism: when all
//! buffers of a stage are in flight, the producer blocks — exactly the
//! stall the multibuffering analysis in §3.1 reasons about. Pool size is
//! therefore a first-class experiment knob (see `ablation_buffers`).
//!
//! The *read* side (the streamed `X_R` blocks) rotates through the
//! refcounted [`SlabPool`](crate::storage::SlabPool) instead: those
//! buffers are shared by reference with the block cache and the device
//! lanes, so their return to the pool is a refcount event, not an
//! ownership hand-back.

use std::collections::VecDeque;

/// A pool of same-capacity `Vec<f64>` buffers recycled through the
/// pipeline. Never grows: `take` on an empty pool returns `None` (callers
/// then drain downstream stages — see `pipeline.rs`).
#[derive(Debug)]
pub struct BufPool {
    bufs: VecDeque<Vec<f64>>,
    cap_each: usize,
    total: usize,
}

impl BufPool {
    /// `count` buffers of `cap_each` elements, pre-zeroed (pre-faulted).
    pub fn new(count: usize, cap_each: usize) -> Self {
        let bufs = (0..count).map(|_| vec![0.0; cap_each]).collect();
        BufPool { bufs, cap_each, total: count }
    }

    /// Take a buffer if one is free. Length is reset to full capacity.
    pub fn take(&mut self) -> Option<Vec<f64>> {
        self.bufs.pop_front().map(|mut b| {
            debug_assert!(b.capacity() >= self.cap_each);
            b.resize(self.cap_each, 0.0);
            b
        })
    }

    /// Return a buffer to the pool.
    ///
    /// Panics if the pool would exceed its configured size (a returned
    /// foreign buffer means the rotation invariant broke — fail loudly).
    pub fn put(&mut self, buf: Vec<f64>) {
        assert!(
            self.bufs.len() < self.total,
            "BufPool::put would exceed pool size {} — buffer leak or double-put",
            self.total
        );
        self.bufs.push_back(buf);
    }

    pub fn free(&self) -> usize {
        self.bufs.len()
    }

    pub fn in_flight(&self) -> usize {
        self.total - self.bufs.len()
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn cap_each(&self) -> usize {
        self.cap_each
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_until_empty_then_put_back() {
        let mut p = BufPool::new(3, 8);
        let a = p.take().unwrap();
        let b = p.take().unwrap();
        let c = p.take().unwrap();
        assert!(p.take().is_none());
        assert_eq!(p.in_flight(), 3);
        p.put(a);
        p.put(b);
        assert_eq!(p.free(), 2);
        p.put(c);
        assert_eq!(p.free(), 3);
    }

    #[test]
    fn buffers_are_zeroed_initially_and_full_length() {
        let mut p = BufPool::new(1, 5);
        let b = p.take().unwrap();
        assert_eq!(b.len(), 5);
        assert!(b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn take_restores_capacity_after_shrink() {
        let mut p = BufPool::new(1, 10);
        let mut b = p.take().unwrap();
        b.truncate(3); // stage shrank it (tail block)
        p.put(b);
        let b2 = p.take().unwrap();
        assert_eq!(b2.len(), 10);
    }

    #[test]
    #[should_panic(expected = "double-put")]
    fn overfilling_panics() {
        let mut p = BufPool::new(1, 4);
        p.put(vec![0.0; 4]);
    }
}
