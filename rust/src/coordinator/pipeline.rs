//! The cuGWAS streaming pipeline — paper Listing 1.3, live.
//!
//! ```text
//!        disk ──aio──▶ host ring (hb bufs) ──send──▶ device ring (db/lane)
//!                                                         │ trsm (+fused)
//!        disk ◀──aio── result bufs ◀──S-loop(CPU)◀──recv──┘
//! ```
//!
//! One coordinator thread drives the schedule; the I/O threads (storage
//! [`AioEngine`]) and the device lanes ([`DeviceLane`]) supply the
//! asynchrony. All steady-state buffers come from fixed pools
//! ([`BufPool`]) — the rotation discipline of the paper's Fig. 5, with
//! pool exhaustion providing the back-pressure (`aio_wait`,
//! `cu_send_wait`) the listing spells out explicitly.
//!
//! The S-loop for block `b-1` runs on the coordinator thread while the
//! lanes compute block `b` — the paper's pipelining — because lane results
//! are drained opportunistically between submissions.
//!
//! Since the autotuner landed, a run is a sequence of **segments**: the
//! work is a list of column windows, each segment streams a batch of them
//! under one block size, and (with [`PipelineConfig::adapt`] on) the
//! coordinator compares the live stall profile against the model between
//! segments and re-plans the block size for the remainder — journaling
//! every persisted window ([`journal`]) so `--resume` stays correct
//! across a mid-run switch.

use crate::coordinator::journal::{self, Journal};
use crate::coordinator::lane::{Backend, DevIn, DevOut, DeviceLane, LaneOutputs, OffloadMode};
use crate::coordinator::metrics::{Metrics, Phase};
use crate::coordinator::pool::BufPool;
use crate::devsim::{sloop_flops, trsm_flops};
use crate::error::{Error, Result};
use crate::gwas::preprocess::{preprocess, Preprocessed};
use crate::gwas::problem::Dims;
use crate::gwas::sloop::{sloop_block_into, sloop_from_reductions_into, SloopScratch};
use crate::linalg::Matrix;
use crate::runtime::{ArtifactEntry, ArtifactKey, Kind, Manifest};
use crate::storage::{
    dataset, AioEngine, AioHandle, AioStats, BlockCache, BlockKey, Header, Throttle, XrdFile,
};
use crate::tune::{replan_block, LiveObs};
use crate::util::threads;
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which compute backend the lanes use.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// In-crate linalg (no artifacts needed).
    Native,
    /// AOT HLO artifacts through PJRT.
    Pjrt { artifacts: PathBuf },
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Dataset directory (from `storage::generate`).
    pub dataset: PathBuf,
    /// SNP columns per iteration, across all lanes.
    pub block: usize,
    /// Emulated GPU count (device lanes).
    pub ngpus: usize,
    /// Host buffer count (paper: 3; 2 = the ablation).
    pub host_buffers: usize,
    /// Device buffers per lane (paper: 2; the autotuner may pick more).
    pub device_buffers: usize,
    pub mode: OffloadMode,
    pub backend: BackendKind,
    /// Optional bandwidth throttles emulating slower storage.
    pub read_throttle: Option<Throttle>,
    pub write_throttle: Option<Throttle>,
    /// Resume an interrupted run: column ranges journaled in `r.progress`
    /// are skipped (their results are already on disk). Studies at paper
    /// scale run for hours-to-days — a crash must not restart from zero.
    /// The journal header pins the run parameters; resuming with a
    /// different `block`/`m` is refused with [`Error::Config`].
    pub resume: bool,
    /// Shared block cache (the multi-study service hands the same
    /// `Arc` to every job): reads probe it first and misses populate it,
    /// so repeated studies over one dataset skip the HDD entirely.
    /// `None` (the default) streams straight from disk, as the paper does.
    pub cache: Option<Arc<BlockCache>>,
    /// Total compute threads for this run (0 = all cores). Partitioned
    /// between the device lanes and the coordinator-side S-loop: each of
    /// the `ngpus` lanes gets an equal share for its trsm/gemm kernels
    /// and the coordinator keeps the remainder, so a `serve` worker
    /// running on a slice of the machine doesn't fan its kernels out
    /// past its share. Note the floor: the pipeline always runs its
    /// `ngpus` lane threads plus the coordinator, so a budget below
    /// `ngpus + 1` clamps to one (serial) kernel worker per thread —
    /// it cannot shrink the pipeline's own `ngpus + 1` concurrency.
    pub threads: usize,
    /// Explicit kernel threads per lane (0 = the equal split above).
    /// The autotuner searches this split; a tuned profile pins it.
    pub lane_threads: usize,
    /// Re-plan the block size at segment boundaries from the live stall
    /// profile (read-starved → larger, compute-starved → smaller).
    /// Native backend only — PJRT artifacts are compiled per block size.
    pub adapt: bool,
    /// Blocks per adaptive segment (how often the re-planner looks).
    pub adapt_every: usize,
}

impl PipelineConfig {
    /// Sensible defaults for a dataset directory: paper topology
    /// (3 host buffers, 2 device buffers, 1 GPU, trsm offload, native
    /// backend, no adaptation).
    pub fn new(dataset: impl Into<PathBuf>, block: usize) -> Self {
        PipelineConfig {
            dataset: dataset.into(),
            block,
            ngpus: 1,
            host_buffers: 3,
            device_buffers: 2,
            mode: OffloadMode::Trsm,
            backend: BackendKind::Native,
            read_throttle: None,
            write_throttle: None,
            resume: false,
            cache: None,
            threads: 0,
            lane_threads: 0,
            adapt: false,
            adapt_every: 16,
        }
    }
}

/// Run summary.
#[derive(Debug)]
pub struct PipelineReport {
    pub blocks: usize,
    pub snps: usize,
    pub wall_secs: f64,
    pub snps_per_sec: f64,
    /// Coordinator-thread phase accounting + merged lane compute time.
    pub metrics: Metrics,
    /// Sum of device-side compute seconds across lanes.
    pub device_secs: f64,
    /// Adaptive block-size switches taken (0 without `adapt`).
    pub replans: usize,
}

/// Per-block assembly state: the result buffer filling up chunk by chunk.
struct BlockAssembly {
    buf: Vec<f64>,
    live_total: usize,
    chunks_left: usize,
}

/// Immutable per-run context shared by every segment.
struct RunCtx<'a> {
    cfg: &'a PipelineConfig,
    pre: &'a Preprocessed,
    backend_proto: &'a Option<ArtifactEntry>,
    reader: &'a AioEngine,
    writer: &'a AioEngine,
    cache_dataset: Option<String>,
    n: usize,
    p: usize,
}

/// Mutable streaming state of one segment.
struct SegmentState {
    host_pool: BufPool,
    result_pool: BufPool,
    chunk_pools: Vec<BufPool>,
    pending_writes: VecDeque<(u64, u64, AioHandle)>,
    completed: Vec<(u64, u64)>,
    assemblies: HashMap<u64, BlockAssembly>,
    live_of: HashMap<u64, usize>,
    retired: usize,
}

/// Pop up to `max_windows` column windows of at most `block` columns off
/// the remaining work list (splitting the front range as needed).
fn take_windows(
    remaining: &mut VecDeque<(u64, u64)>,
    block: u64,
    max_windows: usize,
) -> Vec<(u64, usize)> {
    let mut out = Vec::new();
    while out.len() < max_windows {
        let Some((c0, len)) = remaining.pop_front() else { break };
        let take = block.min(len);
        out.push((c0, take as usize));
        if take < len {
            remaining.push_front((c0 + take, len - take));
        }
    }
    out
}

/// Run the streaming solver over a dataset; results land in `r.xrd`.
pub fn run(cfg: &PipelineConfig) -> Result<PipelineReport> {
    validate(cfg)?;
    let (meta, kin, xl, y) = dataset::load_sidecars(&cfg.dataset)?;
    let dims = meta.dims;
    let n = dims.n;
    let p = dims.p();
    let mb_gpu = cfg.block / cfg.ngpus;

    // Resolve backend + the diagonal block size for preprocessing.
    let (backend_proto, dinv_nb) = match &cfg.backend {
        BackendKind::Native => (None, 0),
        BackendKind::Pjrt { artifacts } => {
            let manifest = Manifest::load(artifacts)?;
            let kind = match cfg.mode {
                OffloadMode::Trsm => Kind::Trsm,
                OffloadMode::Block => Kind::Block,
                OffloadMode::BlockFull => Kind::BlockFull,
            };
            let entry = manifest
                .get(&ArtifactKey { kind, n, pl: dims.pl, mb: mb_gpu })?
                .clone();
            let nb = entry.nb;
            (Some(entry), nb)
        }
    };

    // Core partition: each lane gets an equal share (or the tuned pin)
    // for its kernels, the coordinator keeps the remainder (both ≥ 1).
    let total = if cfg.threads == 0 { threads::available() } else { cfg.threads };
    let lane_threads = if cfg.lane_threads > 0 {
        cfg.lane_threads
    } else {
        (total / (cfg.ngpus + 1)).max(1)
    };
    let coord_threads = total.saturating_sub(lane_threads * cfg.ngpus).max(1);

    // Preprocessing (Listing 1.3 lines 1–7; seconds, excluded by the
    // paper from streaming timings but included in our wall clock). The
    // lanes don't exist yet, so it may use the full budget.
    let pre: Preprocessed = {
        let _full = threads::with_budget(total);
        preprocess(&kin, &xl, &y, dinv_nb)?
    };
    // From here on this thread runs the S-loop on its core share.
    let _coord_budget = threads::with_budget(coord_threads);

    // Storage engines (one I/O thread each — read and write devices).
    let paths = dataset::DatasetPaths::new(&cfg.dataset);
    let xr = XrdFile::open(&paths.xr())?.with_throttle(cfg.read_throttle);
    let r_header = Header::new(p as u64, dims.m as u64, cfg.block.min(dims.m) as u64, meta.seed)?;
    // Resume: validate the journal header (refusal on a parameter
    // mismatch — see `journal`), then reuse the results file when its
    // geometry matches; a missing/foreign results file restarts clean.
    let fresh = |paths: &dataset::DatasetPaths| -> Result<(XrdFile, Journal)> {
        let j = Journal::create(&paths.progress(), dims.m as u64, cfg.block as u64)?;
        Ok((XrdFile::create(&paths.results(), r_header)?, j))
    };
    let (rfile, mut journal, done_ranges) = if cfg.resume {
        let (journal, ranges) =
            Journal::open_resume(&paths.progress(), dims.m as u64, cfg.block as u64)?;
        match XrdFile::open_rw(&paths.results()) {
            Ok(f) if *f.header() == r_header => (f, journal, ranges),
            _ => {
                // Journaled progress points at a results file that no
                // longer matches — recompute everything.
                drop(journal);
                let (f, j) = fresh(&paths)?;
                (f, j, Vec::new())
            }
        }
    } else {
        let (f, j) = fresh(&paths)?;
        (f, j, Vec::new())
    };
    let rfile = rfile.with_throttle(cfg.write_throttle);
    let reader = AioEngine::new(xr);
    let writer = AioEngine::new(rfile);

    // Work list: the uncovered column ranges, streamed as block windows.
    let mut remaining: VecDeque<(u64, u64)> =
        journal::uncovered(dims.m as u64, &done_ranges).into();

    let cache_dataset: Option<String> = cfg
        .cache
        .as_ref()
        .map(|_| dataset::canonical_key(&cfg.dataset).to_string_lossy().into_owned());
    let ctx = RunCtx {
        cfg,
        pre: &pre,
        backend_proto: &backend_proto,
        reader: &reader,
        writer: &writer,
        cache_dataset,
        n,
        p,
    };

    let mut metrics = Metrics::new();
    let mut scratch = SloopScratch::new(dims.pl);
    let mut device_secs = 0.0f64;
    let mut windows_done = 0usize;
    let mut replans = 0usize;
    let mut plan_block = cfg.block;
    let seg_windows = if cfg.adapt { cfg.adapt_every } else { usize::MAX };
    let t_wall = Instant::now();

    loop {
        let items = take_windows(&mut remaining, plan_block as u64, seg_windows);
        if items.is_empty() {
            break;
        }
        let seg_cols: usize = items.iter().map(|&(_, live)| live).sum();
        let before = SegmentSnapshot::take(&metrics, reader.stats());
        let t_seg = Instant::now();
        device_secs += run_segment(
            &ctx,
            plan_block,
            lane_threads,
            &items,
            &mut metrics,
            &mut scratch,
            &mut journal,
        )?;
        windows_done += items.len();

        if cfg.adapt && !remaining.is_empty() {
            let t0 = Instant::now();
            let obs = before.observe(
                &metrics,
                reader.stats(),
                t_seg.elapsed().as_secs_f64(),
                n,
                dims.pl,
                seg_cols,
            );
            let left: u64 = remaining.iter().map(|&(_, len)| len).sum();
            let rdims = Dims::new(n, dims.pl, left as usize)?;
            if let Some(nb) = replan_block(
                &obs,
                rdims,
                plan_block,
                cfg.ngpus,
                cfg.host_buffers,
                cfg.device_buffers,
            ) {
                crate::log_info!(
                    "pipeline",
                    "adapt: block {plan_block} → {nb} (read {:.0}%, recv {:.0}%, disk {:.0} MB/s)",
                    100.0 * obs.read_wait_secs / obs.wall_secs.max(1e-12),
                    100.0 * obs.recv_wait_secs / obs.wall_secs.max(1e-12),
                    obs.disk_mbps
                );
                plan_block = nb;
                replans += 1;
            }
            metrics.add(Phase::Replan, t0.elapsed());
        }
    }

    let wall_secs = t_wall.elapsed().as_secs_f64();
    Ok(PipelineReport {
        blocks: windows_done,
        snps: dims.m,
        wall_secs,
        snps_per_sec: dims.m as f64 / wall_secs.max(1e-12),
        metrics,
        device_secs,
        replans,
    })
}

/// Phase/engine counters at a segment boundary, for live-rate deltas.
struct SegmentSnapshot {
    read_wait: Duration,
    recv_wait: Duration,
    send: Duration,
    sloop: Duration,
    device: Duration,
    reader: AioStats,
}

impl SegmentSnapshot {
    fn take(metrics: &Metrics, reader: AioStats) -> SegmentSnapshot {
        SegmentSnapshot {
            read_wait: metrics.total(Phase::ReadWait),
            recv_wait: metrics.total(Phase::RecvWait),
            send: metrics.total(Phase::Send),
            sloop: metrics.total(Phase::Sloop),
            device: metrics.total(Phase::DeviceCompute),
            reader,
        }
    }

    /// Turn the counter deltas since this snapshot into live rates.
    fn observe(
        &self,
        metrics: &Metrics,
        reader: AioStats,
        wall_secs: f64,
        n: usize,
        pl: usize,
        cols: usize,
    ) -> LiveObs {
        let secs = |now: Duration, then: Duration| now.saturating_sub(then).as_secs_f64();
        let rate = |units: f64, secs: f64| if secs > 0.0 { units / secs } else { 0.0 };
        let device = secs(metrics.total(Phase::DeviceCompute), self.device);
        let sloop = secs(metrics.total(Phase::Sloop), self.sloop);
        let send = secs(metrics.total(Phase::Send), self.send);
        LiveObs {
            wall_secs,
            read_wait_secs: secs(metrics.total(Phase::ReadWait), self.read_wait),
            recv_wait_secs: secs(metrics.total(Phase::RecvWait), self.recv_wait),
            disk_mbps: reader.since(&self.reader).mbps(),
            trsm_gflops: rate(trsm_flops(n, cols), device) / 1e9,
            cpu_gflops: rate(sloop_flops(n, pl, cols), sloop) / 1e9,
            pcie_gbps: rate((n * cols * 8) as f64, send) / 1e9,
        }
    }
}

/// Retire one lane result: run the CPU tail, fill the assembly, and
/// kick the write when the block is complete.
fn process_out(
    ctx: &RunCtx<'_>,
    mb_gpu: usize,
    out: DevOut,
    st: &mut SegmentState,
    metrics: &mut Metrics,
    scratch: &mut SloopScratch,
) -> Result<()> {
    let col0 = out.block;
    let p = ctx.p;
    st.chunk_pools[out.lane].put(out.inbuf);
    let live_total = *st
        .live_of
        .get(&col0)
        .ok_or_else(|| Error::Pipeline(format!("lane returned unknown window {col0}")))?;
    // Ensure an assembly buffer exists (may need to wait on a write).
    if !st.assemblies.contains_key(&col0) {
        let buf = loop {
            if let Some(buf) = st.result_pool.take() {
                break buf;
            }
            let (wc0, wlen, h) = st.pending_writes.pop_front().ok_or_else(|| {
                Error::Pipeline("result pool empty with no writes in flight".into())
            })?;
            let t0 = Instant::now();
            let (wbuf, res) = h.wait();
            metrics.add(Phase::WriteWait, t0.elapsed());
            res?;
            st.completed.push((wc0, wlen));
            st.result_pool.put(wbuf);
        };
        let chunks = live_total.div_ceil(mb_gpu);
        st.assemblies.insert(col0, BlockAssembly { buf, live_total, chunks_left: chunks });
    }
    let asm = st.assemblies.get_mut(&col0).expect("assembly exists");
    let c_off = out.lane * mb_gpu; // chunk's first column within window
    let t0 = Instant::now();
    // The S-loop writes its solutions straight into this chunk's
    // segment of the assembly buffer — no per-chunk result matrix,
    // no copy: the retire path is allocation-free in steady state.
    match out.outs {
        LaneOutputs::Xbt(xbt) => {
            let live = xbt.cols();
            sloop_block_into(ctx.pre, &xbt, scratch, &mut asm.buf[c_off * p..(c_off + live) * p])?;
        }
        LaneOutputs::Reductions { xbt: _, g, rb, d } => {
            let live = d.len();
            let seg = &mut asm.buf[c_off * p..(c_off + live) * p];
            sloop_from_reductions_into(ctx.pre, &g, &d, &rb, scratch, seg)?;
        }
        LaneOutputs::Solutions(rblk) => {
            let live = rblk.cols();
            asm.buf[c_off * p..(c_off + live) * p].copy_from_slice(rblk.as_slice());
        }
    }
    metrics.add(Phase::Sloop, t0.elapsed());
    asm.chunks_left -= 1;
    if asm.chunks_left == 0 {
        let mut asm = st.assemblies.remove(&col0).expect("assembly exists");
        st.live_of.remove(&col0);
        asm.buf.truncate(p * asm.live_total);
        let h = ctx.writer.write_cols(col0, asm.live_total as u64, asm.buf);
        st.pending_writes.push_back((col0, asm.live_total as u64, h));
        st.retired += 1;
    }
    Ok(())
}

/// Stream one batch of column windows under a single block size: the
/// body of paper Listing 1.3. Returns the lanes' device-compute seconds.
fn run_segment(
    ctx: &RunCtx<'_>,
    block: usize,
    lane_threads: usize,
    items: &[(u64, usize)],
    metrics: &mut Metrics,
    scratch: &mut SloopScratch,
    journal: &mut Journal,
) -> Result<f64> {
    let cfg = ctx.cfg;
    let n = ctx.n;
    let p = ctx.p;
    let mb_gpu = block / cfg.ngpus;

    // Device lanes (fresh per segment — a block-size switch changes the
    // chunk width every lane is sized for). Known trade-off: with
    // `adapt` on, lanes and pools are rebuilt even at boundaries where
    // the re-planner keeps the block; reusing them across unchanged
    // segments is a ROADMAP item. Without `adapt` there is exactly one
    // segment, so the default path pays nothing.
    let mut lanes: Vec<DeviceLane> = (0..cfg.ngpus)
        .map(|gi| {
            let backend = match (&cfg.backend, ctx.backend_proto) {
                (BackendKind::Native, _) => Backend::Native,
                (BackendKind::Pjrt { .. }, Some(entry)) => Backend::Pjrt { entry: entry.clone() },
                _ => unreachable!(),
            };
            DeviceLane::spawn(
                gi,
                cfg.mode,
                backend,
                ctx.pre,
                mb_gpu,
                lane_threads,
                cfg.device_buffers,
            )
        })
        .collect::<Result<_>>()?;

    // Buffer pools: hb host blocks, hb result blocks, db chunks per lane.
    let mut st = SegmentState {
        host_pool: BufPool::new(cfg.host_buffers, n * block),
        result_pool: BufPool::new(cfg.host_buffers, p * block),
        chunk_pools: (0..cfg.ngpus)
            .map(|_| BufPool::new(cfg.device_buffers, n * mb_gpu))
            .collect(),
        pending_writes: VecDeque::new(),
        completed: Vec::new(),
        assemblies: HashMap::new(),
        live_of: HashMap::new(),
        retired: 0,
    };
    let njobs = items.len();
    let read_ahead = cfg.host_buffers.saturating_sub(1).max(1);
    let block_key = |ds: &str, col0: u64, live: usize| BlockKey {
        dataset: ds.to_string(),
        col0,
        ncols: live as u64,
    };

    // ---- pipeline state ------------------------------------------------
    // (window col0, in-flight read, whether it was served from the cache)
    let mut pending_reads: VecDeque<(u64, AioHandle, bool)> = VecDeque::new();
    let mut next_read = 0usize; // index into `items`

    // Submit disk reads up to the ring's read-ahead. With a shared cache
    // attached, each window first probes it: a hit is an already-complete
    // "read" served from RAM (no disk I/O), a miss goes to the engine as
    // usual and is inserted into the cache on arrival.
    macro_rules! pump_reads {
        () => {
            while next_read < njobs && pending_reads.len() < read_ahead {
                match st.host_pool.take() {
                    Some(mut buf) => {
                        let (col0, live) = items[next_read];
                        buf.truncate(n * live);
                        let mut from_cache = false;
                        if let (Some(cache), Some(ds)) =
                            (cfg.cache.as_deref(), ctx.cache_dataset.as_deref())
                        {
                            let key = block_key(ds, col0, live);
                            let t0 = Instant::now();
                            if cache.get_into(&key, &mut buf) {
                                metrics.add(Phase::CacheHit, t0.elapsed());
                                from_cache = true;
                            } else {
                                metrics.add(Phase::CacheMiss, Duration::ZERO);
                            }
                        }
                        let h = if from_cache {
                            AioHandle::ready(buf, Ok(()))
                        } else {
                            ctx.reader.read_cols(col0, live as u64, buf)
                        };
                        pending_reads.push_back((col0, h, from_cache));
                        next_read += 1;
                    }
                    None => break,
                }
            }
        };
    }

    // ---- main loop (Listing 1.3) ----------------------------------------
    for &(col0, live_total) in items {
        st.live_of.insert(col0, live_total);
        pump_reads!();
        let (rc0, handle, from_cache) = pending_reads
            .pop_front()
            .ok_or_else(|| Error::Pipeline("no pending read (pool starved?)".into()))?;
        debug_assert_eq!(rc0, col0);
        let t0 = Instant::now();
        let (buf, res) = handle.wait(); // aio_wait Xr[b]
        metrics.add(Phase::ReadWait, t0.elapsed());
        res?;
        // A freshly read (miss) window becomes cache residency for the
        // next job streaming this dataset.
        if !from_cache {
            if let (Some(cache), Some(ds)) = (cfg.cache.as_deref(), ctx.cache_dataset.as_deref()) {
                cache.insert(block_key(ds, col0, live_total), &buf);
            }
        }
        let chunks = live_total.div_ceil(mb_gpu);

        // Split-send to lanes (cu_send; blocking on pool = cu_send_wait).
        for gi in 0..chunks {
            let live = (live_total - gi * mb_gpu).min(mb_gpu);
            // Opportunistically drain results while waiting for a chunk buffer
            // — this is where the S-loop of block b-1 overlaps the trsm of b.
            let mut chunkbuf = loop {
                if let Some(cb) = st.chunk_pools[gi].take() {
                    break cb;
                }
                let t0 = Instant::now();
                let out = lanes[gi]
                    .rx_out
                    .recv()
                    .map_err(|_| Error::Pipeline(format!("lane {gi} closed early")))?;
                metrics.add(Phase::RecvWait, t0.elapsed());
                process_out(ctx, mb_gpu, out, &mut st, metrics, scratch)?;
            };
            let t0 = Instant::now();
            chunkbuf[..n * live].copy_from_slice(&buf[gi * mb_gpu * n..gi * mb_gpu * n + n * live]);
            chunkbuf[n * live..].fill(0.0); // zero-pad the artifact width
            metrics.add(Phase::Send, t0.elapsed());
            lanes[gi].submit(DevIn { block: col0, buf: chunkbuf, live })?;
        }
        st.host_pool.put(buf);

        // Drain any already-finished results without blocking.
        for lane in &lanes {
            while let Ok(out) = lane.rx_out.try_recv() {
                process_out(ctx, mb_gpu, out, &mut st, metrics, scratch)?;
            }
        }
    }

    // ---- drain ----------------------------------------------------------
    // Closing the input channels lets lanes finish their queues and exit,
    // which disconnects their output channels — the natural end-of-stream.
    for lane in &mut lanes {
        lane.close();
    }
    let mut open = vec![true; cfg.ngpus];
    while st.retired < njobs && open.iter().any(|&o| o) {
        for gi in 0..cfg.ngpus {
            if !open[gi] {
                continue;
            }
            let t0 = Instant::now();
            match lanes[gi].rx_out.recv_timeout(Duration::from_millis(20)) {
                Ok(out) => {
                    metrics.add(Phase::RecvWait, t0.elapsed());
                    process_out(ctx, mb_gpu, out, &mut st, metrics, scratch)?;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => open[gi] = false,
            }
        }
    }
    if st.retired < njobs {
        // Lanes exited without delivering everything — surface their errors.
        for lane in lanes {
            lane.join()?;
        }
        return Err(Error::Pipeline(format!("lanes exited after {}/{njobs} blocks", st.retired)));
    }
    // Flush writes.
    while let Some((wc0, wlen, h)) = st.pending_writes.pop_front() {
        let t0 = Instant::now();
        let (wbuf, res) = h.wait();
        metrics.add(Phase::WriteWait, t0.elapsed());
        res?;
        st.completed.push((wc0, wlen));
        st.result_pool.put(wbuf);
    }
    ctx.writer.sync().wait().1?;
    // Journal after the data sync so a journaled window is truly durable.
    for (wc0, wlen) in st.completed.drain(..) {
        journal.append(wc0, wlen)?;
    }
    journal.sync()?;

    // Merge lane metrics.
    let mut device_secs = 0.0;
    for lane in lanes {
        let lm = lane.join()?;
        device_secs += lm.total(Phase::DeviceCompute).as_secs_f64();
        metrics.merge(&lm);
    }
    Ok(device_secs)
}

fn validate(cfg: &PipelineConfig) -> Result<()> {
    if cfg.ngpus == 0 {
        return Err(Error::Config("ngpus must be ≥ 1".into()));
    }
    if cfg.block == 0 || cfg.block % cfg.ngpus != 0 {
        return Err(Error::Config(format!(
            "block {} must be positive and divisible by ngpus {}",
            cfg.block, cfg.ngpus
        )));
    }
    if cfg.host_buffers < 2 {
        return Err(Error::Config("host_buffers must be ≥ 2 (double buffering)".into()));
    }
    if !(2..=64).contains(&cfg.device_buffers) {
        return Err(Error::Config("device_buffers must be in 2..=64".into()));
    }
    if cfg.adapt {
        if cfg.adapt_every == 0 {
            return Err(Error::Config("adapt_every must be ≥ 1".into()));
        }
        if matches!(cfg.backend, BackendKind::Pjrt { .. }) {
            return Err(Error::Config(
                "adaptive re-planning requires the native backend \
                 (PJRT artifacts are compiled per block size)"
                    .into(),
            ));
        }
    }
    Ok(())
}

/// Compare the pipeline's `r.xrd` against the in-core oracle (test sizes).
pub fn verify_against_oracle(dataset_dir: &std::path::Path, tol: f64) -> Result<f64> {
    let (meta, kin, xl, y) = dataset::load_sidecars(dataset_dir)?;
    let xr = dataset::load_xr_incore(dataset_dir)?;
    let prob = crate::gwas::problem::Problem { dims: meta.dims, m: kin, xl, y, xr };
    let want = crate::gwas::solve_incore(&prob)?;
    let paths = dataset::DatasetPaths::new(dataset_dir);
    let rfile = XrdFile::open(&paths.results())?;
    let p = meta.dims.p();
    let mut got = vec![0.0; p * meta.dims.m];
    rfile.read_cols_into(0, meta.dims.m as u64, &mut got)?;
    let got = Matrix::from_vec(p, meta.dims.m, got)?;
    let diff = got.max_abs_diff(&want);
    if diff > tol {
        return Err(Error::Numerical(format!(
            "pipeline result differs from oracle by {diff:.3e} (tol {tol:.1e})"
        )));
    }
    Ok(diff)
}
