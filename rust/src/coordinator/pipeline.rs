//! The cuGWAS streaming pipeline — paper Listing 1.3, live.
//!
//! ```text
//!        disk ──aio──▶ host ring (hb bufs) ──send──▶ device pair (2/lane)
//!                                                         │ trsm (+fused)
//!        disk ◀──aio── result bufs ◀──S-loop(CPU)◀──recv──┘
//! ```
//!
//! One coordinator thread drives the schedule; the I/O threads (storage
//! [`AioEngine`]) and the device lanes ([`DeviceLane`]) supply the
//! asynchrony. All steady-state buffers come from fixed pools
//! ([`BufPool`]) — the rotation discipline of the paper's Fig. 5, with
//! pool exhaustion providing the back-pressure (`aio_wait`,
//! `cu_send_wait`) the listing spells out explicitly.
//!
//! The S-loop for block `b-1` runs on the coordinator thread while the
//! lanes compute block `b` — the paper's pipelining — because lane results
//! are drained opportunistically between submissions.

use crate::coordinator::lane::{Backend, DevIn, DevOut, DeviceLane, LaneOutputs, OffloadMode};
use crate::coordinator::metrics::{Metrics, Phase};
use crate::coordinator::pool::BufPool;
use crate::error::{Error, Result};
use crate::gwas::preprocess::{preprocess, Preprocessed};
use crate::gwas::sloop::{sloop_block_into, sloop_from_reductions_into, SloopScratch};
use crate::linalg::Matrix;
use crate::runtime::{ArtifactKey, Kind, Manifest};
use crate::storage::{
    dataset, AioEngine, AioHandle, BlockCache, BlockKey, Header, Throttle, XrdFile,
};
use crate::util::threads;
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which compute backend the lanes use.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// In-crate linalg (no artifacts needed).
    Native,
    /// AOT HLO artifacts through PJRT.
    Pjrt { artifacts: PathBuf },
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Dataset directory (from `storage::generate`).
    pub dataset: PathBuf,
    /// SNP columns per iteration, across all lanes.
    pub block: usize,
    /// Emulated GPU count (device lanes).
    pub ngpus: usize,
    /// Host buffer count (paper: 3; 2 = the ablation).
    pub host_buffers: usize,
    pub mode: OffloadMode,
    pub backend: BackendKind,
    /// Optional bandwidth throttles emulating slower storage.
    pub read_throttle: Option<Throttle>,
    pub write_throttle: Option<Throttle>,
    /// Resume an interrupted run: blocks journaled in `r.progress` are
    /// skipped (their results are already on disk). Studies at paper
    /// scale run for hours-to-days — a crash must not restart from zero.
    pub resume: bool,
    /// Shared block cache (the multi-study service hands the same
    /// `Arc` to every job): reads probe it first and misses populate it,
    /// so repeated studies over one dataset skip the HDD entirely.
    /// `None` (the default) streams straight from disk, as the paper does.
    pub cache: Option<Arc<BlockCache>>,
    /// Total compute threads for this run (0 = all cores). Partitioned
    /// between the device lanes and the coordinator-side S-loop: each of
    /// the `ngpus` lanes gets an equal share for its trsm/gemm kernels
    /// and the coordinator keeps the remainder, so a `serve` worker
    /// running on a slice of the machine doesn't fan its kernels out
    /// past its share. Note the floor: the pipeline always runs its
    /// `ngpus` lane threads plus the coordinator, so a budget below
    /// `ngpus + 1` clamps to one (serial) kernel worker per thread —
    /// it cannot shrink the pipeline's own `ngpus + 1` concurrency.
    pub threads: usize,
}

impl PipelineConfig {
    /// Sensible defaults for a dataset directory: paper topology
    /// (3 host buffers, 1 GPU, trsm offload, native backend).
    pub fn new(dataset: impl Into<PathBuf>, block: usize) -> Self {
        PipelineConfig {
            dataset: dataset.into(),
            block,
            ngpus: 1,
            host_buffers: 3,
            mode: OffloadMode::Trsm,
            backend: BackendKind::Native,
            read_throttle: None,
            write_throttle: None,
            resume: false,
            cache: None,
            threads: 0,
        }
    }
}

/// Read the checkpoint journal (complete u64 records only — a torn tail
/// from a crash is ignored).
fn read_progress(path: &std::path::Path) -> std::collections::HashSet<usize> {
    let mut done = std::collections::HashSet::new();
    if let Ok(bytes) = std::fs::read(path) {
        for chunk in bytes.chunks_exact(8) {
            done.insert(u64::from_le_bytes(chunk.try_into().unwrap()) as usize);
        }
    }
    done
}

/// Run summary.
#[derive(Debug)]
pub struct PipelineReport {
    pub blocks: usize,
    pub snps: usize,
    pub wall_secs: f64,
    pub snps_per_sec: f64,
    /// Coordinator-thread phase accounting + merged lane compute time.
    pub metrics: Metrics,
    /// Sum of device-side compute seconds across lanes.
    pub device_secs: f64,
}

/// Per-block assembly state: the result buffer filling up chunk by chunk.
struct BlockAssembly {
    buf: Vec<f64>,
    live_total: usize,
    chunks_left: usize,
}

/// Run the streaming solver over a dataset; results land in `r.xrd`.
pub fn run(cfg: &PipelineConfig) -> Result<PipelineReport> {
    validate(cfg)?;
    let (meta, kin, xl, y) = dataset::load_sidecars(&cfg.dataset)?;
    let dims = meta.dims;
    let n = dims.n;
    let p = dims.p();
    let mb_gpu = cfg.block / cfg.ngpus;

    // Resolve backend + the diagonal block size for preprocessing.
    let (backend_proto, dinv_nb) = match &cfg.backend {
        BackendKind::Native => (None, 0),
        BackendKind::Pjrt { artifacts } => {
            let manifest = Manifest::load(artifacts)?;
            let kind = match cfg.mode {
                OffloadMode::Trsm => Kind::Trsm,
                OffloadMode::Block => Kind::Block,
                OffloadMode::BlockFull => Kind::BlockFull,
            };
            let entry = manifest
                .get(&ArtifactKey { kind, n, pl: dims.pl, mb: mb_gpu })?
                .clone();
            let nb = entry.nb;
            (Some(entry), nb)
        }
    };

    // Core partition: each lane gets an equal share for its kernels, the
    // coordinator keeps the remainder for the S-loop (both ≥ 1).
    let total = if cfg.threads == 0 { threads::available() } else { cfg.threads };
    let lane_threads = (total / (cfg.ngpus + 1)).max(1);
    let coord_threads = total.saturating_sub(lane_threads * cfg.ngpus).max(1);

    // Preprocessing (Listing 1.3 lines 1–7; seconds, excluded by the
    // paper from streaming timings but included in our wall clock). The
    // lanes don't exist yet, so it may use the full budget.
    let pre: Preprocessed = {
        let _full = threads::with_budget(total);
        preprocess(&kin, &xl, &y, dinv_nb)?
    };
    // From here on this thread runs the S-loop on its core share.
    let _coord_budget = threads::with_budget(coord_threads);

    // Storage engines (one I/O thread each — read and write devices).
    let paths = dataset::DatasetPaths::new(&cfg.dataset);
    let xr = XrdFile::open(&paths.xr())?.with_throttle(cfg.read_throttle);
    let r_header = Header::new(p as u64, dims.m as u64, cfg.block.min(dims.m) as u64, meta.seed)?;
    // Resume: reuse the existing results file + checkpoint journal when
    // their geometry matches; otherwise start clean.
    let mut done: std::collections::HashSet<usize> = std::collections::HashSet::new();
    let rfile = if cfg.resume {
        match XrdFile::open_rw(&paths.results()) {
            Ok(f) if *f.header() == r_header => {
                done = read_progress(&paths.progress());
                f
            }
            _ => {
                let _ = std::fs::remove_file(&paths.progress());
                XrdFile::create(&paths.results(), r_header)?
            }
        }
    } else {
        let _ = std::fs::remove_file(&paths.progress());
        XrdFile::create(&paths.results(), r_header)?
    }
    .with_throttle(cfg.write_throttle);
    let mut journal = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(paths.progress())
        .map_err(|e| Error::io("opening progress journal", e))?;
    let reader = AioEngine::new(xr);
    let writer = AioEngine::new(rfile);

    // Device lanes.
    let mut lanes: Vec<DeviceLane> = (0..cfg.ngpus)
        .map(|gi| {
            let backend = match (&cfg.backend, &backend_proto) {
                (BackendKind::Native, _) => Backend::Native,
                (BackendKind::Pjrt { .. }, Some(entry)) => Backend::Pjrt { entry: entry.clone() },
                _ => unreachable!(),
            };
            DeviceLane::spawn(gi, cfg.mode, backend, &pre, mb_gpu, lane_threads)
        })
        .collect::<Result<_>>()?;

    // Buffer pools: hb host blocks, hb result blocks, 2 chunks per lane.
    let mut host_pool = BufPool::new(cfg.host_buffers, n * cfg.block);
    let mut result_pool = BufPool::new(cfg.host_buffers, p * cfg.block);
    let mut chunk_pools: Vec<BufPool> =
        (0..cfg.ngpus).map(|_| BufPool::new(2, n * mb_gpu)).collect();

    let nblocks = dims.m.div_ceil(cfg.block);
    // Work list: skip journaled blocks when resuming.
    let todo: Vec<usize> = (0..nblocks).filter(|b| !done.contains(b)).collect();
    let njobs = todo.len();
    let read_ahead = cfg.host_buffers.saturating_sub(1).max(1);
    let mut metrics = Metrics::new();
    let mut scratch = SloopScratch::new(dims.pl);
    // Canonical dataset identity for cache keys — the same helper the
    // scheduler's per-dataset lock uses, so the two can never diverge.
    let cache_dataset: Option<String> = cfg
        .cache
        .as_ref()
        .map(|_| dataset::canonical_key(&cfg.dataset).to_string_lossy().into_owned());
    let block_key = |ds: &str, b: usize, live: usize| BlockKey {
        dataset: ds.to_string(),
        col0: (b * cfg.block) as u64,
        ncols: live as u64,
    };
    let t_wall = Instant::now();

    // ---- pipeline state ------------------------------------------------
    // (block id, in-flight read, whether it was served from the cache)
    let mut pending_reads: VecDeque<(usize, AioHandle, bool)> = VecDeque::new();
    let mut next_read = 0usize; // index into `todo`
    let mut assemblies: HashMap<usize, BlockAssembly> = HashMap::new();
    let mut pending_writes: VecDeque<(usize, AioHandle)> = VecDeque::new();
    let mut retired = 0usize;

    let cols_in = |b: usize| -> usize {
        if (b + 1) * cfg.block <= dims.m { cfg.block } else { dims.m - b * cfg.block }
    };

    // Submit disk reads up to the ring's read-ahead. With a shared cache
    // attached, each block first probes it: a hit is an already-complete
    // "read" served from RAM (no disk I/O), a miss goes to the engine as
    // usual and is inserted into the cache on arrival.
    macro_rules! pump_reads {
        () => {
            while next_read < njobs && pending_reads.len() < read_ahead {
                match host_pool.take() {
                    Some(mut buf) => {
                        let b = todo[next_read];
                        let live = cols_in(b);
                        buf.truncate(n * live);
                        let mut from_cache = false;
                        if let (Some(cache), Some(ds)) =
                            (cfg.cache.as_deref(), cache_dataset.as_deref())
                        {
                            let key = block_key(ds, b, live);
                            let t0 = Instant::now();
                            if cache.get_into(&key, &mut buf) {
                                metrics.add(Phase::CacheHit, t0.elapsed());
                                from_cache = true;
                            } else {
                                metrics.add(Phase::CacheMiss, Duration::ZERO);
                            }
                        }
                        let h = if from_cache {
                            AioHandle::ready(buf, Ok(()))
                        } else {
                            reader.read_cols((b * cfg.block) as u64, live as u64, buf)
                        };
                        pending_reads.push_back((b, h, from_cache));
                        next_read += 1;
                    }
                    None => break,
                }
            }
        };
    }

    // Journal a persisted block (crash-safe resume point).
    macro_rules! journal_block {
        ($id:expr) => {
            std::io::Write::write_all(&mut journal, &($id as u64).to_le_bytes())
                .map_err(|e| Error::io("appending progress journal", e))?;
        };
    }

    let mut completed_writes: Vec<usize> = Vec::new();

    // Retire one lane result: run the CPU tail, fill the assembly, and
    // kick the write when the block is complete.
    let process_out = |out: DevOut,
                           metrics: &mut Metrics,
                           scratch: &mut SloopScratch,
                           chunk_pools: &mut Vec<BufPool>,
                           result_pool: &mut BufPool,
                           pending_writes: &mut VecDeque<(usize, AioHandle)>,
                           completed_writes: &mut Vec<usize>,
                           assemblies: &mut HashMap<usize, BlockAssembly>,
                           retired: &mut usize|
     -> Result<()> {
        let b = out.block as usize;
        chunk_pools[out.lane].put(out.inbuf);
        let live_total = cols_in(b);
        // Ensure an assembly buffer exists (may need to wait on a write).
        if !assemblies.contains_key(&b) {
            let buf = loop {
                if let Some(buf) = result_pool.take() {
                    break buf;
                }
                let (wb, h) = pending_writes.pop_front().ok_or_else(|| {
                    Error::Pipeline("result pool empty with no writes in flight".into())
                })?;
                let t0 = Instant::now();
                let (wbuf, res) = h.wait();
                metrics.add(Phase::WriteWait, t0.elapsed());
                res?;
                completed_writes.push(wb);
                result_pool.put(wbuf);
            };
            let chunks = live_total.div_ceil(mb_gpu);
            assemblies.insert(b, BlockAssembly { buf, live_total, chunks_left: chunks });
        }
        let asm = assemblies.get_mut(&b).expect("assembly exists");
        let col0 = out.lane * mb_gpu; // chunk's first column within block
        let t0 = Instant::now();
        // The S-loop writes its solutions straight into this chunk's
        // segment of the assembly buffer — no per-chunk result matrix,
        // no copy: the retire path is allocation-free in steady state.
        match out.outs {
            LaneOutputs::Xbt(xbt) => {
                let live = xbt.cols();
                sloop_block_into(&pre, &xbt, scratch, &mut asm.buf[col0 * p..(col0 + live) * p])?;
            }
            LaneOutputs::Reductions { xbt: _, g, rb, d } => {
                let live = d.len();
                let seg = &mut asm.buf[col0 * p..(col0 + live) * p];
                sloop_from_reductions_into(&pre, &g, &d, &rb, scratch, seg)?;
            }
            LaneOutputs::Solutions(rblk) => {
                let live = rblk.cols();
                asm.buf[col0 * p..(col0 + live) * p].copy_from_slice(rblk.as_slice());
            }
        }
        metrics.add(Phase::Sloop, t0.elapsed());
        asm.chunks_left -= 1;
        if asm.chunks_left == 0 {
            let mut asm = assemblies.remove(&b).expect("assembly exists");
            asm.buf.truncate(p * asm.live_total);
            let h = writer.write_cols((b * cfg.block) as u64, asm.live_total as u64, asm.buf);
            pending_writes.push_back((b, h));
            *retired += 1;
        }
        Ok(())
    };

    // ---- main loop (Listing 1.3) ----------------------------------------
    for &b in &todo {
        pump_reads!();
        let (rb_idx, handle, from_cache) = pending_reads
            .pop_front()
            .ok_or_else(|| Error::Pipeline("no pending read (pool starved?)".into()))?;
        debug_assert_eq!(rb_idx, b);
        let t0 = Instant::now();
        let (buf, res) = handle.wait(); // aio_wait Xr[b]
        metrics.add(Phase::ReadWait, t0.elapsed());
        res?;
        let live_total = cols_in(b);
        // A freshly read (miss) block becomes cache residency for the
        // next job streaming this dataset.
        if !from_cache {
            if let (Some(cache), Some(ds)) = (cfg.cache.as_deref(), cache_dataset.as_deref()) {
                cache.insert(block_key(ds, b, live_total), &buf);
            }
        }
        let chunks = live_total.div_ceil(mb_gpu);

        // Split-send to lanes (cu_send; blocking on pool = cu_send_wait).
        for gi in 0..chunks {
            let live = (live_total - gi * mb_gpu).min(mb_gpu);
            // Opportunistically drain results while waiting for a chunk buffer
            // — this is where the S-loop of block b-1 overlaps the trsm of b.
            let mut chunkbuf = loop {
                if let Some(cb) = chunk_pools[gi].take() {
                    break cb;
                }
                let t0 = Instant::now();
                let out = lanes[gi]
                    .rx_out
                    .recv()
                    .map_err(|_| Error::Pipeline(format!("lane {gi} closed early")))?;
                metrics.add(Phase::RecvWait, t0.elapsed());
                process_out(
                    out,
                    &mut metrics,
                    &mut scratch,
                    &mut chunk_pools,
                    &mut result_pool,
                    &mut pending_writes,
                    &mut completed_writes,
                    &mut assemblies,
                    &mut retired,
                )?;
            };
            let t0 = Instant::now();
            chunkbuf[..n * live].copy_from_slice(&buf[gi * mb_gpu * n..gi * mb_gpu * n + n * live]);
            chunkbuf[n * live..].fill(0.0); // zero-pad the artifact width
            metrics.add(Phase::Send, t0.elapsed());
            lanes[gi].submit(DevIn { block: b as u64, buf: chunkbuf, live })?;
        }
        host_pool.put(buf);

        // Drain any already-finished results without blocking.
        for gi in 0..cfg.ngpus {
            while let Ok(out) = lanes[gi].rx_out.try_recv() {
                process_out(
                    out,
                    &mut metrics,
                    &mut scratch,
                    &mut chunk_pools,
                    &mut result_pool,
                    &mut pending_writes,
                    &mut completed_writes,
                    &mut assemblies,
                    &mut retired,
                )?;
            }
        }
    }

    // ---- drain ----------------------------------------------------------
    // Closing the input channels lets lanes finish their queues and exit,
    // which disconnects their output channels — the natural end-of-stream.
    for lane in &mut lanes {
        lane.close();
    }
    let mut open = vec![true; cfg.ngpus];
    while retired < njobs && open.iter().any(|&o| o) {
        for gi in 0..cfg.ngpus {
            if !open[gi] {
                continue;
            }
            match lanes[gi].rx_out.recv_timeout(std::time::Duration::from_millis(20)) {
                Ok(out) => {
                    let t0 = Instant::now();
                    metrics.add(Phase::RecvWait, t0.elapsed());
                    process_out(
                        out,
                        &mut metrics,
                        &mut scratch,
                        &mut chunk_pools,
                        &mut result_pool,
                        &mut pending_writes,
                        &mut completed_writes,
                        &mut assemblies,
                        &mut retired,
                    )?;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => open[gi] = false,
            }
        }
    }
    if retired < njobs {
        // Lanes exited without delivering everything — surface their errors.
        for lane in lanes {
            lane.join()?;
        }
        return Err(Error::Pipeline(format!(
            "lanes exited after {retired}/{njobs} blocks"
        )));
    }
    // Flush writes.
    while let Some((wb, h)) = pending_writes.pop_front() {
        let t0 = Instant::now();
        let (wbuf, res) = h.wait();
        metrics.add(Phase::WriteWait, t0.elapsed());
        res?;
        completed_writes.push(wb);
        result_pool.put(wbuf);
    }
    writer.sync().wait().1?;
    // Journal after the data sync so a journaled block is truly durable.
    for wb in completed_writes.drain(..) {
        journal_block!(wb);
    }
    journal.sync_data().map_err(|e| Error::io("syncing progress journal", e))?;

    // Merge lane metrics.
    let mut device_secs = 0.0;
    for lane in lanes {
        let lm = lane.join()?;
        device_secs += lm.total(Phase::DeviceCompute).as_secs_f64();
        metrics.merge(&lm);
    }

    let wall_secs = t_wall.elapsed().as_secs_f64();
    Ok(PipelineReport {
        blocks: njobs,
        snps: dims.m,
        wall_secs,
        snps_per_sec: dims.m as f64 / wall_secs.max(1e-12),
        metrics,
        device_secs,
    })
}

fn validate(cfg: &PipelineConfig) -> Result<()> {
    if cfg.ngpus == 0 {
        return Err(Error::Config("ngpus must be ≥ 1".into()));
    }
    if cfg.block == 0 || cfg.block % cfg.ngpus != 0 {
        return Err(Error::Config(format!(
            "block {} must be positive and divisible by ngpus {}",
            cfg.block, cfg.ngpus
        )));
    }
    if cfg.host_buffers < 2 {
        return Err(Error::Config("host_buffers must be ≥ 2 (double buffering)".into()));
    }
    Ok(())
}

/// Compare the pipeline's `r.xrd` against the in-core oracle (test sizes).
pub fn verify_against_oracle(dataset_dir: &std::path::Path, tol: f64) -> Result<f64> {
    let (meta, kin, xl, y) = dataset::load_sidecars(dataset_dir)?;
    let xr = dataset::load_xr_incore(dataset_dir)?;
    let prob = crate::gwas::problem::Problem { dims: meta.dims, m: kin, xl, y, xr };
    let want = crate::gwas::solve_incore(&prob)?;
    let paths = dataset::DatasetPaths::new(dataset_dir);
    let rfile = XrdFile::open(&paths.results())?;
    let p = meta.dims.p();
    let mut got = vec![0.0; p * meta.dims.m];
    rfile.read_cols_into(0, meta.dims.m as u64, &mut got)?;
    let got = Matrix::from_vec(p, meta.dims.m, got)?;
    let diff = got.max_abs_diff(&want);
    if diff > tol {
        return Err(Error::Numerical(format!(
            "pipeline result differs from oracle by {diff:.3e} (tol {tol:.1e})"
        )));
    }
    Ok(diff)
}
