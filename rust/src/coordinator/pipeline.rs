//! The cuGWAS streaming pipeline — paper Listing 1.3, live.
//!
//! ```text
//!        disk ──aio──▶ host ring (hb bufs) ──send──▶ device ring (db/lane)
//!                                                         │ trsm (+fused)
//!        disk ◀──aio── result bufs ◀──S-loop(CPU)◀──recv──┘
//! ```
//!
//! One coordinator thread drives the schedule; the I/O threads (storage
//! [`AioEngine`](crate::storage::AioEngine)) and the device lanes
//! ([`DeviceLane`](crate::coordinator::lane::DeviceLane)) supply the
//! asynchrony. All steady-state buffers come from fixed pools
//! ([`BufPool`](crate::coordinator::pool::BufPool)) — the rotation
//! discipline of the paper's Fig. 5, with pool exhaustion providing the
//! back-pressure (`aio_wait`, `cu_send_wait`) the listing spells out
//! explicitly.
//!
//! Since the unified engine landed, this module is the *configuration*
//! face of the stream: [`PipelineConfig`] describes a run,
//! [`run`] hands it to a freshly opened
//! [`Engine`](crate::coordinator::engine::Engine), and the engine owns
//! the long-lived resources (aio engines, buffer rings, device lanes,
//! S-loop scratch, journal) across segments — and, for the service,
//! across back-to-back jobs on one dataset. See
//! [`engine`](crate::coordinator::engine) for the execution core.

use crate::coordinator::engine::Engine;
use crate::coordinator::lane::OffloadMode;
use crate::coordinator::metrics::Metrics;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::storage::{dataset, BlockCache, Throttle, XrdFile};
use crate::telemetry::StallVerdict;
use std::path::PathBuf;
use std::sync::Arc;

/// A cooperative stop request threaded from the service scheduler down
/// to the engine's segment loop.
///
/// The engine checks it only at segment boundaries — the one place the
/// progress journal is (or is about to be) durably committed — so a
/// triggered token never tears a segment: in-flight windows finish,
/// the boundary's intents+commit land, and the run returns
/// [`Error::Cancelled`] with everything before the boundary resumable
/// via `--resume`. Cloning shares the flag (it is an `Arc`), which is
/// how one drain request fans out to every in-flight job.
#[derive(Clone, Default)]
pub struct ShutdownToken(Arc<std::sync::atomic::AtomicBool>);

impl ShutdownToken {
    pub fn new() -> ShutdownToken {
        ShutdownToken::default()
    }

    /// Request a cooperative stop (idempotent, thread-safe).
    pub fn trigger(&self) {
        self.0.store(true, std::sync::atomic::Ordering::Release);
    }

    pub fn is_triggered(&self) -> bool {
        self.0.load(std::sync::atomic::Ordering::Acquire)
    }
}

impl std::fmt::Debug for ShutdownToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ShutdownToken({})", if self.is_triggered() { "triggered" } else { "armed" })
    }
}

/// Which compute backend the lanes use.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// In-crate linalg (no artifacts needed).
    Native,
    /// AOT HLO artifacts through PJRT.
    Pjrt { artifacts: PathBuf },
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Dataset directory (from `storage::generate`).
    pub dataset: PathBuf,
    /// SNP columns per iteration, across all lanes.
    pub block: usize,
    /// Emulated GPU count (device lanes).
    pub ngpus: usize,
    /// Host buffer count (paper: 3; 2 = the ablation).
    pub host_buffers: usize,
    /// Device buffers per lane (paper: 2; the autotuner may pick more).
    pub device_buffers: usize,
    pub mode: OffloadMode,
    pub backend: BackendKind,
    /// Optional bandwidth throttles emulating slower storage.
    pub read_throttle: Option<Throttle>,
    pub write_throttle: Option<Throttle>,
    /// Resume an interrupted run: column ranges journaled in `r.progress`
    /// are skipped (their results are already on disk). Studies at paper
    /// scale run for hours-to-days — a crash must not restart from zero.
    /// The journal header pins the run parameters; resuming with a
    /// different `block`/`m` is refused with [`Error::Config`].
    pub resume: bool,
    /// Shared block cache (the multi-study service hands the same
    /// `Arc` to every job): reads probe it first and misses populate it,
    /// so repeated studies over one dataset skip the HDD entirely.
    /// `None` (the default) streams straight from disk, as the paper does.
    pub cache: Option<Arc<BlockCache>>,
    /// Total compute threads for this run (0 = all cores). Partitioned
    /// between the device lanes and the coordinator-side S-loop: each of
    /// the `ngpus` lanes gets an equal share for its trsm/gemm kernels
    /// and the coordinator keeps the remainder, so a `serve` worker
    /// running on a slice of the machine doesn't fan its kernels out
    /// past its share. Note the floor: the pipeline always runs its
    /// `ngpus` lane threads plus the coordinator, so a budget below
    /// `ngpus + 1` clamps to one (serial) kernel worker per thread —
    /// it cannot shrink the pipeline's own `ngpus + 1` concurrency.
    pub threads: usize,
    /// Explicit kernel threads per lane (0 = the equal split above).
    /// The autotuner searches this split; a tuned profile pins it.
    pub lane_threads: usize,
    /// Re-plan the pipeline knobs at segment boundaries from the live
    /// stall profile: block size, host/device buffer counts and the
    /// lane-vs-S-loop thread split — the full depth the offline planner
    /// searches — with the DES pricing every candidate switch including
    /// its transition cost. Native backend only — PJRT artifacts are
    /// compiled per block size.
    pub adapt: bool,
    /// Blocks per adaptive segment (how often the re-planner looks).
    pub adapt_every: usize,
    /// Trait-batch width `t` (≥ 1): one disk stream amortized over `t`
    /// right-hand sides. `t = 1 + permutations` when permutation mode is
    /// on; result columns hold `t` stacked `p`-vectors (journal v3 pins
    /// `t` so resume refuses a width mismatch).
    pub traits: usize,
    /// Seed for the Fisher–Yates phenotype shuffles when `traits > 1`
    /// (see [`crate::gwas::phenotype_batch`]).
    pub perm_seed: u64,
    /// Cooperative stop: when triggered, the engine checkpoints at the
    /// next segment boundary and returns [`Error::Cancelled`]. `None`
    /// (the default) costs nothing — no check is even reached.
    pub shutdown: Option<ShutdownToken>,
    /// Absolute per-job deadline: past this instant the engine
    /// checkpoints at the next segment boundary and returns
    /// [`Error::Cancelled`] naming the budget. `None` = no deadline.
    pub deadline_at: Option<std::time::Instant>,
    /// Disk-space low-water mark in bytes for the dataset's filesystem
    /// (where `r.xrd` and `r.progress` live). Checked at segment
    /// boundaries; falling under it fails the run with an error naming
    /// the path — after the boundary's commit was reaped, so the
    /// journal is never torn. 0 disables the sentinel.
    pub disk_low_water: u64,
}

impl PipelineConfig {
    /// Sensible defaults for a dataset directory: paper topology
    /// (3 host buffers, 2 device buffers, 1 GPU, trsm offload, native
    /// backend, no adaptation).
    pub fn new(dataset: impl Into<PathBuf>, block: usize) -> Self {
        PipelineConfig {
            dataset: dataset.into(),
            block,
            ngpus: 1,
            host_buffers: 3,
            device_buffers: 2,
            mode: OffloadMode::Trsm,
            backend: BackendKind::Native,
            read_throttle: None,
            write_throttle: None,
            resume: false,
            cache: None,
            threads: 0,
            lane_threads: 0,
            adapt: false,
            adapt_every: 16,
            traits: 1,
            perm_seed: 0,
            shutdown: None,
            deadline_at: None,
            disk_low_water: 0,
        }
    }
}

/// Run summary.
#[derive(Debug)]
pub struct PipelineReport {
    pub blocks: usize,
    pub snps: usize,
    pub wall_secs: f64,
    pub snps_per_sec: f64,
    /// Coordinator-thread phase accounting + merged lane compute time.
    pub metrics: Metrics,
    /// Sum of device-side compute seconds across lanes.
    pub device_secs: f64,
    /// Adaptive knob switches taken (0 without `adapt`).
    pub replans: usize,
    /// Whole-run stall attribution: which resource bounded the stream
    /// (disk, device, or the S-loop CPU tail) and by what share of wall
    /// time — [`StallVerdict::from_metrics`] over the phase totals.
    pub stall: StallVerdict,
}

/// Run the streaming solver over a dataset; results land in `r.xrd`.
///
/// This is the one-shot face of the engine: open, execute, drop. Callers
/// that stream several runs over one dataset (the service's worker
/// lanes) hold the [`Engine`] instead and call
/// [`Engine::execute`] repeatedly to keep the preprocess, reader, lanes
/// and pools warm.
pub fn run(cfg: &PipelineConfig) -> Result<PipelineReport> {
    Engine::open(cfg)?.execute(cfg)
}

pub(crate) fn validate(cfg: &PipelineConfig) -> Result<()> {
    if cfg.ngpus == 0 {
        return Err(Error::Config("ngpus must be ≥ 1".into()));
    }
    if cfg.block == 0 || cfg.block % cfg.ngpus != 0 {
        return Err(Error::Config(format!(
            "block {} must be positive and divisible by ngpus {}",
            cfg.block, cfg.ngpus
        )));
    }
    if cfg.host_buffers < 2 {
        return Err(Error::Config("host_buffers must be ≥ 2 (double buffering)".into()));
    }
    if !(2..=64).contains(&cfg.device_buffers) {
        return Err(Error::Config("device_buffers must be in 2..=64".into()));
    }
    if cfg.traits == 0 {
        return Err(Error::Config("traits must be ≥ 1".into()));
    }
    if cfg.traits > 1 && matches!(cfg.backend, BackendKind::Pjrt { .. }) {
        return Err(Error::Config(
            "multi-trait batching requires the native backend \
             (PJRT literals are compiled for a single phenotype)"
                .into(),
        ));
    }
    if cfg.adapt {
        if cfg.adapt_every == 0 {
            return Err(Error::Config("adapt_every must be ≥ 1".into()));
        }
        if matches!(cfg.backend, BackendKind::Pjrt { .. }) {
            return Err(Error::Config(
                "adaptive re-planning requires the native backend \
                 (PJRT artifacts are compiled per block size)"
                    .into(),
            ));
        }
    }
    Ok(())
}

/// Compare the pipeline's `r.xrd` against the in-core oracle (test sizes).
pub fn verify_against_oracle(dataset_dir: &std::path::Path, tol: f64) -> Result<f64> {
    verify_against_oracle_multi(dataset_dir, tol, 1, 0)
}

/// [`verify_against_oracle`] for a `t`-trait run: re-derives the batched
/// phenotype from `(traits, perm_seed)` and checks the `(p·t) × m` result
/// file against [`crate::gwas::solve_incore_multi`].
pub fn verify_against_oracle_multi(
    dataset_dir: &std::path::Path,
    tol: f64,
    traits: usize,
    perm_seed: u64,
) -> Result<f64> {
    let (meta, kin, xl, y) = dataset::load_sidecars(dataset_dir)?;
    let xr = dataset::load_xr_incore(dataset_dir)?;
    let t = traits.max(1);
    let ys = crate::gwas::phenotype_batch(&y, t, perm_seed);
    let prob = crate::gwas::problem::Problem { dims: meta.dims, m: kin, xl, y, xr };
    let (want, _) = crate::gwas::solve_incore_multi(&prob, &ys)?;
    let paths = dataset::DatasetPaths::new(dataset_dir);
    let rfile = XrdFile::open(&paths.results())?;
    let rows = meta.dims.p() * t;
    let mut got = vec![0.0; rows * meta.dims.m];
    rfile.read_cols_into(0, meta.dims.m as u64, &mut got)?;
    let got = Matrix::from_vec(rows, meta.dims.m, got)?;
    let diff = got.max_abs_diff(&want);
    if diff > tol {
        return Err(Error::Numerical(format!(
            "pipeline result differs from oracle by {diff:.3e} (tol {tol:.1e})"
        )));
    }
    Ok(diff)
}
