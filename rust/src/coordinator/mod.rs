//! Layer-3 coordinator — the paper's contribution.
//!
//! * [`pipeline`] — the cuGWAS streaming loop (Listing 1.3): triple-
//!   buffered host ring, double-buffered device lanes, pipelined S-loop,
//!   run as journaled segments so the autotuner can re-plan in flight.
//! * [`lane`] — one worker thread per emulated GPU, PJRT or native.
//! * [`pool`] — the fixed buffer pools that realize the rotation.
//! * [`metrics`] — per-phase accounting (the live Fig. 3).
//! * [`journal`] — the v2 checkpoint journal (parameter header +
//!   column-range records) behind `--resume`.

pub mod journal;
pub mod lane;
pub mod metrics;
pub mod pipeline;
pub mod pool;

pub use journal::Journal;
pub use lane::{Backend, DevIn, DevOut, DeviceLane, LaneOutputs, OffloadMode};
pub use metrics::{Metrics, Phase};
pub use pipeline::{run, verify_against_oracle, BackendKind, PipelineConfig, PipelineReport};
pub use pool::BufPool;
