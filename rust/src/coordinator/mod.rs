//! Layer-3 coordinator — the paper's contribution.
//!
//! * [`pipeline`] — the cuGWAS streaming loop (Listing 1.3): triple-
//!   buffered host ring, double-buffered device lanes, pipelined S-loop.
//! * [`lane`] — one worker thread per emulated GPU, PJRT or native.
//! * [`pool`] — the fixed buffer pools that realize the rotation.
//! * [`metrics`] — per-phase accounting (the live Fig. 3).

pub mod lane;
pub mod metrics;
pub mod pipeline;
pub mod pool;

pub use lane::{Backend, DevIn, DevOut, DeviceLane, LaneOutputs, OffloadMode};
pub use metrics::{Metrics, Phase};
pub use pipeline::{run, verify_against_oracle, BackendKind, PipelineConfig, PipelineReport};
pub use pool::BufPool;
