//! Layer-3 coordinator — the paper's contribution.
//!
//! * [`engine`] — the unified streaming engine: a long-lived execution
//!   core owning the aio engines, buffer rings, device lanes and S-loop
//!   scratch, executing segment plans against them with resources
//!   reused across segments *and* across back-to-back runs (the
//!   `serve` path). The full-depth in-flight re-planner lives here.
//! * [`pipeline`] — the configuration face (Listing 1.3's knobs):
//!   [`PipelineConfig`], validation, the one-shot [`run`] wrapper, and
//!   the oracle check.
//! * [`lane`] — one worker thread per emulated GPU, PJRT or native;
//!   lanes receive zero-copy [`BlockSlice`](crate::storage::BlockSlice)
//!   views into the shared read slabs.
//! * [`pool`] — the fixed result-ring pool; the read side rotates
//!   through the refcounted [`SlabPool`](crate::storage::SlabPool).
//! * [`metrics`] — per-phase accounting (the live Fig. 3) plus the
//!   data-plane `bytes_copied` / `bytes_borrowed` counters.
//! * [`journal`] — the v3 checkpoint journal (parameter header incl.
//!   trait width + column-range records) behind `--resume`.

pub mod engine;
pub mod journal;
pub mod lane;
pub mod metrics;
pub mod pipeline;
pub mod pool;

pub use crate::devsim::SegmentKnobs;
pub use engine::{Engine, EngineStats, SegmentPlan};
pub use journal::Journal;
pub use lane::{Backend, DevIn, DevOut, DeviceLane, LaneOutputs, OffloadMode};
pub use metrics::{Counter, Metrics, Phase};
pub use pipeline::{
    run, verify_against_oracle, verify_against_oracle_multi, BackendKind, PipelineConfig,
    PipelineReport, ShutdownToken,
};
pub use pool::BufPool;
