//! Checkpoint journal v3 — crash-safe progress for multi-hour streams.
//!
//! The v1 journal was a bare sequence of block indices, which made a
//! resumed run *silently mis-indexed* whenever the block size differed
//! from the original run (a tuned profile is exactly such a change). v2
//! fixed both problems at once; v3 adds the trait-batch width `t` to the
//! header, because a resumed multi-trait run with a different `t` would
//! read/write result columns of the wrong height:
//!
//! * a **header** persists the run parameters that define block indices
//!   and the result geometry (`m`, the starting block size `nb`, the
//!   trait width `t`) — resuming with different parameters is refused
//!   with a clear [`Error::Config`], never silently misread;
//! * records are **column ranges** `(col0, ncols)` rather than block
//!   indices, so a run whose block size changed mid-stream (the adaptive
//!   re-planner) journals each persisted window exactly as written and
//!   resume recomputes precisely the uncovered columns.
//!
//! Layout (all little-endian u64):
//!
//! ```text
//! magic "CGWJRNL3" | m | nb | t       — 32-byte header
//! (col0, ncols)*                      — 16-byte records, appended after
//!                                       the corresponding data sync
//! ```
//!
//! A torn tail (crash mid-append) is truncated away on resume, so later
//! appends can never land misaligned behind a partial record. A v2
//! journal (no trait width) is refused as unrecognized — the engine's
//! resume fallback recreates it fresh.

use crate::error::{Error, Result};
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;

/// Format magic — bump the trailing digit on layout changes.
pub const MAGIC: [u8; 8] = *b"CGWJRNL3";
const HEADER_BYTES: usize = 32;
const RECORD_BYTES: usize = 16;

/// An open journal, positioned for appending.
pub struct Journal {
    file: std::fs::File,
}

impl Journal {
    /// Start a fresh journal (truncates any previous one).
    pub fn create(path: &Path, m: u64, nb: u64, t: u64) -> Result<Journal> {
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .map_err(|e| Error::io("creating progress journal", e))?;
        let mut header = [0u8; HEADER_BYTES];
        header[..8].copy_from_slice(&MAGIC);
        header[8..16].copy_from_slice(&m.to_le_bytes());
        header[16..24].copy_from_slice(&nb.to_le_bytes());
        header[24..32].copy_from_slice(&t.to_le_bytes());
        file.write_all(&header).map_err(|e| Error::io("writing journal header", e))?;
        Ok(Journal { file })
    }

    /// Open an existing journal for resume, validating its header against
    /// this run's parameters. Returns the journal plus the persisted
    /// column ranges. A missing or header-less file starts clean; a
    /// journal written under different `(m, nb, t)` is refused — resuming
    /// it with this geometry would recompute (or mis-slice) the wrong
    /// columns.
    pub fn open_resume(
        path: &Path,
        m: u64,
        nb: u64,
        t: u64,
    ) -> Result<(Journal, Vec<(u64, u64)>)> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok((Journal::create(path, m, nb, t)?, Vec::new()));
            }
            Err(e) => return Err(Error::io("reading progress journal", e)),
        };
        if bytes.len() < HEADER_BYTES {
            // Crash before the header landed — nothing usable, start clean.
            return Ok((Journal::create(path, m, nb, t)?, Vec::new()));
        }
        if bytes[..8] != MAGIC {
            return Err(Error::Config(format!(
                "{}: unrecognized journal format — delete it to start clean",
                path.display()
            )));
        }
        let jm = u64::from_le_bytes(bytes[8..16].try_into().expect("8 bytes"));
        let jnb = u64::from_le_bytes(bytes[16..24].try_into().expect("8 bytes"));
        let jt = u64::from_le_bytes(bytes[24..32].try_into().expect("8 bytes"));
        if jm != m || jnb != nb {
            return Err(Error::Config(format!(
                "{}: journal was written for m={jm}, block={jnb} but this run has m={m}, \
                 block={nb} — resume with the original --block, or delete the journal to \
                 recompute from scratch",
                path.display()
            )));
        }
        if jt != t {
            return Err(Error::Config(format!(
                "{}: journal was written for traits={jt} but this run has traits={t} — \
                 resume with the original trait batch, or delete the journal to recompute \
                 from scratch",
                path.display()
            )));
        }
        // Parse records up to the first invalid one: everything after it
        // is untrustworthy, and truncating exactly there keeps the file a
        // valid prefix (a mid-file filter would misalign the truncation
        // length against the surviving bytes).
        let mut ranges = Vec::new();
        for rec in bytes[HEADER_BYTES..].chunks_exact(RECORD_BYTES) {
            let col0 = u64::from_le_bytes(rec[..8].try_into().expect("8 bytes"));
            let ncols = u64::from_le_bytes(rec[8..].try_into().expect("8 bytes"));
            if ncols == 0 || !col0.checked_add(ncols).is_some_and(|end| end <= m) {
                break;
            }
            ranges.push((col0, ncols));
        }
        let valid = (HEADER_BYTES + ranges.len() * RECORD_BYTES) as u64;
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .map_err(|e| Error::io("opening progress journal", e))?;
        // Drop a torn tail so future appends stay record-aligned.
        file.set_len(valid).map_err(|e| Error::io("truncating torn journal tail", e))?;
        Ok((Journal { file }, ranges))
    }

    /// Append one persisted column range (call only after the data sync —
    /// a journaled range must be durable on disk).
    pub fn append(&mut self, col0: u64, ncols: u64) -> Result<()> {
        let mut rec = [0u8; RECORD_BYTES];
        rec[..8].copy_from_slice(&col0.to_le_bytes());
        rec[8..].copy_from_slice(&ncols.to_le_bytes());
        self.file.seek(SeekFrom::End(0)).map_err(|e| Error::io("seeking journal", e))?;
        // Chaos harness: a "torn append" writes a prefix of the record,
        // makes it durable, and reports the crash — exactly the on-disk
        // state a power loss mid-append leaves behind. `open_resume`
        // must truncate it away.
        if let Some(k) = crate::storage::fault::torn_append(RECORD_BYTES) {
            self.file.write_all(&rec[..k]).map_err(|e| Error::io("appending journal", e))?;
            let _ = self.file.sync_data();
            return Err(Error::io(
                "journal append torn mid-record (injected crash)",
                std::io::Error::new(std::io::ErrorKind::WriteZero, "partial record"),
            ));
        }
        self.file.write_all(&rec).map_err(|e| Error::io("appending progress journal", e))
    }

    /// Flush appended records to stable storage — `fdatasync` on the
    /// journal *file*, not just the writer's buffer, so a journaled
    /// range survives power loss. The coordinator calls this at every
    /// segment boundary, right after the data file's own sync.
    pub fn sync(&self) -> Result<()> {
        self.file.sync_data().map_err(|e| Error::io("syncing progress journal", e))
    }
}

/// Complement of the persisted ranges over `[0, m)`: the column spans a
/// resumed run still has to compute. Overlapping/adjacent records merge.
pub fn uncovered(m: u64, ranges: &[(u64, u64)]) -> Vec<(u64, u64)> {
    let mut spans: Vec<(u64, u64)> = ranges
        .iter()
        .filter(|&&(_, n)| n > 0)
        .map(|&(c, n)| (c.min(m), (c.saturating_add(n)).min(m)))
        .filter(|&(a, b)| b > a)
        .collect();
    spans.sort_unstable();
    let mut out = Vec::new();
    let mut cursor = 0u64;
    for (a, b) in spans {
        if a > cursor {
            out.push((cursor, a - cursor));
        }
        cursor = cursor.max(b);
    }
    if cursor < m {
        out.push((cursor, m - cursor));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cugwas_jnl_{}_{tag}.progress", std::process::id()))
    }

    #[test]
    fn create_append_resume_roundtrip() {
        let p = tmpfile("rt");
        let mut j = Journal::create(&p, 40, 8, 1).unwrap();
        j.append(0, 8).unwrap();
        j.append(8, 8).unwrap();
        j.sync().unwrap();
        drop(j);
        let (_j, ranges) = Journal::open_resume(&p, 40, 8, 1).unwrap();
        assert_eq!(ranges, vec![(0, 8), (8, 8)]);
        assert_eq!(uncovered(40, &ranges), vec![(16, 24)]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn mismatched_parameters_are_refused() {
        let p = tmpfile("mismatch");
        Journal::create(&p, 40, 8, 1).unwrap();
        let err = Journal::open_resume(&p, 40, 12, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("block=8"), "{err}");
        let err = Journal::open_resume(&p, 48, 8, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn mismatched_trait_width_is_refused() {
        // The v3 guarantee: a journal from a t-wide run cannot silently
        // resume a run with a different trait batch — the result columns
        // would have the wrong height.
        let p = tmpfile("traits");
        Journal::create(&p, 40, 8, 4).unwrap();
        let err = Journal::open_resume(&p, 40, 8, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("traits=4"), "{err}");
        let (_j, ranges) = Journal::open_resume(&p, 40, 8, 4).unwrap();
        assert!(ranges.is_empty());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn v2_journal_is_refused_as_unrecognized() {
        // Old 24-byte-header files (magic CGWJRNL2) must not parse: the
        // engine treats the Config error as "recreate fresh".
        let p = tmpfile("v2");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"CGWJRNL2");
        bytes.extend_from_slice(&40u64.to_le_bytes());
        bytes.extend_from_slice(&8u64.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = Journal::open_resume(&p, 40, 8, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("unrecognized"), "{err}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn foreign_file_is_refused_and_missing_starts_clean() {
        let p = tmpfile("foreign");
        std::fs::write(&p, b"not a journal, definitely long enough").unwrap();
        assert!(matches!(Journal::open_resume(&p, 8, 4, 1), Err(Error::Config(_))));
        std::fs::remove_file(&p).unwrap();
        let (_j, ranges) = Journal::open_resume(&p, 8, 4, 1).unwrap();
        assert!(ranges.is_empty());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn torn_tail_is_truncated_before_appending() {
        let p = tmpfile("torn");
        let mut j = Journal::create(&p, 40, 8, 1).unwrap();
        j.append(0, 8).unwrap();
        drop(j);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.extend_from_slice(&[0xAB, 0xCD, 0xEF]); // partial record
        std::fs::write(&p, &bytes).unwrap();
        let (mut j, ranges) = Journal::open_resume(&p, 40, 8, 1).unwrap();
        assert_eq!(ranges, vec![(0, 8)]);
        j.append(8, 8).unwrap();
        drop(j);
        let (_j, ranges) = Journal::open_resume(&p, 40, 8, 1).unwrap();
        assert_eq!(ranges, vec![(0, 8), (8, 8)], "append after torn tail stays aligned");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn parsing_stops_at_the_first_invalid_record() {
        // A zeroed/corrupt record mid-file invalidates everything after
        // it: the survivors are a clean prefix, the rest is truncated
        // (those columns simply get recomputed).
        let p = tmpfile("midcorrupt");
        let mut j = Journal::create(&p, 40, 8, 1).unwrap();
        j.append(0, 8).unwrap();
        j.append(0, 0).unwrap(); // corrupt: zero width
        j.append(16, 8).unwrap();
        drop(j);
        let (_j, ranges) = Journal::open_resume(&p, 40, 8, 1).unwrap();
        assert_eq!(ranges, vec![(0, 8)]);
        assert_eq!(std::fs::metadata(&p).unwrap().len(), 32 + 16);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn uncovered_merges_overlaps_and_mixed_widths() {
        // Ranges from an adaptive run: different widths, out of order,
        // overlapping.
        let ranges = vec![(16, 16), (0, 8), (8, 8), (24, 16)];
        assert_eq!(uncovered(64, &ranges), vec![(40, 24)]);
        assert_eq!(uncovered(64, &[]), vec![(0, 64)]);
        assert_eq!(uncovered(8, &[(0, 8)]), Vec::<(u64, u64)>::new());
        // Records past m are clamped, zero-width ignored.
        assert_eq!(uncovered(10, &[(4, 100), (2, 0)]), vec![(0, 4)]);
    }
}
