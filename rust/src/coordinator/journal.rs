//! Checkpoint journal v4 — crash-safe progress with a two-phase commit
//! that keeps the segment boundary off the pipeline's critical path.
//!
//! The v1 journal was a bare sequence of block indices, which made a
//! resumed run *silently mis-indexed* whenever the block size differed
//! from the original run. v2 added a parameter header and column-range
//! records; v3 added the trait-batch width `t`. All three shared one
//! performance flaw: every record was appended *after* the segment's
//! data sync and followed by its own journal sync, so the boundary
//! quiesced the whole pipeline — reads, compute and writes all waited
//! on two serial fsyncs. v4 splits the record in two:
//!
//! * an **intent** record (`kind = 1`) is appended *without* any sync
//!   the moment a segment's results are handed to the writer — it costs
//!   one buffered `write(2)`;
//! * a **commit** record (`kind = 2`, carrying the number of intents it
//!   covers) is appended and `fdatasync`ed by [`Journal::commit`], which
//!   the engine schedules on the aio writer's background thread *after*
//!   the data sync, while the next segment's reads are already in
//!   flight.
//!
//! Resume trusts only intents covered by a following valid commit: an
//! intent without a durable commit mark is dropped (and its tail
//! truncated away), so those columns are recomputed — safe because the
//! result writes are idempotent (same column ⇒ same offset ⇒ same
//! bytes). A torn tail (crash mid-append) truncates the same way.
//!
//! Layout (all little-endian u64):
//!
//! ```text
//! magic "CGWJRNL4" | m | nb | t        — 32-byte header
//! (kind, a, b)*                        — 24-byte records:
//!     kind 1 (intent): a = col0, b = ncols
//!     kind 2 (commit): a = 0,    b = count of intents it covers
//! ```
//!
//! The header persists the run parameters that define block indices and
//! the result geometry (`m`, starting block size `nb`, trait width `t`)
//! — resuming with different parameters is refused with a clear
//! [`Error::Config`], never silently misread. A v3-or-older journal is
//! refused as unrecognized — the engine's resume fallback recreates it
//! fresh.

use crate::error::{Error, Result};
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;

/// Format magic — bump the trailing digit on layout changes.
pub const MAGIC: [u8; 8] = *b"CGWJRNL4";
const HEADER_BYTES: usize = 32;
const RECORD_BYTES: usize = 24;

const KIND_INTENT: u64 = 1;
const KIND_COMMIT: u64 = 2;

/// An open journal, positioned for appending.
pub struct Journal {
    file: std::fs::File,
}

impl Journal {
    /// Start a fresh journal (truncates any previous one).
    pub fn create(path: &Path, m: u64, nb: u64, t: u64) -> Result<Journal> {
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .map_err(|e| Error::io("creating progress journal", e))?;
        let mut header = [0u8; HEADER_BYTES];
        header[..8].copy_from_slice(&MAGIC);
        header[8..16].copy_from_slice(&m.to_le_bytes());
        header[16..24].copy_from_slice(&nb.to_le_bytes());
        header[24..32].copy_from_slice(&t.to_le_bytes());
        file.write_all(&header).map_err(|e| Error::io("writing journal header", e))?;
        file.sync_data().map_err(|e| Error::io("syncing journal header", e))?;
        // File sync alone does not make the *name* durable: on a power
        // cut the directory entry itself can vanish, leaving a resumed
        // run with no journal and a result file it would recompute from
        // zero. Sync the parent directory so create-then-crash leaves
        // either no journal or a whole one — never a named-but-lost file.
        sync_parent_dir(path)?;
        Ok(Journal { file })
    }

    /// Open an existing journal for resume, validating its header against
    /// this run's parameters. Returns the journal plus the *committed*
    /// column ranges — intents not covered by a durable commit mark are
    /// dropped and truncated away (their columns get recomputed). A
    /// missing or header-less file starts clean; a journal written under
    /// different `(m, nb, t)` is refused — resuming it with this
    /// geometry would recompute (or mis-slice) the wrong columns.
    pub fn open_resume(
        path: &Path,
        m: u64,
        nb: u64,
        t: u64,
    ) -> Result<(Journal, Vec<(u64, u64)>)> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok((Journal::create(path, m, nb, t)?, Vec::new()));
            }
            Err(e) => return Err(Error::io("reading progress journal", e)),
        };
        if bytes.len() < HEADER_BYTES {
            // Crash before the header landed — nothing usable, start clean.
            return Ok((Journal::create(path, m, nb, t)?, Vec::new()));
        }
        if bytes[..8] != MAGIC {
            return Err(Error::Config(format!(
                "{}: unrecognized journal format — delete it to start clean",
                path.display()
            )));
        }
        let jm = u64::from_le_bytes(bytes[8..16].try_into().expect("8 bytes"));
        let jnb = u64::from_le_bytes(bytes[16..24].try_into().expect("8 bytes"));
        let jt = u64::from_le_bytes(bytes[24..32].try_into().expect("8 bytes"));
        if jm != m || jnb != nb {
            return Err(Error::Config(format!(
                "{}: journal was written for m={jm}, block={jnb} but this run has m={m}, \
                 block={nb} — resume with the original --block, or delete the journal to \
                 recompute from scratch",
                path.display()
            )));
        }
        if jt != t {
            return Err(Error::Config(format!(
                "{}: journal was written for traits={jt} but this run has traits={t} — \
                 resume with the original trait batch, or delete the journal to recompute \
                 from scratch",
                path.display()
            )));
        }
        // Parse records up to the first invalid one: everything after it
        // is untrustworthy. Only intents sealed by a following commit
        // record (whose count must match the open intents exactly) are
        // returned; the file is truncated right after the last valid
        // commit, so uncommitted intents and torn tails both vanish and
        // future appends stay record-aligned.
        let mut committed = Vec::new();
        let mut pending: Vec<(u64, u64)> = Vec::new();
        let mut records = 0usize;
        let mut valid_records = 0usize;
        for rec in bytes[HEADER_BYTES..].chunks_exact(RECORD_BYTES) {
            let kind = u64::from_le_bytes(rec[..8].try_into().expect("8 bytes"));
            let a = u64::from_le_bytes(rec[8..16].try_into().expect("8 bytes"));
            let b = u64::from_le_bytes(rec[16..].try_into().expect("8 bytes"));
            match kind {
                KIND_INTENT if b > 0 && a.checked_add(b).is_some_and(|end| end <= m) => {
                    pending.push((a, b));
                }
                KIND_COMMIT if a == 0 && !pending.is_empty() && b as usize == pending.len() => {
                    committed.append(&mut pending);
                    valid_records = records + 1;
                }
                _ => break,
            }
            records += 1;
        }
        let valid = (HEADER_BYTES + valid_records * RECORD_BYTES) as u64;
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .map_err(|e| Error::io("opening progress journal", e))?;
        file.set_len(valid).map_err(|e| Error::io("truncating journal tail", e))?;
        Ok((Journal { file }, committed))
    }

    /// Phase one: record the *intent* to persist one column range. No
    /// sync — this is a buffered append on the retire path, called the
    /// moment the segment's results are handed to the writer. The range
    /// is not trusted on resume until [`Journal::commit`] seals it.
    pub fn append_intent(&mut self, col0: u64, ncols: u64) -> Result<()> {
        let rec = encode(KIND_INTENT, col0, ncols);
        self.file.seek(SeekFrom::End(0)).map_err(|e| Error::io("seeking journal", e))?;
        // Chaos harness: a "torn append" writes a prefix of the record,
        // makes it durable, and reports the crash — exactly the on-disk
        // state a power loss mid-append leaves behind. `open_resume`
        // must truncate it away. The sync error is surfaced: a failed
        // durability sync must never report success.
        if let Some(k) = crate::storage::fault::torn_append(RECORD_BYTES) {
            self.file.write_all(&rec[..k]).map_err(|e| Error::io("appending journal", e))?;
            self.file.sync_data().map_err(|e| Error::io("syncing torn journal append", e))?;
            return Err(Error::io(
                "journal append torn mid-record (injected crash)",
                std::io::Error::new(std::io::ErrorKind::WriteZero, "partial record"),
            ));
        }
        self.file.write_all(&rec).map_err(|e| Error::io("appending progress journal", e))
    }

    /// Phase two: seal the `n` intent records appended since the last
    /// commit with a durable commit mark — one record append plus one
    /// `fdatasync` of the journal *file* (not just the writer's buffer),
    /// so the sealed ranges survive power loss. The engine runs this on
    /// the aio writer's background thread, after the segment's data
    /// sync, while the next segment's reads are in flight. A failed
    /// sync surfaces as [`Error::Io`] — it is the durable-commit error
    /// path, never swallowed.
    pub fn commit(&mut self, n: u64) -> Result<()> {
        debug_assert!(n > 0, "commit with no intents to seal");
        // Chaos harness: a crash after the intents landed but before the
        // commit mark — resume must drop the unsealed intents and replay.
        if crate::storage::fault::commit_crash() {
            return Err(Error::io(
                "journal commit crashed before durable mark (injected)",
                std::io::Error::new(std::io::ErrorKind::Interrupted, "injected crash"),
            ));
        }
        let rec = encode(KIND_COMMIT, 0, n);
        self.file.seek(SeekFrom::End(0)).map_err(|e| Error::io("seeking journal", e))?;
        self.file.write_all(&rec).map_err(|e| Error::io("appending journal commit", e))?;
        self.file.sync_data().map_err(|e| Error::io("syncing journal commit", e))
    }
}

/// `fsync` the directory holding `path`, making a freshly created or
/// renamed entry durable. File data syncs (`sync_data`/`sync_all`) only
/// cover the inode — the *directory entry* needs its own sync on Linux,
/// or a power cut can forget the name while keeping the bytes. Shared
/// by journal creation, the service WAL, and the scheduler's
/// quarantine/spool renames.
pub fn sync_parent_dir(path: &Path) -> Result<()> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d,
        _ => Path::new("."),
    };
    std::fs::File::open(dir)
        .and_then(|d| d.sync_all())
        .map_err(|e| Error::io(format!("syncing directory {}", dir.display()), e))
}

fn encode(kind: u64, a: u64, b: u64) -> [u8; RECORD_BYTES] {
    let mut rec = [0u8; RECORD_BYTES];
    rec[..8].copy_from_slice(&kind.to_le_bytes());
    rec[8..16].copy_from_slice(&a.to_le_bytes());
    rec[16..].copy_from_slice(&b.to_le_bytes());
    rec
}

/// Complement of the persisted ranges over `[0, m)`: the column spans a
/// resumed run still has to compute. Overlapping/adjacent records merge.
pub fn uncovered(m: u64, ranges: &[(u64, u64)]) -> Vec<(u64, u64)> {
    let mut spans: Vec<(u64, u64)> = ranges
        .iter()
        .filter(|&&(_, n)| n > 0)
        .map(|&(c, n)| (c.min(m), (c.saturating_add(n)).min(m)))
        .filter(|&(a, b)| b > a)
        .collect();
    spans.sort_unstable();
    let mut out = Vec::new();
    let mut cursor = 0u64;
    for (a, b) in spans {
        if a > cursor {
            out.push((cursor, a - cursor));
        }
        cursor = cursor.max(b);
    }
    if cursor < m {
        out.push((cursor, m - cursor));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cugwas_jnl_{}_{tag}.progress", std::process::id()))
    }

    #[test]
    fn create_append_commit_resume_roundtrip() {
        let p = tmpfile("rt");
        let mut j = Journal::create(&p, 40, 8, 1).unwrap();
        j.append_intent(0, 8).unwrap();
        j.append_intent(8, 8).unwrap();
        j.commit(2).unwrap();
        drop(j);
        let (_j, ranges) = Journal::open_resume(&p, 40, 8, 1).unwrap();
        assert_eq!(ranges, vec![(0, 8), (8, 8)]);
        assert_eq!(uncovered(40, &ranges), vec![(16, 24)]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn uncommitted_intents_are_dropped_and_truncated() {
        // The two-phase contract: an intent without a durable commit
        // mark is exactly a crash between handing results to the writer
        // and the background commit — resume must replay those columns.
        let p = tmpfile("uncommitted");
        let mut j = Journal::create(&p, 40, 8, 1).unwrap();
        j.append_intent(0, 8).unwrap();
        j.commit(1).unwrap();
        j.append_intent(8, 8).unwrap();
        j.append_intent(16, 8).unwrap();
        drop(j); // crash before commit
        let (_j, ranges) = Journal::open_resume(&p, 40, 8, 1).unwrap();
        assert_eq!(ranges, vec![(0, 8)], "unsealed intents must not count as done");
        assert_eq!(
            std::fs::metadata(&p).unwrap().len(),
            32 + 2 * 24,
            "truncated right after the last valid commit"
        );
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn commit_count_mismatch_invalidates_the_tail() {
        // A commit that doesn't cover the open intents exactly is
        // corruption: nothing after it can be trusted.
        let p = tmpfile("badcount");
        let mut j = Journal::create(&p, 40, 8, 1).unwrap();
        j.append_intent(0, 8).unwrap();
        j.commit(5).unwrap(); // wrong count
        drop(j);
        let (_j, ranges) = Journal::open_resume(&p, 40, 8, 1).unwrap();
        assert!(ranges.is_empty());
        assert_eq!(std::fs::metadata(&p).unwrap().len(), 32);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn multiple_commit_cycles_accumulate() {
        let p = tmpfile("cycles");
        let mut j = Journal::create(&p, 64, 8, 1).unwrap();
        j.append_intent(0, 8).unwrap();
        j.append_intent(8, 8).unwrap();
        j.commit(2).unwrap();
        j.append_intent(16, 8).unwrap();
        j.commit(1).unwrap();
        drop(j);
        let (_j, ranges) = Journal::open_resume(&p, 64, 8, 1).unwrap();
        assert_eq!(ranges, vec![(0, 8), (8, 8), (16, 8)]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn mismatched_parameters_are_refused() {
        let p = tmpfile("mismatch");
        Journal::create(&p, 40, 8, 1).unwrap();
        let err = Journal::open_resume(&p, 40, 12, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("block=8"), "{err}");
        let err = Journal::open_resume(&p, 48, 8, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn mismatched_trait_width_is_refused() {
        // A journal from a t-wide run cannot silently resume a run with
        // a different trait batch — the result columns would have the
        // wrong height.
        let p = tmpfile("traits");
        Journal::create(&p, 40, 8, 4).unwrap();
        let err = Journal::open_resume(&p, 40, 8, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("traits=4"), "{err}");
        let (_j, ranges) = Journal::open_resume(&p, 40, 8, 4).unwrap();
        assert!(ranges.is_empty());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn v3_journal_is_refused_as_unrecognized() {
        // Old single-phase files (magic CGWJRNL3, 16-byte records) must
        // not parse: the engine treats the Config error as "recreate
        // fresh" rather than misreading ranges at the wrong stride.
        let p = tmpfile("v3");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"CGWJRNL3");
        bytes.extend_from_slice(&40u64.to_le_bytes());
        bytes.extend_from_slice(&8u64.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes()); // one v3 record
        bytes.extend_from_slice(&8u64.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = Journal::open_resume(&p, 40, 8, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("unrecognized"), "{err}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn foreign_file_is_refused_and_missing_starts_clean() {
        let p = tmpfile("foreign");
        std::fs::write(&p, b"not a journal, definitely long enough").unwrap();
        assert!(matches!(Journal::open_resume(&p, 8, 4, 1), Err(Error::Config(_))));
        std::fs::remove_file(&p).unwrap();
        let (_j, ranges) = Journal::open_resume(&p, 8, 4, 1).unwrap();
        assert!(ranges.is_empty());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn torn_tail_is_truncated_before_appending() {
        let p = tmpfile("torn");
        let mut j = Journal::create(&p, 40, 8, 1).unwrap();
        j.append_intent(0, 8).unwrap();
        j.commit(1).unwrap();
        drop(j);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.extend_from_slice(&[0xAB, 0xCD, 0xEF]); // partial record
        std::fs::write(&p, &bytes).unwrap();
        let (mut j, ranges) = Journal::open_resume(&p, 40, 8, 1).unwrap();
        assert_eq!(ranges, vec![(0, 8)]);
        j.append_intent(8, 8).unwrap();
        j.commit(1).unwrap();
        drop(j);
        let (_j, ranges) = Journal::open_resume(&p, 40, 8, 1).unwrap();
        assert_eq!(ranges, vec![(0, 8), (8, 8)], "append after torn tail stays aligned");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn parsing_stops_at_the_first_invalid_record() {
        // A zeroed/corrupt record mid-file invalidates everything after
        // it: the survivors are a clean prefix, the rest is truncated
        // (those columns simply get recomputed).
        let p = tmpfile("midcorrupt");
        let mut j = Journal::create(&p, 40, 8, 1).unwrap();
        j.append_intent(0, 8).unwrap();
        j.commit(1).unwrap();
        j.append_intent(0, 0).unwrap(); // corrupt: zero width
        j.append_intent(16, 8).unwrap();
        j.commit(2).unwrap();
        drop(j);
        let (_j, ranges) = Journal::open_resume(&p, 40, 8, 1).unwrap();
        assert_eq!(ranges, vec![(0, 8)]);
        assert_eq!(std::fs::metadata(&p).unwrap().len(), 32 + 2 * 24);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn uncovered_merges_overlaps_and_mixed_widths() {
        // Ranges from an adaptive run: different widths, out of order,
        // overlapping.
        let ranges = vec![(16, 16), (0, 8), (8, 8), (24, 16)];
        assert_eq!(uncovered(64, &ranges), vec![(40, 24)]);
        assert_eq!(uncovered(64, &[]), vec![(0, 64)]);
        assert_eq!(uncovered(8, &[(0, 8)]), Vec::<(u64, u64)>::new());
        // Records past m are clamped, zero-width ignored.
        assert_eq!(uncovered(10, &[(4, 100), (2, 0)]), vec![(0, 4)]);
    }
}
